"""`repro.faults`: deterministic, seedable fault injection.

Chaos testing only works when the chaos is *reproducible*: a failure the
harness provoked must be re-provokable from the same seed, or the test
that caught it cannot be rerun.  This module gives the whole stack ONE
injection mechanism:

  * **named sites** — the places a production failure can actually enter
    the system (:data:`SITES`): AIGER parsing, the prefetch thread, a
    packed device launch, the service prepare pool, the service device
    worker, and cache/journal loads.  Each site is a single
    :func:`fire` call in the product code; when no plan is installed
    that call is one global read and a ``None`` check.
  * **a FaultPlan** — per-site trigger specs (probability, exact
    nth-call, every-nth, latency, substring ``match`` against the call's
    tag) and an exception *kind* (transient / fatal / resource / kill /
    latency-only), all derived from one seed, so two runs of the same
    plan fail the same calls.
  * **one activation path** — ``SessionConfig(fault_plan=...)``,
    :func:`install`, or the ``$REPRO_FAULT_PLAN`` environment variable
    (read once at import): tests, benchmarks, and CI chaos lanes share
    the mechanism instead of each monkeypatching its own failures.

Plan spec grammar (also accepted as a JSON list of spec dicts)::

    site:key=value,key=value[;site:key=value,...]
    # 20% transient device failures, poison any tag containing "bad":
    service.device:p=0.2,kind=transient;service.device:match=bad,kind=fatal

Exception kinds map to classes the product code can classify:
:class:`TransientFault` (retryable), :class:`FatalFault` (never
retried), :class:`ResourceFault` (triggers the streaming executor's
capacity degradation), and :class:`WorkerKilled` — a ``BaseException``
that deliberately escapes worker-thread exception forwarding, i.e. an
abrupt thread death the watchdogs must detect.
"""
from __future__ import annotations

import dataclasses
import json
import os
import random
import threading
import time
from typing import Optional

#: the named injection points wired into the product code
SITES = (
    "io.parse",         # AIGER parsing (repro.io.aiger.loads)
    "exec.prefetch",    # streaming executor's host prefetch thread
    "exec.launch",      # streaming executor's packed device launch
    "mesh.launch",      # sharded executor's per-device lane launch
    "service.prepare",  # service prepare-pool task
    "service.device",   # service device-worker pack/stream call
    "cache.load",       # result-cache / partition-journal load
)

#: environment variable holding a plan spec, read once at import time —
#: how CI chaos lanes activate injection without touching code
PLAN_ENV = "REPRO_FAULT_PLAN"


class FaultError(RuntimeError):
    """Base class of every injected failure (except :class:`WorkerKilled`)."""


class TransientFault(FaultError):
    """An injected failure that a retry is expected to clear."""


class FatalFault(FaultError):
    """An injected failure that retrying can never clear (poisoned input)."""


class ResourceFault(FaultError):
    """An injected device resource exhaustion (triggers degradation)."""


class WorkerKilled(BaseException):
    """Simulated abrupt worker-thread death.

    Derives from ``BaseException`` and is deliberately NOT forwarded by
    worker-thread ``except`` clauses — the thread just dies, which is
    what an OS kill looks like.  Watchdogs must notice its absence.
    """


_KIND_EXC = {
    "transient": TransientFault,
    "fatal": FatalFault,
    "resource": ResourceFault,
    "kill": WorkerKilled,
}

#: kinds that only delay the call instead of failing it
_LATENCY_ONLY = ("latency", "delay")


@dataclasses.dataclass(frozen=True)
class SiteSpec:
    """One trigger rule at one site."""

    site: str
    p: float = 0.0                 # per-call trigger probability
    nth: Optional[int] = None      # trigger exactly the nth matching call (1-based)
    every: Optional[int] = None    # trigger every nth matching call
    latency_s: float = 0.0         # injected sleep when triggered
    kind: str = "transient"        # transient|fatal|resource|kill|latency
    match: Optional[str] = None    # only calls whose tag contains this substring
    max_fires: Optional[int] = None  # stop triggering after this many fires

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(f"unknown fault site {self.site!r} (know {SITES})")
        if self.kind not in _KIND_EXC and self.kind not in _LATENCY_ONLY:
            raise ValueError(
                f"unknown fault kind {self.kind!r} "
                f"(know {sorted(_KIND_EXC)} + {list(_LATENCY_ONLY)})"
            )

    def to_spec(self) -> str:
        parts = [self.site + ":"]
        kv = []
        if self.p:
            kv.append(f"p={self.p}")
        if self.nth is not None:
            kv.append(f"nth={self.nth}")
        if self.every is not None:
            kv.append(f"every={self.every}")
        if self.latency_s:
            kv.append(f"latency={self.latency_s}")
        if self.match is not None:
            kv.append(f"match={self.match}")
        if self.max_fires is not None:
            kv.append(f"max_fires={self.max_fires}")
        kv.append(f"kind={self.kind}")
        return parts[0] + ",".join(kv)


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A seeded set of :class:`SiteSpec` rules — the unit of activation."""

    specs: tuple = ()
    seed: int = 0

    @classmethod
    def parse(cls, text: str) -> "FaultPlan":
        """Parse the compact spec grammar (or a JSON list of spec dicts).

        ``seed=N`` may appear inside any site's key/value list; the last
        one wins for the whole plan.
        """
        text = text.strip()
        if not text:
            return cls()
        if text.startswith(("[", "{")):
            raw = json.loads(text)
            if isinstance(raw, dict):
                seed = int(raw.pop("seed", 0))
                raw = raw.get("specs", [])
            else:
                seed = 0
            return cls(specs=tuple(SiteSpec(**d) for d in raw), seed=seed)
        specs: list[SiteSpec] = []
        seed = 0
        for clause in text.split(";"):
            clause = clause.strip()
            if not clause:
                continue
            site, sep, body = clause.partition(":")
            if not sep:
                raise ValueError(f"bad fault clause {clause!r} (want site:k=v,...)")
            kw: dict = {"site": site.strip()}
            for item in body.split(","):
                item = item.strip()
                if not item:
                    continue
                k, sep, v = item.partition("=")
                if not sep:
                    raise ValueError(f"bad fault option {item!r} in {clause!r}")
                k = k.strip()
                v = v.strip()
                if k == "seed":
                    seed = int(v)
                elif k == "p":
                    kw["p"] = float(v)
                elif k in ("nth", "every", "max_fires"):
                    kw[k] = int(v)
                elif k in ("latency", "latency_s"):
                    kw["latency_s"] = float(v)
                elif k in ("kind", "match"):
                    kw[k] = v
                else:
                    raise ValueError(f"unknown fault option {k!r} in {clause!r}")
            specs.append(SiteSpec(**kw))
        return cls(specs=tuple(specs), seed=seed)

    @classmethod
    def coerce(cls, plan) -> "FaultPlan":
        """A :class:`FaultPlan` from a plan, a spec string, or None."""
        if plan is None:
            return cls()
        if isinstance(plan, cls):
            return plan
        return cls.parse(str(plan))

    def to_spec(self) -> str:
        """Round-trippable spec string (what ``$REPRO_FAULT_PLAN`` holds)."""
        clauses = [s.to_spec() for s in self.specs]
        if self.seed and clauses:
            clauses[0] += f",seed={self.seed}"
        return ";".join(clauses)

    def __bool__(self) -> bool:
        return bool(self.specs)


class FaultInjector:
    """Evaluates a :class:`FaultPlan` at every :func:`fire` call.

    Deterministic: each spec draws from its own ``random.Random`` seeded
    from ``(plan.seed, site, spec index)`` as a string (string seeding is
    stable across processes, unlike hash-based tuple seeding), and
    nth/every counters count only calls the spec's ``match`` accepts.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._lock = threading.Lock()
        self._by_site: dict[str, list] = {}
        for i, spec in enumerate(plan.specs):
            rng = random.Random(f"{plan.seed}:{spec.site}:{i}")
            # [spec, rng, matching-call count, fire count]
            self._by_site.setdefault(spec.site, []).append([spec, rng, 0, 0])
        self.calls: dict[str, int] = {}
        self.fired: dict[str, int] = {}

    def check(self, site: str, tag: Optional[str] = None) -> None:
        """Raise / sleep according to the plan; no-op for unplanned sites."""
        rules = self._by_site.get(site)
        with self._lock:
            self.calls[site] = self.calls.get(site, 0) + 1
            if not rules:
                return
            verdict = None    # (spec, exc_class or None)
            for rule in rules:
                spec, rng, _, fires = rule
                if spec.match is not None and spec.match not in (tag or ""):
                    continue
                rule[2] += 1
                n = rule[2]
                if spec.max_fires is not None and fires >= spec.max_fires:
                    continue
                hit = (
                    (spec.nth is not None and n == spec.nth)
                    or (spec.every is not None and n % spec.every == 0)
                    or (spec.p > 0.0 and rng.random() < spec.p)
                )
                if not hit:
                    continue
                rule[3] += 1
                self.fired[site] = self.fired.get(site, 0) + 1
                verdict = (spec, _KIND_EXC.get(spec.kind))
                break
        if verdict is None:
            return
        spec, exc_cls = verdict
        if spec.latency_s > 0.0:
            time.sleep(spec.latency_s)
        if exc_cls is not None:
            detail = f" (tag={tag!r})" if tag else ""
            raise exc_cls(f"injected {spec.kind} fault at {site}{detail}")

    def stats(self) -> dict:
        with self._lock:
            return {"calls": dict(self.calls), "fired": dict(self.fired)}


#: the installed injector; None means every ``fire()`` is a cheap no-op
_ACTIVE: Optional[FaultInjector] = None


def fire(site: str, tag: Optional[str] = None) -> None:
    """The product-code hook: evaluate the active plan at ``site``.

    The inactive path (no plan installed — i.e. production) is a single
    global load and ``None`` check; keep call sites coarse-grained (per
    parse / per launch, never per node) and this stays unmeasurable.
    ``tag`` may be a zero-arg callable — it is only evaluated when a plan
    is active, so call sites can attach identity tags without paying for
    their construction in production.
    """
    inj = _ACTIVE
    if inj is not None:
        inj.check(site, tag() if callable(tag) else tag)


def install(plan) -> Optional[FaultInjector]:
    """Install a plan (FaultPlan | spec string | None) process-wide;
    returns the injector (None when the plan is empty)."""
    global _ACTIVE
    plan = FaultPlan.coerce(plan)
    _ACTIVE = FaultInjector(plan) if plan else None
    return _ACTIVE


def uninstall() -> None:
    global _ACTIVE
    _ACTIVE = None


def active() -> Optional[FaultInjector]:
    return _ACTIVE


class injected:
    """Context manager for tests: install a plan, restore on exit."""

    def __init__(self, plan):
        self.plan = plan
        self._prev: Optional[FaultInjector] = None

    def __enter__(self) -> Optional[FaultInjector]:
        global _ACTIVE
        self._prev = _ACTIVE
        return install(self.plan)

    def __exit__(self, *exc):
        global _ACTIVE
        _ACTIVE = self._prev


def is_resource_error(exc: BaseException) -> bool:
    """Classify device resource exhaustion — the trigger for the streaming
    executor's capacity degradation.  Covers injected :class:`ResourceFault`,
    host ``MemoryError``, and XLA's RESOURCE_EXHAUSTED / out-of-memory
    runtime errors (matched by message: the class lives in jaxlib and we
    must classify without importing it)."""
    if isinstance(exc, (ResourceFault, MemoryError)):
        return True
    msg = str(exc)
    return "RESOURCE_EXHAUSTED" in msg or "Out of memory" in msg or (
        type(exc).__name__ == "XlaRuntimeError" and "oom" in msg.lower()
    )


# import-time env activation: CI chaos lanes export $REPRO_FAULT_PLAN and
# run unmodified entry points
if os.environ.get(PLAN_ENV):
    install(os.environ[PLAN_ENV])
