"""`repro`: the console entry point over :class:`repro.api.Session`.

    repro verify design.aig               # train a small model, route, verify
    repro verify csa:32 booth:16 --backend groot_fused --partitions 8
    repro explain design.aig --budget-mb 64   # the routing decision only
    repro serve --designs csa:8,csa:16 --repeat 2   # the batched service
    repro serve ... --metrics-port 9100   # + /metrics + /stats endpoint
    repro top 127.0.0.1:9100              # live view of a running service

``verify``/``explain`` accept AIGER files (``.aig``/``.aag``) and
``family:bits`` generator specs interchangeably.  ``explain`` needs no
trained model — routing is host-side only.
"""
from __future__ import annotations

import argparse
import os
import sys
from typing import Optional


def _session_args(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("designs", nargs="+",
                    help="AIGER files (.aig/.aag) or family:bits specs "
                         "(csa:32, booth:16, mapped:8, fpga:8)")
    ap.add_argument("--backend", default="ref",
                    help="aggregation backend: ref | onehot | groot | "
                         "groot_mxu | groot_fused")
    ap.add_argument("--partitions", type=int, default=1)
    ap.add_argument("--no-regrow", action="store_true")
    ap.add_argument("--hops", type=int, default=1,
                    help="re-growth depth (>= GNN layers -> bit-exact)")
    ap.add_argument("--budget-mb", type=float, default=None,
                    help="device memory budget; the router partitions and "
                         "streams designs that exceed it")
    ap.add_argument("--stream-dtype", default=None,
                    help='staged edge-stream dtype (e.g. "bfloat16")')
    ap.add_argument("--devices", type=int, default=None,
                    help="shard the streamed route across N mesh devices "
                         "(repro.mesh); default: every visible device "
                         "when more than one exists.  CPU hosts fake "
                         "devices via XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="journal streamed partition results under this "
                         "directory so a killed run can resume")
    ap.add_argument("--resume", dest="resume", action="store_true",
                    default=True,
                    help="restore a prior partial run from --checkpoint-dir "
                         "(default)")
    ap.add_argument("--no-resume", dest="resume", action="store_false",
                    help="ignore (wipe) any prior journal and run fresh")
    ap.add_argument("--fault-plan", default=None,
                    help="chaos testing: a repro.faults plan spec, e.g. "
                         '"exec.launch:p=0.1,kind=transient,seed=7" '
                         "(also honoured from $REPRO_FAULT_PLAN)")


def _make_session(args):
    from repro.api import Session, SessionConfig

    budget = None
    if args.budget_mb is not None:
        budget = int(args.budget_mb * 1e6)
    return Session(config=SessionConfig(
        backend=args.backend,
        num_partitions=args.partitions,
        regrow=not args.no_regrow,
        regrow_hops=args.hops,
        memory_budget_bytes=budget,
        stream_dtype=args.stream_dtype,
        mesh_devices=getattr(args, "devices", None),
        trace=bool(getattr(args, "trace", None)),
        checkpoint_dir=getattr(args, "checkpoint_dir", None),
        resume=getattr(args, "resume", True),
        fault_plan=getattr(args, "fault_plan", None),
    ))


def _resolve(spec: str):
    """A design argument -> (design-or-None, dataset, bits) for the façade.

    Raises SystemExit with a usable message on a bad spec, so callers can
    validate every argument up front (before minutes of training).
    """
    if os.path.exists(spec) or spec.endswith((".aig", ".aag")):
        if not os.path.exists(spec):
            raise SystemExit(f"repro: AIGER file not found: {spec}")
        return spec, None, None
    fam, _, bits = spec.partition(":")
    try:
        return None, fam, int(bits or 8)
    except ValueError:
        raise SystemExit(
            f"repro: bad design spec {spec!r} (want an .aig/.aag path or "
            f"family:bits, e.g. csa:32)"
        ) from None


def _print_decision(label: str, d) -> None:
    devices = f" devices={d.mesh_devices}" if d.mesh_devices > 1 else ""
    print(f"{label}: mode={d.mode} backend={d.backend} k={d.k} "
          f"buckets={d.num_buckets}{list(d.buckets) if d.buckets else ''}"
          f"{devices}")
    print(f"    nodes={d.num_nodes} edges={d.num_edges} "
          f"modeled full={d.modeled_full_bytes/1e6:.1f} MB "
          f"peak={d.modeled_peak_bytes/1e6:.1f} MB "
          f"budget={'-' if d.memory_budget_bytes is None else f'{d.memory_budget_bytes/1e6:.1f} MB'}")
    print(f"    {d.reason}")


def cmd_explain(args) -> int:
    sess = _make_session(args)
    for spec in args.designs:
        design, dataset, bits = _resolve(spec)
        _print_decision(spec, sess.explain(design, dataset=dataset, bits=bits))
    return 0


def cmd_verify(args) -> int:
    # resolve every spec BEFORE training: a typo must fail in milliseconds,
    # not after the (minutes-long) training run
    resolved = [_resolve(spec) for spec in args.designs]
    sess = _make_session(args)
    print(f"training groot-gnn on csa {args.train_bits}b "
          f"({args.epochs} epochs)...")
    sess.train("csa", args.train_bits, epochs=args.epochs)
    print(f"\n{'design':>24} {'route':>12} {'status':>13} {'acc':>7} "
          f"{'nodes':>8} {'peak_MB':>8} {'total_s':>8}")
    bad = 0
    for design, dataset, bits in resolved:
        r = sess.verify(design, dataset=dataset, bits=bits,
                        verify=not args.no_verify)
        bad += r.status in ("falsified", "error")
        print(f"{r.name:>24} {r.routing.mode:>12} {r.status:>13} "
              f"{r.accuracy:7.4f} {r.num_nodes:>8} "
              f"{r.peak_memory_bytes/1e6:8.1f} {r.timings['total']:8.3f}")
        if args.explain:
            _print_decision("  routing", r.routing)
    if args.trace:
        sess.save_trace(args.trace)
        print(f"\ntrace written to {args.trace}")
    return 1 if bad else 0


def cmd_top(args) -> int:
    """Live terminal view of a running service: poll its ``/stats`` JSON
    endpoint (``repro serve --metrics-port N``) and render the hot
    numbers.  ``--iterations`` bounds the loop (tests; one-shot peeks)."""
    import json
    import time
    import urllib.request

    url = args.url.rstrip("/")
    if "://" not in url:
        url = f"http://{url}"
    n = 0
    while args.iterations is None or n < args.iterations:
        try:
            with urllib.request.urlopen(f"{url}/stats", timeout=5) as resp:
                stats = json.load(resp)
        except OSError as e:
            print(f"repro top: cannot reach {url}/stats ({e})", file=sys.stderr)
            return 1
        svc = stats.get("service", stats)
        obs = svc.get("obs", {})
        gauges, hists = obs.get("gauges", {}), obs.get("histograms", {})
        flights = svc.get("flights", {})
        cache = svc.get("cache", {})
        if isinstance(cache, str):       # dataclass stringified by the server
            cache = {}
        if n:
            print()
        print(f"-- repro top @ {time.strftime('%H:%M:%S')} ({url}) --")
        print(f"queue depth {gauges.get('service.queue_depth', {}).get('value', 0):>4}"
              f"  (peak {gauges.get('service.queue_depth', {}).get('max', 0)})"
              f"   slots {gauges.get('service.slot_occupancy', {}).get('value', 0):>3}"
              f"  (peak {gauges.get('service.slot_occupancy', {}).get('max', 0)})")
        print(f"device calls {svc.get('device_calls', 0):>5}"
              f"   compiles {svc.get('compile_count', 0):>4}"
              f"   cold {svc.get('cold_compiles', 0):>3}"
              f"   streamed {svc.get('streamed_items', 0):>4}")
        print(f"flights: {flights.get('recorded', 0)} recorded, "
              f"{flights.get('failures', 0)} failed, "
              f"{flights.get('retained', 0)}/{flights.get('capacity', 0)} retained")
        for stage in ("prepare_s", "queue_wait_s", "infer_s", "verify_s"):
            h = hists.get(f"service.{stage}")
            if h:
                print(f"  {stage:<13} n={h.get('count', 0):<6} "
                      f"p50={h.get('p50', 0) * 1e3:8.2f} ms  "
                      f"p95={h.get('p95', 0) * 1e3:8.2f} ms")
        n += 1
        if args.iterations is None or n < args.iterations:
            time.sleep(args.interval)
    return 0


def main(argv: Optional[list] = None) -> int:
    argv = sys.argv[1:] if argv is None else list(argv)
    if argv and argv[0] == "serve":
        # hand everything (flags included) to the service CLI untouched —
        # argparse.REMAINDER cannot capture leading options
        from repro.service.server import main as serve_main

        serve_main(argv[1:])
        return 0

    ap = argparse.ArgumentParser(
        prog="repro", description="GROOT verification stack (repro.api)"
    )
    sub = ap.add_subparsers(dest="cmd", required=True)

    v = sub.add_parser("verify", help="train a small model, route, verify")
    _session_args(v)
    v.add_argument("--train-bits", type=int, default=8)
    v.add_argument("--epochs", type=int, default=300)
    v.add_argument("--no-verify", action="store_true",
                   help="classification only (skip adder extraction)")
    v.add_argument("--explain", action="store_true",
                   help="also print each design's routing decision")
    v.add_argument("--trace", metavar="OUT.json", default=None,
                   help="record spans for every verify and write a "
                        "Chrome-trace JSON (open in chrome://tracing "
                        "or Perfetto)")
    v.set_defaults(fn=cmd_verify)

    e = sub.add_parser("explain",
                       help="print the routing decision without running")
    _session_args(e)
    e.set_defaults(fn=cmd_explain)

    # listed for --help only; dispatched above before parsing
    sub.add_parser("serve", help="run the batched verification service "
                                 "(args pass through to repro.service.server)")

    t = sub.add_parser("top", help="live view of a running service "
                                   "(polls serve --metrics-port's /stats)")
    t.add_argument("url", nargs="?", default="127.0.0.1:9100",
                   help="host:port of the service's metrics endpoint")
    t.add_argument("--interval", type=float, default=2.0,
                   help="seconds between polls")
    t.add_argument("--iterations", type=int, default=None,
                   help="stop after N polls (default: run until ^C)")
    t.set_defaults(fn=cmd_top)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
