"""Logical-axis -> mesh-axis sharding rules (GSPMD style).

Model code annotates activations with *logical* axes (``batch``, ``seq``,
``heads`` ...); parameters carry logical axes in their
:class:`repro.zoo.configs.base.ParamSpec`.  This module maps them onto the
production mesh:

  single pod:  (16, 16)    axes ("data", "model")
  multi-pod:   (2, 16, 16) axes ("pod", "data", "model")

Rules (Megatron-style TP over "model", DP over "pod"+"data"):

  batch       -> ("pod", "data")      activations' leading dim
  seq_shard   -> "model"              sequence-parallel residuals (saved
                                      activations between blocks)
  heads/kv_heads/heads_flat -> model  attention TP
  d_ff        -> model                MLP TP
  vocab       -> model                embedding/logits TP
  experts     -> model                expert parallelism
  d_model     -> None (or "data" under FSDP for the giant archs)
  layers      -> None                 scan axis

A dim is left unsharded whenever its size does not divide the mesh axis
(e.g. kv_heads=8 on model=16 -> replicated KV, standard GQA TP).
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import math
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def make_rules(mesh: Mesh, *, fsdp: bool = False, sp: bool = False) -> dict:
    """Logical axis -> mesh axis (or tuple of mesh axes).

    ``sp``: Megatron-style sequence-parallel residuals (seq over "model").
    Measured effect (EXPERIMENTS.md §Perf): shrinks saved-activation bytes
    ~16x but adds two reshard collectives per layer — a win only for the
    memory-starved giant-MoE train cells, a 25x collective regression for
    the dense <=10B archs.  Default off.
    """
    has_pod = "pod" in mesh.axis_names
    batch_axes = ("pod", "data") if has_pod else ("data",)
    rules = {
        "batch": batch_axes,
        # full data-parallel reshard (batch over every axis incl. model):
        # used by attention layers whose head count does not divide TP
        # (qwen2: 28H, llama4: 40H, whisper: 8H) — without it their
        # attention compute replicates 16x over "model" (measured 5.3x
        # total-FLOP inflation on qwen2 train, EXPERIMENTS.md §Perf).
        "batch_all": batch_axes + ("model",),
        "seq_shard": "model" if sp else None,
        "kv_seq": "model",
        "heads": "model",
        "kv_heads": "model",
        "heads_flat": "model",
        "d_ff": "model",
        "vocab": "model",
        "experts": "model",
        "d_model": "data" if fsdp else None,
        "layers": None,
        None: None,
    }
    return rules


def _axis_size(mesh: Mesh, mesh_axes) -> int:
    if mesh_axes is None:
        return 1
    if isinstance(mesh_axes, str):
        mesh_axes = (mesh_axes,)
    return int(np.prod([mesh.shape[a] for a in mesh_axes]))


def partition_spec(shape, logical_axes, mesh: Mesh, rules: dict) -> P:
    """Build a PartitionSpec, dropping non-divisible / duplicate axes."""
    used: set = set()
    parts = []
    for size, ax in zip(shape, logical_axes):
        mesh_ax = rules.get(ax)
        if mesh_ax is None:
            parts.append(None)
            continue
        axes_t = (mesh_ax,) if isinstance(mesh_ax, str) else tuple(mesh_ax)
        if any(a in used for a in axes_t) or size % _axis_size(mesh, axes_t) != 0:
            parts.append(None)
            continue
        used.update(axes_t)
        parts.append(mesh_ax if isinstance(mesh_ax, str) else tuple(mesh_ax))
    return P(*parts)


def sharding_for_spec(spec, mesh: Mesh, rules: dict) -> NamedSharding:
    return NamedSharding(mesh, partition_spec(spec.shape, spec.axes, mesh, rules))


def tree_shardings(spec_tree, mesh: Mesh, rules: dict):
    """NamedSharding tree matching a ParamSpec tree."""
    from repro.zoo.configs.base import ParamSpec

    return jax.tree.map(
        lambda s: sharding_for_spec(s, mesh, rules),
        spec_tree,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


# ---------------------------------------------------------------------------
# Activation-sharding context (model code is mesh-agnostic)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardingCtx:
    mesh: Mesh
    rules: dict


_CTX: contextvars.ContextVar = contextvars.ContextVar("sharding_ctx", default=None)


def current_ctx() -> Optional[ShardingCtx]:
    return _CTX.get()


@contextlib.contextmanager
def use_sharding(mesh: Optional[Mesh], *, fsdp: bool = False, sp: bool = False):
    if mesh is None:
        yield None
        return
    tok = _CTX.set(ShardingCtx(mesh, make_rules(mesh, fsdp=fsdp, sp=sp)))
    try:
        yield _CTX.get()
    finally:
        _CTX.reset(tok)


def shard(x: jax.Array, logical_axes: tuple) -> jax.Array:
    """Constrain an activation's sharding by logical axes (no-op without
    an active :func:`use_sharding` context — smoke tests run unsharded)."""
    ctx = current_ctx()
    if ctx is None:
        return x
    spec = partition_spec(x.shape, logical_axes, ctx.mesh, ctx.rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))
