from repro.sharding.rules import (  # noqa: F401
    ShardingCtx,
    current_ctx,
    make_rules,
    shard,
    sharding_for_spec,
    tree_shardings,
    use_sharding,
)
