"""Partition execution plans: the host-side schedule of a streamed run.

A :class:`PartitionPlan` is everything the streaming executor needs to
drive an arbitrarily large design through device-sized launches, computed
ONCE per design:

  * the k-way partition + boundary re-growth (paper §III-C / Algorithm 1),
  * the pow-2 shape bucket each subgraph falls in (the compile-unit
    equivalence classes of ``repro.service.bucketing``),
  * a deterministic batch schedule grouping same-bucket subgraphs into
    ``capacity``-slot packed launches.

Plans are pure functions of (graph structure, partition knobs), so they are
content-hash cached in the process-wide structural
:data:`~repro.kernels.plan_cache.PLAN_CACHE` — a regression farm
resubmitting the same netlist repartitions nothing.

``choose_k`` closes the loop with the device: given a memory budget it
picks the partition count from the analytic
:func:`repro.core.pipeline.memory_model_bytes` model, accounting for halo
growth, pow-2 padding, and the ``capacity`` slots resident per launch —
the knob that lets a 1,024-bit multiplier fit one accelerator.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Optional

import numpy as np

from repro.core.graph import EdgeGraph
from repro.core.partition import PARTITIONERS
from repro.core.regrowth import Subgraph, boundary_edge_fraction, extract_partitions
from repro.kernels import ops
from repro.kernels.plan_cache import PlanCache, graph_key
from repro.obs import REGISTRY, span
from repro.service.bucketing import BucketShape

#: Dedicated cache for execution plans, NOT the kernel-layer PLAN_CACHE:
#: a PartitionPlan embeds every subgraph's arrays (roughly the whole
#: design plus halo), so entries are design-sized — a small LRU bounds
#: host memory where the 256-entry kernel cache (sized for small
#: SpmmPlan/AggPair closures) would not.  Plans are also built OUTSIDE
#: the cache lock (peek/add): partitioning a huge design must not stall
#: concurrent make_agg_pair/cached_plan users.
EXEC_PLAN_CACHE = PlanCache(capacity=8)

#: Assumed relative halo growth of a re-grown partition (the paper observes
#: ~10% boundary edges on METIS-partitioned AIGs; 15% is a safe planning
#: margin).  Only used for *estimates* (choose_k) — the built plan uses the
#: real subgraph sizes.
HALO_FRAC = 0.15


@dataclasses.dataclass(frozen=True)
class PartitionPlan:
    """Partition + bucket assignment for one design (immutable, cacheable)."""

    num_nodes: int               # global node count (scatter target size)
    num_edges: int
    k: int                       # requested partition count
    regrow: bool
    partitioner: str
    seed: int
    min_nodes: int               # bucket floors (compile-unit quantisation)
    min_edges: int
    subgraphs: tuple[Subgraph, ...]
    buckets: tuple[BucketShape, ...]   # distinct shapes, sorted ascending
    bucket_of: np.ndarray        # (num_parts,) int32 -> index into buckets
    boundary_edge_frac: float

    @property
    def num_parts(self) -> int:
        return len(self.subgraphs)

    @property
    def num_buckets(self) -> int:
        return len(self.buckets)

    def schedule(self, capacity: int) -> list[tuple[BucketShape, list[int]]]:
        """Deterministic launch schedule: same-bucket subgraphs chunked
        ``capacity`` at a time, buckets in ascending shape order."""
        assert capacity >= 1
        out: list[tuple[BucketShape, list[int]]] = []
        for bi, shape in enumerate(self.buckets):
            members = [i for i in range(self.num_parts) if self.bucket_of[i] == bi]
            for j in range(0, len(members), capacity):
                out.append((shape, members[j : j + capacity]))
        return out

    def peak_batch_memory_bytes(self, gnn_cfg, capacity: int) -> int:
        """Modeled device bytes of the largest packed launch (what is
        resident while the device runs: ``capacity`` padded slots of the
        biggest bucket)."""
        from repro.core.pipeline import memory_model_bytes

        if not self.buckets:
            return 0
        big = self.buckets[-1]
        return memory_model_bytes(capacity * big.n_pad, capacity * big.e_pad, gnn_cfg)

    def peak_layer_traffic_bytes(
        self, gnn_cfg, capacity: int, *, hoisted: bool = True,
        stream_dtype: str | None = None,
    ) -> int:
        """Modeled per-layer HBM traffic of the largest packed launch
        (the ForwardPlan hoisting before/after comparison the partitioned
        benchmark reports — packed streamed batches inherit hoisted plans
        through ``make_agg_pair``)."""
        from repro.core.pipeline import layer_traffic_model_bytes

        if not self.buckets:
            return 0
        big = self.buckets[-1]
        return layer_traffic_model_bytes(
            capacity * big.n_pad, capacity * big.e_pad, gnn_cfg,
            hoisted=hoisted, stream_dtype=stream_dtype,
        )


def _bucket_for(num_nodes: int, num_edges: int, min_nodes: int, min_edges: int) -> BucketShape:
    n_pad, e_pad = ops.padded_shape(
        num_nodes, num_edges, min_nodes=min_nodes, min_edges=min_edges
    )
    return BucketShape(n_pad, e_pad)


def plan_from_subgraphs(
    subgraphs: list[Subgraph],
    num_nodes: int,
    *,
    num_edges: int = 0,
    regrow: bool = True,
    partitioner: str = "precomputed",
    seed: int = 0,
    min_nodes: int = 64,
    min_edges: int = 128,
) -> PartitionPlan:
    """Wrap already-extracted partitions (``predict_partitioned``'s input)
    into a plan: assigns buckets, no re-partitioning."""
    shapes = [
        _bucket_for(sg.num_nodes, sg.num_edges, min_nodes, min_edges)
        for sg in subgraphs
    ]
    buckets = sorted(set(shapes), key=lambda b: (b.n_pad, b.e_pad))
    index = {b: i for i, b in enumerate(buckets)}
    return PartitionPlan(
        num_nodes=num_nodes,
        num_edges=num_edges,
        k=len(subgraphs),
        regrow=regrow,
        partitioner=partitioner,
        seed=seed,
        min_nodes=min_nodes,
        min_edges=min_edges,
        subgraphs=tuple(subgraphs),
        buckets=tuple(buckets),
        bucket_of=np.array([index[s] for s in shapes], dtype=np.int32),
        boundary_edge_frac=0.0,
    )


def build_partition_plan(
    graph: EdgeGraph,
    k: int,
    *,
    regrow: bool = True,
    hops: int = 1,
    partitioner: str = "multilevel",
    seed: int = 0,
    min_nodes: int = 64,
    min_edges: int = 128,
    use_cache: bool = True,
) -> PartitionPlan:
    """Partition + re-growth + bucket assignment for one design.

    ``hops`` is the re-growth depth (iterated Algorithm 1; ``hops >=
    num_layers`` makes core predictions bit-exact with the full graph).

    Content-hash cached: the same (structure, knobs) always returns the
    SAME plan object, so repeated streamed runs over a recurring design
    skip the whole host-side partitioning pass.
    """

    def _build() -> PartitionPlan:
        with span("exec.plan_build", k=k, partitioner=partitioner):
            REGISTRY.counter("exec.plan_builds").inc()
            part = PARTITIONERS[partitioner](graph, k, seed=seed)
            bfrac = boundary_edge_fraction(graph, part) if part.size else 0.0
            subs = extract_partitions(graph, part, regrow=regrow, hops=hops)
            plan = plan_from_subgraphs(
                subs,
                graph.num_nodes,
                num_edges=graph.num_edges,
                regrow=regrow,
                partitioner=partitioner,
                seed=seed,
                min_nodes=min_nodes,
                min_edges=min_edges,
            )
            return dataclasses.replace(plan, k=k, boundary_edge_frac=bfrac)

    if not use_cache:
        return _build()
    key = (
        "exec_plan",
        graph_key(graph.edge_src, graph.edge_dst, graph.num_nodes),
        _annotation_key(graph),
        k, regrow, hops, partitioner, seed, min_nodes, min_edges,
    )
    cached = EXEC_PLAN_CACHE.peek(key)
    if cached is not None:
        REGISTRY.counter("exec.plan_cache_hits").inc()
        return cached
    return EXEC_PLAN_CACHE.add(key, _build())


def _annotation_key(graph: EdgeGraph) -> str:
    """Digest of edge_inv/edge_slot.  ``graph_key`` hashes endpoints only
    (right for SpmmPlans, which are structure-pure), but a PartitionPlan
    embeds the annotation slices in its Subgraphs — two designs with the
    same connectivity and different inverter placement must NOT share a
    cached plan."""
    h = hashlib.sha256()
    for arr in (graph.edge_inv, graph.edge_slot):
        if arr is None:
            h.update(b"~")
        else:
            h.update(np.ascontiguousarray(np.asarray(arr, np.uint8)).tobytes())
        h.update(b"|")
    return h.hexdigest()


# ---------------------------------------------------------------------------
# Budget-driven partition-count selection
# ---------------------------------------------------------------------------

def _estimated_partition_bucket(
    num_nodes: int,
    num_edges: int,
    k: int,
    *,
    halo_frac: float,
    min_nodes: int,
    min_edges: int,
) -> tuple[int, int]:
    """Padded (n_pad, e_pad) bucket of one partition if the design is cut
    k ways: per-partition share + halo margin, pow-2 padded.  The ONE
    sizing estimate both choosers share."""
    n_part = int(np.ceil(num_nodes / k * (1.0 + halo_frac)))
    e_part = int(np.ceil(num_edges / k * (1.0 + halo_frac)))
    return ops.padded_shape(n_part, e_part, min_nodes=min_nodes, min_edges=min_edges)


def _estimated_batch_bytes(
    num_nodes: int,
    num_edges: int,
    k: int,
    gnn_cfg,
    capacity: int,
    *,
    halo_frac: float,
    min_nodes: int,
    min_edges: int,
) -> int:
    """Modeled bytes of one ``capacity``-slot packed launch at cut k."""
    from repro.core.pipeline import memory_model_bytes

    n_pad, e_pad = _estimated_partition_bucket(
        num_nodes, num_edges, k,
        halo_frac=halo_frac, min_nodes=min_nodes, min_edges=min_edges,
    )
    return memory_model_bytes(capacity * n_pad, capacity * e_pad, gnn_cfg)


def choose_k(
    num_nodes: int,
    num_edges: int,
    gnn_cfg,
    budget_bytes: int,
    *,
    capacity: int = 2,
    halo_frac: float = HALO_FRAC,
    min_nodes: int = 64,
    min_edges: int = 128,
    max_k: Optional[int] = None,
) -> int:
    """Smallest power-of-two k whose packed launches fit ``budget_bytes``.

    Walks k = 1, 2, 4, ... through the analytic memory model (per-partition
    share + ``halo_frac`` re-growth margin, padded to the pow-2 bucket,
    times the ``capacity`` slots resident per launch).  Returns the cap
    (``max_k`` or the node count) if even the finest cut does not fit —
    callers stream the best they can rather than reject.
    """
    if num_nodes <= 0:
        return 1
    cap = max(1, min(max_k or num_nodes, num_nodes))
    k = 1
    while k < cap:
        need = _estimated_batch_bytes(
            num_nodes, num_edges, k, gnn_cfg, capacity,
            halo_frac=halo_frac, min_nodes=min_nodes, min_edges=min_edges,
        )
        if need <= budget_bytes:
            return k
        k *= 2
    return min(k, cap)


def choose_k_for_caps(
    num_nodes: int,
    num_edges: int,
    max_bucket_nodes: int,
    max_bucket_edges: Optional[int] = None,
    *,
    halo_frac: float = HALO_FRAC,
    min_nodes: int = 64,
    min_edges: int = 128,
) -> int:
    """Smallest power-of-two k whose per-partition bucket fits a shape cap.

    The scheduler-side chooser: the service bounds its compile units by the
    largest allowed bucket shape rather than a byte budget (shape, not
    bytes, is what jit specialises on).
    """
    if num_nodes <= 0:
        return 1
    k = 1
    while k < num_nodes:
        n_pad, e_pad = _estimated_partition_bucket(
            num_nodes, num_edges, k,
            halo_frac=halo_frac, min_nodes=min_nodes, min_edges=min_edges,
        )
        if n_pad <= max_bucket_nodes and (
            max_bucket_edges is None or e_pad <= max_bucket_edges
        ):
            return k
        k *= 2
    return min(k, num_nodes)
