"""Partitioned streaming execution: run arbitrarily large AIGs through
bucketed, plan-cached, double-buffered partition batches.

    EdgeGraph ──▶ PartitionPlan (partition + re-growth + pow-2 buckets,
               │   content-hash cached; choose_k picks k from a device
               │   memory budget)
               ├─▶ PackedBatch stream (capacity same-bucket subgraphs per
               │   disjoint-union launch; features staged by the prefetch
               │   thread)
               └─▶ StreamingExecutor (one jitted padded forward per bucket;
                   core predictions scattered back to global rows)

The layer every multi-device / sharding PR builds on: a design that does
not fit the device is expressed as a stream of device-sized launches with
a handful of compile units.
"""
from repro.exec.plan import (  # noqa: F401
    PartitionPlan,
    build_partition_plan,
    choose_k,
    choose_k_for_caps,
    plan_from_subgraphs,
)
from repro.exec.packing import PackedBatch, pack_partitions  # noqa: F401
from repro.exec.stream import (  # noqa: F401
    StreamingExecutor,
    StreamStats,
    stream_predict_partitioned,
)

__all__ = [
    "PartitionPlan", "build_partition_plan", "choose_k", "choose_k_for_caps",
    "plan_from_subgraphs", "PackedBatch", "pack_partitions",
    "StreamingExecutor", "StreamStats", "stream_predict_partitioned",
]
