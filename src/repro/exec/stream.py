"""Double-buffered streaming execution of partition plans.

:class:`StreamingExecutor` turns a :class:`~repro.exec.plan.PartitionPlan`
into a stream of packed launches through ONE jitted padded forward pass
(the service layer's :class:`~repro.service.scheduler.BucketRunner`):

    host prefetch thread                 device (caller thread)
    --------------------                 ----------------------
    pack batch 0  ──queue──▶
    pack batch 1  ──queue──▶             run batch 0, scatter cores
    pack batch 2  ──queue──▶             run batch 1, scatter cores
    ...                                  ...

While the device runs batch *i*, the prefetch thread gathers and pads
batch *i+1*'s features — the host staging that made the sequential
``predict_partitioned`` loop transfer-bound.  The queue depth
(``prefetch``) bounds host memory: at most ``prefetch + 1`` packed batches
exist at once, so the host footprint is O(batch), not O(design).

Compile discipline: every launch of the same bucket reuses the same jit
executable, so a whole streamed run compiles at most ``plan.num_buckets``
programs for shape-stable backends ("ref"/"onehot") — the probe-asserted
acceptance criterion.  Structure-keyed ``groot*`` backends compile per
distinct packed structure instead (each batch's degree plan is a jit
constant); recurring designs still hit the process-wide plan cache and
compile nothing new.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Optional

import numpy as np

from repro import faults
from repro.core.graph import EdgeGraph
from repro.core.regrowth import Subgraph
from repro.exec.packing import PackedBatch, pack_partitions, scatter_core_predictions
from repro.exec.plan import PartitionPlan, build_partition_plan, plan_from_subgraphs
from repro.obs import REGISTRY, current_tracer, span
from repro.service.scheduler import BucketRunner


@dataclasses.dataclass
class StreamStats:
    """Probe counters for one executor (cumulative across runs)."""

    runs: int = 0                 # run_plan invocations
    batches: int = 0              # packed launches issued
    partitions: int = 0           # subgraphs streamed
    core_rows: int = 0            # core predictions scattered
    compiles: int = 0             # jit traces of the padded forward
    launches: int = 0             # device calls
    bytes_h2d: int = 0            # staged host->device transfer bytes
    pack_s: float = 0.0           # host packing time (prefetch thread)
    device_s: float = 0.0         # device execution + readback time
    wall_s: float = 0.0           # end-to-end streamed time
    max_queue_depth: int = 0      # prefetch occupancy high-water mark
    # failure-domain counters: launches replayed at reduced pack capacity
    # after a device resource error, and partitions skipped on a resumed
    # run because a journal already held their core predictions
    capacity_halvings: int = 0
    resumed_partitions: int = 0
    # model-vs-actual memory accounting (high-water marks): what the plan
    # modeled as the packed-launch peak vs the model evaluated on the
    # REAL launched padded shapes — the validation loop for choose_k
    modeled_peak_bytes: int = 0
    actual_peak_bytes: int = 0

    @property
    def overlap_s(self) -> float:
        """Host pack time hidden behind device execution."""
        return max(0.0, self.pack_s + self.device_s - self.wall_s)

    def delta(self, before: "StreamStats") -> "StreamStats":
        """Per-run view: this (cumulative) snapshot minus ``before``.
        High-water marks (``max_queue_depth``, ``*_peak_bytes``) keep the
        later value — a peak has no meaningful difference."""
        return StreamStats(
            runs=self.runs - before.runs,
            batches=self.batches - before.batches,
            partitions=self.partitions - before.partitions,
            core_rows=self.core_rows - before.core_rows,
            compiles=self.compiles - before.compiles,
            launches=self.launches - before.launches,
            bytes_h2d=self.bytes_h2d - before.bytes_h2d,
            pack_s=self.pack_s - before.pack_s,
            device_s=self.device_s - before.device_s,
            wall_s=self.wall_s - before.wall_s,
            capacity_halvings=self.capacity_halvings - before.capacity_halvings,
            resumed_partitions=self.resumed_partitions - before.resumed_partitions,
            max_queue_depth=self.max_queue_depth,
            modeled_peak_bytes=self.modeled_peak_bytes,
            actual_peak_bytes=self.actual_peak_bytes,
        )


_SENTINEL = object()


class StreamingExecutor:
    """Drives partition plans through bucketed, double-buffered launches."""

    def __init__(
        self,
        params=None,
        backend: str = "ref",
        *,
        runner: Optional[BucketRunner] = None,
        capacity: int = 2,
        prefetch: int = 1,
        min_nodes: int = 64,
        min_edges: int = 128,
        stream_dtype: Optional[str] = None,
    ):
        """Either ``params`` (a fresh runner is built) or an existing
        ``runner`` (the service scheduler shares its compile probe)."""
        if runner is None:
            if params is None:
                raise ValueError("need params or a BucketRunner")
            runner = BucketRunner(params, backend, stream_dtype=stream_dtype)
        self.runner = runner
        self.capacity = max(1, capacity)
        self.prefetch = max(0, prefetch)
        self.min_nodes = min_nodes
        self.min_edges = min_edges
        self.stats = StreamStats()
        #: every distinct bucket shape streamed through this executor —
        #: the denominator of the compile-count probe (for shape-stable
        #: backends, runner.compile_count <= len(buckets_seen))
        self.buckets_seen: set = set()

    # -- plan construction helpers ------------------------------------------

    def plan_graph(
        self,
        graph: EdgeGraph,
        k: int,
        *,
        regrow: bool = True,
        hops: int = 1,
        partitioner: str = "multilevel",
        seed: int = 0,
    ) -> PartitionPlan:
        return build_partition_plan(
            graph, k, regrow=regrow, hops=hops, partitioner=partitioner,
            seed=seed, min_nodes=self.min_nodes, min_edges=self.min_edges,
        )

    # -- execution ----------------------------------------------------------

    def run_plan(self, plan: PartitionPlan, features: np.ndarray,
                 gnn_cfg=None, journal=None) -> np.ndarray:
        """Stream every partition batch; returns (num_nodes,) int32 global
        predictions with every core row written (halo rows are computed
        under their owning partition).

        ``gnn_cfg`` enables model-vs-actual memory accounting: the plan's
        modeled packed-launch peak and the same analytic model evaluated
        on every REAL launched padded shape land in ``stats`` and the
        ``exec.modeled_peak_bytes`` / ``exec.actual_peak_bytes`` gauges.

        ``journal`` (a :class:`repro.checkpoint.PartitionJournal`) makes
        the run crash-safe: each launched partition's core predictions are
        committed as they land, previously committed partitions are
        restored into ``out`` and dropped from the schedule, and the
        journal is cleared once every partition has been written.
        """
        t_wall = time.perf_counter()
        schedule = plan.schedule(self.capacity)
        self.buckets_seen.update(plan.buckets)
        if gnn_cfg is not None:
            modeled = plan.peak_batch_memory_bytes(gnn_cfg, self.capacity)
            self.stats.modeled_peak_bytes = max(
                self.stats.modeled_peak_bytes, modeled
            )
            REGISTRY.gauge("exec.modeled_peak_bytes").set(modeled)
        out = np.zeros(plan.num_nodes, dtype=np.int32)
        if journal is not None:
            restored = journal.restore(plan, out)
            if restored:
                schedule = [
                    (shape, kept)
                    for shape, indices in schedule
                    if (kept := [i for i in indices if i not in restored])
                ]
                self.stats.resumed_partitions += len(restored)
                REGISTRY.counter("exec.resumed_partitions").inc(len(restored))
        compiles_before = self.runner.compile_count
        tracer = current_tracer()
        # per-run degradation state: a device resource error halves the
        # effective pack capacity for the REST of this run (mutated by
        # _launch_degradable), so one undersized device doesn't turn every
        # remaining batch into its own failure
        degrade = {"cap": self.capacity}

        with tracer.span(
            "exec.stream",
            partitions=plan.num_parts,
            batches=len(schedule),
        ) as stream_sp:
            if self.prefetch == 0 or len(schedule) <= 1:
                # synchronous fallback (also the degenerate 0/1-batch case)
                for shape, indices in schedule:
                    batch = self._pack_timed(plan, indices, features, shape)
                    self._launch_degradable(
                        plan, batch, out, features, gnn_cfg, degrade, journal
                    )
            else:
                q: queue.Queue = queue.Queue(maxsize=self.prefetch)
                stop = threading.Event()  # consumer died: unblock producer
                # pack spans from the prefetch thread parent under this
                # run's stream span, not under whatever that thread did last
                stream_id = stream_sp.span_id

                def _put(item) -> bool:
                    while not stop.is_set():
                        try:
                            q.put(item, timeout=0.1)
                            return True
                        except queue.Full:
                            continue
                    return False

                def _producer():
                    with tracer.adopt(stream_id):
                        try:
                            for shape, indices in schedule:
                                faults.fire(
                                    "exec.prefetch",
                                    tag=lambda: f"parts={len(indices)}",
                                )
                                if not _put(
                                    self._pack_timed(plan, indices, features, shape)
                                ):
                                    return
                            _put(_SENTINEL)
                        except faults.WorkerKilled:
                            # simulated abrupt thread death: deliver NOTHING
                            # — the consumer-side watchdog must catch this
                            return
                        except BaseException as e:  # noqa: BLE001 — forwarded to consumer
                            _put(e)

                th = threading.Thread(
                    target=_producer, name="exec-prefetch", daemon=True
                )
                th.start()
                try:
                    while True:
                        depth = q.qsize()
                        self.stats.max_queue_depth = max(
                            self.stats.max_queue_depth, depth
                        )
                        REGISTRY.gauge("exec.queue_depth").set(depth)
                        got = self._next_batch(q, th)
                        if got is _SENTINEL:
                            break
                        if isinstance(got, BaseException):
                            raise got
                        self._launch_degradable(
                            plan, got, out, features, gnn_cfg, degrade, journal
                        )
                finally:
                    # a launch failure leaves the producer blocked mid-put;
                    # the stop flag makes its bounded put give up promptly
                    # instead of stalling join for its full timeout
                    stop.set()
                    th.join(timeout=60.0)

        if journal is not None:
            journal.complete()

        self.stats.runs += 1
        # delta, not the runner's cumulative count: a runner shared with
        # the service scheduler also compiles for regular bucketed items,
        # and those must not be attributed to this stream
        run_compiles = self.runner.compile_count - compiles_before
        self.stats.compiles += run_compiles
        wall = time.perf_counter() - t_wall
        self.stats.wall_s += wall
        REGISTRY.counter("exec.runs").inc()
        REGISTRY.counter("exec.compiles").inc(run_compiles)
        REGISTRY.histogram("exec.wall_s").observe(wall)
        return out

    def run_subgraphs(
        self,
        subgraphs: list[Subgraph],
        features: np.ndarray,
        num_nodes: int,
    ) -> np.ndarray:
        """Stream pre-extracted partitions (``predict_partitioned``'s
        calling convention)."""
        plan = plan_from_subgraphs(
            list(subgraphs), num_nodes,
            min_nodes=self.min_nodes, min_edges=self.min_edges,
        )
        return self.run_plan(plan, features)

    def run_graph(
        self,
        graph: EdgeGraph,
        features: np.ndarray,
        k: int,
        *,
        regrow: bool = True,
        hops: int = 1,
        partitioner: str = "multilevel",
        seed: int = 0,
    ) -> np.ndarray:
        """Plan + stream in one call (the service auto-route entry)."""
        plan = self.plan_graph(
            graph, k, regrow=regrow, hops=hops, partitioner=partitioner,
            seed=seed,
        )
        return self.run_plan(plan, features)

    # -- internals ----------------------------------------------------------

    @staticmethod
    def _next_batch(q: queue.Queue, th: threading.Thread):
        """Bounded-wait queue read with a producer watchdog.

        A blocking ``q.get()`` turns a dead prefetch thread into a silent
        hang: nothing will ever arrive, and nothing ever raises.  Poll
        instead, and if the producer has died without delivering either a
        batch or a forwarded exception, fail the run loudly.
        """
        while True:
            try:
                return q.get(timeout=0.2)
            except queue.Empty:
                if not th.is_alive():
                    REGISTRY.counter("exec.prefetch_deaths").inc()
                    raise RuntimeError(
                        "prefetch thread died without delivering a batch "
                        "or an error (see exec.prefetch_deaths)"
                    ) from None

    def _pack_timed(self, plan, indices, features, shape,
                    capacity: Optional[int] = None) -> PackedBatch:
        t0 = time.perf_counter()
        with span("exec.pack", parts=len(indices)) as sp:
            batch = pack_partitions(
                plan, indices, features, shape, capacity or self.capacity
            )
            sp.set(bytes=batch.nbytes)
        dt = time.perf_counter() - t0
        self.stats.pack_s += dt
        self.stats.bytes_h2d += batch.nbytes
        REGISTRY.counter("exec.bytes_h2d").inc(batch.nbytes)
        REGISTRY.histogram("exec.pack_s").observe(dt)
        return batch

    def _launch_degradable(self, plan, batch: PackedBatch, out: np.ndarray,
                           features, gnn_cfg, degrade: dict,
                           journal=None) -> None:
        """Launch with graceful capacity degradation.

        On a device resource error (OOM and friends, classified by
        :func:`repro.faults.is_resource_error`) the effective pack
        capacity for the rest of the run is halved and the failed batch
        is re-packed as smaller chunks and relaunched — smaller padded
        arrays, a smaller jit signature, a smaller device footprint.  A
        singleton batch that still hits a resource error cannot shrink
        further, so it propagates.
        """
        cap = max(1, degrade["cap"])
        if len(batch.indices) > cap:
            # capacity already degraded earlier in the run: proactively
            # split batches packed (e.g. by the prefetch thread) at the
            # old capacity instead of rediscovering the OOM per batch
            self._relaunch_split(
                plan, batch, out, features, gnn_cfg, degrade, journal, cap
            )
            return
        try:
            self._launch(batch, out, gnn_cfg, journal)
        except Exception as e:
            if not faults.is_resource_error(e) or len(batch.indices) <= 1:
                raise
            degrade["cap"] = cap = max(1, min(cap, len(batch.indices)) // 2)
            self.stats.capacity_halvings += 1
            REGISTRY.counter("exec.capacity_halvings").inc()
            REGISTRY.gauge("exec.effective_capacity").set(cap)
            self._relaunch_split(
                plan, batch, out, features, gnn_cfg, degrade, journal, cap
            )

    def _relaunch_split(self, plan, batch, out, features, gnn_cfg,
                        degrade, journal, cap: int) -> None:
        indices = list(batch.indices)
        for at in range(0, len(indices), cap):
            chunk = indices[at:at + cap]
            repacked = self._pack_timed(
                plan, chunk, features, batch.shape, capacity=cap
            )
            self._launch_degradable(
                plan, repacked, out, features, gnn_cfg, degrade, journal
            )

    def _launch(self, batch: PackedBatch, out: np.ndarray,
                gnn_cfg=None, journal=None) -> None:
        if gnn_cfg is not None:
            # the same analytic model, evaluated on the padded shapes this
            # launch ACTUALLY ships (capacity*n_pad rows, capacity*e_pad
            # edges) — staged bytes are separately measured as bytes_h2d
            from repro.core.pipeline import memory_model_bytes

            actual = memory_model_bytes(
                int(batch.arrays["x"].shape[0]),
                int(batch.arrays["edge_src"].shape[0]),
                gnn_cfg,
            )
            self.stats.actual_peak_bytes = max(
                self.stats.actual_peak_bytes, actual
            )
            REGISTRY.gauge("exec.actual_peak_bytes").set(actual)
        t0 = time.perf_counter()
        with span("exec.launch", parts=len(batch.items)):
            faults.fire(
                "exec.launch",
                tag=lambda: f"parts={len(batch.items)} shape={batch.shape}",
            )
            pred = self.runner(batch.arrays)
        dt = time.perf_counter() - t0
        self.stats.device_s += dt
        self.stats.launches += 1
        self.stats.batches += 1
        self.stats.partitions += len(batch.items)
        self.stats.core_rows += scatter_core_predictions(out, batch, pred)
        REGISTRY.counter("exec.launches").inc()
        REGISTRY.histogram("exec.device_s").observe(dt)
        if journal is not None:
            # commit core predictions partition-by-partition AFTER the
            # scatter: each journal file is written atomically, so a crash
            # between launches loses at most the in-flight batch
            for idx, it in zip(batch.indices, batch.items):
                ids = it.global_ids[: it.num_core]
                journal.commit(int(idx), ids, out[ids])


#: small identity-keyed executor reuse pool: a fresh executor per call
#: would mean a fresh ``jax.jit`` per call, retracing every bucket on
#: every ``predict_partitioned`` — the exact recompile churn the bucket
#:  discipline exists to kill.  Entries hold a strong ref to the params
#: tree, so an ``id()`` can never alias a collected object.
_EXECUTOR_POOL: dict[tuple, tuple[object, "StreamingExecutor"]] = {}
_EXECUTOR_POOL_MAX = 8


def shared_executor(
    params, backend: str, *, capacity: int = 2, prefetch: int = 1,
    stream_dtype: Optional[str] = None,
    min_nodes: int = 64, min_edges: int = 128,
) -> StreamingExecutor:
    """The process-wide executor for (params identity, backend, knobs)."""
    if stream_dtype == "float32":
        stream_dtype = None   # numerically identical: share the executor
    key = (id(params), backend, capacity, prefetch, stream_dtype,
           min_nodes, min_edges)
    hit = _EXECUTOR_POOL.get(key)
    if hit is not None and hit[0] is params:
        return hit[1]
    ex = StreamingExecutor(params, backend, capacity=capacity, prefetch=prefetch,
                           stream_dtype=stream_dtype,
                           min_nodes=min_nodes, min_edges=min_edges)
    if len(_EXECUTOR_POOL) >= _EXECUTOR_POOL_MAX:
        _EXECUTOR_POOL.clear()
    _EXECUTOR_POOL[key] = (params, ex)
    return ex


def stream_predict_partitioned(
    params,
    subgraphs: list[Subgraph],
    features: np.ndarray,
    num_nodes: int,
    backend: str = "ref",
    *,
    capacity: int = 2,
    prefetch: int = 1,
    stream_dtype: Optional[str] = None,
) -> np.ndarray:
    """One-shot convenience: stream through the shared executor pool.

    Predictions are bit-exact with the sequential per-subgraph loop
    (:func:`repro.core.gnn.predict_partitioned_loop`) on core rows — the
    padding/packing contract keeps every real row's arithmetic identical.
    Repeated calls with the same params reuse one executor (and so one
    jit cache): recurring subgraph buckets compile nothing new.
    """
    ex = shared_executor(params, backend, capacity=capacity, prefetch=prefetch,
                         stream_dtype=stream_dtype)
    return ex.run_subgraphs(subgraphs, features, num_nodes)
