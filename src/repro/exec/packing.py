"""Packing partition batches for the streaming executor.

One :class:`PackedBatch` is one device launch: up to ``capacity``
same-bucket subgraphs laid out as a disjoint union in the bucket's
canonical padded shape (the paper's "batch size 16" of partitions).  The
layout and the exactness contract (zero features on padding rows, padding
edges self-looped on each slot's dummy row) are
:func:`repro.service.bucketing.pack_batch`'s — this module adds the
feature *staging* (the host gather of each partition's global feature
rows, the work the prefetch thread overlaps with device execution) and the
reverse *scatter* of core-node predictions into the global output.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.exec.plan import PartitionPlan
from repro.service.bucketing import (
    BucketShape,
    WorkItem,
    item_from_subgraph,
    pack_batch,
    unpack_predictions,
)


@dataclasses.dataclass
class PackedBatch:
    """One staged device launch (host arrays, ready for transfer)."""

    shape: BucketShape
    indices: list[int]            # plan subgraph indices, slot order
    items: list[WorkItem]
    arrays: dict                  # pack_batch output (x/edge_*/num_nodes)
    capacity: int

    @property
    def nbytes(self) -> int:
        """Host->device transfer size of this launch."""
        return sum(
            a.nbytes for a in self.arrays.values() if isinstance(a, np.ndarray)
        )


def pack_partitions(
    plan: PartitionPlan,
    indices: list[int],
    features: np.ndarray,
    shape: BucketShape,
    capacity: int,
) -> PackedBatch:
    """Stage one schedule entry: gather features, pad, pack into slots."""
    items = [
        item_from_subgraph(0, i, plan.subgraphs[i], features) for i in indices
    ]
    return PackedBatch(
        shape=shape,
        indices=list(indices),
        items=items,
        arrays=pack_batch(items, shape, capacity),
        capacity=capacity,
    )


def scatter_core_predictions(
    out: np.ndarray, batch: PackedBatch, pred: np.ndarray
) -> int:
    """Write each slot's CORE-node predictions to their global rows.

    Halo rows are message-passing context only (paper §III-C); their
    predictions are discarded.  Returns the number of core rows written.
    """
    written = 0
    for it, p in zip(batch.items, unpack_predictions(pred, batch.items, batch.shape)):
        out[it.global_ids[: it.num_core]] = p[: it.num_core]
        written += it.num_core
    return written
