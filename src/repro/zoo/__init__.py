"""`repro.zoo`: quarantined LLM-era scaffolding, OFF the verification path.

The repo grew from a generic JAX serving/training skeleton; the
transformer model zoo (``zoo.models``), its architecture registry
(``zoo.configs`` — deepseek/llama/qwen/... plus the ``groot_gnn`` entry
that bridges back), and the decode-serving loop (``zoo.serving``) are
exercised only by the LM launchers (``repro.launch``), the roofline
reports, and their tests.  Nothing under ``repro.core`` / ``repro.exec``
/ ``repro.mesh`` / ``repro.api`` imports this namespace, so the GROOT
verification stack never drags transformer code — in particular,
``repro.mesh``'s use of :mod:`repro.sharding.rules` stays free of model
imports (the rules module only reaches into the zoo lazily, for
ParamSpec-annotated trees the zoo itself produced).
"""
