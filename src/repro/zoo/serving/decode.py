"""Serving steps: prefill (build cache + first logits) and decode (one
token against the cache).  The shapes brief:

  * ``prefill_32k``  lowers ``prefill_step`` (S = 32768 causal forward
    that also writes the KV cache),
  * ``decode_32k`` / ``long_500k`` lower ``serve_step`` (one new token,
    cache of seq_len).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.zoo.configs.base import ModelConfig
from repro.zoo.models.transformer import init_cache_tree, model_forward


def make_prefill_step(cfg: ModelConfig, max_seq: int):
    """(params, tokens (B,S), enc_input?) -> (last_logits (B,V), cache)."""

    def prefill_step(params, tokens, enc_input=None):
        cache = init_cache_tree(cfg, tokens.shape[0], max_seq, dtype=jnp.bfloat16)
        logits, cache = model_forward(
            params, cfg, tokens, enc_input=enc_input, cache=cache, last_only=True
        )
        return logits[:, -1], cache

    return prefill_step


def make_serve_step(cfg: ModelConfig):
    """(params, cache, token (B,1)) -> (next_token (B,1), logits, cache)."""

    def serve_step(params, cache, token):
        logits, cache = model_forward(
            params, cfg, token, cache=cache, decode=True
        )
        if cfg.padded_vocab != cfg.vocab_size:  # never sample pad ids
            col = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
            logits = jnp.where(col < cfg.vocab_size, logits, -jnp.inf)
        nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return nxt, logits[:, -1], cache

    return serve_step


def greedy_generate(
    params,
    cfg: ModelConfig,
    prompt: jax.Array,
    steps: int,
    *,
    max_seq: Optional[int] = None,
    enc_input=None,
):
    """Reference generation loop (prefill + scan of decode steps)."""
    b, s = prompt.shape
    max_seq = max_seq or (s + steps)
    prefill = make_prefill_step(cfg, max_seq)
    serve = make_serve_step(cfg)
    last_logits, cache = prefill(params, prompt, enc_input)
    tok0 = jnp.argmax(last_logits, axis=-1)[:, None].astype(jnp.int32)

    def step(carry, _):
        tok, cache = carry
        nxt, _, cache = serve(params, cache, tok)
        return (nxt, cache), tok

    (_, _), toks = jax.lax.scan(step, (tok0, cache), None, length=steps)
    return jnp.moveaxis(toks[..., 0], 0, 1)  # (B, steps)
