"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Gated linear recurrence, per channel:

    r_t = sigmoid(x_t W_rg)                    (recurrence gate)
    i_t = sigmoid(x_t W_ig)                    (input gate)
    a_t = a^(c * r_t)     with a = sigmoid(Λ), c = 8
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)

The block wraps the recurrence Griffin-style: two input branches (linear +
gated), a short temporal conv (width 4) before the RG-LRU, GeLU-gated merge,
and an output projection.

The recurrence is a first-order linear scan -> implemented with
``jax.lax.associative_scan`` (log-depth, parallelisable over "model"-sharded
channels); the decode path is the O(1) single-step update.  Both are tested
for equivalence against a plain sequential scan.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.zoo.configs.base import ModelConfig

C_EXP = 8.0


def init_state(cfg: ModelConfig, batch: int) -> dict:
    dr = cfg.d_rnn_
    return {
        "h": jnp.zeros((batch, dr), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, dr), jnp.bfloat16),
    }


def _conv1d(x: jax.Array, w: jax.Array, b: jax.Array, carry: Optional[jax.Array]):
    """Causal depthwise conv over time.  x: (B,S,C); w: (W,C).

    Returns (out (B,S,C), new_carry (B,W-1,C))."""
    width = w.shape[0]
    if carry is None:
        carry = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xx = jnp.concatenate([carry.astype(x.dtype), x], axis=1)
    out = sum(
        xx[:, i : i + x.shape[1]] * w[i][None, None, :] for i in range(width)
    )
    return out + b, xx[:, -(width - 1) :] if width > 1 else carry


def _gates(xr: jax.Array, p: dict):
    r = jax.nn.sigmoid(jnp.einsum("bsc,cd->bsd", xr, p["w_rec_gate"]))
    i = jax.nn.sigmoid(jnp.einsum("bsc,cd->bsd", xr, p["w_input_gate"]))
    log_a = C_EXP * r.astype(jnp.float32) * jax.nn.log_sigmoid(
        p["lambda_p"].astype(jnp.float32)
    )
    a = jnp.exp(log_a)
    gated_x = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2 * log_a), 1e-12)) * (
        i.astype(jnp.float32) * xr.astype(jnp.float32)
    )
    return a, gated_x


def rg_lru(
    xr: jax.Array, p: dict, h0: Optional[jax.Array] = None
) -> tuple[jax.Array, jax.Array]:
    """Linear recurrence via associative scan.  xr: (B,S,C) post-conv.

    Returns (h (B,S,C) in input dtype, h_final (B,C) f32)."""
    a, gx = _gates(xr, p)  # (B,S,C) f32
    if h0 is not None:
        # fold the carried state into the first step: h_1 = a_1 h_0 + gx_1
        gx = gx.at[:, 0].add(a[:, 0] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    a_s, h = jax.lax.associative_scan(combine, (a, gx), axis=1)
    return h.astype(xr.dtype), h[:, -1]


def rg_lru_step(xr: jax.Array, p: dict, h0: jax.Array):
    """Decode: one token.  xr: (B,1,C).  Returns (out, h_new)."""
    a, gx = _gates(xr, p)
    h = a[:, 0] * h0 + gx[:, 0]
    return h[:, None].astype(xr.dtype), h


def rglru_block(
    x: jax.Array,
    p: dict,
    cfg: ModelConfig,
    state: Optional[dict] = None,
    *,
    decode: bool = False,
):
    """Full Griffin recurrent block.  x: (B,S,D) -> (B,S,D), state'."""
    gate = jax.nn.gelu(jnp.einsum("bsd,dc->bsc", x, p["w_gate_branch"]))
    xb = jnp.einsum("bsd,dc->bsc", x, p["w_x"])
    conv_carry = state["conv"] if state else None
    xb, conv_carry = _conv1d(xb, p["conv_w"], p["conv_b"], conv_carry)
    h0 = state["h"] if state else None
    if decode:
        y, h_fin = rg_lru_step(xb, p, h0 if h0 is not None else jnp.zeros(
            (x.shape[0], cfg.d_rnn_), jnp.float32))
    else:
        y, h_fin = rg_lru(xb, p, h0)
    out = jnp.einsum("bsc,cd->bsd", y * gate, p["w_out"])
    new_state = {"h": h_fin, "conv": conv_carry}
    return out, new_state
