"""Attention: GQA + RoPE + qk-norm + QKV-bias + sliding window + softcap +
cross-attention, with an in-graph KV cache for decode.

One function serves training (full causal), prefill (causal + cache
write-out), decode (single query against the cache), encoder
(bidirectional) and cross-attention.  All masks are position-based
(iota compares on global positions), so ring-buffer caches and padded key
blocks fall out of the same code path.

Memory: whenever S*T score elements exceed ``FLASH_THRESHOLD`` the
computation switches to a flash-attention schedule in pure ``lax`` —
``lax.map`` over query blocks, ``lax.scan`` over key blocks with an online
softmax (running max + denominator).  Peak live score memory is
O(q_chunk * kv_chunk) per head instead of O(S*T): the 32k and 500k shapes
are impossible without this.  (On real TPU hardware the Pallas kernel in
``repro.kernels.flash_attention`` replaces this schedule — same blocking,
scores resident in VMEM; the lax form is what the CPU dry-run compiles.)
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.zoo.configs.base import ModelConfig
from repro.zoo.models.layers import rms_norm, rope, softcap
from repro.sharding import current_ctx, shard


# TP-incompatible head counts are handled by ZERO-PADDING the head axis to
# the TP multiple (cfg.head_pad_to, Megatron-style): exact math — padded
# wo rows are zero so pad heads contribute nothing and receive no
# gradient.  (A batch-reshard alternative was measured and refuted: the
# per-microbatch batch (32) does not divide data*model=256, so the
# constraint silently dropped — EXPERIMENTS.md §Perf.)

FLASH_THRESHOLD = 4 * 1024 * 1024  # S*T elements above which we chunk
Q_CHUNK = 1024
KV_CHUNK = 1024
PAD_POS = 1 << 30  # key-position sentinel: fails every mask test
NEG_INF = -1e30


@dataclasses.dataclass
class KVCache:
    """Per-layer KV cache. ``k/v``: (B, S_max, KV, hd); ``pos``: scalar count.

    For sliding-window layers S_max == window and writes wrap (ring buffer).
    """

    k: jax.Array
    v: jax.Array
    pos: jax.Array  # int32 scalar — total tokens written so far
    window: int = 0  # 0 = full cache


jax.tree_util.register_pytree_with_keys(
    KVCache,
    lambda c: (
        (
            (jax.tree_util.GetAttrKey("k"), c.k),
            (jax.tree_util.GetAttrKey("v"), c.v),
            (jax.tree_util.GetAttrKey("pos"), c.pos),
        ),
        c.window,
    ),
    lambda window, kids: KVCache(kids[0], kids[1], kids[2], window),
)


def init_cache(
    cfg: ModelConfig, batch: int, max_seq: int, *, window: int = 0, dtype=jnp.bfloat16
) -> KVCache:
    s = window or max_seq
    kv, hd = cfg.num_kv_heads, cfg.head_dim_
    return KVCache(
        k=jnp.zeros((batch, s, kv, hd), dtype),
        v=jnp.zeros((batch, s, kv, hd), dtype),
        pos=jnp.zeros((), jnp.int32),
        window=window,
    )


def _project_qkv(x, p: dict, cfg: ModelConfig):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if "q_norm" in p:  # qwen3 qk-norm (per-head RMS over head_dim)
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def _mask(q_pos, k_pos, *, causal: bool, window: int):
    """(S, T) boolean validity from global positions."""
    ok = k_pos[None, :] < PAD_POS if not causal else k_pos[None, :] <= q_pos[:, None]
    if not causal:
        ok = jnp.broadcast_to(ok, (q_pos.shape[0], k_pos.shape[0]))
    if window:
        ok = ok & (k_pos[None, :] > q_pos[:, None] - window)
    return ok


def _scores(q, k, cfg: ModelConfig, scale: float):
    """q: (B,S,KV,G,hd), k: (B,T,KV,hd) -> (B,KV,G,S,T) f32 (capped)."""
    s = jnp.einsum("bskgd,btkd->bkgst", q, k).astype(jnp.float32) * scale
    if cfg.attn_softcap:
        s = softcap(s, cfg.attn_softcap)
    return s


def _sdpa_plain(q, k, v, q_pos, k_pos, cfg, scale, *, causal, window):
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    q = q.reshape(b, s, kvh, h // kvh, hd)
    sc = _scores(q, k, cfg, scale)
    ok = _mask(q_pos, k_pos, causal=causal, window=window)
    sc = jnp.where(ok[None, None, None], sc, NEG_INF)
    probs = jax.nn.softmax(sc, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(b, s, h, hd)


def _sdpa_flash(q, k, v, q_pos, k_pos, cfg, scale, *, causal, window):
    """Flash schedule: lax.map over query blocks, scan over key blocks."""
    b, s, h, hd = q.shape
    t = k.shape[1]
    kvh = k.shape[2]
    g = h // kvh
    qc = min(Q_CHUNK, s)
    kc = min(KV_CHUNK, t)
    s_pad, t_pad = -s % qc, -t % kc
    if s_pad:
        q = jnp.pad(q, ((0, 0), (0, s_pad), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, (0, s_pad))
    if t_pad:
        k = jnp.pad(k, ((0, 0), (0, t_pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, t_pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, t_pad), constant_values=PAD_POS)
    nq, nk = (s + s_pad) // qc, (t + t_pad) // kc
    q_blocks = jnp.moveaxis(q.reshape(b, nq, qc, kvh, g, hd), 1, 0)
    qpos_blocks = q_pos.reshape(nq, qc)
    k_blocks = jnp.moveaxis(k.reshape(b, nk, kc, kvh, hd), 1, 0)
    v_blocks = jnp.moveaxis(v.reshape(b, nk, kc, kvh, hd), 1, 0)
    kpos_blocks = k_pos.reshape(nk, kc)

    def one_q_block(args):
        qb, qpos = args  # (B,qc,KV,G,hd), (qc,)

        def kv_step(carry, xs):
            acc, m, l = carry
            kb, vb, kpos = xs
            sc = _scores(qb, kb, cfg, scale)  # (B,KV,G,qc,kc) f32
            ok = _mask(qpos, kpos, causal=causal, window=window)
            sc = jnp.where(ok[None, None, None], sc, NEG_INF)
            m_new = jnp.maximum(m, sc.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(sc - m_new[..., None])
            l = l * alpha + p.sum(axis=-1)
            pv = jnp.einsum("bkgsc,bckd->bkgsd", p.astype(vb.dtype), vb)
            acc = acc * alpha[..., None].astype(acc.dtype) + pv.astype(acc.dtype)
            return (acc, m_new, l), None

        acc0 = jnp.zeros((b, kvh, g, qc, hd), jnp.float32)
        m0 = jnp.full((b, kvh, g, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, qc), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0), (k_blocks, v_blocks, kpos_blocks)
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return jnp.moveaxis(out, 3, 1)  # (B,qc,KV,G,hd)

    out = jax.lax.map(one_q_block, (q_blocks, qpos_blocks))  # (nq,B,qc,KV,G,hd)
    out = jnp.moveaxis(out, 0, 1).reshape(b, s + s_pad, h, hd)
    return out[:, :s].astype(v.dtype)


def _sdpa(q, k, v, q_pos, k_pos, cfg, scale, *, causal=True, window=0):
    if q.shape[1] * k.shape[1] > FLASH_THRESHOLD:
        return _sdpa_flash(q, k, v, q_pos, k_pos, cfg, scale, causal=causal, window=window)
    b, s, h, hd = q.shape
    return _sdpa_plain(q, k, v, q_pos, k_pos, cfg, scale, causal=causal, window=window)


def attention(
    x: jax.Array,
    p: dict,
    cfg: ModelConfig,
    *,
    window: int = 0,
    cache: Optional[KVCache] = None,
    bidirectional: bool = False,
) -> tuple[jax.Array, Optional[KVCache]]:
    """Self-attention.  Returns (out, updated_cache).

    Training/encoder: ``cache=None``.  Prefill: pass a zeroed cache of
    S_max >= S; keys land at positions [0, S).  Decode: S == 1, cache holds
    history; the new token is written at ``cache.pos`` (mod window).
    """
    b, s, _ = x.shape
    q, k, v = _project_qkv(x, p, cfg)
    offset = cache.pos if cache is not None else jnp.zeros((), jnp.int32)
    positions = offset + jnp.arange(s, dtype=jnp.int32)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    scale = cfg.head_dim_**-0.5

    new_cache = None
    if cache is not None:
        s_max = cache.k.shape[1]
        if s > 1:
            # Prefill (assumes an empty cache): attend over THIS call's
            # k/v — for ring caches the early queries need keys that the
            # ring will overwrite, so the cache is write-only here.
            if s >= s_max:  # ring smaller than the prompt: keep the tail
                kw, vw = k[:, -s_max:], v[:, -s_max:]
                slots = positions[-s_max:] % s_max if cache.window else positions[-s_max:]
            else:
                kw, vw = k, v
                slots = positions % s_max if cache.window else positions
            k_all = cache.k.at[:, slots].set(kw.astype(cache.k.dtype))
            v_all = cache.v.at[:, slots].set(vw.astype(cache.v.dtype))
            k_all = shard(k_all, ("batch", "kv_seq", None, None))
            v_all = shard(v_all, ("batch", "kv_seq", None, None))
            new_cache = KVCache(k_all, v_all, offset + s, cache.window)
            out = _sdpa(
                q, k, v, positions, positions, cfg, scale,
                causal=True, window=window,
            )
        else:
            # Decode: write one token, attend against the cache.
            slots = positions % s_max if cache.window else positions
            k_all = cache.k.at[:, slots].set(k.astype(cache.k.dtype))
            v_all = cache.v.at[:, slots].set(v.astype(cache.v.dtype))
            k_all = shard(k_all, ("batch", "kv_seq", None, None))
            v_all = shard(v_all, ("batch", "kv_seq", None, None))
            new_cache = KVCache(k_all, v_all, offset + s, cache.window)
            if cache.window:
                # global position held by ring slot j after this write
                j = jnp.arange(s_max, dtype=jnp.int32)
                total = offset + s
                wraps = jnp.where(total > j, (total - 1 - j) // s_max, 0)
                k_pos = j + wraps * s_max
                # slots never written yet hold zeros: mask them out
                k_pos = jnp.where(k_pos < total, k_pos, PAD_POS)
                win = window or s_max
            else:
                k_pos = jnp.arange(s_max, dtype=jnp.int32)
                win = window
            out = _sdpa(
                q, k_all.astype(q.dtype), v_all.astype(q.dtype),
                positions, k_pos, cfg, scale, causal=True, window=win,
            )
    else:
        out = _sdpa(
            q, k, v, positions, positions, cfg, scale,
            causal=not bidirectional, window=window,
        )

    out = shard(out, ("batch", None, "heads", None))
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), new_cache


def cross_attention(
    x: jax.Array,
    enc_kv: tuple[jax.Array, jax.Array],
    p: dict,
    cfg: ModelConfig,
) -> jax.Array:
    """Decoder query over precomputed encoder K/V (B, S_enc, KV, hd)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
    k, v = enc_kv
    t = k.shape[1]
    q_pos = jnp.zeros((q.shape[1],), jnp.int32)
    k_pos = jnp.zeros((t,), jnp.int32)
    out = _sdpa(
        q, k.astype(q.dtype), v.astype(q.dtype), q_pos, k_pos, cfg,
        cfg.head_dim_**-0.5, causal=False, window=0,
    )
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def encode_cross_kv(enc_out: jax.Array, p: dict, cfg: ModelConfig):
    """Project encoder output once into cross-attention K/V."""
    k = jnp.einsum("btd,dhk->bthk", enc_out, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", enc_out, p["wv"])
    if "k_norm" in p:
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return k, v
