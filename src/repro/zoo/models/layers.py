"""Shared neural layers (pure functions over param dicts)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, gain: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(jnp.square(xf), axis=-1, keepdims=True) + eps)
    return (y * gain.astype(jnp.float32)).astype(dt)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    return cap * jnp.tanh(x / cap)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding.  x: (..., S, H, hd); positions: (S,) or (B, S)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]  # broadcast over heads
    sin = jnp.sin(ang)[..., None, :]
    if ang.ndim == 2:  # positions was (S,) -> add batch broadcast dim
        cos, sin = cos[None], sin[None]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def swiglu(x: jax.Array, w_in, w_gate, w_out) -> jax.Array:
    h = jnp.einsum("...d,df->...f", x, w_in)
    g = jnp.einsum("...d,df->...f", x, w_gate)
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * h, w_out)


def gelu_mlp(x: jax.Array, w_in, w_out) -> jax.Array:
    return jnp.einsum(
        "...f,fd->...d", jax.nn.gelu(jnp.einsum("...d,df->...f", x, w_in)), w_out
    )


def mlp(x: jax.Array, p: dict, act: str) -> jax.Array:
    from repro.sharding import shard

    if act == "swiglu" and "w_gate" in p:
        h = jnp.einsum("...d,df->...f", x, p["w_in"])
        g = jnp.einsum("...d,df->...f", x, p["w_gate"])
        h = shard(jax.nn.silu(g) * h, ("batch", None, "d_ff"))
        return jnp.einsum("...f,fd->...d", h, p["w_out"])
    h = jnp.einsum("...d,df->...f", x, p["w_in"])
    h = shard(jax.nn.gelu(h), ("batch", None, "d_ff"))
    return jnp.einsum("...f,fd->...d", h, p["w_out"])
