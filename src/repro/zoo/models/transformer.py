"""The generic LM stack: assembles attention / MoE / RWKV6 / RG-LRU layers
per the config's layer pattern, with scan-over-super-blocks + remat.

Entry points
------------
``model_forward(params, cfg, tokens, ...)``
    (B, S) tokens -> (B, S, V) logits; optionally threads a cache pytree
    (prefill/decode).  ``decode=True`` means S == 1 against the cache.

``init_cache_tree(cfg, batch, max_seq)``
    cache pytree matching the scan structure (stacked per super-block).

Layer scan: layers are grouped into super-blocks of ``cfg.pattern_period``
heterogeneous positions (see configs.base.stack_layers); ``lax.scan`` runs
over the stacked super-blocks so the HLO contains each distinct layer kind
once — 95-layer models compile in seconds, which the multi-pod dry-run
depends on.

Attention uses a chunked online-softmax path (flash-attention schedule in
pure lax, see attention._sdpa) whenever S*T would materialise more than
``FLASH_THRESHOLD`` score elements per head — the 32k/500k shapes are
impossible without it.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.zoo.configs.base import ModelConfig
from repro.zoo.models import rglru as rglru_mod
from repro.zoo.models import rwkv6
from repro.zoo.models.attention import (
    KVCache,
    attention,
    cross_attention,
    encode_cross_kv,
    init_cache,
)
from repro.zoo.models.layers import mlp, rms_norm, softcap
from repro.zoo.models.moe import moe_apply
from repro.sharding import shard


# ---------------------------------------------------------------------------
# Per-layer application
# ---------------------------------------------------------------------------

def apply_layer(
    x: jax.Array,
    lp: dict,
    cfg: ModelConfig,
    kind: str,
    is_moe: bool,
    cache: Optional[dict],
    enc_out: Optional[jax.Array],
    decode: bool,
):
    """One residual layer.  Returns (x, new_cache_entry)."""
    new_cache: dict = {}
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    if kind in ("global", "local"):
        window = cfg.sliding_window if kind == "local" else 0
        kv_cache = cache.get("kv") if cache else None
        out, nc = attention(h, lp["attn"], cfg, window=window, cache=kv_cache)
        if nc is not None:
            new_cache["kv"] = nc
    elif kind == "cross+global":
        kv_cache = cache.get("kv") if cache else None
        out, nc = attention(h, lp["attn"], cfg, cache=kv_cache)
        if nc is not None:
            new_cache["kv"] = nc
        x = x + out.astype(x.dtype)
        h = rms_norm(x, lp["ln_cross"], cfg.norm_eps)
        if cache is not None and decode:
            ckv = (cache["ck"], cache["cv"])
        else:
            ckv = encode_cross_kv(enc_out, lp["cross"], cfg)
        if cache is not None:
            new_cache["ck"], new_cache["cv"] = ckv
        out = cross_attention(h, ckv, lp["cross"], cfg)
    elif kind == "rwkv":
        st = cache.get("mix") if cache else None
        if decode or rwkv6.FORCE_SCAN or (st is not None and x.shape[1] <= 4):
            out, ns = rwkv6.time_mix_scan(h, lp["rwkv"], cfg, st)
        else:
            out, ns = rwkv6.time_mix_chunked(h, lp["rwkv"], cfg, st)
        if cache is not None:
            new_cache["mix"] = ns
    elif kind == "rglru":
        st = cache.get("rec") if cache else None
        out, ns = rglru_mod.rglru_block(h, lp["rglru"], cfg, st, decode=decode)
        if cache is not None:
            new_cache["rec"] = ns
    else:  # pragma: no cover
        raise ValueError(kind)
    x = x + out.astype(x.dtype)
    x = shard(x, ("batch", "seq_shard", None))

    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    if is_moe:
        out = moe_apply(h, lp["moe"], cfg)
    elif kind == "rwkv":
        prev = cache.get("ffn_prev") if cache else None
        out, carry = rwkv6.channel_mix(h, lp["ffn"], prev)
        if cache is not None:
            new_cache["ffn_prev"] = carry
    else:
        out = mlp(h, lp["ffn"], cfg.act)
    x = x + out.astype(x.dtype)
    return shard(x, ("batch", "seq_shard", None)), new_cache


# ---------------------------------------------------------------------------
# Cache construction (mirrors the scan structure)
# ---------------------------------------------------------------------------

def _layer_cache(cfg: ModelConfig, kind: str, batch: int, max_seq: int, dtype):
    c: dict[str, Any] = {}
    if kind in ("global", "local", "cross+global"):
        window = cfg.sliding_window if kind == "local" else 0
        window = min(window, max_seq) if window else 0
        c["kv"] = init_cache(cfg, batch, max_seq, window=window, dtype=dtype)
        # shard the cache along seq over "model" (flash-decode layout):
        # kv_heads (<=8) never divides model=16, the seq dim always does.
        c["kv"] = KVCache(
            shard(c["kv"].k, ("batch", "kv_seq", None, None)),
            shard(c["kv"].v, ("batch", "kv_seq", None, None)),
            c["kv"].pos,
            c["kv"].window,
        )
    if kind == "cross+global":
        kv, hd = cfg.num_kv_heads, cfg.head_dim_
        enc_s = cfg.encoder_seq or cfg.cross_seq
        c["ck"] = jnp.zeros((batch, enc_s, kv, hd), dtype)
        c["cv"] = jnp.zeros((batch, enc_s, kv, hd), dtype)
    if kind == "rwkv":
        st = rwkv6.init_state(cfg, batch)
        c["mix"] = {"s": st["s"], "x_prev": st["x_prev"]}
        c["ffn_prev"] = st["ffn_prev"]
    if kind == "rglru":
        c["rec"] = rglru_mod.init_state(cfg, batch)
    return c


def init_cache_tree(
    cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.bfloat16
) -> dict:
    kinds = cfg.layer_kinds()
    period = cfg.pattern_period
    n_super, _ = divmod(cfg.num_layers, period)
    mk = lambda kind: _layer_cache(cfg, kind, batch, max_seq, dtype)
    if n_super <= 1:
        return {"blocks": None, "tail": [mk(k) for k in kinds]}
    stack = lambda tree: jax.tree.map(
        lambda x: jnp.broadcast_to(x, (n_super,) + x.shape), tree
    )
    blocks = [stack(mk(kinds[t])) for t in range(period)]
    tail = [mk(k) for k in kinds[n_super * period :]]
    return {"blocks": blocks, "tail": tail}


# ---------------------------------------------------------------------------
# Encoder (whisper)
# ---------------------------------------------------------------------------

def run_encoder(enc_params: dict, enc_input: jax.Array, cfg: ModelConfig):
    """Bidirectional encoder over stub frontend embeddings (B, S_enc, D)."""
    x = enc_input + enc_params["pos_embed"][None, : enc_input.shape[1]].astype(
        enc_input.dtype
    )
    for lp in enc_params["layers"]:
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        out, _ = attention(h, lp["attn"], cfg, bidirectional=True)
        x = x + out.astype(x.dtype)
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + mlp(h, lp["ffn"], cfg.act).astype(x.dtype)
    return rms_norm(x, enc_params["final_norm"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------

def model_forward(
    params: dict,
    cfg: ModelConfig,
    tokens: jax.Array,
    *,
    enc_input: Optional[jax.Array] = None,
    cache: Optional[dict] = None,
    decode: bool = False,
    remat: bool = False,
    remat_group: int = 1,
    last_only: bool = False,
):
    """tokens (B, S) -> logits (B, S, V).  Returns (logits, new_cache)."""
    kinds = cfg.layer_kinds()
    period = cfg.pattern_period
    n_super, _ = divmod(cfg.num_layers, period)

    # fp32-master scheme: f32 stored params are cast to the compute dtype
    # at use.  The cast happens PER BLOCK inside the layer scan (casting
    # the whole tree up front materialises a full bf16 copy of the model
    # — +3.1 GB/device on the 400B arch, measured in §Perf).
    cast = lambda t: jax.tree.map(
        lambda a: a.astype(cfg.dtype) if a.dtype == jnp.float32 else a, t
    )
    params = dict(params)
    for k in ("embed", "final_norm", "lm_head", "encoder"):
        if params.get(k) is not None:
            params[k] = cast(params[k])
    if params.get("tail"):
        params["tail"] = cast(params["tail"])

    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    if cfg.tie_embeddings:
        x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
    x = shard(x, ("batch", "seq_shard", None))

    enc_out = None
    if cfg.encoder_layers and enc_input is not None:
        enc_out = run_encoder(params["encoder"], enc_input, cfg)
    elif cfg.cross_seq and enc_input is not None:
        enc_out = enc_input  # vlm: stub patch embeddings are the "encoder"

    has_cache = cache is not None

    def block_body(x, block_params, block_cache):
        block_params = cast(block_params)  # per-block f32 -> bf16
        new_entries = []
        for t in range(period):
            lc = block_cache[t] if has_cache else None
            x, nc = apply_layer(
                x,
                block_params[t],
                cfg,
                kinds[t],
                cfg.is_moe_layer(t),
                lc,
                enc_out,
                decode,
            )
            new_entries.append(nc)
        return x, tuple(new_entries)

    if remat:
        block_body = jax.checkpoint(
            block_body, policy=jax.checkpoint_policies.nothing_saveable
        )

    new_cache: dict = {"blocks": None, "tail": []}
    if params.get("blocks") is not None and n_super > 1:

        def scan_fn(x, xs):
            bp, bc = xs
            x, nc = block_body(x, bp, bc)
            return x, nc

        bc = tuple(cache["blocks"]) if has_cache else tuple(
            jnp.zeros((n_super,)) for _ in range(period)
        )
        if remat_group > 1 and not has_cache:
            # Grouped remat (scan-over-scan checkpointing): the residual
            # stream is saved once per GROUP of ``remat_group``
            # super-blocks instead of per block — sqrt(L)-style memory at
            # the same recompute budget (each group's chain is replayed
            # once during its backward; blocks inside stay per-block
            # rematerialised).  SP residual sharding was measured to cost
            # 11-24x collective volume for the same purpose (§Perf).
            g = remat_group
            n_grp, rem = divmod(n_super, g)

            def group_fn(x, xs):
                bp, _ = xs
                x, _ = jax.lax.scan(scan_fn, x, (bp, tuple(
                    jnp.zeros((g,)) for _ in range(period))))
                return x, ()

            group_fn = jax.checkpoint(
                group_fn, policy=jax.checkpoint_policies.nothing_saveable
            )
            head = jax.tree.map(
                lambda a: a[: n_grp * g].reshape((n_grp, g) + a.shape[1:]),
                tuple(params["blocks"]),
            )
            x, _ = jax.lax.scan(
                group_fn, x, (head, jnp.zeros((n_grp,)))
            )
            if rem:
                tail_blocks = jax.tree.map(
                    lambda a: a[n_grp * g :], tuple(params["blocks"])
                )
                x, _ = jax.lax.scan(
                    scan_fn, x,
                    (tail_blocks, tuple(jnp.zeros((rem,)) for _ in range(period))),
                )
        else:
            x, stacked_nc = jax.lax.scan(
                scan_fn, x, (tuple(params["blocks"]), bc)
            )
            if has_cache:
                new_cache["blocks"] = list(stacked_nc)
    elif params.get("blocks") is not None:  # n_super == 1, unscanned
        bc = tuple(cache["blocks"]) if has_cache else (None,) * period
        x, nc = block_body(x, tuple(params["blocks"]), bc)
        if has_cache:
            new_cache["blocks"] = list(nc)

    # tail (pattern remainder) + fully-unstacked models
    tail_params = params.get("tail") or []
    n_body = n_super * period if n_super > 1 or params.get("blocks") else 0
    for i, lp in enumerate(tail_params):
        li = n_body + i
        lc = cache["tail"][i] if has_cache else None
        x, nc = apply_layer(
            x, lp, cfg, kinds[li], cfg.is_moe_layer(li), lc, enc_out, decode
        )
        new_cache["tail"].append(nc)

    if last_only:
        x = x[:, -1:]  # prefill: only the last position feeds the LM head
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype))
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    if cfg.final_softcap:
        logits = softcap(logits.astype(jnp.float32), cfg.final_softcap)
    logits = shard(logits, ("batch", None, "vocab"))
    return logits, (new_cache if has_cache else None)
