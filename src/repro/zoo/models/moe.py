"""Mixture-of-Experts with sort-based capacity dispatch (EP over "model").

Design note (DESIGN.md §4): the token->expert dispatch is where the paper's
LD-kernel insight *conceptually transfers* — tokens are count-sorted by
destination expert so each expert's inputs become a contiguous dense slab
(the ELL idea), processed by a plain dense matmul.  Compared to the GSPMD
one-hot dispatch einsum (which materialises a (T, E, C) tensor), the
sort-based form keeps memory at O(E*C*D + T*k):

    scores -> top_k -> stable-sort (token,expert) pairs by expert
    -> position-within-expert (capacity C drops overflow)
    -> scatter tokens into the (E, C, D) expert slab   [all-to-all]
    -> per-expert dense FFN (experts sharded over "model")
    -> gather back + combine-weight sum                [all-to-all]
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.zoo.configs.base import ModelConfig
from repro.zoo.models.layers import mlp
from repro.sharding import shard


def capacity(tokens: int, cfg: ModelConfig) -> int:
    c = int(tokens * cfg.top_k / max(cfg.num_experts, 1) * cfg.capacity_factor)
    return max(c, cfg.top_k)


def route(x2d: jax.Array, router_w: jax.Array, cfg: ModelConfig):
    """Top-k routing.  x2d: (T, D).  Returns (idx (T,k), weights (T,k))."""
    logits = jnp.einsum("td,de->te", x2d, router_w).astype(jnp.float32)
    gates = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(gates, cfg.top_k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)  # renorm
    return top_i.astype(jnp.int32), top_w.astype(x2d.dtype)


def moe_ffn(x: jax.Array, p: dict, cfg: ModelConfig) -> jax.Array:
    """x: (B, S, D) -> (B, S, D)."""
    b, s, d = x.shape
    t = b * s
    x2 = x.reshape(t, d)
    top_i, top_w = route(x2, p["router"], cfg)
    e, k = cfg.num_experts, cfg.top_k
    c = capacity(t, cfg)

    flat_e = top_i.reshape(-1)                    # (T*k,)
    tok_of = jnp.arange(t * k, dtype=jnp.int32) // k

    # count-sort by expert: position within the expert's contiguous segment
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    first_of_val = jnp.searchsorted(sorted_e, sorted_e, side="left")
    pos_sorted = jnp.arange(t * k, dtype=jnp.int32) - first_of_val.astype(jnp.int32)
    pos = jnp.zeros((t * k,), jnp.int32).at[order].set(pos_sorted)
    keep = pos < c
    slot = jnp.where(keep, pos, c)                # c = overflow bin (dropped)

    # scatter into the expert slab (E, C, D) — EP all-to-all happens here
    slab = jnp.zeros((e, c + 1, d), x.dtype)
    slab = slab.at[flat_e, slot].add(x2[tok_of])
    slab = shard(slab[:, :c], ("experts", None, None))

    # dense per-expert FFN (einsum over the expert dim stays local under EP)
    h = jnp.einsum("ecd,edf->ecf", slab, p["w_in"])
    if "w_gate" in p:
        g = jnp.einsum("ecd,edf->ecf", slab, p["w_gate"])
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    h = shard(h, ("experts", None, None))
    y_slab = jnp.einsum("ecf,efd->ecd", h, p["w_out"])

    # gather back + combine
    y_tok = y_slab[flat_e, jnp.minimum(slot, c - 1)]       # (T*k, D)
    y_tok = jnp.where(keep[:, None], y_tok, 0.0)
    y = (y_tok.reshape(t, k, d) * top_w[..., None]).sum(axis=1)
    return y.reshape(b, s, d)


def _local_dispatch_ffn(x2, top_i, top_w, p_local, cfg: ModelConfig, lo: int, e_local: int, c: int):
    """Sort-based dispatch + dense FFN over ONE device's expert slice.

    Runs inside shard_map: every array is local, so the count-sort /
    scatter lowers to plain per-device code (no GSPMD rewrites).
    x2: (T, D) local tokens; experts [lo, lo+e_local) live here.
    """
    t, d = x2.shape
    k = cfg.top_k
    flat_e = top_i.reshape(-1) - lo                       # (T*k,) local ids
    in_range = (flat_e >= 0) & (flat_e < e_local)
    key = jnp.where(in_range, flat_e, e_local)            # sort key; out = bin e_local
    order = jnp.argsort(key, stable=True)
    sorted_e = key[order]
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    pos_sorted = jnp.arange(t * k, dtype=jnp.int32) - first.astype(jnp.int32)
    pos = jnp.zeros((t * k,), jnp.int32).at[order].set(pos_sorted)
    keep = in_range & (pos < c)
    slot = jnp.where(keep, pos, c)
    e_idx = jnp.where(in_range, flat_e, e_local - 1)

    tok_of = jnp.arange(t * k, dtype=jnp.int32) // k
    slab = jnp.zeros((e_local, c + 1, d), x2.dtype)
    slab = slab.at[e_idx, slot].add(x2[tok_of] * keep[:, None].astype(x2.dtype))
    slab = slab[:, :c]

    h = jnp.einsum("ecd,edf->ecf", slab, p_local["w_in"])
    if "w_gate" in p_local:
        g = jnp.einsum("ecd,edf->ecf", slab, p_local["w_gate"])
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    y_slab = jnp.einsum("ecf,efd->ecd", h, p_local["w_out"])

    y_tok = y_slab[e_idx, jnp.minimum(slot, c - 1)]
    y_tok = jnp.where(keep[:, None], y_tok, 0.0)
    y = (y_tok.reshape(t, k, d) * top_w[..., None]).sum(axis=1)
    return y


def moe_ffn_dist(x: jax.Array, p: dict, cfg: ModelConfig) -> jax.Array:
    """Production MoE: shard_map over the mesh.

    Activations are batch-sharded over ("pod","data") and replicated over
    "model"; experts are sharded over "model" (EP).  Each device therefore
    already holds every token it could need — dispatch is a *local*
    count-sort + gather onto its expert slice, and the only collective is
    the per-layer psum over "model" (the exact TP-MLP pattern).  FSDP
    weight shards are re-gathered by shard_map's in_specs resharding.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.sharding import current_ctx

    ctx = current_ctx()
    mesh = ctx.mesh
    n_shards = mesh.shape["model"]
    if cfg.num_experts % n_shards != 0:
        return moe_ffn(x, p, cfg)
    e_local = cfg.num_experts // n_shards
    bs_axes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    bs = bs_axes if len(bs_axes) > 1 else bs_axes[0]
    b, s, d = x.shape

    def body(xb, router, w_in, w_gate, w_out):
        bl = xb.shape[0]
        t = bl * s
        x2 = xb.reshape(t, d)
        top_i, top_w = route(x2, router, cfg)  # identical on every model shard
        me = jax.lax.axis_index("model")
        lo = (me * e_local).astype(jnp.int32)
        c = capacity(t, cfg)
        p_local = {"w_in": w_in, "w_out": w_out}
        if w_gate is not None:
            p_local["w_gate"] = w_gate
        y = _local_dispatch_ffn(x2, top_i, top_w, p_local, cfg, lo, e_local, c)
        y = jax.lax.psum(y, "model")
        return y.reshape(bl, s, d)

    w_gate = p.get("w_gate")
    in_specs = (
        P(bs, None, None),
        P(None, None),
        P("model", None, None),
        P("model", None, None) if w_gate is not None else None,
        P("model", None, None),
    )
    return shard_map(
        body,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=P(bs, None, None),
        check_rep=False,
    )(x, p["router"], p["w_in"], w_gate, p["w_out"])


def moe_apply(x: jax.Array, p: dict, cfg: ModelConfig) -> jax.Array:
    """Dispatch to the shard_map path when a mesh context is active."""
    from repro.sharding import current_ctx

    if current_ctx() is not None:
        return moe_ffn_dist(x, p, cfg)
    return moe_ffn(x, p, cfg)


def aux_load_balance_loss(x2d: jax.Array, router_w: jax.Array, cfg: ModelConfig):
    """Switch-style load-balancing auxiliary loss (mean gate * mean count)."""
    logits = jnp.einsum("td,de->te", x2d, router_w).astype(jnp.float32)
    gates = jax.nn.softmax(logits, axis=-1)
    top1 = jnp.argmax(gates, axis=-1)
    e = cfg.num_experts
    counts = jnp.zeros((e,), jnp.float32).at[top1].add(1.0) / x2d.shape[0]
    return e * jnp.sum(counts * gates.mean(axis=0))
