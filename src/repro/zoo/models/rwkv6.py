"""RWKV-6 "Finch" time-mix + channel-mix (arXiv:2404.05892), in JAX.

Per head (head size ``hs``), with data-dependent per-channel decay
``w_t = exp(-exp(w0 + tanh(x_t A) B))``:

    y_t = ( S_{t-1} + (u ⊙ k_t) v_tᵀ )ᵀ r_t
    S_t = diag(w_t) S_{t-1} + k_t v_tᵀ

Two execution forms, numerically identical (tests assert allclose):

  * ``scan``     — ``lax.scan`` over time, O(1) state: the decode path and
                   the paper-faithful-style training baseline.
  * ``chunked``  — O(T/C) sequential steps of dense intra-chunk matmuls
                   (the linear-attention chunk trick): inter-chunk state is
                   carried like scan, intra-chunk contributions become
                   causal matmuls that feed the MXU.  This is the
                   beyond-paper perf form used in §Perf.

Chunked-form numerics: decay factors are exponentials of per-channel
cumulative logs; all carry/state factors have non-positive exponents (safe),
and the intra-chunk attention is stabilised around the chunk-midpoint
cumulant so both factors stay < e^(C/2 * |log w|_max).  ``log w`` is clamped
at -8 (decay < 3e-4 is numerically dead anyway), bounding exponents by
C/2 * 8 < 88 for the default C=16.

Token-shift: every projection sees ``lerp(x_t, x_{t-1}, mu)``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.zoo.configs.base import ModelConfig

LOGW_FLOOR = -8.0

# §Perf toggle: force the sequential lax.scan recurrence for training
# shapes (the baseline the chunked form is measured against).
FORCE_SCAN = False


def _token_shift(x: jax.Array, prev: Optional[jax.Array]):
    """x: (B,S,D) -> x shifted right by one; ``prev`` is the carry (B,D)."""
    if prev is None:
        p = jnp.zeros_like(x[:, :1])
    else:
        p = prev[:, None]
    return jnp.concatenate([p, x[:, :-1]], axis=1)


def _projections(x: jax.Array, p: dict, cfg: ModelConfig, x_prev):
    xs = _token_shift(x, x_prev)
    mix = lambda mu: x + (xs - x) * mu  # lerp with learned per-channel mu
    r = jnp.einsum("bsd,de->bse", mix(p["mu"]["r"]), p["wr"])
    k = jnp.einsum("bsd,de->bse", mix(p["mu"]["k"]), p["wk"])
    v = jnp.einsum("bsd,de->bse", mix(p["mu"]["v"]), p["wv"])
    g = jnp.einsum("bsd,de->bse", mix(p["mu"]["g"]), p["wg"])
    # data-dependent decay (low-rank LoRA): log w = -exp(w0 + tanh(x A) B)
    lora = jnp.einsum(
        "bsl,ld->bsd",
        jnp.tanh(jnp.einsum("bsd,dl->bsl", mix(p["mu"]["w"]), p["wa"])),
        p["wb"],
    )
    logw = -jnp.exp((p["w0"] + lora).astype(jnp.float32))
    logw = jnp.maximum(logw, LOGW_FLOOR)
    nh = cfg.mixer_heads_
    hs = cfg.d_model // nh
    shp = lambda a: a.reshape(a.shape[0], a.shape[1], nh, hs)
    return shp(r), shp(k), shp(v), g, shp(logw)


def _finalize(y: jax.Array, g: jax.Array, p: dict, cfg: ModelConfig, dtype):
    b, s = y.shape[:2]
    y = y.reshape(b, s, cfg.d_model).astype(jnp.float32)
    # per-head group norm
    nh = cfg.mixer_heads_
    yh = y.reshape(b, s, nh, -1)
    yh = (yh - yh.mean(-1, keepdims=True)) * jax.lax.rsqrt(
        yh.var(-1, keepdims=True) + 1e-5
    )
    y = (yh.reshape(b, s, cfg.d_model) * p["ln_x"]).astype(dtype)
    y = y * jax.nn.silu(g)
    return jnp.einsum("bsd,de->bse", y, p["wo"])


def init_state(cfg: ModelConfig, batch: int) -> dict:
    nh = cfg.mixer_heads_
    hs = cfg.d_model // nh
    return {
        "s": jnp.zeros((batch, nh, hs, hs), jnp.float32),
        "x_prev": jnp.zeros((batch, cfg.d_model), jnp.bfloat16),
        "ffn_prev": jnp.zeros((batch, cfg.d_model), jnp.bfloat16),
    }


def time_mix_scan(
    x: jax.Array, p: dict, cfg: ModelConfig, state: Optional[dict] = None
):
    """lax.scan over time.  Returns (out (B,S,D), new_state)."""
    b, s, d = x.shape
    nh = cfg.mixer_heads_
    hs = d // nh
    x_prev = state["x_prev"].astype(x.dtype) if state else None
    r, k, v, g, logw = _projections(x, p, cfg, x_prev)
    u = p["u"].astype(jnp.float32)
    s0 = state["s"] if state else jnp.zeros((b, nh, hs, hs), jnp.float32)

    def step(S, inp):
        r_t, k_t, v_t, lw_t = (a.astype(jnp.float32) for a in inp)  # (B,nh,hs)
        kv = k_t[..., :, None] * v_t[..., None, :]
        y = jnp.einsum("bhij,bhi->bhj", S + u[..., :, None] * kv, r_t)
        S = jnp.exp(lw_t)[..., :, None] * S + kv
        return S, y

    seq = tuple(jnp.moveaxis(a, 1, 0) for a in (r, k, v, logw))
    s_fin, ys = jax.lax.scan(step, s0, seq)
    y = jnp.moveaxis(ys, 0, 1)  # (B,S,nh,hs)
    out = _finalize(y, g, p, cfg, x.dtype)
    return out, {"s": s_fin, "x_prev": x[:, -1]}


def time_mix_chunked(
    x: jax.Array,
    p: dict,
    cfg: ModelConfig,
    state: Optional[dict] = None,
    chunk: int = 16,
):
    """Chunked parallel form: identical math, O(T/chunk) sequential steps."""
    b, s, d = x.shape
    nh = cfg.mixer_heads_
    hs = d // nh
    x_prev = state["x_prev"].astype(x.dtype) if state else None
    r, k, v, g, logw = _projections(x, p, cfg, x_prev)
    u = p["u"].astype(jnp.float32)
    s0 = state["s"] if state else jnp.zeros((b, nh, hs, hs), jnp.float32)

    pad = (-s) % chunk
    if pad:
        zp = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v, logw = map(zp, (r, k, v, logw))
        # padded logw = 0 (w = 1): state passes through unchanged
    n_ch = (s + pad) // chunk

    def to_chunks(a):  # (B, S, nh, hs) -> (n_ch, B, C, nh, hs)
        return jnp.moveaxis(a.reshape(b, n_ch, chunk, nh, hs), 1, 0)

    rc, kc, vc, lwc = map(to_chunks, (r, k, v, logw))
    tri = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)

    def chunk_step(S, inp):
        r_, k_, v_, lw = (a.astype(jnp.float32) for a in inp)  # (B,C,nh,hs)
        cum = jnp.cumsum(lw, axis=1)                  # log W_t (inclusive)
        w_prev = jnp.exp(cum - lw)                    # W_{t-1} <= 1
        # carry-in: y_t += (r_t ⊙ W_{t-1}) · S_in
        y = jnp.einsum("bchi,bhij->bchj", r_ * w_prev, S)
        # intra-chunk attention, stabilised at the chunk midpoint cumulant
        m = cum[:, chunk // 2][:, None]               # (B,1,nh,hs)
        qa = r_ * jnp.exp(cum - lw - m)
        ka = k_ * jnp.exp(m - cum)
        att = jnp.einsum("bchi,bdhi->bhcd", qa, ka)
        att = jnp.where(tri[None, None], att, 0.0)    # strict causal (j < t)
        y = y + jnp.einsum("bhcd,bdhj->bchj", att, v_)
        # diagonal bonus term
        diag = jnp.einsum("bchi,bchi->bch", r_ * u[None, None], k_)
        y = y + diag[..., None] * v_
        # state carry-out: S' = W_C S + Σ_j (W_C/W_j) k_j v_jᵀ
        w_total = jnp.exp(cum[:, -1])                 # (B,nh,hs)
        k_state = k_ * jnp.exp(cum[:, -1][:, None] - cum)  # exponent <= 0
        S = w_total[..., :, None] * S + jnp.einsum("bchi,bchj->bhij", k_state, v_)
        return S, y

    s_fin, ys = jax.lax.scan(chunk_step, s0, (rc, kc, vc, lwc))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, n_ch * chunk, nh, hs)[:, :s]
    out = _finalize(y, g, p, cfg, x.dtype)
    return out, {"s": s_fin, "x_prev": x[:, -1]}


def channel_mix(x: jax.Array, p: dict, prev: Optional[jax.Array] = None):
    """RWKV channel-mix FFN: r-gated squared-ReLU.  Returns (out, carry)."""
    xs = _token_shift(x, None if prev is None else prev.astype(x.dtype))
    mix = lambda mu: x + (xs - x) * mu
    kx = mix(p["mu_k"])
    rx = mix(p["mu_r"])
    h = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", kx, p["w_k"])))
    out = jnp.einsum("bsf,fd->bsd", h, p["w_v"])
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", rx, p["w_r"]))
    return r * out, x[:, -1]
