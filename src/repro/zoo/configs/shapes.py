"""The assigned input-shape set (LM-family: seq_len x global_batch) and
ShapeDtypeStruct input specs per (arch, shape).

  train_4k      seq 4,096    batch 256   -> train_step
  prefill_32k   seq 32,768   batch 32    -> prefill_step
  decode_32k    seq 32,768   batch 128   -> serve_step (1 new token)
  long_500k     seq 524,288  batch 1     -> serve_step (sub-quadratic only)
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.zoo.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def _enc_spec(cfg: ModelConfig, batch: int):
    """Stub modality frontend: precomputed frame/patch embeddings."""
    s_enc = cfg.encoder_seq or cfg.cross_seq
    if not s_enc:
        return None
    return jax.ShapeDtypeStruct((batch, s_enc, cfg.d_model), jnp.bfloat16)


def input_specs(cfg: ModelConfig, shape_name: str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this shape.

    For decode shapes the KV-cache/state specs are derived with
    ``jax.eval_shape`` over the cache initialiser — weak-type-correct and
    allocation-free.
    """
    sh = SHAPES[shape_name]
    b, s = sh.global_batch, sh.seq_len
    tok = jnp.int32
    if sh.kind == "train":
        specs = {"tokens": jax.ShapeDtypeStruct((b, s + 1), tok)}
    elif sh.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), tok)}
    else:  # decode: one new token against a cache of seq_len
        from repro.zoo.models.transformer import init_cache_tree

        cache = jax.eval_shape(
            lambda: init_cache_tree(cfg, b, s, dtype=jnp.bfloat16)
        )
        specs = {"token": jax.ShapeDtypeStruct((b, 1), tok), "cache": cache}
    enc = _enc_spec(cfg, b)
    if enc is not None and sh.kind != "decode":
        specs["enc_input"] = enc
    return specs


def supported_shapes(cfg: ModelConfig) -> list:
    return [k for k in SHAPES if k not in cfg.skip_shapes]
