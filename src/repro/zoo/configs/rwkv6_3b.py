"""rwkv6-3b [ssm]: 32L d_model=2560 (attn-free) d_ff=8960 vocab=65536 —
Finch, data-dependent decay.  [arXiv:2404.05892; hf]

Attention-free: supports long_500k with O(1) recurrent state.
GROOT-technique note (DESIGN.md §4): inapplicable (dense recurrence,
no sparse adjacency).
"""
import dataclasses

from repro.zoo.configs.base import ModelConfig

ARCH_ID = "rwkv6-3b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="ssm",
        num_layers=32,
        d_model=2560,
        num_heads=40,          # rwkv heads = d_model / 64
        num_kv_heads=40,
        d_ff=8960,
        vocab_size=65536,
        mixer_heads=40,
        tie_embeddings=False,
        layer_pattern=("rwkv",),
        skip_shapes=(),
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=3, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=512, mixer_heads=4,
    )
