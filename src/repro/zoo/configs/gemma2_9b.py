"""gemma2-9b [dense]: 42L d_model=3584 16H (GQA kv=8) d_ff=14336
vocab=256000 — local+global alternating, logit softcap.
[arXiv:2408.00118; hf]"""
import dataclasses

from repro.zoo.configs.base import ModelConfig

ARCH_ID = "gemma2-9b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        num_layers=42,
        d_model=3584,
        num_heads=16,
        num_kv_heads=8,
        head_dim=256,
        d_ff=14336,
        vocab_size=256000,
        attn_softcap=50.0,
        final_softcap=30.0,
        sliding_window=4096,
        layer_pattern=("local", "global"),
        tie_embeddings=True,
        # skip note: not pure full-attention, but every 2nd (global) layer
        # still needs the full 512k cache -> long_500k skipped (DESIGN.md).
        skip_shapes=("long_500k",),
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=4, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=512, head_dim=16, sliding_window=8,
    )
