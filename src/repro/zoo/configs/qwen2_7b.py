"""qwen2-7b [dense]: 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064 — GQA, QKV bias.  [arXiv:2407.10671; hf]"""
import dataclasses

from repro.zoo.configs.base import ModelConfig

ARCH_ID = "qwen2-7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        num_layers=28,
        d_model=3584,
        num_heads=28,
        num_kv_heads=4,
        d_ff=18944,
        vocab_size=152064,
        qkv_bias=True,
        head_pad_to=32,   # 28 heads -> TP16-compatible (zero-pad, exact)
        rope_theta=1e6,
        tie_embeddings=False,
        layer_pattern=("global",),
        skip_shapes=("long_500k",),
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=3, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=160, vocab_size=512, head_dim=16,
    )
