"""qwen3-moe-235b-a22b [moe]: 94L d_model=4096 64H (GQA kv=4) d_ff=1536
vocab=151936, MoE 128e top-8.  [hf:Qwen/Qwen3-30B-A3B; hf]"""
import dataclasses

from repro.zoo.configs.base import ModelConfig

ARCH_ID = "qwen3-moe-235b-a22b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="moe",
        num_layers=94,
        d_model=4096,
        num_heads=64,
        num_kv_heads=4,
        head_dim=128,
        d_ff=1536,
        vocab_size=151936,
        qk_norm=True,
        moe=True,
        num_experts=128,
        top_k=8,
        moe_d_ff=1536,
        moe_interleave=1,
        rope_theta=1e6,
        tie_embeddings=False,
        layer_pattern=("global",),
        skip_shapes=("long_500k",),
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=3, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=64, vocab_size=512, head_dim=16, num_experts=4, top_k=2,
        moe_d_ff=64, capacity_factor=4.0,
    )
