"""llama4-maverick-400b-a17b [moe]: 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, MoE 128e top-1 — MoE, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

Config interpretation (DESIGN.md §6): the published Maverick interleaves
MoE every 2nd layer (interleave_moe_layer_step=2), which reproduces the
400B-total / 17B-active figures; an all-MoE 48L reading would be ~780B.
"""
import dataclasses

from repro.zoo.configs.base import ModelConfig

ARCH_ID = "llama4-maverick-400b-a17b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="moe",
        num_layers=48,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        d_ff=8192,
        vocab_size=202048,
        moe=True,
        num_experts=128,
        top_k=1,
        moe_d_ff=8192,
        moe_interleave=2,
        head_pad_to=48,   # 40 heads -> TP16-compatible (zero-pad, exact)
        rope_theta=5e5,
        tie_embeddings=False,
        layer_pattern=("global",),
        skip_shapes=("long_500k",),
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=4, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=512, head_dim=16, num_experts=4, moe_d_ff=64, capacity_factor=4.0,
    )
