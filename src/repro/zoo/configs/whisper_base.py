"""whisper-base [audio]: 6L d_model=512 8H d_ff=2048 vocab=51865 —
enc-dec, conv frontend (stub).  [arXiv:2212.04356; unverified]

The modality frontend is a STUB per the brief: input_specs() provides
precomputed (B, 1500, d_model) frame embeddings.  Adaptation note
(DESIGN.md): real whisper caps decoder positions at 448; the brief's
decode shapes exercise the backbone, so the positional range is extended.
"""
import dataclasses

from repro.zoo.configs.base import ModelConfig

ARCH_ID = "whisper-base"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="audio",
        num_layers=6,            # decoder depth; + 6 encoder layers below
        d_model=512,
        num_heads=8,
        num_kv_heads=8,
        d_ff=2048,
        vocab_size=51865,
        head_pad_to=16,   # 8 heads -> TP16-compatible (zero-pad, exact)
        encoder_layers=6,
        encoder_seq=1500,
        act="gelu",
        tie_embeddings=True,
        layer_pattern=("cross+global",),
        skip_shapes=("long_500k",),  # dense decoder self-attention cache
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=512, encoder_layers=2, encoder_seq=16,
    )
