"""llama-3.2-vision-11b [vlm]: 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256 — cross-attn image layers (every 5th).
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]

Vision frontend is a STUB per the brief: input_specs() provides
precomputed (B, 1601, d_model) patch embeddings (projector output).
"""
import dataclasses

from repro.zoo.configs.base import ModelConfig

ARCH_ID = "llama-3.2-vision-11b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="vlm",
        num_layers=40,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=14336,
        vocab_size=128256,
        cross_seq=1601,
        rope_theta=5e5,
        tie_embeddings=False,
        layer_pattern=("global", "global", "global", "global", "cross+global"),
        skip_shapes=("long_500k",),
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=5, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=512, head_dim=16, cross_seq=16,
    )
