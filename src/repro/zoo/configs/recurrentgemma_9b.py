"""recurrentgemma-9b [hybrid]: 38L d_model=4096 16H (GQA kv=1) d_ff=12288
vocab=256000 — RG-LRU + local attn, 1:2.  [arXiv:2402.19427; unverified]

Pattern: (rglru, rglru, local-attention[window 2048]) repeating.
Supports long_500k: recurrent state is O(1), attention cache is bounded
by the 2048 window.
"""
import dataclasses

from repro.zoo.configs.base import ModelConfig

ARCH_ID = "recurrentgemma-9b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="hybrid",
        num_layers=38,
        d_model=4096,
        num_heads=16,
        num_kv_heads=1,
        head_dim=256,
        d_ff=12288,
        vocab_size=256000,
        sliding_window=2048,
        d_rnn=4096,
        conv_width=4,
        tie_embeddings=True,
        layer_pattern=("rglru", "rglru", "local"),
        skip_shapes=(),
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=4, d_model=64, num_heads=4, num_kv_heads=1,
        d_ff=128, vocab_size=512, head_dim=16, sliding_window=8, d_rnn=64,
    )
