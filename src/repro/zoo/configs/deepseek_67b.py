"""deepseek-67b [dense]: 95L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=102400 — llama-arch.  [arXiv:2401.02954; hf]"""
import dataclasses

from repro.zoo.configs.base import ModelConfig

ARCH_ID = "deepseek-67b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        num_layers=95,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        d_ff=22016,
        vocab_size=102400,
        rope_theta=1e4,
        tie_embeddings=False,
        layer_pattern=("global",),
        skip_shapes=("long_500k",),
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=3, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=192, vocab_size=512, head_dim=16,
    )
