"""Model configuration + parameter-spec system.

One :class:`ModelConfig` dataclass drives all 10 assigned architectures
(plus reduced smoke variants).  Parameters are described once as a tree of
:class:`ParamSpec` (shape + logical axes + init); the same tree serves

  * ``materialize``  — real arrays for smoke tests / examples,
  * ``abstract``     — ShapeDtypeStruct stand-ins for the dry-run
                       (no allocation),
  * ``shardings``    — NamedSharding per leaf from the logical->mesh rules
                       (``repro.sharding.rules``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | audio | vlm | hybrid | gnn
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads

    # attention options
    qk_norm: bool = False            # qwen3
    qkv_bias: bool = False           # qwen2
    attn_softcap: Optional[float] = None    # gemma2 attention logit softcap
    final_softcap: Optional[float] = None   # gemma2 final logit softcap
    sliding_window: int = 0          # local-attention window (0 = none)
    rope_theta: float = 1e4

    # layer pattern, cycled over the depth.  Entries:
    #   "global"  full causal attention + FFN
    #   "local"   sliding-window attention + FFN
    #   "rwkv"    RWKV6 time-mix + channel-mix
    #   "rglru"   RG-LRU recurrent block + FFN
    #   "cross+global"  causal self-attn, then cross-attn to encoder, + FFN
    layer_pattern: tuple = ("global",)

    # MoE
    moe: bool = False
    num_experts: int = 0
    top_k: int = 1
    moe_d_ff: int = 0
    moe_interleave: int = 1          # every k-th layer is MoE (llama4: 2)
    capacity_factor: float = 1.25

    # families
    mixer_heads: int = 0             # rwkv6 head count (d_model/64 default)
    conv_width: int = 4              # rglru temporal conv
    d_rnn: int = 0                   # rglru recurrent width (0 -> d_model)
    encoder_layers: int = 0          # whisper encoder depth
    encoder_seq: int = 0             # stub frontend length (whisper 1500)
    cross_seq: int = 0               # vlm stub patch-sequence length

    # TP head padding: pad the q/o head axis to this count with zero
    # weights (0 = no padding).  Exact: see models/attention.py note.
    head_pad_to: int = 0

    # embeddings / numerics
    tie_embeddings: bool = True
    act: str = "swiglu"              # swiglu | gelu
    dtype: str = "bfloat16"
    norm_eps: float = 1e-6

    # which shapes this arch supports (DESIGN.md shape-skip notes)
    skip_shapes: tuple = ()

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a lane multiple so logits stay TP-shardable
        (whisper's 51865 is the only non-divisible case)."""
        return -(-self.vocab_size // 128) * 128

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_heads(self) -> int:
        return max(self.num_heads, self.head_pad_to)

    @property
    def d_rnn_(self) -> int:
        return self.d_rnn or self.d_model

    @property
    def mixer_heads_(self) -> int:
        return self.mixer_heads or max(self.d_model // 64, 1)

    def layer_kinds(self) -> list:
        p = self.layer_pattern
        return [p[i % len(p)] for i in range(self.num_layers)]

    def is_moe_layer(self, i: int) -> bool:
        return self.moe and ((i + 1) % self.moe_interleave == 0)

    @property
    def pattern_period(self) -> int:
        """Length of the repeating super-block (layer pattern x MoE phase)."""
        p = len(self.layer_pattern)
        if self.moe:
            p = int(np.lcm(p, self.moe_interleave))
        return p

    def param_count(self) -> int:
        """Total parameters (host-side arithmetic; no arrays)."""
        total = 0
        for leaf in jax.tree.leaves(
            param_tree(self), is_leaf=lambda x: isinstance(x, ParamSpec)
        ):
            total += int(np.prod(leaf.shape))
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of num_experts)."""
        if not self.moe:
            return self.param_count()
        total = 0
        for leaf in jax.tree.leaves(
            param_tree(self), is_leaf=lambda x: isinstance(x, ParamSpec)
        ):
            n = int(np.prod(leaf.shape))
            if "experts" in leaf.axes:
                n = n * self.top_k // max(self.num_experts, 1)
            total += n
        return total


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple
    axes: tuple              # logical axis name (or None) per dim
    init: str = "normal"     # normal | zeros | ones
    scale: float = 0.0       # 0 -> 1/sqrt(fan_in)


def _p(shape, axes, init="normal", scale=0.0):
    assert len(shape) == len(axes), (shape, axes)
    return ParamSpec(tuple(int(s) for s in shape), tuple(axes), init, scale)


def _attention_specs(cfg: ModelConfig, cross: bool = False) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.padded_heads, cfg.num_kv_heads, cfg.head_dim_
    s: dict[str, Any] = {
        "wq": _p((d, h, hd), ("d_model", "heads", None)),
        "wk": _p((d, kv, hd), ("d_model", "kv_heads", None)),
        "wv": _p((d, kv, hd), ("d_model", "kv_heads", None)),
        "wo": _p((h, hd, d), ("heads", None, "d_model")),
    }
    if cfg.qkv_bias and not cross:
        s["bq"] = _p((h, hd), ("heads", None), init="zeros")
        s["bk"] = _p((kv, hd), ("kv_heads", None), init="zeros")
        s["bv"] = _p((kv, hd), ("kv_heads", None), init="zeros")
    if cfg.qk_norm:
        s["q_norm"] = _p((hd,), (None,), init="ones")
        s["k_norm"] = _p((hd,), (None,), init="ones")
    return s


def _mlp_specs(cfg: ModelConfig, d_ff: Optional[int] = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    s = {
        "w_in": _p((d, f), ("d_model", "d_ff")),
        "w_out": _p((f, d), ("d_ff", "d_model")),
    }
    if cfg.act == "swiglu":
        s["w_gate"] = _p((d, f), ("d_model", "d_ff"))
    return s


def _moe_specs(cfg: ModelConfig) -> dict:
    d, f, e = cfg.d_model, cfg.moe_d_ff or cfg.d_ff, cfg.num_experts
    s = {
        "router": _p((d, e), ("d_model", None)),
        "w_in": _p((e, d, f), ("experts", "d_model", None)),
        "w_out": _p((e, f, d), ("experts", None, "d_model")),
    }
    if cfg.act == "swiglu":
        s["w_gate"] = _p((e, d, f), ("experts", "d_model", None))
    return s


def _rwkv_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    nh = cfg.mixer_heads_
    hs = d // nh
    lora = max(32, d // 16)
    return {
        # token-shift mix coefficients (static per-channel; x_t vs x_{t-1})
        "mu": {k: _p((d,), ("d_model",), init="zeros") for k in "rkvwg"},
        "wr": _p((d, d), ("d_model", "heads_flat")),
        "wk": _p((d, d), ("d_model", "heads_flat")),
        "wv": _p((d, d), ("d_model", "heads_flat")),
        "wg": _p((d, d), ("d_model", "heads_flat")),
        "wo": _p((d, d), ("heads_flat", "d_model")),
        # data-dependent decay LoRA: w = exp(-exp(w0 + tanh(x A) B))
        "w0": _p((d,), ("d_model",), init="zeros"),
        "wa": _p((d, lora), ("d_model", None)),
        "wb": _p((lora, d), (None, "d_model")),
        # per-head bonus u
        "u": _p((nh, hs), (None, None), init="zeros"),
        "ln_x": _p((d,), ("d_model",), init="ones"),  # group-norm gain
    }


def _rglru_specs(cfg: ModelConfig) -> dict:
    d, dr = cfg.d_model, cfg.d_rnn_
    return {
        "w_x": _p((d, dr), ("d_model", "d_ff")),     # input branch
        "w_gate_branch": _p((d, dr), ("d_model", "d_ff")),
        "conv_w": _p((cfg.conv_width, dr), (None, "d_ff"), init="zeros"),
        "conv_b": _p((dr,), ("d_ff",), init="zeros"),
        "w_input_gate": _p((dr, dr), ("d_ff", None)),
        "w_rec_gate": _p((dr, dr), ("d_ff", None)),
        "lambda_p": _p((dr,), ("d_ff",), init="ones"),  # recurrence decay param
        "w_out": _p((dr, d), ("d_ff", "d_model")),
    }


def _layer_specs(cfg: ModelConfig, layer_idx: int) -> dict:
    kind = cfg.layer_kinds()[layer_idx]
    s: dict[str, Any] = {"ln1": _p((cfg.d_model,), ("d_model",), init="ones")}
    if kind in ("global", "local"):
        s["attn"] = _attention_specs(cfg)
    elif kind == "cross+global":
        s["attn"] = _attention_specs(cfg)
        s["cross"] = _attention_specs(cfg, cross=True)
        s["ln_cross"] = _p((cfg.d_model,), ("d_model",), init="ones")
    elif kind == "rwkv":
        s["rwkv"] = _rwkv_specs(cfg)
    elif kind == "rglru":
        s["rglru"] = _rglru_specs(cfg)
    else:  # pragma: no cover
        raise ValueError(kind)
    s["ln2"] = _p((cfg.d_model,), ("d_model",), init="ones")
    if cfg.is_moe_layer(layer_idx):
        s["moe"] = _moe_specs(cfg)
    elif kind == "rwkv":
        # rwkv channel-mix (its own FFN form): relu(x Wk)^2 Wv with r-gate
        d, f = cfg.d_model, cfg.d_ff
        s["ffn"] = {
            "mu_k": _p((d,), ("d_model",), init="zeros"),
            "mu_r": _p((d,), ("d_model",), init="zeros"),
            "w_k": _p((d, f), ("d_model", "d_ff")),
            "w_v": _p((f, d), ("d_ff", "d_model")),
            "w_r": _p((d, d), ("d_model", None)),
        }
    else:
        s["ffn"] = _mlp_specs(cfg)
    return s


def param_tree(cfg: ModelConfig) -> dict:
    """Full parameter spec tree (pre-stacking; layers listed per depth)."""
    d = cfg.d_model
    tree: dict[str, Any] = {
        "embed": _p((cfg.padded_vocab, d), ("vocab", "d_model"), scale=1.0),
        "final_norm": _p((d,), ("d_model",), init="ones"),
        "layers": [_layer_specs(cfg, i) for i in range(cfg.num_layers)],
    }
    if not cfg.tie_embeddings:
        tree["lm_head"] = _p((d, cfg.padded_vocab), ("d_model", "vocab"))
    if cfg.encoder_layers:  # whisper: encoder stack + frontend stub proj
        enc_cfg = dataclasses.replace(
            cfg, qk_norm=False, qkv_bias=False, moe=False, layer_pattern=("global",)
        )
        tree["encoder"] = {
            "layers": [
                {
                    "ln1": _p((d,), ("d_model",), init="ones"),
                    "attn": _attention_specs(enc_cfg),
                    "ln2": _p((d,), ("d_model",), init="ones"),
                    "ffn": _mlp_specs(enc_cfg),
                }
                for _ in range(cfg.encoder_layers)
            ],
            "final_norm": _p((d,), ("d_model",), init="ones"),
            "pos_embed": _p((cfg.encoder_seq, d), (None, "d_model"), scale=0.02),
        }
    return tree


# ---------------------------------------------------------------------------
# Spec-tree utilities
# ---------------------------------------------------------------------------

def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def materialize(tree, key, dtype) -> Any:
    """Random-init real arrays (smoke tests / examples)."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))

    def mk(spec: ParamSpec, k):
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, dtype)
        if spec.init == "ones":
            return jnp.ones(spec.shape, dtype)
        scale = spec.scale or 1.0 / np.sqrt(max(spec.shape[0], 1))
        return (jax.random.normal(k, spec.shape, jnp.float32) * scale).astype(dtype)

    return jax.tree.unflatten(treedef, [mk(s, k) for s, k in zip(leaves, keys)])


def abstract(tree, dtype) -> Any:
    """ShapeDtypeStruct stand-ins (dry-run: no allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype), tree, is_leaf=_is_spec
    )


def logical_axes(tree) -> Any:
    """Tree of logical-axes tuples, same structure as the param tree."""
    return jax.tree.map(lambda s: s.axes, tree, is_leaf=_is_spec)


def stack_layers(cfg: ModelConfig, tree: dict) -> dict:
    """Group per-depth layer *specs* into scanned super-blocks.

    Layers are grouped into repeating super-blocks of ``pattern_period``
    heterogeneous layers; the n_super repeats get a leading ``layers`` axis
    for ``lax.scan`` (small HLO, fast compile — essential for 94-layer
    archs in the dry-run).  A remainder of ``num_layers % period`` layers
    stays unstacked in ``tail``.  Operates purely on :class:`ParamSpec`
    trees, so materialised params are *born* stacked — no runtime stack.
    """
    period = cfg.pattern_period
    n_super, rem = divmod(cfg.num_layers, period)
    layers = tree["layers"]
    out = {k: v for k, v in tree.items() if k != "layers"}
    if n_super <= 1:
        out["blocks"] = None
        out["tail"] = layers
        return out
    body = layers[: n_super * period]
    out["tail"] = layers[n_super * period :]

    def stack_spec(*xs: ParamSpec) -> ParamSpec:
        return ParamSpec(
            (len(xs),) + xs[0].shape, ("layers",) + xs[0].axes, xs[0].init, xs[0].scale
        )

    # super-block j consists of layers [j*period + t for t in range(period)];
    # position-t layers are spec-identical across super-blocks by construction.
    out["blocks"] = [
        jax.tree.map(
            stack_spec,
            *[body[j * period + t] for j in range(n_super)],
            is_leaf=_is_spec,
        )
        for t in range(period)
    ]
    return out


def model_spec_tree(cfg: ModelConfig) -> dict:
    """The deployable spec tree: param_tree with layers stacked for scan."""
    return stack_layers(cfg, param_tree(cfg))
