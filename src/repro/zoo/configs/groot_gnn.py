"""groot-gnn: the paper's own architecture — GraphSAGE node classification
over partitioned EDA graphs (the 11th dry-run arch).

Not a ModelConfig (it is not an LM); exposes the same registry surface:
``config()`` returns a GrootConfig consumed by launch/dryrun.py's
dedicated GNN step builder.
"""
import dataclasses

from repro.core.gnn import GNNConfig


@dataclasses.dataclass(frozen=True)
class GrootConfig:
    name: str = "groot-gnn"
    family: str = "gnn"
    dataset: str = "csa"
    bits: int = 64               # dry-run design size (per-device subgraphs)
    batch: int = 16              # paper's large-batch setting
    num_partitions: int = 256    # one partition per device
    gnn: GNNConfig = dataclasses.field(default_factory=lambda: GNNConfig(hidden=128))
    skip_shapes: tuple = ()


ARCH_ID = "groot-gnn"


def config() -> GrootConfig:
    return GrootConfig()


def smoke_config() -> GrootConfig:
    return GrootConfig(bits=8, batch=2, num_partitions=2, gnn=GNNConfig(hidden=16))
