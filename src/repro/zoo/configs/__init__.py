"""Architecture registry: --arch <id> resolution for launcher/dryrun."""
from repro.zoo.configs import (
    deepseek_67b,
    gemma2_9b,
    groot_gnn,
    llama32_vision_11b,
    llama4_maverick,
    qwen2_7b,
    qwen3_8b,
    qwen3_moe_235b,
    recurrentgemma_9b,
    rwkv6_3b,
    whisper_base,
)

_MODULES = (
    qwen3_8b,
    qwen2_7b,
    gemma2_9b,
    deepseek_67b,
    llama4_maverick,
    qwen3_moe_235b,
    rwkv6_3b,
    whisper_base,
    llama32_vision_11b,
    recurrentgemma_9b,
    groot_gnn,
)

ARCHS = {m.ARCH_ID: m for m in _MODULES}
LM_ARCHS = {k: v for k, v in ARCHS.items() if k != "groot-gnn"}


def get_config(arch: str, smoke: bool = False):
    mod = ARCHS[arch]
    return mod.smoke_config() if smoke else mod.config()
