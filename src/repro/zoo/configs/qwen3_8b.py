"""qwen3-8b [dense]: 36L d_model=4096 32H (GQA kv=8) d_ff=12288
vocab=151936 — qk_norm, GQA.  [hf:Qwen/Qwen3-8B; hf]"""
import dataclasses

from repro.zoo.configs.base import ModelConfig

ARCH_ID = "qwen3-8b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        num_layers=36,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=12288,
        vocab_size=151936,
        qk_norm=True,
        rope_theta=1e6,
        tie_embeddings=False,
        layer_pattern=("global",),
        skip_shapes=("long_500k",),  # pure full attention (DESIGN.md)
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=4, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=512, head_dim=16,
    )
