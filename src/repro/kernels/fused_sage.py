"""Fused LD-aggregate + weight-matmul Pallas kernel (beyond-paper opt).

The GROOT paper stops at the SpMM; in GraphSAGE every aggregation is
immediately followed by a dense ``(N, F) @ (F, H)`` matmul.  Fusing the two
keeps the aggregated row block in VMEM and feeds it straight to the MXU —
the aggregated ``(R_t, F)`` tile is never written to HBM.  This removes
one full round-trip of the aggregate array per layer per group:

    unfused:  write (N,F) agg + read (N,F) agg  = 2*N*F*4 bytes per group
    fused:    0 bytes (lives in VMEM/VREG)

For the GNN's memory-bound regime (arithmetic intensity of the SpMM is
O(1) flops/byte) this is the dominant HBM-traffic term after the gather —
see EXPERIMENTS.md §Perf (GROOT kernel iterations).

Validated in interpret mode against ``ref.ell_block_reduce_ref @ W``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.groot_spmm import F_TILE


def _fused_kernel(msgs_ref, w_ref, o_ref, *, rows: int, deg: int):
    """(R_t*d, F) tile + (F, H_t) weights -> (R_t, H_t) = rowsum @ W."""
    m = msgs_ref[...]
    agg = m.reshape(rows, deg, m.shape[-1]).sum(axis=1)
    o_ref[...] = jax.lax.dot(agg, w_ref[...], preferred_element_type=o_ref.dtype)


def fused_ld_matmul(
    msgs: jax.Array,
    w_mat: jax.Array,
    deg: int,
    rows_per_tile: int,
    *,
    interpret: bool = True,
    h_tile: int = F_TILE,
) -> jax.Array:
    """msgs: (R_pad * deg, F_pad); w_mat: (F_pad, H_pad) -> (R_pad, H_pad).

    Equivalent to ``ell_block_reduce(msgs) @ w_mat`` with the intermediate
    kept in VMEM.  F is carried whole per tile (GNN hidden <= 256 floats =
    1 KiB/row); H is tiled on the lane dim.
    """
    f_pad = msgs.shape[1]
    h_pad = w_mat.shape[1]
    r_pad = msgs.shape[0] // deg
    r_t = rows_per_tile
    h_t = min(h_tile, h_pad)
    grid = (r_pad // r_t, h_pad // h_t)
    return pl.pallas_call(
        functools.partial(_fused_kernel, rows=r_t, deg=deg),
        grid=grid,
        in_specs=[
            pl.BlockSpec((r_t * deg, f_pad), lambda i, j: (i, 0)),
            pl.BlockSpec((f_pad, h_t), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((r_t, h_t), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r_pad, h_pad), msgs.dtype),
        interpret=interpret,
    )(msgs, w_mat)


def fused_ref(msgs: jax.Array, w_mat: jax.Array, deg: int) -> jax.Array:
    """Oracle: reshape-sum then matmul."""
    r = msgs.shape[0] // deg
    agg = msgs.reshape(r, deg, msgs.shape[1]).sum(axis=1)
    return agg @ w_mat
