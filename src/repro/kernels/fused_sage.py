"""Fused LD-aggregate + weight-matmul Pallas kernel (beyond-paper opt).

The GROOT paper stops at the SpMM; in GraphSAGE every aggregation is
immediately followed by a dense ``(N, F) @ (F, H)`` matmul.  Fusing the two
keeps the aggregated row block in VMEM and feeds it straight to the MXU —
the aggregated ``(R_t, F)`` tile is never written to HBM.  This removes
one full round-trip of the aggregate array per layer per group:

    unfused:  write (N,F) agg + read (N,F) agg  = 2*N*F*4 bytes per group
    fused:    0 bytes (lives in VMEM/VREG)

For the GNN's memory-bound regime (arithmetic intensity of the SpMM is
O(1) flops/byte) this is the dominant HBM-traffic term after the gather —
see EXPERIMENTS.md §Perf (GROOT kernel iterations).

Validated in interpret mode against ``ref.ell_block_reduce_ref @ W``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.groot_spmm import F_TILE, PROBE


def _fused_kernel(msgs_ref, w_ref, o_ref, *, rows: int, deg: int):
    """(R_t*d, F) tile + (F, H_t) weights -> (R_t, H_t) = rowsum @ W.

    Accumulation is always f32 (bf16 edge streams are widened in VREGs),
    matching the unfused LD kernel's numerics."""
    m = msgs_ref[...].astype(jnp.float32)
    agg = m.reshape(rows, deg, m.shape[-1]).sum(axis=1)
    o_ref[...] = jax.lax.dot(
        agg, w_ref[...].astype(jnp.float32), preferred_element_type=o_ref.dtype
    )


def fused_ld_matmul(
    msgs: jax.Array,
    w_mat: jax.Array,
    deg: int,
    rows_per_tile: int,
    *,
    interpret: bool = True,
    h_tile: int = F_TILE,
) -> jax.Array:
    """msgs: (R_pad * deg, F_pad); w_mat: (F_pad, H_pad) -> (R_pad, H_pad).

    Equivalent to ``ell_block_reduce(msgs) @ w_mat`` with the intermediate
    kept in VMEM.  F is carried whole per tile (GNN hidden <= 256 floats =
    1 KiB/row); H is tiled on the lane dim.
    """
    PROBE["pallas_calls"] += 1
    f_pad = msgs.shape[1]
    h_pad = w_mat.shape[1]
    r_pad = msgs.shape[0] // deg
    r_t = rows_per_tile
    h_t = min(h_tile, h_pad)
    grid = (r_pad // r_t, h_pad // h_t)
    return pl.pallas_call(
        functools.partial(_fused_kernel, rows=r_t, deg=deg),
        grid=grid,
        in_specs=[
            pl.BlockSpec((r_t * deg, f_pad), lambda i, j: (i, 0)),
            pl.BlockSpec((f_pad, h_t), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((r_t, h_t), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r_pad, h_pad), jnp.float32),
        interpret=interpret,
    )(msgs, w_mat)


def fused_ref(msgs: jax.Array, w_mat: jax.Array, deg: int) -> jax.Array:
    """Oracle: reshape-sum then matmul."""
    r = msgs.shape[0] // deg
    agg = msgs.reshape(r, deg, msgs.shape[1]).sum(axis=1)
    return agg @ w_mat


# ---------------------------------------------------------------------------
# Grouped fused kernel: all G slot x polarity groups of a SAGE layer in
# one pass.  The message tile is loaded once; per group it is weighted,
# segment-reduced, and matmul'd against that group's weight matrix, with
# the G partial (R_t, H_t) products summed in VREGs — the layer-level
# ``sum_g (agg_g @ W_g)`` never touches HBM between groups.
# ---------------------------------------------------------------------------

def _fused_kernel_grouped(msgs_ref, wg_ref, w_ref, o_ref, *, rows: int, deg: int,
                          groups: int):
    """(R_t*d, F) tile + (R_t*d, G) weights + (G, F, H_t) mats ->
    (R_t, H_t) = sum_g rowsum(wg[:, g] * msgs) @ W_g.

    Messages and weights may arrive as bf16 streams; the weighted
    reduction and the MXU products accumulate in f32."""
    m = msgs_ref[...].astype(jnp.float32)
    w = wg_ref[...].astype(jnp.float32)
    acc = None
    for g in range(groups):  # static, tiny (2 or 4): unrolls on the MXU
        agg = (m * w[:, g][:, None]).reshape(rows, deg, m.shape[-1]).sum(axis=1)
        part = jax.lax.dot(agg, w_ref[g], preferred_element_type=o_ref.dtype)
        acc = part if acc is None else acc + part
    o_ref[...] = acc


def fused_ld_matmul_grouped(
    msgs: jax.Array,
    wg: jax.Array,
    w_stack: jax.Array,
    deg: int,
    rows_per_tile: int,
    *,
    interpret: bool = True,
    h_tile: int = F_TILE,
) -> jax.Array:
    """msgs: (R_pad*deg, F_pad); wg: (R_pad*deg, G); w_stack: (G, F_pad, H_pad)
    -> (R_pad, H_pad) = sum_g ell_block_reduce(wg[:, g] * msgs) @ w_stack[g].
    """
    PROBE["pallas_calls"] += 1
    f_pad = msgs.shape[1]
    g, _, h_pad = w_stack.shape
    r_pad = msgs.shape[0] // deg
    r_t = rows_per_tile
    h_t = min(h_tile, h_pad)
    grid = (r_pad // r_t, h_pad // h_t)
    return pl.pallas_call(
        functools.partial(_fused_kernel_grouped, rows=r_t, deg=deg, groups=g),
        grid=grid,
        in_specs=[
            pl.BlockSpec((r_t * deg, f_pad), lambda i, j: (i, 0)),
            pl.BlockSpec((r_t * deg, g), lambda i, j: (i, 0)),
            pl.BlockSpec((g, f_pad, h_t), lambda i, j: (0, 0, j)),
        ],
        out_specs=pl.BlockSpec((r_t, h_t), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r_pad, h_pad), jnp.float32),
        interpret=interpret,
    )(msgs, wg.astype(msgs.dtype), w_stack.astype(jnp.float32))


def fused_grouped_ref(msgs: jax.Array, wg: jax.Array, w_stack: jax.Array,
                      deg: int) -> jax.Array:
    """Oracle: per-group weight, reshape-sum, matmul, sum over groups."""
    r = msgs.shape[0] // deg
    out = None
    for g in range(w_stack.shape[0]):
        agg = (msgs * wg[:, g][:, None]).reshape(r, deg, msgs.shape[1]).sum(axis=1)
        part = agg @ w_stack[g]
        out = part if out is None else out + part
    return out
