"""Process-wide structural plan cache.

A :class:`~repro.kernels.groot_spmm.SpmmPlan` is a pure function of the
graph structure (edge endpoints + node count) — and verification traffic
is heavily structure-duplicated: regression farms resubmit identical
netlists, ``predict_partitioned`` walks the same subgraphs every call,
and the service scheduler packs the same padded disjoint unions over and
over.  Rebuilding the O(E) host-side count-sort (and, worse, a fresh
:class:`~repro.kernels.ops.AggPair`, whose identity keys the jit cache)
for a structure the process has already served wastes host time AND
forces a full XLA retrace.

This module gives both layers one LRU keyed on a content hash of the
edge arrays (the kernel-layer analogue of ``repro.io.aiger``'s
format-invariant structural hash):

  * ``("plan", graph_key, e_t)``  -> a built ``SpmmPlan``
  * ``("fwd", graph_key, e_t)``   -> a built ``ForwardPlan`` (the
    layer-invariant hoisting schedule of
    ``repro.kernels.forward_plan`` — both direction plans + staged
    edge-id streams)
  * ``("pair", graph_key, backend)`` -> a built ``AggPair`` (see
    ``repro.kernels.ops.make_agg_pair``) — a hit returns the *same
    object*, so ``jax.jit(..., static_argnames=("agg",))`` callers get a
    compile-cache hit instead of a retrace.

Thread-safe (the service prepare pool and device worker both read it).
"""
from __future__ import annotations

import dataclasses
import hashlib
import threading
from collections import OrderedDict
from typing import Callable, Hashable

import numpy as np


@dataclasses.dataclass
class PlanCacheStats:
    hits: int = 0
    misses: int = 0
    builds: int = 0
    evictions: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class PlanCache:
    """LRU of structure-keyed build products (plans, agg pairs)."""

    def __init__(self, capacity: int = 256):
        assert capacity > 0
        self.capacity = capacity
        self._lock = threading.RLock()
        self._data: OrderedDict[Hashable, object] = OrderedDict()
        self.stats = PlanCacheStats()

    def get_or_build(self, key: Hashable, builder: Callable[[], object]) -> object:
        with self._lock:
            if key in self._data:
                self._data.move_to_end(key)
                self.stats.hits += 1
                return self._data[key]
            self.stats.misses += 1
            # build under the lock: builders are host-side and building the
            # same plan twice concurrently would defeat the jit-identity
            # property the pair cache exists to provide
            value = builder()
            self.stats.builds += 1
            self._data[key] = value
            while len(self._data) > self.capacity:
                self._data.popitem(last=False)
                self.stats.evictions += 1
            return value

    def peek(self, key: Hashable) -> object | None:
        """Lookup without building (counts as hit/miss).  Pair with
        :meth:`add` for SLOW builders that must not run under the cache
        lock (e.g. whole-design partitioning): peek, build outside, add."""
        with self._lock:
            if key in self._data:
                self._data.move_to_end(key)
                self.stats.hits += 1
                return self._data[key]
            self.stats.misses += 1
            return None

    def add(self, key: Hashable, value: object) -> object:
        """Insert a value built outside the lock; an earlier racer's entry
        wins (returns the canonical value, preserving same-object reuse)."""
        with self._lock:
            if key in self._data:
                self._data.move_to_end(key)
                return self._data[key]
            self.stats.builds += 1
            self._data[key] = value
            while len(self._data) > self.capacity:
                self._data.popitem(last=False)
                self.stats.evictions += 1
            return value

    def clear(self) -> None:
        with self._lock:
            self._data.clear()

    def reset_stats(self) -> None:
        with self._lock:
            self.stats = PlanCacheStats()

    def snapshot(self) -> PlanCacheStats:
        with self._lock:
            return dataclasses.replace(self.stats)

    def __len__(self) -> int:
        with self._lock:
            return len(self._data)


#: The process-wide instance every layer shares (pipeline, predict paths,
#: service scheduler).  Sized for a service's working set of distinct
#: structures; entries are host numpy + closures, so cheap relative to
#: the retraces they avoid.  NOTE: the same-object (and so jit-cache-hit
#: / 0-builds) guarantee only holds while a structure stays resident in
#: this LRU — once the working set exceeds ``capacity``, an evicted
#: structure's next appearance rebuilds a fresh pair (new identity, one
#: retrace, ``builds`` increments).  Size ``capacity`` above the traffic
#: working set, and keep it >= any scheduler's ``max_structures``.
PLAN_CACHE = PlanCache(capacity=256)


def graph_key(edge_src, edge_dst, num_nodes: int) -> str:
    """Content hash of a graph structure (direction-sensitive: the fanin
    and fanout plans of the same graph hash differently, as they must)."""
    h = hashlib.sha256()
    h.update(np.int64(num_nodes).tobytes())
    h.update(np.ascontiguousarray(np.asarray(edge_src, dtype=np.int64)).tobytes())
    h.update(b"|")
    h.update(np.ascontiguousarray(np.asarray(edge_dst, dtype=np.int64)).tobytes())
    return h.hexdigest()


def cached_plan(edge_src, edge_dst, num_nodes: int, *, e_t: int | None = None):
    """``build_plan`` through the process-wide cache."""
    from repro.kernels.groot_spmm import E_T, build_plan

    e_t = E_T if e_t is None else e_t
    key = ("plan", graph_key(edge_src, edge_dst, num_nodes), e_t)
    return PLAN_CACHE.get_or_build(
        key, lambda: build_plan(edge_src, edge_dst, num_nodes, e_t=e_t)
    )


def cached_forward_plan(edge_src, edge_dst, num_nodes: int, *, e_t: int | None = None):
    """The graph's :class:`~repro.kernels.forward_plan.ForwardPlan` through
    the process-wide cache (direction plans themselves come from
    :func:`cached_plan`, so a recurring structure builds nothing)."""
    from repro.kernels.forward_plan import build_forward_plan
    from repro.kernels.groot_spmm import E_T

    e_t = E_T if e_t is None else e_t
    key = ("fwd", graph_key(edge_src, edge_dst, num_nodes), e_t)
    return PLAN_CACHE.get_or_build(
        key,
        lambda: build_forward_plan(
            cached_plan(edge_src, edge_dst, num_nodes, e_t=e_t),
            cached_plan(edge_dst, edge_src, num_nodes, e_t=e_t),
        ),
    )
