"""Backend dispatch + jit wrappers for graph aggregation.

An *aggregation pair* is ``(in_agg, out_agg)`` — two callables ``(x, w) ->
(N, F)`` computing the weighted neighbour sums over fanin edges and fanout
edges respectively.  ``repro.core.gnn.forward`` consumes such pairs; this
module builds them for each backend:

  ``ref``         gather + segment_sum (row-parallel SpMM; the
                  GNNAdvisor-style baseline)
  ``onehot``      dense one-hot matmul formulation (cuSPARSE-dense
                  analogue; O(N*E) — small graphs/benchmarks only)
  ``groot``       the Pallas degree-bucketed HD/LD kernels (VPU reduce),
                  interpret=True on CPU
  ``groot_mxu``   same, LD reduction as one-hot block-diag MXU matmul
  ``groot_fused`` ``groot`` aggregation whose LD slabs can additionally be
                  fused with the following weight matmul
                  (``agg_mm`` method; beyond-paper optimization)

Plans are built once per graph on host (numpy) and embedded as constants
in the jitted computation — exactly how a static EDA graph is deployed.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import plan_cache as pc
from repro.kernels import ref as kref
from repro.kernels.forward_plan import ForwardPlan, build_forward_plan
from repro.kernels.groot_spmm import (
    PROBE,
    SpmmPlan,
    StagedWeights,
    apply_plan,
    apply_plan_grouped,
    apply_plan_grouped_staged,
    assemble_rows,
    build_plan,
    hd_grouped_apply,
    pad_features,
    stage_group_weights,
)
from repro.kernels.fused_sage import fused_ld_matmul, fused_ld_matmul_grouped

BACKENDS = ("ref", "onehot", "groot", "groot_mxu", "groot_fused")


def onehot_spmm(x, edge_src, edge_dst, num_nodes: int, w=None):
    """Dense formulation: ``onehot(dst)^T @ (x[src] * w)``.

    This is what a "just use dense matmul" port of SpMM to the MXU looks
    like *without* the GROOT insight — the baseline the degree-bucketed
    kernels beat on memory (it materialises an (E, N) one-hot).
    """
    msgs = jnp.take(x, edge_src, axis=0)
    if w is not None:
        msgs = msgs * w[:, None].astype(msgs.dtype)
    oh = jax.nn.one_hot(edge_dst, num_nodes, dtype=x.dtype)  # (E, N)
    return oh.T @ msgs


@dataclasses.dataclass
class AggPair:
    """Aggregation callables for one graph (+ optional fused/grouped paths).

    The grouped entry points take a ``(E, G)`` weight matrix — one column
    per slot x polarity group — and compute every group's aggregation in
    a single plan walk with a single gather of the edge stream, returning
    group-major ``(G, N, F)``.  They are ``None`` for backends that have
    no shared plan to exploit (``ref``/``onehot``), where the model layer
    keeps its per-group loop.
    """

    in_agg: Callable      # (x, w) -> (N, F) over fanin edges
    out_agg: Callable     # (x, w) -> (N, F) over fanout edges
    backend: str
    # fused aggregate+matmul over fanin LD slabs; None when unsupported
    in_agg_mm: Optional[Callable] = None
    in_plan: Optional[SpmmPlan] = None
    out_plan: Optional[SpmmPlan] = None
    # grouped paths: (x, wg (E, G)) -> (G, N, F) in one plan walk
    in_agg_grouped: Optional[Callable] = None
    out_agg_grouped: Optional[Callable] = None
    # grouped fuse: (x, wg (E, G), w_stack (G, F, H)) -> (N, H)
    in_agg_mm_grouped: Optional[Callable] = None
    # forward-invariant hoisting (all groot* backends): the ForwardPlan
    # stages the weight streams once per forward; the *_staged entry
    # points consume pre-padded features + staged streams and return f32
    # padded-lane outputs — (G, N, F_pad), or (N, H_pad) for the fuse
    fwd_plan: Optional[ForwardPlan] = None
    in_agg_staged: Optional[Callable] = None     # (x_p, staged) -> (G, N, F_pad)
    out_agg_staged: Optional[Callable] = None
    in_agg_mm_staged: Optional[Callable] = None  # (x_p, staged, wm_p) -> (N, H_pad)

    def __hash__(self):  # jit static-arg friendliness
        return id(self)

    def __eq__(self, other):
        return self is other


def ungrouped(pair: AggPair) -> AggPair:
    """A copy of ``pair`` with the grouped entry points stripped — forces
    the model layer back onto the per-group loop (parity tests and the
    grouped-vs-per-group benchmark)."""
    return dataclasses.replace(
        pair,
        in_agg_grouped=None,
        out_agg_grouped=None,
        in_agg_mm_grouped=None,
        fwd_plan=None,
        in_agg_staged=None,
        out_agg_staged=None,
        in_agg_mm_staged=None,
    )


def unhoisted(pair: AggPair) -> AggPair:
    """A copy of ``pair`` without the ForwardPlan — keeps the grouped
    walks but re-stages the weight streams every layer (the pre-hoist
    walk; the hoisting bit-exactness tests and the before/after traffic
    benchmark route through it)."""
    return dataclasses.replace(
        pair,
        fwd_plan=None,
        in_agg_staged=None,
        out_agg_staged=None,
        in_agg_mm_staged=None,
    )


def _segment_pair(edge_src, edge_dst, num_nodes) -> AggPair:
    s = jnp.asarray(edge_src)
    d = jnp.asarray(edge_dst)
    return AggPair(
        in_agg=lambda x, w=None: kref.spmm_ref(x, s, d, num_nodes, w),
        out_agg=lambda x, w=None: kref.spmm_ref(x, d, s, num_nodes, w),
        backend="ref",
    )


def _onehot_pair(edge_src, edge_dst, num_nodes) -> AggPair:
    s = jnp.asarray(edge_src)
    d = jnp.asarray(edge_dst)
    return AggPair(
        in_agg=lambda x, w=None: onehot_spmm(x, s, d, num_nodes, w),
        out_agg=lambda x, w=None: onehot_spmm(x, d, s, num_nodes, w),
        backend="onehot",
    )


def _groot_pair(
    edge_src,
    edge_dst,
    num_nodes,
    *,
    mxu: bool,
    fused: bool,
    interpret: bool = True,
    use_cache: bool = True,
) -> AggPair:
    src = np.asarray(edge_src)
    dst = np.asarray(edge_dst)
    if use_cache:
        in_plan = pc.cached_plan(src, dst, num_nodes)
        out_plan = pc.cached_plan(dst, src, num_nodes)
        fwd_plan = pc.cached_forward_plan(src, dst, num_nodes)
    else:
        in_plan = build_plan(src, dst, num_nodes)
        out_plan = build_plan(dst, src, num_nodes)
        fwd_plan = build_forward_plan(in_plan, out_plan)

    def in_agg(x, w=None):
        return apply_plan(in_plan, x, w, interpret=interpret, mxu=mxu)

    def out_agg(x, w=None):
        return apply_plan(out_plan, x, w, interpret=interpret, mxu=mxu)

    def in_agg_grouped(x, wg):
        return apply_plan_grouped(in_plan, x, wg, interpret=interpret, mxu=mxu)

    def out_agg_grouped(x, wg):
        return apply_plan_grouped(out_plan, x, wg, interpret=interpret, mxu=mxu)

    def in_agg_staged(x_p, staged):
        return apply_plan_grouped_staged(
            in_plan, x_p, staged, interpret=interpret, mxu=mxu
        )

    def out_agg_staged(x_p, staged):
        return apply_plan_grouped_staged(
            out_plan, x_p, staged, interpret=interpret, mxu=mxu
        )

    in_agg_mm = None
    in_agg_mm_grouped = None
    in_agg_mm_staged = None
    if fused:

        def in_agg_mm(x, w, w_mat):
            return _apply_plan_fused(in_plan, x, w, w_mat, interpret=interpret)

        def in_agg_mm_grouped(x, wg, w_stack):
            return _apply_plan_fused_grouped(
                in_plan, x, wg, w_stack, interpret=interpret
            )

        def in_agg_mm_staged(x_p, staged, wm_p):
            return _apply_plan_fused_grouped_staged(
                in_plan, x_p, staged, wm_p, interpret=interpret
            )

    return AggPair(
        in_agg=in_agg,
        out_agg=out_agg,
        backend="groot_fused" if fused else ("groot_mxu" if mxu else "groot"),
        in_agg_mm=in_agg_mm,
        in_plan=in_plan,
        out_plan=out_plan,
        in_agg_grouped=in_agg_grouped,
        out_agg_grouped=out_agg_grouped,
        in_agg_mm_grouped=in_agg_mm_grouped,
        fwd_plan=fwd_plan,
        in_agg_staged=in_agg_staged,
        out_agg_staged=out_agg_staged,
        in_agg_mm_staged=in_agg_mm_staged,
    )


def _apply_plan_fused(plan: SpmmPlan, x, w, w_mat, *, interpret: bool):
    """apply_plan with the LD reductions fused with ``@ w_mat``.

    Output is (N, H) = (sum_e w_e x[src_e] into rows) @ w_mat, with the
    aggregated (N, F) intermediate never materialised for LD rows.
    Assembly is scatter-free (inverse count-sort permutation).
    """
    from repro.kernels.groot_spmm import F_TILE, hd_apply

    PROBE["edge_stream_gathers"] += 1
    PROBE["kernel_walks"] += 1
    if w is not None:
        PROBE["weight_gathers"] += 1
    n, f = x.shape
    h = w_mat.shape[1]
    f_extra = -f % F_TILE
    h_extra = -h % F_TILE
    x_p = pad_features(x)
    w_p = None if w is None else jnp.pad(w.astype(x.dtype), (0, 1))
    wm_p = jnp.pad(w_mat.astype(jnp.float32), ((0, f_extra), (0, h_extra)))

    def gather(cols, eids):
        g = jnp.take(x_p, jnp.asarray(cols), axis=0)
        if w_p is not None:
            g = g * jnp.take(w_p, jnp.asarray(eids), axis=0)[:, None]
        return g

    parts = []
    for b in plan.buckets:
        msgs = gather(b.cols, b.eids)
        parts.append(
            fused_ld_matmul(msgs, wm_p, b.deg, b.rows_per_tile, interpret=interpret)
        )
    if plan.hd is not None:
        msgs = gather(plan.hd.cols, plan.hd.eids)
        red = hd_apply(
            msgs, plan.hd.chunk_meta, len(plan.hd.rows), plan.e_t, interpret=interpret
        )
        parts.append(red[:, :f] @ wm_p[:f, :])
    out = assemble_rows(plan, parts, h + h_extra)
    return out[:, :h].astype(x.dtype)


def _apply_plan_fused_grouped_staged(
    plan: SpmmPlan, x_p, staged: StagedWeights, wm_p, *, interpret: bool
):
    """Hoisted grouped fused walk: pre-padded features, pre-staged weight
    streams, and a pre-padded ``(G, F_pad, H_pad)`` weight stack in;
    ``(N, H_pad)`` f32 out.

    One gather of the edge stream and one walk of the bucket schedule
    serve all G groups; per LD slab the grouped fused kernel keeps every
    group's (R_t, F) aggregate in VMEM and sums the G MXU products before
    the single (R_t, H_t) store.  HD rows reduce through the grouped HD
    kernel and contract with the weight stack outside (HD rows are few).
    Output assembly is one permutation gather — no scatters.
    """
    PROBE["edge_stream_gathers"] += 1
    PROBE["kernel_walks"] += 1
    f_pad = x_p.shape[1]
    h_pad = wm_p.shape[2]
    PROBE["stream_bytes"] += plan.num_slots * f_pad * x_p.dtype.itemsize
    parts = []
    for b, wge in zip(plan.buckets, staged.buckets):
        msgs = jnp.take(x_p, jnp.asarray(b.cols), axis=0)
        parts.append(
            fused_ld_matmul_grouped(
                msgs, wge, wm_p, b.deg, b.rows_per_tile, interpret=interpret
            )
        )
    if plan.hd is not None:
        msgs = jnp.take(x_p, jnp.asarray(plan.hd.cols), axis=0)
        red = hd_grouped_apply(
            msgs, staged.hd, plan.hd.chunk_meta, len(plan.hd.rows), plan.e_t,
            interpret=interpret,
        )  # (G, n_hd, F_pad); pad lanes are zero, so the full-F_pad
        # contraction against the zero-padded stack is exact
        parts.append(jnp.einsum("gnf,gfh->nh", red, wm_p))
    return assemble_rows(plan, parts, h_pad)


def _apply_plan_fused_grouped(plan: SpmmPlan, x, wg, w_stack, *, interpret: bool):
    """Grouped fused path: ``sum_g (group-g aggregation) @ w_stack[g]``.

    Stages the weight streams and pads per call — the pre-hoist walk the
    hoisted forward replaces (kept for the per-call API and as the
    bit-exactness oracle of the hoisting refactor).
    """
    h = w_stack.shape[2]
    staged = stage_group_weights(plan, wg)
    out = _apply_plan_fused_grouped_staged(
        plan,
        pad_features(x),
        staged,
        ForwardPlan.pad_weight_stack(w_stack),
        interpret=interpret,
    )
    return out[:, :h].astype(x.dtype)


# ---------------------------------------------------------------------------
# Padded-shape entry points (the service scheduler's bucketing contract).
#
# jit specialises on array shapes: serving many differently-sized graphs
# through the same compiled GNN requires padding every graph to a small
# set of canonical (nodes, edges) shapes.  The contract that keeps padded
# inference *exact* for real rows:
#
#   * padded feature rows are zero and are never aggregated into real rows;
#   * padded edges are self-loops on a dummy node (>= num_real), so every
#     aggregation/degree a real node sees is identical to the unpadded run.
# ---------------------------------------------------------------------------

def next_pow2(n: int) -> int:
    """Smallest power of two >= n (1 for n <= 1)."""
    return 1 if n <= 1 else 1 << int(n - 1).bit_length()


def padded_shape(
    num_nodes: int, num_edges: int, *, min_nodes: int = 16, min_edges: int = 16
) -> tuple[int, int]:
    """Power-of-two (nodes, edges) padding target.

    Nodes round up from ``num_nodes + 1``: at least one spare row is
    guaranteed, which is where padding edges park their endpoints.
    """
    n_pad = next_pow2(max(num_nodes + 1, min_nodes))
    e_pad = next_pow2(max(num_edges, min_edges, 1))
    return n_pad, e_pad


def pad_graph_arrays(
    edge_src,
    edge_dst,
    edge_inv,
    edge_slot,
    num_nodes: int,
    n_pad: int,
    e_pad: int,
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Pad COO edge arrays to length ``e_pad`` for a ``n_pad``-row graph.

    Padding edges are self-loops on the dummy row ``n_pad - 1``; missing
    inv/slot annotations come back as zeros (dense arrays keep the jit
    signature uniform across designs that do / don't carry them).
    """
    e = len(edge_src)
    if n_pad <= num_nodes or e_pad < e:
        raise ValueError(
            f"padded shape ({n_pad}, {e_pad}) cannot hold graph "
            f"({num_nodes} nodes, {e} edges)"
        )
    dummy = n_pad - 1
    pad = e_pad - e
    src = np.concatenate([edge_src, np.full(pad, dummy)]).astype(np.int32)
    dst = np.concatenate([edge_dst, np.full(pad, dummy)]).astype(np.int32)
    inv = np.zeros(e_pad, dtype=bool)
    if edge_inv is not None:
        inv[:e] = edge_inv
    slot = np.zeros(e_pad, dtype=np.uint8)
    if edge_slot is not None:
        slot[:e] = edge_slot
    return src, dst, inv, slot


def _build_pair(edge_src, edge_dst, num_nodes: int, backend: str,
                use_cache: bool) -> AggPair:
    if backend == "ref":
        return _segment_pair(edge_src, edge_dst, num_nodes)
    if backend == "onehot":
        return _onehot_pair(edge_src, edge_dst, num_nodes)
    if backend == "groot":
        return _groot_pair(edge_src, edge_dst, num_nodes, mxu=False, fused=False,
                           use_cache=use_cache)
    if backend == "groot_mxu":
        return _groot_pair(edge_src, edge_dst, num_nodes, mxu=True, fused=False,
                           use_cache=use_cache)
    if backend == "groot_fused":
        return _groot_pair(edge_src, edge_dst, num_nodes, mxu=False, fused=True,
                           use_cache=use_cache)
    raise ValueError(f"unknown backend {backend!r} (want one of {BACKENDS})")


def make_agg_pair(
    edge_src, edge_dst, num_nodes: int, backend: str = "ref", *, use_cache: bool = True
) -> AggPair:
    """Build (or fetch) the aggregation pair for a graph under a backend.

    When the edge arrays are concrete host numpy, the pair comes from the
    process-wide structural :data:`~repro.kernels.plan_cache.PLAN_CACHE`:
    the same structure always yields the *same object*, so jit callers
    holding the pair as a static argument hit their compile cache instead
    of retracing (``predict_partitioned`` over recurring subgraphs, the
    service scheduler over recurring packed batches).  Traced inputs
    (e.g. the onehot backend built inside a jitted forward) bypass the
    cache — they cannot be content-hashed.
    """
    cacheable = (
        use_cache
        and isinstance(edge_src, np.ndarray)
        and isinstance(edge_dst, np.ndarray)
    )
    if not cacheable:
        return _build_pair(edge_src, edge_dst, num_nodes, backend, use_cache=False)
    key = ("pair", pc.graph_key(edge_src, edge_dst, num_nodes), backend)
    return pc.PLAN_CACHE.get_or_build(
        key,
        lambda: _build_pair(edge_src, edge_dst, num_nodes, backend, use_cache=True),
    )


def groot_spmm(
    x,
    edge_src,
    edge_dst,
    num_nodes: int,
    w=None,
    *,
    backend="groot",
    use_cache: bool = True,
):
    """One-shot SpMM through the GROOT kernels (for tests/benches;
    persistent users should hold an :class:`AggPair`).

    The plan comes from the process-wide structural
    :data:`~repro.kernels.plan_cache.PLAN_CACHE`: a recurring structure
    builds nothing.  Pass ``use_cache=False`` to force a cold plan build
    (benchmarks that time host-side plan construction).
    """
    pair = make_agg_pair(
        np.asarray(edge_src), np.asarray(edge_dst), num_nodes, backend,
        use_cache=use_cache,
    )
    return pair.in_agg(jnp.asarray(x), None if w is None else jnp.asarray(w))
