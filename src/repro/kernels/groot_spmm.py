"""GROOT degree-bucketed SpMM as Pallas TPU kernels (paper §IV, TPU-adapted).

The paper's insight: EDA graph degree distributions are *polarized* — a few
extreme high-degree (HD >= 512) rows (high-fanout nets) and millions of
low-degree (LD <= 12) rows (AND gates: in-degree 2, fanout 2-4).  One
schedule cannot serve both.  The CUDA design assigns 32 warps to one HD row
and packs many LD rows per warp after a degree count-sort.

TPU adaptation (see DESIGN.md §2): no warps — the unit of work is a VMEM
tile feeding the VPU/MXU.

  * **count-sort** (host, O(E)) buckets rows by next-pow2(degree); within a
    bucket every row has the same padded degree ``d``, so the bucket is an
    ELL slab: its gathered edge messages form a dense ``(R_b * d, F)``
    array where each destination row owns ``d`` consecutive message rows —
    the TPU equivalent of "rows with the same degree are assembled into the
    same blocks" (paper Fig. 5).
  * **LD kernel**: grid tile ``(R_t * d, F_t)`` -> output tile ``(R_t,
    F_t)``; the segment reduction is a reshape-sum (VPU) or a one-hot
    block-diagonal matmul (MXU) — contiguous loads, coalesced stores, no
    atomics: the same "aggregate many whole small rows per work unit"
    economics as packing ``6m/3m/2m`` rows per warp.
  * **HD kernel**: a row's edge stream is split into fixed ``E_t``-edge
    chunks; the grid walks chunks of the same row consecutively and
    accumulates partial sums into the row's output block *in VMEM*
    (initialised on the row's first chunk via scalar-prefetched metadata)
    — the analogue of splitting one row across 32 warps, with the shuffle
    reduction replaced by output-block revisiting.
  * the neighbour gather itself (``x[src]``) is done by XLA outside the
    kernel: TPUs have no efficient in-kernel random HBM gather, so the
    TPU-native formulation is gather -> dense edge stream -> systolic
    reduce (DESIGN.md §2, "hardware adaptation").

Thresholds mirror the paper: ``E_T = 512`` — rows with degree > 512 take
the HD path, everything else lands in an LD power-of-2 bucket (1..512).

All kernels are validated in ``interpret=True`` mode against
``kernels/ref.py`` (CPU container; TPU is the target).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Paper §IV thresholds: HD rows have degree >= 512; LD buckets are the
# power-of-two degrees up to E_T.
E_T = 512
F_TILE = 128           # lane dimension tile (TPU lane width)
LD_TILE_EDGES = 2048   # target edges per LD VMEM tile (R_t * d)
SUBLANE = 8            # f32 sublane quantum


# ---------------------------------------------------------------------------
# Hot-path probe.  Counters increment at *trace* time (or per eager call),
# so tracing one forward pass measures exactly how many times the edge
# stream is gathered and how many times the bucket-kernel schedule is
# walked — the quantities the grouped-SpMM refactor reduces from 6 to 2
# per layer.  ``pallas_calls`` counts individual kernel launches.
#
# The forward-invariant hoisting counters:
#   ``weight_gathers``   passes over the per-edge weight arrays (one
#                        ``jnp.take`` of the (E, G) stream).  Pre-hoist the
#                        grouped forward paid 2 per layer; the ForwardPlan
#                        stages the streams once -> 2 per FORWARD.
#   ``output_scatters``  ``out.at[rows].add`` ops issued.  The historical
#                        walks scattered once per bucket (+1 for HD,
#                        ``plan.num_segments`` per aggregation); since the
#                        scatter-free rewrite EVERY walk assembles via the
#                        inverse count-sort permutation, so the counter
#                        reads 0 — it exists as a regression tripwire: any
#                        reintroduced output scatter must bump it (the CI
#                        fast lane gates <= 2 per forward).
#   ``stream_bytes``     modeled HBM bytes of gathered edge streams
#                        (messages + staged weights), accumulated at trace
#                        time from static shapes/dtypes.
# ---------------------------------------------------------------------------

# Since the repro.obs spine landed, PROBE is a dict-shaped *view* over
# the process-wide metrics registry (counters ``kernels.spmm.<key>``):
# the historic ``PROBE["k"] += 1`` / ``dict(PROBE)`` idiom keeps working
# while every increment is visible to Session.report() and benchmarks.
from repro.obs.metrics import REGISTRY, CounterGroup

PROBE = CounterGroup(
    REGISTRY,
    "kernels.spmm",
    (
        "edge_stream_gathers",
        "kernel_walks",
        "pallas_calls",
        "weight_gathers",
        "output_scatters",
        "stream_bytes",
    ),
)


def reset_probe() -> None:
    for k in PROBE:
        PROBE[k] = 0


def probe_snapshot() -> dict:
    return dict(PROBE)


# ---------------------------------------------------------------------------
# Host-side plan (the count-sort / row-assembly of paper Fig. 5, step B)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LdBucket:
    """All rows whose (padded) degree is ``deg``: an ELL slab."""

    deg: int
    rows: np.ndarray        # (R_pad,) int32 destination row ids (pad = -1)
    cols: np.ndarray        # (R_pad * deg,) int32 source node ids (pad = N)
    eids: np.ndarray        # (R_pad * deg,) int32 edge ids (pad = E)
    rows_per_tile: int      # R_t

    @property
    def num_rows(self) -> int:
        return int(self.rows.shape[0])


@dataclasses.dataclass(frozen=True)
class HdPlan:
    """Rows with degree > E_T, chunked into E_t-edge pieces."""

    rows: np.ndarray        # (n_hd,) int32 destination row ids
    cols: np.ndarray        # (n_chunks * E_t,) int32 source ids (pad = N)
    eids: np.ndarray        # (n_chunks * E_t,) int32 edge ids (pad = E)
    chunk_meta: np.ndarray  # (n_chunks, 2) int32: [output row slot, is_first]

    @property
    def num_chunks(self) -> int:
        return int(self.chunk_meta.shape[0])


@dataclasses.dataclass(frozen=True)
class SpmmPlan:
    num_nodes: int
    num_edges: int
    buckets: tuple          # tuple[LdBucket, ...]
    hd: Optional[HdPlan]
    e_t: int = E_T
    # Inverse count-sort permutation for scatter-free output assembly:
    # bucket (then HD) reductions concatenated row-major form a
    # (asm_rows, F) array whose LAST row is zero; ``asm_index[r]`` is the
    # concat position of destination row r (degree-0 rows point at the
    # zero row).  A row appears in exactly one LD bucket OR the HD plan —
    # never both — so one gather (no adds) assembles the (N, F) output.
    asm_index: Optional[np.ndarray] = None   # (N,) int32
    asm_rows: int = 0

    def padding_overhead(self) -> float:
        """Padded-slot fraction — the cost of ELL bucketing (tests assert
        the pow-2 bound: <= ~2x + tile-rounding)."""
        slots = sum(b.eids.size for b in self.buckets)
        slots += self.hd.eids.size if self.hd else 0
        return slots / max(self.num_edges, 1)

    @property
    def num_slots(self) -> int:
        """Gathered edge-stream rows per walk (real edges + ELL padding)."""
        return sum(b.eids.size for b in self.buckets) + (
            self.hd.eids.size if self.hd else 0
        )

    @property
    def num_segments(self) -> int:
        """Output segments one aggregation produces (LD buckets + HD) —
        the per-walk scatter count of the pre-hoist assembly."""
        return len(self.buckets) + (1 if self.hd is not None else 0)


def build_plan(
    edge_src: np.ndarray,
    edge_dst: np.ndarray,
    num_nodes: int,
    *,
    e_t: int = E_T,
    ld_tile_edges: int = LD_TILE_EDGES,
) -> SpmmPlan:
    """Degree count-sort + row assembly (paper Fig. 5 step B, host, O(E)).

    ``eids`` index the *edge array*, so one plan serves any (x, w) pair on
    the same graph (all six slot/polarity groups of the GNN reuse it).
    """
    edge_src = np.asarray(edge_src, dtype=np.int64)
    edge_dst = np.asarray(edge_dst, dtype=np.int64)
    n, e = int(num_nodes), int(edge_dst.shape[0])
    # indices are staged as int32 (halves the index bytes per launch);
    # partitioned subgraphs guarantee device-sized N and E
    assert n < 2**31 and e < 2**31, (
        f"graph too large for int32 plan indices ({n} nodes, {e} edges)"
    )
    deg = np.bincount(edge_dst, minlength=n).astype(np.int64)

    # CSR-style row starts after a stable count-sort of edges by dest row.
    order = np.argsort(edge_dst, kind="stable").astype(np.int64)
    starts = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(deg, out=starts[1:])

    buckets: list[LdBucket] = []
    d = 1
    while d <= e_t:
        lo = 1 if d == 1 else d // 2 + 1
        rows = np.where((deg >= lo) & (deg <= d))[0]
        if rows.size:
            r_t = max(SUBLANE, (ld_tile_edges // d) // SUBLANE * SUBLANE)
            r_pad = -rows.size % r_t
            eids = np.full((rows.size + r_pad, d), e, dtype=np.int64)
            for slot in range(d):  # d slots; loop count <= 512, host-only
                take = deg[rows] > slot
                eids[: rows.size][take, slot] = order[starts[rows[take]] + slot]
            rows_p = np.concatenate(
                [rows, np.full(r_pad, -1, dtype=np.int64)]
            ).astype(np.int32)
            flat = eids.reshape(-1)
            cols = np.where(flat < e, edge_src[np.minimum(flat, e - 1)], n)
            buckets.append(
                LdBucket(
                    deg=d,
                    rows=rows_p,
                    cols=cols.astype(np.int32),
                    eids=flat.astype(np.int32),
                    rows_per_tile=r_t,
                )
            )
        d *= 2

    hd_rows = np.where(deg > e_t)[0]
    hd = None
    if hd_rows.size:
        n_chunks_per = -(-deg[hd_rows] // e_t)
        total_chunks = int(n_chunks_per.sum())
        eids = np.full((total_chunks, e_t), e, dtype=np.int64)
        meta = np.zeros((total_chunks, 2), dtype=np.int32)
        c = 0
        for slot_i, r in enumerate(hd_rows):
            row_edges = order[starts[r] : starts[r + 1]]
            for k in range(int(n_chunks_per[slot_i])):
                chunk = row_edges[k * e_t : (k + 1) * e_t]
                eids[c, : chunk.size] = chunk
                meta[c] = (slot_i, 1 if k == 0 else 0)
                c += 1
        flat = eids.reshape(-1)
        cols = np.where(flat < e, edge_src[np.minimum(flat, e - 1)], n)
        hd = HdPlan(
            rows=hd_rows.astype(np.int32),
            cols=cols.astype(np.int32),
            eids=flat.astype(np.int32),
            chunk_meta=meta,
        )

    asm_index, asm_rows = _assembly_index(n, buckets, hd)
    return SpmmPlan(
        num_nodes=n, num_edges=e, buckets=tuple(buckets), hd=hd, e_t=e_t,
        asm_index=asm_index, asm_rows=asm_rows,
    )


def _assembly_index(
    n: int, buckets: list[LdBucket], hd: Optional[HdPlan]
) -> tuple[np.ndarray, int]:
    """Inverse count-sort permutation (scatter-free output assembly).

    Concatenating every bucket's padded reduction and the HD rows
    row-major, followed by one zero row, gives an (asm_rows, F) array
    where ``take(cat, asm_index)`` is exactly what the per-bucket
    ``out.at[rows].add`` passes used to build — a destination row belongs
    to exactly one LD bucket or the HD plan, so no adds are needed.
    """
    asm = np.full(n, -1, dtype=np.int64)
    off = 0
    for b in buckets:
        live = b.rows >= 0
        rows_live = b.rows[live].astype(np.int64)
        assert (asm[rows_live] < 0).all(), "row in two LD buckets"
        asm[rows_live] = off + np.nonzero(live)[0]
        off += b.rows.shape[0]
    if hd is not None:
        hd_rows = hd.rows.astype(np.int64)
        # a row receiving both an LD and an HD contribution would need an
        # add on top of the gather; the degree partition makes it
        # impossible within one plan — assert it
        assert (asm[hd_rows] < 0).all(), "row is both LD and HD"
        asm[hd_rows] = off + np.arange(hd.rows.shape[0])
        off += hd.rows.shape[0]
    zero_row = off
    asm[asm < 0] = zero_row           # degree-0 rows read the zero row
    asm_rows = off + 1
    assert asm_rows < 2**31
    return asm.astype(np.int32), asm_rows


# ---------------------------------------------------------------------------
# LD kernel
# ---------------------------------------------------------------------------

def _ld_kernel(msgs_ref, o_ref, *, rows: int, deg: int):
    """(R_t * d, F_t) edge-message tile -> (R_t, F_t) row sums (VPU path).

    Accumulation is always f32 (bf16 inputs are widened in VREGs — free on
    the VPU, and required for deep-degree numerical sanity)."""
    m = msgs_ref[...].astype(jnp.float32)
    o_ref[...] = m.reshape(rows, deg, m.shape[-1]).sum(axis=1)


def _ld_kernel_mxu(red_ref, msgs_ref, o_ref):
    """MXU path: one-hot block-diagonal reduction matrix @ message tile.

    ``red`` is (R_t, R_t*d) with red[r, r*d:(r+1)*d] = 1 — the segment sum
    becomes a systolic matmul (DESIGN.md §2, "one-hot MXU matmul").
    """
    o_ref[...] = jax.lax.dot(
        red_ref[...], msgs_ref[...], preferred_element_type=o_ref.dtype
    )


def ld_bucket_apply(
    msgs: jax.Array, deg: int, rows_per_tile: int, *, interpret: bool, mxu: bool
) -> jax.Array:
    """Run the LD kernel over one ELL slab.  msgs: (R_pad * deg, F_pad)."""
    PROBE["pallas_calls"] += 1
    f_pad = msgs.shape[1]
    r_pad = msgs.shape[0] // deg
    r_t = rows_per_tile
    grid = (r_pad // r_t, f_pad // F_TILE)
    out_shape = jax.ShapeDtypeStruct((r_pad, f_pad), jnp.float32)
    if mxu and deg > 1:
        red = np.zeros((r_t, r_t * deg), dtype=np.float32)
        for r in range(r_t):
            red[r, r * deg : (r + 1) * deg] = 1.0
        return pl.pallas_call(
            _ld_kernel_mxu,
            grid=grid,
            in_specs=[
                pl.BlockSpec((r_t, r_t * deg), lambda i, j: (0, 0)),
                pl.BlockSpec((r_t * deg, F_TILE), lambda i, j: (i, j)),
            ],
            out_specs=pl.BlockSpec((r_t, F_TILE), lambda i, j: (i, j)),
            out_shape=out_shape,
            interpret=interpret,
        )(jnp.asarray(red, msgs.dtype), msgs)
    return pl.pallas_call(
        functools.partial(_ld_kernel, rows=r_t, deg=deg),
        grid=grid,
        in_specs=[pl.BlockSpec((r_t * deg, F_TILE), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((r_t, F_TILE), lambda i, j: (i, j)),
        out_shape=out_shape,
        interpret=interpret,
    )(msgs)


# ---------------------------------------------------------------------------
# HD kernel
# ---------------------------------------------------------------------------

def _hd_kernel(meta_ref, msgs_ref, o_ref):
    """One E_t-edge chunk -> partial sum accumulated into the row's output.

    Chunks of the same row are consecutive in the (inner) chunk grid dim,
    so the output block stays resident in VMEM across the row's chunks —
    the TPU version of the 32-warp row split + shuffle reduce.
    """
    c = pl.program_id(1)
    part = msgs_ref[...].astype(jnp.float32).sum(axis=0, keepdims=True)

    @pl.when(meta_ref[c, 1] == 1)
    def _init():
        o_ref[...] = part

    @pl.when(meta_ref[c, 1] == 0)
    def _acc():
        o_ref[...] += part


def hd_apply(
    msgs: jax.Array,
    chunk_meta: np.ndarray,
    n_hd_rows: int,
    e_t: int,
    *,
    interpret: bool,
) -> jax.Array:
    """msgs: (n_chunks * e_t, F_pad) -> (n_hd_rows, F_pad).

    Grid is (F-tiles, chunks): the chunk dim is innermost so same-row
    chunks revisit the same output block back-to-back (required for the
    VMEM accumulation pattern).
    """
    PROBE["pallas_calls"] += 1
    f_pad = msgs.shape[1]
    n_chunks = msgs.shape[0] // e_t
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(f_pad // F_TILE, n_chunks),
        in_specs=[pl.BlockSpec((e_t, F_TILE), lambda j, c, meta: (c, j))],
        out_specs=pl.BlockSpec((1, F_TILE), lambda j, c, meta: (meta[c, 0], j)),
    )
    return pl.pallas_call(
        _hd_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_hd_rows, f_pad), jnp.float32),
        interpret=interpret,
    )(jnp.asarray(chunk_meta), msgs)


# ---------------------------------------------------------------------------
# Full SpMM: gather (XLA) -> per-bucket kernels -> permutation assembly (XLA)
# ---------------------------------------------------------------------------

def pad_features(x: jax.Array) -> jax.Array:
    """Feature staging for the bucket walks: one zero row appended (the
    gather pad target) and lanes padded to the F_TILE quantum.  Hoisted
    callers (the ForwardPlan forward) pad once per layer and share the
    result across both direction walks."""
    f = x.shape[1]
    return jnp.pad(x, ((0, 1), (0, -f % F_TILE)))


def assemble_rows(plan: SpmmPlan, parts: list, f_pad: int) -> jax.Array:
    """Scatter-free output assembly via the inverse count-sort permutation.

    ``parts`` are the per-bucket (R_pad, F_pad) reductions (then HD) in
    plan order; one concatenate + one gather replaces the pre-hoist
    ``num_segments`` ``out.at[rows].add`` passes over the (N, F) output.
    """
    parts = list(parts) + [jnp.zeros((1, f_pad), jnp.float32)]
    cat = jnp.concatenate(parts, axis=0)
    return jnp.take(cat, jnp.asarray(plan.asm_index), axis=0)


def assemble_rows_grouped(
    plan: SpmmPlan, parts: list, groups: int, f_pad: int
) -> jax.Array:
    """Grouped variant: parts are (G, R_pad, F_pad); concat/gather on axis 1.

    ``groups`` is passed explicitly — a zero-edge graph has no parts to
    infer it from but must still return (G, N, F_pad)."""
    parts = list(parts) + [jnp.zeros((groups, 1, f_pad), jnp.float32)]
    cat = jnp.concatenate(parts, axis=1)
    return jnp.take(cat, jnp.asarray(plan.asm_index), axis=1)


def apply_plan(
    plan: SpmmPlan,
    x: jax.Array,
    w: Optional[jax.Array] = None,
    *,
    interpret: bool = True,
    mxu: bool = False,
) -> jax.Array:
    """Compute ``out[r] = sum_{e: dst[e]=r} w[e] * x[src[e]]`` via the
    degree-bucketed kernels.  ``plan`` is static (host numpy); ``x``/``w``
    are traced.  Matches :func:`repro.kernels.ref.spmm_ref`.
    """
    PROBE["edge_stream_gathers"] += 1
    PROBE["kernel_walks"] += 1
    if w is not None:
        PROBE["weight_gathers"] += 1
        PROBE["stream_bytes"] += plan.num_slots * x.dtype.itemsize
    n, f = x.shape
    f_pad = f + (-f % F_TILE)
    x_p = pad_features(x)
    w_p = None if w is None else jnp.pad(w.astype(x.dtype), (0, 1))
    PROBE["stream_bytes"] += plan.num_slots * f_pad * x.dtype.itemsize

    def gather(cols: np.ndarray, eids: np.ndarray) -> jax.Array:
        g = jnp.take(x_p, jnp.asarray(cols), axis=0)
        if w_p is not None:
            g = g * jnp.take(w_p, jnp.asarray(eids), axis=0)[:, None]
        return g

    parts = []
    for b in plan.buckets:
        msgs = gather(b.cols, b.eids)
        parts.append(
            ld_bucket_apply(msgs, b.deg, b.rows_per_tile, interpret=interpret, mxu=mxu)
        )
    if plan.hd is not None:
        msgs = gather(plan.hd.cols, plan.hd.eids)
        parts.append(
            hd_apply(
                msgs, plan.hd.chunk_meta, len(plan.hd.rows), plan.e_t,
                interpret=interpret,
            )
        )
    out = assemble_rows(plan, parts, f_pad)
    return out[:, :f].astype(x.dtype)


# ---------------------------------------------------------------------------
# Grouped multi-polarity SpMM.  The SAGE layer's six slot x polarity
# aggregations share one plan and identical gather columns — only the
# per-edge weights differ.  The grouped kernels take a (slots, G) weight
# matrix, gather ``x[src]`` ONCE, broadcast-multiply by the G weight
# columns inside the tile, and reduce every group in the same pass:
# 6 gathers + 6 kernel walks per layer collapse to one per direction.
# Output layout is group-major (G, R, F) — per-group (N, F) planes the
# layer contracts directly via ``einsum('gnf,gfh->nh')``.
# ---------------------------------------------------------------------------

def _ld_kernel_grouped(wg_ref, msgs_ref, o_ref, *, rows: int, deg: int):
    """(R_t*d, F_t) tile + (R_t*d, G) weights -> (G, R_t, F_t) row sums.

    One edge-message load serves every group; the per-group weighting is
    a VREG broadcast (f32 accumulation as in the ungrouped kernel)."""
    m = msgs_ref[...].astype(jnp.float32)
    w = wg_ref[...].astype(jnp.float32)
    prod = w.T[:, :, None] * m[None, :, :]            # (G, R_t*d, F_t)
    o_ref[...] = prod.reshape(w.shape[1], rows, deg, m.shape[-1]).sum(axis=2)


def _ld_kernel_grouped_mxu(red_ref, wg_ref, msgs_ref, o_ref, *, groups: int):
    """MXU path: per group, one-hot block-diag reduction @ weighted tile.

    ``groups`` is static and tiny (2 or 4), so the loop unrolls into G
    back-to-back systolic matmuls over the SAME resident message tile."""
    m = msgs_ref[...]
    w = wg_ref[...]
    red = red_ref[...]
    o_ref[...] = jnp.stack(
        [
            jax.lax.dot(red, m * w[:, g][:, None], preferred_element_type=o_ref.dtype)
            for g in range(groups)
        ],
        axis=0,
    )


def ld_grouped_apply(
    msgs: jax.Array,
    wg: jax.Array,
    deg: int,
    rows_per_tile: int,
    *,
    interpret: bool,
    mxu: bool,
) -> jax.Array:
    """Grouped LD reduction over one ELL slab.

    msgs: (R_pad * deg, F_pad); wg: (R_pad * deg, G) -> (G, R_pad, F_pad).
    """
    PROBE["pallas_calls"] += 1
    f_pad = msgs.shape[1]
    g = wg.shape[1]
    r_pad = msgs.shape[0] // deg
    r_t = rows_per_tile
    grid = (r_pad // r_t, f_pad // F_TILE)
    out_shape = jax.ShapeDtypeStruct((g, r_pad, f_pad), jnp.float32)
    if mxu and deg > 1:
        red = np.zeros((r_t, r_t * deg), dtype=np.float32)
        for r in range(r_t):
            red[r, r * deg : (r + 1) * deg] = 1.0
        return pl.pallas_call(
            functools.partial(_ld_kernel_grouped_mxu, groups=g),
            grid=grid,
            in_specs=[
                pl.BlockSpec((r_t, r_t * deg), lambda i, j: (0, 0)),
                pl.BlockSpec((r_t * deg, g), lambda i, j: (i, 0)),
                pl.BlockSpec((r_t * deg, F_TILE), lambda i, j: (i, j)),
            ],
            out_specs=pl.BlockSpec((g, r_t, F_TILE), lambda i, j: (0, i, j)),
            out_shape=out_shape,
            interpret=interpret,
        )(jnp.asarray(red, msgs.dtype), wg.astype(msgs.dtype), msgs)
    return pl.pallas_call(
        functools.partial(_ld_kernel_grouped, rows=r_t, deg=deg),
        grid=grid,
        in_specs=[
            pl.BlockSpec((r_t * deg, g), lambda i, j: (i, 0)),
            pl.BlockSpec((r_t * deg, F_TILE), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((g, r_t, F_TILE), lambda i, j: (0, i, j)),
        out_shape=out_shape,
        interpret=interpret,
    )(wg, msgs)


def _hd_kernel_grouped(meta_ref, wg_ref, msgs_ref, o_ref):
    """One E_t-edge chunk -> per-group partial sums for the chunk's row.

    The weighted reduction is one (G, E_t) @ (E_t, F_t) systolic matmul;
    accumulation across a row's chunks revisits the same (G, 1, F_t)
    output block in VMEM, exactly like the ungrouped HD kernel."""
    c = pl.program_id(1)
    m = msgs_ref[...].astype(jnp.float32)
    w = wg_ref[...].astype(jnp.float32)
    part = jax.lax.dot(w.T, m, preferred_element_type=jnp.float32)[:, None, :]

    @pl.when(meta_ref[c, 1] == 1)
    def _init():
        o_ref[...] = part

    @pl.when(meta_ref[c, 1] == 0)
    def _acc():
        o_ref[...] += part


def hd_grouped_apply(
    msgs: jax.Array,
    wg: jax.Array,
    chunk_meta: np.ndarray,
    n_hd_rows: int,
    e_t: int,
    *,
    interpret: bool,
) -> jax.Array:
    """msgs: (n_chunks * e_t, F_pad); wg: (n_chunks * e_t, G)
    -> (G, n_hd_rows, F_pad)."""
    PROBE["pallas_calls"] += 1
    f_pad = msgs.shape[1]
    g = wg.shape[1]
    n_chunks = msgs.shape[0] // e_t
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(f_pad // F_TILE, n_chunks),
        in_specs=[
            pl.BlockSpec((e_t, g), lambda j, c, meta: (c, 0)),
            pl.BlockSpec((e_t, F_TILE), lambda j, c, meta: (c, j)),
        ],
        out_specs=pl.BlockSpec((g, 1, F_TILE), lambda j, c, meta: (0, meta[c, 0], j)),
    )
    return pl.pallas_call(
        _hd_kernel_grouped,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((g, n_hd_rows, f_pad), jnp.float32),
        interpret=interpret,
    )(jnp.asarray(chunk_meta), wg, msgs)


# ---------------------------------------------------------------------------
# Forward-invariant weight staging.  The (E, G) group-weight matrices of a
# GNN forward are layer-invariant; pre-hoist every layer of every forward
# re-gathered them into each bucket's ELL layout (``jnp.take(wg_p,
# b.eids)`` per bucket per layer).  ``stage_group_weights`` performs ONE
# gather of the concatenated edge-id stream and slices the result into
# per-bucket (and HD-chunk) streams the staged walks consume directly —
# layers 2..L touch zero edge-weight bytes.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StagedWeights:
    """Edge-weight streams pre-gathered into kernel layout (traced arrays,
    aligned with ``plan.buckets`` order; ``hd`` in HD-chunk layout)."""

    buckets: tuple                 # per-bucket (R_pad * deg, G)
    hd: Optional[jax.Array]        # (n_chunks * e_t, G) or None
    groups: int


def plan_cat_eids(plan: SpmmPlan) -> np.ndarray:
    """Concatenated edge-id stream of every bucket + HD chunk (int32) —
    the single gather index of :func:`stage_group_weights`."""
    parts = [b.eids for b in plan.buckets]
    if plan.hd is not None:
        parts.append(plan.hd.eids)
    if not parts:
        return np.zeros(0, np.int32)
    return np.concatenate(parts).astype(np.int32)


def stage_group_weights(
    plan: SpmmPlan,
    wg: jax.Array,
    *,
    cat_eids: Optional[np.ndarray] = None,
    dtype=None,
) -> StagedWeights:
    """Gather the (E, G) group-weight matrix into every bucket's ELL
    layout and the HD chunk layout in ONE pass (``dtype`` casts the
    staged streams, e.g. bf16 — kernels accumulate in f32 regardless)."""
    PROBE["weight_gathers"] += 1
    g = wg.shape[1]
    if cat_eids is None:
        cat_eids = plan_cat_eids(plan)
    wg_p = jnp.pad(wg.astype(jnp.float32), ((0, 1), (0, 0)))  # row E = 0 weight
    cat = jnp.take(wg_p, jnp.asarray(cat_eids), axis=0)
    if dtype is not None:
        cat = cat.astype(dtype)
    PROBE["stream_bytes"] += int(cat_eids.size) * g * cat.dtype.itemsize
    chunks = []
    off = 0
    for b in plan.buckets:
        chunks.append(cat[off : off + b.eids.size])
        off += b.eids.size
    hd = None
    if plan.hd is not None:
        hd = cat[off : off + plan.hd.eids.size]
    return StagedWeights(buckets=tuple(chunks), hd=hd, groups=g)


def apply_plan_grouped_staged(
    plan: SpmmPlan,
    x_p: jax.Array,
    staged: StagedWeights,
    *,
    interpret: bool = True,
    mxu: bool = False,
) -> jax.Array:
    """Hoisted grouped walk: pre-padded features (see :func:`pad_features`)
    + pre-staged weight streams in, ``(G, N, F_pad)`` f32 out.  Touches no
    edge-weight bytes and issues no output scatters (permutation
    assembly)."""
    PROBE["edge_stream_gathers"] += 1
    PROBE["kernel_walks"] += 1
    f_pad = x_p.shape[1]
    PROBE["stream_bytes"] += plan.num_slots * f_pad * x_p.dtype.itemsize
    parts = []
    for b, wge in zip(plan.buckets, staged.buckets):
        msgs = jnp.take(x_p, jnp.asarray(b.cols), axis=0)
        parts.append(
            ld_grouped_apply(
                msgs, wge, b.deg, b.rows_per_tile, interpret=interpret, mxu=mxu
            )
        )
    if plan.hd is not None:
        msgs = jnp.take(x_p, jnp.asarray(plan.hd.cols), axis=0)
        parts.append(
            hd_grouped_apply(
                msgs, staged.hd, plan.hd.chunk_meta, len(plan.hd.rows), plan.e_t,
                interpret=interpret,
            )
        )
    return assemble_rows_grouped(plan, parts, staged.groups, f_pad)


def apply_plan_grouped(
    plan: SpmmPlan,
    x: jax.Array,
    wg: jax.Array,
    *,
    interpret: bool = True,
    mxu: bool = False,
) -> jax.Array:
    """All-groups SpMM: ``out[g, r] = sum_{e: dst[e]=r} wg[e, g] * x[src[e]]``.

    One walk of the bucket schedule and one gather of the edge stream
    serve every group — ``wg`` is ``(E, G)`` with one weight column per
    slot x polarity group.  Returns ``(G, N, F)`` in ``x.dtype``.
    Matches ``stack([apply_plan(plan, x, wg[:, g]) for g])``.

    Stages the weight streams per call; the hoisted forward
    (:mod:`repro.kernels.forward_plan`) stages once per forward instead.
    """
    f = x.shape[1]
    staged = stage_group_weights(plan, wg)
    out = apply_plan_grouped_staged(
        plan, pad_features(x), staged, interpret=interpret, mxu=mxu
    )
    return out[:, :, :f].astype(x.dtype)
