"""Forward-invariant hoisting: everything a GNN forward reuses across layers.

The paper's HD/LD co-design does the expensive restructuring once on the
host and keeps per-iteration device work minimal.  The degree-bucketed
kernels honor that for the edge *topology* (one :class:`SpmmPlan` per
graph) but, pre-hoist, not for the edge *weights* or the output assembly:
every layer of every forward re-gathered the (E, 4)/(E, 2) group-weight
matrices into each bucket's ELL layout and re-scattered the output once
per bucket.  For a static EDA graph those are invariant across all
``num_layers`` layers — the dominant avoidable HBM-traffic term in the
memory-bound regime row-parallel baselines live in.

A :class:`ForwardPlan` packages what one forward hoists out of the layer
loop:

  * both direction plans (fanin/fanout) plus their concatenated edge-id
    streams, so :meth:`stage_in`/:meth:`stage_out` gather each direction's
    weight streams ONCE per forward (``PROBE["weight_gathers"] == 2``
    regardless of ``num_layers``) — optionally cast to a narrow
    ``stream_dtype`` (bf16 halves the staged bytes; kernels accumulate
    in f32);
  * the padded feature staging contract (:meth:`pad_x`,
    :meth:`pad_weight_stack` record the F_TILE-quantised shapes), so
    activations are padded once per layer and shared by both direction
    walks, and the fused path's weight stacks are padded in a prologue;
  * the scatter-free assembly indices live on the :class:`SpmmPlan`s
    themselves (``asm_index``) — the staged walks never issue an
    ``out.at[rows].add``.

ForwardPlans are pure functions of graph structure and are registered in
the process-wide structural cache beside ``SpmmPlan``/``AggPair``
(:func:`repro.kernels.plan_cache.cached_forward_plan`), so the executor
and service inherit hoisted plans across launches through
``make_agg_pair``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.groot_spmm import (
    F_TILE,
    SpmmPlan,
    StagedWeights,
    pad_features,
    plan_cat_eids,
    stage_group_weights,
)


@dataclasses.dataclass(frozen=True, eq=False)
class ForwardPlan:
    """Layer-invariant staging schedule for one graph (host-side, static).

    Identity-hashed (``eq=False``): like :class:`~repro.kernels.ops.AggPair`,
    the cached instance doubles as a jit static argument.
    """

    in_plan: SpmmPlan
    out_plan: SpmmPlan
    in_cat_eids: np.ndarray      # int32 concat of fanin bucket + HD eids
    out_cat_eids: np.ndarray

    @property
    def num_nodes(self) -> int:
        return self.in_plan.num_nodes

    @property
    def num_edges(self) -> int:
        return self.in_plan.num_edges

    # -- per-forward staging -------------------------------------------------

    def stage_in(self, wg: jax.Array, *, dtype=None) -> StagedWeights:
        """Gather the (E, 4) fanin group weights into kernel layout once."""
        return stage_group_weights(
            self.in_plan, wg, cat_eids=self.in_cat_eids, dtype=dtype
        )

    def stage_out(self, wg: jax.Array, *, dtype=None) -> StagedWeights:
        """Gather the (E, 2) fanout group weights into kernel layout once."""
        return stage_group_weights(
            self.out_plan, wg, cat_eids=self.out_cat_eids, dtype=dtype
        )

    # -- padded-shape contract ----------------------------------------------

    @staticmethod
    def pad_x(x: jax.Array) -> jax.Array:
        """(N, F) -> (N + 1, F_pad): one pad per layer, shared by both
        direction walks (pre-hoist each aggregation padded its own copy)."""
        return pad_features(x)

    @staticmethod
    def pad_weight_stack(w_stack: jax.Array) -> jax.Array:
        """(G, F, H) -> (G, F_pad, H_pad) f32 for the fused kernels —
        padded once per forward in the prologue, not per layer call."""
        g, f, h = w_stack.shape
        return jnp.pad(
            w_stack.astype(jnp.float32),
            ((0, 0), (0, -f % F_TILE), (0, -h % F_TILE)),
        )


def build_forward_plan(in_plan: SpmmPlan, out_plan: SpmmPlan) -> ForwardPlan:
    """Assemble the hoisting schedule from a graph's two direction plans."""
    assert in_plan.num_nodes == out_plan.num_nodes
    assert in_plan.num_edges == out_plan.num_edges
    return ForwardPlan(
        in_plan=in_plan,
        out_plan=out_plan,
        in_cat_eids=plan_cat_eids(in_plan),
        out_cat_eids=plan_cat_eids(out_plan),
    )
