"""Pallas flash attention (TPU target) — the kernel form of the lax-flash
schedule in ``repro.zoo.models.attention``.

The roofline analysis (EXPERIMENTS.md §Roofline) shows the dominant
memory-term contributor for every attention arch is the score stream the
lax schedule materialises between loop steps (e.g. ~3.7 TB/device of the
qwen3-8b train traffic).  This kernel keeps scores, the running max and
the denominator in VMEM scratch across the kv-block grid dimension —
exactly the classic flash-attention tiling, expressed as:

    grid = (B*KV*G, n_q_blocks, n_kv_blocks)   (kv innermost)
    q block   (1, qc, hd)   revisited across kv blocks
    k/v block (1, kc, hd)
    scratch   acc (qc, hd) f32, m (qc, 1) f32, l (qc, 1) f32
    out block (1, qc, hd)   written on the last kv step

Causal/window masks are reconstructed from block indices (global
positions = block_id * block + iota), so no mask tensor ever exists.

Validated in interpret mode against the plain softmax reference for
causal / windowed / bidirectional cases (tests/test_kernels_flash.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, acc, m_scr, l_scr,
    *, scale: float, causal: bool, window: int, qc: int, kc: int,
    n_kv: int, softcap: float,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)

    q = q_ref[0]                                   # (qc, hd)
    k = k_ref[0]                                   # (kc, hd)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                      # (qc, kc)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)

    q_pos = qi * qc + jax.lax.broadcasted_iota(jnp.int32, (qc, kc), 0)
    k_pos = ki * kc + jax.lax.broadcasted_iota(jnp.int32, (qc, kc), 1)
    ok = k_pos <= q_pos if causal else jnp.ones((qc, kc), jnp.bool_)
    if window:
        ok &= k_pos > q_pos - window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[...]                            # (qc, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                         # (qc, kc)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
    m_scr[...] = m_new
    pv = jax.lax.dot(
        p.astype(v_ref.dtype), v_ref[0], preferred_element_type=jnp.float32
    )
    acc[...] = acc[...] * alpha + pv

    @pl.when(ki == n_kv - 1)
    def _emit():
        o_ref[0] = (acc[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    scale: float | None = None,
    softcap: float = 0.0,
    q_block: int = 256,
    kv_block: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """q: (BH, S, hd); k/v: (BH, T, hd) — GQA callers flatten (B, KV, G)
    into BH and broadcast k/v per group.  Returns (BH, S, hd)."""
    bh, s, hd = q.shape
    t = k.shape[1]
    scale = hd**-0.5 if scale is None else scale
    qc, kc = min(q_block, s), min(kv_block, t)
    s_pad, t_pad = -s % qc, -t % kc
    if s_pad:
        q = jnp.pad(q, ((0, 0), (0, s_pad), (0, 0)))
    if t_pad:
        # padded keys land at positions > any query -> masked by causal;
        # for bidirectional we mask them via a window-free position test
        k = jnp.pad(k, ((0, 0), (0, t_pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, t_pad), (0, 0)))
    nq, nk = (s + s_pad) // qc, (t + t_pad) // kc
    if not causal and t_pad:
        # bidirectional + padding needs an explicit key bound: fall back
        # to a window covering everything real (masks pads via k_pos).
        raise ValueError("bidirectional flash requires T % kv_block == 0")

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, scale=scale, causal=causal, window=window,
            qc=qc, kc=kc, n_kv=nk, softcap=softcap,
        ),
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, qc, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, kc, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, kc, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, qc, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s + s_pad, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((qc, hd), jnp.float32),
            pltpu.VMEM((qc, 1), jnp.float32),
            pltpu.VMEM((qc, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :s]


def flash_ref(q, k, v, *, causal=True, window=0, scale=None, softcap=0.0):
    """Plain-softmax oracle, same signature."""
    bh, s, hd = q.shape
    t = k.shape[1]
    scale = hd**-0.5 if scale is None else scale
    sc = jnp.einsum("bsd,btd->bst", q, k).astype(jnp.float32) * scale
    if softcap:
        sc = softcap * jnp.tanh(sc / softcap)
    q_pos = np.arange(s)[:, None]
    k_pos = np.arange(t)[None, :]
    ok = k_pos <= q_pos if causal else np.ones((s, t), bool)
    if window:
        ok = ok & (k_pos > q_pos - window)
    sc = jnp.where(jnp.asarray(ok)[None], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1).astype(v.dtype)
    return jnp.einsum("bst,btd->bsd", p, v)
