"""Pure-jnp oracles for the GROOT SpMM kernels.

The kernel contract (shared by every backend) is *weighted gather-scatter
aggregation*: given node features ``x (N, F)``, edge endpoints
``src/dst (E,)`` and edge weights ``w (E,)``,

    out[r] = sum over edges e with dst[e] == r of  w[e] * x[src[e]]

which is SpMM ``A @ x`` with ``A[dst, src] = w`` in COO form.  Every Pallas
kernel in this package is validated against :func:`spmm_ref` (tests sweep
shapes and dtypes).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def spmm_ref(x, edge_src, edge_dst, num_nodes: int, w=None):
    """Gather + segment-sum reference (row-parallel SpMM)."""
    msgs = jnp.take(x, edge_src, axis=0)
    if w is not None:
        msgs = msgs * w[:, None].astype(msgs.dtype)
    return jax.ops.segment_sum(msgs, edge_dst, num_segments=num_nodes)


def spmm_dense_ref(x, edge_src, edge_dst, num_nodes: int, w=None):
    """Dense-adjacency oracle (O(N^2) memory — tiny graphs only).

    Independent of segment_sum, used to cross-validate spmm_ref itself in
    property tests.
    """
    a = jnp.zeros((num_nodes, x.shape[0]), x.dtype)
    vals = jnp.ones_like(edge_src, dtype=x.dtype) if w is None else w.astype(x.dtype)
    a = a.at[edge_dst, edge_src].add(vals)
    return a @ x


def ell_block_reduce_ref(msgs, rows_per_tile: int, degree: int):
    """Oracle for the LD kernel body: (R*d, F) padded edge stream ->
    (R, F) row sums.  ``msgs`` rows are grouped per destination row."""
    r = msgs.shape[0] // degree
    del rows_per_tile
    return msgs.reshape(r, degree, msgs.shape[1]).sum(axis=1)


def hd_chunk_reduce_ref(msgs, chunk_rows):
    """Oracle for the HD kernel: msgs (C, E_t, F) chunks, chunk_rows (C,)
    destination row per chunk -> (num_rows, F) accumulated sums."""
    n_rows = int(chunk_rows.max()) + 1 if chunk_rows.size else 0
    partial = msgs.sum(axis=1)  # (C, F)
    return jax.ops.segment_sum(partial, chunk_rows, num_segments=n_rows)
