"""Telemetry export: Prometheus text rendering, JSONL sampling, scrape HTTP.

Three ways numbers leave the process:

  * :func:`render_prometheus` — any :class:`~repro.obs.metrics.
    MetricsRegistry` as Prometheus text exposition (counters as
    ``*_total``, gauges with a ``*_max`` high-water twin, histogram
    summaries as ``_count``/``_sum`` + quantile lines).  Dotted
    instrument names are sanitized to the Prometheus charset;
    :func:`parse_prometheus` parses the text back (the CI round-trip
    gate: render → parse → same counter values).
  * :class:`Sampler` — a daemon thread appending one JSONL time-series
    snapshot per interval (gauge value+max, histogram count/p50/p95,
    counters) while a service run or benchmark suite executes; the file
    is the raw material for queue-depth / slot-occupancy plots across a
    run, uploaded by CI next to the BENCH JSONs.
  * :func:`start_metrics_server` — a stdlib ``http.server`` scrape
    endpoint: ``GET /metrics`` renders the live registry, ``GET /stats``
    returns an arbitrary stats callable as JSON (what ``repro top``
    polls).  No third-party dependency; ``ThreadingHTTPServer`` so a
    slow scraper never blocks the service.
"""
from __future__ import annotations

import dataclasses
import json
import re
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Callable, Optional

from repro.obs.metrics import MetricsRegistry

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")
_LINE_RE = re.compile(
    r'^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)'
    r'(?:\{(?P<labels>[^}]*)\})?\s+(?P<value>[^\s]+)\s*$'
)


def sanitize_metric_name(name: str) -> str:
    """Dotted registry names -> Prometheus charset (``a.b-c`` -> ``a_b_c``)."""
    out = _NAME_RE.sub("_", name)
    if out and out[0].isdigit():
        out = "_" + out
    return out


def render_prometheus(registry: MetricsRegistry, *,
                      namespace: str = "repro") -> str:
    """Text exposition (version 0.0.4) of every instrument in ``registry``.

    Counters become ``<ns>_<name>_total``; gauges emit the live value and
    a ``_max`` high-water twin (the peak queue depth / slot occupancy the
    last-value export used to silently lose); histograms emit summary
    ``_count``/``_sum`` plus ``quantile``-labelled p50/p95/p99 lines.
    """
    snap = registry.snapshot()
    lines: list[str] = []

    def emit(name: str, kind: str, value, labels: str = "") -> None:
        lines.append(f"# TYPE {name} {kind}")
        lines.append(f"{name}{labels} {value}")

    for key, v in sorted(snap["counters"].items()):
        emit(f"{namespace}_{sanitize_metric_name(key)}_total", "counter", v)
    for key, g in sorted(snap["gauges"].items()):
        base = f"{namespace}_{sanitize_metric_name(key)}"
        emit(base, "gauge", g["value"])
        emit(f"{base}_max", "gauge", g["max"])
    for key, s in sorted(snap["histograms"].items()):
        base = f"{namespace}_{sanitize_metric_name(key)}"
        lines.append(f"# TYPE {base} summary")
        for q in ("p50", "p95", "p99"):
            if q in s:
                lines.append(
                    f'{base}{{quantile="0.{q[1:]}"}} {s[q]}'
                )
        lines.append(f"{base}_sum {s.get('sum', 0.0)}")
        lines.append(f"{base}_count {s.get('count', 0)}")
    return "\n".join(lines) + "\n"


def parse_prometheus(text: str) -> dict[str, float]:
    """Text exposition -> ``{metric_name[{labels}]: value}``.

    Minimal but sufficient for the round-trip gate: comments/TYPE lines
    are skipped, label sets are kept verbatim in the key.
    """
    out: dict[str, float] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        m = _LINE_RE.match(line)
        if m is None:
            continue
        key = m.group("name")
        if m.group("labels"):
            key += "{" + m.group("labels") + "}"
        out[key] = float(m.group("value"))
    return out


def _jsonable(obj):
    """json.dumps default= for stats payloads (dataclasses, shapes, ...)."""
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return dataclasses.asdict(obj)
    if hasattr(obj, "tolist"):
        return obj.tolist()
    return str(obj)


class Sampler:
    """Background thread appending periodic registry snapshots as JSONL.

    One line per ``interval_s``: wall/elapsed time, every gauge's
    value+max, every histogram's count/mean/p50/p95, and raw counters —
    the time axis the point-in-time ``Report`` lacks.  ``extra`` is an
    optional callable returning a dict merged into each line (e.g. a
    service's pending-ticket count).  Stop flushes one final sample, so
    even a run shorter than the interval leaves at least one line.
    """

    def __init__(self, path, registry: MetricsRegistry, *,
                 interval_s: float = 0.5,
                 extra: Optional[Callable[[], dict]] = None):
        self.path = str(path)
        self.registry = registry
        self.interval_s = max(0.01, float(interval_s))
        self.extra = extra
        self.samples = 0
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._t0 = 0.0
        self._file = None
        self._lock = threading.Lock()

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> "Sampler":
        if self._thread is not None:
            return self
        self._t0 = time.perf_counter()
        self._file = open(self.path, "a")
        self._thread = threading.Thread(
            target=self._loop, name="obs-sampler", daemon=True
        )
        self._thread.start()
        return self

    def stop(self) -> int:
        """Stop the thread, flush a final sample; returns samples written."""
        if self._thread is None:
            return self.samples
        self._stop.set()
        self._thread.join(timeout=10.0)
        self._thread = None
        self._sample()                 # the closing bookend
        with self._lock:
            self._file.close()
            self._file = None
        return self.samples

    def __enter__(self) -> "Sampler":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- internals -----------------------------------------------------------

    def _sample(self) -> None:
        snap = self.registry.snapshot()
        line = {
            "t": time.time(),
            "elapsed_s": time.perf_counter() - self._t0,
            "gauges": snap["gauges"],
            "histograms": {
                k: {q: s[q] for q in ("count", "mean", "p50", "p95") if q in s}
                for k, s in snap["histograms"].items()
            },
            "counters": snap["counters"],
        }
        if self.extra is not None:
            try:
                line.update(self.extra())
            except Exception:  # noqa: BLE001 — telemetry must not kill the run
                pass
        with self._lock:
            if self._file is None:
                return
            self._file.write(json.dumps(line, default=_jsonable) + "\n")
            self._file.flush()
            self.samples += 1

    def _loop(self) -> None:
        while not self._stop.wait(self.interval_s):
            self._sample()


class _MetricsHandler(BaseHTTPRequestHandler):
    server: "MetricsServer"

    def do_GET(self):  # noqa: N802 — http.server API
        try:
            if self.path.split("?")[0] in ("/metrics", "/"):
                body = render_prometheus(self.server.registry_fn()).encode()
                ctype = "text/plain; version=0.0.4; charset=utf-8"
            elif self.path.split("?")[0] == "/stats":
                body = json.dumps(
                    self.server.stats_fn(), default=_jsonable, indent=1
                ).encode()
                ctype = "application/json"
            else:
                self.send_error(404)
                return
        except Exception as e:  # noqa: BLE001 — a scrape must not crash us
            self.send_error(500, str(e))
            return
        self.send_response(200)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def log_message(self, *args):  # silence per-scrape stderr noise
        pass


class MetricsServer:
    """A live scrape endpoint over a registry (+ optional stats callable)."""

    def __init__(self, registry_or_fn, *, port: int = 0,
                 stats_fn: Optional[Callable[[], dict]] = None,
                 host: str = "127.0.0.1"):
        if callable(registry_or_fn):
            self.registry_fn = registry_or_fn
        else:
            self.registry_fn = lambda: registry_or_fn
        self.stats_fn = stats_fn or (lambda: self.registry_fn().snapshot())
        self._httpd = ThreadingHTTPServer((host, port), _MetricsHandler)
        self._httpd.registry_fn = self.registry_fn        # type: ignore[attr-defined]
        self._httpd.stats_fn = self.stats_fn              # type: ignore[attr-defined]
        self.port = self._httpd.server_address[1]
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="obs-metrics-http",
            daemon=True,
        )
        self._thread.start()

    @property
    def url(self) -> str:
        return f"http://127.0.0.1:{self.port}"

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5.0)

    def __enter__(self) -> "MetricsServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def start_metrics_server(registry_or_fn, *, port: int = 0,
                         stats_fn: Optional[Callable[[], dict]] = None
                         ) -> MetricsServer:
    """Serve ``/metrics`` (Prometheus) + ``/stats`` (JSON) on ``port``
    (0 = ephemeral; read ``server.port``)."""
    return MetricsServer(registry_or_fn, port=port, stats_fn=stats_fn)
