"""Flight recorder: one structured forensic record per request.

The metrics registry answers "how much / how fast on average"; a
:class:`FlightRecord` answers "what happened to ticket 17".  Each record
carries the request's identity (name, tenant, priority), its routing
facts (bucket, capacity, cached/coalesced flags), a stage *timeline* of
monotonic ``perf_counter`` marks (submit → prepared → admitted →
inferred → done), and — for failures — the attributable failure cause
plus the stage it died in.

The :class:`FlightRecorder` is a bounded, thread-safe ring: a long-lived
service keeps the last ``capacity`` flights in memory at O(capacity)
cost, so post-hoc incident questions ("which tenant's requests queued
behind the spike at 14:03?") are answerable without any external
infrastructure.  ``dump()`` / ``dump_failure()`` write JSON files — the
service dumps a failed ticket's record at failure time, so the forensic
trail survives the process.

Stage-duration contract (what the tests pin): ``stages`` is derived from
*consecutive present marks*, each segment named by the stage it ends in,
so ``sum(stages.values()) == total_s`` exactly and the marks are
monotonic non-decreasing.  A cache hit has only ``submit``/``done``
marks; its whole life is one ``done`` segment.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
from collections import deque
from typing import Optional

#: canonical stage order of a service ticket's life; sync ``verify`` uses
#: the same vocabulary minus ``admitted`` (no device queue to wait in)
STAGE_ORDER = ("submit", "prepared", "admitted", "inferred", "done")

#: segment label for the interval ENDING at each mark (the queue-wait is
#: the time between being prepared and being admitted to a device pack)
SEGMENT_OF = {
    "prepared": "prepare",
    "admitted": "queue_wait",
    "inferred": "infer",
    "done": "finalize",
}


@dataclasses.dataclass(frozen=True)
class FlightRecord:
    """One request's full life, json-safe via :meth:`to_dict`."""

    req_id: int
    name: str
    status: str                       # verified|falsified|...|classified|error
    cached: bool = False
    coalesced: bool = False
    priority: int = 1
    tenant: Optional[str] = None
    bucket: Optional[tuple] = None    # (n_pad, e_pad) of the request's pack
    capacity: Optional[int] = None    # slots per device call when packed
    streamed: bool = False            # ran the oversized partitioned route
    error: Optional[str] = None       # "TypeError: ..." failure cause
    failed_stage: Optional[str] = None
    marks: tuple = ()                 # ((stage, perf_counter), ...) ordered
    stages: dict = dataclasses.field(default_factory=dict)
    total_s: float = 0.0
    # failure-domain facts: transient-launch replays this ticket consumed
    # and the wall-clock budget it was armed with (None = no deadline)
    retries: int = 0
    deadline_s: Optional[float] = None

    @property
    def ok(self) -> bool:
        return self.status != "error"

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["marks"] = [[s, t] for s, t in self.marks]
        d["bucket"] = list(self.bucket) if self.bucket else None
        return d


def stages_from_marks(marks) -> tuple[dict, float]:
    """(segment durations, total) from an ordered mark list.

    Segments are named after the mark that *ends* them (see
    :data:`SEGMENT_OF`), so they tile the timeline: their sum equals
    ``last - first`` exactly, which is what makes "queue-wait + stage
    durations ≈ total" an assertable invariant rather than a hope.
    """
    if len(marks) < 2:
        return {}, 0.0
    stages: dict[str, float] = {}
    for (_, t0), (name, t1) in zip(marks, marks[1:]):
        seg = SEGMENT_OF.get(name, name)
        stages[seg] = stages.get(seg, 0.0) + (t1 - t0)
    return stages, marks[-1][1] - marks[0][1]


def failed_stage_from_marks(marks) -> str:
    """The segment a request died in: the one *after* its last mark.

    Call this on the timeline as it stood at failure time — before the
    terminal ``done`` mark is stamped — or the answer degenerates to
    ``finalize`` for every failure.
    """
    last = marks[-1][0] if marks else STAGE_ORDER[0]
    idx = STAGE_ORDER.index(last) if last in STAGE_ORDER else 0
    nxt = STAGE_ORDER[min(idx + 1, len(STAGE_ORDER) - 1)]
    return SEGMENT_OF.get(nxt, nxt)


def record_from_marks(
    req_id: int,
    name: str,
    status: str,
    marks,
    **facts,
) -> FlightRecord:
    """Assemble a record, deriving stage durations and — on error — the
    stage the request died in (the segment *after* its last mark)."""
    marks = tuple((str(s), float(t)) for s, t in marks)
    stages, total = stages_from_marks(marks)
    failed_stage = facts.pop("failed_stage", None)
    if status == "error" and failed_stage is None and marks:
        failed_stage = failed_stage_from_marks(marks)
    return FlightRecord(
        req_id=req_id, name=name, status=status, marks=marks,
        stages=stages, total_s=total, failed_stage=failed_stage, **facts,
    )


class FlightRecorder:
    """Bounded thread-safe ring of :class:`FlightRecord`."""

    def __init__(self, capacity: int = 256):
        assert capacity >= 1
        self.capacity = capacity
        self._ring: deque[FlightRecord] = deque(maxlen=capacity)
        self._lock = threading.Lock()
        self._recorded = 0
        self._failures = 0

    def record(self, rec: FlightRecord) -> FlightRecord:
        with self._lock:
            self._ring.append(rec)
            self._recorded += 1
            if not rec.ok:
                self._failures += 1
        return rec

    def __len__(self) -> int:
        with self._lock:
            return len(self._ring)

    def records(self, *, failures_only: bool = False) -> list[FlightRecord]:
        with self._lock:
            out = list(self._ring)
        if failures_only:
            out = [r for r in out if not r.ok]
        return out

    def stats(self) -> dict:
        """Json-safe summary for ``service.stats()["flights"]``."""
        with self._lock:
            ring = list(self._ring)
            recorded, failures = self._recorded, self._failures
        return {
            "recorded": recorded,
            "retained": len(ring),
            "capacity": self.capacity,
            "dropped": recorded - len(ring),
            "failures": failures,
            "last": ring[-1].to_dict() if ring else None,
        }

    # -- forensic dumps ------------------------------------------------------

    def dump(self, path, *, failures_only: bool = False) -> int:
        """Write the retained ring as a JSON list; returns records written."""
        recs = self.records(failures_only=failures_only)
        with open(path, "w") as f:
            json.dump([r.to_dict() for r in recs], f, indent=1)
        return len(recs)

    def dump_failure(self, rec: FlightRecord, directory) -> Optional[str]:
        """Write one failed ticket's record (plus the ring context around
        it) to ``<directory>/flight_fail_<req_id>.json``; returns the path
        (None when the directory cannot be created — a dump must never
        take the service down with it)."""
        try:
            os.makedirs(directory, exist_ok=True)
            path = os.path.join(directory, f"flight_fail_{rec.req_id}.json")
            with open(path, "w") as f:
                json.dump(
                    {
                        "failure": rec.to_dict(),
                        "wallclock": time.time(),
                        "context": [r.to_dict() for r in self.records()[-16:]],
                    },
                    f,
                    indent=1,
                )
            return path
        except OSError:
            return None


#: where failure dumps land when no explicit directory is configured —
#: benchmarks/CI set this so forensic trails ride the artifact upload
DUMP_DIR_ENV = "REPRO_FLIGHT_DUMP_DIR"


def failure_dump_dir(configured: Optional[str]) -> Optional[str]:
    """Resolve the dump directory: explicit config wins, else the
    :data:`DUMP_DIR_ENV` environment override, else None (no dump)."""
    return configured or os.environ.get(DUMP_DIR_ENV) or None
