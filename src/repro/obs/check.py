"""CI trace gate: validate an exported Chrome trace, bound tracing cost.

Run after a traced quickstart/verify has written its trace JSON:

    python -m repro.obs.check trace.json \
        --require parse plan execute verdict \
        --coverage 0.95 --overhead-gate 0.05

Checks, in order:

  1. the trace parses back into spans (export round-trip);
  2. at least one ``session.verify`` root exists, and every ``--require``
     name appears among its *direct* children (the pipeline's top-level
     stages made it into the trace);
  3. each root's direct children cover at least ``--coverage`` of the
    root's wall time (no untraced gaps inside a verify);
  4. with ``--overhead-gate``, a self-contained micro-benchmark verifies
     a small design traced and untraced (best-of-N each) and fails when
     traced wall time exceeds untraced by more than the gate fraction.

Exit status 0 = all gates pass; 1 = any failure (message on stderr).
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.obs.trace import span_coverage, spans_from_chrome

ROOT_SPAN = "session.verify"


def check_trace(data: dict, require: list[str], coverage: float) -> list[str]:
    """Return a list of failure messages (empty = pass)."""
    failures: list[str] = []
    spans = spans_from_chrome(data)
    if not spans:
        return [f"trace contains no spans (required: {ROOT_SPAN})"]
    roots = [s for s in spans if s["name"] == ROOT_SPAN]
    if not roots:
        names = sorted({s["name"] for s in spans})
        return [f"no {ROOT_SPAN!r} root span found (saw: {names})"]
    # result-LRU hits never run plan/execute/verdict; their roots are
    # tagged cached=True and exempt from the full-pipeline span checks
    full_roots = [r for r in roots if not r["attrs"].get("cached")]
    if not full_roots:
        return [f"every {ROOT_SPAN} span was a cache hit — nothing to gate"]
    for root in full_roots:
        kids = [s for s in spans if s["parent_id"] == root["span_id"]]
        kid_names = {s["name"] for s in kids}
        missing = [n for n in require if n not in kid_names]
        if missing:
            failures.append(
                f"{ROOT_SPAN} span {root['span_id']} is missing required "
                f"child span(s) {missing} (has: {sorted(kid_names)})"
            )
        cov = span_coverage(spans, root["span_id"])
        if cov < coverage:
            failures.append(
                f"{ROOT_SPAN} span {root['span_id']} child coverage "
                f"{cov:.1%} below the {coverage:.0%} gate"
            )
    return failures


def measure_overhead(design: str = "csa-16", repeats: int = 3,
                     sample: bool = True) -> dict:
    """Best-of-N traced vs untraced verify wall time on a small design.

    Uses fresh params and distinct designs-by-cache-key so neither arm
    benefits from the other's result cache; plan/jit caches are warmed by
    an untimed run first, so the comparison isolates tracer cost rather
    than compile noise.  Both arms run with the flight recorder active
    (every ``Session`` records flights) and, with ``sample=True``, a live
    :class:`~repro.obs.export.Sampler` over the session registry — so the
    gate bounds the cost of the FULL observability stack, not just spans.
    """
    import os
    import tempfile
    import time

    from repro.api import Session, SessionConfig
    from repro.obs.export import Sampler

    import jax

    from repro.core import gnn

    fam, _, bits = design.partition("-")
    params = gnn.init_params(gnn.GNNConfig(), jax.random.key(0))

    def best(trace: bool) -> float:
        sess = Session(params, SessionConfig(trace=trace))
        kw = dict(dataset=fam, bits=int(bits or 16), verify=False,
                  use_cache=False)
        sess.verify(**kw)  # warm compile/plan caches, untimed
        sampler = None
        if sample:
            fd, path = tempfile.mkstemp(suffix=".jsonl")
            os.close(fd)
            sampler = Sampler(path, sess.obs.metrics, interval_s=0.05).start()
        try:
            t = float("inf")
            for _ in range(repeats):
                t0 = time.perf_counter()
                sess.verify(**kw)
                t = min(t, time.perf_counter() - t0)
        finally:
            if sampler is not None:
                sampler.stop()
                os.unlink(sampler.path)
        return t

    untraced = best(False)
    traced = best(True)
    return {
        "design": design,
        "repeats": repeats,
        "untraced_s": untraced,
        "traced_s": traced,
        "overhead": (traced - untraced) / untraced if untraced > 0 else 0.0,
    }


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("trace", help="Chrome-trace JSON written by --trace")
    p.add_argument(
        "--require",
        nargs="*",
        default=["parse", "plan", "execute", "verdict"],
        help="span names that must appear as direct children of every "
        f"{ROOT_SPAN} root",
    )
    p.add_argument(
        "--coverage",
        type=float,
        default=0.95,
        help="minimum fraction of each root's wall time its children cover",
    )
    p.add_argument(
        "--overhead-gate",
        type=float,
        default=None,
        help="also micro-benchmark traced-vs-untraced verify and fail "
        "when traced overhead exceeds this fraction (e.g. 0.05)",
    )
    p.add_argument(
        "--overhead-design",
        "--design",
        dest="overhead_design",
        default="csa-16",
        help="design for the overhead micro-benchmark",
    )
    p.add_argument(
        "--repeats",
        type=int,
        default=3,
        help="timed verifies per arm of the overhead micro-benchmark "
        "(best-of-N)",
    )
    args = p.parse_args(argv)

    with open(args.trace) as f:
        data = json.load(f)
    failures = check_trace(data, args.require, args.coverage)

    n_spans = len(spans_from_chrome(data))
    print(f"{args.trace}: {n_spans} spans", file=sys.stderr)

    if args.overhead_gate is not None:
        m = measure_overhead(args.overhead_design, repeats=args.repeats)
        print(
            f"overhead on {m['design']} (x{m['repeats']}, flights+sampler "
            f"on): traced {m['traced_s'] * 1e3:.2f} ms "
            f"vs untraced {m['untraced_s'] * 1e3:.2f} ms "
            f"({m['overhead']:+.1%})",
            file=sys.stderr,
        )
        if m["overhead"] > args.overhead_gate:
            failures.append(
                f"traced overhead {m['overhead']:.1%} exceeds the "
                f"{args.overhead_gate:.0%} gate"
            )

    for msg in failures:
        print(f"FAIL: {msg}", file=sys.stderr)
    if not failures:
        print("trace gate: OK", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
