"""Perf-regression sentry: diff fresh BENCH JSONs against blessed baselines.

``benchmarks/run.py --json`` writes one ``BENCH_<suite>.json`` per suite;
until now those were upload-only artifacts with nothing to compare
against, so the perf trajectory across PRs was unobservable.  This module
closes the loop:

    python -m repro.obs.regress BENCH_service.json \
        --baseline benchmarks/baselines/

walks every numeric metric shared by the fresh payload and the committed
baseline, applies a per-metric tolerance rule (runtimes may grow 50%,
throughput may drop 30%, cache hit-rates may sag 5 points, cold-compile
counts must match exactly, total compiles may only shrink), prints a
human table, and exits nonzero naming the first offending metric and its
tolerance — which is exactly the message CI shows on a perf regression.

Environment fencing: payloads carry a ``schema`` version and a ``host``
fingerprint (platform / jax version / device kind — see
:func:`host_info`, stamped by ``benchmarks/run.py``).  A schema mismatch
is a hard failure (the comparison would be meaningless); a *host*
mismatch is a skip-with-notice by default — committed baselines come
from one machine and CI runners are another, and cross-environment
runtime diffs are noise, not signal.  ``--strict-host`` upgrades the
skip to a failure for same-fleet setups.  Counter-equality rules still
run on a host mismatch (they are environment-independent).

Blessing a new baseline after an intentional perf change::

    python -m benchmarks.run --json --suites grouped service partitioned
    python -m repro.obs.regress BENCH_service.json \
        --baseline benchmarks/baselines/ --bless
"""
from __future__ import annotations

import argparse
import fnmatch
import json
import os
import platform
import shutil
import sys
from dataclasses import dataclass
from typing import Optional

#: bump when the BENCH payload layout changes incompatibly; the sentry
#: refuses to diff across schema versions
SCHEMA_VERSION = 2


def host_info() -> dict:
    """Environment fingerprint stamped into every BENCH payload."""
    info = {
        "platform": platform.platform(),
        "machine": platform.machine(),
        "python": platform.python_version(),
        "jax": None,
        "device": None,
    }
    try:
        import jax

        info["jax"] = jax.__version__
        info["device"] = jax.devices()[0].platform
    except Exception:  # noqa: BLE001 — fingerprinting must never fail a bench
        pass
    return info


def hosts_comparable(a: Optional[dict], b: Optional[dict]) -> bool:
    """Timing numbers are only comparable on matching machine+device."""
    if not a or not b:
        return False
    keys = ("machine", "device", "jax")
    return all(a.get(k) == b.get(k) for k in keys)


# ---------------------------------------------------------------------------
# tolerance rules


@dataclass(frozen=True)
class Rule:
    """One tolerance policy, matched against the metric's leaf key."""

    pattern: str          # fnmatch over the path's final component
    kind: str             # max_ratio | min_ratio | floor_abs | equal | ceiling
    tol: float = 0.0
    timing: bool = True   # timing rules are skipped on host mismatch

    def check(self, base: float, fresh: float) -> tuple[bool, str]:
        """(ok, human detail).  ``base`` is the blessed value."""
        if self.kind == "max_ratio":
            # fresh may exceed base by at most tol (0.5 -> 1.5x allowed)
            limit = base * (1.0 + self.tol)
            return fresh <= limit or base == 0.0, (
                f"fresh {fresh:.6g} vs base {base:.6g} "
                f"(allowed <= {limit:.6g}, +{self.tol:.0%})"
            )
        if self.kind == "min_ratio":
            limit = base * (1.0 - self.tol)
            return fresh >= limit, (
                f"fresh {fresh:.6g} vs base {base:.6g} "
                f"(allowed >= {limit:.6g}, -{self.tol:.0%})"
            )
        if self.kind == "floor_abs":
            limit = base - self.tol
            return fresh >= limit, (
                f"fresh {fresh:.6g} vs base {base:.6g} "
                f"(allowed >= {limit:.6g}, floor -{self.tol:g})"
            )
        if self.kind == "equal":
            return fresh == base, f"fresh {fresh:g} vs base {base:g} (must be equal)"
        if self.kind == "ceiling":
            return fresh <= base, f"fresh {fresh:g} vs base {base:g} (must not grow)"
        raise ValueError(f"unknown rule kind {self.kind!r}")


#: first match wins; deliberately NO equality rules on timing-racy
#: counters (``coalesced`` varies with scheduler interleaving)
DEFAULT_RULES = (
    Rule("cold_compiles", "equal", timing=False),
    Rule("compiles", "ceiling", timing=False),
    Rule("*hit_rate", "floor_abs", 0.05, timing=False),
    Rule("*accuracy*", "floor_abs", 0.01, timing=False),
    Rule("req_per_s", "min_ratio", 0.30),
    Rule("*throughput*", "min_ratio", 0.30),
    Rule("runtime_s", "max_ratio", 0.50),
    Rule("*wall_s", "max_ratio", 0.50),
    Rule("*_ms", "max_ratio", 0.50),
    Rule("*_s", "max_ratio", 0.50),
)


def rule_for(key: str, rules=DEFAULT_RULES) -> Optional[Rule]:
    leaf = key.rsplit(".", 1)[-1]
    for r in rules:
        if fnmatch.fnmatch(leaf, r.pattern):
            return r
    return None


# ---------------------------------------------------------------------------
# payload flattening


_SKIP_KEYS = {"schema", "host", "error", "title"}


def flatten(payload: dict, prefix: str = "") -> dict[str, float]:
    """Dotted-path -> numeric value over the whole payload.

    Lists of row-dicts (the ``tables`` section) are keyed by each row's
    first value so rows pair up across runs regardless of order; plain
    lists are indexed.  Non-numeric leaves are dropped — the sentry only
    reasons about numbers.
    """
    out: dict[str, float] = {}
    for key, val in payload.items():
        if not prefix and key in _SKIP_KEYS:
            continue
        path = f"{prefix}{key}"
        if isinstance(val, bool):
            out[path] = float(val)
        elif isinstance(val, (int, float)):
            out[path] = float(val)
        elif isinstance(val, dict):
            out.update(flatten(val, prefix=path + "."))
        elif isinstance(val, list):
            for i, item in enumerate(val):
                if isinstance(item, dict):
                    tag = None
                    for v in item.values():
                        if isinstance(v, str):
                            tag = v
                            break
                    sub = tag if tag is not None else str(i)
                    out.update(flatten(item, prefix=f"{path}.{sub}."))
                elif isinstance(item, (int, float)) and not isinstance(item, bool):
                    out[f"{path}.{i}"] = float(item)
    return out


# ---------------------------------------------------------------------------
# comparison


@dataclass(frozen=True)
class Finding:
    key: str
    rule: Rule
    base: float
    fresh: float
    ok: bool
    detail: str


@dataclass
class Comparison:
    suite: str
    findings: list
    skipped_timing: bool = False      # host mismatch -> timing rules idle
    note: str = ""

    @property
    def regressions(self) -> list:
        return [f for f in self.findings if not f.ok]

    @property
    def ok(self) -> bool:
        return not self.regressions


def compare(fresh: dict, base: dict, *, suite: str = "?",
            rules=DEFAULT_RULES, strict_host: bool = False) -> Comparison:
    """Diff two BENCH payloads; raises ValueError on schema mismatch."""
    fs, bs = fresh.get("schema"), base.get("schema")
    if fs != bs:
        raise ValueError(
            f"schema mismatch for {suite}: fresh={fs!r} baseline={bs!r} "
            f"— re-bless the baseline (sentry schema {SCHEMA_VERSION})"
        )
    same_host = hosts_comparable(fresh.get("host"), base.get("host"))
    if not same_host and strict_host:
        raise ValueError(
            f"host mismatch for {suite}: fresh={fresh.get('host')} "
            f"baseline={base.get('host')} (--strict-host)"
        )
    f_flat, b_flat = flatten(fresh), flatten(base)
    findings: list[Finding] = []
    for key in sorted(f_flat.keys() & b_flat.keys()):
        rule = rule_for(key, rules)
        if rule is None:
            continue
        if rule.timing and not same_host:
            continue
        ok, detail = rule.check(b_flat[key], f_flat[key])
        findings.append(Finding(key, rule, b_flat[key], f_flat[key], ok, detail))
    note = "" if same_host else (
        "host differs from baseline; timing rules skipped "
        "(counter rules still enforced)"
    )
    return Comparison(suite=suite, findings=findings,
                      skipped_timing=not same_host, note=note)


def render_table(cmp: Comparison) -> str:
    rows = [("metric", "rule", "baseline", "fresh", "verdict")]
    for f in cmp.findings:
        rows.append((
            f.key,
            f"{f.rule.kind}({f.rule.tol:g})" if f.rule.tol else f.rule.kind,
            f"{f.base:.6g}",
            f"{f.fresh:.6g}",
            "ok" if f.ok else "REGRESSION",
        ))
    widths = [max(len(r[i]) for r in rows) for i in range(5)]
    lines = [f"== regress: {cmp.suite} =="]
    if cmp.note:
        lines.append(f"   note: {cmp.note}")
    for j, r in enumerate(rows):
        lines.append("  ".join(c.ljust(w) for c, w in zip(r, widths)))
        if j == 0:
            lines.append("  ".join("-" * w for w in widths))
    n_bad = len(cmp.regressions)
    lines.append(
        f"{len(cmp.findings)} metrics checked, {n_bad} regression(s)"
        + ("" if cmp.ok else f" — first: {cmp.regressions[0].key} "
           f"[{cmp.regressions[0].detail}]")
    )
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# CLI


def _baseline_path(baseline: str, fresh_path: str) -> str:
    # baseline files are always *.json; anything else is a directory
    # (which --bless may still need to create)
    if os.path.isdir(baseline) or not baseline.endswith(".json"):
        return os.path.join(baseline, os.path.basename(fresh_path))
    return baseline


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.regress",
        description="Gate BENCH_<suite>.json files against blessed baselines.",
    )
    ap.add_argument("fresh", nargs="+",
                    help="freshly generated BENCH_<suite>.json file(s)")
    ap.add_argument("--baseline", required=True,
                    help="baseline file, or directory holding same-named files")
    ap.add_argument("--bless", action="store_true",
                    help="copy the fresh payload(s) over the baseline and exit")
    ap.add_argument("--strict-host", action="store_true",
                    help="fail (instead of skipping timing rules) on host mismatch")
    args = ap.parse_args(argv)

    rc = 0
    for fresh_path in args.fresh:
        base_path = _baseline_path(args.baseline, fresh_path)
        suite = os.path.basename(fresh_path)
        if args.bless:
            os.makedirs(os.path.dirname(base_path) or ".", exist_ok=True)
            shutil.copyfile(fresh_path, base_path)
            print(f"blessed {fresh_path} -> {base_path}")
            continue
        if not os.path.exists(base_path):
            print(f"== regress: {suite} ==\n   no baseline at {base_path}; "
                  f"skipping (bless one with --bless)")
            continue
        with open(fresh_path) as f:
            fresh = json.load(f)
        with open(base_path) as f:
            base = json.load(f)
        try:
            cmp = compare(fresh, base, suite=suite,
                          strict_host=args.strict_host)
        except ValueError as e:
            print(f"== regress: {suite} ==\n   ERROR: {e}")
            rc = 2
            continue
        print(render_table(cmp))
        if not cmp.ok:
            rc = 1
        if not fresh.get("ok", True):
            print(f"   suite itself reported ok=false "
                  f"({fresh.get('error') or 'no error recorded'})")
            rc = rc or 1
    return rc


if __name__ == "__main__":
    sys.exit(main())
