"""repro.obs — unified tracing + metrics spine.

Public surface:

  * tracing: :class:`Tracer`, :func:`span`, :func:`current_tracer`,
    :data:`NULL_TRACER`, :class:`TraceHandle`, plus the Chrome-trace
    round-trip helpers :func:`spans_from_chrome` / :func:`span_coverage`;
  * metrics: :class:`MetricsRegistry`, the process-wide :data:`REGISTRY`,
    :class:`CounterGroup` (the ``PROBE`` bridge), :func:`fold_into`;
  * reporting: :class:`Report` (built by ``Session.report()``);
  * forensics: :class:`FlightRecord` / :class:`FlightRecorder` (one
    structured record per service ticket, bounded ring);
  * export: :func:`render_prometheus` / :func:`parse_prometheus`,
    :class:`Sampler` (JSONL time series), :func:`start_metrics_server`
    (``/metrics`` + ``/stats`` scrape endpoint).
"""
from repro.obs.export import (
    MetricsServer,
    Sampler,
    parse_prometheus,
    render_prometheus,
    start_metrics_server,
)
from repro.obs.flight import FlightRecord, FlightRecorder, record_from_marks
from repro.obs.metrics import (
    Counter,
    CounterGroup,
    Gauge,
    Histogram,
    MetricsRegistry,
    REGISTRY,
    fold_into,
)
from repro.obs.report import Report
from repro.obs.trace import (
    NULL_TRACER,
    NullTracer,
    Span,
    TraceHandle,
    Tracer,
    current_tracer,
    span,
    span_coverage,
    spans_from_chrome,
)

__all__ = [
    "Counter",
    "CounterGroup",
    "FlightRecord",
    "FlightRecorder",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "MetricsServer",
    "NULL_TRACER",
    "NullTracer",
    "REGISTRY",
    "Report",
    "Sampler",
    "Span",
    "TraceHandle",
    "Tracer",
    "current_tracer",
    "fold_into",
    "parse_prometheus",
    "record_from_marks",
    "render_prometheus",
    "span",
    "span_coverage",
    "spans_from_chrome",
    "start_metrics_server",
]
