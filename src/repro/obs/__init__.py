"""repro.obs — unified tracing + metrics spine.

Public surface:

  * tracing: :class:`Tracer`, :func:`span`, :func:`current_tracer`,
    :data:`NULL_TRACER`, :class:`TraceHandle`, plus the Chrome-trace
    round-trip helpers :func:`spans_from_chrome` / :func:`span_coverage`;
  * metrics: :class:`MetricsRegistry`, the process-wide :data:`REGISTRY`,
    :class:`CounterGroup` (the ``PROBE`` bridge), :func:`fold_into`;
  * reporting: :class:`Report` (built by ``Session.report()``).
"""
from repro.obs.metrics import (
    Counter,
    CounterGroup,
    Gauge,
    Histogram,
    MetricsRegistry,
    REGISTRY,
    fold_into,
)
from repro.obs.report import Report
from repro.obs.trace import (
    NULL_TRACER,
    NullTracer,
    Span,
    TraceHandle,
    Tracer,
    current_tracer,
    span,
    span_coverage,
    spans_from_chrome,
)

__all__ = [
    "Counter",
    "CounterGroup",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NULL_TRACER",
    "NullTracer",
    "REGISTRY",
    "Report",
    "Span",
    "TraceHandle",
    "Tracer",
    "current_tracer",
    "fold_into",
    "span",
    "span_coverage",
    "spans_from_chrome",
]
