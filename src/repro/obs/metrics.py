"""Metrics registry: counters, gauges, histograms — one namespace.

Before ``repro.obs`` every layer kept its own ad-hoc numbers: trace-time
kernel counters in ``kernels/groot_spmm.PROBE``, per-runner compile
counts in ``service/scheduler.BucketRunner``, streaming probes in
``exec/stream.StreamStats``, cache stats on three different LRU classes.
None shared a registry or an export format, so "where did the time go"
needed four imports and hand-stitched dicts.

:class:`MetricsRegistry` is the one sink.  Instruments are get-or-create
by dotted name (``registry.counter("exec.bytes_h2d")``), thread-safe,
and cheap enough for trace-time probe increments (a counter ``inc`` is
one lock-free int add under CPython's atomic int semantics isn't
guaranteed, so we take a per-instrument lock — still nanoseconds against
the kernel walks they count).  Two registries matter in practice:

  * :data:`REGISTRY` — the process-wide instance.  The kernel ``PROBE``
    counters live here (as a :class:`CounterGroup` view, so the historic
    ``PROBE["weight_gathers"] += 1`` dict idiom keeps working), as do the
    io/exec/gnn counters that are inherently process-global (jit traces,
    plan builds, staged bytes).
  * per-``Session`` instances — route counts, per-stage latency
    histograms, folded executor stats — so two live sessions never read
    each other's numbers (``Session.report()`` isolation).

``snapshot()``/``delta()`` produce plain json-safe dicts — the building
blocks of :class:`repro.obs.report.Report`.
"""
from __future__ import annotations

import threading
from collections import deque
from collections.abc import MutableMapping
from typing import Iterable, Optional


class Counter:
    """Monotonic-by-convention integer (``set`` exists for probe resets)."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._value = 0
        self._lock = threading.Lock()

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._value += n

    def set(self, v: int) -> None:
        with self._lock:
            self._value = int(v)

    @property
    def value(self) -> int:
        return self._value


class Gauge:
    """Last-set value plus the high-water mark (queue depths, pool sizes)."""

    __slots__ = ("name", "_value", "_max", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._value = 0.0
        self._max = 0.0
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        with self._lock:
            self._value = v
            if v > self._max:
                self._max = v

    @property
    def value(self) -> float:
        return self._value

    @property
    def max(self) -> float:
        return self._max


class Histogram:
    """Streaming summary: count/sum/min/max plus percentile estimates
    from a bounded reservoir of the most recent observations (plenty for
    per-request latency distributions; O(1) memory)."""

    __slots__ = ("name", "count", "total", "_min", "_max", "_recent", "_lock")

    RESERVOIR = 512

    def __init__(self, name: str):
        self.name = name
        self.count = 0
        self.total = 0.0
        self._min = float("inf")
        self._max = float("-inf")
        self._recent: deque = deque(maxlen=self.RESERVOIR)
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        with self._lock:
            self.count += 1
            self.total += v
            if v < self._min:
                self._min = v
            if v > self._max:
                self._max = v
            self._recent.append(v)

    def summary(self) -> dict:
        with self._lock:
            if not self.count:
                return {"count": 0, "sum": 0.0}
            vals = sorted(self._recent)
            q = lambda p: vals[min(len(vals) - 1, int(p * (len(vals) - 1) + 0.5))]
            return {
                "count": self.count,
                "sum": self.total,
                "mean": self.total / self.count,
                "min": self._min,
                "max": self._max,
                "p50": q(0.50),
                "p95": q(0.95),
                "p99": q(0.99),
            }


class MetricsRegistry:
    """Get-or-create namespace of instruments; snapshots are plain dicts."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        with self._lock:
            c = self._counters.get(name)
            if c is None:
                c = self._counters[name] = Counter(name)
            return c

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            g = self._gauges.get(name)
            if g is None:
                g = self._gauges[name] = Gauge(name)
            return g

    def histogram(self, name: str) -> Histogram:
        with self._lock:
            h = self._histograms.get(name)
            if h is None:
                h = self._histograms[name] = Histogram(name)
            return h

    # -- export ---------------------------------------------------------------

    def counters(self, prefix: str = "") -> dict[str, int]:
        with self._lock:
            items = list(self._counters.items())
        return {k: c.value for k, c in items if k.startswith(prefix)}

    def snapshot(self, prefix: str = "") -> dict:
        """Json-safe point-in-time view of every instrument under ``prefix``."""
        with self._lock:
            counters = list(self._counters.items())
            gauges = list(self._gauges.items())
            hists = list(self._histograms.items())
        return {
            "counters": {k: c.value for k, c in counters if k.startswith(prefix)},
            "gauges": {
                k: {"value": g.value, "max": g.max}
                for k, g in gauges
                if k.startswith(prefix)
            },
            "histograms": {
                k: h.summary() for k, h in hists if k.startswith(prefix)
            },
        }

    def delta(self, before: dict, prefix: str = "") -> dict[str, int]:
        """Counter movement since a prior ``snapshot()`` (gauges and
        histograms are not differenced — read them from the snapshot)."""
        base = before.get("counters", before) if isinstance(before, dict) else {}
        return {
            k: v - base.get(k, 0)
            for k, v in self.counters(prefix).items()
        }


#: The process-wide registry: kernel probes, io/exec/gnn counters, and
#: anything else inherently global (jit traces happen per process, not
#: per session).  Per-session numbers live on ``Session.obs.metrics``.
REGISTRY = MetricsRegistry()


class CounterGroup(MutableMapping):
    """Dict-shaped view over a set of registry counters.

    The backwards-compatibility bridge for ``kernels.groot_spmm.PROBE``:
    code (and tests) written against the historic probe dict —
    ``PROBE["weight_gathers"] += 1``, ``dict(PROBE)``, iteration in
    ``reset_probe`` — keeps working unchanged while every increment
    lands in the shared registry under ``<prefix>.<key>``.
    """

    def __init__(self, registry: MetricsRegistry, prefix: str,
                 keys: Iterable[str]):
        self._counters = {k: registry.counter(f"{prefix}.{k}") for k in keys}

    def __getitem__(self, key: str) -> int:
        return self._counters[key].value

    def __setitem__(self, key: str, value: int) -> None:
        self._counters[key].set(value)

    def __delitem__(self, key: str) -> None:
        raise TypeError("CounterGroup keys are fixed at construction")

    def __iter__(self):
        return iter(self._counters)

    def __len__(self) -> int:
        return len(self._counters)

    def __repr__(self) -> str:
        return f"CounterGroup({dict(self)})"


def fold_into(registry: MetricsRegistry, prefix: str, stats: dict,
              *, seconds_suffix: str = "_s") -> None:
    """Accumulate a plain stats dict into a registry: ints add to
    counters, float ``*_s`` timings are observed into histograms (the
    bridge that folds one run's ``exec_stats`` into a session report)."""
    for k, v in stats.items():
        if isinstance(v, bool) or not isinstance(v, (int, float)):
            continue
        name = f"{prefix}.{k}"
        if isinstance(v, float) or k.endswith(seconds_suffix):
            registry.histogram(name).observe(float(v))
        else:
            registry.counter(name).inc(int(v))
