"""The `Report` snapshot: one json-safe answer to "where did the time go".

A :class:`Report` is assembled by ``Session.report()`` (and embedded by
``benchmarks/run.py`` into each ``BENCH_<suite>.json``).  It stitches the
previously scattered stats surfaces into one stable dict:

  * ``session``  — the session's own registry: route counts, per-stage
    latency histograms, folded executor stats;
  * ``process``  — counter movement in the process-wide registry since
    the session was created (kernel probes, jit traces, staged bytes);
  * ``plan_cache`` / ``results_cache`` — hit/miss/eviction rates;
  * ``scheduler`` — ``SchedulerStats`` when the service engine is live;
  * ``exec``     — accumulated streaming-executor ``exec_stats``;
  * ``spans``    — the tracer's per-name wall-time summary when tracing
    was on;
  * ``process_gauges`` — process-registry gauges with their high-water
    marks (peak queue depth / slot occupancy);
  * ``memory_model``   — modeled vs actual packed-peak bytes + drift
    ratio, validating the model that drives ``choose_k``;
  * ``flights``  — flight-recorder summary when any ticket was recorded.

``to_dict()`` drops absent sections and sorts keys, so serialized
reports diff cleanly across runs.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional


def _sorted(obj):
    """Recursively sort dict keys (stable serialization)."""
    if isinstance(obj, dict):
        return {k: _sorted(obj[k]) for k in sorted(obj, key=str)}
    if isinstance(obj, (list, tuple)):
        return [_sorted(v) for v in obj]
    return obj


@dataclasses.dataclass
class Report:
    """Point-in-time observability snapshot (json-safe once ``to_dict``)."""

    created: str
    session: dict
    process: dict
    plan_cache: Optional[dict] = None
    results_cache: Optional[dict] = None
    scheduler: Optional[dict] = None
    exec: Optional[dict] = None
    spans: Optional[dict] = None
    #: process-registry gauges as {name: {value, max}} — the high-water
    #: marks the counter-only ``process`` delta cannot carry
    process_gauges: Optional[dict] = None
    #: model-vs-actual packed-peak accounting ({modeled_peak_bytes,
    #: actual_peak_bytes, drift}) validating the choose_k memory model
    memory_model: Optional[dict] = None
    #: flight-recorder summary (recorded/retained/failures + last record)
    flights: Optional[dict] = None

    def to_dict(self) -> dict:
        out = {"created": self.created}
        for field in (
            "session",
            "process",
            "process_gauges",
            "memory_model",
            "flights",
            "plan_cache",
            "results_cache",
            "scheduler",
            "exec",
            "spans",
        ):
            v = getattr(self, field)
            if v is not None:
                out[field] = _sorted(v)
        return out

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent, default=str)

    def __repr__(self) -> str:
        counters = self.session.get("counters", {}) if self.session else {}
        return (
            f"Report(created={self.created!r}, "
            f"verifies={counters.get('session.verifies', 0)}, "
            f"sections={[k for k in self.to_dict() if k != 'created']})"
        )
