"""Span tracer with Chrome-trace export.

One :class:`Tracer` records a forest of timed spans — nested via a
per-thread stack, joinable across threads (the ``exec/stream.py``
prefetch thread parents its pack spans under the consumer's stream span
via :meth:`Tracer.adopt`) — and exports the standard Chrome trace-event
JSON (``chrome://tracing`` / Perfetto "traceEvents" format).

Instrumented modules never hold a tracer: they call the module-level
:func:`span`, which resolves the *current* tracer from a thread-local
set by :meth:`Tracer.activate`.  When nothing is active the resolution
returns :data:`NULL_TRACER`, whose ``span()`` hands back one shared
no-op context manager — the disabled path costs two attribute lookups
and an empty ``with``, so kernels, the prefetch loop, and the service
workers pay effectively nothing unless a session (or benchmark) opted
in.  That is the one-flag gate: ``SessionConfig(trace=True)`` builds a
real tracer and activates it around each ``verify``; everything else in
the stack is permanently instrumented.

    tracer = Tracer()
    with tracer.activate():
        with span("parse"):
            ...
    tracer.save("trace.json")           # chrome://tracing-loadable
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
from typing import Optional

_ACTIVE = threading.local()           # .tracer: the thread's current Tracer


@dataclasses.dataclass(frozen=True)
class Span:
    """One finished span (times are ``perf_counter`` seconds)."""

    span_id: int
    parent_id: Optional[int]
    name: str
    t0: float
    t1: float
    tid: int
    thread: str
    attrs: dict

    @property
    def duration(self) -> float:
        return self.t1 - self.t0


class _NullSpan:
    """The shared no-op span context (also serves as adopt/activate ctx)."""

    __slots__ = ()
    span_id = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs) -> None:
        pass


_NULL_SPAN = _NullSpan()


class NullTracer:
    """Absent-tracer behaviour: every operation is a no-op."""

    enabled = False

    def span(self, name: str, **attrs):
        return _NULL_SPAN

    def current_id(self) -> Optional[int]:
        return None

    def adopt(self, parent_id: Optional[int]):
        return _NULL_SPAN

    def activate(self):
        return _NULL_SPAN


NULL_TRACER = NullTracer()


def current_tracer():
    """The thread's active tracer (:data:`NULL_TRACER` when none)."""
    return getattr(_ACTIVE, "tracer", None) or NULL_TRACER


def span(name: str, **attrs):
    """Open a span on the current tracer (no-op when none is active)."""
    return current_tracer().span(name, **attrs)


class _SpanCtx:
    """Context manager recording one span on enter/exit."""

    __slots__ = ("_tracer", "name", "attrs", "span_id", "parent_id", "_t0")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs
        self.span_id = None
        self.parent_id = None
        self._t0 = 0.0

    def set(self, **attrs) -> None:
        """Attach attributes mid-span (e.g. the routing mode, an accuracy)."""
        self.attrs.update(attrs)

    def __enter__(self):
        tr = self._tracer
        stack = tr._stack()
        self.parent_id = stack[-1] if stack else None
        self.span_id = tr._new_id()
        stack.append(self.span_id)
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter()
        tr = self._tracer
        stack = tr._stack()
        if stack and stack[-1] == self.span_id:
            stack.pop()
        th = threading.current_thread()
        tr._record(
            Span(
                span_id=self.span_id,
                parent_id=self.parent_id,
                name=self.name,
                t0=self._t0,
                t1=t1,
                tid=th.ident or 0,
                thread=th.name,
                attrs=self.attrs,
            )
        )
        return False


class _Activate:
    """Sets/restores the thread's current tracer (optionally seeding a
    cross-thread parent for :meth:`Tracer.adopt`)."""

    __slots__ = ("_tracer", "_parent", "_prev_tracer", "_prev_stack")

    def __init__(self, tracer: "Tracer", parent_id: Optional[int] = None):
        self._tracer = tracer
        self._parent = parent_id

    def __enter__(self):
        self._prev_tracer = getattr(_ACTIVE, "tracer", None)
        _ACTIVE.tracer = self._tracer
        if self._parent is not None:
            # a worker thread joining under a span that lives on another
            # thread: seed this thread's stack so nesting parents there
            tls = self._tracer._tls
            self._prev_stack = getattr(tls, "stack", None)
            tls.stack = [self._parent]
        else:
            self._prev_stack = None
        return self._tracer

    def __exit__(self, *exc):
        _ACTIVE.tracer = self._prev_tracer
        if self._parent is not None:
            self._tracer._tls.stack = self._prev_stack or []
        return False


class Tracer:
    """Thread-safe span recorder with Chrome trace-event export."""

    enabled = True

    def __init__(self, name: str = "repro"):
        self.name = name
        self.pid = os.getpid()
        #: perf_counter/epoch pair taken together so exported timestamps
        #: can be anchored to wall-clock time
        self.epoch_perf = time.perf_counter()
        self.epoch_wall = time.time()
        self._lock = threading.Lock()
        self._spans: list[Span] = []
        self._next = 0
        self._tls = threading.local()

    # -- recording ------------------------------------------------------------

    def _stack(self) -> list:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def _new_id(self) -> int:
        with self._lock:
            self._next += 1
            return self._next

    def _record(self, s: Span) -> None:
        with self._lock:
            self._spans.append(s)

    def span(self, name: str, **attrs) -> _SpanCtx:
        return _SpanCtx(self, name, attrs)

    def current_id(self) -> Optional[int]:
        st = getattr(self._tls, "stack", None)
        return st[-1] if st else None

    def activate(self) -> _Activate:
        """Make this the current tracer for the calling thread."""
        return _Activate(self)

    def adopt(self, parent_id: Optional[int]) -> _Activate:
        """Activate on a *worker* thread, parenting new spans under
        ``parent_id`` (captured on the owning thread via
        :meth:`current_id`) — how the prefetch thread's pack spans nest
        under the consumer's stream span."""
        return _Activate(self, parent_id=parent_id)

    # -- queries --------------------------------------------------------------

    def spans(self) -> list[Span]:
        with self._lock:
            return list(self._spans)

    def subtree(self, root_id: int) -> list[Span]:
        """``root_id``'s span plus every transitive child."""
        spans = self.spans()
        children: dict[Optional[int], list[Span]] = {}
        for s in spans:
            children.setdefault(s.parent_id, []).append(s)
        out, todo = [], [root_id]
        by_id = {s.span_id: s for s in spans}
        while todo:
            sid = todo.pop()
            if sid in by_id:
                out.append(by_id[sid])
            todo.extend(c.span_id for c in children.get(sid, ()))
        return out

    def summary(self) -> dict:
        """Per-span-name wall-time totals — the "where did the time go"
        table a :class:`~repro.obs.report.Report` embeds."""
        out: dict[str, dict] = {}
        for s in self.spans():
            row = out.setdefault(s.name, {"count": 0, "total_s": 0.0})
            row["count"] += 1
            row["total_s"] += s.duration
        return out

    # -- export ---------------------------------------------------------------

    def to_chrome(self, spans: Optional[list[Span]] = None) -> dict:
        """Chrome trace-event JSON (``chrome://tracing`` / Perfetto)."""
        spans = self.spans() if spans is None else spans
        events = []
        tids = {}
        for s in spans:
            tids.setdefault(s.tid, s.thread)
            events.append(
                {
                    "name": s.name,
                    "ph": "X",
                    "pid": self.pid,
                    "tid": s.tid,
                    "ts": (s.t0 - self.epoch_perf) * 1e6,
                    "dur": s.duration * 1e6,
                    "args": {
                        **s.attrs,
                        "span_id": s.span_id,
                        "parent_id": s.parent_id,
                    },
                }
            )
        for tid, name in tids.items():
            events.append(
                {
                    "name": "thread_name",
                    "ph": "M",
                    "pid": self.pid,
                    "tid": tid,
                    "args": {"name": name},
                }
            )
        return {
            "traceEvents": events,
            "displayTimeUnit": "ms",
            "otherData": {
                "tracer": self.name,
                "epoch_wall": self.epoch_wall,
            },
        }

    def save(self, path, spans: Optional[list[Span]] = None) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome(spans), f, indent=1)


class TraceHandle:
    """One verify's span subtree — the per-result trace view
    (``SessionResult.trace`` / ``PipelineResult.trace``)."""

    def __init__(self, tracer: Tracer, root_id: int):
        self.tracer = tracer
        self.root_id = root_id

    def spans(self) -> list[Span]:
        return self.tracer.subtree(self.root_id)

    def root(self) -> Optional[Span]:
        for s in self.spans():
            if s.span_id == self.root_id:
                return s
        return None

    def coverage(self) -> float:
        """Fraction of the root span's wall time covered by its direct
        children (the ≥ 95% acceptance gate: un-spanned gaps inside a
        traced verify must stay under 5%)."""
        return span_coverage(self.spans(), self.root_id)

    def to_chrome(self) -> dict:
        return self.tracer.to_chrome(self.spans())

    def save(self, path) -> None:
        self.tracer.save(path, self.spans())


def span_coverage(spans: list, root_id: int) -> float:
    """Union of direct-child intervals, clipped to the root, over the
    root's duration.  ``spans`` accepts :class:`Span`s or the plain
    dicts :func:`spans_from_chrome` yields."""
    get = lambda s, k: getattr(s, k, None) if not isinstance(s, dict) else s[k]
    root = next((s for s in spans if get(s, "span_id") == root_id), None)
    if root is None:
        return 0.0
    r0, r1 = get(root, "t0"), get(root, "t1")
    if r1 <= r0:
        return 1.0
    ivals = sorted(
        (max(get(s, "t0"), r0), min(get(s, "t1"), r1))
        for s in spans
        if get(s, "parent_id") == root_id
    )
    covered, cur0, cur1 = 0.0, None, None
    for a, b in ivals:
        if b <= a:
            continue
        if cur1 is None or a > cur1:
            if cur1 is not None:
                covered += cur1 - cur0
            cur0, cur1 = a, b
        else:
            cur1 = max(cur1, b)
    if cur1 is not None:
        covered += cur1 - cur0
    return covered / (r1 - r0)


def spans_from_chrome(data: dict) -> list[dict]:
    """Parse exported Chrome trace JSON back into span dicts (keys:
    ``name/span_id/parent_id/t0/t1/tid/attrs``) — the export round-trip
    used by the CI trace gate and ``tests/test_obs.py``."""
    out = []
    for ev in data.get("traceEvents", []):
        if ev.get("ph") != "X":
            continue
        args = dict(ev.get("args", {}))
        sid = args.pop("span_id", None)
        pid = args.pop("parent_id", None)
        t0 = ev["ts"] / 1e6
        out.append(
            {
                "name": ev["name"],
                "span_id": sid,
                "parent_id": pid,
                "t0": t0,
                "t1": t0 + ev.get("dur", 0) / 1e6,
                "tid": ev.get("tid"),
                "attrs": args,
            }
        )
    return out
