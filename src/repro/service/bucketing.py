"""Shape buckets: pad every (sub)graph to power-of-two (nodes, edges).

jit recompiles per distinct array shape — per-graph shapes would make a
verification service recompile the GNN for every submitted design.
Bucketing quantises shapes: a request's subgraphs land in the pow-2
bucket that fits them, and every bucket maps to exactly one compiled
executable.  ``pack_batch`` additionally packs up to ``capacity`` items
of the same bucket into one disjoint-union device graph (fixed slot
layout), so a batch of same-bucket subgraphs is a single device call
with a single static shape.

Padding preserves exact numerics for real rows — see the contract in
``repro.kernels.ops`` (zero features on padding rows, padding edges
self-looped on each slot's dummy row).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.pipeline import PreparedDesign
from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class BucketShape:
    """One compiled-shape equivalence class: (slot nodes, slot edges)."""

    n_pad: int
    e_pad: int

    def total(self, capacity: int) -> tuple[int, int]:
        return capacity * self.n_pad, capacity * self.e_pad


@dataclasses.dataclass
class WorkItem:
    """One device-sized unit of work: a whole graph or one partition."""

    req_id: int
    part_index: int
    feats: np.ndarray             # (num_nodes, F) — includes halo rows
    edge_src: np.ndarray
    edge_dst: np.ndarray
    edge_inv: Optional[np.ndarray]
    edge_slot: Optional[np.ndarray]
    num_core: int                 # predictions are read back for these rows
    global_ids: np.ndarray        # local row -> request-graph node id

    @property
    def num_nodes(self) -> int:
        return int(self.feats.shape[0])

    @property
    def num_edges(self) -> int:
        return int(self.edge_src.shape[0])

    def bucket(self, *, min_nodes: int = 64, min_edges: int = 128) -> BucketShape:
        n_pad, e_pad = ops.padded_shape(
            self.num_nodes, self.num_edges, min_nodes=min_nodes, min_edges=min_edges
        )
        return BucketShape(n_pad, e_pad)


def item_from_subgraph(
    req_id: int, part_index: int, sg, features: np.ndarray
) -> WorkItem:
    """One partition as a work item: gathers (stages) its feature rows.

    The single Subgraph->WorkItem mapping shared by the service prepare
    path and the streaming executor's packer — the staging contract
    (float32, contiguous, halo rows included) lives here only.
    """
    return WorkItem(
        req_id=req_id,
        part_index=part_index,
        feats=np.ascontiguousarray(features[sg.global_ids], dtype=np.float32),
        edge_src=sg.edge_src,
        edge_dst=sg.edge_dst,
        edge_inv=sg.edge_inv,
        edge_slot=sg.edge_slot,
        num_core=sg.num_core,
        global_ids=sg.global_ids,
    )


def items_from_prepared(req_id: int, prep: PreparedDesign) -> list[WorkItem]:
    """Split a prepared request into schedulable work items."""
    if prep.subgraphs is None:
        g = prep.graph
        return [
            WorkItem(
                req_id=req_id,
                part_index=0,
                feats=prep.feats,
                edge_src=g.edge_src,
                edge_dst=g.edge_dst,
                edge_inv=g.edge_inv,
                edge_slot=g.edge_slot,
                num_core=g.num_nodes,
                global_ids=np.arange(g.num_nodes, dtype=np.int64),
            )
        ]
    return [
        item_from_subgraph(req_id, i, sg, prep.feats)
        for i, sg in enumerate(prep.subgraphs)
    ]


def dummy_item(n_feat: int) -> WorkItem:
    """Minimal valid work item (2 nodes, 1 edge) for compile-ahead warmup.

    ``pack_batch`` pads it out to any target :class:`BucketShape`, so one
    dummy per bucket is enough to trigger that bucket's jit trace without
    synthesising a real design of the right size.
    """
    return WorkItem(
        req_id=-1,
        part_index=0,
        feats=np.zeros((2, n_feat), dtype=np.float32),
        edge_src=np.array([0], dtype=np.int32),
        edge_dst=np.array([1], dtype=np.int32),
        edge_inv=np.zeros(1, dtype=bool),
        edge_slot=np.zeros(1, dtype=np.uint8),
        num_core=2,
        global_ids=np.arange(2, dtype=np.int64),
    )


def pack_batch(items: list[WorkItem], shape: BucketShape, capacity: int) -> dict:
    """Disjoint-union pack of <= ``capacity`` same-bucket items.

    Slot ``i`` owns node rows [i*n_pad, (i+1)*n_pad); unused slots are
    all-padding.  The resulting arrays have the bucket's canonical
    shapes regardless of how many items are present — one jit signature
    per (bucket, capacity).
    """
    assert 0 < len(items) <= capacity
    n_pad, e_pad = shape.n_pad, shape.e_pad
    n_feat = items[0].feats.shape[1]
    x = np.zeros((capacity * n_pad, n_feat), dtype=np.float32)
    src = np.empty(capacity * e_pad, dtype=np.int32)
    dst = np.empty(capacity * e_pad, dtype=np.int32)
    inv = np.zeros(capacity * e_pad, dtype=bool)
    slot = np.zeros(capacity * e_pad, dtype=np.uint8)
    for i in range(capacity):
        n0, e0 = i * n_pad, i * e_pad
        if i < len(items):
            it = items[i]
            x[n0 : n0 + it.num_nodes] = it.feats
            s, d, iv, sl = ops.pad_graph_arrays(
                it.edge_src, it.edge_dst, it.edge_inv, it.edge_slot,
                it.num_nodes, n_pad, e_pad,
            )
            src[e0 : e0 + e_pad] = s + n0
            dst[e0 : e0 + e_pad] = d + n0
            inv[e0 : e0 + e_pad] = iv
            slot[e0 : e0 + e_pad] = sl
        else:
            src[e0 : e0 + e_pad] = n0 + n_pad - 1
            dst[e0 : e0 + e_pad] = n0 + n_pad - 1
    return {"x": x, "edge_src": src, "edge_dst": dst, "edge_inv": inv,
            "edge_slot": slot, "num_nodes": capacity * n_pad}


def unpack_predictions(
    pred: np.ndarray, items: list[WorkItem], shape: BucketShape
) -> list[np.ndarray]:
    """Slice each item's real-node predictions back out of a packed run."""
    return [
        pred[i * shape.n_pad : i * shape.n_pad + it.num_nodes]
        for i, it in enumerate(items)
    ]
