"""`VerificationService`: submit/poll API + CLI entry point.

    svc = VerificationService(params, num_partitions=4)
    ticket = svc.submit_aiger("design.aig")        # or submit_design(...)
    result = svc.result(ticket)                    # blocking; poll() doesn't

Three overlapping execution stages, mirroring a production inference
server:

  * a *prepare pool* (threads) runs the host-side work per request —
    AIGER parsing, structural hashing + cache lookup, feature
    extraction, partitioning, boundary re-growth;
  * a single *device worker* drains prepared requests, batches their
    partitions through the :class:`ShapeBucketScheduler` (padded pow-2
    buckets -> stable jit shapes), and hands finished predictions back;
  * verification (adder extraction + simulation cross-check) runs back
    on the pool, so the device never waits on host post-processing.

Cache hits skip partitioning, inference, and verification entirely.

CLI (the ``repro`` console entry point; ``python -m repro.service.server``
still works)::

    repro serve --designs csa:8,csa:16,booth:8 --partitions 4 --repeat 2
    repro serve --aiger design.aig

NOTE: ``repro.api.Session`` is the public front door — it owns this
engine behind ``session.submit()/poll()``.  Constructing
``VerificationService`` directly still works but is deprecated.
"""
from __future__ import annotations

import argparse
import dataclasses
import queue
import threading
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from typing import Optional

import numpy as np

from repro.core import aig as A
from repro.core import gnn
from repro.core import pipeline as P
from repro.core.verify import VerifyResult
from repro.io import aiger
from repro.obs import MetricsRegistry, span
from repro.service.bucketing import items_from_prepared
from repro.service.cache import ResultCache
from repro.service.scheduler import ShapeBucketScheduler


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    num_partitions: int = 1
    regrow: bool = True
    partitioner: str = "multilevel"
    backend: str = "ref"          # shape-stable OR structure-keyed (see scheduler)
    capacity: int = 2             # same-bucket items packed per device call
    max_structures: int = 64      # groot* backends: jit executables kept before
                                  # a wholesale cache clear (memory bound)
    min_nodes: int = 64           # bucket floor (nodes)
    min_edges: int = 128          # bucket floor (edges)
    # bucket ceilings ("device size").  A request whose graph would need a
    # larger bucket is NOT rejected: the scheduler partitions it (with
    # re-growth) and streams it through the repro.exec executor.
    max_bucket_nodes: Optional[int] = None
    max_bucket_edges: Optional[int] = None
    stream_capacity: int = 2      # partitions packed per streamed launch
    prepare_workers: int = 2
    cache_capacity: int = 1024
    max_batch_requests: int = 16  # requests drained per device-worker cycle
    max_done_retained: int = 4096  # finished tickets kept pollable (FIFO evict)
    # staged edge-stream dtype for the groot* backends (None/f32 is
    # bit-exact; "bfloat16" halves staged stream bytes) — threaded through
    # to the BucketRunner, and part of the result-cache key because it
    # changes numerics
    stream_dtype: Optional[str] = None

    def cache_key_part(self) -> tuple:
        return (
            self.num_partitions, self.regrow, self.partitioner, self.backend,
            self.stream_dtype,
        )


@dataclasses.dataclass
class ServiceResult:
    req_id: int
    name: str
    status: str                   # verified|falsified|inconclusive|classified|error
    accuracy: float
    core_accuracy: float
    verdict: Optional[VerifyResult]
    cached: bool
    num_nodes: int
    num_edges: int
    timings: dict
    error: Optional[str] = None


@dataclasses.dataclass
class _Request:
    req_id: int
    design: object                       # AIG/LUTGraph or None (generate/parse)
    aiger_bytes: Optional[bytes]
    dataset: str
    bits: int
    seed: int
    verify: bool
    signed: Optional[bool]
    t_submit: float
    event: threading.Event = dataclasses.field(default_factory=threading.Event)
    result: Optional[ServiceResult] = None


class VerificationService:
    """Batched, cached verification over a trained GROOT model.

    DEPRECATED as a public entry point: :class:`repro.api.Session` is the
    façade (``session.submit()/poll()`` is this engine behind one config);
    the class keeps working as the service engine the session owns.
    ``**overrides`` always apply on top of ``config`` when both are given
    (via ``dataclasses.replace``), so a shared base config can be
    specialised per instance.
    """

    def __init__(self, params, config: Optional[ServiceConfig] = None,
                 _warn: bool = True, metrics: Optional[MetricsRegistry] = None,
                 **overrides):
        if _warn:
            import warnings

            warnings.warn(
                "constructing VerificationService directly is deprecated; "
                "use repro.api.Session (submit/poll)",
                DeprecationWarning,
                stacklevel=2,
            )
        if config is None:
            config = ServiceConfig(**overrides)
        elif overrides:
            config = dataclasses.replace(config, **overrides)
        self.config = config
        # per-engine registry (a Session passes its own, so two live
        # sessions never read each other's service numbers)
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.cache = ResultCache(config.cache_capacity)
        self.scheduler = ShapeBucketScheduler(
            params,
            backend=config.backend,
            capacity=config.capacity,
            min_nodes=config.min_nodes,
            min_edges=config.min_edges,
            max_structures=config.max_structures,
            max_bucket_nodes=config.max_bucket_nodes,
            max_bucket_edges=config.max_bucket_edges,
            stream_capacity=config.stream_capacity,
            stream_dtype=config.stream_dtype,
        )
        self._pool = ThreadPoolExecutor(
            max_workers=config.prepare_workers, thread_name_prefix="svc-prepare"
        )
        self._device_q: queue.Queue = queue.Queue()
        self._requests: dict[int, _Request] = {}
        self._done_order: deque[int] = deque()
        self._lock = threading.Lock()
        self._next_id = 0
        self._stop = False
        self._device_thread = threading.Thread(
            target=self._device_loop, name="svc-device", daemon=True
        )
        self._device_thread.start()

    # -- submission API ------------------------------------------------------

    def submit(
        self,
        design=None,
        *,
        dataset: str = "csa",
        bits: int = 8,
        seed: int = 0,
        aiger_bytes: Optional[bytes] = None,
        verify: bool = True,
        signed: Optional[bool] = None,
    ) -> int:
        """Enqueue one verification request; returns a ticket id."""
        if self._stop:
            raise RuntimeError("service is closed")
        with self._lock:
            rid = self._next_id
            self._next_id += 1
            req = _Request(
                req_id=rid,
                design=design,
                aiger_bytes=aiger_bytes,
                dataset=dataset,
                bits=bits,
                seed=seed,
                verify=verify,
                signed=signed,
                t_submit=time.perf_counter(),
            )
            self._requests[rid] = req
        self.metrics.counter("service.admitted").inc()
        self._pool.submit(self._prepare_one, req)
        return rid

    def submit_design(self, dataset: str, bits: int, *, seed: int = 0,
                      verify: bool = True) -> int:
        return self.submit(dataset=dataset, bits=bits, seed=seed, verify=verify)

    def submit_aiger(self, source, *, verify: bool = True,
                     signed: Optional[bool] = None) -> int:
        """Submit an AIGER file (path) or raw AIGER bytes."""
        return self.submit(
            aiger_bytes=aiger.source_bytes(source), verify=verify, signed=signed
        )

    # -- retrieval API -------------------------------------------------------

    def poll(self, ticket: int) -> Optional[ServiceResult]:
        """Non-blocking: the result if finished, else None."""
        req = self._requests.get(ticket)
        if req is None:
            raise KeyError(f"unknown ticket {ticket}")
        return req.result if req.event.is_set() else None

    def result(self, ticket: int, timeout: Optional[float] = None) -> ServiceResult:
        """Blocking retrieval."""
        req = self._requests.get(ticket)
        if req is None:
            raise KeyError(f"unknown ticket {ticket}")
        if not req.event.wait(timeout):
            raise TimeoutError(f"ticket {ticket} not done within {timeout}s")
        assert req.result is not None
        return req.result

    def close(self, timeout: Optional[float] = 300.0) -> None:
        """Drain outstanding requests and stop the workers."""
        with self._lock:
            pending = list(self._requests.values())
        for req in pending:
            req.event.wait(timeout)
        self._stop = True
        self._pool.shutdown(wait=True)
        self._device_thread.join(timeout=10.0)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def stats(self) -> dict:
        from repro.kernels.plan_cache import PLAN_CACHE

        s = self.scheduler.stats()
        return {
            "cache": self.cache.stats,
            "compile_count": s.compile_count,
            "device_calls": s.run_count,
            "buckets": [(b.n_pad, b.e_pad) for b in s.buckets],
            "items_run": s.items_run,
            "streamed_items": s.streamed_items,
            # process-wide structural plan cache (groot* backends)
            "plan_cache": PLAN_CACHE.snapshot(),
            # this engine's obs registry: admit counts, queue depth/wait,
            # per-stage latency histograms
            "obs": self.metrics.snapshot(prefix="service."),
        }

    # -- workers -------------------------------------------------------------

    def _finish(self, req: _Request, result: ServiceResult) -> None:
        req.result = result
        req.event.set()
        # bound the ticket table: a long-lived service must not retain one
        # _Request (+ result payload) per request forever.  Oldest finished
        # tickets stop being pollable past max_done_retained.
        with self._lock:
            self._done_order.append(req.req_id)
            while len(self._done_order) > self.config.max_done_retained:
                self._requests.pop(self._done_order.popleft(), None)

    def _fail(self, req: _Request, exc: Exception) -> None:
        self._finish(
            req,
            ServiceResult(
                req_id=req.req_id, name="?", status="error", accuracy=0.0,
                core_accuracy=0.0, verdict=None, cached=False, num_nodes=0,
                num_edges=0, timings={}, error=f"{type(exc).__name__}: {exc}",
            ),
        )

    def _prepare_one(self, req: _Request) -> None:
        try:
            t0 = time.perf_counter()
            design = req.design
            if design is None and req.aiger_bytes is not None:
                design = aiger.loads(req.aiger_bytes)
            cfg = P.PipelineConfig(
                dataset=req.dataset,
                bits=req.bits,
                num_partitions=self.config.num_partitions,
                regrow=self.config.regrow,
                partitioner=self.config.partitioner,
                backend=self.config.backend,
                seed=req.seed,
                stream_dtype=self.config.stream_dtype,
            )
            key = None
            if design is None or isinstance(design, A.AIG):
                with span("service.hash"):
                    h = (
                        aiger.structural_hash(design)
                        if design is not None
                        else f"gen:{req.dataset}:{req.bits}:{req.seed}"
                    )
                # every request field that can change the outcome must be in
                # the key: seed steers the partitioner, signed the spec check
                key = ResultCache.key(
                    h,
                    self.config.cache_key_part()
                    + (req.verify, req.signed, req.seed),
                )
                hit = self.cache.get(key)
                if hit is not None:
                    assert isinstance(hit, ServiceResult)
                    self.metrics.counter("service.cache_hits").inc()
                    self._finish(
                        req,
                        dataclasses.replace(
                            hit,
                            req_id=req.req_id,
                            cached=True,
                            timings={
                                "prepare": time.perf_counter() - t0,
                                "total": time.perf_counter() - req.t_submit,
                            },
                        ),
                    )
                    return
            with span("service.prepare", req_id=req.req_id):
                prep = P.prepare(cfg, design)
                items = items_from_prepared(req.req_id, prep)
            t_prep = time.perf_counter() - t0
            self.metrics.histogram("service.prepare_s").observe(t_prep)
            self._device_q.put(
                (req, key, prep, items, t_prep, time.perf_counter())
            )
            self.metrics.gauge("service.queue_depth").set(self._device_q.qsize())
        except Exception as e:  # noqa: BLE001 — request-scoped failure
            self._fail(req, e)

    def _device_loop(self) -> None:
        while True:
            try:
                entry = self._device_q.get(timeout=0.05)
            except queue.Empty:
                if self._stop:
                    return
                continue
            batch = [entry]
            while len(batch) < self.config.max_batch_requests:
                try:
                    batch.append(self._device_q.get_nowait())
                except queue.Empty:
                    break
            try:
                t0 = time.perf_counter()
                for entry_ in batch:
                    self.metrics.histogram("service.queue_wait_s").observe(
                        t0 - entry_[5]
                    )
                self.metrics.gauge("service.queue_depth").set(
                    self._device_q.qsize()
                )
                all_items = [it for (_, _, _, items, _, _) in batch for it in items]
                preds = self.scheduler.run_items(all_items)
                t_inf = time.perf_counter() - t0
                self.metrics.histogram("service.infer_s").observe(t_inf)
            except Exception as e:  # noqa: BLE001
                for req, *_ in batch:
                    self._fail(req, e)
                continue
            for req, key, prep, items, t_prep, _t_enq in batch:
                out = np.zeros(prep.num_nodes, dtype=np.int32)
                for it in items:
                    p = preds[(req.req_id, it.part_index)]
                    out[it.global_ids[: it.num_core]] = p[: it.num_core]
                timings = {"prepare": t_prep, "inference": t_inf}
                # host post-processing goes back to the pool: the device
                # worker moves on to the next batch immediately
                self._pool.submit(self._finalize, req, key, prep, out, timings)

    def _finalize(self, req, key, prep, pred: np.ndarray, timings: dict) -> None:
        try:
            t0 = time.perf_counter()
            acc = gnn.accuracy(pred, prep.labels)
            verdict = None
            if req.verify:
                verdict = P.verify_prepared(prep, pred, signed=req.signed)
            timings["verify"] = time.perf_counter() - t0
            self.metrics.histogram("service.verify_s").observe(timings["verify"])
            timings["total"] = time.perf_counter() - req.t_submit
            result = ServiceResult(
                req_id=req.req_id,
                name=getattr(prep.design, "name", "?"),
                status=verdict.status if verdict is not None else "classified",
                accuracy=acc,
                core_accuracy=acc,
                verdict=verdict,
                cached=False,
                num_nodes=prep.num_nodes,
                num_edges=prep.num_edges,
                timings=timings,
            )
            if key is not None:
                self.cache.put(key, result)
            self._finish(req, result)
        except Exception as e:  # noqa: BLE001
            self._fail(req, e)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def _parse_designs(spec: str) -> list[tuple[str, int]]:
    out = []
    for part in spec.split(","):
        if not part:
            continue
        fam, _, bits = part.partition(":")
        out.append((fam, int(bits or 8)))
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description="GROOT verification service")
    ap.add_argument("--designs", default="csa:8,csa:16,booth:8",
                    help="comma list of family:bits to generate and submit")
    ap.add_argument("--aiger", nargs="*", default=[],
                    help="AIGER files (.aig/.aag) to submit")
    ap.add_argument("--repeat", type=int, default=1,
                    help="submit the workload this many times (cache demo)")
    ap.add_argument("--partitions", type=int, default=1)
    ap.add_argument("--no-regrow", action="store_true")
    ap.add_argument("--capacity", type=int, default=2)
    ap.add_argument("--max-bucket-nodes", type=int, default=None,
                    help="bucket ceiling; larger designs stream through "
                         "the partitioned executor instead of erroring")
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--train-bits", type=int, default=8)
    ap.add_argument("--epochs", type=int, default=300)
    args = ap.parse_args(argv)

    # the CLI is a thin client of the façade: one Session owns the params,
    # the batched engine, and every cache
    from repro.api import Session, SessionConfig

    sess = Session(config=SessionConfig(
        num_partitions=args.partitions,
        regrow=not args.no_regrow,
        capacity=args.capacity,
        prepare_workers=args.workers,
        max_bucket_nodes=args.max_bucket_nodes,
    ))
    print(f"training groot-gnn on csa {args.train_bits}b ({args.epochs} epochs)...")
    sess.train("csa", args.train_bits, epochs=args.epochs)

    t0 = time.perf_counter()
    results = []
    with sess:
        # rounds are sequential so repeat > 1 demonstrates cache hits
        for _ in range(args.repeat):
            tickets = [
                sess.submit(dataset=fam, bits=bits)
                for fam, bits in _parse_designs(args.designs)
            ]
            tickets += [sess.submit(path) for path in args.aiger]
            results += [sess.result(t) for t in tickets]
        svc_stats = sess.stats()["service"]
    dt = time.perf_counter() - t0
    print(f"\n{'ticket':>6} {'design':>18} {'status':>13} {'acc':>7} "
          f"{'nodes':>7} {'cached':>6} {'total_s':>8}")
    for r in results:
        print(f"{r.req_id:>6} {r.name:>18} {r.status:>13} {r.accuracy:7.4f} "
              f"{r.num_nodes:>7} {str(r.cached):>6} {r.timings.get('total', 0):8.3f}")
        if r.error:
            print(f"       error: {r.error}")
    s = svc_stats
    print(f"\nserved {len(results)} requests in {dt:.2f}s "
          f"({len(results) / dt:.1f} req/s incl. compile)")
    print(f"jit compiles: {s['compile_count']}  device calls: {s['device_calls']}  "
          f"buckets: {s['buckets']}  streamed: {s['streamed_items']}")
    print(f"cache: {s['cache'].hits} hits / {s['cache'].misses} misses "
          f"(rate {s['cache'].hit_rate:.0%})")


if __name__ == "__main__":
    main()
