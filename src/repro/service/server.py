"""`VerificationService`: submit/poll API + CLI entry point.

    svc = VerificationService(params, num_partitions=4, warmup=True)
    ticket = svc.submit_aiger("design.aig")        # or submit_design(...)
    result = svc.result(ticket)                    # blocking; poll() doesn't

Three overlapping execution stages, mirroring a production inference
server:

  * a *prepare pool* (threads) runs the host-side work per request —
    AIGER parsing, structural hashing + cache lookup, feature
    extraction, partitioning, boundary re-growth;
  * a single *device worker* runs a **continuous-batching** loop: every
    prepared item is admitted into a priority-ordered
    :class:`~repro.service.scheduler.SlotPool`, and between any two
    device calls the loop re-drains its queue — so a request arriving
    mid-flight joins the very next same-bucket pack (up to ``capacity``
    slots per call) instead of waiting behind a drained wave;
  * verification (adder extraction + simulation cross-check) runs back
    on the pool, so the device never waits on host post-processing.

Latency/robustness features layered on the loop:

  * **compile-ahead warmup** (``warmup=True``): the configured
    ``(n_pad, e_pad)`` bucket grid — and the streamed route's slot
    layout when bucket ceilings are set — is jit-compiled at startup,
    so no user request pays a cold compile.  The ``service.cold_compiles``
    counter must read 0 afterwards; anything else is a regression.
  * **priority lanes**: ``submit(priority=0)`` jumps a saturated queue —
    the pool orders items by ``(priority, arrival)`` (lower = sooner).
  * **per-tenant admission caps** (``max_inflight_per_tenant``): a
    tenant at its in-flight limit gets :class:`AdmissionError` back at
    ``submit()`` instead of head-of-line-blocking everyone else.
  * **in-flight coalescing** (``coalesce=True``): concurrent submissions
    with the same cache key share one execution — followers are finished
    from the leader's result with ``cached=True``, which is what makes
    revision-heavy (resubmit-the-same-netlist) traffic cheap.

Cache hits skip partitioning, inference, and verification entirely.

Failure-domain hardening (see README "Failure semantics"):

  * **deadlines**: ``submit(deadline_s=...)`` (or the config default)
    arms a per-ticket budget checked cooperatively at every stage
    boundary — an expired ticket fails with :class:`DeadlineExceeded`
    (flight-recorded, ``service.deadline_exceeded``) and ``poll()`` /
    ``result()`` themselves expire overdue tickets, so a wedged worker
    can never hang a caller past its deadline;
  * **retries**: a transient launch failure of a lone item replays with
    exponential backoff + seeded jitter (the shared policy in
    ``repro.distributed.fault_tolerance``; ``service.retries``);
  * **bisection**: a failed multi-item pack is split and re-run in
    halves (``service.bisections``) so one poisoned design fails alone
    while its co-batched tickets complete;
  * **worker-death detection**: ``poll()``/``result()`` notice a dead
    device thread and fail the affected tickets instead of blocking
    forever; every failure path releases tenant in-flight counts and
    slot-pool occupancy.

CLI (the ``repro`` console entry point; ``python -m repro.service.server``
still works)::

    repro serve --designs csa:8,csa:16,booth:8 --partitions 4 --repeat 2
    repro serve --aiger design.aig

NOTE: ``repro.api.Session`` is the public front door — it owns this
engine behind ``session.submit()/poll()``.  Constructing
``VerificationService`` directly still works but is deprecated.
"""
from __future__ import annotations

import argparse
import dataclasses
import heapq
import itertools
import queue
import threading
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from typing import Optional

import numpy as np

from repro import faults
from repro.core import aig as A
from repro.core import gnn
from repro.core import pipeline as P
from repro.core.verify import VerifyResult
from repro.distributed.fault_tolerance import retry_call
from repro.io import aiger
from repro.obs import FlightRecorder, MetricsRegistry, record_from_marks, span
from repro.obs.flight import failed_stage_from_marks, failure_dump_dir
from repro.service.bucketing import items_from_prepared
from repro.service.cache import ResultCache
from repro.service.scheduler import ShapeBucketScheduler, SlotPool


class AdmissionError(RuntimeError):
    """Raised by ``submit()`` when a tenant is at its in-flight cap."""


class DeadlineExceeded(RuntimeError):
    """A ticket ran past its ``deadline_s`` budget.

    Raised *as the ticket's failure cause* (``result.error``), never out
    of ``poll()``/``result()`` themselves: expiry is cooperative — the
    stage boundaries and the retrieval API both check the clock, fail the
    ticket, release its tenant/pool resources, and record a flight.
    """


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    num_partitions: int = 1
    regrow: bool = True
    partitioner: str = "multilevel"
    backend: str = "ref"          # shape-stable OR structure-keyed (see scheduler)
    capacity: int = 2             # same-bucket items packed per device call
    max_structures: int = 64      # groot* backends: jit executables kept before
                                  # a wholesale cache clear (memory bound)
    min_nodes: int = 64           # bucket floor (nodes)
    min_edges: int = 128          # bucket floor (edges)
    # bucket ceilings ("device size").  A request whose graph would need a
    # larger bucket is NOT rejected: the scheduler partitions it (with
    # re-growth) and streams it through the repro.exec executor.
    max_bucket_nodes: Optional[int] = None
    max_bucket_edges: Optional[int] = None
    stream_capacity: int = 2      # partitions packed per streamed launch
    prepare_workers: int = 2
    cache_capacity: int = 1024
    max_batch_requests: int = 16  # requests drained per device-worker cycle
    max_done_retained: int = 4096  # finished tickets kept pollable (FIFO evict)
    # staged edge-stream dtype for the groot* backends (None/f32 is
    # bit-exact; "bfloat16" halves staged stream bytes) — threaded through
    # to the BucketRunner, and part of the result-cache key because it
    # changes numerics
    stream_dtype: Optional[str] = None
    # compile-ahead warmup: pre-compile the bucket grid at construction so
    # no user request pays a cold jit.  warmup_shapes pins the exact
    # (n_pad, e_pad) grid; None derives one from min/max bucket bounds.
    warmup: bool = False
    warmup_shapes: Optional[tuple] = None
    # in-flight coalescing: concurrent same-cache-key submissions share one
    # execution (followers finish from the leader's result, cached=True)
    coalesce: bool = True
    # per-tenant admission cap: submit(tenant=...) raises AdmissionError
    # once that tenant has this many unfinished requests (None = unlimited)
    max_inflight_per_tenant: Optional[int] = None
    # flight recorder: last N per-ticket forensic records kept in memory
    # (stats()["flights"]); failed tickets additionally dump a JSON record
    # to flight_dump_dir (or $REPRO_FLIGHT_DUMP_DIR) at failure time
    flight_records: int = 256
    flight_dump_dir: Optional[str] = None
    # failure domain (README "Failure semantics").  deadline_s arms every
    # ticket with a wall-clock budget (None = no deadline; a per-submit
    # deadline_s overrides).  launch_retries bounds transient-failure
    # replays of a lone item; retry_backoff_s seeds the exponential
    # backoff.  None of these changes results, so none is cache-keyed.
    deadline_s: Optional[float] = None
    launch_retries: int = 2
    retry_backoff_s: float = 0.05

    def cache_key_part(self) -> tuple:
        return (
            self.num_partitions, self.regrow, self.partitioner, self.backend,
            self.stream_dtype,
        )


@dataclasses.dataclass
class ServiceResult:
    req_id: int
    name: str
    status: str                   # verified|falsified|inconclusive|classified|error
    accuracy: float
    core_accuracy: float
    verdict: Optional[VerifyResult]
    cached: bool
    num_nodes: int
    num_edges: int
    timings: dict
    error: Optional[str] = None


@dataclasses.dataclass
class _Request:
    req_id: int
    design: object                       # AIG/LUTGraph or None (generate/parse)
    aiger_bytes: Optional[bytes]
    dataset: str
    bits: int
    seed: int
    verify: bool
    signed: Optional[bool]
    t_submit: float
    priority: int = 1                    # lower = sooner (0 = express lane)
    tenant: Optional[str] = None
    key: object = None                   # result-cache key, set during prepare
    event: threading.Event = dataclasses.field(default_factory=threading.Event)
    result: Optional[ServiceResult] = None
    # flight-record facts, filled in as the ticket moves through stages
    marks: list = dataclasses.field(default_factory=list)
    bucket: Optional[tuple] = None       # (n_pad, e_pad) of the pack it rode
    bucket_capacity: Optional[int] = None
    streamed: bool = False
    coalesced: bool = False
    # failure-domain state
    deadline_s: Optional[float] = None   # the armed budget (for the record)
    deadline: Optional[float] = None     # absolute perf_counter expiry
    retries: int = 0                     # transient-launch replays consumed
    claimed: bool = False                # first-result-wins guard (_finish)


@dataclasses.dataclass
class _Prepared:
    """One prepared request, queued for the device loop."""

    req: _Request
    key: object
    prep: object                         # PreparedDesign
    items: list
    t_prep: float
    t_enq: float


@dataclasses.dataclass
class _Inflight:
    """Device-loop state for a request whose items are in the pool."""

    req: _Request
    key: object
    prep: object
    remaining: int                       # items not yet run
    out: np.ndarray                      # predictions scattered so far
    t_prep: float
    t_enq: float
    t_infer: float = 0.0
    failed: bool = False


@dataclasses.dataclass
class _Slot:
    """One pool entry: a work item plus the request it belongs to."""

    inflight: _Inflight
    item: object                         # WorkItem


class VerificationService:
    """Batched, cached verification over a trained GROOT model.

    DEPRECATED as a public entry point: :class:`repro.api.Session` is the
    façade (``session.submit()/poll()`` is this engine behind one config);
    the class keeps working as the service engine the session owns.
    ``**overrides`` always apply on top of ``config`` when both are given
    (via ``dataclasses.replace``), so a shared base config can be
    specialised per instance.
    """

    def __init__(self, params, config: Optional[ServiceConfig] = None,
                 _warn: bool = True, metrics: Optional[MetricsRegistry] = None,
                 flights: Optional[FlightRecorder] = None,
                 **overrides):
        if _warn:
            import warnings

            warnings.warn(
                "constructing VerificationService directly is deprecated; "
                "use repro.api.Session (submit/poll)",
                DeprecationWarning,
                stacklevel=2,
            )
        if config is None:
            config = ServiceConfig(**overrides)
        elif overrides:
            config = dataclasses.replace(config, **overrides)
        self.config = config
        # per-engine registry (a Session passes its own, so two live
        # sessions never read each other's service numbers)
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        # per-ticket forensic ring (a Session passes its own so
        # Session.flights() sees both sync and service flights)
        self.flights = (
            flights if flights is not None
            else FlightRecorder(config.flight_records)
        )
        self.cache = ResultCache(config.cache_capacity)
        self.scheduler = ShapeBucketScheduler(
            params,
            backend=config.backend,
            capacity=config.capacity,
            min_nodes=config.min_nodes,
            min_edges=config.min_edges,
            max_structures=config.max_structures,
            max_bucket_nodes=config.max_bucket_nodes,
            max_bucket_edges=config.max_bucket_edges,
            stream_capacity=config.stream_capacity,
            stream_dtype=config.stream_dtype,
            metrics=self.metrics,
        )
        self._pool = ThreadPoolExecutor(
            max_workers=config.prepare_workers, thread_name_prefix="svc-prepare"
        )
        self._device_q: queue.Queue = queue.Queue()
        self._requests: dict[int, _Request] = {}
        self._done_order: deque[int] = deque()
        self._lock = threading.Lock()
        self._next_id = 0
        self._seq = itertools.count()           # pool admission order
        self._coalesce: dict = {}               # cache key -> follower reqs
        self._tenant_inflight: dict[str, int] = {}
        self._stop = False
        if config.warmup:
            # synchronous, before the device thread exists: every bucket in
            # the grid is compiled before the first submit() can race it
            self.warm()
        self._device_thread = threading.Thread(
            target=self._device_loop, name="svc-device", daemon=True
        )
        self._device_thread.start()

    # -- compile-ahead warmup ------------------------------------------------

    def _default_warm_shapes(self) -> tuple:
        """Diagonal bucket grid from the floor up to the ceilings.

        Real AIGs land between ~1 and ~2 edges per node after padding, so
        for each pow-2 node count we warm both the (n, n) and (n, 2n)
        buckets (clamped to the configured edge bounds).
        """
        c = self.config
        n_hi = c.max_bucket_nodes or c.min_nodes * 8
        e_hi = c.max_bucket_edges or c.min_edges * 16
        shapes: list[tuple[int, int]] = []
        n = c.min_nodes
        while n <= n_hi:
            for e in (n, 2 * n):
                e = min(max(e, c.min_edges), e_hi)
                if (n, e) not in shapes:
                    shapes.append((n, e))
            n *= 2
        return tuple(shapes)

    def warm(self, shapes: Optional[tuple] = None) -> int:
        """Pre-compile the bucket grid; returns the jit traces triggered.

        Afterwards the runner counts every further trace as a *cold*
        compile (``service.cold_compiles`` — a warmed service keeps it 0).
        Only shape-stable backends can be fully pre-compiled; for the
        structure-keyed ``groot*`` backends this primes the pack path but
        unseen structures still trace on first sight.
        """
        shapes = shapes or self.config.warmup_shapes or self._default_warm_shapes()
        stream = (
            self.config.max_bucket_nodes is not None
            or self.config.max_bucket_edges is not None
        )
        with span("service.warmup", shapes=len(shapes)):
            return self.scheduler.warm(shapes, stream=stream)

    # -- submission API ------------------------------------------------------

    def submit(
        self,
        design=None,
        *,
        dataset: str = "csa",
        bits: int = 8,
        seed: int = 0,
        aiger_bytes: Optional[bytes] = None,
        verify: bool = True,
        signed: Optional[bool] = None,
        priority: int = 1,
        tenant: Optional[str] = None,
        deadline_s: Optional[float] = None,
    ) -> int:
        """Enqueue one verification request; returns a ticket id.

        ``priority`` orders the device pool (lower = sooner; 0 is the
        express lane).  ``tenant`` attributes the request for admission
        control: past ``max_inflight_per_tenant`` unfinished requests a
        tenant gets :class:`AdmissionError` instead of queueing.
        ``deadline_s`` arms a wall-clock budget (default:
        ``config.deadline_s``); past it the ticket fails with
        :class:`DeadlineExceeded` instead of waiting further.
        """
        if self._stop:
            raise RuntimeError("service is closed")
        cap = self.config.max_inflight_per_tenant
        with self._lock:
            if tenant is not None and cap is not None:
                if self._tenant_inflight.get(tenant, 0) >= cap:
                    self.metrics.counter("service.rejected").inc()
                    raise AdmissionError(
                        f"tenant {tenant!r} already has {cap} requests "
                        f"in flight (max_inflight_per_tenant={cap})"
                    )
            rid = self._next_id
            self._next_id += 1
            req = _Request(
                req_id=rid,
                design=design,
                aiger_bytes=aiger_bytes,
                dataset=dataset,
                bits=bits,
                seed=seed,
                verify=verify,
                signed=signed,
                t_submit=time.perf_counter(),
                priority=priority,
                tenant=tenant,
            )
            budget = deadline_s if deadline_s is not None else self.config.deadline_s
            if budget is not None:
                req.deadline_s = budget
                req.deadline = req.t_submit + budget
            req.marks.append(("submit", req.t_submit))
            self._requests[rid] = req
            if tenant is not None:
                self._tenant_inflight[tenant] = (
                    self._tenant_inflight.get(tenant, 0) + 1
                )
        self.metrics.counter("service.admitted").inc()
        try:
            fast = self._fast_admit(req)
        except Exception as e:  # noqa: BLE001 — submit-side failures (e.g. an
            # injected cache.load fault) become per-ticket errors, releasing
            # the tenant slot, instead of leaking out of submit()
            self._fail(req, e)
            return rid
        if not fast:
            self._pool.submit(self._prepare_one, req)
        return rid

    def _gen_key(self, req: _Request):
        """Cache key for a generated design — computable without parsing."""
        return ResultCache.key(
            f"gen:{req.dataset}:{req.bits}:{req.seed}",
            self.config.cache_key_part() + (req.verify, req.signed, req.seed),
        )

    def _fast_admit(self, req: _Request) -> bool:
        """Resolve a generated-design request at submit time when its key
        alone decides it: a cache hit finishes immediately, a duplicate of
        an in-flight key coalesces behind the leader — either way no pool
        task is scheduled, so a burst of identical submissions costs one
        execution plus ~nothing per follower.  Returns True when the
        request needs no prepare."""
        if req.design is not None or req.aiger_bytes is not None:
            return False
        key = self._gen_key(req)
        hit = self.cache.get(key)
        if hit is not None:
            self.metrics.counter("service.cache_hits").inc()
            self._finish(
                req,
                dataclasses.replace(
                    hit,
                    req_id=req.req_id,
                    cached=True,
                    timings={"total": time.perf_counter() - req.t_submit},
                ),
            )
            return True
        if self.config.coalesce:
            with self._lock:
                followers = self._coalesce.get(key)
                if followers is not None:
                    req.coalesced = True
                    followers.append(req)
                    self.metrics.counter("service.coalesced").inc()
                    return True
                self._coalesce[key] = []
                req.key = key
        return False

    def submit_design(self, dataset: str, bits: int, *, seed: int = 0,
                      verify: bool = True) -> int:
        return self.submit(dataset=dataset, bits=bits, seed=seed, verify=verify)

    def submit_aiger(self, source, *, verify: bool = True,
                     signed: Optional[bool] = None) -> int:
        """Submit an AIGER file (path) or raw AIGER bytes."""
        return self.submit(
            aiger_bytes=aiger.source_bytes(source), verify=verify, signed=signed
        )

    # -- retrieval API -------------------------------------------------------

    def _worker_died(self) -> bool:
        """True when the device thread is gone without a clean shutdown."""
        return not self._stop and not self._device_thread.is_alive()

    def _expire_if_due(self, req: _Request) -> bool:
        """Cooperative deadline check: fail an overdue unfinished ticket
        (flight-recorded, tenant/pool resources released) and return True.
        Called at every stage boundary AND from poll()/result(), so an
        expired ticket is observed as failed no matter where it wedged."""
        if req.deadline is None or req.event.is_set():
            return False
        if time.perf_counter() < req.deadline:
            return False
        self.metrics.counter("service.deadline_exceeded").inc()
        self._fail(req, DeadlineExceeded(
            f"ticket {req.req_id} exceeded its {req.deadline_s:.4g}s deadline"
        ))
        return True

    def _fail_if_worker_dead(self, req: _Request) -> bool:
        if req.event.is_set() or not self._worker_died():
            return False
        self._fail(req, RuntimeError(
            "service device worker died; ticket can never finish"
        ))
        return True

    def poll(self, ticket: int) -> Optional[ServiceResult]:
        """Non-blocking: the result if finished, else None.

        Never returns None forever for a doomed ticket: an expired
        deadline or a dead device worker fails the ticket right here, so
        the caller sees an errored result on its next poll.
        """
        req = self._requests.get(ticket)
        if req is None:
            raise KeyError(f"unknown ticket {ticket}")
        if not req.event.is_set():
            self._expire_if_due(req)
            self._fail_if_worker_dead(req)
        return req.result if req.event.is_set() else None

    def result(self, ticket: int, timeout: Optional[float] = None) -> ServiceResult:
        """Blocking retrieval, bounded by ``timeout`` and the ticket's
        deadline.  Raises :class:`TimeoutError` past ``timeout``; a dead
        device worker or an expired deadline fails the ticket (errored
        result) instead of blocking forever."""
        req = self._requests.get(ticket)
        if req is None:
            raise KeyError(f"unknown ticket {ticket}")
        end = None if timeout is None else time.perf_counter() + timeout
        while not req.event.is_set():
            wait = 0.1
            now = time.perf_counter()
            if req.deadline is not None:
                wait = min(wait, max(req.deadline - now, 0.0) + 0.005)
            if end is not None:
                wait = min(wait, max(end - now, 0.0))
            if req.event.wait(max(wait, 0.005)):
                break
            if self._expire_if_due(req) or self._fail_if_worker_dead(req):
                break
            if end is not None and time.perf_counter() >= end:
                raise TimeoutError(f"ticket {ticket} not done within {timeout}s")
        assert req.result is not None
        return req.result

    def close(self, timeout: Optional[float] = 300.0) -> None:
        """Drain outstanding requests and stop the workers."""
        with self._lock:
            pending = list(self._requests.values())
        for req in pending:
            req.event.wait(timeout)
        self._stop = True
        self._pool.shutdown(wait=True)
        self._device_thread.join(timeout=10.0)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def stats(self) -> dict:
        from repro.kernels.plan_cache import PLAN_CACHE

        s = self.scheduler.stats()
        obs = self.metrics.snapshot(prefix="service.")
        return {
            "cache": self.cache.stats,
            "compile_count": s.compile_count,
            "device_calls": s.run_count,
            "buckets": [(b.n_pad, b.e_pad) for b in s.buckets],
            "items_run": s.items_run,
            "streamed_items": s.streamed_items,
            # compile-ahead warmup: grid size + cost, and the counter that
            # must stay 0 afterwards (every post-warmup jit trace is a
            # cold compile some user request paid for)
            "cold_compiles": s.cold_compiles,
            "warm_compiles": s.warm_compiles,
            "warm_shapes": list(s.warm_shapes),
            "warmup_s": s.warmup_s,
            # process-wide structural plan cache (groot* backends)
            "plan_cache": PLAN_CACHE.snapshot(),
            # this engine's obs registry: admit counts, queue depth/wait,
            # per-stage latency histograms
            "obs": obs,
            # high-water marks — the peaks last-value gauges silently lose
            "peaks": {k: g["max"] for k, g in obs["gauges"].items()},
            # per-ticket forensic ring (recorded/retained/failures + last)
            "flights": self.flights.stats(),
        }

    # -- workers -------------------------------------------------------------

    @staticmethod
    def _mark(req: _Request, stage: str) -> None:
        """Record a stage timestamp once per request (a multi-item request
        hits the device several times; only the first admission counts)."""
        if not any(s == stage for s, _ in req.marks):
            req.marks.append((stage, time.perf_counter()))

    def _record_flight(self, req: _Request, result: ServiceResult) -> None:
        """One forensic record per finished ticket, built at the single
        finish funnel so cache hits, coalesced followers, failures and
        normal completions all leave a trail.  Failed tickets also dump
        to disk immediately — the trail must survive the process."""
        # which segment a failure died in is only derivable before the
        # terminal mark lands
        failed_stage = (
            failed_stage_from_marks(req.marks)
            if result.status == "error" else None
        )
        self._mark(req, "done")
        rec = record_from_marks(
            req.req_id,
            result.name,
            result.status,
            req.marks,
            failed_stage=failed_stage,
            cached=result.cached and not req.coalesced,
            coalesced=req.coalesced,
            priority=req.priority,
            tenant=req.tenant,
            bucket=req.bucket,
            capacity=req.bucket_capacity,
            streamed=req.streamed,
            error=result.error,
            retries=req.retries,
            deadline_s=req.deadline_s,
        )
        self.flights.record(rec)
        if not rec.ok:
            directory = failure_dump_dir(self.config.flight_dump_dir)
            if directory:
                self.flights.dump_failure(rec, directory)

    def _finish(self, req: _Request, result: ServiceResult) -> None:
        # first-result-wins: a ticket can be finished concurrently from
        # several failure paths (deadline expiry in a poll()ing thread vs
        # the device loop completing it) — the claim below makes exactly
        # one of them the ticket's outcome; later finishes are no-ops, so
        # a DeadlineExceeded can never be overwritten by a late success
        with self._lock:
            first = not req.claimed
            req.claimed = True
        if not first:
            return          # the claiming path owns result + event
        self._record_flight(req, result)
        req.result = result
        req.event.set()
        # bound the ticket table: a long-lived service must not retain one
        # _Request (+ result payload) per request forever.  Oldest finished
        # tickets stop being pollable past max_done_retained.
        with self._lock:
            if req.tenant is not None:
                n = self._tenant_inflight.get(req.tenant, 1) - 1
                if n <= 0:
                    self._tenant_inflight.pop(req.tenant, None)
                else:
                    self._tenant_inflight[req.tenant] = n
            self._done_order.append(req.req_id)
            while len(self._done_order) > self.config.max_done_retained:
                self._requests.pop(self._done_order.popleft(), None)

    @staticmethod
    def _req_name(req: _Request) -> str:
        """Best attributable name for a request, even when it failed
        before (or during) parsing: the parsed design's name, else the
        AIGER comment name, else the generator spec."""
        name = getattr(req.design, "name", None)
        if name:
            return name
        if req.aiger_bytes is not None:
            return aiger.peek_name(req.aiger_bytes) or "aiger"
        return f"{req.dataset}:{req.bits}"

    def _pop_followers(self, key) -> list[_Request]:
        if key is None:
            return []
        with self._lock:
            return self._coalesce.pop(key, [])

    def _fail(self, req: _Request, exc: Exception) -> None:
        err = f"{type(exc).__name__}: {exc}"

        def _errored(r: _Request) -> ServiceResult:
            return ServiceResult(
                req_id=r.req_id, name=self._req_name(r), status="error",
                accuracy=0.0, core_accuracy=0.0, verdict=None, cached=False,
                num_nodes=0, num_edges=0, timings={}, error=err,
            )

        self._finish(req, _errored(req))
        # a coalesced leader takes its followers down with it — they share
        # the execution, so they share the failure
        for f in self._pop_followers(req.key):
            self._finish(f, _errored(f))

    def _prepare_one(self, req: _Request) -> None:
        try:
            if self._expire_if_due(req):
                return
            faults.fire("service.prepare", tag=lambda: self._req_name(req))
            t0 = time.perf_counter()
            design = req.design
            if design is None and req.aiger_bytes is not None:
                design = aiger.loads(req.aiger_bytes)
                req.design = design     # failed tickets stay attributable
            cfg = P.PipelineConfig(
                dataset=req.dataset,
                bits=req.bits,
                num_partitions=self.config.num_partitions,
                regrow=self.config.regrow,
                partitioner=self.config.partitioner,
                backend=self.config.backend,
                seed=req.seed,
                stream_dtype=self.config.stream_dtype,
            )
            key = req.key
            if key is None and (design is None or isinstance(design, A.AIG)):
                with span("service.hash"):
                    h = (
                        aiger.structural_hash(design)
                        if design is not None
                        else f"gen:{req.dataset}:{req.bits}:{req.seed}"
                    )
                # every request field that can change the outcome must be in
                # the key: seed steers the partitioner, signed the spec check
                key = ResultCache.key(
                    h,
                    self.config.cache_key_part()
                    + (req.verify, req.signed, req.seed),
                )
                hit = self.cache.get(key)
                if hit is not None:
                    assert isinstance(hit, ServiceResult)
                    self.metrics.counter("service.cache_hits").inc()
                    self._finish(
                        req,
                        dataclasses.replace(
                            hit,
                            req_id=req.req_id,
                            cached=True,
                            timings={
                                "prepare": time.perf_counter() - t0,
                                "total": time.perf_counter() - req.t_submit,
                            },
                        ),
                    )
                    return
                if self.config.coalesce:
                    # in-flight coalescing: if the same key is already being
                    # executed, ride along as a follower — the leader's
                    # _finalize/_fail finishes us too.  (A follower that
                    # registers just after the leader popped the entry
                    # simply becomes a new leader: duplicated work, never a
                    # hang or a wrong result.)
                    with self._lock:
                        followers = self._coalesce.get(key)
                        if followers is not None:
                            req.coalesced = True
                            followers.append(req)
                            self.metrics.counter("service.coalesced").inc()
                            return
                        self._coalesce[key] = []
                        req.key = key
            with span("service.prepare", req_id=req.req_id):
                prep = P.prepare(cfg, design)
                items = items_from_prepared(req.req_id, prep)
            t_prep = time.perf_counter() - t0
            self.metrics.histogram("service.prepare_s").observe(t_prep)
            self._mark(req, "prepared")
            self._device_q.put(
                _Prepared(req, key, prep, items, t_prep, time.perf_counter())
            )
            self.metrics.gauge("service.queue_depth").set(self._device_q.qsize())
        except Exception as e:  # noqa: BLE001 — request-scoped failure
            self._fail(req, e)

    def _drain_device_q(self, block: bool) -> Optional[list[_Prepared]]:
        """Everything currently queued (non-blocking past the first get).

        Called between every two device calls — this re-drain is what
        admits a freshly-prepared request into the next pack.  Returns
        None when the service is stopping and nothing is queued.
        """
        out: list[_Prepared] = []
        if block:
            try:
                out.append(self._device_q.get(timeout=0.05))
            except queue.Empty:
                if self._stop:
                    return None
        while True:
            try:
                out.append(self._device_q.get_nowait())
            except queue.Empty:
                break
        if out:
            self.metrics.gauge("service.queue_depth").set(self._device_q.qsize())
        return out

    def _admit(self, prepared: _Prepared, pool: SlotPool,
               streamed: list) -> None:
        """Slot a prepared request's items into the admission pool."""
        inf = _Inflight(
            req=prepared.req,
            key=prepared.key,
            prep=prepared.prep,
            remaining=len(prepared.items),
            out=np.zeros(prepared.prep.num_nodes, dtype=np.int32),
            t_prep=prepared.t_prep,
            t_enq=prepared.t_enq,
        )
        self.metrics.histogram("service.queue_wait_s").observe(
            time.perf_counter() - prepared.t_enq
        )
        for it in prepared.items:
            shape = self.scheduler.bucket_of(it)
            slot = _Slot(inf, it)
            if self.scheduler._oversized(shape):
                heapq.heappush(
                    streamed, (prepared.req.priority, next(self._seq), slot)
                )
            else:
                pool.admit(shape, prepared.req.priority, next(self._seq), slot)

    def _scatter(self, slot: _Slot, pred: np.ndarray, t_inf: float) -> None:
        """Fold one item's predictions into its request; finalize when the
        request's last item lands (host post-processing goes back to the
        pool so the device worker moves straight on)."""
        inf = slot.inflight
        it = slot.item
        inf.out[it.global_ids[: it.num_core]] = pred[: it.num_core]
        inf.t_infer += t_inf
        inf.remaining -= 1
        if inf.remaining == 0 and not inf.failed:
            self._mark(inf.req, "inferred")
            timings = {"prepare": inf.t_prep, "inference": inf.t_infer}
            self._pool.submit(
                self._finalize, inf.req, inf.key, inf.prep, inf.out, timings
            )

    def _fail_inflight(self, inf: _Inflight, exc: Exception) -> None:
        if not inf.failed:
            inf.failed = True
            self._fail(inf.req, exc)

    def _with_retries(self, attempt, req: _Request):
        """Run one device attempt with the shared transient-retry policy:
        exponential backoff + seeded jitter, bounded by ``launch_retries``
        AND the ticket's deadline (an expired budget aborts the replay
        loop with :class:`DeadlineExceeded`)."""
        def on_retry(i, exc):
            if req.deadline is not None and time.perf_counter() >= req.deadline:
                self.metrics.counter("service.deadline_exceeded").inc()
                raise DeadlineExceeded(
                    f"ticket {req.req_id} exceeded its {req.deadline_s:.4g}s "
                    f"deadline while retrying: {exc}"
                ) from exc
            req.retries += 1
            self.metrics.counter("service.retries").inc()

        return retry_call(
            attempt,
            retries=self.config.launch_retries,
            seed=req.req_id,
            base_s=self.config.retry_backoff_s,
            on_retry=on_retry,
        )

    def _run_streamed_slot(self, slot: _Slot) -> None:
        """One oversized item: partitioned + streamed through the shared
        runner (one whole-item unit; its sub-launches batch internally at
        stream_capacity).  Transient failures retry like packed items."""
        inf = slot.inflight
        req = inf.req
        t0 = time.perf_counter()
        self.metrics.histogram("service.admission_s").observe(t0 - inf.t_enq)
        req.streamed = True
        self._mark(req, "admitted")

        def _attempt():
            faults.fire("service.device", tag=lambda: self._req_name(req))
            return self.scheduler.run_one(slot.item)

        try:
            preds = self._with_retries(_attempt, req)
            t_inf = time.perf_counter() - t0
            self.metrics.histogram("service.infer_s").observe(t_inf)
            self._scatter(slot, preds[(req.req_id, slot.item.part_index)], t_inf)
        except Exception as e:  # noqa: BLE001
            self._fail_inflight(inf, e)

    def _run_pack_slots(self, slots: list, shape, depth: int = 0) -> None:
        """One device call over ≤capacity live same-bucket slots, with
        blast-radius isolation: a failing multi-slot pack is bisected and
        each half re-run (``service.bisections``), so a poisoned item
        ultimately fails *alone* while its co-batched tickets complete; a
        lone item's transient failure replays with backoff
        (``service.retries``)."""
        live = []
        for s in slots:
            inf = s.inflight
            if inf.failed or inf.req.event.is_set():
                continue
            if self._expire_if_due(inf.req):
                inf.failed = True
                continue
            live.append(s)
        slots = live
        if not slots:
            return
        t0 = time.perf_counter()
        for s in slots:
            if depth == 0:
                self.metrics.histogram("service.admission_s").observe(
                    t0 - s.inflight.t_enq
                )
            if s.inflight.req.bucket is None:
                s.inflight.req.bucket = (shape.n_pad, shape.e_pad)
                s.inflight.req.bucket_capacity = self.scheduler.capacity
            self._mark(s.inflight.req, "admitted")

        def _attempt():
            faults.fire(
                "service.device",
                tag=lambda: ",".join(
                    self._req_name(s.inflight.req) for s in slots
                ),
            )
            return self.scheduler.run_pack([s.item for s in slots], shape)

        try:
            if len(slots) == 1:
                preds = self._with_retries(_attempt, slots[0].inflight.req)
            else:
                preds = _attempt()
        except Exception as e:  # noqa: BLE001
            if len(slots) > 1:
                self.metrics.counter("service.bisections").inc()
                mid = (len(slots) + 1) // 2
                self._run_pack_slots(slots[:mid], shape, depth + 1)
                self._run_pack_slots(slots[mid:], shape, depth + 1)
                return
            self._fail_inflight(slots[0].inflight, e)
            return
        t_inf = time.perf_counter() - t0
        self.metrics.histogram("service.infer_s").observe(t_inf)
        for s in slots:
            self._scatter(
                s, preds[(s.inflight.req.req_id, s.item.part_index)], t_inf
            )

    @staticmethod
    def _slot_dead(slot: _Slot) -> bool:
        return slot.inflight.failed or slot.inflight.req.event.is_set()

    def _device_loop(self) -> None:
        """Crash containment around the batching loop: the device worker
        must never die silently — an escaped exception (including an
        injected :class:`~repro.faults.WorkerKilled`) fails every pending
        ticket so pollers/result() unblock with an attributed error."""
        try:
            self._device_loop_inner()
        except BaseException as e:  # noqa: BLE001 — worker-death containment
            self.metrics.counter("service.worker_deaths").inc()
            with self._lock:
                pending = [
                    r for r in self._requests.values() if not r.event.is_set()
                ]
            for r in pending:
                self._fail(r, RuntimeError(f"device worker crashed: {e!r}"))

    def _device_loop_inner(self) -> None:
        """Continuous batching: one device call per iteration, re-draining
        the queue in between.  The pool orders items by (priority, seq);
        each iteration runs one pack of the globally most-urgent bucket —
        so an item prepared while a pack was on the device joins the next
        pack of its bucket mid-flight instead of waiting out a wave.
        """
        pool = SlotPool()
        streamed: list = []             # (priority, seq, _Slot) heap
        while True:
            idle = len(pool) == 0 and not streamed
            drained = self._drain_device_q(block=idle)
            if drained is None:
                return
            for prepared in drained:
                self._admit(prepared, pool, streamed)
            # release pool occupancy of failed / finished / expired slots
            # every cycle — no failure path leaves ghosts in the heaps
            pool.prune(self._slot_dead)
            while streamed and self._slot_dead(streamed[0][2]):
                heapq.heappop(streamed)
            self.metrics.gauge("service.pending_items").set(
                len(pool) + len(streamed)
            )
            shape = pool.best_bucket()
            if shape is None and not streamed:
                continue
            if streamed and (
                shape is None or streamed[0][:2] < pool.head_key(shape)
            ):
                _, _, slot = heapq.heappop(streamed)
                if not self._slot_dead(slot):
                    self._run_streamed_slot(slot)
                continue
            taken = pool.take(shape, self.scheduler.capacity)
            self._run_pack_slots([s for (_, _, s) in taken], shape)

    def _finalize(self, req, key, prep, pred: np.ndarray, timings: dict) -> None:
        try:
            if self._expire_if_due(req):
                return
            t0 = time.perf_counter()
            acc = gnn.accuracy(pred, prep.labels)
            verdict = None
            if req.verify:
                verdict = P.verify_prepared(prep, pred, signed=req.signed)
            timings["verify"] = time.perf_counter() - t0
            self.metrics.histogram("service.verify_s").observe(timings["verify"])
            timings["total"] = time.perf_counter() - req.t_submit
            result = ServiceResult(
                req_id=req.req_id,
                name=getattr(prep.design, "name", None) or self._req_name(req),
                status=verdict.status if verdict is not None else "classified",
                accuracy=acc,
                core_accuracy=acc,
                verdict=verdict,
                cached=False,
                num_nodes=prep.num_nodes,
                num_edges=prep.num_edges,
                timings=timings,
            )
            if key is not None:
                self.cache.put(key, result)
            self._finish(req, result)
            # coalesced followers share the leader's execution: finish them
            # from the same result, marked cached (it IS a shared outcome)
            for f in self._pop_followers(key):
                self._finish(
                    f,
                    dataclasses.replace(
                        result,
                        req_id=f.req_id,
                        cached=True,
                        timings={
                            **timings,
                            "total": time.perf_counter() - f.t_submit,
                        },
                    ),
                )
        except Exception as e:  # noqa: BLE001
            self._fail(req, e)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def _parse_designs(spec: str) -> list[tuple[str, int]]:
    out = []
    for part in spec.split(","):
        if not part:
            continue
        fam, _, bits = part.partition(":")
        out.append((fam, int(bits or 8)))
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description="GROOT verification service")
    ap.add_argument("--designs", default="csa:8,csa:16,booth:8",
                    help="comma list of family:bits to generate and submit")
    ap.add_argument("--aiger", nargs="*", default=[],
                    help="AIGER files (.aig/.aag) to submit")
    ap.add_argument("--repeat", type=int, default=1,
                    help="submit the workload this many times (cache demo)")
    ap.add_argument("--partitions", type=int, default=1)
    ap.add_argument("--no-regrow", action="store_true")
    ap.add_argument("--capacity", type=int, default=2)
    ap.add_argument("--max-bucket-nodes", type=int, default=None,
                    help="bucket ceiling; larger designs stream through "
                         "the partitioned executor instead of erroring")
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--train-bits", type=int, default=8)
    ap.add_argument("--epochs", type=int, default=300)
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve GET /metrics (Prometheus text) and "
                         "GET /stats (JSON) on this port while running")
    ap.add_argument("--sample", metavar="OUT.jsonl", default=None,
                    help="append periodic JSONL registry snapshots "
                         "(queue depth, slot occupancy, stage latencies)")
    ap.add_argument("--sample-interval", type=float, default=0.5)
    ap.add_argument("--flight-dump-dir", default=None,
                    help="directory for failed tickets' flight-record dumps "
                         "(default: $REPRO_FLIGHT_DUMP_DIR)")
    args = ap.parse_args(argv)

    # the CLI is a thin client of the façade: one Session owns the params,
    # the batched engine, and every cache
    from repro.api import Session, SessionConfig

    sess = Session(config=SessionConfig(
        num_partitions=args.partitions,
        regrow=not args.no_regrow,
        capacity=args.capacity,
        prepare_workers=args.workers,
        max_bucket_nodes=args.max_bucket_nodes,
        flight_dump_dir=args.flight_dump_dir,
    ))
    print(f"training groot-gnn on csa {args.train_bits}b ({args.epochs} epochs)...")
    sess.train("csa", args.train_bits, epochs=args.epochs)

    metrics_server = None
    sampler = None
    if args.metrics_port is not None:
        from repro.obs import start_metrics_server

        metrics_server = start_metrics_server(
            sess.obs.metrics, port=args.metrics_port, stats_fn=sess.stats
        )
        print(f"metrics: {metrics_server.url}/metrics  "
              f"stats: {metrics_server.url}/stats")
    if args.sample is not None:
        from repro.obs import Sampler

        sampler = Sampler(
            args.sample, sess.obs.metrics, interval_s=args.sample_interval
        ).start()

    t0 = time.perf_counter()
    results = []
    with sess:
        # rounds are sequential so repeat > 1 demonstrates cache hits
        for _ in range(args.repeat):
            tickets = [
                sess.submit(dataset=fam, bits=bits)
                for fam, bits in _parse_designs(args.designs)
            ]
            tickets += [sess.submit(path) for path in args.aiger]
            results += [sess.result(t) for t in tickets]
        svc_stats = sess.stats()["service"]
    dt = time.perf_counter() - t0
    print(f"\n{'ticket':>6} {'design':>18} {'status':>13} {'acc':>7} "
          f"{'nodes':>7} {'cached':>6} {'total_s':>8}")
    for r in results:
        print(f"{r.req_id:>6} {r.name:>18} {r.status:>13} {r.accuracy:7.4f} "
              f"{r.num_nodes:>7} {str(r.cached):>6} {r.timings.get('total', 0):8.3f}")
        if r.error:
            print(f"       error: {r.error}")
    s = svc_stats
    print(f"\nserved {len(results)} requests in {dt:.2f}s "
          f"({len(results) / dt:.1f} req/s incl. compile)")
    print(f"jit compiles: {s['compile_count']}  device calls: {s['device_calls']}  "
          f"buckets: {s['buckets']}  streamed: {s['streamed_items']}")
    print(f"cache: {s['cache'].hits} hits / {s['cache'].misses} misses "
          f"(rate {s['cache'].hit_rate:.0%})")
    fl = s["flights"]
    print(f"flights: {fl['recorded']} recorded, {fl['failures']} failed, "
          f"{fl['retained']}/{fl['capacity']} retained")
    if sampler is not None:
        print(f"sampler: {sampler.stop()} snapshots -> {sampler.path}")
    if metrics_server is not None:
        metrics_server.close()


if __name__ == "__main__":
    main()
