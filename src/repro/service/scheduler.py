"""Shape-bucketed device scheduler with a compile-count probe.

:class:`BucketRunner` owns the single jitted padded forward pass.  The
Python body of a jitted function executes once per *trace* — i.e. once
per new (shape signature, static args) cache entry — so a plain counter
incremented inside it is an exact compile-count probe.  That probe is
what the acceptance criterion ("N same-family designs trigger <=
num_buckets compilations") asserts against.

:class:`ShapeBucketScheduler` groups work items by bucket, packs up to
``capacity`` same-bucket items per device call, and reads back per-item
real-node predictions.  Backends: only shape-stable aggregation
backends are allowed ("ref", "onehot") — the Pallas ``groot*`` backends
embed a per-graph degree-bucketing plan as jit constants, which defeats
shape bucketing by design (each plan is its own executable); the
one-shot pipeline remains the entry point for those.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import defaultdict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gnn
from repro.service.bucketing import (
    BucketShape,
    WorkItem,
    pack_batch,
    unpack_predictions,
)

SHAPE_STABLE_BACKENDS = ("ref", "onehot")


class BucketRunner:
    """One jitted padded GNN forward; counts compiles and device calls."""

    def __init__(self, params, backend: str = "ref"):
        if backend not in SHAPE_STABLE_BACKENDS:
            raise ValueError(
                f"service backend must be shape-stable {SHAPE_STABLE_BACKENDS}, "
                f"got {backend!r} (use the one-shot pipeline for Pallas backends)"
            )
        self._params = jax.tree_util.tree_map(jnp.asarray, params)
        self._backend = backend
        self.compile_count = 0
        self.run_count = 0
        self._lock = threading.Lock()

        def _fwd(params, x, edge_src, edge_dst, edge_inv, edge_slot, num_nodes):
            # Executes at trace time only: one increment per compilation.
            self.compile_count += 1
            agg = None
            if self._backend == "onehot":
                from repro.kernels import ops

                # same pair the pipeline path uses (closures over tracers)
                agg = ops.make_agg_pair(edge_src, edge_dst, num_nodes, "onehot")
            logits = gnn.forward(
                params, x, edge_src, edge_dst, edge_inv, edge_slot,
                num_nodes=num_nodes, agg=agg,
            )
            return jnp.argmax(logits, axis=-1)

        self._jit = jax.jit(_fwd, static_argnames=("num_nodes",))

    def __call__(self, batch: dict) -> np.ndarray:
        with self._lock:  # one device stream; keeps the probe race-free
            self.run_count += 1
            return np.asarray(
                self._jit(
                    self._params,
                    jnp.asarray(batch["x"]),
                    jnp.asarray(batch["edge_src"]),
                    jnp.asarray(batch["edge_dst"]),
                    jnp.asarray(batch["edge_inv"]),
                    jnp.asarray(batch["edge_slot"]),
                    batch["num_nodes"],
                )
            )


@dataclasses.dataclass
class SchedulerStats:
    compile_count: int
    run_count: int
    buckets: list[BucketShape]
    items_run: int


class ShapeBucketScheduler:
    """Groups work items into shape buckets and runs them batched."""

    def __init__(
        self,
        params,
        *,
        backend: str = "ref",
        capacity: int = 2,
        min_nodes: int = 64,
        min_edges: int = 128,
    ):
        assert capacity >= 1
        self.runner = BucketRunner(params, backend)
        self.capacity = capacity
        self.min_nodes = min_nodes
        self.min_edges = min_edges
        self._buckets_seen: set[BucketShape] = set()
        self._items_run = 0

    def bucket_of(self, item: WorkItem) -> BucketShape:
        return item.bucket(min_nodes=self.min_nodes, min_edges=self.min_edges)

    def run_items(self, items: list[WorkItem]) -> dict[tuple[int, int], np.ndarray]:
        """Run a set of items; returns (req_id, part_index) -> real-node preds.

        Items of the same bucket are packed ``capacity`` at a time, so a
        burst of same-shaped requests shares device calls as well as
        compilations.
        """
        by_bucket: dict[BucketShape, list[WorkItem]] = defaultdict(list)
        for it in items:
            by_bucket[self.bucket_of(it)].append(it)
        out: dict[tuple[int, int], np.ndarray] = {}
        for shape, group in by_bucket.items():
            self._buckets_seen.add(shape)
            for i in range(0, len(group), self.capacity):
                chunk = group[i : i + self.capacity]
                pred = self.runner(pack_batch(chunk, shape, self.capacity))
                for it, p in zip(chunk, unpack_predictions(pred, chunk, shape)):
                    out[(it.req_id, it.part_index)] = p
                self._items_run += len(chunk)
        return out

    def stats(self) -> SchedulerStats:
        return SchedulerStats(
            compile_count=self.runner.compile_count,
            run_count=self.runner.run_count,
            buckets=sorted(self._buckets_seen, key=lambda b: (b.n_pad, b.e_pad)),
            items_run=self._items_run,
        )
