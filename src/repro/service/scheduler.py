"""Shape-bucketed device scheduler with a compile-count probe.

:class:`BucketRunner` owns the single jitted padded forward pass.  The
Python body of a jitted function executes once per *trace* — i.e. once
per new (shape signature, static args) cache entry — so a plain counter
incremented inside it is an exact compile-count probe.  That probe is
what the acceptance criterion ("N same-family designs trigger <=
num_buckets compilations") asserts against.  After :meth:`BucketRunner.
mark_warm` (the service calls it once compile-ahead warmup finishes),
every further trace also counts as a *cold* compile — the
``service.cold_compiles`` counter a warmed service keeps at zero.

:class:`ShapeBucketScheduler` packs up to ``capacity`` same-bucket items
per device call (:meth:`run_pack`) and reads back per-item real-node
predictions; :class:`SlotPool` is the priority-ordered admission pool
the continuous device loop feeds packs from.  Backends come in two
classes:

  * **shape-stable** ("ref", "onehot"): one compiled executable per
    bucket — the compile-count <= num_buckets guarantee holds, and
    :meth:`ShapeBucketScheduler.warm` can pre-compile the whole bucket
    grid so no user request ever pays a cold jit;
  * **structure-keyed** (the Pallas ``groot*`` backends): each packed
    batch's degree-bucketing plan is a jit constant, so the compile unit
    is the packed *structure*, not the padded shape.  The runner fetches
    the batch's :class:`~repro.kernels.ops.AggPair` from the process-wide
    structural plan cache — a recurring structure (regression farms
    resubmitting the same netlist) reuses the SAME pair object and
    therefore the same compiled executable with 0 new plan builds.
    Warmup primes the pack path and bucket bookkeeping but cannot
    pre-compile unseen structures.
"""
from __future__ import annotations

import dataclasses
import heapq
import threading
from collections import defaultdict, deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gnn
from repro.kernels import ops
from repro.obs import REGISTRY, MetricsRegistry, span
from repro.service.bucketing import (
    BucketShape,
    WorkItem,
    dummy_item,
    pack_batch,
    unpack_predictions,
)

SHAPE_STABLE_BACKENDS = ("ref", "onehot")
STRUCTURE_KEYED_BACKENDS = ("groot", "groot_mxu", "groot_fused")


class BucketRunner:
    """One jitted padded GNN forward; counts compiles and device calls."""

    def __init__(self, params, backend: str = "ref", *, max_structures: int = 64,
                 stream_dtype: str | None = None,
                 metrics: Optional[MetricsRegistry] = None):
        if backend not in SHAPE_STABLE_BACKENDS + STRUCTURE_KEYED_BACKENDS:
            raise ValueError(
                f"service backend must be one of {SHAPE_STABLE_BACKENDS} "
                f"(shape-stable) or {STRUCTURE_KEYED_BACKENDS} "
                f"(structure-keyed, via the plan cache), got {backend!r}"
            )
        self._params = jax.tree_util.tree_map(jnp.asarray, params)
        self._backend = backend
        # edge-stream dtype for the hoisted groot* forward (None/f32 =
        # bit-exact staging; "bfloat16" halves the staged stream bytes)
        self._stream_dtype = stream_dtype
        # per-engine registry for cold-compile attribution (the service
        # passes its own; standalone runners fall back to a private one)
        self._metrics = metrics if metrics is not None else MetricsRegistry()
        self.compile_count = 0
        self.run_count = 0
        #: set by ``mark_warm()`` once compile-ahead warmup is done; any
        #: trace after that is a *cold* compile a user request paid for
        self.warmed = False
        self.cold_compile_count = 0
        # structure-keyed backends: jit retains one executable (+ its
        # embedded plan constants) per static AggPair for the function's
        # lifetime — without a bound, a stream of structurally distinct
        # designs grows host+device memory monotonically.  Past
        # ``max_structures`` distinct pairs the jit cache is dropped
        # wholesale (hot structures re-trace on next sight; the host-side
        # plans stay in PLAN_CACHE, so only XLA compiles are repaid).
        self.max_structures = max_structures
        self._structures_seen: set[int] = set()
        self.jit_cache_clears = 0
        self._lock = threading.Lock()

        def _fwd(params, x, edge_src, edge_dst, edge_inv, edge_slot, num_nodes, agg):
            # Executes at trace time only: one increment per compilation.
            self.compile_count += 1
            REGISTRY.counter("service.runner_compiles").inc()
            if self.warmed:
                self.cold_compile_count += 1
                REGISTRY.counter("service.cold_compiles").inc()
                self._metrics.counter("service.cold_compiles").inc()
            if agg is None and self._backend == "onehot":
                # same pair the pipeline path uses (closures over tracers)
                agg = ops.make_agg_pair(edge_src, edge_dst, num_nodes, "onehot")
            logits = gnn.forward(
                params, x, edge_src, edge_dst, edge_inv, edge_slot,
                num_nodes=num_nodes, agg=agg, stream_dtype=self._stream_dtype,
            )
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)

        self._jit = jax.jit(_fwd, static_argnames=("num_nodes", "agg"))

    @property
    def in_features(self) -> int:
        """Model input width — what warmup's dummy feature rows must be."""
        try:
            return int(self._params["layers"][0]["w_self"].shape[0])
        except (KeyError, IndexError, TypeError):
            return 4

    def mark_warm(self) -> None:
        """Compile-ahead warmup is done: traces from here on are cold."""
        self.warmed = True

    def __call__(self, batch: dict) -> np.ndarray:
        with self._lock:  # one device stream; keeps the probe race-free
            self.run_count += 1
            agg = None
            if self._backend in STRUCTURE_KEYED_BACKENDS:
                # cached by packed-batch structure: a recurring structural
                # hash returns the same pair object -> jit cache hit, 0
                # new plan builds
                agg = ops.make_agg_pair(
                    batch["edge_src"], batch["edge_dst"], batch["num_nodes"],
                    self._backend,
                )
                if id(agg) not in self._structures_seen:
                    if len(self._structures_seen) >= self.max_structures:
                        self._jit.clear_cache()
                        self._structures_seen.clear()
                        self.jit_cache_clears += 1
                    self._structures_seen.add(id(agg))
            return np.asarray(
                self._jit(
                    self._params,
                    jnp.asarray(batch["x"]),
                    jnp.asarray(batch["edge_src"]),
                    jnp.asarray(batch["edge_dst"]),
                    jnp.asarray(batch["edge_inv"]),
                    jnp.asarray(batch["edge_slot"]),
                    num_nodes=batch["num_nodes"],
                    agg=agg,
                )
            )


@dataclasses.dataclass
class SchedulerStats:
    compile_count: int
    run_count: int
    buckets: list[BucketShape]
    items_run: int
    streamed_items: int = 0
    cold_compiles: int = 0
    warm_compiles: int = 0
    warm_shapes: tuple = ()
    warmup_s: float = 0.0


class SlotPool:
    """Priority-ordered pending work items, grouped by bucket shape.

    The continuous device loop's admission structure: ``admit`` slots a
    prepared item under its bucket; ``best_bucket`` names the bucket
    whose head item is globally most urgent (lowest ``(priority, seq)``);
    ``take`` pops up to one pack's worth of that bucket — so a request
    arriving between two device calls joins the very next same-bucket
    pack instead of waiting behind a whole drained wave.  Single-consumer
    (the device thread); producers go through the device queue.
    """

    def __init__(self):
        self._heaps: dict[BucketShape, list] = {}
        self._size = 0

    def __len__(self) -> int:
        return self._size

    def admit(self, shape: BucketShape, priority: int, seq: int, payload) -> None:
        heapq.heappush(
            self._heaps.setdefault(shape, []), (priority, seq, payload)
        )
        self._size += 1

    def head_key(self, shape: BucketShape) -> tuple:
        """The (priority, seq) of the most urgent item in ``shape``."""
        return self._heaps[shape][0][:2]

    def best_bucket(self) -> Optional[BucketShape]:
        best, best_key = None, None
        for shape, heap in self._heaps.items():
            if not heap:
                continue
            key = heap[0][:2]
            if best_key is None or key < best_key:
                best, best_key = shape, key
        return best

    def take(self, shape: BucketShape, n: int) -> list:
        """Pop up to ``n`` payloads of ``shape`` in (priority, seq) order."""
        heap = self._heaps.get(shape, [])
        out = []
        while heap and len(out) < n:
            out.append(heapq.heappop(heap))
        if not heap:
            self._heaps.pop(shape, None)
        self._size -= len(out)
        return out

    def prune(self, dead) -> int:
        """Drop every payload ``dead(payload)`` accepts; returns the count.

        The device loop prunes slots of failed / deadline-expired requests
        each cycle, so their pool occupancy is released immediately rather
        than riding along until their bucket next drains — part of the
        "every failure path releases its resources" contract.
        """
        dropped = 0
        for shape in list(self._heaps):
            heap = self._heaps[shape]
            keep = [entry for entry in heap if not dead(entry[2])]
            dropped += len(heap) - len(keep)
            if not keep:
                self._heaps.pop(shape)
            elif len(keep) != len(heap):
                heapq.heapify(keep)
                self._heaps[shape] = keep
        self._size -= dropped
        return dropped


class ShapeBucketScheduler:
    """Groups work items into shape buckets and runs them batched.

    With ``max_bucket_nodes`` set, an item too large for the largest
    allowed bucket is not rejected: it is auto-routed through the
    partitioned streaming executor (``repro.exec``) — partitioned with
    re-growth into device-sized pieces that themselves land in (capped)
    buckets and stream through the SAME :class:`BucketRunner`, so the
    compile-count probe keeps covering them.
    """

    #: bounded log of recent device packs — (bucket, [req ids], fill) —
    #: what the continuous-batching tests assert admission order against
    PACK_LOG_MAX = 256

    def __init__(
        self,
        params,
        *,
        backend: str = "ref",
        capacity: int = 2,
        min_nodes: int = 64,
        min_edges: int = 128,
        max_structures: int = 64,
        max_bucket_nodes: int | None = None,
        max_bucket_edges: int | None = None,
        stream_capacity: int = 2,
        stream_partitioner: str = "multilevel",
        stream_dtype: str | None = None,
        metrics: Optional[MetricsRegistry] = None,
    ):
        assert capacity >= 1
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.runner = BucketRunner(params, backend, max_structures=max_structures,
                                   stream_dtype=stream_dtype,
                                   metrics=self.metrics)
        self.capacity = capacity
        self.min_nodes = min_nodes
        self.min_edges = min_edges
        self.max_bucket_nodes = max_bucket_nodes
        self.max_bucket_edges = max_bucket_edges
        self.stream_capacity = stream_capacity
        self.stream_partitioner = stream_partitioner
        self._executor = None         # lazy; shares self.runner
        self._buckets_seen: set[BucketShape] = set()
        self._items_run = 0
        self._streamed_items = 0
        self._warm_compiles = 0
        self._warm_shapes: tuple = ()
        self._warmup_s = 0.0
        self.pack_log: deque = deque(maxlen=self.PACK_LOG_MAX)

    def bucket_of(self, item: WorkItem) -> BucketShape:
        return item.bucket(min_nodes=self.min_nodes, min_edges=self.min_edges)

    def _oversized(self, shape: BucketShape) -> bool:
        if self.max_bucket_nodes is not None and shape.n_pad > self.max_bucket_nodes:
            return True
        if self.max_bucket_edges is not None and shape.e_pad > self.max_bucket_edges:
            return True
        return False

    def _stream_item(self, item: WorkItem) -> np.ndarray:
        """Run one oversized item through the partitioned streaming
        executor; returns predictions for every item row (its internal
        partitions' cores tile the item graph)."""
        from repro.core.graph import EdgeGraph
        from repro.exec.plan import choose_k_for_caps
        from repro.exec.stream import StreamingExecutor

        if self._executor is None:
            self._executor = StreamingExecutor(
                runner=self.runner,
                capacity=self.stream_capacity,
                min_nodes=self.min_nodes,
                min_edges=self.min_edges,
            )
        g = EdgeGraph(
            item.num_nodes, item.edge_src, item.edge_dst,
            item.edge_inv, item.edge_slot,
        )
        k = choose_k_for_caps(
            g.num_nodes, g.num_edges,
            self.max_bucket_nodes or g.num_nodes + 1,
            self.max_bucket_edges,
            min_nodes=self.min_nodes, min_edges=self.min_edges,
        )
        # choose_k_for_caps estimates the halo; actual re-growth can
        # overshoot it, so verify the BUILT plan's buckets and re-split
        # finer until every launch really fits the configured ceiling
        plan = self._executor.plan_graph(
            g, k, regrow=True, partitioner=self.stream_partitioner, seed=0
        )
        while k < g.num_nodes and any(
            self._oversized(shape) for shape in plan.buckets
        ):
            k *= 2
            plan = self._executor.plan_graph(
                g, k, regrow=True, partitioner=self.stream_partitioner, seed=0
            )
        self._streamed_items += 1
        pred = self._executor.run_plan(plan, item.feats)
        self._buckets_seen.update(self._executor.buckets_seen)
        return pred[: item.num_nodes]

    def run_pack(
        self, chunk: list[WorkItem], shape: BucketShape
    ) -> dict[tuple[int, int], np.ndarray]:
        """One device call: pack <= ``capacity`` same-bucket items, run,
        unpack.  The continuous device loop's unit of work — between two
        ``run_pack`` calls the loop re-drains its queue, which is what
        admits a newly-prepared request into the next open slot."""
        assert 0 < len(chunk) <= self.capacity
        self._buckets_seen.add(shape)
        with span("scheduler.batch", bucket=str(shape), n=len(chunk)):
            pred = self.runner(pack_batch(chunk, shape, self.capacity))
        out = {}
        for it, p in zip(chunk, unpack_predictions(pred, chunk, shape)):
            out[(it.req_id, it.part_index)] = p
        self._items_run += len(chunk)
        fill = len(chunk) / self.capacity
        self.pack_log.append((shape, [it.req_id for it in chunk], fill))
        self.metrics.gauge("service.slot_occupancy").set(fill)
        # fill as a distribution, not just the last value: p50/p95 of
        # pack utilisation is what the sampler/exporter trend over a run
        self.metrics.histogram("service.pack_fill").observe(fill)
        REGISTRY.counter("scheduler.items_run").inc(len(chunk))
        return out

    def run_items(self, items: list[WorkItem]) -> dict[tuple[int, int], np.ndarray]:
        """Run a set of items; returns (req_id, part_index) -> real-node preds.

        Items of the same bucket are packed ``capacity`` at a time, so a
        burst of same-shaped requests shares device calls as well as
        compilations.  Oversized items stream through the executor.
        (Synchronous convenience over :meth:`run_pack`; the service's
        continuous loop feeds packs one at a time instead.)
        """
        by_bucket: dict[BucketShape, list[WorkItem]] = defaultdict(list)
        out: dict[tuple[int, int], np.ndarray] = {}
        with span("scheduler.run_items", items=len(items)):
            for it in items:
                shape = self.bucket_of(it)
                if self._oversized(shape):
                    out[(it.req_id, it.part_index)] = self._stream_item(it)
                    self._items_run += 1
                else:
                    by_bucket[shape].append(it)
            for shape, group in by_bucket.items():
                for i in range(0, len(group), self.capacity):
                    out.update(self.run_pack(group[i : i + self.capacity], shape))
        return out

    def run_one(self, item: WorkItem) -> dict[tuple[int, int], np.ndarray]:
        """Run a single (possibly oversized) item — the streamed route's
        entry for the continuous loop."""
        shape = self.bucket_of(item)
        if self._oversized(shape):
            pred = self._stream_item(item)
            self._items_run += 1
            REGISTRY.counter("scheduler.items_run").inc()
            return {(item.req_id, item.part_index): pred}
        return self.run_pack([item], shape)

    # -- compile-ahead warmup ------------------------------------------------

    def warm(self, shapes, *, stream: bool = False) -> int:
        """Pre-compile the bucket grid: one dummy pack per (shape,
        slot-layout) so no user request pays a cold jit.  ``stream=True``
        additionally compiles each shape at the streamed route's
        ``stream_capacity`` slot layout (a different jit signature).
        Returns the number of jit traces warmup triggered and marks the
        runner warm — every later trace counts as a cold compile."""
        import time

        t0 = time.perf_counter()
        before = self.runner.compile_count
        f = self.runner.in_features
        capacities = [self.capacity]
        if stream and self.stream_capacity != self.capacity:
            capacities.append(self.stream_capacity)
        warmed = []
        for n_pad, e_pad in shapes:
            shape = BucketShape(int(n_pad), int(e_pad))
            warmed.append((shape.n_pad, shape.e_pad))
            it = dummy_item(f)
            for cap in capacities:
                self.runner(pack_batch([it], shape, cap))
        self._warm_compiles += self.runner.compile_count - before
        self._warm_shapes = tuple(sorted(set(self._warm_shapes) | set(warmed)))
        self._warmup_s += time.perf_counter() - t0
        self.runner.mark_warm()
        self.metrics.counter("service.warmup_compiles").inc(
            self.runner.compile_count - before
        )
        return self.runner.compile_count - before

    def stats(self) -> SchedulerStats:
        return SchedulerStats(
            compile_count=self.runner.compile_count,
            run_count=self.runner.run_count,
            buckets=sorted(self._buckets_seen, key=lambda b: (b.n_pad, b.e_pad)),
            items_run=self._items_run,
            streamed_items=self._streamed_items,
            cold_compiles=self.runner.cold_compile_count,
            warm_compiles=self._warm_compiles,
            warm_shapes=self._warm_shapes,
            warmup_s=self._warmup_s,
        )
