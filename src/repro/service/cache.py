"""Structural-hash result cache.

Verification traffic is heavily duplicated — the same design arrives
from many users (regression farms re-submit identical netlists).  The
cache keys on (structural hash of the AIG, verification config), so a
hit returns the finished verdict without touching the device at all.
LRU-bounded; thread-safe (the prepare pool reads it, the device worker
writes it).
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import Hashable, Optional

from repro import faults


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class ResultCache:
    def __init__(self, capacity: int = 1024):
        assert capacity > 0
        self.capacity = capacity
        self._lock = threading.Lock()
        self._data: OrderedDict[Hashable, object] = OrderedDict()
        self.stats = CacheStats()

    @staticmethod
    def key(design_hash: str, config_key: Hashable) -> Hashable:
        return (design_hash, config_key)

    def get(self, key: Hashable) -> Optional[object]:
        faults.fire("cache.load")
        with self._lock:
            if key in self._data:
                self._data.move_to_end(key)
                self.stats.hits += 1
                return self._data[key]
            self.stats.misses += 1
            return None

    def put(self, key: Hashable, value: object) -> None:
        with self._lock:
            self._data[key] = value
            self._data.move_to_end(key)
            while len(self._data) > self.capacity:
                self._data.popitem(last=False)
                self.stats.evictions += 1

    def __len__(self) -> int:
        with self._lock:
            return len(self._data)
