"""Verification-as-a-service: request-oriented layer over the GROOT flow.

    repro.io.aiger  ->  VerificationService.submit()/poll()
                          |-- ResultCache (structural-hash dedup)
                          |-- prepare pool (host: partition + re-growth)
                          |-- ShapeBucketScheduler (device: padded buckets)
                          `-- verify (host: adders + simulation check)

``python -m repro.service.server`` runs the CLI front end.
"""
from repro.service.cache import CacheStats, ResultCache  # noqa: F401
from repro.service.bucketing import BucketShape, WorkItem, pack_batch  # noqa: F401
from repro.service.scheduler import (  # noqa: F401
    BucketRunner,
    ShapeBucketScheduler,
    SlotPool,
)

_SERVER_EXPORTS = ("AdmissionError", "ServiceConfig", "ServiceResult",
                   "VerificationService")
__all__ = [
    "CacheStats", "ResultCache", "BucketShape", "WorkItem", "pack_batch",
    "BucketRunner", "ShapeBucketScheduler", "SlotPool", *_SERVER_EXPORTS,
]


def __getattr__(name):
    # Lazy so `python -m repro.service.server` doesn't double-import server.
    if name in _SERVER_EXPORTS:
        from repro.service import server

        return getattr(server, name)
    raise AttributeError(name)
