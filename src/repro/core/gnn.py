"""GraphSAGE for AIG node classification (paper §III-C/D), in JAX.

Direction- and polarity-separated SAGE: each layer aggregates three
neighbourhoods with separate weights — non-inverted fanin edges, inverted
fanin edges, and fanout edges.  (AIGs are DAGs: a node's function-root
pattern lives in its *fanin* cone, and the paper's core domain insight is
that the *polarity* of input connections identifies XOR/MAJ structures.)

    h'_u = act( W_s h_u + W_in+ mean_{v->u, pos} h_v
                        + W_in- mean_{v->u, inv} h_v
                        + W_out mean_{u->v} h_v + b )

Aggregation (the SpMM that dominates runtime, §IV) is pluggable:
``aggregate_fn(x, edge_src, edge_dst, num_nodes, w=None)`` — pure-jnp
segment ops (ref), the Pallas GROOT kernel, or the XLA one-hot
formulation.  Inference on partitioned graphs runs per-subgraph and reads
back core-node rows only.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aig as A
from repro.core.graph import EdgeGraph
from repro.core.regrowth import Subgraph
from repro.obs import REGISTRY, span
from repro.training import optimizer as opt


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    in_features: int = 4
    hidden: int = 32
    num_layers: int = 4
    num_classes: int = A.NUM_CLASSES
    dtype: str = "float32"
    # dtype of the staged edge streams (hoisted weight streams + gathered
    # messages) on the groot* backends: "float32" (default, bit-exact) or
    # "bfloat16" (halves the per-layer gather traffic; kernels accumulate
    # in f32, parity bounds pinned by tests/test_forward_plan.py).
    # Honored by the pipeline/service/executor paths, which read the
    # config; direct ``gnn.forward``/``gnn.predict`` callers pass the
    # explicit ``stream_dtype=`` kwarg instead (forward never sees a
    # GNNConfig).
    stream_dtype: str = "float32"


IN_GROUPS = ("w_in_l_pos", "w_in_l_neg", "w_in_r_pos", "w_in_r_neg")
OUT_GROUPS = ("w_out_pos", "w_out_neg")


def init_params(cfg: GNNConfig, key) -> dict:
    dims = [cfg.in_features] + [cfg.hidden] * cfg.num_layers
    params = {"layers": []}
    for i in range(cfg.num_layers):
        names = ("w_self",) + IN_GROUPS + OUT_GROUPS
        key, *keys = jax.random.split(key, 1 + len(names))
        s = 1.0 / np.sqrt(dims[i])
        layer = {
            nm: jax.random.uniform(kk, (dims[i], dims[i + 1]), jnp.float32, -s, s)
            for nm, kk in zip(names, keys)
        }
        layer["b"] = jnp.zeros((dims[i + 1],), jnp.float32)
        params["layers"].append(layer)
    key, kh = jax.random.split(key)
    s = 1.0 / np.sqrt(cfg.hidden)
    params["head"] = {
        "w": jax.random.uniform(kh, (cfg.hidden, cfg.num_classes), jnp.float32, -s, s),
        "b": jnp.zeros((cfg.num_classes,), jnp.float32),
    }
    return params


# ---------------------------------------------------------------------------
# Aggregation backends.  Signature:
#   agg(x, edge_src, edge_dst, num_nodes, w=None) ->
#       sum over incoming edges of w_e * x[src] per dst row.
# ---------------------------------------------------------------------------

def segment_sum_agg(x, edge_src, edge_dst, num_nodes, w=None):
    """Reference: gather + segment-sum (what PyG/GNNAdvisor-style row
    parallel SpMM computes)."""
    msgs = x[edge_src]
    if w is not None:
        msgs = msgs * w[:, None]
    return jax.ops.segment_sum(msgs, edge_dst, num_segments=num_nodes)


def forward(
    params,
    x,
    edge_src,
    edge_dst,
    edge_inv=None,
    edge_slot=None,
    *,
    num_nodes: int,
    agg=None,
    stream_dtype: Optional[str] = None,
):
    """Full forward pass -> logits (num_nodes, num_classes).

    In-edges are aggregated in four (slot x polarity) groups.  Each AIG node
    has at most one edge per group, so group aggregation is *exact* ordered
    message passing (no mean washout) while remaining an SpMM — the same
    kernel serves all groups (weights select the group) plus the fanout
    direction, which is where the HD/LD degree polarization lives.

    ``agg`` is an :class:`repro.kernels.ops.AggPair` (or None for the
    segment-sum reference).  Paths, most specific wins:

      * **hoisted grouped** (``fwd_plan`` present — all ``groot*``
        backends): the grouped path below, plus everything layer-invariant
        hoisted out of the layer loop via the
        :class:`~repro.kernels.forward_plan.ForwardPlan`: the group-weight
        streams are staged into kernel layout ONCE per forward (2 weight
        gathers total, not 2 per layer), activations are padded once per
        layer and shared by both directions, and output assembly is a
        single scatter-free permutation gather.  ``stream_dtype="bfloat16"``
        stages the edge streams narrow (f32 accumulation in-kernel).
      * **grouped** (``in_agg_grouped`` present): the four fanin and two
        fanout groups are *channels of one SpMM*.  The ``(E, 4)`` /
        ``(E, 2)`` group-weight matrices are
        built once, the mean norms are folded into them (exact — every
        edge's destination norm is known per edge), and each layer issues
        ONE grouped aggregation per direction: 6 -> 2 edge-stream gathers
        and 6 -> 2 bucket-kernel walks per layer.  The per-group ``@ W``
        collapses to one ``einsum('gnf,gfh->nh')`` contraction (or is
        fused into the grouped kernel when ``in_agg_mm_grouped`` exists).
      * **fused per-group** (``in_agg_mm``): per-group ``agg @ W`` inside
        the kernel, norm folded into edge weights (post-scaling would be
        wrong: the aggregated row is never materialised).
      * **per-group loop** (ref/onehot/None): aggregate per group, then
        post-scale by the per-destination norm ((N,1) elementwise — under
        SPMD a per-edge gather of the (N,) norm array forces a 0.7 GB
        all-gather per group, measured in §Perf).
    """
    # Executes at trace time only (the body of every jitted caller —
    # _predict, the runner's _fwd — runs once per compilation), so this is
    # the process-wide compile probe all three routes share.
    REGISTRY.counter("gnn.forward_traces").inc()
    one = jnp.ones_like(edge_dst, dtype=x.dtype)
    w_neg = edge_inv.astype(x.dtype) if edge_inv is not None else jnp.zeros_like(one)
    w_pos = 1.0 - w_neg
    w_r = edge_slot.astype(x.dtype) if edge_slot is not None else jnp.zeros_like(one)
    w_l = 1.0 - w_r
    group_w = {
        "w_in_l_pos": w_l * w_pos,
        "w_in_l_neg": w_l * w_neg,
        "w_in_r_pos": w_r * w_pos,
        "w_in_r_neg": w_r * w_neg,
    }
    out_w = {"w_out_pos": w_pos, "w_out_neg": w_neg}

    in_grouped = getattr(agg, "in_agg_grouped", None)
    out_grouped = getattr(agg, "out_agg_grouped", None)
    if in_grouped is not None and out_grouped is not None:
        return _forward_grouped(
            params, x, edge_src, edge_dst, group_w, out_w, num_nodes, agg,
            stream_dtype=stream_dtype,
        )

    deg = lambda idx, w: jax.ops.segment_sum(w, idx, num_segments=num_nodes)
    norm_in = {
        nm: (1.0 / jnp.maximum(deg(edge_dst, w), 1.0))[:, None]
        for nm, w in group_w.items()
    }
    norm_out = {
        nm: (1.0 / jnp.maximum(deg(edge_src, w), 1.0))[:, None]
        for nm, w in out_w.items()
    }

    if agg is None:
        in_agg = lambda h, w: segment_sum_agg(h, edge_src, edge_dst, num_nodes, w)
        out_agg = lambda h, w: segment_sum_agg(h, edge_dst, edge_src, num_nodes, w)
        in_agg_mm = None
    else:
        in_agg, out_agg, in_agg_mm = agg.in_agg, agg.out_agg, agg.in_agg_mm

    if in_agg_mm is not None:  # fused path: fold norms into edge weights
        group_w = {nm: w * norm_in[nm][:, 0][edge_dst] for nm, w in group_w.items()}

    h = x
    for layer in params["layers"]:
        acc = h @ layer["w_self"] + layer["b"]
        for nm in IN_GROUPS:
            if in_agg_mm is not None:
                acc = acc + in_agg_mm(h, group_w[nm], layer[nm])
            else:
                acc = acc + (in_agg(h, group_w[nm]) * norm_in[nm]) @ layer[nm]
        for nm in OUT_GROUPS:
            acc = acc + (out_agg(h, out_w[nm]) * norm_out[nm]) @ layer[nm]
        h = jax.nn.relu(acc)
    return h @ params["head"]["w"] + params["head"]["b"]


def _forward_grouped(params, x, edge_src, edge_dst, group_w, out_w, num_nodes, agg,
                     *, stream_dtype: Optional[str] = None):
    """Grouped hot path: one aggregation per direction per layer.

    Group weights become ``(E, G)`` matrices (column order = IN_GROUPS /
    OUT_GROUPS) with the per-destination mean norm folded in, so the
    grouped SpMM's output planes are already normalised and the layer
    reduces to ``einsum('gnf,gfh->nh')`` over the stacked group weights.

    When the pair carries a :class:`~repro.kernels.forward_plan.ForwardPlan`
    the loop below is replaced by :func:`_forward_hoisted`; this body is
    the pre-hoist walk, kept as the bit-exactness oracle
    (``ops.unhoisted(pair)`` routes here).
    """
    wg_in = jnp.stack([group_w[nm] for nm in IN_GROUPS], axis=1)     # (E, 4)
    wg_out = jnp.stack([out_w[nm] for nm in OUT_GROUPS], axis=1)     # (E, 2)
    # per-group in/out degrees in ONE segment-sum per direction (the
    # per-group path needs six)
    deg_in = jax.ops.segment_sum(wg_in, edge_dst, num_segments=num_nodes)
    deg_out = jax.ops.segment_sum(wg_out, edge_src, num_segments=num_nodes)
    wg_in = wg_in * (1.0 / jnp.maximum(deg_in, 1.0))[edge_dst]
    wg_out = wg_out * (1.0 / jnp.maximum(deg_out, 1.0))[edge_src]

    fp = getattr(agg, "fwd_plan", None)
    if fp is not None and agg.in_agg_staged is not None:
        return _forward_hoisted(params, x, wg_in, wg_out, agg, fp, stream_dtype)

    h = x
    for layer in params["layers"]:
        acc = h @ layer["w_self"] + layer["b"]
        w_in_stack = jnp.stack([layer[nm] for nm in IN_GROUPS], axis=0)
        w_out_stack = jnp.stack([layer[nm] for nm in OUT_GROUPS], axis=0)
        if agg.in_agg_mm_grouped is not None:
            acc = acc + agg.in_agg_mm_grouped(h, wg_in, w_in_stack)
        else:
            gin = agg.in_agg_grouped(h, wg_in)                       # (4, N, F)
            acc = acc + jnp.einsum("gnf,gfh->nh", gin.astype(acc.dtype), w_in_stack)
        gout = agg.out_agg_grouped(h, wg_out)                        # (2, N, F)
        acc = acc + jnp.einsum("gnf,gfh->nh", gout.astype(acc.dtype), w_out_stack)
        h = jax.nn.relu(acc)
    return h @ params["head"]["w"] + params["head"]["b"]


def _forward_hoisted(params, x, wg_in, wg_out, agg, fp, stream_dtype):
    """Hoisted grouped hot path: everything layer-invariant leaves the loop.

    The :class:`~repro.kernels.forward_plan.ForwardPlan` contract:

      * the fanin/fanout group-weight streams are staged into each
        bucket's ELL layout (and the HD chunk layout) ONCE — 2 weight
        gathers per FORWARD, so layers 2..L touch zero edge-weight bytes;
      * activations are padded once per layer, shared by both direction
        walks (pre-hoist each aggregation padded its own copy);
      * the fused path's per-layer weight stacks are padded in a prologue;
      * output assembly inside the staged walks is one permutation gather
        — zero ``.at[].add`` scatters per forward.

    ``stream_dtype="bfloat16"`` narrows the staged weight streams and the
    gathered messages; kernels accumulate in f32.
    """
    sdt = None
    if stream_dtype is not None and jnp.dtype(stream_dtype) != jnp.float32:
        sdt = jnp.dtype(stream_dtype)
    sw_in = fp.stage_in(wg_in, dtype=sdt)
    sw_out = fp.stage_out(wg_out, dtype=sdt)
    layers = params["layers"]
    fused = agg.in_agg_mm_staged is not None
    stacks_in = [jnp.stack([l[nm] for nm in IN_GROUPS], axis=0) for l in layers]
    stacks_out = [jnp.stack([l[nm] for nm in OUT_GROUPS], axis=0) for l in layers]
    if fused:
        stacks_in = [fp.pad_weight_stack(s) for s in stacks_in]

    h = x
    for layer, w_in_stack, w_out_stack in zip(layers, stacks_in, stacks_out):
        acc = h @ layer["w_self"] + layer["b"]
        f = h.shape[1]
        h_p = fp.pad_x(h)
        if sdt is not None:
            h_p = h_p.astype(sdt)
        if fused:
            acc = acc + agg.in_agg_mm_staged(h_p, sw_in, w_in_stack)[
                :, : acc.shape[1]
            ].astype(acc.dtype)
        else:
            gin = agg.in_agg_staged(h_p, sw_in)[:, :, :f]            # (4, N, F)
            acc = acc + jnp.einsum("gnf,gfh->nh", gin.astype(acc.dtype), w_in_stack)
        gout = agg.out_agg_staged(h_p, sw_out)[:, :, :f]             # (2, N, F)
        acc = acc + jnp.einsum("gnf,gfh->nh", gout.astype(acc.dtype), w_out_stack)
        h = jax.nn.relu(acc)
    return h @ params["head"]["w"] + params["head"]["b"]


def loss_fn(params, batch):
    logits = forward(
        params,
        batch["x"],
        batch["edge_src"],
        batch["edge_dst"],
        batch.get("edge_inv"),
        batch.get("edge_slot"),
        num_nodes=batch["x"].shape[0],
    )
    logp = jax.nn.log_softmax(logits)
    ll = jnp.take_along_axis(logp, batch["labels"][:, None], axis=1)[:, 0]
    mask = batch.get("mask")
    if mask is not None:
        return -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return -ll.mean()


@partial(jax.jit, static_argnames=("optimizer",))
def train_step(params, state, batch, optimizer: opt.AdamW):
    loss, grads = jax.value_and_grad(loss_fn)(params, batch)
    updates, state = optimizer.update(grads, state, params)
    params = opt.apply_updates(params, updates)
    return params, state, loss


def make_batch(design, features: np.ndarray, labels: np.ndarray) -> dict:
    g = design.to_edge_graph() if hasattr(design, "to_edge_graph") else design
    batch = {
        "x": jnp.asarray(features),
        "edge_src": jnp.asarray(g.edge_src),
        "edge_dst": jnp.asarray(g.edge_dst),
        "labels": jnp.asarray(labels.astype(np.int32)),
    }
    if g.edge_inv is not None:
        batch["edge_inv"] = jnp.asarray(g.edge_inv)
    if g.edge_slot is not None:
        batch["edge_slot"] = jnp.asarray(g.edge_slot)
    return batch


def train(
    params,
    batch: dict,
    *,
    epochs: int = 200,
    lr: float = 5e-3,
    log_every: int = 0,
) -> tuple[dict, list]:
    optimizer = opt.AdamW(lr=lr, weight_decay=1e-4)
    state = optimizer.init(params)
    history = []
    for e in range(epochs):
        params, state, loss = train_step(params, state, batch, optimizer)
        if log_every and (e % log_every == 0 or e == epochs - 1):
            history.append((e, float(loss)))
    return params, history


@partial(jax.jit, static_argnames=("num_nodes", "agg", "stream_dtype"))
def _predict(params, x, edge_src, edge_dst, edge_inv, edge_slot, num_nodes, agg,
             stream_dtype=None):
    return jnp.argmax(
        forward(
            params, x, edge_src, edge_dst, edge_inv, edge_slot,
            num_nodes=num_nodes, agg=agg, stream_dtype=stream_dtype,
        ),
        axis=-1,
    ).astype(jnp.int32)


def _make_agg(g, backend: str):
    """Build the kernel-backend aggregation pair for a graph (None = ref)."""
    if backend in (None, "ref"):
        return None
    from repro.kernels import ops

    return ops.make_agg_pair(g.edge_src, g.edge_dst, g.num_nodes, backend)


def predict(
    params, design, features, backend: str = "ref",
    *, stream_dtype: Optional[str] = None,
) -> np.ndarray:
    g = design.to_edge_graph() if hasattr(design, "to_edge_graph") else design
    inv = None if g.edge_inv is None else jnp.asarray(g.edge_inv)
    slot = None if g.edge_slot is None else jnp.asarray(g.edge_slot)
    feats = np.asarray(features)
    # staged h2d bytes: features + the edge index/annotation arrays
    REGISTRY.counter("gnn.bytes_staged").inc(
        feats.nbytes + 2 * g.edge_src.nbytes + 2 * g.edge_dst.nbytes
    )
    with span("gnn.predict", backend=backend, nodes=g.num_nodes):
        REGISTRY.counter("gnn.predicts").inc()
        return np.asarray(
            _predict(
                params,
                jnp.asarray(feats),
                jnp.asarray(g.edge_src),
                jnp.asarray(g.edge_dst),
                inv,
                slot,
                g.num_nodes,
                _make_agg(g, backend),
                stream_dtype,
            )
        )


def predict_partitioned(
    params,
    subgraphs: list[Subgraph],
    features: np.ndarray,
    num_nodes: int,
    backend: str = "ref",
    *,
    streaming: bool = True,
    capacity: int = 2,
    prefetch: int = 1,
    stream_dtype: Optional[str] = None,
) -> np.ndarray:
    """DEPRECATED: per-partition inference; core-node predictions only.

    Use :class:`repro.api.Session` (whose router picks the streamed or
    sequential path) or call
    :func:`repro.exec.stream.stream_predict_partitioned` /
    :func:`predict_partitioned_loop` directly.  Kept as a
    behaviour-preserving shim: each subgraph is an independent
    device-sized problem, streamed through the ``repro.exec`` executor by
    default, or run through the sequential per-subgraph loop with
    ``streaming=False`` — bit-exact on core rows either way.
    """
    import warnings

    warnings.warn(
        "gnn.predict_partitioned is deprecated; use repro.api.Session "
        "(or stream_predict_partitioned / predict_partitioned_loop)",
        DeprecationWarning,
        stacklevel=2,
    )
    if streaming:
        from repro.exec.stream import stream_predict_partitioned

        return stream_predict_partitioned(
            params, subgraphs, features, num_nodes, backend,
            capacity=capacity, prefetch=prefetch, stream_dtype=stream_dtype,
        )
    return predict_partitioned_loop(
        params, subgraphs, features, num_nodes, backend,
        stream_dtype=stream_dtype,
    )


def predict_partitioned_loop(
    params,
    subgraphs: list[Subgraph],
    features: np.ndarray,
    num_nodes: int,
    backend: str = "ref",
    *,
    stream_dtype: Optional[str] = None,
) -> np.ndarray:
    """Sequential reference: one unpadded device call per subgraph.

    Kept as the bit-exactness oracle for the streaming executor and as the
    baseline ``benchmarks/bench_partitioned.py`` measures against (it
    recompiles per subgraph shape and staging never overlaps the device).
    Predictions are int32 end-to-end (``_predict`` emits int32 argmax),
    matching the streamed path — parity never rides on an implicit upcast.
    """
    out = np.zeros(num_nodes, dtype=np.int32)
    for sg in subgraphs:
        feats = jnp.asarray(features[sg.global_ids])
        inv = None if sg.edge_inv is None else jnp.asarray(sg.edge_inv)
        slot = None if sg.edge_slot is None else jnp.asarray(sg.edge_slot)
        REGISTRY.counter("gnn.loop_launches").inc()
        REGISTRY.counter("gnn.bytes_staged").inc(
            int(feats.nbytes) + 2 * sg.edge_src.nbytes + 2 * sg.edge_dst.nbytes
        )
        pred = _predict(
            params,
            feats,
            jnp.asarray(sg.edge_src),
            jnp.asarray(sg.edge_dst),
            inv,
            slot,
            sg.num_nodes,
            _make_agg(sg.to_edge_graph(), backend),
            stream_dtype,
        )
        out[sg.global_ids[: sg.num_core]] = np.asarray(pred)[: sg.num_core]
    return out


def accuracy(pred: np.ndarray, labels: np.ndarray) -> float:
    return float((pred == labels).mean())
