"""Graph containers for EDA (AIG-derived) graphs.

All host-side graph manipulation (generation, partitioning, re-growth) is
numpy-based; device arrays are produced only at the batching boundary.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class EdgeGraph:
    """A directed graph as flat edge arrays (COO), nodes are 0..num_nodes-1.

    ``edge_src[k] -> edge_dst[k]`` is a directed edge.  For AIGs the direction
    is fanin -> node (signal flow).  ``edge_inv[k]`` marks an inverted edge;
    ``edge_slot[k]`` is the fanin position (0=left, 1=right — AIG nodes have
    exactly two ordered fanins, the ordering the paper's '01'/'10' polarity
    encoding relies on).
    """

    num_nodes: int
    edge_src: np.ndarray  # int32 (E,)
    edge_dst: np.ndarray  # int32 (E,)
    edge_inv: Optional[np.ndarray] = None  # bool (E,)
    edge_slot: Optional[np.ndarray] = None  # uint8 (E,)

    @property
    def num_edges(self) -> int:
        return int(self.edge_src.shape[0])

    def validate(self) -> None:
        assert self.edge_src.shape == self.edge_dst.shape
        if self.num_edges:
            assert self.edge_src.min() >= 0 and self.edge_src.max() < self.num_nodes
            assert self.edge_dst.min() >= 0 and self.edge_dst.max() < self.num_nodes

    def symmetrized(self) -> "EdgeGraph":
        """Undirected message-passing view: A + A^T (deduplicated)."""
        src = np.concatenate([self.edge_src, self.edge_dst])
        dst = np.concatenate([self.edge_dst, self.edge_src])
        key = src.astype(np.int64) * self.num_nodes + dst
        _, idx = np.unique(key, return_index=True)
        return EdgeGraph(self.num_nodes, src[idx], dst[idx])

    def in_degrees(self) -> np.ndarray:
        return np.bincount(self.edge_dst, minlength=self.num_nodes).astype(np.int32)

    def out_degrees(self) -> np.ndarray:
        return np.bincount(self.edge_src, minlength=self.num_nodes).astype(np.int32)

    def csr(self) -> tuple[np.ndarray, np.ndarray]:
        """Return (row_ptr, col_idx) with rows = edge_dst (aggregation rows).

        Row i's entries are the *sources* of edges arriving at node i — the
        neighbours aggregated by one step of message passing.
        """
        order = np.argsort(self.edge_dst, kind="stable")
        col = self.edge_src[order].astype(np.int32)
        counts = np.bincount(self.edge_dst, minlength=self.num_nodes)
        row_ptr = np.zeros(self.num_nodes + 1, dtype=np.int64)
        np.cumsum(counts, out=row_ptr[1:])
        return row_ptr, col

    def subgraph_edge_mask(self, node_mask: np.ndarray) -> np.ndarray:
        """Edges with BOTH endpoints inside ``node_mask`` (E[S] in the paper)."""
        return node_mask[self.edge_src] & node_mask[self.edge_dst]


def batch_graphs(graphs: list[EdgeGraph]) -> EdgeGraph:
    """Disjoint-union batching (the paper's "batch size" of identical designs)."""
    offsets = np.cumsum([0] + [g.num_nodes for g in graphs])
    src = np.concatenate([g.edge_src + off for g, off in zip(graphs, offsets)])
    dst = np.concatenate([g.edge_dst + off for g, off in zip(graphs, offsets)])
    inv = None
    if all(g.edge_inv is not None for g in graphs):
        inv = np.concatenate([g.edge_inv for g in graphs])
    slot = None
    if all(g.edge_slot is not None for g in graphs):
        slot = np.concatenate([g.edge_slot for g in graphs])
    return EdgeGraph(
        int(offsets[-1]), src.astype(np.int32), dst.astype(np.int32), inv, slot
    )
