"""GROOT's 4-bit node features (§III-B) + GAMORA's 3-bit baseline features.

Feature layout (one bit per column, float32 0/1):

  bits[0:2]  node type:     PI -> 00,  internal AND -> 11,  PO -> 0X
             (X = polarity of the PO's single driving edge)
  bits[2:4]  input polarity: AND -> (left_inverted, right_inverted)
             PI -> 00;  PO -> 11  (the paper's worked example: PO m0 = 0011)

This reproduces the paper's vector table exactly:
  node 5  (AND, both inputs non-inv)  -> 1100
  node 10 (AND, both inputs inverted) -> 1111
  node 1  (PI)                        -> 0000
  node 15 (PO, non-inverted driver)   -> 0011
"""
from __future__ import annotations

import numpy as np

from repro.core import aig as A


def groot_features(design) -> np.ndarray:
    """4-bit GROOT features for an AIG (or LUTGraph, which generalizes)."""
    if isinstance(design, A.AIG):
        n = design.num_nodes
        feat = np.zeros((n, 4), dtype=np.float32)
        is_and = design.kind == A.AND
        is_po = design.kind == A.PO
        # type bits
        feat[is_and, 0] = 1.0
        feat[is_and, 1] = 1.0
        feat[is_po, 1] = (design.fanin0[is_po] & 1).astype(np.float32)  # 0X
        # polarity bits
        feat[is_and, 2] = (design.fanin0[is_and] & 1).astype(np.float32)
        feat[is_and, 3] = (design.fanin1[is_and] & 1).astype(np.float32)
        feat[is_po, 2] = 1.0
        feat[is_po, 3] = 1.0
        return feat
    # LUTGraph: type bits as for AIG; polarity bits = (any leaf inverted,
    # all leaves inverted) aggregated over the LUT cone's boundary edges.
    n = design.num_nodes
    feat = np.zeros((n, 4), dtype=np.float32)
    is_and = design.kind == A.AND
    is_po = design.kind == A.PO
    feat[is_and, 0] = 1.0
    feat[is_and, 1] = 1.0
    inv_any = np.zeros(n, dtype=bool)
    inv_all = np.ones(n, dtype=bool)
    np.logical_or.at(inv_any, design.edge_dst, design.edge_inv)
    np.logical_and.at(inv_all, design.edge_dst, design.edge_inv)
    has_in = np.zeros(n, dtype=bool)
    has_in[design.edge_dst] = True
    inv_all &= has_in
    feat[is_po, 1] = inv_any[is_po].astype(np.float32)
    feat[is_and, 2] = inv_any[is_and].astype(np.float32)
    feat[is_and, 3] = inv_all[is_and].astype(np.float32)
    feat[is_po, 2] = 1.0
    feat[is_po, 3] = 1.0
    return feat


def gamora_features(design) -> np.ndarray:
    """The 3-feature baseline of GAMORA [7]: (node type as one value,
    #inverted fanins, #fanins) — PI/PO not distinguished, the gap the paper
    calls out.  Used for the feature-ablation benchmark."""
    if isinstance(design, A.AIG):
        n = design.num_nodes
        feat = np.zeros((n, 3), dtype=np.float32)
        is_and = design.kind == A.AND
        feat[is_and, 0] = 1.0  # "gate" vs "terminal" — PI and PO collapse to 0
        n_inv = (design.fanin0 & 1) + (design.fanin1 & 1)
        feat[is_and, 1] = n_inv[is_and].astype(np.float32)
        is_po = design.kind == A.PO
        feat[is_po, 1] = (design.fanin0[is_po] & 1).astype(np.float32)
        feat[is_and, 2] = 2.0
        feat[is_po, 2] = 1.0
        return feat
    n = design.num_nodes
    feat = np.zeros((n, 3), dtype=np.float32)
    is_and = design.kind == A.AND
    feat[is_and, 0] = 1.0
    ninv = np.zeros(n, dtype=np.float32)
    np.add.at(ninv, design.edge_dst, design.edge_inv.astype(np.float32))
    deg = np.zeros(n, dtype=np.float32)
    np.add.at(deg, design.edge_dst, 1.0)
    feat[:, 1] = ninv
    feat[:, 2] = deg
    return feat
