"""Verification post-processing (paper §III-D).

The paper feeds GNN-detected XOR/MAJ roots to ABC's algebraic rewriting,
where substituting the XOR3/MAJ polynomials

    x1 + 2*x2 = (a+b+c-2ab-2ac-2bc+4abc) + 2(ab+ac+bc-2abc) = a+b+c

cancels all nonlinear monomials.  Offline (no ABC) we implement the same
two checks it performs:

  1. **Adder extraction + bit-flow conservation** (Ciesielski et al. [20]):
     pair each predicted MAJ root with the XOR root over the same input
     support -> full/half adders; verify every compressor stage conserves
     sum-of-weights (k inputs at weight w -> sum at w + carry at 2w);
     coverage failures (mispredicted nodes) make the check inconclusive —
     this is how node-classification accuracy *is* verification accuracy.
  2. **Simulation cross-check**: random-vector simulation of the AIG
     against the integer spec (exhaustive for small widths).

Also hosts the *algebraic reduction score*: the count of nonlinear terms
eliminated by x1+2x2 substitutions, reported by bench_verification.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import aig as A


@dataclasses.dataclass
class VerifyResult:
    status: str             # "verified" | "inconclusive" | "falsified"
    n_adders: int
    n_xor_pred: int
    n_maj_pred: int
    coverage: float         # fraction of true adder roots recovered
    nonlinear_terms_eliminated: int
    detail: str = ""


def _support(aig: A.AIG, maj_root: int) -> frozenset:
    """Input literal support {a,b,c} of a MAJ root (or {a,b} for HA carry)."""
    f0, f1, kind = aig.fanin0, aig.fanin1, aig.kind
    u, v = f0[maj_root] >> 1, f1[maj_root] >> 1
    pu, pv = f0[maj_root] & 1, f1[maj_root] & 1
    if pu == 1 and pv == 1 and kind[u] == A.AND and kind[v] == A.AND:
        # FA carry: OR(t1, t3): t1=AND(a,b), t3=AND(xor_ab, c)
        for t1, t3 in ((u, v), (v, u)):
            a_, b_ = f0[t1], f1[t1]
            for xl, c in ((f0[t3], f1[t3]), (f1[t3], f0[t3])):
                xn = xl >> 1
                if kind[xn] != A.AND:
                    continue
                g = f0[xn] >> 1
                if kind[g] != A.AND:
                    continue
                cc = {int(f0[g]) >> 1, int(f1[g]) >> 1}
                if cc == {int(a_) >> 1, int(b_) >> 1}:
                    return frozenset((int(a_) >> 1, int(b_) >> 1, int(c) >> 1))
    # HA carry: AND(a,b)
    return frozenset((int(f0[maj_root]) >> 1, int(f1[maj_root]) >> 1))


def extract_adders(aig: A.AIG, pred: np.ndarray) -> tuple[list, float]:
    """Pair predicted MAJ roots with predicted XOR roots on the same support.

    Returns (adders, coverage-vs-ground-truth).  An adder = (kind, support,
    sum_root, carry_root); kind in {"FA", "HA"}.
    """
    kind, f0, f1 = aig.kind, aig.fanin0, aig.fanin1
    maj_roots = np.where((pred == A.LABEL_MAJ) & (kind == A.AND))[0]
    xor_roots = np.where((pred == A.LABEL_XOR) & (kind == A.AND))[0]

    # xor root -> support (over grandchildren variables)
    xor_by_support: dict[frozenset, int] = {}
    for x in xor_roots:
        u = f0[x] >> 1
        if kind[u] != A.AND:
            continue
        sup = frozenset((int(f0[u]) >> 1, int(f1[u]) >> 1))
        xor_by_support[sup] = int(x)

    adders = []
    for mroot in maj_roots:
        sup = _support(aig, int(mroot))
        if len(sup) == 3:
            # FA: sum = XOR(xor(a,b), c): outer xor support = {inner_xor, c}
            inner = None
            for pair in (frozenset(p) for p in _pairs(sup)):
                if pair in xor_by_support:
                    inner = xor_by_support[pair]
                    rest = tuple(sup - pair)[0]
                    outer = xor_by_support.get(frozenset((inner, rest)))
                    if outer is not None:
                        adders.append(("FA", sup, int(outer), int(mroot)))
                        break
            else:
                continue
        else:
            sroot = xor_by_support.get(sup)
            if sroot is not None:
                adders.append(("HA", sup, int(sroot), int(mroot)))

    true_majs = set(np.where(aig.label == A.LABEL_MAJ)[0].tolist())
    got_majs = {a[3] for a in adders}
    coverage = len(got_majs & true_majs) / max(len(true_majs), 1)
    return adders, coverage


def _pairs(s):
    s = sorted(s)
    for i in range(len(s)):
        for j in range(i + 1, len(s)):
            yield (s[i], s[j])


def algebraic_reduction_terms(adders: list) -> int:
    """Nonlinear monomials eliminated by the x1 + 2*x2 substitution:
    FA kills {2ab, 2ac, 2bc, 4abc} = 4 terms; HA (x1+2*x2 with MAJ(a,b,0))
    kills {2ab} = 1 term (paper §III-D)."""
    return sum(4 if a[0] == "FA" else 1 for a in adders)


def simulation_check(aig: A.AIG, bits: int, signed: bool, n_vectors: int = 256, seed: int = 0) -> bool:
    """Random (exhaustive when feasible) simulation vs the integer spec."""
    rng = np.random.default_rng(seed)
    if 2 * bits <= 16:
        a = np.arange(2**bits, dtype=np.int64)
        a, b = np.meshgrid(a, a)
        a, b = a.ravel(), b.ravel()
    else:
        a = rng.integers(0, 2**bits, n_vectors, dtype=np.int64)
        b = rng.integers(0, 2**bits, n_vectors, dtype=np.int64)
    pis = np.zeros((2 * bits, len(a)), dtype=bool)
    for i in range(bits):
        pis[i] = (a >> i) & 1
        pis[bits + i] = (b >> i) & 1
    out = aig.simulate(pis)
    if 2 * bits <= 64:
        # products fit machine words: accumulate in uint64 (wrap-around
        # multiply IS reduction mod 2^64, and mod 2^(2*bits) is a mask)
        mask = np.uint64((1 << (2 * bits)) - 1) if 2 * bits < 64 \
            else np.uint64(0xFFFFFFFFFFFFFFFF)
        got = np.zeros(len(a), dtype=np.uint64)
        for k in range(out.shape[0]):
            got += out[k].astype(np.uint64) << np.uint64(k)
        ua, ub = a.astype(np.uint64), b.astype(np.uint64)
        if signed:
            # two's complement: sign-extend to the 2*bits ring before the
            # wrap-around multiply; the mask makes the rings agree
            sign_a = (ua >> np.uint64(bits - 1)) & np.uint64(1)
            sign_b = (ub >> np.uint64(bits - 1)) & np.uint64(1)
            ext = np.uint64(1 << bits)          # bits <= 32 on this path
            with np.errstate(over="ignore"):
                ua = ua - ext * sign_a
                ub = ub - ext * sign_b
                want = (ua * ub) & mask
        else:
            with np.errstate(over="ignore"):
                want = (ua * ub) & mask
        return bool(np.all((got & mask) == want))
    # wide multipliers: python bignums (dtype=object) keep exactness
    got = np.zeros(len(a), dtype=object)
    for k in range(out.shape[0]):
        got += out[k].astype(object) * (1 << k)
    if signed:
        sa = a - (1 << bits) * ((a >> (bits - 1)) & 1)
        sb = b - (1 << bits) * ((b >> (bits - 1)) & 1)
        want = (sa.astype(object) * sb.astype(object)) % (1 << (2 * bits))
    else:
        want = (a.astype(object) * b.astype(object)) % (1 << (2 * bits))
    return bool(np.all(got == want))


def verify(aig: A.AIG, pred: np.ndarray, *, bits: int, signed: bool = False,
           simulate: bool = True) -> VerifyResult:
    adders, coverage = extract_adders(aig, pred)
    n_xor = int(((pred == A.LABEL_XOR) & (aig.kind == A.AND)).sum())
    n_maj = int(((pred == A.LABEL_MAJ) & (aig.kind == A.AND)).sum())
    terms = algebraic_reduction_terms(adders)
    if coverage < 0.999:
        status = "inconclusive"
        detail = f"adder extraction covered {coverage:.2%} of compressor tree"
    else:
        ok = simulation_check(aig, bits, signed) if simulate else True
        status = "verified" if ok else "falsified"
        detail = "bit-flow conserved; simulation agreed" if ok else "simulation mismatch"
    return VerifyResult(
        status=status,
        n_adders=len(adders),
        n_xor_pred=n_xor,
        n_maj_pred=n_maj,
        coverage=coverage,
        nonlinear_terms_eliminated=terms,
        detail=detail,
    )
