"""Boundary edge re-growth (paper §III-C, Algorithm 1).

For each partition p with node set S_p:

    B_p = ( U_{u in S_p} N(u) ) \\ S_p            (Eq. 1, boundary nodes)
    C_p = { (i,j) in E : i in S_p, j in B_p  or  i in B_p, j in S_p }  (Eq. 2)
    S_p+ = S_p u B_p ;   E_p+ = E[S_p] u C_p       (augmented sets)

``extract_partitions`` returns one ``Subgraph`` per partition, either with
re-growth (augmented sets, the paper's method) or without (plain induced
subgraphs E[S_p], the ablation baseline).  Message passing runs on each
subgraph independently; predictions are read back only for core nodes.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.graph import EdgeGraph


@dataclasses.dataclass
class Subgraph:
    """One partition, relabeled to local ids [0, num_nodes)."""

    global_ids: np.ndarray   # int64 (n_local,) — core nodes first, halo after
    num_core: int            # first num_core of global_ids are S_p
    edge_src: np.ndarray     # int32, local ids
    edge_dst: np.ndarray     # int32, local ids
    edge_inv: np.ndarray | None
    edge_slot: np.ndarray | None = None

    @property
    def num_nodes(self) -> int:
        return int(self.global_ids.shape[0])

    @property
    def num_edges(self) -> int:
        return int(self.edge_src.shape[0])

    @property
    def num_halo(self) -> int:
        return self.num_nodes - self.num_core

    def to_edge_graph(self) -> EdgeGraph:
        return EdgeGraph(
            self.num_nodes, self.edge_src, self.edge_dst, self.edge_inv, self.edge_slot
        )


def extract_partitions(
    graph: EdgeGraph, part: np.ndarray, regrow: bool = True, hops: int = 1
) -> list[Subgraph]:
    """Algorithm 1, vectorized over all partitions at once.

    Without ``regrow``: induced subgraphs E[S_p] only (what plain METIS
    partitioning gives you — the dashed lines of paper Fig. 6).

    ``hops`` iterates Algorithm 1's boundary growth: ``hops=1`` is the
    paper's B_p/C_p exactly; ``hops=h`` augments with the h-hop
    neighbourhood A_h = N^h(S_p) and every edge internal to it, which makes
    an L-layer GNN's core predictions *bit-exact* with the full-graph run
    once ``hops >= L`` (each core node then sees its complete receptive
    field, including the degree norms of every node whose representation it
    consumes).  Deeper halos trade memory for accuracy — the streaming
    executor's knob for the paper Fig. 6 recovery curve.

    Part ids are compacted first (``np.unique``), so sparse or gappy
    labelings — e.g. a partitioner asked for more parts than nodes — yield
    one ``Subgraph`` per *non-empty* partition and never an empty or
    out-of-range entry.  An empty graph yields an empty list.
    """
    if part.size == 0:
        return []
    # compact to consecutive ids 0..k-1 over non-empty partitions only
    _, part = np.unique(part, return_inverse=True)
    k = int(part.max()) + 1
    src, dst = graph.edge_src, graph.edge_dst
    ps, pd = part[src], part[dst]
    inv = graph.edge_inv

    subs: list[Subgraph] = []
    internal = ps == pd
    for p in range(k):
        core_mask = part == p
        core_ids = np.where(core_mask)[0]
        e_int = internal & (ps == p)

        if regrow and hops > 1:
            # iterated re-growth: A = N^hops(S_p); keep E[A] (halo-halo
            # edges included — they feed the halo representations the core
            # consumes at depth > 1)
            grown = core_mask.copy()
            for _ in range(hops):
                touch = grown[src] | grown[dst]
                grown[src[touch]] = True
                grown[dst[touch]] = True
            keep = grown[src] & grown[dst]
            halo_ids = np.where(grown & ~core_mask)[0]
            local_ids = np.concatenate([core_ids, halo_ids])
        elif regrow:
            # crossing edges C_p: exactly-one endpoint in S_p. (Any such
            # edge's other endpoint is 1-hop away, i.e. in B_p by Eq. 1.)
            cross = (ps == p) ^ (pd == p)
            # boundary nodes B_p from the crossing edges (Eq. 1)
            halo = np.concatenate(
                [dst[cross & (ps == p)], src[cross & (pd == p)]]
            )
            halo_ids = np.unique(halo)
            keep = cross | e_int
            local_ids = np.concatenate([core_ids, halo_ids])
        else:
            keep = e_int
            local_ids = core_ids

        remap = np.full(graph.num_nodes, -1, dtype=np.int64)
        remap[local_ids] = np.arange(len(local_ids))
        subs.append(
            Subgraph(
                global_ids=local_ids.astype(np.int64),
                num_core=len(core_ids),
                edge_src=remap[src[keep]].astype(np.int32),
                edge_dst=remap[dst[keep]].astype(np.int32),
                edge_inv=None if inv is None else inv[keep],
                edge_slot=None if graph.edge_slot is None else graph.edge_slot[keep],
            )
        )
    return subs


def boundary_edge_fraction(graph: EdgeGraph, part: np.ndarray) -> float:
    """Fraction of edges crossing partitions (the paper's ~10% observation)."""
    if graph.num_edges == 0:
        return 0.0
    return float((part[graph.edge_src] != part[graph.edge_dst]).mean())
