"""And-Inverter Graph construction + arithmetic-circuit generators.

The paper obtains AIGs by running netlists through ABC.  ABC is unavailable
offline, so we *generate* the same families of designs structurally:

  * CSA (carry-save array) multipliers       (paper Figs. 6a/6b, 8a/8b, 10)
  * Booth (radix-4) multipliers              (paper Figs. 6c, 8c)
  * "technology-mapped" CSA multipliers      (paper Figs. 6d, 8d) — emulated
    with mixed XOR decompositions (irregular local structure, the property
    that makes the mapped dataset hard)
  * FPGA 4-LUT mapped variant                (paper Fig. 7) — a cone-packing
    LUT mapper over the CSA AIG

Ground-truth node labels (PO=0, MAJ=1, XOR=2, AND=3, PI=4 — §III-B) are
known *by construction*: every XOR/MAJ root is created explicitly by the
half-/full-adder builders, which is oracle-equivalent to ABC labeling.

Literals follow the ABC convention: ``lit = 2*node + inv``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.graph import EdgeGraph

# Node kinds
PI, AND, PO = 0, 1, 2
# Node labels (paper §III-B)
LABEL_PO, LABEL_MAJ, LABEL_XOR, LABEL_AND, LABEL_PI = 0, 1, 2, 3, 4
NUM_CLASSES = 5
LABEL_NAMES = ("PO", "MAJ", "XOR", "AND", "PI")

# Literal helpers (lit = 2*node + inv). Constants: we fold them away at build
# time, representing const-0 as lit -2 and const-1 as lit -1.
CONST0, CONST1 = -2, -1


def lit_node(lit: int) -> int:
    return lit >> 1


def lit_inv(lit: int) -> int:
    return lit & 1


def lit_not(lit: int) -> int:
    if lit == CONST0:
        return CONST1
    if lit == CONST1:
        return CONST0
    return lit ^ 1


@dataclasses.dataclass
class AIG:
    """A built AIG with construction-time labels.

    ``fanin0/fanin1`` store literals (2*node+inv); PIs have -3 sentinels,
    POs use only fanin0.
    """

    name: str
    kind: np.ndarray      # int8 (N,)  PI/AND/PO
    fanin0: np.ndarray    # int64 (N,) literal
    fanin1: np.ndarray    # int64 (N,) literal
    label: np.ndarray     # int8 (N,)
    n_pi: int
    pos: np.ndarray       # int64 (num_po,) node-ids of POs in output-bit order

    @property
    def num_nodes(self) -> int:
        return int(self.kind.shape[0])

    @property
    def num_ands(self) -> int:
        return int((self.kind == AND).sum())

    def to_edge_graph(self) -> EdgeGraph:
        """Directed fanin->node edges with inversion flags (the EDA graph)."""
        is_and = self.kind == AND
        is_po = self.kind == PO
        dst_and = np.where(is_and)[0]
        dst_po = np.where(is_po)[0]
        src = np.concatenate(
            [
                self.fanin0[dst_and] >> 1,
                self.fanin1[dst_and] >> 1,
                self.fanin0[dst_po] >> 1,
            ]
        )
        dst = np.concatenate([dst_and, dst_and, dst_po])
        inv = np.concatenate(
            [
                self.fanin0[dst_and] & 1,
                self.fanin1[dst_and] & 1,
                self.fanin0[dst_po] & 1,
            ]
        ).astype(bool)
        slot = np.concatenate(
            [
                np.zeros(len(dst_and), np.uint8),
                np.ones(len(dst_and), np.uint8),
                np.zeros(len(dst_po), np.uint8),
            ]
        )
        order = np.argsort(dst, kind="stable")
        return EdgeGraph(
            self.num_nodes,
            src[order].astype(np.int32),
            dst[order].astype(np.int32),
            inv[order],
            slot[order],
        )

    def simulate(self, pi_values: np.ndarray) -> np.ndarray:
        """Bit-parallel simulation.

        ``pi_values``: bool/uint (n_pi, batch).  Returns (num_po, batch).
        Nodes are in topological order by construction.
        """
        n, b = self.num_nodes, pi_values.shape[1]
        val = np.zeros((n, b), dtype=bool)
        val[: self.n_pi] = pi_values.astype(bool)
        kind, f0, f1 = self.kind, self.fanin0, self.fanin1

        def lit_val(lit_arr, mask):
            node = lit_arr[mask] >> 1
            inv = (lit_arr[mask] & 1).astype(bool)
            return val[node] ^ inv[:, None]

        # Topological order == node-id order; evaluate in chunks of same-kind
        # runs for speed (simple loop is fine for tests; vectorized by level).
        level = np.zeros(n, dtype=np.int32)
        and_nodes = np.where(kind == AND)[0]
        for i in and_nodes:  # levels computed cheaply
            level[i] = 1 + max(level[f0[i] >> 1], level[f1[i] >> 1])
        max_level = level.max() if len(and_nodes) else 0
        for lv in range(1, max_level + 1):
            mask = (kind == AND) & (level == lv)
            if not mask.any():
                continue
            a = lit_val(f0, mask)
            bb = lit_val(f1, mask)
            val[mask] = a & bb
        po_mask = kind == PO
        val[po_mask] = lit_val(f0, po_mask)
        return val[self.pos]


class AIGBuilder:
    """Incremental AIG builder with constant folding + structural hashing."""

    def __init__(self, name: str = "aig"):
        self.name = name
        self.kind: list[int] = []
        self.fanin0: list[int] = []
        self.fanin1: list[int] = []
        self.label: list[int] = []
        self.pos: list[int] = []
        self.n_pi = 0
        self._strash: dict[tuple[int, int], int] = {}

    def add_pi(self) -> int:
        self.kind.append(PI)
        self.fanin0.append(-3)
        self.fanin1.append(-3)
        self.label.append(LABEL_PI)
        self.n_pi += 1
        return 2 * (len(self.kind) - 1)

    def add_and(self, a: int, b: int, label: int = LABEL_AND) -> int:
        # constant folding
        if a == CONST0 or b == CONST0:
            return CONST0
        if a == CONST1:
            return b
        if b == CONST1:
            return a
        if a == b:
            return a
        if a == lit_not(b):
            return CONST0
        key = (min(a, b), max(a, b))
        hit = self._strash.get(key)
        if hit is not None:
            node = hit
            # upgrade label if a structural root is re-derived (keep strongest)
            if label != LABEL_AND and self.label[node] == LABEL_AND:
                self.label[node] = label
            return 2 * node
        self.kind.append(AND)
        self.fanin0.append(key[0])
        self.fanin1.append(key[1])
        self.label.append(label)
        node = len(self.kind) - 1
        self._strash[key] = node
        return 2 * node

    def add_po(self, lit: int) -> int:
        assert lit >= 0, "constant PO should not occur in generated designs"
        self.kind.append(PO)
        self.fanin0.append(lit)
        self.fanin1.append(-3)
        self.label.append(LABEL_PO)
        node = len(self.kind) - 1
        self.pos.append(node)
        return node

    # -- gate macros ---------------------------------------------------------
    def or_(self, a: int, b: int, label: int = LABEL_AND) -> int:
        return lit_not(self.add_and(lit_not(a), lit_not(b), label=label))

    def xor2(self, a: int, b: int, decomp: int = 0) -> int:
        """XOR with an explicitly-labeled root.

        decomp 0: XOR  = AND(NOT(ab), NOT(a'b'))  = (a'+b')(a+b) = a'b+ab'
        decomp 1: XNOR = AND(NOT(ab'), NOT(a'b))  → XOR is its complement
        Either way the root AND node (an XOR/XNOR function root up to phase)
        carries LABEL_XOR — exactly what the GNN must detect.
        """
        if a in (CONST0, CONST1) or b in (CONST0, CONST1):
            if a == CONST0:
                return b
            if a == CONST1:
                return lit_not(b)
            if b == CONST0:
                return a
            return lit_not(a)
        if a == b:
            return CONST0
        if a == lit_not(b):
            return CONST1
        if decomp == 0:
            n1 = self.add_and(a, b)
            n2 = self.add_and(lit_not(a), lit_not(b))
            root = self.add_and(lit_not(n1), lit_not(n2), label=LABEL_XOR)
            return root
        n1 = self.add_and(a, lit_not(b))
        n2 = self.add_and(lit_not(a), b)
        root = self.add_and(lit_not(n1), lit_not(n2), label=LABEL_XOR)
        return lit_not(root)

    def half_adder(self, a: int, b: int, decomp: int = 0) -> tuple[int, int]:
        """(sum, carry).  carry=AND(a,b) is a degenerate MAJ(a,b,0) — the
        paper labels HA carries as MAJ (nodes 8/12 of the 2-bit example)."""
        s = self.xor2(a, b, decomp=decomp)
        c = self.add_and(a, b, label=LABEL_MAJ)
        return s, c

    def full_adder(self, a: int, b: int, c: int, decomp: int = 0) -> tuple[int, int]:
        """(sum, carry) with shared XOR(a,b):
        sum = XOR(XOR(a,b),c);  carry = ab OR c*XOR(a,b)  (the MAJ root).
        """
        x_ab = self.xor2(a, b, decomp=decomp)
        s = self.xor2(x_ab, c, decomp=decomp)
        t1 = self.add_and(a, b)
        t3 = self.add_and(x_ab, c)
        carry = self.or_(t1, t3, label=LABEL_MAJ)
        return s, carry

    def build(self) -> AIG:
        return AIG(
            name=self.name,
            kind=np.asarray(self.kind, dtype=np.int8),
            fanin0=np.asarray(self.fanin0, dtype=np.int64),
            fanin1=np.asarray(self.fanin1, dtype=np.int64),
            label=np.asarray(self.label, dtype=np.int8),
            n_pi=self.n_pi,
            pos=np.asarray(self.pos, dtype=np.int64),
        )


# ---------------------------------------------------------------------------
# Generators
# ---------------------------------------------------------------------------

def _column_compress(
    b: AIGBuilder, cols: list[list[int]], rng: Optional[np.random.Generator], mixed: bool
) -> list[list[int]]:
    """Carry-save (Wallace-style 3:2 / 2:2) compression until <=2 per column."""
    def pick():
        return int(rng.integers(0, 2)) if (mixed and rng is not None) else 0

    while max(len(c) for c in cols) > 2:
        nxt: list[list[int]] = [[] for _ in range(len(cols) + 1)]
        for ci, col in enumerate(cols):
            i = 0
            while len(col) - i >= 3:
                s, cy = b.full_adder(col[i], col[i + 1], col[i + 2], decomp=pick())
                nxt[ci].append(s)
                nxt[ci + 1].append(cy)
                i += 3
            if len(col) - i == 2:
                s, cy = b.half_adder(col[i], col[i + 1], decomp=pick())
                nxt[ci].append(s)
                nxt[ci + 1].append(cy)
                i += 2
            nxt[ci].extend(col[i:])
        while nxt and not nxt[-1]:
            nxt.pop()
        cols = nxt
    return cols


def _final_cpa(
    b: AIGBuilder, cols: list[list[int]], rng: Optional[np.random.Generator], mixed: bool
) -> list[int]:
    """Ripple-carry adder over the two remaining carry-save rows."""
    def pick():
        return int(rng.integers(0, 2)) if (mixed and rng is not None) else 0

    out: list[int] = []
    carry = CONST0
    for col in cols:
        ops = list(col)
        if carry != CONST0:
            ops.append(carry)
        if not ops:
            out.append(CONST0)
            carry = CONST0
        elif len(ops) == 1:
            out.append(ops[0])
            carry = CONST0
        elif len(ops) == 2:
            s, carry = b.half_adder(ops[0], ops[1], decomp=pick())
            out.append(s)
        else:
            s, carry = b.full_adder(ops[0], ops[1], ops[2], decomp=pick())
            out.append(s)
    if carry != CONST0:
        out.append(carry)
    return out


def csa_multiplier(bits: int, mixed_decomp: bool = False, seed: int = 0) -> AIG:
    """n-bit unsigned carry-save-array multiplier AIG.

    ``mixed_decomp=True`` emulates the post-technology-mapping dataset: XOR
    decompositions are chosen per-gate at random, producing the local
    irregularity that makes the paper's 7nm-mapped dataset harder.
    """
    rng = np.random.default_rng(seed) if mixed_decomp else None
    name = f"{'mapped' if mixed_decomp else 'csa'}_mult_{bits}b"
    b = AIGBuilder(name)
    a_in = [b.add_pi() for _ in range(bits)]
    b_in = [b.add_pi() for _ in range(bits)]
    cols: list[list[int]] = [[] for _ in range(2 * bits)]
    for i in range(bits):
        for j in range(bits):
            cols[i + j].append(b.add_and(a_in[i], b_in[j]))
    cols = _column_compress(b, cols, rng, mixed_decomp)
    out = _final_cpa(b, cols, rng, mixed_decomp)
    for k in range(2 * bits):
        b.add_po(out[k] if k < len(out) else CONST0)
    return b.build()


def booth_multiplier(bits: int, seed: int = 0) -> AIG:
    """Radix-4 Booth-encoded signed multiplier (two's complement).

    Booth digits d_k = -2*y_{2k+1} + y_{2k} + y_{2k-1} in {-2,-1,0,1,2};
    each partial product is a MUX network (one&B_j | two&B_{j-1}) with
    conditional inversion + "+1" correction — the denser, more irregular
    graphs of the paper's Booth dataset.  Sign handling uses full sign
    extension modulo 2^(2n) (functionally identical to the !s,s,s trick).
    """
    assert bits % 2 == 0, "radix-4 booth needs even width"
    del seed
    b = AIGBuilder(f"booth_mult_{bits}b")
    a_in = [b.add_pi() for _ in range(bits)]
    b_in = [b.add_pi() for _ in range(bits)]
    width = 2 * bits
    cols: list[list[int]] = [[] for _ in range(width)]

    def b_at(j: int) -> int:
        if j < 0:
            return CONST0
        if j >= bits:
            return b_in[bits - 1]  # sign extension of multiplicand B
        return b_in[j]

    for k in range(bits // 2):
        y0 = a_in[2 * k - 1] if 2 * k - 1 >= 0 else CONST0
        y1 = a_in[2 * k]
        y2 = a_in[2 * k + 1] if 2 * k + 1 < bits else a_in[bits - 1]
        one = b.xor2(y0, y1)                               # |d|=1
        two = b.add_and(b.xor2(y2, y1), lit_not(one))      # |d|=2
        neg = y2                                            # d<0 (or d=0, harmless)
        shift = 2 * k
        p_top = CONST0
        for j in range(bits + 1):                           # v is (n+1)-bit signed
            t1 = b.add_and(one, b_at(j))
            t2 = b.add_and(two, b_at(j - 1))
            v = b.or_(t1, t2)
            p = b.xor2(v, neg)                              # conditional invert
            if shift + j < width:
                cols[shift + j].append(p)
            if j == bits:
                p_top = p
        for j in range(bits + 1, width - shift):            # full sign extension
            cols[shift + j].append(p_top)
        cols[shift].append(neg)                             # "+1" completes negation

    cols = _column_compress(b, cols, None, False)
    out = _final_cpa(b, cols, None, False)
    for k in range(width):
        b.add_po(out[k] if k < len(out) else CONST0)
    return b.build()


# ---------------------------------------------------------------------------
# FPGA 4-LUT mapping (paper Fig. 7): greedy cone packing of the AIG into
# <=K-input LUTs. The LUT graph keeps the label of each LUT's root AIG node.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LUTGraph:
    name: str
    num_nodes: int
    n_pi: int
    edge_src: np.ndarray
    edge_dst: np.ndarray
    edge_inv: np.ndarray   # polarity of cone leaf edges (root-phase aggregated)
    label: np.ndarray
    kind: np.ndarray       # PI / AND(=LUT) / PO

    def to_edge_graph(self) -> EdgeGraph:
        # LUT fanin "slots": position parity within the sorted leaf list (a
        # degraded ordering signal — real LUT pins are symmetric anyway).
        order = np.argsort(self.edge_dst, kind="stable")
        dst_sorted = self.edge_dst[order]
        pos = np.arange(len(dst_sorted))
        starts = np.zeros(self.num_nodes, dtype=np.int64)
        first = np.ones(len(dst_sorted), dtype=bool)
        first[1:] = dst_sorted[1:] != dst_sorted[:-1]
        starts[dst_sorted[first]] = pos[first]
        slot = ((pos - starts[dst_sorted]) % 2).astype(np.uint8)
        return EdgeGraph(
            self.num_nodes,
            self.edge_src[order],
            self.edge_dst[order],
            self.edge_inv[order],
            slot,
        )


def fpga_lut_map(aig: AIG, k: int = 4) -> LUTGraph:
    """Greedy topological K-feasible cone packing (a simple FlowMap-lite)."""
    n = aig.num_nodes
    kind, f0, f1 = aig.kind, aig.fanin0, aig.fanin1
    # cut[i] = frozenset of leaf node-ids of the cone rooted at i
    cut: list[frozenset] = [frozenset()] * n
    is_root = np.zeros(n, dtype=bool)
    for i in range(n):
        if kind[i] == PI:
            cut[i] = frozenset((i,))
            is_root[i] = True
        elif kind[i] == AND:
            c0, c1 = cut[f0[i] >> 1], cut[f1[i] >> 1]
            merged = c0 | c1
            if len(merged) <= k:
                cut[i] = merged
            else:
                cut[i] = frozenset((f0[i] >> 1, f1[i] >> 1))
                is_root[f0[i] >> 1] = True
                is_root[f1[i] >> 1] = True
        else:  # PO
            is_root[f0[i] >> 1] = True
            cut[i] = frozenset((i,))
    is_root |= kind == PO
    roots = np.where(is_root)[0]
    remap = -np.ones(n, dtype=np.int64)
    remap[roots] = np.arange(len(roots))
    src, dst, inv = [], [], []
    for new_i, i in enumerate(roots):
        if kind[i] == PI:
            continue
        if kind[i] == PO:
            src.append(remap[f0[i] >> 1])
            dst.append(new_i)
            inv.append(bool(f0[i] & 1))
            continue
        for leaf in sorted(cut[i]):
            src.append(remap[leaf])
            dst.append(new_i)
            inv.append(False)
    order = np.argsort(np.asarray(dst), kind="stable")
    return LUTGraph(
        name=f"fpga{k}lut_{aig.name}",
        num_nodes=len(roots),
        n_pi=int((kind[roots] == PI).sum()),
        edge_src=np.asarray(src, dtype=np.int32)[order],
        edge_dst=np.asarray(dst, dtype=np.int32)[order],
        edge_inv=np.asarray(inv, dtype=bool)[order],
        label=aig.label[roots].copy(),
        kind=aig.kind[roots].copy(),
    )


DATASETS = ("csa", "booth", "mapped", "fpga")


def make_design(dataset: str, bits: int, seed: int = 0):
    """Uniform entry point used by the pipeline/benchmarks."""
    if dataset == "csa":
        return csa_multiplier(bits)
    if dataset == "booth":
        return booth_multiplier(bits, seed=seed)
    if dataset == "mapped":
        return csa_multiplier(bits, mixed_decomp=True, seed=seed)
    if dataset == "fpga":
        return fpga_lut_map(csa_multiplier(bits))
    raise ValueError(f"unknown dataset {dataset!r} (want one of {DATASETS})")
