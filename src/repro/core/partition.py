"""Graph partitioning for GROOT (§III-C).

The paper uses METIS [31].  METIS is not installable offline, so we provide
two partitioners with the same interface (``-> int32 part_id per node``):

  * ``multilevel_partition`` — a METIS-style multilevel scheme: heavy-edge
    random matching coarsening, greedy region-growing initial partition on
    the coarsest graph, and boundary FM-lite refinement during uncoarsening.
    This is the default (quality within ~1.3x of a spectral reference on our
    AIGs — see tests/test_partition.py).
  * ``bfs_stripe_partition`` — topological-order stripes; O(N), useful as a
    fast baseline and for very large graphs.

Both balance |S_p| within ``tol``.
"""
from __future__ import annotations

import numpy as np

from repro.core.graph import EdgeGraph


def edge_cut(graph: EdgeGraph, part: np.ndarray) -> int:
    """Number of edges crossing partitions (directed count)."""
    return int((part[graph.edge_src] != part[graph.edge_dst]).sum())


def bfs_stripe_partition(graph: EdgeGraph, k: int) -> np.ndarray:
    """Contiguous stripes in node order.

    AIG builders emit nodes in topological order, so equal stripes of the
    node range are already BFS-like level stripes with good locality.

    ``k`` is clamped to ``[1, num_nodes]`` so every emitted part id names a
    non-empty stripe — downstream consumers (``extract_partitions``, the
    streaming executor) never see an empty or out-of-range partition.
    """
    n = graph.num_nodes
    if n == 0:
        return np.zeros(0, dtype=np.int32)
    k = max(1, min(k, n))
    return ((np.arange(n) * k) // n).astype(np.int32)


# ---------------------------------------------------------------------------
# Multilevel partitioner
# ---------------------------------------------------------------------------

def _coarsen_matching(n: int, src: np.ndarray, dst: np.ndarray, w: np.ndarray, rng):
    """One level of heavy-edge matching.  Returns (coarse_map, n_coarse).

    Vectorized random matching: each node proposes its heaviest incident
    edge (random tie-break); mutual proposals are contracted.
    """
    if len(src) == 0:
        return np.arange(n, dtype=np.int64), n
    # score = weight + small random jitter for tie-breaking
    score = w.astype(np.float64) + rng.random(len(w)) * 0.5
    # For each node, find its best incident edge (consider both directions).
    s2 = np.concatenate([src, dst])
    d2 = np.concatenate([dst, src])
    sc2 = np.concatenate([score, score])
    order = np.lexsort((-sc2, s2))
    s_sorted = s2[order]
    first = np.ones(len(s_sorted), dtype=bool)
    first[1:] = s_sorted[1:] != s_sorted[:-1]
    best_src = s_sorted[first]
    best_dst = d2[order][first]
    choice = -np.ones(n, dtype=np.int64)
    choice[best_src] = best_dst
    mutual = (choice >= 0) & (choice[np.clip(choice, 0, n - 1)] == np.arange(n))
    lo = np.minimum(np.arange(n), choice)
    merged = np.where(mutual & (np.arange(n) > choice), choice, np.arange(n))
    del lo
    # build coarse ids
    reps = np.unique(merged)
    remap = np.zeros(n, dtype=np.int64)
    remap[reps] = np.arange(len(reps))
    return remap[merged], len(reps)


def _contract(src, dst, w, cmap, n_coarse):
    cs, cd = cmap[src], cmap[dst]
    keep = cs != cd
    cs, cd, cw = cs[keep], cd[keep], w[keep]
    lo = np.minimum(cs, cd)
    hi = np.maximum(cs, cd)
    key = lo * n_coarse + hi
    uk, inv = np.unique(key, return_inverse=True)
    ww = np.zeros(len(uk), dtype=np.float64)
    np.add.at(ww, inv, cw)
    return (uk // n_coarse).astype(np.int64), (uk % n_coarse).astype(np.int64), ww


def _greedy_grow(n, src, dst, node_w, k, rng):
    """Initial partition on the coarsest graph: BFS region growing."""
    # adjacency as CSR over symmetrized edges
    s2 = np.concatenate([src, dst])
    d2 = np.concatenate([dst, src])
    order = np.argsort(s2, kind="stable")
    s_sorted, d_sorted = s2[order], d2[order]
    ptr = np.zeros(n + 1, dtype=np.int64)
    np.add.at(ptr, s_sorted + 1, 1)
    np.cumsum(ptr, out=ptr)
    target = node_w.sum() / k
    part = -np.ones(n, dtype=np.int32)
    perm = rng.permutation(n)
    pi = 0
    for p in range(k):
        grown = 0.0
        limit = target if p < k - 1 else np.inf
        frontier: list = []
        while grown < limit:
            if not frontier:
                # (re)seed: a region whose frontier died (disconnected
                # component, or fully surrounded by assigned nodes) keeps
                # growing from the next unassigned node — without this,
                # starved regions stay tiny and the LAST partition swallows
                # every leftover node (observed: 32% of a 530k-node graph).
                while pi < n and part[perm[pi]] >= 0:
                    pi += 1
                if pi >= n:
                    break
                frontier = [int(perm[pi])]
            nxt = []
            for u in frontier:
                if part[u] >= 0:
                    continue
                part[u] = p
                grown += node_w[u]
                if grown >= limit:
                    break
                nbrs = d_sorted[ptr[u] : ptr[u + 1]]
                nxt.extend(int(x) for x in nbrs[part[nbrs] < 0])
            frontier = nxt
    part[part < 0] = k - 1
    return part


def _refine(n, src, dst, w, part, node_w, k, tol, passes=4):
    """FM-lite boundary refinement: move nodes to the neighbouring partition
    with max gain, respecting balance, a few vectorized passes."""
    sizes = np.zeros(k)
    np.add.at(sizes, part, node_w)
    cap = node_w.sum() / k * (1 + tol)
    for _ in range(passes):
        ps, pd = part[src], part[dst]
        boundary_edges = ps != pd
        if not boundary_edges.any():
            break
        # per (node, neighbour-part) accumulated edge weight
        nodes = np.concatenate([src[boundary_edges], dst[boundary_edges]])
        nbr_part = np.concatenate([pd[boundary_edges], ps[boundary_edges]])
        ww = np.concatenate([w[boundary_edges], w[boundary_edges]])
        key = nodes.astype(np.int64) * k + nbr_part
        uk, inv = np.unique(key, return_inverse=True)
        ext = np.zeros(len(uk))
        np.add.at(ext, inv, ww)
        cand_node = (uk // k).astype(np.int64)
        cand_part = (uk % k).astype(np.int32)
        # internal weight of each node (edges to own part)
        internal = np.zeros(n)
        same = ~boundary_edges
        np.add.at(internal, src[same], w[same])
        np.add.at(internal, dst[same], w[same])
        gain = ext - internal[cand_node]
        # best candidate per node
        order = np.lexsort((-gain, cand_node))
        cn = cand_node[order]
        first = np.ones(len(cn), dtype=bool)
        first[1:] = cn[1:] != cn[:-1]
        mv_node = cn[first]
        mv_part = cand_part[order][first]
        mv_gain = gain[order][first]
        good = mv_gain > 0
        mv_node, mv_part = mv_node[good], mv_part[good]
        if len(mv_node) == 0:
            break
        # apply greedily in gain order under balance cap
        order2 = np.argsort(-mv_gain[good])
        moved = 0
        for i in order2:
            u, p = mv_node[i], mv_part[i]
            if sizes[p] + node_w[u] <= cap and sizes[part[u]] - node_w[u] > 0:
                sizes[part[u]] -= node_w[u]
                sizes[p] += node_w[u]
                part[u] = p
                moved += 1
        if moved == 0:
            break
    return part


def multilevel_partition(
    graph: EdgeGraph,
    k: int,
    tol: float = 0.1,
    seed: int = 0,
    coarse_target: int | None = None,
) -> np.ndarray:
    """METIS-style multilevel k-way partition.

    ``k`` is clamped to ``[1, num_nodes]`` (a partition cannot be empty);
    ``k == num_nodes`` degenerates to singletons without running the
    coarsen/grow/refine machinery.

    ``coarse_target`` (default ``max(4096, num_nodes // 8)``) bounds how
    far coarsening runs.  Stopping earlier on large graphs costs a little
    host time in the initial partition but measurably improves the cut —
    on a 530k-node CSA-256 AIG, n//8 vs a flat 4096 shrinks the 2-hop
    re-grown worst partition ~15% (the margin that keeps a k=16 stream
    under half the full-graph memory model).
    """
    n0 = graph.num_nodes
    if n0 == 0:
        return np.zeros(0, dtype=np.int32)
    if coarse_target is None:
        coarse_target = max(4096, n0 // 8)
    k = max(1, min(k, n0))
    if k <= 1:
        return np.zeros(n0, dtype=np.int32)
    if k == n0:
        return np.arange(n0, dtype=np.int32)
    rng = np.random.default_rng(seed)
    levels = []
    n = graph.num_nodes
    src = graph.edge_src.astype(np.int64)
    dst = graph.edge_dst.astype(np.int64)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    w = np.ones(len(src), dtype=np.float64)
    node_w = np.ones(n, dtype=np.float64)
    while n > max(coarse_target, 8 * k):
        cmap, nc = _coarsen_matching(n, src, dst, w, rng)
        if nc >= n * 0.98:  # matching stalled
            break
        levels.append((n, src, dst, w, node_w, cmap))
        cw = np.zeros(nc)
        np.add.at(cw, cmap, node_w)
        src, dst, w = _contract(src, dst, w, cmap, nc)
        node_w = cw
        n = nc
    part = _greedy_grow(n, src, dst, node_w, k, rng)
    part = _refine(n, src, dst, w, part, node_w, k, tol)
    for (pn, psrc, pdst, pw, pnw, cmap) in reversed(levels):
        part = part[cmap]
        part = _refine(pn, psrc, pdst, pw, part, pnw, k, tol, passes=2)
    return part.astype(np.int32)


PARTITIONERS = {
    "multilevel": multilevel_partition,
    "bfs": lambda g, k, **kw: bfs_stripe_partition(g, k),
}
