"""Ground-truth labels + the classical structural XOR/MAJ detector.

Construction-time labels live on ``AIG.label`` (oracle-equivalent to ABC's
labeling — see DESIGN.md §7).  This module adds the *structural detector*:
the classical pattern-matching pass that algebraic-rewriting flows (ABC's
``&polyn`` / GAMORA's teacher) run over a flattened netlist.  It serves two
roles:

  1. independent validation of the construction labels (tests), and
  2. the "classical detector" runtime baseline of benchmark Fig. 10 —
     the thing whose cost the GNN replaces.
"""
from __future__ import annotations

import numpy as np

from repro.core import aig as A


def structural_detect(aig: A.AIG) -> np.ndarray:
    """Label every node by local structural pattern matching.

    An AND node ``g = AND(u^pu, v^pv)`` (p* = edge inversions) is:

      * an XOR/XNOR root iff pu=pv=1, u and v are AND nodes, and the
        grandchild literal sets satisfy  {u0,u1} = {~v0,~v1}  — i.e.
        u = AND(x,y), v = AND(~x,~y) up to permutation;
      * a MAJ root iff pu=pv=1 and u,v are ANDs sharing exactly the
        pattern u=AND(a,b), v=AND(xor_root(a,b)^phase, c)  — i.e. the
        OR(ab, c·(a XOR b)) carry shape — or the degenerate HA carry
        (an AND both of whose fanins also feed a sibling XOR root);
      * otherwise a plain AND.

    Vectorized over all nodes with numpy; O(N).
    """
    n = aig.num_nodes
    kind, f0, f1 = aig.kind, aig.fanin0, aig.fanin1
    out = np.full(n, A.LABEL_AND, dtype=np.int8)
    out[kind == A.PI] = A.LABEL_PI
    out[kind == A.PO] = A.LABEL_PO

    is_and = kind == A.AND
    ands = np.where(is_and)[0]
    u, pu = f0[ands] >> 1, f0[ands] & 1
    v, pv = f1[ands] >> 1, f1[ands] & 1
    both_inv = (pu == 1) & (pv == 1)
    u_is_and = is_and[u]
    v_is_and = is_and[v]
    cand = both_inv & u_is_and & v_is_and

    # Grandchild literals (valid only where cand)
    u0 = np.where(cand, f0[u], 0)
    u1 = np.where(cand, f1[u], 0)
    v0 = np.where(cand, f0[v], 0)
    v1 = np.where(cand, f1[v], 0)

    # XOR root: {u0,u1} == {v0^1, v1^1} as sets
    xa = (u0 == (v0 ^ 1)) & (u1 == (v1 ^ 1))
    xb = (u0 == (v1 ^ 1)) & (u1 == (v0 ^ 1))
    is_xor = cand & (xa | xb)
    out[ands[is_xor]] = A.LABEL_XOR

    # MAJ root: AND(~t1, ~t3) where t1 = AND(a,b), t3 = AND(xor(a,b)^ph, c)
    # i.e. one grandchild of t3 is an XOR root over t1's children.
    xor_node = np.zeros(n, dtype=bool)
    xor_node[ands[is_xor]] = True

    def _maj_side(t1, t3):
        """t1 = AND(a,b); t3's children contain an XOR root whose own
        grandchildren literal-set matches {a,b} or {~a,~b}."""
        a_, b_ = f0[t1], f1[t1]
        ok = np.zeros(t1.shape, dtype=bool)
        for gc in (f0[t3] >> 1, f1[t3] >> 1):
            gx = xor_node[gc]
            g0, g1 = f0[gc], f1[gc]
            # XOR root gc has children AND(x,y), AND(~x,~y); recover {x,y}
            c0 = f0[g0 >> 1]
            c1 = f1[g0 >> 1]
            m_pos = (c0 == a_) & (c1 == b_) | (c0 == b_) & (c1 == a_)
            m_neg = (c0 == (a_ ^ 1)) & (c1 == (b_ ^ 1)) | (
                (c0 == (b_ ^ 1)) & (c1 == (a_ ^ 1))
            )
            ok |= gx & is_and[g0 >> 1] & (m_pos | m_neg)
        return ok

    maj = cand & ~is_xor & (_maj_side(u, v) | _maj_side(v, u))
    out[ands[maj]] = A.LABEL_MAJ

    # Degenerate HA carry: in an AIG, a half adder shares its carry AND(a,b)
    # with the XOR decomposition's first child (structural hashing), so the
    # carry is an XOR-root child with *external* fanout (>= 2: the root plus
    # the next compressor stage / PO).  Exclusion: a full adder's t1 = ab is
    # also an XOR-root child with fanout 2, but its extra consumer is the FA
    # MAJ root (consuming it inverted) — an HA carry is never consumed
    # inverted by a MAJ root.
    xr = ands[is_xor]
    if xr.size:
        fanout = np.zeros(n, dtype=np.int64)
        valid0 = f0 >= 0
        valid1 = (f1 >= 0) & (kind == A.AND)
        np.add.at(fanout, f0[valid0] >> 1, 1)
        np.add.at(fanout, f1[valid1] >> 1, 1)
        maj_nodes = np.zeros(n, dtype=bool)
        maj_nodes[ands[maj]] = True
        eaten_by_maj = np.zeros(n, dtype=bool)  # consumed inverted by MAJ root
        for ff in (f0, f1):
            sel = maj_nodes & ((ff & 1) == 1) & (ff >= 0)
            eaten_by_maj[ff[sel] >> 1] = True
        for child in (f0[xr] >> 1, f1[xr] >> 1):
            carry_like = (
                (fanout[child] >= 2)
                & (out[child] == A.LABEL_AND)
                & ~eaten_by_maj[child]
            )
            out[child[carry_like]] = A.LABEL_MAJ
    return out


def label_counts(labels: np.ndarray) -> dict[str, int]:
    c = np.bincount(labels, minlength=A.NUM_CLASSES)
    return {A.LABEL_NAMES[i]: int(c[i]) for i in range(A.NUM_CLASSES)}
