"""End-to-end GROOT verification pipeline (paper Fig. 2 stages a-e).

    netlist/AIG -> features -> [partition -> re-growth] -> GNN inference
    -> XOR/MAJ classification -> algebraic verification

The stable front door over this flow is :class:`repro.api.Session`
(``run_pipeline`` survives as a deprecated shim over it).  The module
exposes the three reusable stages the façade composes —

  :func:`prepare`          host-side: design gen/ingest, features,
                           partitioning + boundary re-growth
  :func:`infer`            device-side: (partitioned) GNN prediction
  :func:`verify_prepared`  host-side: adder extraction + simulation check

— so batch schedulers (``repro.service``) can interleave the host and
device stages of many requests instead of running each end to end.

Also provides the device-memory model used by the Fig. 8 / Table II
benchmark: because this container is CPU-only, "GPU memory" is an
*analytic but array-accurate* count of the device buffers each inference
step allocates (features, activations for L layers, edge arrays, gathered
edge streams, params).  Partitioned runs count the PEAK over partitions —
exactly the quantity the paper's partitioning bounds.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

from repro.core import aig as A
from repro.core import gnn
from repro.core.features import groot_features
from repro.core.graph import EdgeGraph, batch_graphs
from repro.core.partition import PARTITIONERS
from repro.core.regrowth import Subgraph, extract_partitions, boundary_edge_fraction
from repro.core.verify import VerifyResult, verify
from repro.obs import REGISTRY, span


def resolve_backend_alias(backend: Optional[str], aggregate: Optional[str],
                          *, owner: str) -> str:
    """Collapse the ``aggregate``/``backend`` naming split to ``backend``.

    ``aggregate=`` (the old ``PipelineConfig`` spelling) keeps working as
    a write-only alias: it warns, fills ``backend`` when that is unset,
    and conflicts loudly instead of silently preferring one.  Returns the
    resolved backend (default ``"ref"``).  Lives here (not ``repro.api``)
    so the core layer never imports upward.
    """
    if aggregate is not None:
        import warnings

        warnings.warn(
            f"{owner}(aggregate=...) is deprecated; the knob is named "
            f"backend= everywhere now",
            DeprecationWarning,
            # resolve_backend_alias <- __post_init__ <- generated __init__
            # <- the user's call site
            stacklevel=4,
        )
        if backend is None:
            backend = aggregate
        elif backend != aggregate:
            raise ValueError(
                f"{owner}: backend={backend!r} and its deprecated alias "
                f"aggregate={aggregate!r} disagree — pass only backend="
            )
    return "ref" if backend is None else backend


@dataclasses.dataclass
class PipelineConfig:
    dataset: str = "csa"
    bits: int = 32
    batch: int = 1
    num_partitions: int = 1
    regrow: bool = True
    regrow_hops: int = 1          # re-growth depth (iterated Algorithm 1);
                                  # >= gnn.num_layers -> partitioned == full
    partitioner: str = "multilevel"
    gnn: gnn.GNNConfig = dataclasses.field(default_factory=gnn.GNNConfig)
    # aggregation backend: "ref" | "onehot" | "groot" | "groot_mxu" |
    # "groot_fused" — the ONE name for the knob across every layer (the
    # service config always called it backend).  None resolves to "ref".
    backend: Optional[str] = None
    seed: int = 0
    # streaming-executor knobs (repro.exec).  ``memory_budget_bytes`` set
    # and num_partitions <= 1: prepare() derives the partition count from
    # the device budget via choose_k (the "fit this accelerator" mode).
    memory_budget_bytes: Optional[int] = None
    stream_capacity: int = 2      # same-bucket partitions packed per launch
    stream_prefetch: int = 1      # packed batches staged ahead of the device
    # edge-stream dtype for the hoisted groot* forward ("bfloat16" halves
    # the staged stream bytes; kernels accumulate f32).  None defers to
    # ``gnn.stream_dtype``.
    stream_dtype: Optional[str] = None
    # device-mesh sharding of the streamed route (repro.mesh).  None =
    # auto: use every visible device when more than one exists; 1 forces
    # the single-device executor; N shards across the first N devices.
    mesh_devices: Optional[int] = None
    # crash-safe resume for streamed runs: when ``checkpoint_dir`` is set
    # (and the design has a structural hash), every launched partition's
    # core predictions are journaled atomically, and a re-run restores
    # committed partitions instead of re-executing them.  ``resume=False``
    # keeps journaling but ignores (wipes) any prior journal.
    checkpoint_dir: Optional[str] = None
    resume: bool = True
    # deprecated write-only alias of ``backend`` (the old spelling);
    # consumed and reset to None at construction so dataclasses.replace
    # with backend= never sees a stale conflicting alias
    aggregate: Optional[str] = None

    def __post_init__(self):
        self.backend = resolve_backend_alias(
            self.backend, self.aggregate, owner="PipelineConfig"
        )
        self.aggregate = None


@dataclasses.dataclass
class PipelineResult:
    accuracy: float
    core_accuracy: float          # accuracy on S_p nodes (what the paper plots)
    peak_memory_bytes: int
    unpartitioned_memory_bytes: int
    boundary_edge_frac: float
    timings: dict
    verdict: Optional[VerifyResult]
    num_nodes: int
    num_edges: int
    # structural plan-cache activity during this run's inference stage:
    # {"builds": new plans/pairs built, "hits": reused}.  A repeated run
    # over the same structure shows builds == 0.  Deltas of the
    # process-global cache counters: attribution is only exact when no
    # other thread (e.g. a live VerificationService) runs inference
    # concurrently.
    plan_cache: dict = dataclasses.field(default_factory=dict)
    # streaming-executor probes for partitioned runs: compiles, launches,
    # bytes_h2d, pack/device/wall seconds, peak_packed_memory_bytes (the
    # modeled bytes of the largest capacity-slot launch — the quantity
    # that must fit the device budget), chosen_k.
    exec_stats: dict = dataclasses.field(default_factory=dict)
    # per-verify span subtree (repro.obs.TraceHandle) when the session
    # that produced this result ran with SessionConfig(trace=True)
    trace: Optional[object] = None


def memory_model_bytes(
    num_nodes: int, num_edges: int, cfg: gnn.GNNConfig, include_params: bool = True
) -> int:
    """Device bytes for one inference over a (sub)graph.

    features (N,Fin) fp32 + per-layer activations 2x(N,H) (double-buffered
    current/next) + 2x aggregated (N,H) + edge index arrays 2x int32 x2
    directions + gathered edge stream (E,H) fp32 (the gather->MXU stream of
    the TPU formulation) + params.
    """
    f32 = 4
    n, e = num_nodes, num_edges
    bytes_ = n * cfg.in_features * f32
    h = cfg.hidden
    bytes_ += 2 * n * h * f32          # h, h_next
    bytes_ += 2 * n * h * f32          # agg_in, agg_out
    bytes_ += 2 * 2 * e * 4            # edge src/dst, both directions
    bytes_ += e * h * f32              # gathered edge stream
    if include_params:
        p = cfg.in_features * h * 3 + (cfg.num_layers - 1) * 3 * h * h + h * cfg.num_classes
        bytes_ += p * f32
    return int(bytes_)


def layer_traffic_model_bytes(
    num_nodes: int,
    num_edges: int,
    cfg: gnn.GNNConfig,
    *,
    hoisted: bool = True,
    stream_dtype: Optional[str] = None,
    slots_in: Optional[int] = None,
    slots_out: Optional[int] = None,
    segments_in: int = 4,
    segments_out: int = 4,
) -> int:
    """Modeled per-layer HBM traffic of the grouped aggregation hot path.

    Counts the three per-layer terms the ForwardPlan hoisting targets
    (array-accurate when the caller passes the real plan ``num_slots`` /
    ``num_segments``; pow-2-padding estimates otherwise):

      * **edge-message streams** — ``x[src]`` gathered once per direction
        per layer: ``(slots_in + slots_out) * H * stream_bytes``.  Both
        paths pay it; ``stream_dtype="bfloat16"`` halves it.
      * **edge-weight streams** — pre-hoist each layer re-gathers the
        (E, 4) fanin + (E, 2) fanout group weights into kernel layout;
        hoisted stages them once per forward, so the per-layer share is
        amortised by ``num_layers``.
      * **output assembly** — pre-hoist each aggregation issues one
        ``(N, H)`` scatter per LD bucket plus one for HD (each a
        read-modify-write of the output array) plus the final read;
        hoisted assembles with a single permutation gather (concat write
        + gather read + result write: 3 passes).
    """
    f32 = 4
    sdt = np.dtype(stream_dtype) if stream_dtype is not None else np.dtype("float32")
    sb = sdt.itemsize
    h = cfg.hidden
    s_in = 2 * num_edges if slots_in is None else slots_in
    s_out = 2 * num_edges if slots_out is None else slots_out
    layers = max(cfg.num_layers, 1)

    traffic = (s_in + s_out) * h * sb                 # message streams
    w_bytes = (4 * s_in + 2 * s_out) * sb             # group-weight streams
    traffic += w_bytes // layers if hoisted else w_bytes
    out_plane = num_nodes * h * f32                   # one (N, H) pass
    if hoisted:
        traffic += 2 * 3 * out_plane                  # both directions
    else:
        # segments already counts the HD pass: 2 touches (read+write) per
        # scatter segment, plus the final read of the assembled output
        traffic += (2 * segments_in + 1) * out_plane
        traffic += (2 * segments_out + 1) * out_plane
    return int(traffic)


@dataclasses.dataclass
class PreparedDesign:
    """Host-side output of :func:`prepare` — everything inference needs."""

    cfg: PipelineConfig
    design: object               # AIG or LUTGraph
    labels: np.ndarray
    feats: np.ndarray
    graph: EdgeGraph
    subgraphs: Optional[list[Subgraph]]   # None when unpartitioned
    boundary_edge_frac: float
    timings: dict

    @property
    def num_nodes(self) -> int:
        return self.graph.num_nodes

    @property
    def num_edges(self) -> int:
        return self.graph.num_edges

    @property
    def num_partitions(self) -> int:
        """Effective partition count (budget-driven prepare may exceed
        ``cfg.num_partitions``)."""
        return len(self.subgraphs) if self.subgraphs else 1

    def memory_bytes(self) -> tuple[int, int]:
        """(unpartitioned, peak-over-partitions) device bytes."""
        full = memory_model_bytes(self.num_nodes, self.num_edges, self.cfg.gnn)
        if not self.subgraphs:
            return full, full
        peak = max(
            memory_model_bytes(sg.num_nodes, sg.num_edges, self.cfg.gnn)
            for sg in self.subgraphs
        )
        return full, peak


def prepare(cfg: PipelineConfig, design=None) -> PreparedDesign:
    """Stage 1 (host): design generation/ingest, features, partition+re-growth.

    ``design`` overrides generation — the ingestion path for AIGs parsed
    from AIGER files (``repro.io.aiger``); ``cfg.dataset``/``cfg.bits``
    are then only used for verification metadata downstream.
    """
    t0 = time.perf_counter()
    with span("prepare.features"):
        if design is None:
            design = A.make_design(cfg.dataset, cfg.bits, seed=cfg.seed)
        labels = design.label
        feats = groot_features(design)
        g1 = design.to_edge_graph()
        if cfg.batch > 1:
            g = batch_graphs([g1] * cfg.batch)
            feats = np.tile(feats, (cfg.batch, 1))
            labels = np.tile(labels, cfg.batch)
        else:
            g = g1
    t_gen = time.perf_counter() - t0
    REGISTRY.counter("pipeline.prepares").inc()

    t0 = time.perf_counter()
    k = cfg.num_partitions
    budgeted = k <= 1 and cfg.memory_budget_bytes is not None
    if budgeted:
        from repro.exec.plan import HALO_FRAC, choose_k

        # halo grows with re-growth depth; scale the planning margin so
        # deep-hop runs are not fitted with the 1-hop estimate
        k = choose_k(
            g.num_nodes, g.num_edges, cfg.gnn, cfg.memory_budget_bytes,
            capacity=cfg.stream_capacity,
            halo_frac=HALO_FRAC * max(1, cfg.regrow_hops if cfg.regrow else 1),
        )

    def _cut(k):
        part = PARTITIONERS[cfg.partitioner](g, k, seed=cfg.seed)
        return part, extract_partitions(
            g, part, regrow=cfg.regrow, hops=cfg.regrow_hops
        )

    if k <= 1:
        subs, bfrac, t_part = None, 0.0, 0.0
    else:
        with span("prepare.partition", k=k, partitioner=cfg.partitioner) as sp:
            part, subs = _cut(k)
            if budgeted and subs:
                # the estimate can undershoot real halo growth: validate the
                # BUILT plan's packed peak and re-split finer until it fits
                from repro.exec.plan import plan_from_subgraphs

                while k < g.num_nodes and plan_from_subgraphs(
                    subs, g.num_nodes
                ).peak_batch_memory_bytes(
                    cfg.gnn, cfg.stream_capacity
                ) > cfg.memory_budget_bytes:
                    k *= 2
                    part, subs = _cut(k)
            bfrac = boundary_edge_fraction(g, part)
            if not subs:  # empty graph: fall back to the unpartitioned path
                subs = None
            sp.set(final_k=len(subs) if subs else 1)
        REGISTRY.counter("pipeline.partition_cuts").inc()
        t_part = time.perf_counter() - t0
    return PreparedDesign(
        cfg=cfg,
        design=design,
        labels=labels,
        feats=feats,
        graph=g,
        subgraphs=subs,
        boundary_edge_frac=bfrac,
        timings={"gen": t_gen, "partition": t_part},
    )


def infer(params, prep: PreparedDesign, *, backend: Optional[str] = None) -> np.ndarray:
    """Stage 2 (device): per-node class predictions over the full graph.

    Partitioned designs stream (prepare -> plan -> stream -> scatter);
    :func:`infer_streaming` exposes the executor's probe counters too.
    """
    if prep.subgraphs is None:
        backend = backend or prep.cfg.backend
        return gnn.predict(
            params, prep.graph, prep.feats, backend=backend,
            stream_dtype=_effective_stream_dtype(prep.cfg),
        )
    pred, _ = infer_streaming(params, prep, backend=backend)
    return pred


def _effective_stream_dtype(cfg: PipelineConfig) -> Optional[str]:
    """The staged edge-stream dtype a run uses: the pipeline-level knob
    wins, else the GNN config's; f32 normalises to None (bit-exact path)."""
    sdt = cfg.stream_dtype or cfg.gnn.stream_dtype
    return None if sdt in (None, "float32") else sdt


def _journal_for(prep: PreparedDesign):
    """Build the crash-resume journal for a streamed run, or None.

    Journaling needs a durable identity for "the same work": the design's
    structural hash (the service dedup key).  Only single-AIG runs have
    one, so batched/LUT runs stream unjournaled.  ``resume=False`` wipes
    any prior journal before the run — fresh execution, fresh journal.
    """
    cfg = prep.cfg
    if not cfg.checkpoint_dir or cfg.batch != 1 or not isinstance(prep.design, A.AIG):
        return None
    from repro.checkpoint import PartitionJournal
    from repro.io import aiger

    journal = PartitionJournal(cfg.checkpoint_dir, aiger.structural_hash(prep.design))
    if not cfg.resume:
        journal.complete()  # discard any prior partial run
    return journal


def infer_streaming(
    params,
    prep: PreparedDesign,
    *,
    backend: Optional[str] = None,
    executor=None,
    plan=None,
    journal=None,
) -> tuple[np.ndarray, dict]:
    """Partitioned inference through the streaming executor.

    Returns ``(pred, exec_stats)`` where ``exec_stats`` carries the
    executor probes (compiles, launches, bytes_h2d, pack/device/wall
    seconds) plus ``peak_packed_memory_bytes`` — the modeled device bytes
    of the largest packed launch — and ``chosen_k``.

    ``journal``: explicit :class:`~repro.checkpoint.PartitionJournal`
    override; when None one is derived from ``cfg.checkpoint_dir`` (keyed
    by the design's structural hash) if configured — see
    :func:`_journal_for`.
    """
    from repro.exec.plan import plan_from_subgraphs
    from repro.exec.stream import shared_executor

    assert prep.subgraphs, "infer_streaming needs a partitioned PreparedDesign"
    backend = backend or prep.cfg.backend
    cfg = prep.cfg
    if executor is None:
        devices = cfg.mesh_devices
        if devices is None:
            import jax

            devices = jax.local_device_count()
        if devices > 1:
            # >1 visible device (or an explicit mesh_devices): shard the
            # stream across the mesh data axis — same packed launches,
            # same verdict, one journal
            from repro.mesh import shared_mesh_executor

            executor = shared_mesh_executor(
                params, backend or "ref", num_devices=devices,
                capacity=cfg.stream_capacity,
                prefetch=cfg.stream_prefetch,
                stream_dtype=_effective_stream_dtype(cfg),
            )
        else:
            # reused per (params, backend): repeated partitioned runs hit
            # the same jit cache instead of retracing every bucket
            executor = shared_executor(
                params, backend, capacity=cfg.stream_capacity,
                prefetch=cfg.stream_prefetch,
                stream_dtype=_effective_stream_dtype(cfg),
            )
    if plan is None:
        plan = plan_from_subgraphs(
            list(prep.subgraphs), prep.num_nodes, num_edges=prep.num_edges,
            regrow=cfg.regrow, partitioner=cfg.partitioner, seed=cfg.seed,
            min_nodes=executor.min_nodes, min_edges=executor.min_edges,
        )
    if journal is None:
        journal = _journal_for(prep)
    before = dataclasses.replace(executor.stats)
    pred = executor.run_plan(plan, prep.feats, gnn_cfg=cfg.gnn, journal=journal)
    stats = dataclasses.asdict(executor.stats.delta(before))
    stats["peak_packed_memory_bytes"] = plan.peak_batch_memory_bytes(
        cfg.gnn, executor.capacity
    )
    stats["num_buckets"] = plan.num_buckets
    stats["chosen_k"] = prep.num_partitions
    # model drift: the analytic model on real launched shapes over the
    # plan-time prediction choose_k budgeted against.  >1 means launches
    # were bigger than modeled (the budget was optimistic); kept next to
    # chosen_k because that is the decision this ratio validates.
    modeled, actual = stats["modeled_peak_bytes"], stats["actual_peak_bytes"]
    if modeled:
        stats["model_drift"] = actual / modeled
    return pred, stats


def verify_prepared(
    prep: PreparedDesign, pred: np.ndarray, *, signed: Optional[bool] = None
) -> Optional[VerifyResult]:
    """Stage 3 (host): algebraic adder extraction + simulation cross-check.

    Returns None when the prepared design is not verifiable as a single
    multiplier AIG (batched runs, LUT graphs).
    """
    if prep.cfg.batch != 1 or not isinstance(prep.design, A.AIG):
        return None
    bits = prep.design.n_pi // 2
    if signed is None:
        signed = prep.cfg.dataset == "booth" or prep.design.name.startswith("booth")
    with span("pipeline.verify_prepared", bits=bits):
        REGISTRY.counter("pipeline.verifications").inc()
        return verify(
            prep.design,
            pred[: prep.design.num_nodes],
            bits=bits,
            signed=signed,
            simulate=bits <= 64,
        )


def run_pipeline(
    cfg: PipelineConfig, params, *, verify_result: bool = False
) -> PipelineResult:
    """DEPRECATED shim over :class:`repro.api.Session` (the one façade).

    Behaviour-preserving: the session is configured field-for-field from
    ``cfg`` (``SessionConfig.from_pipeline``) and its router takes the
    same full/streamed path this function used to hard-code, with the
    result LRU bypassed so every call really runs.
    """
    import warnings

    warnings.warn(
        "run_pipeline is deprecated; use repro.api.Session.verify",
        DeprecationWarning,
        stacklevel=2,
    )
    from repro.api import Session, SessionConfig

    r = Session(params, SessionConfig.from_pipeline(cfg)).verify(
        verify=verify_result, use_cache=False
    )
    return PipelineResult(
        accuracy=r.accuracy,
        core_accuracy=r.core_accuracy,
        peak_memory_bytes=r.peak_memory_bytes,
        unpartitioned_memory_bytes=r.unpartitioned_memory_bytes,
        boundary_edge_frac=r.boundary_edge_frac,
        timings=r.timings,
        verdict=r.verdict,
        num_nodes=r.num_nodes,
        num_edges=r.num_edges,
        plan_cache=r.plan_cache,
        exec_stats=r.exec_stats,
        trace=r.trace,
    )


def train_model(
    dataset: str = "csa",
    bits: int = 8,
    *,
    cfg: Optional[gnn.GNNConfig] = None,
    epochs: int = 300,
    seed: int = 0,
):
    """Train the GNN on a small design (the paper trains on 8-bit)."""
    import jax

    cfg = cfg or gnn.GNNConfig()
    design = A.make_design(dataset, bits, seed=seed)
    feats = groot_features(design)
    batch = gnn.make_batch(design, feats, design.label.astype(np.int32))
    params = gnn.init_params(cfg, jax.random.key(seed))
    params, hist = gnn.train(params, batch, epochs=epochs, log_every=50)
    return params, hist
