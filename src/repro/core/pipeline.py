"""End-to-end GROOT verification pipeline (paper Fig. 2 stages a-e).

    netlist/AIG -> features -> [partition -> re-growth] -> GNN inference
    -> XOR/MAJ classification -> algebraic verification

The flow is exposed both as the one-shot :func:`run_pipeline` and as the
three reusable stages it composes —

  :func:`prepare`          host-side: design gen/ingest, features,
                           partitioning + boundary re-growth
  :func:`infer`            device-side: (partitioned) GNN prediction
  :func:`verify_prepared`  host-side: adder extraction + simulation check

— so batch schedulers (``repro.service``) can interleave the host and
device stages of many requests instead of running each end to end.

Also provides the device-memory model used by the Fig. 8 / Table II
benchmark: because this container is CPU-only, "GPU memory" is an
*analytic but array-accurate* count of the device buffers each inference
step allocates (features, activations for L layers, edge arrays, gathered
edge streams, params).  Partitioned runs count the PEAK over partitions —
exactly the quantity the paper's partitioning bounds.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

from repro.core import aig as A
from repro.core import gnn
from repro.core.features import groot_features
from repro.core.graph import EdgeGraph, batch_graphs
from repro.core.partition import PARTITIONERS
from repro.core.regrowth import Subgraph, extract_partitions, boundary_edge_fraction
from repro.core.verify import VerifyResult, verify
from repro.kernels.plan_cache import PLAN_CACHE


@dataclasses.dataclass
class PipelineConfig:
    dataset: str = "csa"
    bits: int = 32
    batch: int = 1
    num_partitions: int = 1
    regrow: bool = True
    partitioner: str = "multilevel"
    gnn: gnn.GNNConfig = dataclasses.field(default_factory=gnn.GNNConfig)
    aggregate: str = "ref"   # "ref" | "groot" (Pallas kernel) | "onehot"
    seed: int = 0


@dataclasses.dataclass
class PipelineResult:
    accuracy: float
    core_accuracy: float          # accuracy on S_p nodes (what the paper plots)
    peak_memory_bytes: int
    unpartitioned_memory_bytes: int
    boundary_edge_frac: float
    timings: dict
    verdict: Optional[VerifyResult]
    num_nodes: int
    num_edges: int
    # structural plan-cache activity during this run's inference stage:
    # {"builds": new plans/pairs built, "hits": reused}.  A repeated run
    # over the same structure shows builds == 0.  Deltas of the
    # process-global cache counters: attribution is only exact when no
    # other thread (e.g. a live VerificationService) runs inference
    # concurrently.
    plan_cache: dict = dataclasses.field(default_factory=dict)


def memory_model_bytes(
    num_nodes: int, num_edges: int, cfg: gnn.GNNConfig, include_params: bool = True
) -> int:
    """Device bytes for one inference over a (sub)graph.

    features (N,Fin) fp32 + per-layer activations 2x(N,H) (double-buffered
    current/next) + 2x aggregated (N,H) + edge index arrays 2x int32 x2
    directions + gathered edge stream (E,H) fp32 (the gather->MXU stream of
    the TPU formulation) + params.
    """
    f32 = 4
    n, e = num_nodes, num_edges
    bytes_ = n * cfg.in_features * f32
    h = cfg.hidden
    bytes_ += 2 * n * h * f32          # h, h_next
    bytes_ += 2 * n * h * f32          # agg_in, agg_out
    bytes_ += 2 * 2 * e * 4            # edge src/dst, both directions
    bytes_ += e * h * f32              # gathered edge stream
    if include_params:
        p = cfg.in_features * h * 3 + (cfg.num_layers - 1) * 3 * h * h + h * cfg.num_classes
        bytes_ += p * f32
    return int(bytes_)


@dataclasses.dataclass
class PreparedDesign:
    """Host-side output of :func:`prepare` — everything inference needs."""

    cfg: PipelineConfig
    design: object               # AIG or LUTGraph
    labels: np.ndarray
    feats: np.ndarray
    graph: EdgeGraph
    subgraphs: Optional[list[Subgraph]]   # None when unpartitioned
    boundary_edge_frac: float
    timings: dict

    @property
    def num_nodes(self) -> int:
        return self.graph.num_nodes

    @property
    def num_edges(self) -> int:
        return self.graph.num_edges

    def memory_bytes(self) -> tuple[int, int]:
        """(unpartitioned, peak-over-partitions) device bytes."""
        full = memory_model_bytes(self.num_nodes, self.num_edges, self.cfg.gnn)
        if not self.subgraphs:
            return full, full
        peak = max(
            memory_model_bytes(sg.num_nodes, sg.num_edges, self.cfg.gnn)
            for sg in self.subgraphs
        )
        return full, peak


def prepare(cfg: PipelineConfig, design=None) -> PreparedDesign:
    """Stage 1 (host): design generation/ingest, features, partition+re-growth.

    ``design`` overrides generation — the ingestion path for AIGs parsed
    from AIGER files (``repro.io.aiger``); ``cfg.dataset``/``cfg.bits``
    are then only used for verification metadata downstream.
    """
    t0 = time.perf_counter()
    if design is None:
        design = A.make_design(cfg.dataset, cfg.bits, seed=cfg.seed)
    labels = design.label
    feats = groot_features(design)
    g1 = design.to_edge_graph()
    if cfg.batch > 1:
        g = batch_graphs([g1] * cfg.batch)
        feats = np.tile(feats, (cfg.batch, 1))
        labels = np.tile(labels, cfg.batch)
    else:
        g = g1
    t_gen = time.perf_counter() - t0

    t0 = time.perf_counter()
    if cfg.num_partitions <= 1:
        subs, bfrac, t_part = None, 0.0, 0.0
    else:
        part = PARTITIONERS[cfg.partitioner](g, cfg.num_partitions, seed=cfg.seed)
        bfrac = boundary_edge_fraction(g, part)
        subs = extract_partitions(g, part, regrow=cfg.regrow)
        t_part = time.perf_counter() - t0
    return PreparedDesign(
        cfg=cfg,
        design=design,
        labels=labels,
        feats=feats,
        graph=g,
        subgraphs=subs,
        boundary_edge_frac=bfrac,
        timings={"gen": t_gen, "partition": t_part},
    )


def infer(params, prep: PreparedDesign, *, backend: Optional[str] = None) -> np.ndarray:
    """Stage 2 (device): per-node class predictions over the full graph."""
    backend = backend or prep.cfg.aggregate
    if prep.subgraphs is None:
        return gnn.predict(params, prep.graph, prep.feats, backend=backend)
    return gnn.predict_partitioned(
        params, prep.subgraphs, prep.feats, prep.num_nodes, backend=backend
    )


def verify_prepared(
    prep: PreparedDesign, pred: np.ndarray, *, signed: Optional[bool] = None
) -> Optional[VerifyResult]:
    """Stage 3 (host): algebraic adder extraction + simulation cross-check.

    Returns None when the prepared design is not verifiable as a single
    multiplier AIG (batched runs, LUT graphs).
    """
    if prep.cfg.batch != 1 or not isinstance(prep.design, A.AIG):
        return None
    bits = prep.design.n_pi // 2
    if signed is None:
        signed = prep.cfg.dataset == "booth" or prep.design.name.startswith("booth")
    return verify(
        prep.design,
        pred[: prep.design.num_nodes],
        bits=bits,
        signed=signed,
        simulate=bits <= 64,
    )


def run_pipeline(
    cfg: PipelineConfig, params, *, verify_result: bool = False
) -> PipelineResult:
    """Inference + verification with a trained model (composes the stages)."""
    prep = prepare(cfg)
    t0 = time.perf_counter()
    pc_before = PLAN_CACHE.snapshot()
    pred = infer(params, prep)
    pc_after = PLAN_CACHE.snapshot()
    t_inf = time.perf_counter() - t0
    mem_full, peak_mem = prep.memory_bytes()
    acc = gnn.accuracy(pred, prep.labels)
    verdict = verify_prepared(prep, pred) if verify_result else None
    return PipelineResult(
        accuracy=acc,
        core_accuracy=acc,
        peak_memory_bytes=peak_mem,
        unpartitioned_memory_bytes=mem_full,
        boundary_edge_frac=prep.boundary_edge_frac,
        timings={**prep.timings, "inference": t_inf},
        verdict=verdict,
        num_nodes=prep.num_nodes,
        num_edges=prep.num_edges,
        plan_cache={
            "builds": pc_after.builds - pc_before.builds,
            "hits": pc_after.hits - pc_before.hits,
        },
    )


def train_model(
    dataset: str = "csa",
    bits: int = 8,
    *,
    cfg: Optional[gnn.GNNConfig] = None,
    epochs: int = 300,
    seed: int = 0,
):
    """Train the GNN on a small design (the paper trains on 8-bit)."""
    import jax

    cfg = cfg or gnn.GNNConfig()
    design = A.make_design(dataset, bits, seed=seed)
    feats = groot_features(design)
    batch = gnn.make_batch(design, feats, design.label.astype(np.int32))
    params = gnn.init_params(cfg, jax.random.key(seed))
    params, hist = gnn.train(params, batch, epochs=epochs, log_every=50)
    return params, hist
