"""Ingestion/serialization boundary: standard circuit formats -> repro AIGs."""
from repro.io.aiger import dump, dumps, load, loads, structural_hash  # noqa: F401
