"""Ingestion/serialization boundary: standard circuit formats -> repro AIGs."""
from repro.io.aiger import (  # noqa: F401
    dump,
    dumps,
    load,
    loads,
    source_bytes,
    structural_hash,
)
