"""AIGER reader/writer for :class:`repro.core.aig.AIG`.

Implements both formats of the AIGER 1.9 combinational subset:

  * ASCII  (``aag M I L O A``): explicit input/output/and lines, any
    gate order (we topologically sort on read);
  * binary (``aig M I L O A``): implicit inputs, delta-compressed
    LEB128 gate encoding, gates guaranteed topologically ordered.

Latches are not supported (the GROOT workload is combinational
multipliers).  Both repro and AIGER use the ABC literal convention
``lit = 2*var + inv``, so conversion is a variable renumbering:

  AIGER var 1..I        <->  AIG PI nodes 0..I-1
  AIGER var I+1..I+A    <->  AIG AND nodes, topological order
  AIGER output literals <->  AIG PO nodes (appended after all ANDs)

AIGER carries no node labels, but the GROOT flow needs the
construction-time XOR/MAJ ground truth to score predictions.  We
persist labels losslessly through the comment section (``c``) as a
``groot-labels`` digit string (one char per node, reconstructed node
order); files from other producers fall back to the classical
structural detector (:func:`repro.core.labels.structural_detect`).

:func:`structural_hash` — the service-layer dedup key — hashes the
canonical comment-free binary encoding, so it is invariant to format,
symbol tables, comments, and design names.
"""
from __future__ import annotations

import hashlib
import heapq
import io
from typing import Optional, Union

import numpy as np

from repro import faults
from repro.core import aig as A
from repro.obs import REGISTRY, span

__all__ = [
    "dump", "dumps", "load", "loads", "structural_hash",
    "AigerError", "AigerParseError",
]


class AigerError(ValueError):
    """Malformed or unsupported AIGER input."""


class AigerParseError(AigerError):
    """Malformed AIGER *content*, attributed to a byte offset when known.

    The service parses untrusted bytes on its prepare pool; a corrupt
    upload must come back as one typed, offset-attributed per-ticket
    error — never as a bare ``ValueError`` (or worse, an unbounded
    allocation) escaping from whatever line happened to choke first.
    """

    def __init__(self, message: str, *, offset: Optional[int] = None):
        if offset is not None:
            message = f"{message} (at byte {offset})"
        super().__init__(message)
        self.offset = offset


# ---------------------------------------------------------------------------
# Writing
# ---------------------------------------------------------------------------

def _var_map(aig: A.AIG) -> tuple[np.ndarray, np.ndarray]:
    """AIGER variable index per node (PIs 1..I, ANDs I+1.. in node order)."""
    kind = aig.kind
    if not (kind[: aig.n_pi] == A.PI).all() or int((kind == A.PI).sum()) != aig.n_pi:
        raise AigerError("AIG does not keep its PIs in nodes [0, n_pi)")
    and_nodes = np.where(kind == A.AND)[0]
    var = np.zeros(aig.num_nodes, dtype=np.int64)
    var[: aig.n_pi] = np.arange(1, aig.n_pi + 1)
    var[and_nodes] = aig.n_pi + 1 + np.arange(len(and_nodes))
    return var, and_nodes


def _to_aiger_lit(var: np.ndarray, lit: int) -> int:
    if lit < 0:
        raise AigerError("constant literals are folded at build time; cannot export")
    return 2 * int(var[lit >> 1]) + (lit & 1)


def _label_string(aig: A.AIG, and_nodes: np.ndarray) -> str:
    """Labels in *reconstructed* node order: PIs, ANDs, POs(pos order)."""
    ordered = np.concatenate(
        [aig.label[: aig.n_pi], aig.label[and_nodes], aig.label[aig.pos]]
    )
    return "".join(chr(ord("0") + int(v)) for v in ordered)


def _encode_leb(delta: int, out: bytearray) -> None:
    while delta >= 0x80:
        out.append((delta & 0x7F) | 0x80)
        delta >>= 7
    out.append(delta)


def dumps(aig: A.AIG, *, binary: bool = True, comments: bool = True) -> bytes:
    """Serialize an AIG to AIGER bytes (binary ``aig`` or ASCII ``aag``)."""
    var, and_nodes = _var_map(aig)
    n_and = len(and_nodes)
    m = aig.n_pi + n_and
    outputs = [_to_aiger_lit(var, int(aig.fanin0[p])) for p in aig.pos]

    buf = bytearray()
    magic = b"aig" if binary else b"aag"
    buf += b"%s %d %d 0 %d %d\n" % (magic, m, aig.n_pi, len(outputs), n_and)
    if not binary:
        for i in range(aig.n_pi):
            buf += b"%d\n" % (2 * (i + 1))
    for o in outputs:
        buf += b"%d\n" % o
    if binary:
        for k, node in enumerate(and_nodes):
            lhs = 2 * (aig.n_pi + 1 + k)
            r0 = _to_aiger_lit(var, int(aig.fanin0[node]))
            r1 = _to_aiger_lit(var, int(aig.fanin1[node]))
            rhs0, rhs1 = max(r0, r1), min(r0, r1)
            if rhs0 >= lhs:
                raise AigerError("AND fanins are not topologically ordered")
            _encode_leb(lhs - rhs0, buf)
            _encode_leb(rhs0 - rhs1, buf)
    else:
        for k, node in enumerate(and_nodes):
            lhs = 2 * (aig.n_pi + 1 + k)
            r0 = _to_aiger_lit(var, int(aig.fanin0[node]))
            r1 = _to_aiger_lit(var, int(aig.fanin1[node]))
            # same ordering requirement as the binary format: the reader's
            # smallest-var-first topo sort then reproduces this gate order,
            # which the groot-labels comment relies on
            if max(r0, r1) >= lhs:
                raise AigerError("AND fanins are not topologically ordered")
            buf += b"%d %d %d\n" % (lhs, max(r0, r1), min(r0, r1))
    if comments:
        buf += b"c\n"
        buf += b"groot-name %s\n" % aig.name.encode()
        buf += b"groot-labels %s\n" % _label_string(aig, and_nodes).encode()
    return bytes(buf)


def dump(aig: A.AIG, path, *, binary: bool = True, comments: bool = True) -> None:
    with open(path, "wb") as f:
        f.write(dumps(aig, binary=binary, comments=comments))


# ---------------------------------------------------------------------------
# Reading
# ---------------------------------------------------------------------------

def _read_line(f: io.BytesIO) -> bytes:
    at = f.tell()
    line = f.readline()
    if not line:
        raise AigerParseError("unexpected end of AIGER data", offset=at)
    return line.rstrip(b"\n")


def _read_uint(f: io.BytesIO, what: str) -> int:
    """One non-negative decimal line (output/input literal sections)."""
    at = f.tell()
    line = _read_line(f)
    try:
        value = int(line)
    except ValueError:
        raise AigerParseError(
            f"bad {what} line {line!r}", offset=at
        ) from None
    if value < 0:
        raise AigerParseError(f"negative {what} {value}", offset=at)
    return value


def _decode_leb(f: io.BytesIO) -> int:
    value, shift = 0, 0
    while True:
        at = f.tell()
        byte = f.read(1)
        if not byte:
            raise AigerParseError("truncated binary AND section", offset=at)
        b = byte[0]
        value |= (b & 0x7F) << shift
        if not b & 0x80:
            return value
        shift += 7
        if shift > 63:
            # a literal needing >63 bits is corruption, not a big design —
            # bail before the int (and the arrays sized from it) balloon
            raise AigerParseError(
                "LEB128 delta exceeds 64 bits", offset=at
            )


def _topo_sort_ands(defs: dict[int, tuple[int, int]], n_in: int) -> list[int]:
    """Kahn's algorithm over AND variable definitions (ASCII files may list
    gates in any order).  Smallest ready variable first: a file whose
    variables are already topologically increasing (every writer we know
    of, including ours) round-trips with its gate order intact."""
    indeg = {v: 0 for v in defs}
    users: dict[int, list[int]] = {v: [] for v in defs}
    for v, (r0, r1) in defs.items():
        for r in (r0 >> 1, r1 >> 1):
            if r in defs:
                indeg[v] += 1
                users[r].append(v)
            elif r > n_in and r not in defs:
                raise AigerError(f"undefined AND variable {r}")
    ready = [v for v, d in indeg.items() if d == 0]
    heapq.heapify(ready)
    order: list[int] = []
    while ready:
        v = heapq.heappop(ready)
        order.append(v)
        for u in users[v]:
            indeg[u] -= 1
            if indeg[u] == 0:
                heapq.heappush(ready, u)
    if len(order) != len(defs):
        raise AigerError("cyclic AND definitions")
    return order


def _parse_trailer(f: io.BytesIO) -> dict[str, str]:
    """Symbol table + comment section -> {name, labels} when present."""
    meta: dict[str, str] = {}
    in_comments = False
    for raw in f.read().split(b"\n"):
        line = raw.decode("utf-8", errors="replace")
        if not in_comments:
            if line == "c":
                in_comments = True
            continue
        if line.startswith("groot-name "):
            meta["name"] = line[len("groot-name "):]
        elif line.startswith("groot-labels "):
            meta["labels"] = line[len("groot-labels "):]
    return meta


def peek_name(data: bytes) -> Optional[str]:
    """Cheap name scan: the ``groot-name`` comment line, without parsing.

    For attributing requests that failed before (or during) the full
    parse — scans only the comment section after the ``c`` marker.
    """
    in_comments = False
    for raw in data.split(b"\n"):
        if not in_comments:
            if raw == b"c":
                in_comments = True
            continue
        if raw.startswith(b"groot-name "):
            return raw[len(b"groot-name "):].decode(
                "utf-8", errors="replace"
            ).strip() or None
    return None


def loads(data: bytes, *, name: str = "aiger") -> A.AIG:
    """Parse AIGER bytes (either format) into an :class:`AIG`."""
    with span("io.aiger.loads", bytes=len(data)) as sp:
        faults.fire("io.parse", tag=lambda: peek_name(data) or name)
        aig = _loads(data, name=name)
        sp.set(nodes=aig.num_nodes)
    REGISTRY.counter("io.aiger.parses").inc()
    REGISTRY.counter("io.aiger.bytes").inc(len(data))
    return aig


def _loads(data: bytes, *, name: str) -> A.AIG:
    f = io.BytesIO(data)
    header = _read_line(f).split()
    if len(header) < 6 or header[0] not in (b"aig", b"aag"):
        raise AigerParseError(
            "not an AIGER file (want 'aig'/'aag M I L O A' header)", offset=0
        )
    binary = header[0] == b"aig"
    try:
        m, n_in, n_latch, n_out, n_and = (int(x) for x in header[1:6])
    except ValueError as e:
        raise AigerParseError(f"bad header {header!r}", offset=0) from e
    if min(m, n_in, n_latch, n_out, n_and) < 0:
        raise AigerParseError(f"negative header count in {header!r}", offset=0)
    if n_latch:
        raise AigerError("latches are not supported (combinational AIGs only)")
    if m != n_in + n_and:
        raise AigerParseError(f"header M={m} != I+A={n_in + n_and}", offset=0)
    # every declared object costs bytes downstream (≥2 for an AND or an
    # output line) — counts past the file size are corruption, and must
    # be rejected BEFORE they size any allocation
    if max(n_in, n_out, n_and) > len(data):
        raise AigerParseError(
            f"header counts {header!r} exceed file size {len(data)}", offset=0
        )

    if binary:
        out_lits = [_read_uint(f, "output literal") for _ in range(n_out)]
        and_order = list(range(n_in + 1, n_in + n_and + 1))
        defs: dict[int, tuple[int, int]] = {}
        for i, v in enumerate(and_order):
            lhs = 2 * v
            at = f.tell()
            d0 = _decode_leb(f)
            d1 = _decode_leb(f)
            rhs0 = lhs - d0
            rhs1 = rhs0 - d1
            if rhs1 < 0 or rhs0 >= lhs:
                raise AigerParseError(
                    f"bad delta encoding for AND {v}", offset=at
                )
            defs[v] = (rhs0, rhs1)
    else:
        in_lits = [_read_uint(f, "input literal") for _ in range(n_in)]
        for i, lit in enumerate(in_lits):
            if lit != 2 * (i + 1):
                raise AigerError("non-contiguous ASCII input literals unsupported")
        out_lits = [_read_uint(f, "output literal") for _ in range(n_out)]
        defs = {}
        for _ in range(n_and):
            at = f.tell()
            fields = _read_line(f).split()
            try:
                lhs, r0, r1 = (int(x) for x in fields)
            except ValueError:
                raise AigerParseError(
                    f"bad AND line {fields!r} (want 'lhs rhs0 rhs1')", offset=at
                ) from None
            if lhs & 1 or not (n_in + 1 <= lhs >> 1 <= m):
                raise AigerParseError(f"bad AND lhs literal {lhs}", offset=at)
            defs[lhs >> 1] = (r0, r1)
        if len(defs) != n_and:
            raise AigerError("duplicate AND definitions")
        and_order = _topo_sort_ands(defs, n_in)
    meta = _parse_trailer(f)

    # Node layout: PIs, ANDs (topological), then POs.
    num_nodes = n_in + n_and + n_out
    node_of_var = np.full(m + 1, -1, dtype=np.int64)
    node_of_var[1 : n_in + 1] = np.arange(n_in)
    for k, v in enumerate(and_order):
        node_of_var[v] = n_in + k

    def conv(lit: int) -> int:
        if lit < 2:
            raise AigerError("constant literals unsupported (fold them upstream)")
        if lit >> 1 > m:
            raise AigerError(f"literal {lit} exceeds max variable index {m}")
        node = int(node_of_var[lit >> 1])
        if node < 0:
            raise AigerError(f"literal {lit} references an undefined variable")
        return 2 * node + (lit & 1)

    kind = np.empty(num_nodes, dtype=np.int8)
    fanin0 = np.full(num_nodes, -3, dtype=np.int64)
    fanin1 = np.full(num_nodes, -3, dtype=np.int64)
    kind[:n_in] = A.PI
    for k, v in enumerate(and_order):
        l0, l1 = (conv(x) for x in defs[v])
        node = n_in + k
        kind[node] = A.AND
        fanin0[node], fanin1[node] = min(l0, l1), max(l0, l1)
    pos = np.arange(n_in + n_and, num_nodes, dtype=np.int64)
    kind[pos] = A.PO
    fanin0[pos] = [conv(o) for o in out_lits]

    label = meta.get("labels", "")
    if len(label) == num_nodes:
        labels = np.frombuffer(label.encode(), dtype=np.uint8).astype(np.int8)
        labels -= ord("0")
        if labels.size and (labels.min() < 0 or labels.max() >= A.NUM_CLASSES):
            raise AigerError("corrupt groot-labels comment")
    else:
        from repro.core.labels import structural_detect

        labels = None  # needs the AIG below

    aig = A.AIG(
        name=meta.get("name", name),
        kind=kind,
        fanin0=fanin0,
        fanin1=fanin1,
        label=labels if labels is not None else np.zeros(num_nodes, np.int8),
        n_pi=n_in,
        pos=pos,
    )
    if labels is None:
        aig.label = structural_detect(aig)
    return aig


def load(path) -> A.AIG:
    with open(path, "rb") as f:
        data = f.read()
    import os

    return loads(data, name=os.path.splitext(os.path.basename(str(path)))[0])


def source_bytes(source) -> bytes:
    """Raw AIGER bytes from raw bytes or a file path — the ONE
    normalisation both the service's ``submit_aiger`` and the façade's
    ``Session.submit`` use, so deferred (per-ticket-error) parsing always
    sees identical input handling."""
    if isinstance(source, (bytes, bytearray)):
        return bytes(source)
    with open(source, "rb") as f:
        return f.read()


# ---------------------------------------------------------------------------
# Structural hashing (service-layer dedup key)
# ---------------------------------------------------------------------------

def structural_hash(design: Union[A.AIG, bytes]) -> str:
    """Canonical content hash of a design.

    AIGs hash their comment-free binary AIGER encoding, so the same
    structure produces the same key regardless of name, labels, or the
    on-disk format it arrived in.  Raw AIGER bytes are normalised by a
    parse -> re-encode round trip.
    """
    if isinstance(design, (bytes, bytearray)):
        design = loads(bytes(design))
    return hashlib.sha256(dumps(design, binary=True, comments=False)).hexdigest()
