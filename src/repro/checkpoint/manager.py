"""Fault-tolerant checkpointing: async, atomic, reshard-on-restore.

Layout (one directory per step):

    <dir>/step_000120.tmp/           written first
        shard_<host>.npz             flat leaf arrays (this host's shards)
        manifest.json                treedef + leaf shapes/dtypes + step
    <dir>/step_000120/               atomic rename when complete

Guarantees used by the restart path:
  * a checkpoint directory either has its final name and is complete, or
    is a ``.tmp`` (crashed mid-write) and is ignored/garbage-collected;
  * ``restore`` loads the newest complete step and re-shards every leaf
    onto the CURRENT mesh (``jax.device_put`` with the target sharding),
    so restarts may change topology (elastic restart: e.g. 512 -> 256
    chips after losing a pod);
  * saving runs on a background thread (compute is not blocked by I/O);
    ``wait()`` joins before the next save so at most one write is in
    flight.

On multi-host deployments each host writes only the addressable shards of
its arrays; this CPU container acts as host 0 of 1.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_names(tree) -> list:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = jax.tree_util.keystr(path)
        out.append((name, leaf))
    return out


def save(tree, directory: str | os.PathLike, step: int, *, host_id: int = 0):
    """Synchronous atomic save of a pytree."""
    d = Path(directory)
    final = d / f"step_{step:09d}"
    tmp = d / (final.name + ".tmp")
    tmp.mkdir(parents=True, exist_ok=True)

    named = _flatten_with_names(tree)
    arrays = {}
    manifest = {"step": step, "leaves": [], "hosts": 1}
    for i, (name, leaf) in enumerate(named):
        arr = np.asarray(leaf)
        key = f"leaf_{i:05d}"
        arrays[key] = arr
        manifest["leaves"].append(
            {"key": key, "name": name, "shape": list(arr.shape), "dtype": str(arr.dtype)}
        )
    np.savez(tmp / f"shard_{host_id}.npz", **arrays)
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)  # atomic publish
    return final


def latest_step(directory: str | os.PathLike) -> Optional[int]:
    d = Path(directory)
    if not d.exists():
        return None
    steps = []
    for p in d.iterdir():
        if p.is_dir() and p.name.startswith("step_") and not p.name.endswith(".tmp"):
            if (p / "manifest.json").exists():
                steps.append(int(p.name.split("_")[1]))
    return max(steps) if steps else None


def restore(
    like_tree,
    directory: str | os.PathLike,
    *,
    step: Optional[int] = None,
    shardings: Any = None,
):
    """Load a checkpoint into the structure of ``like_tree``.

    ``shardings``: optional pytree of NamedSharding for the CURRENT mesh —
    every leaf is re-laid-out via device_put (elastic reshard-on-restore).
    Returns (tree, step).
    """
    d = Path(directory)
    step = step if step is not None else latest_step(d)
    if step is None:
        raise FileNotFoundError(f"no complete checkpoint under {d}")
    final = d / f"step_{step:09d}"
    manifest = json.loads((final / "manifest.json").read_text())
    data = np.load(final / "shard_0.npz")
    leaves = [data[entry["key"]] for entry in manifest["leaves"]]

    flat_like, treedef = jax.tree_util.tree_flatten(like_tree)
    assert len(flat_like) == len(leaves), (
        f"checkpoint has {len(leaves)} leaves, target tree {len(flat_like)}"
    )
    shard_flat = (
        jax.tree_util.tree_flatten(shardings)[0] if shardings is not None else None
    )
    out = []
    for i, (like, arr) in enumerate(zip(flat_like, leaves)):
        arr = arr.astype(like.dtype) if hasattr(like, "dtype") else arr
        if shard_flat is not None:
            out.append(jax.device_put(arr, shard_flat[i]))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), step


class PartitionJournal:
    """Crash-safe per-partition prediction journal for streamed runs.

    A streamed verification of a huge design launches hundreds of packed
    batches; a crash (preemption, OOM kill) at batch *i* used to forfeit
    batches ``0..i-1``.  The journal makes partition results durable as
    they land:

        <base>/<design_key>/
            meta.json            plan fingerprint + partition count
            part_00042.npz       ids (int64 core node ids), pred (int32)
            part_00042.npz.tmp   crashed mid-write -> ignored, overwritten

    Same atomicity discipline as the step checkpoints above: a partition
    file either exists complete (tmp + ``os.replace``) or not at all.
    Each file stores BOTH the core node ids and their predictions, so a
    restore scatters ``out[ids] = pred`` without consulting the plan —
    but the journal is only trusted when the plan *fingerprint* (a hash
    over every partition's core id layout plus the planning knobs)
    matches; different partitioning knobs wipe the directory and start
    fresh rather than scattering stale rows.
    """

    def __init__(self, base_dir: str | os.PathLike, design_key: str):
        self.dir = Path(base_dir) / design_key
        self._validated = False

    # -- plan identity -------------------------------------------------------

    @staticmethod
    def plan_fingerprint(plan) -> str:
        import hashlib

        h = hashlib.sha256()
        h.update(
            repr((plan.num_nodes, plan.num_parts, plan.k, plan.regrow,
                  plan.partitioner, plan.seed)).encode()
        )
        for sg in plan.subgraphs:
            h.update(np.int64(sg.num_core).tobytes())
            h.update(np.ascontiguousarray(
                sg.global_ids[: sg.num_core], dtype=np.int64
            ).tobytes())
        return h.hexdigest()

    # -- lifecycle -----------------------------------------------------------

    def _part_path(self, index: int) -> Path:
        return self.dir / f"part_{index:05d}.npz"

    def open(self, plan) -> set:
        """Validate the journal directory against ``plan``; wipe it on a
        fingerprint mismatch.  Returns committed partition indices."""
        fp = self.plan_fingerprint(plan)
        meta_path = self.dir / "meta.json"
        meta = None
        if meta_path.exists():
            try:
                meta = json.loads(meta_path.read_text())
            except (OSError, ValueError):
                meta = None
        if meta is None or meta.get("plan") != fp:
            if self.dir.exists():
                shutil.rmtree(self.dir, ignore_errors=True)
            self.dir.mkdir(parents=True, exist_ok=True)
            tmp = meta_path.with_suffix(".json.tmp")
            tmp.write_text(json.dumps(
                {"plan": fp, "num_parts": plan.num_parts}
            ))
            os.replace(tmp, meta_path)
        self._validated = True
        done = set()
        for p in self.dir.glob("part_*.npz"):
            try:
                done.add(int(p.stem.split("_")[1]))
            except (IndexError, ValueError):
                continue
        return done

    def restore(self, plan, out: np.ndarray) -> set:
        """Scatter every committed partition's core predictions into
        ``out``; returns the set of restored partition indices."""
        from repro import faults

        faults.fire("cache.load", tag=lambda: self.dir.name)
        restored = set()
        for i in sorted(self.open(plan)):
            if i >= plan.num_parts:
                continue
            try:
                with np.load(self._part_path(i)) as z:
                    ids, pred = z["ids"], z["pred"]
            except (OSError, ValueError, KeyError):
                # unreadable entry: drop it, the partition just re-runs
                self._part_path(i).unlink(missing_ok=True)
                continue
            if ids.shape != pred.shape or (
                ids.size and (ids.min() < 0 or ids.max() >= out.shape[0])
            ):
                self._part_path(i).unlink(missing_ok=True)
                continue
            out[ids] = pred
            restored.add(i)
        return restored

    def commit(self, index: int, ids: np.ndarray, pred: np.ndarray) -> None:
        """Atomically persist one partition's core predictions."""
        assert self._validated, "open()/restore() the journal before commit()"
        final = self._part_path(index)
        tmp = final.with_suffix(".npz.tmp")
        # savez appends ``.npz`` to bare names — write through an open
        # file handle so the tmp path is exactly what os.replace expects
        with open(tmp, "wb") as f:
            np.savez(
                f,
                ids=np.ascontiguousarray(ids, dtype=np.int64),
                pred=np.ascontiguousarray(pred, dtype=np.int32),
            )
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, final)

    def complete(self) -> None:
        """The run finished: the verdict is computed and cached upstream,
        so the journal has served its purpose — reclaim the space."""
        shutil.rmtree(self.dir, ignore_errors=True)
        self._validated = False


class CheckpointManager:
    """Async manager: save() snapshots to host memory and writes on a
    background thread; keeps the newest ``keep`` checkpoints."""

    def __init__(self, directory: str | os.PathLike, *, keep: int = 3):
        self.directory = Path(directory)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.save_count = 0
        self.last_error: Optional[BaseException] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err

    def save_async(self, tree, step: int):
        self.wait()
        # snapshot to host memory NOW (device buffers may be donated later)
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def work():
            try:
                save(host_tree, self.directory, step)
                self._gc()
                self.save_count += 1
            except BaseException as e:  # noqa: BLE001
                self.last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def _gc(self):
        steps = sorted(
            p for p in self.directory.iterdir()
            if p.is_dir() and p.name.startswith("step_")
        )
        complete = [p for p in steps if not p.name.endswith(".tmp")]
        for p in complete[: -self.keep]:
            shutil.rmtree(p, ignore_errors=True)
        # orphaned tmp dirs from crashes
        for p in steps:
            if p.name.endswith(".tmp") and time.time() - p.stat().st_mtime > 300:
                shutil.rmtree(p, ignore_errors=True)
