from repro.checkpoint.manager import (  # noqa: F401
    CheckpointManager,
    PartitionJournal,
    restore,
    save,
)
