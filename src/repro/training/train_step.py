"""Training step: remat + microbatch gradient accumulation + optimizer.

``make_train_step(cfg, optimizer, microbatches=M)`` builds a jit-able

    train_step(params, opt_state, batch) -> (params, opt_state, metrics)

* batch["tokens"]: (B, S+1) int32 — next-token LM loss over all positions.
* batch["enc_input"]: optional (B, S_enc, D) stub frontend embeddings
  (whisper frames / vlm patches).
* The microbatch loop is a ``lax.scan`` accumulating f32 gradients
  (sharded like the params), each microbatch's backward rematerialised
  per layer (``jax.checkpoint`` inside model_forward).
* Loss is softmax cross-entropy in f32; logits stay vocab-sharded — the
  label pick is a take_along_axis (GSPMD turns it into a gather +
  reduce over the "model" axis).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.zoo.configs.base import ModelConfig
from repro.zoo.models.transformer import model_forward
from repro.sharding import shard
from repro.training import optimizer as opt_mod


def lm_loss(params, cfg: ModelConfig, tokens, enc_input=None, *, remat=True,
            remat_group=1):
    """Mean next-token cross entropy.  tokens: (b, s+1)."""
    inputs, labels = tokens[:, :-1], tokens[:, 1:]
    logits, _ = model_forward(
        params, cfg, inputs, enc_input=enc_input, remat=remat,
        remat_group=remat_group,
    )
    logits = logits.astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab_size:  # mask pad columns out of the lse
        col = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
        logits = jnp.where(col < cfg.vocab_size, logits, -1e30)
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return (lse - picked).mean()


def make_train_step(
    cfg: ModelConfig,
    optimizer: opt_mod.AdamW,
    *,
    microbatches: int = 1,
    remat: bool = True,
    remat_group: int = 1,
):
    from repro.zoo.configs.base import model_spec_tree

    spec_tree = model_spec_tree(cfg)

    def constrain_like_params(gtree):
        """Pin gradient shardings to the parameters' logical axes.

        Without this the microbatch-scan's f32 accumulator inherits the
        backward's layout (expert grads lose their FSDP axis -> tens of
        GB/device); with it GSPMD inserts the ZeRO-style reduce-scatter.
        """
        return jax.tree.map(
            lambda g, sp: shard(g, sp.axes), gtree, spec_tree
        )

    def train_step(params, opt_state, batch):
        tokens = batch["tokens"]
        enc = batch.get("enc_input")
        b = tokens.shape[0]
        assert b % microbatches == 0, (b, microbatches)

        if microbatches == 1:
            loss, grads = jax.value_and_grad(lm_loss)(
                params, cfg, tokens, enc, remat=remat, remat_group=remat_group
            )
            grads = constrain_like_params(grads)
        else:
            mb = lambda a: a.reshape(
                (microbatches, b // microbatches) + a.shape[1:]
            )
            tok_mb = mb(tokens)
            enc_mb = mb(enc) if enc is not None else None

            def micro(acc, xs):
                tok = xs[0]
                e = xs[1] if enc is not None else None
                loss, g = jax.value_and_grad(lm_loss)(
                    params, cfg, tok, e, remat=remat, remat_group=remat_group)
                g32 = jax.tree.map(
                    lambda a, g_: a + g_.astype(jnp.float32), acc[0], g
                )
                return (constrain_like_params(g32), acc[1] + loss), None

            zeros = constrain_like_params(
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            )
            xs = (tok_mb, enc_mb) if enc is not None else (tok_mb,)
            (gsum, losssum), _ = jax.lax.scan(
                micro, (zeros, jnp.zeros((), jnp.float32)), xs
            )
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = losssum / microbatches

        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = opt_mod.apply_updates(params, updates)
        metrics = {
            "loss": loss,
            "grad_norm": opt_mod.global_norm(grads),
        }
        return params, opt_state, metrics

    return train_step
