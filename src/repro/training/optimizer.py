"""Optimizers: AdamW with optional int8 (blockwise-scaled) moments.

Self-contained (no optax offline).  The int8 variant keeps Adam's m/v in
int8 with per-block fp32 scales — 1.0+1.0 bytes/param + 2*4/block instead
of 4+4 — the memory plan for the 235B/400B assigned architectures (see
DESIGN.md §6).  API mirrors optax: ``init(params) -> state``,
``update(grads, state, params) -> (updates, state)``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip_norm: Optional[float] = 1.0
    # schedule: callable step -> lr multiplier baked in by the caller

    def init(self, params) -> AdamWState:
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            m=jax.tree.map(zeros, params),
            v=jax.tree.map(zeros, params),
        )

    def update(self, grads, state: AdamWState, params, lr_scale=1.0):
        step = state.step + 1
        if self.grad_clip_norm is not None:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.grad_clip_norm / (gnorm + 1e-12))
            grads = jax.tree.map(lambda g: g * scale, grads)
        b1, b2 = self.b1, self.b2
        m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) * g.astype(jnp.float32), state.m, grads)
        v = jax.tree.map(lambda vv, g: b2 * vv + (1 - b2) * jnp.square(g.astype(jnp.float32)), state.v, grads)
        t = step.astype(jnp.float32)
        mhat_scale = 1.0 / (1 - b1**t)
        vhat_scale = 1.0 / (1 - b2**t)
        lr = self.lr * lr_scale

        def upd(p, mm, vv):
            u = (mm * mhat_scale) / (jnp.sqrt(vv * vhat_scale) + self.eps)
            if self.weight_decay:
                u = u + self.weight_decay * p.astype(jnp.float32)
            return (-lr * u).astype(p.dtype)

        updates = jax.tree.map(upd, params, m, v)
        return updates, AdamWState(step=step, m=m, v=v)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)


# ---------------------------------------------------------------------------
# int8 per-row-quantized moments, for the giant archs.
#
# The moments keep the PARAMETER'S SHAPE in int8 with one f32 scale per
# trailing row ((..., 1)).  This is deliberately not the bitsandbytes
# flat-256-block layout: a flat layout needs reshape(-1) on arrays whose
# sharding follows the parameter (TP over d_ff/heads, FSDP over d_model),
# and GSPMD can only honour such reshapes by fully rematerialising the
# tensor (~150 GB spikes for the 235B expert stacks, observed in the
# dry-run).  Shape-preserving quantization composes with every sharding
# for free; the cost is coarser (per-row) scale granularity on the Adam
# moments, which only modulates the effective epsilon.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Q8:
    q: jax.Array        # int8, same shape as the parameter
    scale: jax.Array    # fp32, shape[:-1] + (1,)


def _q8_encode(x: jax.Array) -> Q8:
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0 + 1e-12
    q = jnp.round(x / scale).astype(jnp.int8)
    return Q8(q=q, scale=scale.astype(jnp.float32))


def _q8_decode(z: Q8) -> jax.Array:
    return z.q.astype(jnp.float32) * z.scale


jax.tree_util.register_pytree_with_keys(
    Q8,
    lambda z: (
        (
            (jax.tree_util.GetAttrKey("q"), z.q),
            (jax.tree_util.GetAttrKey("scale"), z.scale),
        ),
        None,
    ),
    lambda _, children: Q8(children[0], children[1]),
)


@dataclasses.dataclass(frozen=True)
class AdamW8bit(AdamW):
    """AdamW with int8 m/v.  Decode -> update -> re-encode each step; the
    quantization error on m/v is bounded by the per-block scale (<=0.8%)."""

    def init(self, params) -> AdamWState:
        enc = lambda p: _q8_encode(jnp.zeros(p.shape, jnp.float32))
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            m=jax.tree.map(enc, params),
            v=jax.tree.map(enc, params),
        )

    def update(self, grads, state: AdamWState, params, lr_scale=1.0):
        is_q8 = lambda x: isinstance(x, Q8)
        m_f = jax.tree.map(_q8_decode, state.m, is_leaf=is_q8)
        v_f = jax.tree.map(_q8_decode, state.v, is_leaf=is_q8)
        inner = AdamW(
            self.lr, self.b1, self.b2, self.eps, self.weight_decay, self.grad_clip_norm
        )
        updates, new = inner.update(
            grads, AdamWState(state.step, m_f, v_f), params, lr_scale
        )
        return updates, AdamWState(
            step=new.step,
            m=jax.tree.map(_q8_encode, new.m),
            v=jax.tree.map(_q8_encode, new.v),
        )


def make_optimizer(name: str, lr: float, weight_decay: float = 0.0, **kw):
    if name == "adamw":
        return AdamW(lr=lr, weight_decay=weight_decay, **kw)
    if name == "adamw8bit":
        return AdamW8bit(lr=lr, weight_decay=weight_decay, **kw)
    raise ValueError(name)


def cosine_schedule(step, *, base, warmup: int, total: int, min_frac: float = 0.1):
    """lr multiplier (not absolute lr): linear warmup then cosine decay."""
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    del base
    return warm * cos
