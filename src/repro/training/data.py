"""Data pipelines.

Token pipeline: a deterministic, restart-reproducible synthetic LM stream
(hash-PRNG per (seed, step, host)) with the structure of a sharded corpus
reader: each host materialises only its slice of the global batch, and the
stream can be fast-forwarded to any step in O(1) (required by
checkpoint-restart: data order must resume exactly).

For the quickstart example the stream carries a learnable signature
(repeating n-gram structure) so a ~100M model visibly reduces loss within
a few hundred steps.

Graph pipeline: the GROOT verification side — generates (design, features,
labels, partitions) batches for GNN training/inference.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenStreamConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0
    structure: int = 8   # n-gram period of the synthetic signal (0 = iid)


class TokenStream:
    """Deterministic O(1)-seekable synthetic token batches."""

    def __init__(self, cfg: TokenStreamConfig):
        assert cfg.global_batch % cfg.n_hosts == 0
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.n_hosts

    def batch_at(self, step: int) -> np.ndarray:
        """(local_batch, seq_len + 1) int32 — inputs+labels window."""
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, cfg.host_id])
        )
        b, s = self.local_batch, cfg.seq_len + 1
        if not cfg.structure:
            return rng.integers(0, cfg.vocab_size, (b, s), dtype=np.int64).astype(
                np.int32
            )
        # structured stream: one GLOBAL random n-gram (fixed per seed) is
        # repeated with a per-sequence phase roll + 5% corruption.  The
        # n-gram is memorisable in tens of steps, so a correct training
        # pipeline visibly drops the loss within a quickstart run (a
        # per-sequence-random n-gram would instead be an induction task
        # needing hundreds of steps to crack).
        period = cfg.structure
        base_rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, 7]))
        base = base_rng.integers(0, cfg.vocab_size, period, dtype=np.int64)
        reps = -(-s // period) + 1
        row = np.tile(base, reps)
        offs = rng.integers(0, period, b)
        seq = np.stack([row[o : o + s] for o in offs])
        noise = rng.random((b, s)) < 0.05  # 5% corruption
        seq[noise] = rng.integers(0, cfg.vocab_size, int(noise.sum()))
        return seq.astype(np.int32)

    def __iter__(self) -> Iterator[np.ndarray]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


# ---------------------------------------------------------------------------
# Graph pipeline (GROOT)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class GraphBatch:
    x: np.ndarray
    edge_src: np.ndarray
    edge_dst: np.ndarray
    edge_inv: Optional[np.ndarray]
    edge_slot: Optional[np.ndarray]
    labels: np.ndarray


def graph_batch(dataset: str, bits: int, seed: int = 0) -> GraphBatch:
    from repro.core import aig as A
    from repro.core.features import groot_features

    design = A.make_design(dataset, bits, seed=seed)
    g = design.to_edge_graph()
    return GraphBatch(
        x=groot_features(design),
        edge_src=g.edge_src,
        edge_dst=g.edge_dst,
        edge_inv=g.edge_inv,
        edge_slot=g.edge_slot,
        labels=np.asarray(design.label, np.int32),
    )
