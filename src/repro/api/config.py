"""`SessionConfig`: the one flattened configuration of the GROOT stack.

Before the façade, each front door owned its own knob set —
``PipelineConfig`` (with the ``aggregate=`` spelling of the backend),
``GNNConfig.stream_dtype``, ``ServiceConfig`` (with the ``backend=``
spelling), and raw executor kwargs — and callers re-threaded the same
values through every layer.  ``SessionConfig`` is the superset, named
once:

  * design/ingest defaults (``dataset``/``bits``/``seed``/``batch``),
  * execution (``backend`` everywhere — ``aggregate=`` remains a
    deprecated write-only alias), ``stream_dtype``, the nested
    ``GNNConfig``,
  * partitioning + re-growth (``num_partitions``, ``regrow``,
    ``regrow_hops``, ``partitioner``),
  * streaming (``streaming``, ``memory_budget_bytes``,
    ``stream_capacity``, ``stream_prefetch``),
  * batched-service limits (bucket floors/ceilings, worker counts,
    cache sizes).

The legacy configs are now *projections* of this one:
:meth:`pipeline_config` and :meth:`service_config` derive them, and
:meth:`from_pipeline` lifts an old ``PipelineConfig`` so the deprecated
entry points can delegate without changing behaviour.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.gnn import GNNConfig
from repro.core.pipeline import resolve_backend_alias  # noqa: F401 — re-export


@dataclasses.dataclass(frozen=True)
class SessionConfig:
    """Every knob of the full/partitioned/streamed/batched stack, flat."""

    # -- design / ingest defaults (per-call ``verify(dataset=, bits=)``
    #    overrides win) ------------------------------------------------------
    dataset: str = "csa"
    bits: int = 32
    seed: int = 0
    batch: int = 1

    # -- execution ----------------------------------------------------------
    #: aggregation backend: "ref" | "onehot" | "groot" | "groot_mxu" |
    #: "groot_fused" — ONE name across every layer (``aggregate=`` is the
    #: deprecated alias).  None means "ref".
    backend: Optional[str] = None
    #: staged edge-stream dtype for the hoisted groot* forward (None/f32 =
    #: bit-exact; "bfloat16" halves staged stream bytes, f32 accumulation)
    stream_dtype: Optional[str] = None
    gnn: GNNConfig = dataclasses.field(default_factory=GNNConfig)

    # -- partitioning / re-growth (paper §III-C, Algorithm 1) ---------------
    num_partitions: int = 1
    regrow: bool = True
    regrow_hops: int = 1
    partitioner: str = "multilevel"

    # -- streaming executor (repro.exec) ------------------------------------
    #: route partitioned designs through the streaming executor (True, the
    #: default) or the sequential per-subgraph loop (False)
    streaming: bool = True
    #: device budget: lets prepare() derive the partition count via
    #: choose_k when num_partitions is not set explicitly
    memory_budget_bytes: Optional[int] = None
    stream_capacity: int = 2
    stream_prefetch: int = 1
    #: device-mesh sharding of the streamed route (repro.mesh): None =
    #: auto (shard across every visible device when more than one
    #: exists), 1 = force the single-device executor, N = shard across
    #: the first N visible devices (``repro verify --devices N``)
    mesh_devices: Optional[int] = None

    # -- batched service (repro.service; the submit()/poll() path) ----------
    capacity: int = 2
    min_nodes: int = 64
    min_edges: int = 128
    max_structures: int = 64
    max_bucket_nodes: Optional[int] = None
    max_bucket_edges: Optional[int] = None
    prepare_workers: int = 2
    cache_capacity: int = 1024
    max_batch_requests: int = 16
    max_done_retained: int = 4096
    #: compile-ahead warmup: pre-compile the (n_pad, e_pad) bucket grid at
    #: engine construction so no submit() pays a cold jit.  warmup_shapes
    #: pins the grid; None derives a diagonal one from the bucket bounds.
    warmup: bool = False
    warmup_shapes: Optional[tuple] = None
    #: in-flight coalescing: concurrent same-key submissions share one
    #: execution (followers finish from the leader's result, cached=True)
    coalesce: bool = True
    #: per-tenant admission cap — submit(tenant=...) raises AdmissionError
    #: past this many unfinished requests (None = unlimited)
    max_inflight_per_tenant: Optional[int] = None

    # -- observability (repro.obs) ------------------------------------------
    #: record a span tracer around every ``verify()`` (Chrome-trace
    #: exportable via ``Session.save_trace`` / ``SessionResult.trace``).
    #: Off by default: the disabled path is the no-op tracer, so kernels
    #: and the prefetch loop pay nothing.  Deliberately NOT part of
    #: ``cache_key_part`` — tracing never changes results.
    trace: bool = False
    #: flight recorder: last N per-ticket forensic records retained in the
    #: session ring (``Session.flights()`` / ``stats()["flights"]``)
    flight_records: int = 256
    #: where failed tickets dump their flight record as JSON at failure
    #: time (None: fall back to $REPRO_FLIGHT_DUMP_DIR, else no dump).
    #: Not outcome-relevant, so not in ``cache_key_part``.
    flight_dump_dir: Optional[str] = None

    # -- failure domains (repro.faults + service deadlines/retries) ---------
    #: fault-injection plan for chaos runs: a :class:`repro.faults.FaultPlan`
    #: or its spec string (``"site:p=0.1,kind=transient;..."``).  Installed
    #: process-wide when the Session is constructed; None leaves whatever
    #: ``$REPRO_FAULT_PLAN`` installed (usually: nothing).  Never cached-on:
    #: faults perturb execution, not the verdict a ticket WOULD produce.
    fault_plan: Optional[object] = None
    #: default per-ticket wall-clock budget (seconds) for the service path;
    #: expired tickets fail with DeadlineExceeded instead of hanging.
    #: None = no deadline.  Per-submit ``deadline_s=`` overrides win.
    deadline_s: Optional[float] = None
    #: transient device-launch failures replayed per ticket (exponential
    #: backoff, seeded jitter) before the failure is surfaced
    launch_retries: int = 2
    retry_backoff_s: float = 0.05
    #: crash-safe resume for streamed runs: journal per-partition core
    #: predictions under this directory (keyed by design structural hash);
    #: ``resume=False`` wipes any prior journal instead of restoring it
    checkpoint_dir: Optional[str] = None
    resume: bool = True

    #: deprecated write-only alias of ``backend`` — consumed (and reset to
    #: None) at construction so ``dataclasses.replace(cfg, backend=...)``
    #: never sees a stale conflicting alias
    aggregate: Optional[str] = None

    def __post_init__(self):
        backend = resolve_backend_alias(
            self.backend, self.aggregate, owner="SessionConfig"
        )
        object.__setattr__(self, "backend", backend)
        object.__setattr__(self, "aggregate", None)

    # -- projections onto the legacy per-layer configs ----------------------

    def replace(self, **overrides) -> "SessionConfig":
        return dataclasses.replace(self, **overrides)

    def pipeline_config(
        self,
        *,
        dataset: Optional[str] = None,
        bits: Optional[int] = None,
        seed: Optional[int] = None,
    ):
        """The ``PipelineConfig`` view (what prepare/infer/verify read)."""
        from repro.core import pipeline as P

        return P.PipelineConfig(
            dataset=self.dataset if dataset is None else dataset,
            bits=self.bits if bits is None else bits,
            batch=self.batch,
            num_partitions=self.num_partitions,
            regrow=self.regrow,
            regrow_hops=self.regrow_hops,
            partitioner=self.partitioner,
            gnn=self.gnn,
            backend=self.backend,
            seed=self.seed if seed is None else seed,
            memory_budget_bytes=self.memory_budget_bytes,
            stream_capacity=self.stream_capacity,
            stream_prefetch=self.stream_prefetch,
            stream_dtype=self.stream_dtype,
            mesh_devices=self.mesh_devices,
            checkpoint_dir=self.checkpoint_dir,
            resume=self.resume,
        )

    def service_config(self):
        """The ``ServiceConfig`` view (what the batched engine reads)."""
        from repro.service.server import ServiceConfig

        return ServiceConfig(
            num_partitions=self.num_partitions,
            regrow=self.regrow,
            partitioner=self.partitioner,
            backend=self.backend,
            capacity=self.capacity,
            max_structures=self.max_structures,
            min_nodes=self.min_nodes,
            min_edges=self.min_edges,
            max_bucket_nodes=self.max_bucket_nodes,
            max_bucket_edges=self.max_bucket_edges,
            stream_capacity=self.stream_capacity,
            prepare_workers=self.prepare_workers,
            cache_capacity=self.cache_capacity,
            max_batch_requests=self.max_batch_requests,
            max_done_retained=self.max_done_retained,
            stream_dtype=self.stream_dtype,
            warmup=self.warmup,
            warmup_shapes=self.warmup_shapes,
            coalesce=self.coalesce,
            max_inflight_per_tenant=self.max_inflight_per_tenant,
            flight_records=self.flight_records,
            flight_dump_dir=self.flight_dump_dir,
            deadline_s=self.deadline_s,
            launch_retries=self.launch_retries,
            retry_backoff_s=self.retry_backoff_s,
        )

    @classmethod
    def from_pipeline(cls, cfg) -> "SessionConfig":
        """Lift a legacy ``PipelineConfig`` (the ``run_pipeline`` shim's
        path); field-for-field, so delegation is behaviour-preserving."""
        return cls(
            dataset=cfg.dataset,
            bits=cfg.bits,
            seed=cfg.seed,
            batch=cfg.batch,
            backend=cfg.backend,
            stream_dtype=cfg.stream_dtype,
            gnn=cfg.gnn,
            num_partitions=cfg.num_partitions,
            regrow=cfg.regrow,
            regrow_hops=cfg.regrow_hops,
            partitioner=cfg.partitioner,
            streaming=True,   # run_pipeline always streamed partitioned runs
            memory_budget_bytes=cfg.memory_budget_bytes,
            stream_capacity=cfg.stream_capacity,
            stream_prefetch=cfg.stream_prefetch,
            mesh_devices=cfg.mesh_devices,
            checkpoint_dir=cfg.checkpoint_dir,
            resume=cfg.resume,
        )

    def cache_key_part(self) -> tuple:
        """Everything outcome-relevant for the session result LRU."""
        return (
            self.backend, self.stream_dtype, self.gnn, self.batch,
            self.num_partitions, self.regrow, self.regrow_hops,
            self.partitioner, self.streaming, self.memory_budget_bytes,
            self.stream_capacity, self.min_nodes, self.min_edges,
            self.mesh_devices,
        )
