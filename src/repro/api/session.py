"""`Session`: the one façade over full / partitioned / streamed / batched
GROOT verification.

A :class:`Session` owns the long-lived state the legacy entry points each
re-created per call — the trained params, the process-wide structural
``PLAN_CACHE``, the shared :class:`~repro.exec.stream.StreamingExecutor`
(and through it the :class:`~repro.service.scheduler.BucketRunner` jit
pool), the lazily-started batched service engine, and a structural-hash
result LRU — and routes every design through ONE decision point:

    session.verify(design)      sync: route + run + verify
    session.explain(design)     the routing decision, without running
    session.submit()/poll()     async: the batched service engine

The router (:func:`route_prepared`) inspects the *prepared* design
against the analytic device-memory model and the config:

  mode "full"         unpartitioned — the design fits (or no
                      partitioning/budget was requested)
  mode "partitioned"  sequential per-subgraph loop (``streaming=False``)
  mode "streamed"     the ``repro.exec`` executor: bucketed packed
                      launches, budget-driven k, host prefetch
  mode "sharded"      the streamed route fanned over a device mesh
                      (``repro.mesh``) when >1 device is visible or
                      ``mesh_devices`` asks for it

Legacy front doors (`run_pipeline`, `VerificationService`,
`gnn.predict_partitioned`) delegate here and emit ``DeprecationWarning``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import datetime
import itertools
import threading
import time
from typing import Optional

import numpy as np

from repro.api.config import SessionConfig
from repro.core import aig as A
from repro.core import gnn
from repro.core import pipeline as P
from repro.core.verify import VerifyResult
from repro.kernels.plan_cache import PLAN_CACHE
from repro.obs import (
    REGISTRY,
    FlightRecorder,
    MetricsRegistry,
    Report,
    TraceHandle,
    Tracer,
    current_tracer,
    fold_into,
    record_from_marks,
)
from repro.service.cache import ResultCache


@dataclasses.dataclass(frozen=True)
class RoutingDecision:
    """Why a design runs the way it runs (``session.explain()``)."""

    mode: str                         # "full" | "partitioned" | "streamed"
                                      # | "sharded"
    backend: str
    stream_dtype: Optional[str]       # effective staged-stream dtype (None=f32)
    k: int                            # partition count (1 for full)
    num_buckets: int                  # compile-unit count (streamed mode)
    buckets: tuple                    # ((n_pad, e_pad), ...) ascending
    modeled_full_bytes: int           # unpartitioned device-memory model
    modeled_peak_bytes: int           # what is actually resident: full bytes,
                                      # max per-subgraph, or the packed-launch
                                      # peak (capacity slots of the big bucket)
                                      # — PER DEVICE in sharded mode
    memory_budget_bytes: Optional[int]
    num_nodes: int
    num_edges: int
    reason: str
    #: mesh data shards the streamed route launches over (1 = the
    #: single-device executor; >1 = mode "sharded" through repro.mesh)
    mesh_devices: int = 1


@dataclasses.dataclass
class SessionResult:
    """One verified design: verdict + accuracy + probes + the route."""

    name: str
    status: str                       # verified|falsified|inconclusive|classified
    accuracy: float
    core_accuracy: float
    verdict: Optional[VerifyResult]
    cached: bool
    num_nodes: int
    num_edges: int
    peak_memory_bytes: int            # peak over partitions (full bytes if k=1)
    unpartitioned_memory_bytes: int
    boundary_edge_frac: float
    routing: RoutingDecision
    timings: dict
    plan_cache: dict                  # structural-cache deltas for this call
    exec_stats: dict                  # streamed mode: executor probe deltas
    predictions: Optional[np.ndarray] = None   # verify(return_predictions=True)
    #: per-verify span subtree (config.trace=True; None on cache hits and
    #: untraced sessions) — ``result.trace.save(path)`` writes Chrome JSON
    trace: Optional[TraceHandle] = None


# SessionConfig exposes the same (stream_dtype, gnn) attributes, so the
# pipeline's normalisation rule is THE rule — no second copy to drift
_effective_stream_dtype = P._effective_stream_dtype


def resolve_mesh_devices(mesh_devices: Optional[int]) -> int:
    """The mesh data shards a streamed route will launch over.

    None = auto: every visible device when more than one exists (the
    single-device host keeps the plain executor).  An explicit count is
    validated against the visible devices by :class:`~repro.mesh.MeshRunner`
    at execution time; routing only clamps the trivial cases.
    """
    if mesh_devices is not None:
        return max(1, int(mesh_devices))
    import jax

    return jax.local_device_count()


def route_prepared(prep: P.PreparedDesign, cfg: SessionConfig) -> RoutingDecision:
    """The single routing decision ``verify`` executes and ``explain``
    reports — both read the same prepared design, so they cannot drift."""
    return _route_with_plan(prep, cfg)[0]


def _route_with_plan(prep: P.PreparedDesign, cfg: SessionConfig):
    """Route + the PartitionPlan backing a streamed decision (None for
    the other modes), so ``verify`` can hand the exact planned buckets to
    the executor instead of rebuilding them."""
    pcfg = prep.cfg
    full_bytes, peak_parts = prep.memory_bytes()
    budget = pcfg.memory_budget_bytes
    common = dict(
        backend=pcfg.backend,
        stream_dtype=_effective_stream_dtype(cfg),
        modeled_full_bytes=full_bytes,
        memory_budget_bytes=budget,
        num_nodes=prep.num_nodes,
        num_edges=prep.num_edges,
    )
    if prep.subgraphs is None:
        reason = (
            f"modeled {full_bytes} B fits the {budget} B budget unpartitioned"
            if budget is not None
            else "no partitioning requested (num_partitions <= 1, no budget)"
        )
        return RoutingDecision(
            mode="full", k=1, num_buckets=0, buckets=(),
            modeled_peak_bytes=full_bytes, reason=reason, **common,
        ), None
    k = prep.num_partitions
    if not cfg.streaming:
        return RoutingDecision(
            mode="partitioned", k=k, num_buckets=0, buckets=(),
            modeled_peak_bytes=peak_parts,
            reason=f"k={k} partitions through the sequential loop "
                   f"(streaming disabled)",
            **common,
        ), None
    from repro.exec.plan import plan_from_subgraphs

    plan = plan_from_subgraphs(
        list(prep.subgraphs), prep.num_nodes, num_edges=prep.num_edges,
        regrow=pcfg.regrow, partitioner=pcfg.partitioner, seed=pcfg.seed,
        min_nodes=cfg.min_nodes, min_edges=cfg.min_edges,
    )
    if budget is not None and pcfg.num_partitions <= 1:
        reason = (
            f"modeled full-graph {full_bytes} B exceeds the {budget} B "
            f"budget -> choose_k cut k={k}, streamed as "
            f"{plan.num_buckets}-bucket packed launches"
        )
    else:
        reason = (
            f"k={k} partitions requested, streamed as "
            f"{plan.num_buckets}-bucket packed launches"
        )
    peak = plan.peak_batch_memory_bytes(pcfg.gnn, cfg.stream_capacity)
    devices = resolve_mesh_devices(cfg.mesh_devices)
    if devices > 1:
        # the packed batches are independent until the verdict scatter
        # (GROOT Alg. 1), so the stream shards across the mesh data axis;
        # each lane launches the same canonical bucket shapes, so the
        # per-device peak equals the single-device packed peak
        from repro.mesh import build_mesh_plan

        mplan = build_mesh_plan(plan, devices, cfg.stream_capacity)
        reason += (
            f"; sharded across {devices} devices x k={k} x "
            f"{plan.num_buckets} bucket(s), modeled per-device peak "
            f"{peak / 1e6:.1f} MB, launch speedup "
            f"{mplan.modeled_speedup:.2f}x"
        )
        return RoutingDecision(
            mode="sharded", k=k, num_buckets=plan.num_buckets,
            buckets=tuple((b.n_pad, b.e_pad) for b in plan.buckets),
            modeled_peak_bytes=peak, mesh_devices=devices,
            reason=reason, **common,
        ), plan
    return RoutingDecision(
        mode="streamed", k=k, num_buckets=plan.num_buckets,
        buckets=tuple((b.n_pad, b.e_pad) for b in plan.buckets),
        modeled_peak_bytes=peak,
        reason=reason, **common,
    ), plan


class _SessionObs:
    """One session's observability state: a private metrics registry, an
    optional tracer, and the baselines report() deltas against."""

    def __init__(self, trace: bool, flight_records: int = 256):
        self.metrics = MetricsRegistry()
        self.tracer: Optional[Tracer] = Tracer() if trace else None
        # one forensic ring across both paths: the service engine records
        # its tickets here, sync verify records its calls (negative ids)
        self.flights = FlightRecorder(flight_records)
        self.flight_ids = itertools.count(1)     # sync-verify id space (<0)
        # deltas in report() are measured from session creation
        self.registry_baseline = REGISTRY.snapshot()
        self.plan_cache_baseline = PLAN_CACHE.snapshot()
        self.exec_totals: dict = {}


class Session:
    """One stable front door over the whole verification stack."""

    def __init__(self, params=None, config: Optional[SessionConfig] = None,
                 _obs: Optional[_SessionObs] = None, **overrides):
        if config is None:
            config = SessionConfig(**overrides)
        elif overrides:
            config = dataclasses.replace(config, **overrides)
        self.config = config
        self._params = params
        if config.fault_plan is not None:
            # chaos sessions: activate the configured fault plan for the
            # whole process (fire sites are global).  Installing at
            # construction — not per verify — keeps the hot path at one
            # None-check when no plan is configured.
            from repro import faults

            faults.install(config.fault_plan)
        #: tracing + metrics state (``_obs`` lets :meth:`options` share the
        #: parent's, so a family of derived sessions traces one timeline)
        self.obs = (
            _obs if _obs is not None
            else _SessionObs(config.trace, config.flight_records)
        )
        #: structural-hash result LRU: a resubmitted design under the same
        #: config skips prepare + inference + verification entirely
        self.results = ResultCache(config.cache_capacity)
        self._service = None
        self._closed = False
        self._lock = threading.Lock()

    # -- params lifecycle ----------------------------------------------------

    @property
    def params(self):
        if self._params is None:
            raise RuntimeError(
                "session has no params: pass them to Session(params=...) or "
                "call session.train() first"
            )
        return self._params

    @property
    def has_params(self) -> bool:
        return self._params is not None

    def train(self, dataset: Optional[str] = None, bits: int = 8, *,
              epochs: int = 300, seed: Optional[int] = None) -> list:
        """Train on a small design (the paper trains on 8-bit) and adopt
        the params; returns the loss history."""
        params, hist = P.train_model(
            dataset or self.config.dataset, bits,
            cfg=self.config.gnn, epochs=epochs,
            seed=self.config.seed if seed is None else seed,
        )
        self.set_params(params)
        return hist

    def set_params(self, params) -> None:
        """Adopt new params, invalidating every params-derived state: the
        result LRU (its keys carry no params fingerprint, so stale entries
        would be served as fresh) and the service engine (its runner holds
        the old tree).  The executor pool needs no action — it is keyed on
        params identity."""
        with self._lock:
            self._params = params
            svc, self._service = self._service, None
            self.results = ResultCache(self.config.cache_capacity)
        if svc is not None:
            svc.close()

    def options(self, **overrides) -> "Session":
        """A derived session: same params, config overridden.  Derived
        sessions share the process-wide plan cache and executor pool, so
        no jit state is duplicated — only the result LRU is fresh.  Obs
        state (tracer + metrics) is shared too, so a family of derived
        sessions records one timeline — unless the override flips the
        trace flag, which gets fresh obs matching the new flag."""
        cfg = dataclasses.replace(self.config, **overrides)
        obs = self.obs if cfg.trace == self.config.trace else None
        return Session(self._params, cfg, _obs=obs)

    # -- design resolution ---------------------------------------------------

    def _resolve_design(self, design):
        """None (generate from config), an AIG/LUT object, AIGER bytes, or
        an AIGER file path."""
        if design is None or hasattr(design, "to_edge_graph"):
            return design
        from repro.io import aiger

        if isinstance(design, (bytes, bytearray)):
            return aiger.loads(bytes(design))
        return aiger.load(design)      # str / PathLike

    def prepare(self, design=None, *, dataset: Optional[str] = None,
                bits: Optional[int] = None,
                seed: Optional[int] = None) -> P.PreparedDesign:
        """Host-side stage 1 for this session's config (features,
        partitioning, re-growth)."""
        pcfg = self.config.pipeline_config(dataset=dataset, bits=bits, seed=seed)
        return P.prepare(pcfg, self._resolve_design(design))

    # -- the router ----------------------------------------------------------

    def explain(self, design=None, *, dataset: Optional[str] = None,
                bits: Optional[int] = None,
                seed: Optional[int] = None) -> RoutingDecision:
        """The routing decision ``verify`` would take — chosen mode, k,
        buckets, modeled peak bytes — without running inference.  Needs no
        params (host-side only)."""
        return route_prepared(
            self.prepare(design, dataset=dataset, bits=bits, seed=seed),
            self.config,
        )

    def _result_key(self, design, pcfg, verify: bool, signed):
        if pcfg.batch != 1:
            return None
        if design is None:
            h = f"gen:{pcfg.dataset}:{pcfg.bits}:{pcfg.seed}"
        elif isinstance(design, A.AIG):
            from repro.io import aiger

            h = aiger.structural_hash(design)
        else:
            return None
        return ResultCache.key(
            h,
            self.config.cache_key_part()
            + (pcfg.dataset, pcfg.bits, pcfg.seed, verify, signed),
        )

    def _stream_executor(self):
        from repro.exec.stream import shared_executor

        return shared_executor(
            self.params, self.config.backend,
            capacity=self.config.stream_capacity,
            prefetch=self.config.stream_prefetch,
            stream_dtype=_effective_stream_dtype(self.config),
            min_nodes=self.config.min_nodes,
            min_edges=self.config.min_edges,
        )

    def _mesh_executor(self, num_devices: int):
        from repro.mesh import shared_mesh_executor

        return shared_mesh_executor(
            self.params, self.config.backend or "ref",
            num_devices=num_devices,
            capacity=self.config.stream_capacity,
            prefetch=self.config.stream_prefetch,
            stream_dtype=_effective_stream_dtype(self.config),
            min_nodes=self.config.min_nodes,
            min_edges=self.config.min_edges,
            launch_retries=self.config.launch_retries,
            retry_backoff_s=self.config.retry_backoff_s,
        )

    def verify(self, design=None, *, dataset: Optional[str] = None,
               bits: Optional[int] = None, seed: Optional[int] = None,
               verify: bool = True, signed: Optional[bool] = None,
               use_cache: bool = True,
               return_predictions: bool = False) -> SessionResult:
        """Route one design through the stack and (optionally) verify it.

        ``design`` is anything :meth:`_resolve_design` accepts; None
        generates ``dataset``/``bits`` from the config.  ``use_cache=False``
        bypasses the result LRU (probe tests; benchmarking).
        """
        t_start = time.perf_counter()
        met = self.obs.metrics
        met.counter("session.verifies").inc()
        marks = [("submit", t_start)]
        # with our own tracer: activate it (and restore whatever was
        # active after); without: nullcontext, so a surrounding tracer —
        # e.g. the benchmark harness's — still receives every span below
        activate = (
            self.obs.tracer.activate()
            if self.obs.tracer is not None
            else contextlib.nullcontext()
        )
        with activate:
            tracer = self.obs.tracer or current_tracer()
            with tracer.span("session.verify") as root:
                with tracer.span("parse"):
                    design = self._resolve_design(design)
                    pcfg = self.config.pipeline_config(
                        dataset=dataset, bits=bits, seed=seed
                    )
                    key = self._result_key(design, pcfg, verify, signed)
                    # cached entries are stored predictions-free, so a
                    # caller asking for predictions must fall through to a
                    # real run
                    hit = None
                    if use_cache and key is not None and not return_predictions:
                        hit = self.results.get(key)
                if hit is not None:
                    met.counter("session.cache_hits").inc()
                    root.set(cached=True)
                    self._record_sync_flight(
                        marks, hit.name, hit.status, cached=True
                    )
                    return dataclasses.replace(
                        hit,
                        cached=True,
                        # fresh dicts: callers may mutate their result
                        # without corrupting the cached copy or other hits
                        plan_cache=dict(hit.plan_cache),
                        exec_stats=dict(hit.exec_stats),
                        timings={**hit.timings,
                                 "total": time.perf_counter() - t_start},
                    )
                with tracer.span("plan") as plan_sp:
                    prep = P.prepare(pcfg, design)
                    decision, plan = _route_with_plan(prep, self.config)
                    plan_sp.set(mode=decision.mode, k=decision.k)
                marks.append(("prepared", time.perf_counter()))
                met.counter(f"session.route.{decision.mode}").inc()
                met.histogram("session.prepare_s").observe(
                    sum(prep.timings.values())
                )
                root.set(
                    mode=decision.mode, design=getattr(prep.design, "name", "?")
                )

                t0 = time.perf_counter()
                pc_before = PLAN_CACHE.snapshot()
                with tracer.span("execute", mode=decision.mode):
                    if decision.mode == "full":
                        pred, exec_stats = P.infer(self.params, prep), {}
                    elif decision.mode == "partitioned":
                        pred, exec_stats = gnn.predict_partitioned_loop(
                            self.params, prep.subgraphs, prep.feats,
                            prep.num_nodes, pcfg.backend,
                            stream_dtype=decision.stream_dtype,
                        ), {}
                    else:
                        executor = (
                            self._mesh_executor(decision.mesh_devices)
                            if decision.mode == "sharded"
                            else self._stream_executor()
                        )
                        pred, exec_stats = P.infer_streaming(
                            self.params, prep, executor=executor, plan=plan,
                        )
                pc_after = PLAN_CACHE.snapshot()
                t_inf = time.perf_counter() - t0
                marks.append(("inferred", time.perf_counter()))
                met.histogram("session.infer_s").observe(t_inf)
                if exec_stats:
                    # model-vs-actual memory accounting: high-water gauges,
                    # not counters — a peak must never accumulate.  The
                    # mesh width ("devices") is likewise a level, not a
                    # rate
                    for g in ("modeled_peak_bytes", "actual_peak_bytes",
                              "devices"):
                        if exec_stats.get(g):
                            met.gauge(f"exec.{g}").set(exec_stats[g])
                    # per-run executor stats accumulate into the session
                    # registry (ints -> exec.* counters, timings ->
                    # histograms) and the raw totals report() exposes
                    fold_into(met, "exec", {
                        k_: v_ for k_, v_ in exec_stats.items()
                        if not k_.endswith("peak_bytes") and k_ != "devices"
                    })
                    for k_, v_ in exec_stats.items():
                        if k_ == "devices":
                            self.obs.exec_totals[k_] = v_
                        elif isinstance(v_, (int, float)) and not isinstance(v_, bool):
                            if k_.endswith("peak_bytes") or k_ == "model_drift":
                                # peaks/ratios keep their high-water mark
                                self.obs.exec_totals[k_] = max(
                                    self.obs.exec_totals.get(k_, 0), v_
                                )
                            else:
                                self.obs.exec_totals[k_] = (
                                    self.obs.exec_totals.get(k_, 0) + v_
                                )

                with tracer.span("verdict"):
                    t0 = time.perf_counter()
                    acc = gnn.accuracy(pred, prep.labels)
                    verdict = (
                        P.verify_prepared(prep, pred, signed=signed)
                        if verify else None
                    )
                    t_verify = time.perf_counter() - t0
                    met.histogram("session.verify_s").observe(t_verify)
                    mem_full, mem_peak = prep.memory_bytes()
                    result = SessionResult(
                        name=getattr(
                            prep.design, "name", f"{pcfg.dataset}:{pcfg.bits}"
                        ),
                        status=(
                            verdict.status if verdict is not None
                            else "classified"
                        ),
                        accuracy=acc,
                        core_accuracy=acc,
                        verdict=verdict,
                        cached=False,
                        num_nodes=prep.num_nodes,
                        num_edges=prep.num_edges,
                        peak_memory_bytes=mem_peak,
                        unpartitioned_memory_bytes=mem_full,
                        boundary_edge_frac=prep.boundary_edge_frac,
                        routing=decision,
                        timings={
                            **prep.timings,
                            "inference": t_inf,
                            "verify": t_verify,
                            "total": time.perf_counter() - t_start,
                        },
                        plan_cache={
                            "builds": pc_after.builds - pc_before.builds,
                            "hits": pc_after.hits - pc_before.hits,
                        },
                        exec_stats=exec_stats,
                    )
                    if key is not None:
                        # cache a predictions-free, trace-free copy with
                        # its own dicts: the LRU must stay O(results) not
                        # O(designs), and must not alias the mutable stats
                        # (or pin the span tree) the caller receives
                        self.results.put(key, dataclasses.replace(
                            result, predictions=None, trace=None,
                            timings=dict(result.timings),
                            plan_cache=dict(result.plan_cache),
                            exec_stats=dict(result.exec_stats),
                        ))
                    if return_predictions:
                        result.predictions = pred
        met.histogram("session.total_s").observe(time.perf_counter() - t_start)
        if self.obs.tracer is not None and root.span_id is not None:
            result.trace = TraceHandle(self.obs.tracer, root.span_id)
        self._record_sync_flight(marks, result.name, result.status,
                                 decision=decision)
        return result

    def _record_sync_flight(self, marks, name, status, *, cached=False,
                            decision=None) -> None:
        """Sync ``verify`` leaves the same forensic trail as a service
        ticket (negative ids keep the two spaces from colliding in the
        shared ring).  A sync call has no device queue, so its timeline is
        submit -> prepared -> inferred -> done."""
        marks.append(("done", time.perf_counter()))
        streamed = decision is not None and decision.mode in (
            "streamed", "sharded"
        )
        self.obs.flights.record(record_from_marks(
            -next(self.obs.flight_ids), name, status, marks,
            cached=cached,
            streamed=streamed,
            bucket=decision.buckets[-1] if streamed and decision.buckets else None,
            capacity=self.config.stream_capacity if streamed else None,
        ))

    def flights(self, *, failures_only: bool = False) -> list:
        """The session's retained :class:`~repro.obs.FlightRecord` ring —
        sync verifies (negative ids) and service tickets alike, oldest
        first."""
        return self.obs.flights.records(failures_only=failures_only)

    # -- the async (service-batched) path ------------------------------------

    def _service_engine(self):
        with self._lock:
            if self._closed:
                # a fresh engine here would leak worker threads and could
                # never know the closed engine's tickets anyway
                raise RuntimeError(
                    "session is closed: submit/poll/result need a live "
                    "service engine"
                )
            if self._service is None:
                from repro.service.server import VerificationService

                self._service = VerificationService(
                    self.params, self.config.service_config(), _warn=False,
                    metrics=self.obs.metrics, flights=self.obs.flights,
                )
            return self._service

    def submit(self, design=None, *, dataset: Optional[str] = None,
               bits: Optional[int] = None, seed: Optional[int] = None,
               verify: bool = True, signed: Optional[bool] = None,
               priority: int = 1, tenant: Optional[str] = None,
               deadline_s: Optional[float] = None) -> int:
        """Async verification through the batched service engine
        (continuous batching into shape-bucketed packs, compile-ahead
        warmup, overlap of prepare/device/verify across requests); returns
        a ticket for :meth:`poll` / :meth:`result`.

        ``priority`` orders the device pool (lower = sooner; 0 is the
        express lane).  ``tenant`` attributes the request for per-tenant
        admission caps (``max_inflight_per_tenant``) — a tenant at its cap
        gets :class:`repro.service.AdmissionError` here.  ``deadline_s``
        overrides the config's per-ticket wall-clock budget; an expired
        ticket fails with ``DeadlineExceeded`` instead of hanging.

        AIGER bytes/paths are handed to the engine unparsed: parsing runs
        on the prepare pool, so a malformed file yields a per-ticket
        ``status="error"`` result instead of raising here."""
        aiger_bytes = None
        if design is not None and not hasattr(design, "to_edge_graph"):
            from repro.io import aiger

            aiger_bytes, design = aiger.source_bytes(design), None
        return self._service_engine().submit(
            design,
            aiger_bytes=aiger_bytes,
            dataset=self.config.dataset if dataset is None else dataset,
            bits=self.config.bits if bits is None else bits,
            seed=self.config.seed if seed is None else seed,
            verify=verify,
            signed=signed,
            priority=priority,
            tenant=tenant,
            deadline_s=deadline_s,
        )

    def warm(self, shapes: Optional[tuple] = None) -> int:
        """Force-construct the service engine and pre-compile its bucket
        grid now, instead of on first :meth:`submit`.  Returns the number
        of jit traces warmup triggered (0 if the engine already warmed at
        construction via ``SessionConfig(warmup=True)``)."""
        engine = self._service_engine()
        if engine.scheduler.runner.warmed:
            return 0
        return engine.warm(shapes)

    def poll(self, ticket: int):
        """Non-blocking: the ServiceResult if finished, else None."""
        return self._service_engine().poll(ticket)

    def result(self, ticket: int, timeout: Optional[float] = None):
        """Blocking retrieval of a submitted ticket."""
        return self._service_engine().result(ticket, timeout)

    # -- lifecycle / observability -------------------------------------------

    def stats(self) -> dict:
        out = {
            "results": self.results.stats,
            "plan_cache": PLAN_CACHE.snapshot(),
        }
        if self._service is not None:
            out["service"] = self._service.stats()
        return out

    def report(self) -> Report:
        """One snapshot answering "where did the time go" for every route
        this session ran: its own counters/histograms, process-registry
        movement since creation (kernel probes, jit traces, staged bytes),
        plan/result cache rates, scheduler + executor stats, and the span
        summary when tracing is on."""
        pc, base = PLAN_CACHE.snapshot(), self.obs.plan_cache_baseline
        builds = pc.builds - base.builds
        hits = pc.hits - base.hits
        misses = pc.misses - base.misses
        plan_cache = {
            "builds": builds,
            "hits": hits,
            "misses": misses,
            "evictions": pc.evictions - base.evictions,
            "hit_rate": hits / (hits + misses) if hits + misses else 0.0,
        }
        rc = self.results.stats
        scheduler = None
        if self._service is not None:
            s = self._service.scheduler.stats()
            scheduler = {
                "compile_count": s.compile_count,
                "run_count": s.run_count,
                "buckets": [(b.n_pad, b.e_pad) for b in s.buckets],
                "items_run": s.items_run,
                "streamed_items": s.streamed_items,
                "cold_compiles": s.cold_compiles,
                "warm_compiles": s.warm_compiles,
                "warmup_s": s.warmup_s,
            }
        session_snap = self.obs.metrics.snapshot()
        gauges = session_snap["gauges"]
        memory_model = None
        modeled = gauges.get("exec.modeled_peak_bytes", {}).get("max", 0)
        if modeled:
            # the validation loop for the analytic model driving choose_k:
            # drift ~1.0 means routing decisions rest on honest numbers
            actual = gauges.get("exec.actual_peak_bytes", {}).get("max", 0)
            memory_model = {
                "modeled_peak_bytes": int(modeled),
                "actual_peak_bytes": int(actual),
                "drift": actual / modeled,
            }
        return Report(
            created=datetime.datetime.now(datetime.timezone.utc).isoformat(
                timespec="seconds"
            ),
            session=session_snap,
            process=REGISTRY.delta(self.obs.registry_baseline),
            # high-water marks of the process gauges (value + max) — the
            # counter-only `process` delta above cannot carry peaks
            process_gauges=REGISTRY.snapshot()["gauges"] or None,
            memory_model=memory_model,
            flights=self.obs.flights.stats() if len(self.obs.flights) else None,
            plan_cache=plan_cache,
            results_cache={
                "hits": rc.hits, "misses": rc.misses,
                "evictions": rc.evictions, "hit_rate": rc.hit_rate,
            },
            scheduler=scheduler,
            exec=dict(self.obs.exec_totals) or None,
            spans=(
                self.obs.tracer.summary()
                if self.obs.tracer is not None else None
            ),
        )

    def save_trace(self, path) -> None:
        """Write the session's full span timeline as Chrome-trace JSON
        (``chrome://tracing`` / Perfetto loadable)."""
        if self.obs.tracer is None:
            raise RuntimeError(
                "tracing is off: construct the session with "
                "SessionConfig(trace=True)"
            )
        self.obs.tracer.save(path)

    def close(self, timeout: Optional[float] = 300.0) -> None:
        """Drain and stop the async engine.  Sync ``verify``/``explain``
        keep working afterwards; ``submit``/``poll``/``result`` raise."""
        with self._lock:
            svc, self._service = self._service, None
            self._closed = True
        if svc is not None:
            svc.close(timeout)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
