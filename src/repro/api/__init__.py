"""`repro.api`: the stable public surface of the GROOT stack.

    from repro.api import Session, SessionConfig

    sess = Session(params, SessionConfig(backend="groot_fused"))
    print(sess.explain(dataset="csa", bits=256).mode)     # the route
    r = sess.verify("design.aig")                         # sync
    ticket = sess.submit(dataset="csa", bits=32)          # async (batched)
    print(sess.result(ticket).status)

One façade, one flattened config, one router: `Session.verify` inspects
each prepared design against the device-memory model and dispatches to
full-graph, partitioned-loop, streamed-executor, or (via submit/poll)
service-batched execution.  The legacy entry points — ``run_pipeline``,
``VerificationService``, ``gnn.predict_partitioned`` — are deprecated
shims over this module.

``__all__`` is the public API contract: the tier-1 suite snapshots it
against a committed manifest (``tests/data/api_surface.txt``), so
accidental surface changes fail the build.
"""
from repro.api.config import SessionConfig, resolve_backend_alias  # noqa: F401
from repro.api.session import (  # noqa: F401
    RoutingDecision,
    Session,
    SessionResult,
    route_prepared,
)
from repro.obs import Report  # noqa: F401 — Session.report()'s return type

__all__ = [
    "Report",
    "RoutingDecision",
    "Session",
    "SessionConfig",
    "SessionResult",
    "route_prepared",
]
