"""Static HLO analysis for the roofline report.

``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified
empirically — a scan of 8 matmuls reports 1 matmul of flops), so a
scan-over-layers program would under-report FLOPs by ~n_layers.  This
module re-derives loop-corrected totals from ``compiled.as_text()``:

  * parses every computation and instruction (result type, opcode,
    operands) keeping a per-computation symbol table so operand types
    can be resolved,
  * extracts while-loop trip counts from the condition computation's
    compare-against-constant (the shape jax scans lower to),
  * walks the call graph from ENTRY multiplying by trip counts,
  * accumulates:
      - dot FLOPs        2 * prod(result dims) * prod(contracting dims)
      - collective bytes  per kind (all-gather / all-reduce /
        reduce-scatter / all-to-all / collective-permute), result sizes
      - materialised bytes (write+read of every non-trivial result
        outside fusion bodies + entry parameters) — a static HBM-traffic
        proxy.

The parser is resilient: anything it cannot parse contributes zero.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Optional

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
# type strings may contain /*index=N*/ comments inside long tuples
_INSTR_RE = re.compile(
    r"^\s+(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[a-z0-9\[\]{},\s/*=]*?\)?)\s*([\w\-]+)\((.*)$"
)
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\{\s*$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> list:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    rest: str  # operands + attributes


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list
    types: dict  # instr name -> type_str
    is_entry: bool = False


def parse_hlo(text: str) -> dict:
    """-> {comp_name: Computation}."""
    comps: dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            if line.rstrip().endswith("{") and "=" not in line.split("(")[0]:
                m = _COMP_RE.match(line)
                if m:
                    cur = Computation(
                        name=m.group(2),
                        instrs=[],
                        types={},
                        is_entry=bool(m.group(1)),
                    )
            continue
        if line.startswith("}") or line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            ins = Instr(m.group(1), m.group(2), m.group(3), m.group(4))
            cur.instrs.append(ins)
            cur.types[ins.name] = ins.type_str
    return comps


def _dot_flops(ins: Instr, comp: Computation) -> float:
    out_dims = _shape_dims(ins.type_str)
    ops = _OPERAND_RE.findall(ins.rest.split("lhs_contracting_dims")[0])
    if not ops:
        return 0.0
    lhs_type = comp.types.get(ops[0], "")
    lhs_dims = _shape_dims(lhs_type)
    mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.rest)
    k = 1
    if mc and mc.group(1):
        for idx in mc.group(1).split(","):
            i = int(idx)
            if i < len(lhs_dims):
                k *= lhs_dims[i]
    n_out = 1
    for d in out_dims:
        n_out *= d
    return 2.0 * n_out * k


def _trip_count(comps: dict, cond_name: str) -> int:
    """jax scans lower the loop bound as a constant in the condition
    computation (possibly inside a wrapped fusion it calls)."""
    seen = set()

    def scan_comp(name: str) -> int:
        if name in seen or name not in comps:
            return 1
        seen.add(name)
        best = 1
        for ins in comps[name].instrs:
            if ins.opcode == "constant":
                m = re.match(r"(\d+)\)", ins.rest)
                if m:
                    best = max(best, int(m.group(1)))
            for ch in re.findall(r"calls=%?([\w.\-]+)", ins.rest):
                best = max(best, scan_comp(ch))
        return best

    return scan_comp(cond_name)


@dataclasses.dataclass
class HloStats:
    dot_flops: float = 0.0
    collective_bytes: float = 0.0
    collective_by_kind: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float)
    )
    traffic_bytes: float = 0.0
    entry_param_bytes: float = 0.0
    while_trips: dict = dataclasses.field(default_factory=dict)


_SKIP_TRAFFIC = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "iota", "copy-start", "copy-done",
    # layout/dtype ops: on the TPU target these fuse into their consumers
    # (the CPU HLO we parse fuses far less aggressively); counting them
    # double-bills every cast and broadcast as an HBM round-trip.
    "convert", "broadcast", "reshape", "transpose", "copy",
}


def analyze(text: str) -> HloStats:
    comps = parse_hlo(text)
    stats = HloStats()
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:  # pragma: no cover
        return stats

    memo: dict = {}

    def walk(comp_name: str, in_fusion: bool):
        """-> (dot_flops, coll_bytes, traffic, by_kind) for one execution."""
        key = (comp_name, in_fusion)
        if key in memo:
            return memo[key]
        comp = comps.get(comp_name)
        if comp is None:
            return (0.0, 0.0, 0.0, {})
        flops = coll = traffic = 0.0
        by_kind: dict = defaultdict(float)
        for ins in comp.instrs:
            op = ins.opcode
            base = op.replace("-start", "")
            if base in COLLECTIVES:
                b = _type_bytes(ins.type_str)
                coll += b
                by_kind[base] += b
            if op == "dot":
                flops += _dot_flops(ins, comp)
            if (
                not in_fusion
                and op not in _SKIP_TRAFFIC
                and not op.endswith("-done")
            ):
                traffic += 2.0 * _type_bytes(ins.type_str)
            if op == "while":
                mb = re.search(r"body=%?([\w.\-]+)", ins.rest)
                mc = re.search(r"condition=%?([\w.\-]+)", ins.rest)
                body = mb.group(1) if mb else None
                cond = mc.group(1) if mc else None
                trips = _trip_count(comps, cond) if cond else 1
                stats.while_trips[body or comp_name] = trips
                for ch in (body, cond):
                    if ch:
                        f2, c2, t2, k2 = walk(ch, in_fusion)
                        flops += trips * f2
                        coll += trips * c2
                        traffic += trips * t2
                        for k, v in k2.items():
                            by_kind[k] += trips * v
            else:
                child_fusion = in_fusion or op == "fusion"
                for ch in re.findall(
                    r"(?:to_apply|calls)=%?([\w.\-]+)", ins.rest
                ):
                    f2, c2, t2, k2 = walk(ch, child_fusion)
                    flops += f2
                    coll += c2
                    traffic += t2
                    for k, v in k2.items():
                        by_kind[k] += v
        memo[key] = (flops, coll, traffic, dict(by_kind))
        return memo[key]

    f, c, t, kinds = walk(entry.name, False)
    for ins in entry.instrs:
        if ins.opcode == "parameter":
            stats.entry_param_bytes += _type_bytes(ins.type_str)
    stats.dot_flops = f
    stats.collective_bytes = c
    stats.traffic_bytes = t + stats.entry_param_bytes
    stats.collective_by_kind = dict(kinds)
    return stats
