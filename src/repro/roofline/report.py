"""Roofline report builder (deliverable g).

Reads the dry-run artifacts (experiments/dryrun/<mesh>/<arch>__<shape>.json)
and derives, per (arch x shape x mesh):

    compute term    = dot_FLOPs_per_device / peak_FLOPs_per_chip
    memory term     = traffic_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / link_bw

(= the brief's global formulas: per-device numbers already divide by chip
count since the parsed HLO is the per-device SPMD module.)

Plus MODEL_FLOPS = 6*N*D (train) / 2*N*D (prefill) / 2*N_active*B (decode)
and the usefulness ratio MODEL_FLOPS / HLO_FLOPs.

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

    PYTHONPATH=src python -m repro.roofline.report [--mesh pod]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.zoo.configs import ARCHS, get_config
from repro.zoo.configs.shapes import SHAPES

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # bytes/s
LINK_BW = 50e9            # bytes/s per chip ICI

ART_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def model_flops(arch: str, shape: str, devices: int) -> float:
    """Per-device useful FLOPs for the step this cell lowers."""
    if arch == "groot-gnn":
        # GraphSAGE inference over one re-grown partition per device:
        # L layers x (7 dense matmuls (self + 6 groups) + 6 edge
        # aggregations), unpadded node/edge counts.
        from repro.launch.steps import GROOT_SHAPES

        gcfg = get_config(arch)
        bits, batch = GROOT_SHAPES[shape]
        nodes = 8.0 * bits * bits * batch
        edges = 2 * nodes
        h = gcfg.gnn.hidden
        layers = gcfg.gnn.num_layers
        per_graph = layers * (7 * 2 * nodes * h * h + 6 * 2 * edges * h)
        return per_graph / devices
    cfg = get_config(arch)
    sh = SHAPES[shape]
    n_active = cfg.active_param_count()
    if sh.kind == "train":
        tokens = sh.global_batch * sh.seq_len
        total = 6.0 * n_active * tokens
    elif sh.kind == "prefill":
        tokens = sh.global_batch * sh.seq_len
        total = 2.0 * n_active * tokens
    else:  # decode: one token per sequence
        total = 2.0 * n_active * sh.global_batch
    return total / devices


def load_records(mesh: str) -> list:
    out = []
    d = ART_DIR / mesh
    if not d.exists():
        return out
    for p in sorted(d.glob("*.json")):
        out.append(json.loads(p.read_text()))
    return out


def terms(rec: dict) -> dict:
    h = rec["hlo"]
    compute = h["dot_flops_per_device"] / PEAK_FLOPS
    memory = h["traffic_bytes_per_device"] / HBM_BW
    collective = h["collective_bytes_per_device"] / LINK_BW
    dominant = max(
        ("compute", compute), ("memory", memory), ("collective", collective),
        key=lambda kv: kv[1],
    )[0]
    mf = model_flops(rec["arch"], rec["shape"], rec["devices"])
    hlo_f = h["dot_flops_per_device"]
    bound = max(compute, memory, collective)
    return {
        "compute_s": compute,
        "memory_s": memory,
        "collective_s": collective,
        "dominant": dominant,
        "model_flops_per_device": mf,
        "useful_ratio": (mf / hlo_f) if hlo_f else 0.0,
        # roofline fraction: useful work over the time the dominant
        # bottleneck enforces (peak-compute-normalised)
        "roofline_fraction": (mf / PEAK_FLOPS) / bound if bound else 0.0,
    }


def build_table(mesh: str) -> list:
    rows = []
    for rec in load_records(mesh):
        t = terms(rec)
        mem = rec.get("memory_analysis", {})
        rows.append(
            {
                "arch": rec["arch"],
                "shape": rec["shape"],
                "mesh": rec["mesh"],
                "devices": rec["devices"],
                "compile_s": rec["timing"]["compile_s"],
                "hbm_gb_per_dev": round(
                    (
                        mem.get("argument_size_in_bytes", 0)
                        + mem.get("temp_size_in_bytes", 0)
                    )
                    / 1e9,
                    2,
                ),
                **{
                    k: (round(v, 4) if isinstance(v, float) else v)
                    for k, v in t.items()
                },
            }
        )
    return rows


def to_markdown(rows: list) -> str:
    hdr = (
        "| arch | shape | mesh | HBM GB/dev | compute s | memory s | "
        "collective s | dominant | useful ratio | roofline frac |\n"
        "|---|---|---|---|---|---|---|---|---|---|\n"
    )
    lines = []
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['hbm_gb_per_dev']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.3f} | "
            f"{r['roofline_fraction']:.4f} |"
        )
    return hdr + "\n".join(lines) + "\n"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod", choices=("pod", "multipod"))
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()
    rows = build_table(args.mesh)
    if args.json:
        print(json.dumps(rows, indent=1))
    else:
        print(to_markdown(rows))
    out = ART_DIR.parent / f"roofline_{args.mesh}.json"
    out.write_text(json.dumps(rows, indent=1))
    print(f"[saved {out}]")


if __name__ == "__main__":
    main()
