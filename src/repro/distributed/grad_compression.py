"""Error-feedback int8 gradient compression for cross-pod data parallelism.

At 1000+ nodes the pod-to-pod links (DCN class, ~an order of magnitude
slower than ICI) carry only the DP gradient all-reduce.  Compressing that
exchange 4x (f32 -> int8 + per-row scale) with error feedback (the
quantisation residual is added back into the next step's gradient) is a
standard trick that preserves convergence (1-bit Adam lineage).

``compressed_psum(grads, axis, state)`` runs inside shard_map:

    e      = grads + state.residual        (error feedback)
    q, s   = quantize_int8(e)              (per trailing-row scale)
    q_sum  = lax.psum(q.int32, axis)       (the wire transfer, 1/4 bytes)
    out    = dequantize(q_sum) / n
    state' = e - dequantize(q)             (local quantisation error)
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def _quantize(x):
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def init_error_state(grads) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compressed_psum(grads, axis: str, error_state):
    """int8 error-feedback psum over ``axis``.  Returns (mean_grads, state').

    Must be called inside shard_map with ``axis`` in scope.  All
    participants quantise against a SHARED per-row scale (pmax over the
    axis — one tiny extra collective), so the integer sum dequantises
    exactly; the only residual is each participant's own rounding, which
    error feedback re-injects next step.
    """
    n = jax.lax.psum(1, axis)

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        local_max = jnp.max(jnp.abs(g32), axis=-1, keepdims=True)
        scale = jax.lax.pmax(local_max, axis) / 127.0 + 1e-12  # shared
        q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        qs = jax.lax.psum(q.astype(jnp.int32), axis)           # the wire
        out = qs.astype(jnp.float32) * scale / n
        new_e = g32 - q.astype(jnp.float32) * scale            # local error
        return out.astype(g.dtype), new_e

    pairs = jax.tree.map(one, grads, error_state)
    outs = jax.tree.map(lambda p: p[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
    errs = jax.tree.map(lambda p: p[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    return outs, errs


def compression_ratio(grads) -> float:
    """Wire bytes int8-path / f32-path (scale rows included)."""
    num = den = 0
    for g in jax.tree.leaves(grads):
        rows = int(jnp.prod(jnp.asarray(g.shape[:-1]))) if g.ndim else 1
        num += g.size * 1 + rows * 4
        den += g.size * 4
    return num / max(den, 1)
