"""Elastic scaling: rebuild the mesh after topology changes.

A pod loss (512 -> 256 chips) or expansion changes the device set; the
parameters' logical axes are topology-independent, so re-deployment is:

    new_mesh   = choose_mesh(len(healthy_devices))
    shardings  = tree_shardings(spec_tree, new_mesh, make_rules(new_mesh))
    state      = restore(like, ckpt_dir, shardings=shardings)

``choose_mesh`` picks the largest (data x model) grid with the preferred
TP width that fits the device count; global batch is re-split over the
new data extent (batch scaling policy: keep global batch, grow per-device
batch — the optimizer schedule is unchanged).
"""
from __future__ import annotations

import jax
import numpy as np


def choose_mesh(n_devices: int, *, prefer_model: int = 16):
    """Largest (data, model) mesh over n_devices with TP <= prefer_model."""
    model = min(prefer_model, n_devices)
    while n_devices % model:
        model //= 2
    data = n_devices // model
    devs = np.asarray(jax.devices()[:n_devices]).reshape(data, model)
    from jax.sharding import Mesh

    return Mesh(devs, ("data", "model"))


def replan_batch(global_batch: int, old_data: int, new_data: int) -> dict:
    """Keep the global batch constant across topology changes."""
    assert global_batch % new_data == 0, (
        f"global batch {global_batch} not divisible by data={new_data}"
    )
    return {
        "global_batch": global_batch,
        "per_device_batch_old": global_batch // old_data,
        "per_device_batch_new": global_batch // new_data,
    }
