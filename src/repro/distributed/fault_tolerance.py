"""Fault tolerance: heartbeat/straggler monitoring + restartable step loop.

At thousand-node scale the expected time between node failures is shorter
than a long training run, so the loop must (a) notice a dead/straggling
worker quickly and (b) restart from the last checkpoint onto whatever
topology is still healthy.

``ResilientLoop`` wraps a step function with:
  * per-step wall-time tracking -> an EWMA straggler detector
    (step > ``straggler_factor`` x EWMA -> event recorded; on a real
    cluster this triggers requeue-or-evict, here it is surfaced to the
    caller/logs — the *policy* is pluggable);
  * heartbeat files (host-level liveness the launcher can poll);
  * periodic async checkpoints + automatic restore-on-construction, so a
    relaunched job resumes at the last published step;
  * bounded retry of transient step failures (checkpoint-restore-replay).

The elastic-topology path (restore onto a smaller mesh) is exercised in
tests/test_distributed.py via reshard-on-restore.
"""
from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path
from typing import Any, Callable, Optional

from repro.checkpoint.manager import CheckpointManager, latest_step, restore


@dataclasses.dataclass
class StragglerEvent:
    step: int
    duration: float
    ewma: float


class Heartbeat:
    """Liveness file the launcher can poll (one per host)."""

    def __init__(self, directory: str, host_id: int = 0):
        self.path = Path(directory) / f"heartbeat_{host_id}.json"
        self.path.parent.mkdir(parents=True, exist_ok=True)

    def beat(self, step: int):
        self.path.write_text(json.dumps({"step": step, "t": time.time()}))

    @staticmethod
    def stale_hosts(directory: str, timeout_s: float) -> list:
        now = time.time()
        out = []
        for p in Path(directory).glob("heartbeat_*.json"):
            data = json.loads(p.read_text())
            if now - data["t"] > timeout_s:
                out.append(p.stem)
        return out


class ResilientLoop:
    def __init__(
        self,
        step_fn: Callable,                   # (state, batch) -> (state, metrics)
        init_state: Any,
        *,
        ckpt_dir: str,
        ckpt_every: int = 50,
        straggler_factor: float = 3.0,
        max_retries: int = 2,
        shardings: Any = None,
        host_id: int = 0,
    ):
        self.step_fn = step_fn
        self.ckpt = CheckpointManager(ckpt_dir)
        self.ckpt_every = ckpt_every
        self.straggler_factor = straggler_factor
        self.max_retries = max_retries
        self.heartbeat = Heartbeat(ckpt_dir, host_id)
        self.stragglers: list = []
        self.ewma: Optional[float] = None
        self.shardings = shardings

        if latest_step(ckpt_dir) is not None:
            self.state, self.step = restore(
                init_state, ckpt_dir, shardings=shardings
            )
            self.step += 1
            self.resumed = True
        else:
            self.state, self.step = init_state, 0
            self.resumed = False

    def run(self, batches, *, steps: Optional[int] = None):
        """Iterate batches; yields (step, metrics)."""
        for batch in batches:
            if steps is not None and self.step >= steps:
                break
            metrics = self._one_step(batch)
            yield self.step, metrics
            self.step += 1
        self.ckpt.save_async(self.state, self.step - 1)
        self.ckpt.wait()

    def _one_step(self, batch):
        for attempt in range(self.max_retries + 1):
            t0 = time.perf_counter()
            try:
                self.state, metrics = self.step_fn(self.state, batch)
                break
            except Exception:  # noqa: BLE001 transient failure -> replay
                if attempt == self.max_retries:
                    raise
                if latest_step(self.ckpt.directory) is not None:
                    self.state, _ = restore(
                        self.state, self.ckpt.directory, shardings=self.shardings
                    )
        dt = time.perf_counter() - t0
        ewma = dt if self.ewma is None else 0.9 * self.ewma + 0.1 * dt
        if self.ewma is not None and dt > self.straggler_factor * self.ewma:
            self.stragglers.append(StragglerEvent(self.step, dt, self.ewma))
        self.ewma = ewma
        self.heartbeat.beat(self.step)
        if self.step % self.ckpt_every == 0 and self.step > 0:
            self.ckpt.save_async(self.state, self.step)
        return metrics
