"""Fault tolerance: heartbeat/straggler monitoring + restartable step loop.

At thousand-node scale the expected time between node failures is shorter
than a long training run, so the loop must (a) notice a dead/straggling
worker quickly and (b) restart from the last checkpoint onto whatever
topology is still healthy.

``ResilientLoop`` wraps a step function with:
  * per-step wall-time tracking -> an EWMA straggler detector
    (step > ``straggler_factor`` x EWMA -> event recorded; on a real
    cluster this triggers requeue-or-evict, here it is surfaced to the
    caller/logs — the *policy* is pluggable);
  * heartbeat files (host-level liveness the launcher can poll);
  * periodic async checkpoints + automatic restore-on-construction, so a
    relaunched job resumes at the last published step;
  * bounded retry of transient step failures (checkpoint-restore-replay).

This module also owns the ONE retry/backoff policy of the repo:
:func:`backoff_delays` (deterministic exponential backoff with seeded
jitter) and :func:`is_transient` (is this failure worth retrying?).  The
verification service's launch-retry path and :class:`ResilientLoop` both
build on these — no layer keeps its own dormant duplicate.

The elastic-topology path (restore onto a smaller mesh) is exercised in
tests/test_distributed.py via reshard-on-restore.
"""
from __future__ import annotations

import dataclasses
import json
import random
import time
from pathlib import Path
from typing import Any, Callable, Iterator, Optional

from repro.checkpoint.manager import CheckpointManager, latest_step, restore


# ---------------------------------------------------------------------------
# Retry/backoff policy (shared with repro.service)
# ---------------------------------------------------------------------------

def backoff_delays(
    retries: int,
    *,
    base_s: float = 0.05,
    factor: float = 2.0,
    jitter: float = 0.5,
    max_s: float = 5.0,
    seed: object = 0,
) -> Iterator[float]:
    """``retries`` exponential backoff delays with deterministic jitter.

    Delay *i* is ``min(max_s, base_s * factor**i) * (1 + jitter * u_i)``
    with ``u_i`` drawn from a ``random.Random`` seeded from ``seed``
    (string-seeded, so the same (seed, attempt) always jitters the same —
    chaos runs replay bit-identically).  Jitter de-synchronises retry
    herds; determinism keeps them testable.
    """
    rng = random.Random(f"backoff:{seed}")
    for attempt in range(max(0, retries)):
        yield min(max_s, base_s * factor ** attempt) * (1.0 + jitter * rng.random())


def is_transient(exc: BaseException) -> bool:
    """Is this failure plausibly cleared by a retry?

    Injected :class:`repro.faults.TransientFault` (and anything whose
    class name says Transient), connection/timeout errors, and XLA's
    retryable status codes qualify.  Injected ``FatalFault`` — and any
    ordinary logic error — does not: retrying a poisoned design only
    burns device time.
    """
    from repro import faults

    if isinstance(exc, faults.FatalFault):
        return False
    if isinstance(exc, (faults.TransientFault, ConnectionError, TimeoutError)):
        return True
    if "Transient" in type(exc).__name__:
        return True
    msg = str(exc)
    return any(code in msg for code in ("UNAVAILABLE", "ABORTED", "DEADLINE_EXCEEDED"))


def retry_call(
    fn: Callable[[], Any],
    *,
    retries: int,
    seed: object = 0,
    base_s: float = 0.05,
    should_retry: Callable[[BaseException], bool] = is_transient,
    on_retry: Optional[Callable[[int, BaseException], None]] = None,
    sleep: Callable[[float], None] = time.sleep,
):
    """Call ``fn`` with up to ``retries`` backoff-spaced replays.

    Only failures ``should_retry`` accepts are replayed; ``on_retry``
    (attempt index, exception) runs before each sleep — the service uses
    it to bump its retry counter and re-check ticket deadlines (raising
    from ``on_retry`` aborts the retry loop with that error).
    """
    delays = backoff_delays(retries, base_s=base_s, seed=seed)
    attempt = 0
    while True:
        try:
            return fn()
        except Exception as e:  # noqa: BLE001 — classified below
            delay = next(delays, None)
            if delay is None or not should_retry(e):
                raise
            if on_retry is not None:
                on_retry(attempt, e)
            sleep(delay)
            attempt += 1


@dataclasses.dataclass
class StragglerEvent:
    step: int
    duration: float
    ewma: float


class Heartbeat:
    """Liveness file the launcher can poll (one per host)."""

    def __init__(self, directory: str, host_id: int = 0):
        self.path = Path(directory) / f"heartbeat_{host_id}.json"
        self.path.parent.mkdir(parents=True, exist_ok=True)

    def beat(self, step: int):
        self.path.write_text(json.dumps({"step": step, "t": time.time()}))

    @staticmethod
    def stale_hosts(directory: str, timeout_s: float) -> list:
        now = time.time()
        out = []
        for p in Path(directory).glob("heartbeat_*.json"):
            data = json.loads(p.read_text())
            if now - data["t"] > timeout_s:
                out.append(p.stem)
        return out


class ResilientLoop:
    def __init__(
        self,
        step_fn: Callable,                   # (state, batch) -> (state, metrics)
        init_state: Any,
        *,
        ckpt_dir: str,
        ckpt_every: int = 50,
        straggler_factor: float = 3.0,
        max_retries: int = 2,
        shardings: Any = None,
        host_id: int = 0,
    ):
        self.step_fn = step_fn
        self.ckpt = CheckpointManager(ckpt_dir)
        self.ckpt_every = ckpt_every
        self.straggler_factor = straggler_factor
        self.max_retries = max_retries
        self.heartbeat = Heartbeat(ckpt_dir, host_id)
        self.stragglers: list = []
        self.ewma: Optional[float] = None
        self.shardings = shardings

        if latest_step(ckpt_dir) is not None:
            self.state, self.step = restore(
                init_state, ckpt_dir, shardings=shardings
            )
            self.step += 1
            self.resumed = True
        else:
            self.state, self.step = init_state, 0
            self.resumed = False

    def run(self, batches, *, steps: Optional[int] = None):
        """Iterate batches; yields (step, metrics)."""
        for batch in batches:
            if steps is not None and self.step >= steps:
                break
            metrics = self._one_step(batch)
            yield self.step, metrics
            self.step += 1
        self.ckpt.save_async(self.state, self.step - 1)
        self.ckpt.wait()

    def _one_step(self, batch):
        t0 = time.perf_counter()

        def _attempt():
            nonlocal t0
            t0 = time.perf_counter()   # straggler timing covers the attempt
            self.state, metrics = self.step_fn(self.state, batch)
            return metrics

        def _restore_before_retry(attempt, exc):
            # replay from the last published checkpoint, like a relaunch
            if latest_step(self.ckpt.directory) is not None:
                self.state, _ = restore(
                    self.state, self.ckpt.directory, shardings=self.shardings
                )

        # every step failure is treated as a preemption and replayed (the
        # training loop's contract predates fault classification); the
        # service layer passes the stricter ``is_transient`` instead
        metrics = retry_call(
            _attempt,
            retries=self.max_retries,
            seed=self.step,
            base_s=0.01,
            should_retry=lambda e: True,
            on_retry=_restore_before_retry,
        )
        dt = time.perf_counter() - t0
        ewma = dt if self.ewma is None else 0.9 * self.ewma + 0.1 * dt
        if self.ewma is not None and dt > self.straggler_factor * self.ewma:
            self.stragglers.append(StragglerEvent(self.step, dt, self.ewma))
        self.ewma = ewma
        self.heartbeat.beat(self.step)
        if self.step % self.ckpt_every == 0 and self.step > 0:
            self.ckpt.save_async(self.state, self.step)
        return metrics
