"""Microbatch pipeline parallelism over a mesh axis (GPipe schedule).

For cross-pod deployments where the "pod" axis link is latency-bound,
tensor-style collectives (all-reduce per layer) are a poor fit; a pipeline
moves only the (B_mb, S, D) activation cut once per stage per microbatch.

``pipeline_apply(fn_stage, params_stacked, x_mb, axis)`` runs inside
shard_map with the stage dimension mapped to ``axis``:

  * ``params_stacked``: leading dim = n_stages (sharded over ``axis``);
  * ``x_mb``: (n_micro, B_mb, ...) microbatched inputs, everyone holds
    them (stage 0 consumes, later stages ignore);
  * the classic rotating-buffer schedule: n_micro + n_stages - 1 ticks,
    each tick every stage applies its layer then ``ppermute``s its
    activation to the next stage.

Returns the final-stage outputs, (n_micro, B_mb, ...), valid on the last
stage (and broadcast back so every stage returns the same value —
convenient for loss computation under shard_map).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def pipeline_apply(
    stage_fn: Callable,      # (stage_params, x) -> y  (one stage's compute)
    stage_params,            # pytree, leaves (1, ...) — this stage's slice
    x_mb: jax.Array,         # (n_micro, B_mb, ...) microbatched input
    axis: str,
):
    n_stages = jax.lax.psum(1, axis)
    stage = jax.lax.axis_index(axis)
    n_micro = x_mb.shape[0]
    ticks = n_micro + n_stages - 1
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    params = jax.tree.map(lambda p: p[0], stage_params)
    buf = jnp.zeros_like(x_mb[0])                    # rotating activation
    outs = jnp.zeros((n_micro,) + x_mb.shape[1:], x_mb.dtype)

    def tick(carry, t):
        buf, outs = carry
        mb_in = t                                     # microbatch entering
        # stage 0 ingests a fresh microbatch while t < n_micro
        take = jnp.clip(mb_in, 0, n_micro - 1)
        fresh = jax.lax.dynamic_index_in_dim(x_mb, take, 0, keepdims=False)
        inp = jnp.where(stage == 0, fresh, buf)
        # bubble guard: stage s works on microbatch (t - s)
        my_mb = t - stage
        active = (my_mb >= 0) & (my_mb < n_micro)
        y = stage_fn(params, inp)
        y = jnp.where(active, y, buf)
        # last stage records its finished microbatch
        done_idx = jnp.clip(my_mb, 0, n_micro - 1)
        record = active & (stage == n_stages - 1)
        outs = jax.lax.cond(
            record,
            lambda o: jax.lax.dynamic_update_index_in_dim(
                o, y, done_idx, 0
            ),
            lambda o: o,
            outs,
        )
        # rotate activations to the next stage
        buf = jax.lax.ppermute(y, axis, perm)
        return (buf, outs), None

    (buf, outs), _ = jax.lax.scan(tick, (buf, outs), jnp.arange(ticks))
    # broadcast final outputs from the last stage to everyone
    outs = jax.lax.ppermute(
        outs, axis, [( (n_stages - 1 + i) % n_stages, i) for i in range(n_stages)]
    ) if n_stages > 1 else outs
    # after rotation by one, stage 0 holds last stage's outs; rebroadcast:
    outs = jax.lax.psum(
        jnp.where(stage == 0, outs, jnp.zeros_like(outs)), axis
    )
    return outs
