"""Mesh execution plans: sharding a partition stream across devices.

A :class:`MeshPlan` is the device-axis view of a
:class:`~repro.exec.plan.PartitionPlan`: the same bucketed batch schedule
the single-device streaming executor runs, regrouped into *waves* of up
to ``num_devices`` same-bucket packed launches.  Wave ``w`` of a bucket
holds that bucket's batches ``[w*D, (w+1)*D)`` — i.e. batch ``j`` lands
on lane ``j % D`` (round-robin), so the load difference between any two
lanes is at most one batch per bucket.

Because every batch in a wave shares the bucket's canonical padded
shapes (``capacity`` slots of ``(n_pad, e_pad)``), a wave is one SPMD
launch: identical programs over per-lane packed arrays with replicated
params — the compile unit stays per *bucket*, shared by every device.

Partitions stay independent until the core-prediction scatter (GROOT
Alg. 1), so the assignment is pure load balancing: no lane ever needs
another lane's rows, and a :class:`~repro.checkpoint.PartitionJournal`
restored under a different device count simply shrinks the schedule the
waves are built from.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.exec.plan import PartitionPlan
from repro.service.bucketing import BucketShape


@dataclasses.dataclass(frozen=True)
class Wave:
    """One mesh-wide launch: up to ``num_devices`` same-bucket batches.

    ``lanes[d]`` is the list of plan subgraph indices lane ``d`` packs for
    this wave, or ``None`` when the lane idles (the bucket's batch count
    is not a multiple of the device count).
    """

    shape: BucketShape
    lanes: tuple[Optional[list], ...]

    @property
    def active(self) -> int:
        return sum(1 for l in self.lanes if l is not None)


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    """Device-sharded schedule for one partition plan (immutable)."""

    plan: PartitionPlan
    num_devices: int
    capacity: int
    waves: tuple[Wave, ...]

    @property
    def num_buckets(self) -> int:
        return self.plan.num_buckets

    @property
    def total_batches(self) -> int:
        return sum(w.active for w in self.waves)

    @property
    def lane_batches(self) -> tuple[int, ...]:
        """Packed launches per lane — the balance the round-robin buys."""
        counts = [0] * self.num_devices
        for w in self.waves:
            for d, lane in enumerate(w.lanes):
                if lane is not None:
                    counts[d] += 1
        return tuple(counts)

    @property
    def modeled_speedup(self) -> float:
        """Launch-balance speedup over one device: total batches over the
        busiest lane's batches.  This is the *modeled-launch* metric the
        sharded benchmark gates — host CPU "devices" share physical
        cores, so wall time cannot witness the scaling the assignment
        achieves; the lane balance can."""
        busiest = max(self.lane_batches, default=0)
        return self.total_batches / busiest if busiest else 1.0

    @property
    def utilization(self) -> tuple[float, ...]:
        """Per-lane occupancy: fraction of waves the lane had real work."""
        if not self.waves:
            return tuple(0.0 for _ in range(self.num_devices))
        per = [0] * self.num_devices
        for w in self.waves:
            for d, lane in enumerate(w.lanes):
                per[d] += lane is not None
        return tuple(c / len(self.waves) for c in per)

    def per_device_peak_bytes(self, gnn_cfg) -> int:
        """Modeled device bytes of the largest packed launch ONE lane
        holds — identical to the single-device packed peak, because every
        lane launches the same canonical bucket shapes."""
        return self.plan.peak_batch_memory_bytes(gnn_cfg, self.capacity)

    def describe(self) -> str:
        """The mesh decision, the way ``Session.explain()`` reports it."""
        return (
            f"{self.num_devices} device(s) x k={self.plan.k} x "
            f"{self.num_buckets} bucket(s), {self.total_batches} packed "
            f"batches in {len(self.waves)} wave(s), "
            f"modeled launch speedup {self.modeled_speedup:.2f}x"
        )


def build_mesh_plan(
    plan: PartitionPlan,
    num_devices: int,
    capacity: int,
    *,
    schedule: Optional[list] = None,
) -> MeshPlan:
    """Regroup a plan's batch schedule into device waves.

    ``schedule`` overrides ``plan.schedule(capacity)`` — the sharded
    executor passes the journal-filtered schedule of a resumed run, so
    already-committed partitions never occupy a lane.
    """
    if num_devices < 1:
        raise ValueError(f"need at least one device, got {num_devices}")
    if schedule is None:
        schedule = plan.schedule(capacity)
    # schedule is bucket-major (ascending shape): chunk each bucket's
    # contiguous batch run into waves of num_devices lanes
    waves: list[Wave] = []
    i = 0
    while i < len(schedule):
        shape = schedule[i][0]
        j = i
        while j < len(schedule) and schedule[j][0] == shape:
            j += 1
        batches = [indices for _, indices in schedule[i:j]]
        for at in range(0, len(batches), num_devices):
            chunk = batches[at : at + num_devices]
            chunk += [None] * (num_devices - len(chunk))
            waves.append(Wave(shape=shape, lanes=tuple(chunk)))
        i = j
    return MeshPlan(
        plan=plan, num_devices=num_devices, capacity=capacity,
        waves=tuple(waves),
    )
