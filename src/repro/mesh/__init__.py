"""`repro.mesh`: multi-device sharded streaming of partition plans.

The paper's headline run (a 1,024-bit CSA multiplier, 134M nodes at
batch 16) leans on the fact that re-grown partitions are independent
until verdict aggregation — which makes the packed bucket batches of
``repro.exec`` embarrassingly data-parallel.  This package shards that
stream across the data axis of a JAX device mesh:

  :mod:`repro.mesh.plan`    MeshPlan — waves of same-bucket batches,
                            round-robin over lanes
  :mod:`repro.mesh.runner`  MeshRunner — replicated-params pmap (SPMD,
                            shape-stable backends) or per-device jit
                            (MPMD, structure-keyed groot* backends)
  :mod:`repro.mesh.stream`  ShardedStreamingExecutor — per-lane prefetch
                            threads/queues, per-lane fault isolation,
                            journal-composable resume

CPU hosts exercise every path via
``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
"""
from repro.mesh.plan import MeshPlan, Wave, build_mesh_plan
from repro.mesh.runner import MeshRunner
from repro.mesh.stream import (
    MeshStats,
    ShardedStreamingExecutor,
    shared_mesh_executor,
)

__all__ = [
    "MeshPlan",
    "MeshRunner",
    "MeshStats",
    "ShardedStreamingExecutor",
    "Wave",
    "build_mesh_plan",
    "shared_mesh_executor",
]
