"""The device side of sharded streaming: one wave = one mesh launch.

:class:`MeshRunner` executes :class:`~repro.mesh.plan.Wave`\\ s over the
data axis of :func:`repro.launch.mesh.make_host_mesh`:

  * **SPMD** (shape-stable backends, "ref"/"onehot"): the wave's per-lane
    packed arrays are stacked on a leading device axis and dispatched
    through ONE ``jax.pmap`` program — params replicated (``in_axes
    None``), padded shapes static — so the whole mesh shares a single
    compile unit per (bucket, capacity), exactly the single-device
    compile discipline.  Idle lanes are filled with a sibling's arrays
    (their outputs are discarded); the executable never sees a partial
    wave, so the trace count stays at most ``num_buckets`` TOTAL.

  * **MPMD** (structure-keyed ``groot*`` backends): each lane's degree
    plan is a static jit constant (an :func:`~repro.kernels.ops.make_agg_pair`
    pair), so lanes cannot share one SPMD program.  Instead params are
    replicated host-side onto every lane device once, each lane's arrays
    are committed to its device, and all lanes are dispatched
    asynchronously before any result is read back — JAX's async dispatch
    overlaps the per-device executions, MPMD-style.

Both paths return per-lane int32 predictions for the caller's
core-prediction scatter; partitions never cross lanes (GROOT Alg. 1
independence), so no collective beyond the implicit pmap gang exists.
"""
from __future__ import annotations

import threading
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gnn
from repro.kernels import ops
from repro.launch.mesh import MeshConfigError, make_host_mesh
from repro.obs import REGISTRY
from repro.service.scheduler import (
    SHAPE_STABLE_BACKENDS,
    STRUCTURE_KEYED_BACKENDS,
)


class MeshRunner:
    """Replicated-params wave launcher over ``num_devices`` mesh lanes."""

    def __init__(self, params, backend: str = "ref", *,
                 num_devices: Optional[int] = None,
                 stream_dtype: Optional[str] = None):
        if backend not in SHAPE_STABLE_BACKENDS + STRUCTURE_KEYED_BACKENDS:
            raise ValueError(
                f"mesh backend must be one of {SHAPE_STABLE_BACKENDS} or "
                f"{STRUCTURE_KEYED_BACKENDS}, got {backend!r}"
            )
        visible = jax.local_device_count()
        if num_devices is None:
            num_devices = visible
        if num_devices < 1 or num_devices > visible:
            raise MeshConfigError(
                f"mesh_devices={num_devices} out of range: "
                f"{visible} device(s) visible"
            )
        #: the data axis of the host mesh — lane d owns devices[d]
        self.mesh = make_host_mesh(data=num_devices)
        self.devices = list(self.mesh.devices.ravel())
        self.num_devices = num_devices
        self._backend = backend
        self._stream_dtype = stream_dtype
        self._spmd = backend in SHAPE_STABLE_BACKENDS
        self.compile_count = 0
        self.run_count = 0          # wave launches
        self.lane_run_count = 0     # per-lane launches (<= waves * devices)
        self._lock = threading.Lock()

        self._params = jax.tree_util.tree_map(jnp.asarray, params)

        def _fwd(params, x, edge_src, edge_dst, edge_inv, edge_slot,
                 num_nodes, agg):
            # executes at trace time only: one increment per compilation
            self.compile_count += 1
            REGISTRY.counter("mesh.runner_compiles").inc()
            if agg is None and self._backend == "onehot":
                agg = ops.make_agg_pair(edge_src, edge_dst, num_nodes, "onehot")
            logits = gnn.forward(
                params, x, edge_src, edge_dst, edge_inv, edge_slot,
                num_nodes=num_nodes, agg=agg,
                stream_dtype=self._stream_dtype,
            )
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)

        if self._spmd:
            # one program, all lanes: agg is resolved inside the trace, so
            # only (params, arrays..., static num_nodes) cross the boundary
            def _fwd_spmd(params, x, es, ed, ei, esl, num_nodes):
                return _fwd(params, x, es, ed, ei, esl, num_nodes, None)

            self._pmap = jax.pmap(
                _fwd_spmd,
                in_axes=(None, 0, 0, 0, 0, 0),
                static_broadcasted_argnums=6,
                devices=self.devices,
            )
        else:
            # MPMD: params replicated once per lane device; each lane's
            # agg pair is a static jit constant keyed by packed structure
            self._jit = jax.jit(_fwd, static_argnames=("num_nodes", "agg"))
            self._lane_params = [
                jax.tree_util.tree_map(
                    lambda a, d=dev: jax.device_put(a, d), self._params
                )
                for dev in self.devices
            ]

    def launch_wave(self, batches: list) -> list:
        """Run one wave: ``batches[d]`` is lane *d*'s packed-array dict or
        None for an idle lane.  Returns per-lane ``np.ndarray`` predictions
        (None where the lane idled)."""
        assert len(batches) == self.num_devices
        active = [d for d, b in enumerate(batches) if b is not None]
        if not active:
            return [None] * self.num_devices
        with self._lock:
            self.run_count += 1
            self.lane_run_count += len(active)
            if self._spmd:
                return self._launch_spmd(batches, active)
            return self._launch_mpmd(batches, active)

    def _launch_spmd(self, batches: list, active: list) -> list:
        filler = batches[active[0]]
        full = [b if b is not None else filler for b in batches]
        stacked = [
            np.stack([b[key] for b in full])
            for key in ("x", "edge_src", "edge_dst", "edge_inv", "edge_slot")
        ]
        num_nodes = full[0]["num_nodes"]
        pred = np.asarray(self._pmap(self._params, *stacked, num_nodes))
        return [
            pred[d] if batches[d] is not None else None
            for d in range(self.num_devices)
        ]

    def _launch_mpmd(self, batches: list, active: list) -> list:
        # dispatch every lane before blocking on any readback: jax queues
        # the executions asynchronously, so the devices overlap
        futures: dict = {}
        for d in active:
            b = batches[d]
            agg = ops.make_agg_pair(
                b["edge_src"], b["edge_dst"], b["num_nodes"], self._backend
            )
            dev = self.devices[d]
            staged = {
                key: jax.device_put(b[key], dev)
                for key in ("x", "edge_src", "edge_dst", "edge_inv",
                            "edge_slot")
            }
            futures[d] = self._jit(
                self._lane_params[d], staged["x"], staged["edge_src"],
                staged["edge_dst"], staged["edge_inv"], staged["edge_slot"],
                num_nodes=b["num_nodes"], agg=agg,
            )
        return [
            np.asarray(futures[d]) if d in futures else None
            for d in range(self.num_devices)
        ]
