"""Sharded streaming: the multi-device twin of ``repro.exec.stream``.

:class:`ShardedStreamingExecutor` drives a
:class:`~repro.exec.plan.PartitionPlan` across the data axis of a host
device mesh.  One host prefetch thread per *lane* (device) packs that
lane's batches into a bounded per-lane queue — the same
producer/watchdog discipline as the single-device executor, D times —
while the caller thread consumes wave by wave: one same-bucket packed
batch per active lane, launched together through
:class:`~repro.mesh.runner.MeshRunner`, core predictions scattered into
the single global verdict array.

The executor duck-types :class:`~repro.exec.stream.StreamingExecutor`
(``run_plan(plan, features, gnn_cfg=, journal=)`` and a ``stats`` with
``.delta()``), so :func:`repro.core.pipeline.infer_streaming` drives it
unchanged.  Crash-safe resume composes for free: journal commits are
per-*partition*, so a run killed under one shard assignment restores
under any other — the restored partitions are filtered out of the
schedule BEFORE waves are formed, and the remainder is re-balanced over
whatever devices the resumed run sees.

Blast-radius isolation: each lane's launch fires the ``"mesh.launch"``
fault site and is replayed with seeded backoff on transient failures
(:func:`repro.distributed.fault_tolerance.retry_call`) — a transient on
one lane never re-packs, re-runs, or poisons its sibling lanes' batches.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Optional

import numpy as np

from repro import faults
from repro.distributed.fault_tolerance import is_transient, retry_call
from repro.exec.packing import PackedBatch, pack_partitions, scatter_core_predictions
from repro.exec.plan import PartitionPlan
from repro.exec.stream import StreamStats
from repro.mesh.plan import MeshPlan, build_mesh_plan
from repro.mesh.runner import MeshRunner
from repro.obs import REGISTRY, current_tracer, span


@dataclasses.dataclass
class MeshStats(StreamStats):
    """StreamStats plus the mesh-axis probes (cumulative across runs)."""

    devices: int = 0              # lanes of the last run's mesh
    waves: int = 0                # mesh-wide launches issued
    lane_launches: int = 0        # per-lane launches summed over waves
    idle_lane_slots: int = 0      # lane-waves with no work (imbalance)
    lane_retries: int = 0         # transient lane launches replayed

    def delta(self, before: "MeshStats") -> "MeshStats":
        base = super().delta(before)
        return MeshStats(
            **dataclasses.asdict(base),
            devices=self.devices,
            waves=self.waves - before.waves,
            lane_launches=self.lane_launches - before.lane_launches,
            idle_lane_slots=self.idle_lane_slots - before.idle_lane_slots,
            lane_retries=self.lane_retries - before.lane_retries,
        )


_SENTINEL = object()


class ShardedStreamingExecutor:
    """Streams partition plans wave-by-wave over a host device mesh."""

    def __init__(
        self,
        params=None,
        backend: str = "ref",
        *,
        runner: Optional[MeshRunner] = None,
        num_devices: Optional[int] = None,
        capacity: int = 2,
        prefetch: int = 1,
        min_nodes: int = 64,
        min_edges: int = 128,
        stream_dtype: Optional[str] = None,
        launch_retries: int = 2,
        retry_backoff_s: float = 0.05,
    ):
        if runner is None:
            if params is None:
                raise ValueError("need params or a MeshRunner")
            runner = MeshRunner(
                params, backend, num_devices=num_devices,
                stream_dtype=stream_dtype,
            )
        self.runner = runner
        self.num_devices = runner.num_devices
        self.capacity = max(1, capacity)
        self.prefetch = max(0, prefetch)
        self.min_nodes = min_nodes
        self.min_edges = min_edges
        self.launch_retries = max(0, launch_retries)
        self.retry_backoff_s = retry_backoff_s
        self.stats = MeshStats(devices=self.num_devices)
        self.buckets_seen: set = set()

    # -- planning ------------------------------------------------------------

    def mesh_plan(self, plan: PartitionPlan,
                  schedule: Optional[list] = None) -> MeshPlan:
        return build_mesh_plan(
            plan, self.num_devices, self.capacity, schedule=schedule,
        )

    # -- execution -----------------------------------------------------------

    def run_plan(self, plan: PartitionPlan, features: np.ndarray,
                 gnn_cfg=None, journal=None) -> np.ndarray:
        """Stream every partition batch across the mesh; returns the same
        (num_nodes,) int32 global predictions the single-device executor
        produces — bit-identical, because each lane launches the identical
        packed program the single-device route would have launched.
        """
        t_wall = time.perf_counter()
        schedule = plan.schedule(self.capacity)
        self.buckets_seen.update(plan.buckets)
        if gnn_cfg is not None:
            modeled = plan.peak_batch_memory_bytes(gnn_cfg, self.capacity)
            self.stats.modeled_peak_bytes = max(
                self.stats.modeled_peak_bytes, modeled
            )
            REGISTRY.gauge("exec.modeled_peak_bytes").set(modeled)
        out = np.zeros(plan.num_nodes, dtype=np.int32)
        if journal is not None:
            restored = journal.restore(plan, out)
            if restored:
                schedule = [
                    (shape, kept)
                    for shape, indices in schedule
                    if (kept := [i for i in indices if i not in restored])
                ]
                self.stats.resumed_partitions += len(restored)
                REGISTRY.counter("exec.resumed_partitions").inc(len(restored))
        mplan = self.mesh_plan(plan, schedule)
        compiles_before = self.runner.compile_count
        tracer = current_tracer()
        D = self.num_devices

        with tracer.span(
            "mesh.stream",
            partitions=plan.num_parts,
            waves=len(mplan.waves),
            devices=D,
        ) as stream_sp:
            if self.prefetch == 0 or len(mplan.waves) <= 1:
                for wave in mplan.waves:
                    staged = [
                        self._pack_timed(plan, lane, features, wave.shape, d)
                        if lane is not None else None
                        for d, lane in enumerate(wave.lanes)
                    ]
                    self._launch_wave(wave, staged, out, gnn_cfg, journal)
            else:
                self._run_prefetched(
                    mplan, plan, features, out, gnn_cfg, journal,
                    stream_sp.span_id, tracer,
                )

        if journal is not None:
            journal.complete()

        self.stats.runs += 1
        self.stats.waves += len(mplan.waves)
        idle = sum(D - w.active for w in mplan.waves)
        self.stats.idle_lane_slots += idle
        run_compiles = self.runner.compile_count - compiles_before
        self.stats.compiles += run_compiles
        wall = time.perf_counter() - t_wall
        self.stats.wall_s += wall
        for d, util in enumerate(mplan.utilization):
            REGISTRY.gauge(f"exec.device_utilization.d{d}").set(util)
        REGISTRY.counter("exec.runs").inc()
        REGISTRY.counter("exec.compiles").inc(run_compiles)
        REGISTRY.histogram("exec.wall_s").observe(wall)
        return out

    # -- internals -----------------------------------------------------------

    def _run_prefetched(self, mplan: MeshPlan, plan, features, out,
                        gnn_cfg, journal, stream_id, tracer) -> None:
        """One producer thread + bounded queue per lane; the caller thread
        consumes wave-aligned: a lane's queue yields its batches in wave
        order, so wave *w* pops exactly the lanes active in *w*."""
        D = self.num_devices
        queues = [queue.Queue(maxsize=max(1, self.prefetch)) for _ in range(D)]
        stop = threading.Event()

        def _put(q, item) -> bool:
            while not stop.is_set():
                try:
                    q.put(item, timeout=0.1)
                    return True
                except queue.Full:
                    continue
            return False

        def _producer(d: int):
            with tracer.adopt(stream_id):
                q = queues[d]
                try:
                    for wave in mplan.waves:
                        lane = wave.lanes[d]
                        if lane is None:
                            continue
                        faults.fire(
                            "exec.prefetch",
                            tag=lambda: f"lane={d} parts={len(lane)}",
                        )
                        if not _put(q, self._pack_timed(
                            plan, lane, features, wave.shape, d
                        )):
                            return
                    _put(q, _SENTINEL)
                except faults.WorkerKilled:
                    return       # abrupt death: the watchdog must catch it
                except BaseException as e:  # noqa: BLE001 — forwarded
                    _put(q, e)

        threads = [
            threading.Thread(
                target=_producer, args=(d,), name=f"mesh-prefetch-{d}",
                daemon=True,
            )
            for d in range(D)
        ]
        for th in threads:
            th.start()
        try:
            for wave in mplan.waves:
                staged: list = [None] * D
                for d, lane in enumerate(wave.lanes):
                    if lane is None:
                        continue
                    depth = queues[d].qsize()
                    self.stats.max_queue_depth = max(
                        self.stats.max_queue_depth, depth
                    )
                    got = self._next_batch(queues[d], threads[d], d)
                    if isinstance(got, BaseException):
                        raise got
                    staged[d] = got
                self._launch_wave(wave, staged, out, gnn_cfg, journal)
        finally:
            stop.set()
            for th in threads:
                th.join(timeout=60.0)

    @staticmethod
    def _next_batch(q: queue.Queue, th: threading.Thread, lane: int):
        """Per-lane producer watchdog (see StreamingExecutor._next_batch)."""
        while True:
            try:
                got = q.get(timeout=0.2)
            except queue.Empty:
                if not th.is_alive():
                    REGISTRY.counter("exec.prefetch_deaths").inc()
                    raise RuntimeError(
                        f"mesh prefetch thread for lane {lane} died without "
                        f"delivering a batch or an error "
                        f"(see exec.prefetch_deaths)"
                    ) from None
                continue
            if got is _SENTINEL:
                raise RuntimeError(
                    f"lane {lane} queue exhausted before its wave schedule"
                )
            return got

    def _pack_timed(self, plan, indices, features, shape,
                    lane: int) -> PackedBatch:
        t0 = time.perf_counter()
        with span("mesh.pack", lane=lane, parts=len(indices)) as sp:
            batch = pack_partitions(
                plan, indices, features, shape, self.capacity
            )
            sp.set(bytes=batch.nbytes)
        dt = time.perf_counter() - t0
        self.stats.pack_s += dt
        self.stats.bytes_h2d += batch.nbytes
        REGISTRY.counter("exec.bytes_h2d").inc(batch.nbytes)
        REGISTRY.counter(f"mesh.bytes_h2d.d{lane}").inc(batch.nbytes)
        REGISTRY.histogram("mesh.pack_s").observe(dt)
        return batch

    def _launch_wave(self, wave, staged: list, out: np.ndarray,
                     gnn_cfg, journal) -> None:
        """One mesh-wide launch with per-lane fault/retry isolation."""
        active = [d for d, b in enumerate(staged) if b is not None]
        if not active:
            return
        if gnn_cfg is not None:
            from repro.core.pipeline import memory_model_bytes

            b0 = staged[active[0]]
            actual = memory_model_bytes(
                int(b0.arrays["x"].shape[0]),
                int(b0.arrays["edge_src"].shape[0]),
                gnn_cfg,
            )
            self.stats.actual_peak_bytes = max(
                self.stats.actual_peak_bytes, actual
            )
            REGISTRY.gauge("exec.actual_peak_bytes").set(actual)

        def _retried(attempt, err):
            self.stats.lane_retries += 1
            REGISTRY.counter("mesh.lane_retries").inc()

        t0 = time.perf_counter()
        with span("mesh.launch", wave_active=len(active)):
            # per-lane fire + replay: a transient injected on one lane is
            # retried in isolation — the sibling lanes' staged batches are
            # untouched, and the wave launches once every lane is clear
            for d in active:
                batch = staged[d]
                retry_call(
                    lambda d=d, batch=batch: faults.fire(
                        "mesh.launch",
                        tag=lambda: f"lane={d} parts={len(batch.items)} "
                                    f"shape={batch.shape}",
                    ),
                    retries=self.launch_retries,
                    seed=(id(self), d),
                    base_s=self.retry_backoff_s,
                    should_retry=is_transient,
                    on_retry=_retried,
                )
            preds = retry_call(
                lambda: self.runner.launch_wave(
                    [b.arrays if b is not None else None for b in staged]
                ),
                retries=self.launch_retries,
                seed=id(self),
                base_s=self.retry_backoff_s,
                should_retry=is_transient,
                on_retry=_retried,
            )
        dt = time.perf_counter() - t0
        self.stats.device_s += dt
        REGISTRY.histogram("mesh.device_s").observe(dt)
        for d in active:
            batch, pred = staged[d], preds[d]
            self.stats.launches += 1
            self.stats.lane_launches += 1
            self.stats.batches += 1
            self.stats.partitions += len(batch.items)
            self.stats.core_rows += scatter_core_predictions(out, batch, pred)
            REGISTRY.counter("exec.launches").inc()
            REGISTRY.counter(f"mesh.launches.d{d}").inc()
            if journal is not None:
                # same per-partition durability as the single-device path:
                # a crash between waves loses at most the in-flight wave
                for idx, it in zip(batch.indices, batch.items):
                    ids = it.global_ids[: it.num_core]
                    journal.commit(int(idx), ids, out[ids])


#: identity-keyed reuse pool, mirroring ``exec.stream._EXECUTOR_POOL`` —
#: a fresh executor per verify would mean a fresh pmap/jit cache per
#: verify, retracing every bucket each time
_MESH_POOL: dict[tuple, tuple[object, "ShardedStreamingExecutor"]] = {}
_MESH_POOL_MAX = 8


def shared_mesh_executor(
    params, backend: str, *, num_devices: Optional[int] = None,
    capacity: int = 2, prefetch: int = 1,
    stream_dtype: Optional[str] = None,
    min_nodes: int = 64, min_edges: int = 128,
    launch_retries: int = 2, retry_backoff_s: float = 0.05,
) -> ShardedStreamingExecutor:
    """The process-wide sharded executor for (params identity, knobs)."""
    if stream_dtype == "float32":
        stream_dtype = None
    key = (id(params), backend, num_devices, capacity, prefetch,
           stream_dtype, min_nodes, min_edges, launch_retries)
    hit = _MESH_POOL.get(key)
    if hit is not None and hit[0] is params:
        return hit[1]
    ex = ShardedStreamingExecutor(
        params, backend, num_devices=num_devices, capacity=capacity,
        prefetch=prefetch, stream_dtype=stream_dtype,
        min_nodes=min_nodes, min_edges=min_edges,
        launch_retries=launch_retries, retry_backoff_s=retry_backoff_s,
    )
    if len(_MESH_POOL) >= _MESH_POOL_MAX:
        _MESH_POOL.clear()
    _MESH_POOL[key] = (params, ex)
    return ex
