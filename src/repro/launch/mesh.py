"""Production mesh construction.

A function (not a module-level constant) so importing never touches jax
device state.  Single pod: 16x16 = 256 chips ("data", "model"); multi-pod:
2 pods x 256 = 512 chips ("pod", "data", "model") — DP over the pod axis
(cross-pod DCN-class links carry only gradient all-reduces; TP stays inside
a pod's ICI).
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np


class MeshConfigError(ValueError):
    """The requested mesh shape cannot be built from the visible devices."""


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1, *, data: Optional[int] = None):
    """Small mesh over whatever devices exist (tests / examples).

    ``data`` caps the data axis to fewer shards than the visible devices
    allow — a test on an 8-device host can ask for a 2-way mesh.
    """
    n = len(jax.devices())
    if model < 1 or n % model:
        raise MeshConfigError(
            f"model axis {model} does not divide the {n} visible devices"
        )
    max_data = n // model
    if data is None:
        data = max_data
    if data < 1 or data > max_data:
        raise MeshConfigError(
            f"data axis {data} out of range: {n} devices / model={model} "
            f"admit at most {max_data} data shards"
        )
    devices = jax.devices()[: data * model]
    return jax.sharding.Mesh(
        np.asarray(devices).reshape(data, model), ("data", "model")
    )
