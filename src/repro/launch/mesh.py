"""Production mesh construction.

A function (not a module-level constant) so importing never touches jax
device state.  Single pod: 16x16 = 256 chips ("data", "model"); multi-pod:
2 pods x 256 = 512 chips ("pod", "data", "model") — DP over the pod axis
(cross-pod DCN-class links carry only gradient all-reduces; TP stays inside
a pod's ICI).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))
