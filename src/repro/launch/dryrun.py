import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first
# init, and the production meshes below need 512 placeholder host devices.

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from pathlib import Path  # noqa: E402

import jax           # noqa: E402

from repro.zoo.configs import ARCHS, LM_ARCHS, get_config          # noqa: E402
from repro.zoo.configs.shapes import SHAPES, supported_shapes       # noqa: E402
from repro.launch.mesh import make_production_mesh              # noqa: E402
from repro.launch.steps import GROOT_SHAPES, build_cell, build_groot_cell  # noqa: E402
from repro.roofline import hlo as hlo_mod                       # noqa: E402
from repro.sharding.rules import use_sharding                   # noqa: E402

"""Multi-pod dry-run (deliverable e).

For every (architecture x input shape) and both production meshes this
lowers + compiles the appropriate step with full sharding assignments and
records memory_analysis / cost_analysis / loop-corrected HLO stats as JSON
artifacts under experiments/dryrun/<mesh>/<arch>__<shape>.json.

    PYTHONPATH=src python -m repro.launch.dryrun --all
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b \
        --shape train_4k --mesh both
"""

ART_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _cost_fields(compiled):
    """Normalise ``cost_analysis()`` across jax versions: older releases
    return a one-element sequence of dicts (per device kind), newer ones a
    single flat dict; either may be None."""
    ca = compiled.cost_analysis()
    if ca is None:
        return {}
    if isinstance(ca, dict):
        return dict(ca)
    if isinstance(ca, (list, tuple)):
        return dict(ca[0]) if ca else {}
    return dict(ca)


def _mem_fields(compiled):
    ma = compiled.memory_analysis()
    fields = (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    )
    out = {}
    for f in fields:
        try:
            out[f] = int(getattr(ma, f))
        except Exception:
            pass
    return out


def run_cell(cell, mesh, mesh_name: str, save: bool = True) -> dict:
    t0 = time.perf_counter()
    jitted = jax.jit(
        cell.step_fn,
        in_shardings=cell.in_shardings,
        out_shardings=cell.out_shardings,
        donate_argnums=cell.donate_argnums,
    )
    with use_sharding(mesh, fsdp=cell.static_meta.get("fsdp", False),
                      sp=cell.static_meta.get("sp", False)):
        lowered = jitted.lower(*cell.args)
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0

    cost = _cost_fields(compiled)
    mem = _mem_fields(compiled)
    stats = hlo_mod.analyze(compiled.as_text())
    n_dev = mesh.devices.size
    record = {
        "arch": cell.arch,
        "shape": cell.shape,
        "mesh": mesh_name,
        "devices": int(n_dev),
        "meta": cell.static_meta,
        "timing": {"lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2)},
        "memory_analysis": mem,
        "cost_analysis": {
            k: float(v)
            for k, v in cost.items()
            if isinstance(v, (int, float)) and k in ("flops", "bytes accessed")
        },
        "hlo": {
            "dot_flops_per_device": stats.dot_flops,
            "collective_bytes_per_device": stats.collective_bytes,
            "collective_by_kind": stats.collective_by_kind,
            "traffic_bytes_per_device": stats.traffic_bytes,
            "entry_param_bytes_per_device": stats.entry_param_bytes,
            "while_trips": stats.while_trips,
        },
    }
    if save:
        out = ART_DIR / mesh_name
        out.mkdir(parents=True, exist_ok=True)
        path = out / f"{cell.arch}__{cell.shape}.json"
        path.write_text(json.dumps(record, indent=1))
        record["artifact"] = str(path)
    return record


def iter_cells(arch_filter=None, shape_filter=None):
    for arch in ARCHS:
        if arch_filter and arch != arch_filter:
            continue
        cfg = get_config(arch)
        if arch == "groot-gnn":
            shapes = list(GROOT_SHAPES)
        else:
            shapes = supported_shapes(cfg)
        for shape in shapes:
            if shape_filter and shape != shape_filter:
                continue
            yield arch, cfg, shape


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None, help="architecture id")
    ap.add_argument("--shape", default=None, help="input-shape name")
    ap.add_argument("--mesh", default="both", choices=("pod", "multipod", "both"))
    ap.add_argument("--all", action="store_true", help="every cell")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    if args.list:
        for arch, _, shape in iter_cells():
            print(f"{arch:28s} {shape}")
        return

    meshes = []
    if args.mesh in ("pod", "both"):
        meshes.append(("pod", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multipod", "both"):
        meshes.append(("multipod", make_production_mesh(multi_pod=True)))

    failures = []
    for arch, cfg, shape in iter_cells(args.arch, args.shape):
        for mesh_name, mesh in meshes:
            tag = f"{arch} x {shape} x {mesh_name}"
            try:
                if arch == "groot-gnn":
                    cell = build_groot_cell(cfg, shape, mesh)
                else:
                    cell = build_cell(cfg, shape, mesh)
                rec = run_cell(cell, mesh, mesh_name)
                m = rec["memory_analysis"]
                per_dev = (
                    m.get("argument_size_in_bytes", 0)
                    + m.get("temp_size_in_bytes", 0)
                ) / 1e9
                print(
                    f"[ok] {tag:64s} compile={rec['timing']['compile_s']:7.1f}s "
                    f"args+temp/dev={per_dev:7.2f} GB "
                    f"dotTF/dev={rec['hlo']['dot_flops_per_device']/1e12:9.3f} "
                    f"collGB/dev={rec['hlo']['collective_bytes_per_device']/1e9:8.3f}",
                    flush=True,
                )
            except Exception as e:  # noqa: BLE001
                failures.append((tag, repr(e)))
                print(f"[FAIL] {tag}: {e}", flush=True)
                traceback.print_exc()

    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for t, e in failures:
            print(" ", t, e)
        raise SystemExit(1)
    print("\nall dry-run cells compiled.")


if __name__ == "__main__":
    main()
