"""Serving launcher: batched request loop over prefill + decode steps.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --smoke \
        --requests 8 --max-new 32

A deliberately small but production-shaped server core:
  * request queue -> fixed-batch admission (pad/roll),
  * one jitted prefill per admitted batch, jitted per-token decode,
  * per-sequence stop handling (EOS or budget), slot recycling,
  * throughput/latency accounting.

On a real cluster the same loop runs under the production mesh with the
dry-run's serve shardings (see launch/steps.build_cell "decode").
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.zoo.configs import get_config
from repro.zoo.configs.base import materialize, model_spec_tree
from repro.zoo.serving.decode import make_prefill_step, make_serve_step


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    out: Optional[np.ndarray] = None
    t_submit: float = 0.0
    t_done: float = 0.0


class BatchServer:
    """Fixed-batch serving core (continuous-batching-lite: a finished
    sequence's slot keeps decoding pad tokens until the batch drains —
    the production upgrade is slot-level admission, same step fns)."""

    def __init__(self, cfg, params, *, batch: int, max_seq: int):
        self.cfg, self.params = cfg, params
        self.batch, self.max_seq = batch, max_seq
        self.prefill = jax.jit(make_prefill_step(cfg, max_seq))
        self.decode = jax.jit(make_serve_step(cfg))

    def serve_batch(self, reqs: list) -> list:
        assert len(reqs) <= self.batch
        plen = max(len(r.prompt) for r in reqs)
        toks = np.zeros((self.batch, plen), np.int32)
        for i, r in enumerate(reqs):
            toks[i, plen - len(r.prompt):] = r.prompt  # left-pad
        last_logits, cache = self.prefill(self.params, jnp.asarray(toks))
        tok = jnp.argmax(last_logits, axis=-1)[:, None].astype(jnp.int32)
        max_new = max(r.max_new for r in reqs)
        outs = [tok]
        for _ in range(max_new - 1):
            tok, _, cache = self.decode(self.params, cache, tok)
            outs.append(tok)
        gen = np.asarray(jnp.concatenate(outs, axis=1))
        now = time.perf_counter()
        for i, r in enumerate(reqs):
            r.out = gen[i, : r.max_new]
            r.t_done = now
        return reqs


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=24)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    params = materialize(model_spec_tree(cfg), jax.random.key(0), jnp.float32)
    server = BatchServer(
        cfg, params, batch=args.batch,
        max_seq=args.prompt_len + args.max_new + 1,
    )
    rng = np.random.default_rng(0)
    queue = [
        Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, args.prompt_len).astype(np.int32),
            max_new=args.max_new,
            t_submit=time.perf_counter(),
        )
        for i in range(args.requests)
    ]
    t0 = time.perf_counter()
    done: list = []
    while queue:
        batch, queue = queue[: args.batch], queue[args.batch :]
        done += server.serve_batch(batch)
    dt = time.perf_counter() - t0
    n_tok = sum(len(r.out) for r in done)
    lat = [r.t_done - r.t_submit for r in done]
    print(
        f"served {len(done)} requests / {n_tok} tokens in {dt:.2f}s "
        f"({n_tok/dt:.1f} tok/s incl. compile); "
        f"latency p50={np.percentile(lat,50):.2f}s p95={np.percentile(lat,95):.2f}s"
    )


if __name__ == "__main__":
    main()
