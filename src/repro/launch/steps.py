"""Step builders + sharding assignments for the launcher and dry-run.

For every (arch, shape) cell this module produces:
  * the step function (train_step / prefill_step / serve_step),
  * abstract input trees (ShapeDtypeStruct — no allocation),
  * in/out shardings (NamedSharding trees from the logical rules).

Memory plans (DESIGN.md §6):
  * params are stored f32 (the fp32 master) and cast to bf16 at use;
  * train cells shard params/grads/opt-state over BOTH mesh axes
    (TP over "model" + FSDP over "data") — v5e 16 GB/chip demands it for
    the 67B/235B/400B archs and it is strictly better for the small ones;
  * the 235B/400B archs use int8 blockwise Adam moments (AdamW8bit);
  * serve cells hold bf16 weights; TP-only for <=11B dense archs,
    TP+FSDP for the giants.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.zoo.configs.base import ModelConfig, abstract, model_spec_tree
from repro.zoo.configs.shapes import SHAPES, input_specs
from repro.zoo.models.transformer import init_cache_tree
from repro.zoo.serving.decode import make_prefill_step, make_serve_step
from repro.sharding.rules import make_rules, partition_spec, tree_shardings
from repro.training import optimizer as opt_mod
from repro.training.train_step import make_train_step

INT8_OPT_ARCHS = {"llama4-maverick-400b-a17b", "qwen3-moe-235b-a22b"}
# sequence-parallel residuals: only where saved-activation memory demands
# it (see sharding.rules.make_rules docstring + EXPERIMENTS.md §Perf)
SP_TRAIN_ARCHS = set()  # measured: SP regressed collectives on every arch (see §Perf)
FSDP_SERVE_ARCHS = {
    "deepseek-67b", "llama4-maverick-400b-a17b", "qwen3-moe-235b-a22b",
}
# train_4k grad-accumulation per arch.  Each microbatch re-gathers the
# FSDP weight shards (all-gather per layer), so fewer microbatches directly
# divides the collective term; SP-sharded residuals keep activations small
# enough to afford it.
MICROBATCHES = {
    "default": 8,
    "deepseek-67b": 8,
    "llama4-maverick-400b-a17b": 4,
    "qwen3-moe-235b-a22b": 4,
}
# grouped remat (scan-over-scan checkpointing): residual saved once per G
# super-blocks -> sqrt(L)-ish saved-activation memory at unchanged
# recompute; replaces SP residual sharding (11-24x collective regression).
REMAT_GROUP = {
    "default": 1,
    "deepseek-67b": 10,          # n_super=95 -> 9 groups + tail 5
    "llama4-maverick-400b-a17b": 6,   # n_super=24
    "qwen3-moe-235b-a22b": 10,   # n_super=94 -> 9 groups + tail 4
}


def batch_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _batch_sharding(mesh: Mesh, shape):
    """Shard dim 0 over the batch mesh axes when divisible."""
    axes = batch_axes(mesh)
    size = int(np.prod([mesh.shape[a] for a in axes]))
    spec = [None] * len(shape)
    if shape[0] % size == 0:
        spec[0] = axes if len(axes) > 1 else axes[0]
    return NamedSharding(mesh, P(*spec))


# ---------------------------------------------------------------------------
# Cache shardings (path-keyed logical axes)
# ---------------------------------------------------------------------------

_CACHE_AXES = {
    "k": ("batch", "kv_seq", None, None),
    "v": ("batch", "kv_seq", None, None),
    "pos": (),
    "ck": ("batch", None, None, None),
    "cv": ("batch", None, None, None),
    "s": ("batch", None, None, None),       # rwkv state
    "x_prev": ("batch", None),
    "ffn_prev": ("batch", None),
    "h": ("batch", None),                   # rglru state
    "conv": ("batch", None, None),
}


def cache_shardings(cache_avals, mesh: Mesh, rules: dict):
    def leaf_sharding(path, leaf):
        key = None
        for entry in reversed(path):
            name = getattr(entry, "name", None) or getattr(entry, "key", None)
            if isinstance(name, str) and name in _CACHE_AXES:
                key = name
                break
        axes = _CACHE_AXES.get(key, ())
        axes = tuple(axes)
        if len(axes) == leaf.ndim - 1:
            axes = (None,) + axes  # stacked super-block leading dim
        elif len(axes) != leaf.ndim:
            axes = (None,) * leaf.ndim
        return NamedSharding(mesh, partition_spec(leaf.shape, axes, mesh, rules))

    return jax.tree_util.tree_map_with_path(leaf_sharding, cache_avals)


# ---------------------------------------------------------------------------
# Optimizer-state shardings
# ---------------------------------------------------------------------------

def opt_state_shardings(opt_state_avals, param_shardings, mesh: Mesh):
    """m/v like the params (Q8 moments are parameter-shaped, so the q
    tensor takes the param sharding verbatim and the (...,1) scale takes
    it minus the last dim); step scalar replicated."""
    rep = NamedSharding(mesh, P())

    def per_leaf(aval, psh):
        if isinstance(aval, opt_mod.Q8):
            spec = list(psh.spec) + [None] * (aval.q.ndim - len(psh.spec))
            scale_spec = spec[:-1] + [None]
            return opt_mod.Q8(
                q=NamedSharding(mesh, P(*spec)),
                scale=NamedSharding(mesh, P(*scale_spec)),
            )
        return psh

    def map_moment(avals):
        return jax.tree.map(
            per_leaf, avals, param_shardings,
            is_leaf=lambda x: isinstance(x, opt_mod.Q8),
        )

    return opt_mod.AdamWState(
        step=rep,
        m=map_moment(opt_state_avals.m),
        v=map_moment(opt_state_avals.v),
    )


# ---------------------------------------------------------------------------
# Cell construction
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    step_fn: Any
    args: tuple            # abstract inputs
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple = ()
    static_meta: dict = dataclasses.field(default_factory=dict)


def make_optimizer(arch: str):
    if arch in INT8_OPT_ARCHS:
        return opt_mod.AdamW8bit(lr=3e-4, weight_decay=0.1)
    return opt_mod.AdamW(lr=3e-4, weight_decay=0.1)


def build_cell(cfg: ModelConfig, shape_name: str, mesh: Mesh) -> Cell:
    sh = SHAPES[shape_name]
    spec_tree = model_spec_tree(cfg)
    rules_fsdp = make_rules(mesh, fsdp=True)
    rules_tp = make_rules(mesh, fsdp=False)
    specs = input_specs(cfg, shape_name)

    if sh.kind == "train":
        params_avals = abstract(spec_tree, jnp.float32)
        p_shard = tree_shardings(spec_tree, mesh, rules_fsdp)
        optimizer = make_optimizer(cfg.name)
        opt_avals = jax.eval_shape(optimizer.init, params_avals)
        o_shard = opt_state_shardings(opt_avals, p_shard, mesh)
        mb = MICROBATCHES.get(cfg.name, MICROBATCHES["default"])
        rg = REMAT_GROUP.get(cfg.name, REMAT_GROUP["default"])
        step = make_train_step(
            cfg, optimizer, microbatches=mb, remat=True, remat_group=rg
        )
        batch = {"tokens": specs["tokens"]}
        b_shard = {"tokens": _batch_sharding(mesh, specs["tokens"].shape)}
        if "enc_input" in specs:
            batch["enc_input"] = specs["enc_input"]
            b_shard["enc_input"] = _batch_sharding(mesh, specs["enc_input"].shape)

        def fn(params, opt_state, batch):
            return step(params, opt_state, batch)

        rep = NamedSharding(mesh, P())
        out_sh = (
            p_shard,
            o_shard,
            {"loss": rep, "grad_norm": rep},
        )
        return Cell(
            arch=cfg.name, shape=shape_name, step_fn=fn,
            args=(params_avals, opt_avals, batch),
            in_shardings=(p_shard, o_shard, b_shard),
            out_shardings=out_sh,
            donate_argnums=(0, 1),
            static_meta={"microbatches": mb, "optimizer": type(optimizer).__name__,
                         "fsdp": True, "sp": cfg.name in SP_TRAIN_ARCHS,
                         "remat_group": rg},
        )

    # serving cells: bf16 weights
    params_avals = abstract(spec_tree, jnp.bfloat16)
    fsdp = cfg.name in FSDP_SERVE_ARCHS
    p_shard = tree_shardings(spec_tree, mesh, rules_fsdp if fsdp else rules_tp)
    rules = rules_fsdp if fsdp else rules_tp

    if sh.kind == "prefill":
        step = make_prefill_step(cfg, sh.seq_len)
        args = [params_avals, specs["tokens"]]
        in_sh = [p_shard, _batch_sharding(mesh, specs["tokens"].shape)]
        if "enc_input" in specs:
            args.append(specs["enc_input"])
            in_sh.append(_batch_sharding(mesh, specs["enc_input"].shape))
        cache_avals = jax.eval_shape(
            lambda: init_cache_tree(cfg, sh.global_batch, sh.seq_len)
        )
        out_sh = (
            _batch_sharding(mesh, (sh.global_batch, cfg.vocab_size)),
            cache_shardings(cache_avals, mesh, rules),
        )
        return Cell(
            arch=cfg.name, shape=shape_name, step_fn=step,
            args=tuple(args), in_shardings=tuple(in_sh), out_shardings=out_sh,
            static_meta={"fsdp": fsdp},
        )

    # decode
    step = make_serve_step(cfg)
    cache_avals = specs["cache"]
    c_shard = cache_shardings(cache_avals, mesh, rules)
    tok_sh = _batch_sharding(mesh, specs["token"].shape)
    out_sh = (
        tok_sh,
        _batch_sharding(mesh, (sh.global_batch, cfg.vocab_size)),
        c_shard,
    )
    return Cell(
        arch=cfg.name, shape=shape_name, step_fn=step,
        args=(params_avals, cache_avals, specs["token"]),
        in_shardings=(p_shard, c_shard, tok_sh),
        out_shardings=out_sh,
        donate_argnums=(1,),
        static_meta={"fsdp": fsdp},
    )


# ---------------------------------------------------------------------------
# GROOT GNN cell (the paper's own architecture, 11th arch)
# ---------------------------------------------------------------------------

GROOT_SHAPES = {
    # name: (bits, batch) — node/edge counts follow the paper's table
    # (1024-bit CSA x batch 16 = 134,103,040 nodes / 268,140,544 edges).
    "verify_256b_bs16": (256, 16),
    "verify_1024b_bs16": (1024, 16),
}


def groot_graph_dims(bits: int, batch: int, num_partitions: int):
    """Padded per-partition sizes.  CSA node/edge counts scale ~ 6*bits^2
    (paper: 1024b x16 -> 134.1M nodes, 268.1M edges => 8.186M/16.37M per
    design).  Halo re-growth adds ~10% (paper §III-C) + padding slack."""
    nodes = int(8.0 * bits * bits * batch)
    edges = 2 * nodes
    n_per = nodes // num_partitions
    e_per = edges // num_partitions
    pad = lambda x: int(np.ceil(x * 1.3 / 1024.0)) * 1024  # halo + slack
    return pad(n_per), pad(e_per)


def build_groot_cell(gcfg, shape_name: str, mesh: Mesh) -> Cell:
    from repro.core import gnn

    bits, batch = GROOT_SHAPES[shape_name]
    n_dev = int(np.prod(list(mesh.shape.values())))
    parts = n_dev  # one re-grown partition per device
    n_sub, e_sub = groot_graph_dims(bits, batch, parts)
    cfg = gcfg.gnn

    params_avals = jax.eval_shape(
        lambda: gnn.init_params(cfg, jax.random.key(0))
    )
    f32, i32 = jnp.float32, jnp.int32
    bf16 = jnp.bfloat16  # inference dtype: halves the HBM traffic of the
    # memory-bound SpMM (beyond-paper opt; §Perf groot iteration)
    batch_avals = {
        "x": jax.ShapeDtypeStruct((parts, n_sub, cfg.in_features), bf16),
        "edge_src": jax.ShapeDtypeStruct((parts, e_sub), i32),
        "edge_dst": jax.ShapeDtypeStruct((parts, e_sub), i32),
        "edge_inv": jax.ShapeDtypeStruct((parts, e_sub), jnp.bool_),
        "edge_slot": jax.ShapeDtypeStruct((parts, e_sub), jnp.uint8),
        "core_mask": jax.ShapeDtypeStruct((parts, n_sub), jnp.bool_),
    }
    all_axes = tuple(mesh.axis_names)
    part_spec = lambda nd: NamedSharding(mesh, P(all_axes, *([None] * (nd - 1))))
    b_shard = {k: part_spec(v.ndim) for k, v in batch_avals.items()}
    rep = NamedSharding(mesh, P())

    def infer_step(params, batch):
        params16 = jax.tree.map(lambda a: a.astype(bf16), params)

        def one(x, es, ed, ei, sl, mask):
            logits = gnn.forward(
                params16, x, es, ed, ei.astype(bf16) > 0.5, sl.astype(bf16),
                num_nodes=n_sub,
            )
            pred = jnp.argmax(logits, axis=-1).astype(i32)
            return jnp.where(mask, pred, -1)

        return jax.vmap(one)(
            batch["x"], batch["edge_src"], batch["edge_dst"],
            batch["edge_inv"], batch["edge_slot"], batch["core_mask"],
        )

    return Cell(
        arch="groot-gnn", shape=shape_name, step_fn=infer_step,
        args=({k: v for k, v in jax.tree.map(lambda x: x, params_avals).items()}
              if isinstance(params_avals, dict) else params_avals,
              batch_avals),
        in_shardings=(jax.tree.map(lambda _: rep, params_avals), b_shard),
        out_shardings=part_spec(2),
        static_meta={"bits": bits, "batch": batch, "partitions": parts,
                     "nodes_per_part": n_sub, "edges_per_part": e_sub},
    )
