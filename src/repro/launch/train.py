"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --smoke \
        --steps 50 --ckpt-dir /tmp/ckpt

Runs the fault-tolerant loop (heartbeats, straggler EWMA, async
checkpoints, resume-on-restart) on whatever devices exist; on a real
TPU deployment the same entry point runs under the production mesh
(--mesh pod|multipod).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.zoo.configs import get_config
from repro.zoo.configs.base import materialize, model_spec_tree
from repro.distributed.fault_tolerance import ResilientLoop
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.sharding.rules import make_rules, tree_shardings, use_sharding
from repro.training import optimizer as opt_mod
from repro.training.data import TokenStream, TokenStreamConfig
from repro.training.train_step import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", default="host", choices=("host", "pod", "multipod"))
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.mesh == "host":
        mesh = make_host_mesh()
    else:
        mesh = make_production_mesh(multi_pod=args.mesh == "multipod")
    rules = make_rules(mesh, fsdp=True)
    spec_tree = model_spec_tree(cfg)
    p_shard = tree_shardings(spec_tree, mesh, rules)

    optimizer = opt_mod.AdamW(lr=args.lr, weight_decay=0.1)
    step_fn = make_train_step(
        cfg, optimizer, microbatches=args.microbatches, remat=True
    )

    with use_sharding(mesh, fsdp=True):
        params = materialize(spec_tree, jax.random.key(0), jnp.float32)
        params = jax.device_put(params, p_shard)
        opt_state = optimizer.init(params)

        jitted = jax.jit(step_fn, donate_argnums=(0, 1))

        def loop_step(state, batch):
            params, opt_state = state
            b = {"tokens": jnp.asarray(batch)}
            if cfg.encoder_seq or cfg.cross_seq:
                b["enc_input"] = jnp.zeros(
                    (batch.shape[0], cfg.encoder_seq or cfg.cross_seq, cfg.d_model),
                    jnp.bfloat16,
                )
            params, opt_state, metrics = jitted(params, opt_state, b)
            return (params, opt_state), metrics

        stream = TokenStream(
            TokenStreamConfig(
                vocab_size=cfg.vocab_size,
                seq_len=args.seq_len,
                global_batch=args.global_batch,
            )
        )
        loop = ResilientLoop(
            loop_step,
            (params, opt_state),
            ckpt_dir=args.ckpt_dir,
            ckpt_every=args.ckpt_every,
        )
        if loop.resumed:
            print(f"resumed from step {loop.step}")

        t0 = time.perf_counter()
        batches = (stream.batch_at(s) for s in range(loop.step, args.steps))
        for step, metrics in loop.run(batches, steps=args.steps):
            if step % args.log_every == 0:
                dt = time.perf_counter() - t0
                print(
                    f"step {step:5d} loss {float(metrics['loss']):.4f} "
                    f"gnorm {float(metrics['grad_norm']):.3f} ({dt:.1f}s)",
                    flush=True,
                )
        if loop.stragglers:
            print(f"straggler events: {len(loop.stragglers)}")
    print("done.")


if __name__ == "__main__":
    main()
