"""Feature ablation (paper §III-B claim): GROOT's 4-bit node features
(PI/PO distinguished + per-slot input polarity) vs GAMORA's 3 features
(type, #inverted, #fanins — PI/PO collapsed).

The paper argues the richer embedding generalises better from the 8-bit
training design to larger/mapped designs.  Both models share the GNN,
training protocol and evaluation designs; only the input embedding
differs.

    PYTHONPATH=src python -m benchmarks.bench_features [--quick]
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import print_table, save_table
from repro.core import aig as A
from repro.core import gnn
from repro.core.features import gamora_features, groot_features


def _train(feature_fn, in_features: int, dataset: str, bits: int, epochs: int):
    design = A.make_design(dataset, bits)
    feats = feature_fn(design)
    batch = gnn.make_batch(design, feats, design.label.astype(np.int32))
    cfg = gnn.GNNConfig(in_features=in_features)
    params = gnn.init_params(cfg, jax.random.key(0))
    params, _ = gnn.train(params, batch, epochs=epochs)
    return params


def _eval(params, feature_fn, dataset: str, bits: int) -> float:
    design = A.make_design(dataset, bits)
    pred = gnn.predict(params, design, feature_fn(design))
    return float((pred == design.label).mean())


def run(eval_sets, epochs=300):
    # paper protocol: train on the SAME family's 8-bit design, infer on
    # larger designs of that family (Fig. 6 caption)
    trained: dict = {}
    rows = []
    for ds, bits in eval_sets:
        if ds not in trained:
            trained[ds] = (
                _train(groot_features, 4, ds, 8, epochs),
                _train(gamora_features, 3, ds, 8, epochs),
            )
        p_groot, p_gamora = trained[ds]
        a_groot = _eval(p_groot, groot_features, ds, bits)
        a_gamora = _eval(p_gamora, gamora_features, ds, bits)
        rows.append(
            {
                "dataset": ds,
                "bits": bits,
                "groot_4feat": round(a_groot, 4),
                "gamora_3feat": round(a_gamora, 4),
                "delta_%": round(100 * (a_groot - a_gamora), 2),
            }
        )
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)
    if args.quick:
        rows = run([("csa", 16), ("mapped", 16)], epochs=200)
    else:
        rows = run(
            [("csa", 16), ("csa", 32), ("booth", 16), ("mapped", 16),
             ("mapped", 32)],
            epochs=300,
        )
    print_table("feature ablation: GROOT 4-bit vs GAMORA 3-feat (§III-B)", rows)
    save_table("features", rows)
    return rows


if __name__ == "__main__":
    main()
