"""Failure-domain chaos gates: seeded fault injection, end to end.

Three probe-gated scenarios (CI runs this suite in the full lane; the
fast lane smoke-tests the same plan grammar via ``$REPRO_FAULT_PLAN`` on
the quickstart):

  * **chaos burst** (the acceptance workload): a 16-ticket mixed-width
    burst through the batched service under a seeded FaultPlan — 20%
    transient device-launch failures plus a deterministic fatal on the
    one poisoned design.  Gate: every well-formed ticket completes with
    status/accuracy/verdict identical to a fault-free baseline run of
    the same burst; the poisoned ticket fails alone with an attributed
    name and ``failed_stage``; every wait is bounded (no hangs).
  * **resume**: a streamed verify killed mid-run by an injected fatal
    restarts from the partition journal — strictly fewer partitions
    re-execute, the final verdict matches the uninterrupted run, and the
    journal directory is reclaimed on completion.
  * **overhead**: with no plan installed a fault site is a single
    attribute probe — gated at well under a microsecond per fire, so the
    instrumented hot paths stay inside the stack's <5% observability
    overhead budget.

Retry/bisection *counts* are recorded but deliberately not gated:
device-call ordering varies with thread timing, so the per-call
probability draws are not run-stable.  The poisoned outcome IS
deterministic (``match=poison`` fires on every launch that touches it).
"""
from __future__ import annotations

import argparse
import contextlib
import dataclasses
import tempfile
import time
from pathlib import Path

from benchmarks.common import make_session, print_table, save_table, trained_params

#: the seeded chaos plan the burst gate runs under (and the fast-lane CI
#: smoke exports via $REPRO_FAULT_PLAN)
CHAOS_PLAN = (
    "service.device:p=0.2,kind=transient,seed=7;"
    "service.device:every=1,match=poison,kind=fatal"
)


def _burst_specs(quick: bool) -> list:
    """The well-formed half of the burst: mixed families and widths,
    distinct seeds (so nothing coalesces or cache-hits)."""
    if quick:
        return [("csa", b, s) for b in (6, 8) for s in (0, 1)] + \
               [("booth", 6, s) for s in (0, 1, 2)]
    return [("csa", b, s) for b in (6, 8, 10) for s in (0, 1, 2)] + \
           [("booth", b, s) for b in (6, 8, 10) for s in (0, 1)]


def _poisoned_design():
    from repro.core import aig as A

    d = A.csa_multiplier(6)
    return dataclasses.replace(d, name="poison_csa6")


def _run_burst(params, specs, poison, *, plan=None, deadline_s=120.0):
    """Submit the full burst (well-formed specs + the poisoned design)
    through a fresh service engine; returns (good results in submission
    order, poison result, row)."""
    from repro import faults

    ctx = faults.injected(plan) if plan else contextlib.nullcontext()
    with make_session(params, num_partitions=1, capacity=4,
                      prepare_workers=4, launch_retries=6,
                      retry_backoff_s=0.01) as sess:
        with ctx:
            t0 = time.perf_counter()
            tickets = [
                sess.submit(dataset=fam, bits=bits, seed=seed,
                            deadline_s=deadline_s)
                for fam, bits, seed in specs
            ]
            t_poison = sess.submit(design=poison, seed=999,
                                   deadline_s=deadline_s)
            good = [sess.result(t, timeout=600) for t in tickets]
            bad = sess.result(t_poison, timeout=600)
            wall = time.perf_counter() - t0
        fails = [f for f in sess.flights(failures_only=True)
                 if f.name == "poison_csa6"]
        # per-session registry, fresh at construction — raw reads ARE deltas
        counters = sess.obs.metrics.snapshot()["counters"]
    row = {
        "mode": "chaos" if plan else "baseline",
        "requests": len(specs) + 1,
        "wall_s": wall,
        "errors": sum(r.status == "error" for r in good) +
                  (bad.status == "error"),
        "retries": counters.get("service.retries", 0),
        "bisections": counters.get("service.bisections", 0),
        "deadline_exceeded": counters.get("service.deadline_exceeded", 0),
        "worker_deaths": counters.get("service.worker_deaths", 0),
    }
    return good, bad, fails, row


def _outcome(r) -> tuple:
    return (r.status, round(float(r.accuracy), 12), r.verdict)


def chaos_burst_gate(params, quick: bool) -> list:
    specs = _burst_specs(quick)
    base_good, base_bad, _, base_row = _run_burst(
        params, specs, _poisoned_design()
    )
    assert base_row["errors"] == 0, (
        f"fault-free baseline must be clean, got "
        f"{[r.error for r in base_good + [base_bad] if r.error]}"
    )

    good, bad, fails, row = _run_burst(
        params, specs, _poisoned_design(), plan=CHAOS_PLAN
    )
    mismatches = [
        (spec, _outcome(b), _outcome(c))
        for spec, b, c in zip(specs, base_good, good)
        if _outcome(b) != _outcome(c)
    ]
    assert not mismatches, (
        f"chaos gate: {len(mismatches)} well-formed tickets diverged from "
        f"the fault-free run: {mismatches[:3]}"
    )
    assert bad.status == "error" and "FatalFault" in bad.error, (
        f"chaos gate: poisoned design must fail, got {bad.status!r} "
        f"({bad.error!r})"
    )
    assert bad.name == "poison_csa6", bad.name
    assert fails and fails[-1].failed_stage == "infer", (
        f"chaos gate: poisoned failure not attributed in the flight ring "
        f"(records: {fails})"
    )
    assert row["errors"] == 1, (
        f"chaos gate: blast radius leaked — {row['errors']} errors for one "
        f"poisoned design"
    )
    assert row["worker_deaths"] == 0 and row["deadline_exceeded"] == 0
    return [base_row, row]


def resume_gate(params, quick: bool) -> list:
    from repro import faults

    bits = 10 if quick else 12
    rows = []
    with tempfile.TemporaryDirectory(prefix="repro_chaos_ckpt_") as ckpt:
        def session():
            return make_session(params, num_partitions=6, bits=bits,
                                stream_capacity=1, stream_prefetch=0,
                                checkpoint_dir=ckpt)

        t0 = time.perf_counter()
        with session() as sess:
            want = sess.verify(dataset="csa", bits=bits, use_cache=False)
        total = want.exec_stats["partitions"]
        rows.append({"mode": "uninterrupted", "partitions": total,
                     "resumed": 0, "status": want.status,
                     "wall_s": time.perf_counter() - t0})
        assert total >= 3, f"resume gate premise: need >=3 launches, got {total}"

        # the "crash": a fatal fault partway through the launch sequence
        with session() as sess, faults.injected("exec.launch:nth=2,kind=fatal"):
            try:
                sess.verify(dataset="csa", bits=bits, use_cache=False)
                raise AssertionError("injected fatal did not surface")
            except faults.FatalFault:
                pass
        committed = len(list(Path(ckpt).glob("*/part_*.npz")))
        assert 0 < committed < total, (
            f"resume gate premise: crash must land mid-run "
            f"({committed}/{total} committed)"
        )

        t0 = time.perf_counter()
        with session() as sess:
            got = sess.verify(dataset="csa", bits=bits, use_cache=False)
        resumed = got.exec_stats["resumed_partitions"]
        rows.append({"mode": "resumed", "partitions": got.exec_stats["partitions"],
                     "resumed": resumed, "status": got.status,
                     "wall_s": time.perf_counter() - t0})
        assert resumed == committed, (resumed, committed)
        assert got.exec_stats["partitions"] == total - resumed, (
            "resume gate: restart must execute ONLY the unfinished partitions"
        )
        assert _outcome(got) == _outcome(want), (
            f"resume gate: verdict drift {_outcome(got)} vs {_outcome(want)}"
        )
        assert not any(Path(ckpt).iterdir()), "journal not reclaimed"
    return rows


def overhead_gate(quick: bool) -> list:
    from repro import faults

    faults.uninstall()
    n = 50_000 if quick else 200_000
    fire = faults.fire
    t0 = time.perf_counter()
    for _ in range(n):
        fire("exec.launch")
    total = time.perf_counter() - t0
    ns = total / n * 1e9
    assert ns < 2000, (
        f"overhead gate: inactive fault site costs {ns:.0f} ns/fire "
        f"(budget: 2000 ns — the site must be a cheap no-op probe)"
    )
    return [{"mode": "inactive-site", "fires": n, "ns_per_fire": ns}]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)

    params = trained_params("csa", 8)

    svc_rows = chaos_burst_gate(params, args.quick)
    res_rows = resume_gate(params, args.quick)
    ovh_rows = overhead_gate(args.quick)

    print_table("chaos burst: seeded faults vs fault-free baseline", svc_rows)
    print_table("crash-safe resume (partition journal)", res_rows)
    print_table("inactive fault-site overhead", ovh_rows)
    save_table("chaos_service", svc_rows)
    save_table("chaos_resume", res_rows)
    save_table("chaos_overhead", ovh_rows)
    print(f"\nchaos burst survived: {svc_rows[1]['requests'] - 1} clean under "
          f"{CHAOS_PLAN!r} ({svc_rows[1]['retries']} retries, "
          f"{svc_rows[1]['bisections']} bisections); resume re-ran "
          f"{res_rows[1]['partitions']}/{res_rows[0]['partitions']} "
          f"partitions; inactive site {ovh_rows[0]['ns_per_fire']:.0f} ns")


if __name__ == "__main__":
    main()
