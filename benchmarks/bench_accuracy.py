"""Paper Figs. 6 & 7: verification accuracy vs #partitions, with/without
boundary edge re-growth, across the CSA / Booth / mapped / FPGA datasets.

    PYTHONPATH=src python -m benchmarks.bench_accuracy [--quick]
"""
from __future__ import annotations

import argparse

from benchmarks.common import make_session, print_table, save_table, trained_params


def run(datasets, bits_list, partitions, train_bits=8, epochs=300):
    rows = []
    for ds in datasets:
        sess = make_session(trained_params(ds, train_bits, epochs), dataset=ds)
        for bits in bits_list:
            for parts in partitions:
                for regrow in ((True,) if parts == 1 else (True, False)):
                    r = sess.options(
                        num_partitions=parts, regrow=regrow
                    ).verify(bits=bits, verify=False, use_cache=False)
                    rows.append(
                        {
                            "dataset": ds,
                            "bits": bits,
                            "partitions": parts,
                            "regrow": regrow,
                            "accuracy": round(r.accuracy, 4),
                            "boundary_frac": round(r.boundary_edge_frac, 4),
                        }
                    )
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--dataset", default=None)
    args = ap.parse_args(argv)
    if args.quick:
        datasets = [args.dataset] if args.dataset else ["csa", "booth"]
        rows = run(datasets, [16], [1, 4, 8], epochs=200)
    else:
        datasets = [args.dataset] if args.dataset else [
            "csa", "booth", "mapped", "fpga",
        ]
        rows = run(datasets, [16, 32], [1, 2, 4, 8, 16], epochs=300)
    print_table("accuracy vs partitions (paper Fig. 6/7)", rows)
    save_table("accuracy", rows)
    # headline check: re-growth recovers accuracy (paper: up to +8.7%)
    rec = {}
    for r in rows:
        key = (r["dataset"], r["bits"], r["partitions"])
        rec.setdefault(key, {})[r["regrow"]] = r["accuracy"]
    gains = [
        v[True] - v[False] for v in rec.values() if True in v and False in v
    ]
    if gains:
        print(f"\nmax re-growth recovery: +{max(gains)*100:.2f}% accuracy")
    return rows


if __name__ == "__main__":
    main()
