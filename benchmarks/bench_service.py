"""Service throughput/latency vs the one-shot pipeline.

Two workloads, each run through both front doors:

  * **mixed**: same-family designs at mixed bit widths, each wave
    re-submitted (the duplicated traffic a verification farm produces).
    One-shot re-runs the full pipeline per request; the service packs
    shape buckets and serves repeats from the structural-hash cache.
  * **burst** (the acceptance workload): waves of >= 8 *concurrent*
    identical requests — independent clients resubmitting the same
    revision to a shared endpoint.  One-shot models those clients each
    paying the full pipeline (they share no cache); the service warms
    its bucket ahead of time and coalesces the in-flight duplicates
    into one execution.

Compile counts are real probe readings, never sentinels: one-shot rows
report the ``gnn.forward_traces`` process-counter delta across the run;
service rows report the BucketRunner trace probe, plus the post-warmup
``cold_compiles`` counter the acceptance criterion pins at zero.

Gates asserted here (CI runs this suite in the full lane):

  * service >= one-shot throughput on the mixed workload;
  * service >= 3x one-shot throughput on the burst workload;
  * burst p95 latency <= 2x the one-shot warm solo p50;
  * zero cold compiles after warmup (probe-gated).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import make_session, print_table, save_table, trained_params


def _mixed_workload(quick: bool) -> list[list[tuple[str, int, int]]]:
    """Waves of same-family mixed-width requests; later waves repeat the
    first (the duplicate re-submissions cache hits feed on)."""
    widths = [6, 8, 10] if quick else [6, 8, 10, 12, 14, 16]
    repeats = 2 if quick else 3
    return [[("csa", b, 0) for b in widths] for _ in range(repeats)]


def _burst_workload(quick: bool) -> list[list[tuple[str, int, int]]]:
    """Waves of 8 concurrent identical requests (same design, same seed
    within a wave; a fresh seed per wave so waves never hit the result
    cache — every wave exercises in-flight coalescing, not the LRU)."""
    waves = 2 if quick else 3
    return [[("csa", 8, w)] * 8 for w in range(waves)]


def _percentiles(lat: list[float]) -> tuple[float, float]:
    return (
        float(np.percentile(lat, 50)) * 1e3,
        float(np.percentile(lat, 95)) * 1e3,
    )


def _row(mode, results_or_n, wall, lat, compiles, cold, hits, coalesced):
    n = results_or_n if isinstance(results_or_n, int) else len(results_or_n)
    p50, p95 = _percentiles(lat)
    return {
        "mode": mode,
        "requests": n,
        "wall_s": wall,
        "req_per_s": n / wall,
        "p50_ms": p50,
        "p95_ms": p95,
        "compiles": compiles,
        "cold_compiles": cold,
        "cache_hits": hits,
        "coalesced": coalesced,
    }


def bench_one_shot(params, waves, num_partitions: int, *,
                   mode: str = "one-shot", warm: bool = False) -> dict:
    """Sequential ``Session.verify`` per request, no shared cache (each
    request models an independent client).  ``warm=True`` primes the jit
    shapes first, so the row measures serving latency, not compiles."""
    from repro.obs import REGISTRY

    sess = make_session(params, num_partitions=num_partitions)
    if warm:
        for fam, bits, _ in {(f, b, 0) for w in waves for (f, b, _) in w}:
            sess.verify(dataset=fam, bits=bits, seed=999, use_cache=False)
    traces0 = REGISTRY.counter("gnn.forward_traces").value
    lat = []
    t0 = time.perf_counter()
    for wave in waves:
        for fam, bits, seed in wave:
            t1 = time.perf_counter()
            sess.verify(dataset=fam, bits=bits, seed=seed, use_cache=False)
            lat.append(time.perf_counter() - t1)
    wall = time.perf_counter() - t0
    compiles = REGISTRY.counter("gnn.forward_traces").value - traces0
    n = sum(len(w) for w in waves)
    return _row(mode, n, wall, lat, compiles, None, 0, 0)


def bench_service(params, waves, num_partitions: int, capacity: int, *,
                  mode: str, warmup_shapes=None) -> dict:
    results = []
    with make_session(
        params,
        num_partitions=num_partitions,
        capacity=capacity,
        warmup=warmup_shapes is not None,
        warmup_shapes=warmup_shapes,
    ) as sess:
        if warmup_shapes is not None:
            sess.warm()                      # eager engine + bucket grid
        t0 = time.perf_counter()
        for wave in waves:  # each wave's requests are in flight together
            tickets = [
                sess.submit(dataset=fam, bits=bits, seed=seed)
                for fam, bits, seed in wave
            ]
            results += [sess.result(t, timeout=600) for t in tickets]
        wall = time.perf_counter() - t0
        stats = sess.stats()["service"]
    assert all(r.status != "error" for r in results), [r.error for r in results]
    lat = [r.timings.get("total", 0.0) for r in results]
    n_buckets = len(stats["buckets"])
    assert stats["compile_count"] <= n_buckets + stats["warm_compiles"], (
        f"bucketing regression: {stats['compile_count']} compiles > "
        f"{n_buckets} buckets (+{stats['warm_compiles']} warm)"
    )
    coalesced = stats["obs"]["counters"].get("service.coalesced", 0)
    return _row(
        mode, results, wall, lat, stats["compile_count"],
        stats["cold_compiles"], stats["cache"].hits, coalesced,
    )


def _workload_buckets(waves, num_partitions: int) -> tuple:
    """The exact (n_pad, e_pad) bucket grid a workload's items land in —
    host-side prepare only, no device work.  This is the traffic profile
    a serving deployment would warm from."""
    from repro.core import pipeline as P
    from repro.service.bucketing import items_from_prepared

    shapes = set()
    for fam, bits, seed in {x for w in waves for x in w}:
        cfg = P.PipelineConfig(
            dataset=fam, bits=bits, num_partitions=num_partitions, seed=seed
        )
        prep = P.prepare(cfg, None)
        for it in items_from_prepared(0, prep):
            b = it.bucket()
            shapes.add((b.n_pad, b.e_pad))
    return tuple(sorted(shapes))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--partitions", type=int, default=2)
    args = ap.parse_args(argv)

    params = trained_params("csa", 8)

    # -- mixed-width farm traffic -------------------------------------------
    mixed = _mixed_workload(args.quick)
    rows = [bench_one_shot(params, mixed, args.partitions, mode="one-shot")]
    rows.append(bench_service(
        params, mixed, args.partitions, 4, mode="service(mixed,cap=4)",
        warmup_shapes=_workload_buckets(mixed, args.partitions),
    ))
    assert rows[1]["req_per_s"] >= rows[0]["req_per_s"], (
        f"service regression: {rows[1]['req_per_s']:.2f} req/s < one-shot "
        f"{rows[0]['req_per_s']:.2f} on the mixed workload"
    )

    # -- concurrent same-shape burst (the acceptance workload) --------------
    burst = _burst_workload(args.quick)
    one_warm = bench_one_shot(
        params, burst, 1, mode="one-shot(burst,warm)", warm=True
    )
    svc_burst = bench_service(
        params, burst, 1, 1, mode="service(burst)",
        warmup_shapes=_workload_buckets(burst, 1),
    )
    rows += [one_warm, svc_burst]

    speedup = svc_burst["req_per_s"] / one_warm["req_per_s"]
    assert speedup >= 3.0, (
        f"acceptance: service {svc_burst['req_per_s']:.2f} req/s is only "
        f"{speedup:.2f}x one-shot {one_warm['req_per_s']:.2f} on an 8-wide "
        f"concurrent burst (need >= 3x)"
    )
    assert svc_burst["p95_ms"] <= 2.0 * one_warm["p50_ms"], (
        f"acceptance: burst p95 {svc_burst['p95_ms']:.1f} ms > 2x one-shot "
        f"solo p50 {one_warm['p50_ms']:.1f} ms"
    )
    assert svc_burst["cold_compiles"] == 0, (
        f"acceptance: {svc_burst['cold_compiles']} cold compiles after "
        f"warmup (probe-gated zero)"
    )

    print_table("verification service vs one-shot pipeline", rows)
    save_table("service", rows)
    print(f"\nburst speedup vs one-shot: {speedup:.2f}x "
          f"(p95 {svc_burst['p95_ms']:.1f} ms vs solo p50 "
          f"{one_warm['p50_ms']:.1f} ms; "
          f"{svc_burst['coalesced']} coalesced, "
          f"{svc_burst['cold_compiles']} cold compiles after warmup)")


if __name__ == "__main__":
    main()
