"""Service throughput/latency vs the one-shot pipeline.

Workload: a stream of same-family designs at mixed bit widths, each
submitted several times (the duplicated traffic a verification farm
produces).  Reports:

  * one-shot: every request runs the full pipeline end to end
    (re-tracing the jitted GNN for every new graph shape);
  * service: shape-bucketed batching + structural-hash cache.

Also prints the compile-count probe — the acceptance criterion that N
same-family/different-width designs trigger at most ``num_buckets``
distinct jit compilations, with cache hits skipping inference entirely.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import make_session, print_table, save_table, trained_params


def _workload(quick: bool) -> list[list[tuple[str, int]]]:
    """Waves of same-family mixed-width requests; later waves repeat the
    first (the duplicate re-submissions cache hits feed on)."""
    widths = [6, 8, 10] if quick else [6, 8, 10, 12, 14, 16]
    repeats = 2 if quick else 3
    return [[("csa", b) for b in widths] for _ in range(repeats)]


def bench_one_shot(params, waves, num_partitions: int) -> dict:
    sess = make_session(params, num_partitions=num_partitions)
    lat = []
    t0 = time.perf_counter()
    for wave in waves:
        for fam, bits in wave:
            t1 = time.perf_counter()
            sess.verify(dataset=fam, bits=bits, use_cache=False)
            lat.append(time.perf_counter() - t1)
    wall = time.perf_counter() - t0
    n = sum(len(w) for w in waves)
    return {
        "mode": "one-shot",
        "requests": n,
        "wall_s": wall,
        "req_per_s": n / wall,
        "p50_ms": float(np.percentile(lat, 50)) * 1e3,
        "p95_ms": float(np.percentile(lat, 95)) * 1e3,
        "compiles": -1,
        "cache_hits": 0,
    }


def bench_service(params, waves, num_partitions: int, capacity: int) -> dict:
    results = []
    with make_session(
        params, num_partitions=num_partitions, capacity=capacity
    ) as sess:
        t0 = time.perf_counter()
        for wave in waves:  # each wave's requests are in flight together
            tickets = [
                sess.submit(dataset=fam, bits=bits) for fam, bits in wave
            ]
            results += [sess.result(t, timeout=600) for t in tickets]
        wall = time.perf_counter() - t0
        stats = sess.stats()["service"]
    assert all(r.status != "error" for r in results), [r.error for r in results]
    lat = [r.timings.get("total", 0.0) for r in results]
    n_buckets = len(stats["buckets"])
    assert stats["compile_count"] <= n_buckets, (
        f"bucketing regression: {stats['compile_count']} compiles > "
        f"{n_buckets} buckets"
    )
    return {
        "mode": f"service(cap={capacity})",
        "requests": len(results),
        "wall_s": wall,
        "req_per_s": len(results) / wall,
        "p50_ms": float(np.percentile(lat, 50)) * 1e3,
        "p95_ms": float(np.percentile(lat, 95)) * 1e3,
        "compiles": stats["compile_count"],
        "cache_hits": stats["cache"].hits,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--partitions", type=int, default=2)
    args = ap.parse_args(argv)

    params = trained_params("csa", 8)
    workload = _workload(args.quick)
    rows = [bench_one_shot(params, workload, args.partitions)]
    for capacity in (1, 2, 4):
        rows.append(bench_service(params, workload, args.partitions, capacity))
    print_table("verification service vs one-shot pipeline", rows)
    save_table("service", rows)
    speedup = rows[1]["req_per_s"] / rows[0]["req_per_s"]
    print(f"\nservice speedup vs one-shot (cap=1): {speedup:.2f}x; "
          f"compiles {rows[1]['compiles']} vs one per request shape")


if __name__ == "__main__":
    main()
