"""Partitioned streaming executor vs full-graph inference (repro.exec).

Three tables, mirroring the paper's scaling story:

  * ``partitioned_vs_k`` — full graph vs sequential per-partition loop vs
    streaming executor across k: modeled peak device memory, wall time,
    compile count (the executor compiles per BUCKET; the loop per
    subgraph shape).
  * ``regrow_accuracy`` — re-growth on/off core accuracy vs the
    full-graph run (paper Fig. 6's solid vs dashed lines).
  * ``scaling_headline`` — the acceptance row: a 256-bit CSA (~530k
    nodes) at k=16 must stream below 50% of the full-graph modeled
    memory with regrow=True accuracy within 0.1% of full-graph.

    PYTHONPATH=src python -m benchmarks.bench_partitioned [--quick]
"""
from __future__ import annotations

import argparse
import time

from benchmarks.common import print_table, save_table, trained_params
from repro.core import aig as A
from repro.core import gnn
from repro.core import pipeline as P
from repro.core.features import groot_features
from repro.core.partition import PARTITIONERS
from repro.core.regrowth import extract_partitions
from repro.exec import (
    StreamingExecutor,
    build_partition_plan,
    stream_predict_partitioned,
)

CAPACITY = 2


def _design(bits: int):
    d = A.csa_multiplier(bits)
    return d, d.to_edge_graph(), groot_features(d)


def bench_vs_k(params, bits: int, ks: list[int]) -> list[dict]:
    d, g, feats = _design(bits)
    cfg = gnn.GNNConfig()
    full_mem = P.memory_model_bytes(g.num_nodes, g.num_edges, cfg)

    t0 = time.perf_counter()
    pred_full = gnn.predict(params, g, feats, backend="ref")
    t_full = time.perf_counter() - t0
    acc_full = gnn.accuracy(pred_full, d.label)
    rows = [{
        "mode": "full", "k": 1, "peak_mem_mb": full_mem / 1e6,
        "mem_vs_full": 1.0, "runtime_s": t_full, "compiles": 1,
        "core_acc": acc_full,
    }]
    for k in ks:
        plan = build_partition_plan(g, k, partitioner="multilevel", seed=0)
        subs = list(plan.subgraphs)

        t0 = time.perf_counter()
        pred_loop = gnn.predict_partitioned_loop(
            params, subs, feats, g.num_nodes, "ref"
        )
        t_loop = time.perf_counter() - t0
        peak_loop = max(
            P.memory_model_bytes(sg.num_nodes, sg.num_edges, cfg) for sg in subs
        )
        rows.append({
            "mode": "loop", "k": k, "peak_mem_mb": peak_loop / 1e6,
            "mem_vs_full": peak_loop / full_mem, "runtime_s": t_loop,
            "compiles": len(subs), "core_acc": gnn.accuracy(pred_loop, d.label),
        })

        ex = StreamingExecutor(params, "ref", capacity=CAPACITY, prefetch=1)
        t0 = time.perf_counter()
        pred_stream = ex.run_plan(plan, feats)
        t_stream = time.perf_counter() - t0
        peak_stream = plan.peak_batch_memory_bytes(cfg, CAPACITY)
        assert (pred_stream == pred_loop).all(), "stream/loop divergence"
        rows.append({
            "mode": f"stream(cap={CAPACITY})", "k": k,
            "peak_mem_mb": peak_stream / 1e6,
            "mem_vs_full": peak_stream / full_mem, "runtime_s": t_stream,
            "compiles": ex.stats.compiles,
            "core_acc": gnn.accuracy(pred_stream, d.label),
        })
        assert ex.stats.compiles <= plan.num_buckets, "compile probe regression"
    return rows


def bench_regrow(params, bits_grid: list[int], k: int) -> list[dict]:
    """Fig. 6 style: no re-growth vs 1-hop (Algorithm 1) vs 2-hop."""
    rows = []
    for bits in bits_grid:
        d, g, feats = _design(bits)
        acc_full = gnn.accuracy(gnn.predict(params, g, feats, "ref"), d.label)
        part = PARTITIONERS["multilevel"](g, k, seed=0)
        accs = {}
        for label, regrow, hops in (
            ("noregrow", False, 1), ("regrow1", True, 1), ("regrow2", True, 2)
        ):
            subs = extract_partitions(g, part, regrow=regrow, hops=hops)
            pred = stream_predict_partitioned(
                params, subs, feats, g.num_nodes, "ref"
            )
            accs[label] = gnn.accuracy(pred, d.label)
        rows.append({
            "bits": bits, "k": k, "acc_full": acc_full,
            "acc_regrow1": accs["regrow1"], "acc_regrow2": accs["regrow2"],
            "acc_noregrow": accs["noregrow"],
            "regrow1_gap": acc_full - accs["regrow1"],
            "regrow2_gap": acc_full - accs["regrow2"],
            "noregrow_gap": acc_full - accs["noregrow"],
        })
    return rows


def bench_scaling_headline(params, bits: int = 256, k: int = 16) -> list[dict]:
    """Acceptance row: 2-hop re-growth holds accuracy within 0.1% of the
    full graph while the packed stream stays under half its memory."""
    d, g, feats = _design(bits)
    cfg = gnn.GNNConfig()
    full_mem = P.memory_model_bytes(g.num_nodes, g.num_edges, cfg)

    t0 = time.perf_counter()
    acc_full = gnn.accuracy(gnn.predict(params, g, feats, "ref"), d.label)
    t_full = time.perf_counter() - t0

    plan = build_partition_plan(g, k, hops=2, partitioner="multilevel", seed=0)
    ex = StreamingExecutor(params, "ref", capacity=CAPACITY, prefetch=1)
    t0 = time.perf_counter()
    pred = ex.run_plan(plan, feats)
    t_stream = time.perf_counter() - t0
    acc_stream = gnn.accuracy(pred, d.label)
    peak = plan.peak_batch_memory_bytes(cfg, CAPACITY)

    # ForwardPlan hoisting: modeled per-layer HBM traffic of the largest
    # packed launch (streamed batches inherit hoisted plans through
    # make_agg_pair, so the reduction applies per launch)
    traffic_pre = plan.peak_layer_traffic_bytes(cfg, CAPACITY, hoisted=False)
    traffic_post = plan.peak_layer_traffic_bytes(cfg, CAPACITY, hoisted=True)
    row = {
        "bits": bits, "k": k, "nodes": g.num_nodes,
        "full_mem_mb": full_mem / 1e6, "stream_peak_mb": peak / 1e6,
        "mem_vs_full": peak / full_mem,
        "acc_full": acc_full, "acc_stream": acc_stream,
        "acc_delta": abs(acc_full - acc_stream),
        "full_runtime_s": t_full, "stream_runtime_s": t_stream,
        "compiles": ex.stats.compiles, "num_buckets": plan.num_buckets,
        "bytes_h2d_mb": ex.stats.bytes_h2d / 1e6,
        "layer_traffic_prehoist_mb": traffic_pre / 1e6,
        "layer_traffic_hoisted_mb": traffic_post / 1e6,
        "traffic_reduction": 1.0 - traffic_post / max(traffic_pre, 1),
    }
    assert row["mem_vs_full"] < 0.5, (
        f"acceptance: streamed peak {row['mem_vs_full']:.1%} of full-graph "
        "memory (must be < 50%)"
    )
    assert row["acc_delta"] <= 1e-3, (
        f"acceptance: regrow=True accuracy delta {row['acc_delta']:.4%} "
        "(must be within 0.1% of full-graph)"
    )
    assert ex.stats.compiles <= plan.num_buckets
    return [row]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--headline-bits", type=int, default=256)
    args = ap.parse_args(argv)

    params = trained_params("csa", 8)

    bits = 16 if args.quick else 32
    ks = [2, 4, 8] if args.quick else [2, 4, 8, 16]
    rows = bench_vs_k(params, bits, ks)
    print_table(f"full vs partitioned (csa {bits}b, ref backend)", rows)
    save_table("partitioned_vs_k", rows)

    grid = [10, 12] if args.quick else [10, 12, 14, 16]
    rows = bench_regrow(params, grid, k=4)
    print_table("re-growth accuracy recovery (Fig. 6 style, k=4)", rows)
    save_table("regrow_accuracy", rows)

    rows = bench_scaling_headline(params, args.headline_bits, k=16)
    print_table(f"scaling headline (csa {args.headline_bits}b @ k=16)", rows)
    save_table("scaling_headline", rows)
    r = rows[0]
    print(
        f"\n{r['nodes']} nodes: streamed peak {r['stream_peak_mb']:.0f} MB "
        f"= {r['mem_vs_full']:.1%} of full-graph {r['full_mem_mb']:.0f} MB; "
        f"accuracy delta {r['acc_delta']:.4%} (regrow=True); "
        f"{r['compiles']} compiles for {r['num_buckets']} buckets"
    )


if __name__ == "__main__":
    main()
