"""Benchmark orchestrator: one suite per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--json]

Default is the quick pass (CI-sized); --full reproduces the wider grids.
``--json`` additionally writes one ``BENCH_<suite>.json`` per suite, both
under ``experiments/bench/`` and at the repo root — suite runtime, every
table the suite saved (rows carry the peak-memory model / compile-count
columns), and an embedded ``repro.obs`` report (per-stage wall times from
a suite-scoped tracer, the process-counter delta, plan-cache hit rate) —
so the bench trajectory accumulates machine-readable points run over run.

The multi-pod dry-run + roofline tables are separate entry points
(python -m repro.launch.dryrun / python -m repro.roofline.report) since
they re-initialise jax with 512 host devices.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--json", action="store_true",
                    help="write experiments/bench/BENCH_<suite>.json per suite")
    ap.add_argument("--suites", nargs="+", default=None,
                    help="run only the named suites (default: all)")
    args = ap.parse_args(argv)
    quick = [] if args.full else ["--quick"]

    from benchmarks import (
        bench_accuracy,
        bench_chaos,
        bench_features,
        bench_grouped,
        bench_memory,
        bench_partitioned,
        bench_service,
        bench_sharded,
        bench_spmm,
        bench_verification,
    )
    from benchmarks import common
    from repro.kernels.plan_cache import PLAN_CACHE
    from repro.obs import REGISTRY, Sampler, Tracer
    from repro.obs.flight import DUMP_DIR_ENV
    from repro.obs.regress import SCHEMA_VERSION, host_info

    if args.json:
        # failed tickets' flight records land next to the BENCH JSONs, so
        # CI's artifact upload carries the forensic trail too
        common.ART.mkdir(parents=True, exist_ok=True)
        os.environ.setdefault(DUMP_DIR_ENV, str(common.ART))

    t0 = time.time()
    suites = [
        ("accuracy", "accuracy (Fig. 6/7)", bench_accuracy.main),
        ("memory", "memory (Fig. 8 / Table II)", bench_memory.main),
        ("spmm", "spmm kernels (Fig. 9)", bench_spmm.main),
        ("grouped", "grouped multi-polarity spmm (PR 2)", bench_grouped.main),
        ("verification", "verification runtime (Fig. 10)", bench_verification.main),
        ("features", "feature ablation (§III-B)", bench_features.main),
        ("service", "verification service (repro.service)", bench_service.main),
        ("partitioned", "partitioned streaming executor (repro.exec)",
         bench_partitioned.main),
        ("chaos", "failure-domain chaos gates (repro.faults)",
         bench_chaos.main),
        ("sharded", "sharded mesh streaming (repro.mesh)",
         bench_sharded.main),
    ]
    if args.suites:
        known = {k for k, _, _ in suites}
        unknown = set(args.suites) - known
        if unknown:
            ap.error(f"unknown suites {sorted(unknown)} (known: {sorted(known)})")
        suites = [s for s in suites if s[0] in args.suites]
    failed = []
    for key, name, fn in suites:
        print(f"\n#### {name} ####", flush=True)
        common.drain_tables()
        pc0 = PLAN_CACHE.snapshot()
        reg0 = REGISTRY.snapshot()
        tracer = Tracer()
        # per-suite JSONL time series over the process registry (queue
        # depth, executor gauges, stage latencies) — uploaded by CI next
        # to the BENCH JSONs
        sampler = (
            Sampler(common.ART / f"SAMPLER_{key}.jsonl", REGISTRY,
                    interval_s=0.5)
            if args.json else None
        )
        t_suite = time.time()
        err = None
        try:
            # every Session the suite builds (trace=False) emits its spans
            # into this suite-scoped tracer via the active-tracer fallback
            with tracer.activate():
                if sampler is not None:
                    sampler.start()
                fn(quick)
        except Exception as e:  # noqa: BLE001
            err = repr(e)
            failed.append((name, err))
            print(f"[FAIL] {name}: {e}")
        finally:
            if sampler is not None:
                sampler.stop()
        if args.json:
            pc1 = PLAN_CACHE.snapshot()
            lookups = (pc1.hits - pc0.hits) + (pc1.builds - pc0.builds)
            common.ART.mkdir(parents=True, exist_ok=True)
            payload = {
                "schema": SCHEMA_VERSION,
                "host": host_info(),
                "suite": key,
                "title": name,
                "ok": err is None,
                "error": err,
                "runtime_s": time.time() - t_suite,
                "quick": bool(quick),
                "plan_cache": {
                    "builds": pc1.builds - pc0.builds,
                    "hits": pc1.hits - pc0.hits,
                },
                "report": {
                    "stages": tracer.summary(),
                    "counters": REGISTRY.delta(reg0),
                    # null, not 0.0, when the suite never touched the plan
                    # cache — "0% hit rate" and "idle cache" are different
                    # dashboard facts
                    "plan_cache_hit_rate": (
                        (pc1.hits - pc0.hits) / lookups if lookups else None
                    ),
                },
                "tables": common.drain_tables(),
            }
            for path in (common.ART / f"BENCH_{key}.json",
                         REPO_ROOT / f"BENCH_{key}.json"):
                path.write_text(json.dumps(payload, indent=1))
                print(f"[json] wrote {path}")
    print(f"\nbenchmarks done in {time.time()-t0:.1f}s")
    if failed:
        for name, err in failed:
            print(f"FAILED: {name}: {err}")
        sys.exit(1)


if __name__ == "__main__":
    main()
