"""Benchmark orchestrator: one suite per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full]

Default is the quick pass (CI-sized); --full reproduces the wider grids.
The multi-pod dry-run + roofline tables are separate entry points
(python -m repro.launch.dryrun / python -m repro.roofline.report) since
they re-initialise jax with 512 host devices.
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)
    quick = [] if args.full else ["--quick"]

    from benchmarks import (
        bench_accuracy,
        bench_features,
        bench_grouped,
        bench_memory,
        bench_service,
        bench_spmm,
        bench_verification,
    )

    t0 = time.time()
    suites = [
        ("accuracy (Fig. 6/7)", bench_accuracy.main),
        ("memory (Fig. 8 / Table II)", bench_memory.main),
        ("spmm kernels (Fig. 9)", bench_spmm.main),
        ("grouped multi-polarity spmm (PR 2)", bench_grouped.main),
        ("verification runtime (Fig. 10)", bench_verification.main),
        ("feature ablation (§III-B)", bench_features.main),
        ("verification service (repro.service)", bench_service.main),
    ]
    failed = []
    for name, fn in suites:
        print(f"\n#### {name} ####", flush=True)
        try:
            fn(quick)
        except Exception as e:  # noqa: BLE001
            failed.append((name, repr(e)))
            print(f"[FAIL] {name}: {e}")
    print(f"\nbenchmarks done in {time.time()-t0:.1f}s")
    if failed:
        for name, err in failed:
            print(f"FAILED: {name}: {err}")
        sys.exit(1)


if __name__ == "__main__":
    main()
