"""Sharded streaming executor (repro.mesh): launch throughput vs devices.

One table, toward the paper's headline scale (a 1,024-bit CSA at batch
16 needs more than one accelerator's worth of launch bandwidth):

  * ``sharded_scaling`` — the same partition plan streamed across 1/2/4/8
    host devices: per-device launch balance, the modeled-launch speedup
    (``MeshPlan.modeled_speedup`` — total batches over the busiest
    lane's), compile probe, wall/pack/device seconds, and the verdict
    hash.

Gates (assertion-enforced, so the suite fails loudly in CI):

  * **verdict identity** — every device count produces a bit-identical
    prediction vector (sha256 over the int32 verdict);
  * **near-linear scaling** — modeled-launch speedup >= 1.6x at 2
    devices (the paper's partitions are independent, so the only loss is
    round-robin remainder imbalance);
  * **compile discipline** — <= num_buckets compile units TOTAL at every
    device count (the pmap program is shared by all lanes).

Wall time is reported but NOT gated across device counts: the "devices"
are XLA host-platform fakes sharing the same physical cores, so real
wall scaling is not observable here — the modeled-launch metric is the
honest scaling signal (it is exact on real accelerators, where lanes run
concurrently).

Each device count runs in a subprocess (``XLA_FLAGS=
--xla_force_host_platform_device_count=N``): the bench process itself
must keep seeing 1 device, exactly like tests/test_distributed.py.

    PYTHONPATH=src python -m benchmarks.bench_sharded [--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

from benchmarks.common import print_table, save_table

REPO = Path(__file__).resolve().parent.parent

#: the acceptance gate: modeled-launch speedup at 2 devices
MIN_SPEEDUP_AT_2 = 1.6

_WORKER = """
    import hashlib, json, time
    import jax
    from repro.core import aig as A, gnn
    from repro.core.features import groot_features
    from repro.exec import build_partition_plan
    from repro.mesh import ShardedStreamingExecutor, build_mesh_plan

    bits, k, capacity, devices = {bits}, {k}, {capacity}, {devices}
    d = A.csa_multiplier(bits)
    g = d.to_edge_graph()
    feats = groot_features(d)
    params = gnn.init_params(gnn.GNNConfig(), jax.random.key(0))
    plan = build_partition_plan(g, k, partitioner="multilevel", seed=0)
    mplan = build_mesh_plan(plan, devices, capacity)

    ex = ShardedStreamingExecutor(
        params, "ref", num_devices=devices, capacity=capacity)
    t0 = time.perf_counter()
    pred = ex.run_plan(plan, feats, gnn_cfg=gnn.GNNConfig())
    wall = time.perf_counter() - t0
    print(json.dumps({{
        "devices": devices,
        "num_nodes": g.num_nodes,
        "num_buckets": plan.num_buckets,
        "batches": mplan.total_batches,
        "waves": len(mplan.waves),
        "lane_batches": list(mplan.lane_batches),
        "modeled_speedup": mplan.modeled_speedup,
        "modeled_peak_mb": mplan.per_device_peak_bytes(gnn.GNNConfig()) / 1e6,
        "compiles": ex.stats.compiles,
        "launches": ex.stats.launches,
        "wall_s": wall,
        "pack_s": ex.stats.pack_s,
        "device_s": ex.stats.device_s,
        "launches_per_s": ex.stats.launches / wall if wall else 0.0,
        "pred_sha": hashlib.sha256(pred.tobytes()).hexdigest()[:16],
    }}))
"""


def _run_worker(bits: int, k: int, capacity: int, devices: int) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(REPO / "src")
    code = textwrap.dedent(_WORKER.format(
        bits=bits, k=k, capacity=capacity, devices=devices
    ))
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, env=env, timeout=1200, cwd=REPO,
    )
    if proc.returncode != 0:
        raise RuntimeError(
            f"sharded worker (devices={devices}) failed:\n"
            f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
        )
    return json.loads(proc.stdout.strip().splitlines()[-1])


def bench_scaling(bits: int, k: int, capacity: int,
                  device_grid: list[int]) -> list[dict]:
    rows = [_run_worker(bits, k, capacity, D) for D in device_grid]
    for row in rows:
        row.update(bits=bits, k=k, capacity=capacity)
        row["lane_batches"] = "/".join(map(str, row["lane_batches"]))

    # gate 1: verdict identity across every device count
    hashes = {r["pred_sha"] for r in rows}
    assert len(hashes) == 1, f"verdict diverged across device counts: {rows}"
    # gate 2: near-linear modeled-launch scaling at 2 devices
    by_dev = {r["devices"]: r for r in rows}
    if 2 in by_dev:
        got = by_dev[2]["modeled_speedup"]
        assert got >= MIN_SPEEDUP_AT_2, (
            f"modeled-launch speedup at 2 devices {got:.2f} < "
            f"{MIN_SPEEDUP_AT_2} (lane balance regressed)"
        )
    # gate 3: compile discipline — shared program, not per-device
    for r in rows:
        assert r["compiles"] <= r["num_buckets"], (
            f"devices={r['devices']}: {r['compiles']} compiles > "
            f"{r['num_buckets']} buckets"
        )
    # monotonicity: more lanes never lower the modeled speedup
    speeds = [r["modeled_speedup"] for r in rows]
    assert all(b >= a - 1e-9 for a, b in zip(speeds, speeds[1:])), speeds
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="csa-64 instead of the csa-256 headline design")
    args = ap.parse_args(argv)

    if args.quick:
        bits, k, capacity = 64, 32, 2
    else:
        bits, k, capacity = 256, 16, 2
    rows = bench_scaling(bits, k, capacity, [1, 2, 4, 8])
    print_table(
        f"sharded scaling: csa-{bits}, k={k}, capacity={capacity} "
        f"(modeled-launch speedup gated >= {MIN_SPEEDUP_AT_2}x at 2 devices)",
        rows,
    )
    save_table("sharded_scaling", rows)


if __name__ == "__main__":
    main()
