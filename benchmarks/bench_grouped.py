"""Grouped multi-polarity SpMM vs per-group aggregation (PR 2 tentpole).

Measures, per SAGE layer, what the grouped path removes from the hot
path: the six independent slot x polarity aggregations (each re-gathering
the same edge stream and re-walking the bucket-kernel schedule) collapse
to one grouped aggregation per direction.  Reported per configuration:

  * probe counts per layer — edge-stream gathers, bucket-kernel walks,
    and individual pallas_call launches (trace-time counters in
    ``repro.kernels.groot_spmm.PROBE``);
  * forward wall-clock (this CPU container runs Pallas interpret=True,
    so wall-clock ranks dispatch/launch overhead, not TPU time — the
    probe counts are the hardware-portable signal);
  * plan-cache effect: plans/pairs built on the first vs a repeated
    forward over the same structure.

    PYTHONPATH=src python -m benchmarks.bench_grouped [--quick]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import print_table, save_table
from repro.core import aig as A
from repro.core import gnn
from repro.kernels import ops
from repro.kernels.groot_spmm import probe_snapshot, reset_probe
from repro.kernels.plan_cache import PLAN_CACHE


def _forward_once(params, g, x, inv, slot, pair):
    out = gnn.forward(
        params, x, jnp.asarray(g.edge_src), jnp.asarray(g.edge_dst), inv, slot,
        num_nodes=g.num_nodes, agg=pair,
    )
    jax.block_until_ready(out)
    return out


def run(bits_list, backends, quick=False):
    cfg = gnn.GNNConfig(in_features=4, hidden=8 if quick else 32,
                        num_layers=2 if quick else 4)
    params = gnn.init_params(cfg, jax.random.key(0))
    rows = []
    for bits in bits_list:
        design = A.make_design("csa", bits)
        g = design.to_edge_graph()
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((g.num_nodes, 4)), jnp.float32)
        inv = None if g.edge_inv is None else jnp.asarray(g.edge_inv)
        slot = None if g.edge_slot is None else jnp.asarray(g.edge_slot)
        for backend in backends:
            pc0 = PLAN_CACHE.snapshot()
            pair = ops.make_agg_pair(g.edge_src, g.edge_dst, g.num_nodes, backend)
            pc1 = PLAN_CACHE.snapshot()
            # plans are a per-(graph, backend) property shared by both
            # modes; 0 means the structure was already cached this process
            plans_built = pc1.builds - pc0.builds
            for mode, p in (("grouped", pair), ("per-group", ops.ungrouped(pair))):
                _forward_once(params, g, x, inv, slot, p)  # warmup dispatch
                reset_probe()
                t0 = time.perf_counter()
                want = _forward_once(params, g, x, inv, slot, p)
                dt = time.perf_counter() - t0
                probe = probe_snapshot()
                rows.append(
                    {
                        "bits": bits,
                        "backend": backend,
                        "mode": mode,
                        "gathers/layer": probe["edge_stream_gathers"] / cfg.num_layers,
                        "walks/layer": probe["kernel_walks"] / cfg.num_layers,
                        "launches/layer": probe["pallas_calls"] / cfg.num_layers,
                        "wall_s": round(dt, 3),
                        "plans_built": plans_built,
                        "edges": g.num_edges,
                    }
                )
                del want
        # plan-cache effect: same structure again -> zero builds
        pc2 = PLAN_CACHE.snapshot()
        ops.make_agg_pair(g.edge_src, g.edge_dst, g.num_nodes, backends[0])
        pc3 = PLAN_CACHE.snapshot()
        assert pc3.builds == pc2.builds, "plan cache failed to reuse structure"
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)
    if args.quick:
        rows = run([8], ["groot"], quick=True)
    else:
        rows = run([8, 16], ["groot", "groot_mxu", "groot_fused"], quick=False)
    print_table("grouped vs per-group SpMM (6 -> 2 per layer)", rows)
    save_table("grouped", rows)
    return rows


if __name__ == "__main__":
    main()
