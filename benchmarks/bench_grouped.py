"""Grouped multi-polarity SpMM vs per-group aggregation + hoisting traffic.

Measures, per SAGE layer, what the grouped path removes from the hot
path: the six independent slot x polarity aggregations (each re-gathering
the same edge stream and re-walking the bucket-kernel schedule) collapse
to one grouped aggregation per direction.  Reported per configuration:

  * probe counts per layer — edge-stream gathers, bucket-kernel walks,
    weight gathers, output scatters, and individual pallas_call launches
    (trace-time counters in ``repro.kernels.groot_spmm.PROBE``);
  * forward wall-clock (this CPU container runs Pallas interpret=True,
    so wall-clock ranks dispatch/launch overhead, not TPU time — the
    probe counts are the hardware-portable signal);
  * plan-cache effect: plans/pairs built on the first vs a repeated
    forward over the same structure;
  * **hoisting traffic** (``grouped_traffic`` table): modeled per-layer
    HBM bytes before vs after the ForwardPlan hoisting
    (``pipeline.layer_traffic_model_bytes`` fed the REAL plan slot and
    segment counts), f32 and bf16 streams.  The acceptance row asserts
    >= 25% per-layer reduction on csa-64 with the hoisted f32 path.

    PYTHONPATH=src python -m benchmarks.bench_grouped [--quick]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import print_table, save_table
from repro.core import aig as A
from repro.core import gnn
from repro.core.pipeline import layer_traffic_model_bytes
from repro.kernels import ops
from repro.kernels import plan_cache as PC
from repro.kernels.groot_spmm import probe_snapshot, reset_probe
from repro.kernels.plan_cache import PLAN_CACHE


def _forward_once(params, g, x, inv, slot, pair):
    out = gnn.forward(
        params, x, jnp.asarray(g.edge_src), jnp.asarray(g.edge_dst), inv, slot,
        num_nodes=g.num_nodes, agg=pair,
    )
    jax.block_until_ready(out)
    return out


def run(bits_list, backends, quick=False):
    cfg = gnn.GNNConfig(in_features=4, hidden=8 if quick else 32,
                        num_layers=2 if quick else 4)
    params = gnn.init_params(cfg, jax.random.key(0))
    rows = []
    for bits in bits_list:
        design = A.make_design("csa", bits)
        g = design.to_edge_graph()
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((g.num_nodes, 4)), jnp.float32)
        inv = None if g.edge_inv is None else jnp.asarray(g.edge_inv)
        slot = None if g.edge_slot is None else jnp.asarray(g.edge_slot)
        for backend in backends:
            pc0 = PLAN_CACHE.snapshot()
            pair = ops.make_agg_pair(g.edge_src, g.edge_dst, g.num_nodes, backend)
            pc1 = PLAN_CACHE.snapshot()
            # plans are a per-(graph, backend) property shared by both
            # modes; 0 means the structure was already cached this process
            plans_built = pc1.builds - pc0.builds
            modes = (
                ("hoisted", pair),
                ("pre-hoist", ops.unhoisted(pair)),
                ("per-group", ops.ungrouped(pair)),
            )
            for mode, p in modes:
                _forward_once(params, g, x, inv, slot, p)  # warmup dispatch
                reset_probe()
                t0 = time.perf_counter()
                want = _forward_once(params, g, x, inv, slot, p)
                dt = time.perf_counter() - t0
                probe = probe_snapshot()
                rows.append(
                    {
                        "bits": bits,
                        "backend": backend,
                        "mode": mode,
                        "gathers/layer": probe["edge_stream_gathers"] / cfg.num_layers,
                        "walks/layer": probe["kernel_walks"] / cfg.num_layers,
                        "launches/layer": probe["pallas_calls"] / cfg.num_layers,
                        "w_gathers/fwd": probe["weight_gathers"],
                        "out_scatters": probe["output_scatters"],
                        "wall_s": round(dt, 3),
                        "plans_built": plans_built,
                        "edges": g.num_edges,
                    }
                )
                del want
        # plan-cache effect: same structure again -> zero builds
        pc2 = PLAN_CACHE.snapshot()
        ops.make_agg_pair(g.edge_src, g.edge_dst, g.num_nodes, backends[0])
        pc3 = PLAN_CACHE.snapshot()
        assert pc3.builds == pc2.builds, "plan cache failed to reuse structure"
    return rows


def traffic_rows(bits_list, cfg: gnn.GNNConfig) -> list[dict]:
    """Modeled per-layer HBM traffic before/after hoisting, from the REAL
    plan slot/segment counts (host-side only: no forward is run, so the
    csa-64 acceptance row stays cheap enough for --quick/CI)."""
    rows = []
    for bits in bits_list:
        g = A.make_design("csa", bits).to_edge_graph()
        # only the two SpmmPlans are needed (slot/segment counts) — no
        # AggPair/ForwardPlan/jit closures for the model-only rows
        in_plan = PC.cached_plan(g.edge_src, g.edge_dst, g.num_nodes)
        out_plan = PC.cached_plan(g.edge_dst, g.edge_src, g.num_nodes)
        kw = dict(
            slots_in=in_plan.num_slots,
            slots_out=out_plan.num_slots,
            segments_in=in_plan.num_segments,
            segments_out=out_plan.num_segments,
        )
        before = layer_traffic_model_bytes(
            g.num_nodes, g.num_edges, cfg, hoisted=False, **kw
        )
        after = layer_traffic_model_bytes(
            g.num_nodes, g.num_edges, cfg, hoisted=True, **kw
        )
        after_bf16 = layer_traffic_model_bytes(
            g.num_nodes, g.num_edges, cfg, hoisted=True,
            stream_dtype="bfloat16", **kw
        )
        rows.append(
            {
                "bits": bits,
                "nodes": g.num_nodes,
                "edges": g.num_edges,
                "prehoist_mb": before / 1e6,
                "hoisted_mb": after / 1e6,
                "hoisted_bf16_mb": after_bf16 / 1e6,
                "reduction_f32": 1.0 - after / before,
                "reduction_bf16": 1.0 - after_bf16 / before,
            }
        )
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)
    if args.quick:
        rows = run([8], ["groot"], quick=True)
    else:
        rows = run([8, 16], ["groot", "groot_mxu", "groot_fused"], quick=False)
    print_table("grouped vs per-group SpMM (6 -> 2 per layer)", rows)
    save_table("grouped", rows)

    # hoisting acceptance: >= 25% modeled per-layer traffic reduction on
    # csa-64 with the hoisted f32 path (bf16 reported alongside)
    cfg = gnn.GNNConfig()
    trows = traffic_rows([8, 64] if args.quick else [8, 16, 64], cfg)
    print_table("per-layer HBM traffic, pre-hoist vs ForwardPlan", trows)
    save_table("grouped_traffic", trows)
    r64 = next(r for r in trows if r["bits"] == 64)
    assert r64["reduction_f32"] >= 0.25, (
        f"acceptance: hoisted f32 per-layer traffic reduction "
        f"{r64['reduction_f32']:.1%} on csa-64 (must be >= 25%)"
    )
    print(
        f"\ncsa-64 per-layer traffic: {r64['prehoist_mb']:.1f} MB pre-hoist -> "
        f"{r64['hoisted_mb']:.1f} MB hoisted f32 ({r64['reduction_f32']:.1%} "
        f"less), {r64['hoisted_bf16_mb']:.1f} MB bf16 "
        f"({r64['reduction_bf16']:.1%} less)"
    )
    return rows + trows


if __name__ == "__main__":
    main()
