"""Paper Fig. 9: SpMM kernel comparison on EDA graphs.

Backends (JAX analogues of the paper's baselines — DESIGN.md §9):

    cusparse-like    jax.experimental.sparse BCOO @ dense
    gnnadvisor-like  row-parallel gather + segment_sum ("ref")
    onehot-dense     dense one-hot matmul (naive MXU port)
    groot            the degree-bucketed Pallas HD/LD kernels
    groot_mxu        LD reduction as one-hot block-diag MXU matmul

Two scores per backend:
  * wall-clock on this CPU container (jit-compiled XLA; the Pallas path
    runs interpret=True so its wall-clock is NOT meaningful and is
    reported only for completeness), and
  * the structural cost model: HBM bytes touched + MXU-eligible flops
    (what actually ranks kernels on the TPU target).

    PYTHONPATH=src python -m benchmarks.bench_spmm [--quick]
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import print_table, save_table, timer
from repro.core import aig as A
from repro.kernels import ops, ref
from repro.kernels.groot_spmm import build_plan


def _bcoo_backend(src, dst, n):
    from jax.experimental import sparse

    idx = jnp.stack([jnp.asarray(dst), jnp.asarray(src)], axis=1)

    def run(x, w):
        data = w if w is not None else jnp.ones(idx.shape[0], x.dtype)
        mat = sparse.BCOO((data, idx), shape=(n, n))
        return mat @ x

    return run


def structural_model(src, dst, n, f, backend: str) -> dict:
    """Bytes touched / flops for one SpMM on the TPU target."""
    e = len(src)
    f32 = 4
    if backend == "onehot-dense":
        bytes_ = (e * n + e * f + n * f) * f32    # (N,E) one-hot dominates
        flops = 2.0 * n * e * f
    elif backend in ("groot", "groot_mxu"):
        plan = build_plan(np.asarray(src), np.asarray(dst), n)
        slots = sum(b.eids.size for b in plan.buckets) + (
            plan.hd.eids.size if plan.hd else 0
        )
        # gather read + padded edge-stream write/read + output write
        bytes_ = slots * f * f32 * 3 + n * f * f32 + e * 8
        flops = 2.0 * slots * f if backend == "groot_mxu" else slots * f
    else:  # gather + segment_sum row-parallel (and BCOO is similar)
        bytes_ = (e * f * 2 + n * f) * f32 + e * 8
        flops = e * f
    return {"bytes": bytes_, "flops": flops}


def run(bits_list, datasets, f=32, quick=False):
    rows = []
    for ds in datasets:
        for bits in bits_list:
            g = A.make_design(ds, bits).to_edge_graph()
            n = g.num_nodes
            rng = np.random.default_rng(0)
            x = jnp.asarray(rng.standard_normal((n, f)), jnp.float32)
            w = jnp.asarray(rng.standard_normal(g.num_edges), jnp.float32)
            backends = {
                "gnnadvisor-like": lambda x, w: ref.spmm_ref(
                    x, jnp.asarray(g.edge_src), jnp.asarray(g.edge_dst), n, w
                ),
                "cusparse-like": _bcoo_backend(g.edge_src, g.edge_dst, n),
            }
            if not quick and n < 20000:
                pair_oh = ops.make_agg_pair(g.edge_src, g.edge_dst, n, "onehot")
                backends["onehot-dense"] = lambda x, w: pair_oh.in_agg(x, w)
            pair = ops.make_agg_pair(g.edge_src, g.edge_dst, n, "groot")
            backends["groot(interp)"] = lambda x, w: pair.in_agg(x, w)

            want = None
            for name, fn in backends.items():
                jitted = jax.jit(fn)
                dt, out = timer(lambda: jitted(x, w).block_until_ready())
                if want is None:
                    want = out
                else:
                    np.testing.assert_allclose(
                        np.asarray(out), np.asarray(want), rtol=2e-4, atol=2e-4
                    )
                key = name.split("(")[0].replace("-like", "")
                model = structural_model(
                    g.edge_src, g.edge_dst, n, f,
                    {"gnnadvisor": "ref", "cusparse": "ref"}.get(key, key),
                )
                rows.append(
                    {
                        "dataset": ds,
                        "bits": bits,
                        "backend": name,
                        "wall_ms": round(dt * 1e3, 3),
                        "model_MB": round(model["bytes"] / 1e6, 2),
                        "model_MFLOP": round(model["flops"] / 1e6, 2),
                        "nodes": n,
                        "edges": g.num_edges,
                    }
                )
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)
    if args.quick:
        rows = run([16], ["csa"], quick=True)
    else:
        rows = run([16, 32, 64], ["csa", "booth"], quick=False)
    print_table("SpMM kernels on EDA graphs (paper Fig. 9)", rows)
    save_table("spmm", rows)
    return rows


if __name__ == "__main__":
    main()
