"""Shared benchmark utilities: trained-model cache, timing, table output."""
from __future__ import annotations

import functools
import json
import time
from pathlib import Path

ART = Path(__file__).resolve().parent.parent / "experiments" / "bench"


@functools.lru_cache(maxsize=8)
def trained_params(dataset: str = "csa", bits: int = 8, epochs: int = 300):
    from repro.core import pipeline as P

    params, _ = P.train_model(dataset, bits, epochs=epochs)
    return params


def make_session(params, **config):
    """A `repro.api.Session` over a trained model — the benchmarks drive
    the same façade users do (``sess.options(...)`` derives variants)."""
    from repro.api import Session, SessionConfig

    return Session(params, SessionConfig(**config))


def timer(fn, *args, repeats: int = 3, **kw):
    fn(*args, **kw)  # warmup / compile
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return best, out


#: tables saved since the last ``drain_tables`` call — the per-suite JSON
#: artifacts ``benchmarks.run --json`` folds into its BENCH_<suite>.json
TABLES: dict[str, list] = {}


def save_table(name: str, rows: list):
    ART.mkdir(parents=True, exist_ok=True)
    path = ART / f"{name}.json"
    path.write_text(json.dumps(rows, indent=1))
    TABLES[name] = rows
    return path


def drain_tables() -> dict[str, list]:
    """Return and clear the tables saved since the last drain."""
    out = dict(TABLES)
    TABLES.clear()
    return out


def print_table(title: str, rows: list):
    print(f"\n== {title} ==")
    if not rows:
        print("(empty)")
        return
    keys = list(rows[0].keys())
    print(" | ".join(f"{k:>14s}" for k in keys))
    for r in rows:
        print(
            " | ".join(
                f"{r[k]:14.4f}" if isinstance(r[k], float) else f"{str(r[k]):>14s}"
                for k in keys
            )
        )
