"""Paper Fig. 10 + Fig. 1(b): end-to-end verification runtime — GNN flow
vs the classical structural detector ("ABC-like" baseline), and the
ABC-scaling model.

The classical algebraic-rewriting flow spends its time *detecting*
XOR/MAJ structures in the flattened netlist before it can cancel
polynomials; GROOT replaces the detector with GNN inference.  We measure
both on the same designs.  For ABC's full verification runtime (which the
paper reports growing exponentially, e.g. 8.6e5 s at 2048 bits) we report
the paper-calibrated scaling model rather than pretending to run ABC.

    PYTHONPATH=src python -m benchmarks.bench_verification [--quick]
"""
from __future__ import annotations

import argparse
import time

from benchmarks.common import make_session, print_table, save_table, trained_params
from repro.core import aig as A
from repro.core.labels import structural_detect


def abc_runtime_model(bits: int) -> float:
    """Paper-calibrated ABC scaling (Fig. 10a): ~exponential in width;
    anchored at 2048 bits = 8.6e5 s [7] and ~1 s at 64 bits."""
    import math

    # log-linear fit through (64, 1 s) and (2048, 8.6e5 s)
    slope = (math.log(8.6e5) - math.log(1.0)) / (2048 - 64)
    return math.exp(math.log(1.0) + slope * (bits - 64))


def run(bits_list, parts_list, epochs=200):
    sess = make_session(trained_params("csa", 8, epochs), dataset="csa")
    rows = []
    for bits in bits_list:
        design = A.make_design("csa", bits)
        t0 = time.perf_counter()
        structural_detect(design)
        t_detector = time.perf_counter() - t0
        for parts in parts_list:
            r = sess.options(num_partitions=parts).verify(
                bits=bits, verify=bits <= 32, use_cache=False
            )
            rows.append(
                {
                    "bits": bits,
                    "partitions": parts,
                    "gnn_infer_s": round(r.timings["inference"], 4),
                    "partition_s": round(r.timings["partition"], 4),
                    "detector_s": round(t_detector, 4),
                    "abc_model_s": round(abc_runtime_model(bits), 2),
                    "accuracy": round(r.accuracy, 4),
                    "verdict": r.verdict.status if r.verdict else "-",
                }
            )
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)
    if args.quick:
        rows = run([16, 32], [1, 4])
    else:
        rows = run([16, 32, 64, 128], [1, 4, 16])
    print_table("verification runtime (paper Fig. 10)", rows)
    save_table("verification", rows)
    return rows


if __name__ == "__main__":
    main()
