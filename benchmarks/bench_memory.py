"""Paper Fig. 8 / Table II: peak device memory vs #partitions.

Memory is the array-accurate device-buffer model of
``repro.core.pipeline.memory_model_bytes`` (CPU container: no CUDA
allocator to poll; the counted buffers are exactly the arrays the
inference step allocates).

    PYTHONPATH=src python -m benchmarks.bench_memory [--quick]
"""
from __future__ import annotations

import argparse

from benchmarks.common import make_session, print_table, save_table, trained_params


def run(datasets, bits_list, partitions, batch=1, epochs=200):
    rows = []
    for ds in datasets:
        sess = make_session(
            trained_params(ds, 8, epochs), dataset=ds, batch=batch, regrow=True
        )
        for bits in bits_list:
            base = None
            for parts in partitions:
                r = sess.options(num_partitions=parts).verify(
                    bits=bits, verify=False, use_cache=False
                )
                if base is None:
                    base = r.unpartitioned_memory_bytes
                rows.append(
                    {
                        "dataset": ds,
                        "bits": bits,
                        "batch": batch,
                        "partitions": parts,
                        "peak_MB": round(r.peak_memory_bytes / 1e6, 2),
                        "reduction_%": round(
                            100 * (1 - r.peak_memory_bytes / base), 2
                        ),
                        "nodes": r.num_nodes,
                        "edges": r.num_edges,
                    }
                )
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)
    if args.quick:
        rows = run(["csa"], [32], [1, 4, 16], epochs=150)
    else:
        rows = run(["csa", "booth", "mapped"], [32, 64], [1, 2, 4, 8, 16, 32])
        rows += run(["csa"], [64], [1, 8, 16], batch=4)
    print_table("memory vs partitions (paper Fig. 8 / Table II)", rows)
    save_table("memory", rows)
    best = max(rows, key=lambda r: r["reduction_%"])
    print(
        f"\nmax memory reduction: {best['reduction_%']}% "
        f"({best['dataset']}-{best['bits']}b @ {best['partitions']} parts; "
        f"paper: 59.38% on csa-1024 x16)"
    )
    return rows


if __name__ == "__main__":
    main()
