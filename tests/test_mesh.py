"""Sharded partition streaming (repro.mesh): plans, parity, probes.

Fast lane (every push, 1 visible device):

  * ``make_host_mesh`` typed errors and the ``data=`` cap,
  * MeshPlan wave structure — round-robin balance, idle-lane accounting,
    the modeled-launch speedup metric, journal-filtered schedules,
  * the degenerate 1-device mesh: bit-exact with the single-device
    streaming executor through the same plan,
  * the sharded route is a no-op on a 1-device host (router keeps
    mode "streamed").

Slow lane: real multi-device runs in subprocesses with
``--xla_force_host_platform_device_count`` (the main test process must
keep seeing 1 device) — the devices x k grid of bit-exactness, the
compile probe (<= num_buckets TOTAL, not per device), groot
verdict-identity over the MPMD path, journal resume mid-sharded-run, and
per-lane transient-fault isolation.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

import jax

from repro.core import aig as A
from repro.core import gnn
from repro.core.features import groot_features
from repro.exec import StreamingExecutor, build_partition_plan
from repro.launch.mesh import MeshConfigError, make_host_mesh
from repro.mesh import (
    MeshRunner,
    ShardedStreamingExecutor,
    build_mesh_plan,
)

REPO = Path(__file__).resolve().parent.parent


def run_subprocess(code: str, devices: int = 8):
    """Multi-device cases run in a subprocess with faked host devices —
    the main test process must keep seeing 1 device (same discipline as
    tests/test_distributed.py)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(REPO / "src")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True,
        text=True,
        env=env,
        timeout=420,
        cwd=REPO,
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    return proc.stdout


@pytest.fixture(scope="module")
def rand_params():
    return gnn.init_params(gnn.GNNConfig(), jax.random.key(0))


@pytest.fixture(scope="module")
def csa12():
    d = A.csa_multiplier(12)
    return d, d.to_edge_graph(), groot_features(d)


# -- make_host_mesh (satellite: typed error + data cap) ----------------------


def test_make_host_mesh_rejects_bad_model_axis():
    with pytest.raises(MeshConfigError, match="does not divide"):
        make_host_mesh(model=3)          # 1 visible device on the fast lane
    # MeshConfigError IS a ValueError: callers with broad handlers keep
    # working
    assert issubclass(MeshConfigError, ValueError)


def test_make_host_mesh_data_cap():
    m = make_host_mesh(data=1)
    assert dict(m.shape) == {"data": 1, "model": 1}
    with pytest.raises(MeshConfigError, match="at most"):
        make_host_mesh(data=jax.local_device_count() + 1)
    with pytest.raises(MeshConfigError, match="at most"):
        make_host_mesh(data=0)


def test_mesh_runner_rejects_out_of_range_devices(rand_params):
    with pytest.raises(MeshConfigError, match="out of range"):
        MeshRunner(rand_params, "ref",
                   num_devices=jax.local_device_count() + 1)


# -- MeshPlan (host-side, no devices needed) ---------------------------------


def _plan(graph, k):
    return build_partition_plan(graph, k, partitioner="multilevel", seed=0)


def test_mesh_plan_round_robin_balance(csa12):
    _, g, _ = csa12
    plan = _plan(g, 8)
    for D in (1, 2, 4):
        mp = build_mesh_plan(plan, D, 2)
        sched = plan.schedule(2)
        # every scheduled batch appears on exactly one lane, in order
        flat = [
            lane for w in mp.waves for lane in w.lanes if lane is not None
        ]
        assert sorted(map(tuple, flat)) == sorted(
            tuple(ix) for _, ix in sched
        )
        assert mp.total_batches == len(sched)
        # round-robin: lane loads differ by at most one batch per bucket
        assert max(mp.lane_batches) - min(mp.lane_batches) <= plan.num_buckets
        # waves never mix buckets
        for w in mp.waves:
            assert len(w.lanes) == D
        assert len(mp.lane_batches) == D


def test_mesh_plan_speedup_metric(csa12):
    _, g, _ = csa12
    plan = _plan(g, 8)
    mp1 = build_mesh_plan(plan, 1, 2)
    assert mp1.modeled_speedup == 1.0
    mp2 = build_mesh_plan(plan, 2, 2)
    # the busiest lane holds ceil(batches/2) per bucket: strictly better
    # than one device whenever any bucket has >= 2 batches
    if mp2.total_batches >= 2:
        assert mp2.modeled_speedup > 1.0
    assert mp2.modeled_speedup <= 2.0
    # per-device peak equals the single-device packed peak (same shapes)
    cfg = gnn.GNNConfig()
    assert mp2.per_device_peak_bytes(cfg) == plan.peak_batch_memory_bytes(cfg, 2)
    assert "device" in mp2.describe()


def test_mesh_plan_respects_filtered_schedule(csa12):
    _, g, _ = csa12
    plan = _plan(g, 8)
    full = plan.schedule(2)
    # drop the partitions a resumed journal would have restored
    done = {0, 1, 2}
    filtered = [
        (shape, kept)
        for shape, indices in full
        if (kept := [i for i in indices if i not in done])
    ]
    mp = build_mesh_plan(plan, 2, 2, schedule=filtered)
    scheduled = {i for w in mp.waves for l in w.lanes if l for i in l}
    assert scheduled.isdisjoint(done)
    assert scheduled == set(range(plan.num_parts)) - done


def test_build_mesh_plan_rejects_zero_devices(csa12):
    _, g, _ = csa12
    with pytest.raises(ValueError, match="at least one device"):
        build_mesh_plan(_plan(g, 4), 0, 2)


# -- 1-device mesh == single-device executor (fast parity) -------------------


def test_one_device_mesh_matches_streaming_executor(rand_params, csa12):
    d, g, feats = csa12
    plan = _plan(g, 4)
    ref = StreamingExecutor(rand_params, "ref", capacity=2).run_plan(plan, feats)
    ex = ShardedStreamingExecutor(rand_params, "ref", num_devices=1, capacity=2)
    out = ex.run_plan(plan, feats, gnn_cfg=gnn.GNNConfig())
    assert (out == ref).all()
    assert ex.stats.compiles <= plan.num_buckets
    assert ex.stats.partitions == plan.num_parts
    assert ex.stats.lane_launches == ex.stats.launches
    assert ex.stats.devices == 1
    # stats duck-type StreamStats: the pipeline's delta/asdict contract
    import dataclasses

    before = dataclasses.replace(ex.stats)
    stats = dataclasses.asdict(ex.stats.delta(before))
    assert stats["runs"] == 0 and "lane_launches" in stats


def test_router_keeps_streamed_mode_on_one_device(rand_params):
    from repro.api import Session, SessionConfig

    sess = Session(
        params=rand_params,
        config=SessionConfig(dataset="csa", bits=12, num_partitions=4),
    )
    d = sess.explain()
    assert d.mode == "streamed" and d.mesh_devices == 1
    # explicit mesh_devices=1 on a 1-device host: identical decision
    assert sess.options(mesh_devices=1).explain().mode == "streamed"


# -- multi-device (subprocess) grid ------------------------------------------


@pytest.mark.slow
def test_sharded_grid_bit_exact_and_compile_probe():
    """devices x k grid: the sharded verdict is bit-identical to the
    single-device route, and the whole mesh shares <= num_buckets compile
    units TOTAL (the pmap program is traced once for all lanes)."""
    run_subprocess("""
        import jax, numpy as np
        from repro.core import aig as A, gnn
        from repro.core.features import groot_features
        from repro.exec import StreamingExecutor, build_partition_plan
        from repro.mesh import ShardedStreamingExecutor

        d = A.csa_multiplier(16)
        g = d.to_edge_graph()
        feats = groot_features(d)
        params = gnn.init_params(gnn.GNNConfig(), jax.random.key(0))
        for k in (4, 8):
            plan = build_partition_plan(g, k, partitioner="multilevel", seed=0)
            ref = StreamingExecutor(params, "ref", capacity=2).run_plan(
                plan, feats)
            for D in (1, 2, 4):
                ex = ShardedStreamingExecutor(
                    params, "ref", num_devices=D, capacity=2)
                out = ex.run_plan(plan, feats, gnn_cfg=gnn.GNNConfig())
                assert (out == ref).all(), f"D={D} k={k} diverged"
                assert ex.stats.compiles <= plan.num_buckets, (
                    f"D={D} k={k}: {ex.stats.compiles} compiles > "
                    f"{plan.num_buckets} buckets")
                assert ex.stats.partitions == plan.num_parts
        print("grid ok")
    """)


@pytest.mark.slow
def test_sharded_groot_backend_verdict_identical():
    """The structure-keyed MPMD path (per-lane jit + static degree plans)
    agrees with the single-device groot stream."""
    run_subprocess("""
        import jax, numpy as np
        from repro.core import aig as A, gnn
        from repro.core.features import groot_features
        from repro.exec import StreamingExecutor, build_partition_plan
        from repro.mesh import ShardedStreamingExecutor

        d = A.csa_multiplier(12)
        g = d.to_edge_graph()
        feats = groot_features(d)
        params = gnn.init_params(gnn.GNNConfig(), jax.random.key(0))
        plan = build_partition_plan(g, 4, partitioner="multilevel", seed=0)
        ref = StreamingExecutor(params, "groot", capacity=2).run_plan(
            plan, feats)
        ex = ShardedStreamingExecutor(params, "groot", num_devices=2,
                                      capacity=2)
        out = ex.run_plan(plan, feats)
        assert (out == ref).all()
        print("groot ok")
    """, devices=2)


@pytest.mark.slow
def test_sharded_session_route_and_explain():
    """On a multi-device host the router promotes the streamed route to
    "sharded" and explain() reports the mesh decision."""
    run_subprocess("""
        import jax
        from repro.api import Session, SessionConfig
        from repro.core import gnn

        params = gnn.init_params(gnn.GNNConfig(), jax.random.key(0))
        sess = Session(params=params, config=SessionConfig(
            dataset="csa", bits=16, num_partitions=8))
        d = sess.explain()
        assert d.mode == "sharded" and d.mesh_devices == 4, d
        assert "4 devices" in d.reason and "bucket" in d.reason, d.reason
        assert "per-device peak" in d.reason, d.reason
        r = sess.verify(verify=False, return_predictions=True)
        assert r.routing.mode == "sharded"
        assert r.exec_stats["devices"] == 4
        assert r.exec_stats["waves"] >= 1
        r1 = sess.options(mesh_devices=1).verify(
            verify=False, return_predictions=True)
        assert r1.routing.mode == "streamed"
        assert (r.predictions == r1.predictions).all()
        print("session ok")
    """, devices=4)


@pytest.mark.slow
def test_sharded_journal_resume_mid_run():
    """A sharded run killed mid-stream resumes: committed partitions are
    restored regardless of their original shard assignment, and only the
    remainder is re-launched (re-balanced over the lanes)."""
    run_subprocess("""
        import tempfile
        import numpy as np, jax
        from repro import faults
        from repro.core import aig as A, gnn
        from repro.core.features import groot_features
        from repro.exec import StreamingExecutor, build_partition_plan
        from repro.checkpoint import PartitionJournal
        from repro.mesh import ShardedStreamingExecutor

        d = A.csa_multiplier(16)
        g = d.to_edge_graph()
        feats = groot_features(d)
        params = gnn.init_params(gnn.GNNConfig(), jax.random.key(0))
        plan = build_partition_plan(g, 8, partitioner="multilevel", seed=0)
        ref = StreamingExecutor(params, "ref", capacity=2).run_plan(plan, feats)

        base = tempfile.mkdtemp()
        # crash the first run: a fatal fault on a later wave's lane launch
        journal = PartitionJournal(base, "t")
        ex = ShardedStreamingExecutor(params, "ref", num_devices=4,
                                      capacity=2, launch_retries=0)
        with faults.injected("mesh.launch:nth=3,kind=fatal"):
            try:
                ex.run_plan(plan, feats, journal=journal)
                raise SystemExit("expected the injected fatal to surface")
            except faults.FatalFault:
                pass
        committed = journal.open(plan)
        assert committed, "the crashed run committed nothing"
        assert len(committed) < plan.num_parts

        # resume under a DIFFERENT shard count: per-partition commits are
        # assignment-agnostic
        journal2 = PartitionJournal(base, "t")
        ex2 = ShardedStreamingExecutor(params, "ref", num_devices=2,
                                       capacity=2)
        out = ex2.run_plan(plan, feats, journal=journal2)
        assert (out == ref).all()
        assert ex2.stats.resumed_partitions == len(committed)
        assert ex2.stats.partitions == plan.num_parts - len(committed)
        # the journal is reclaimed once the verdict is complete
        assert not journal2.open(plan)
        print("resume ok")
    """, devices=4)


@pytest.mark.slow
def test_sharded_lane_transient_isolated_and_retried():
    """A transient injected on ONE lane's launch is replayed with backoff
    without poisoning sibling lanes: the run completes, the verdict is
    identical, and no sibling batch is re-packed or re-launched."""
    run_subprocess("""
        import numpy as np, jax
        from repro import faults
        from repro.core import aig as A, gnn
        from repro.core.features import groot_features
        from repro.exec import StreamingExecutor, build_partition_plan
        from repro.mesh import ShardedStreamingExecutor, build_mesh_plan

        d = A.csa_multiplier(16)
        g = d.to_edge_graph()
        feats = groot_features(d)
        params = gnn.init_params(gnn.GNNConfig(), jax.random.key(0))
        plan = build_partition_plan(g, 8, partitioner="multilevel", seed=0)
        ref = StreamingExecutor(params, "ref", capacity=2).run_plan(plan, feats)

        ex = ShardedStreamingExecutor(params, "ref", num_devices=4,
                                      capacity=2, launch_retries=2,
                                      retry_backoff_s=0.01)
        mp = build_mesh_plan(plan, 4, 2)
        with faults.injected(
            "mesh.launch:nth=2,kind=transient,max_fires=1"
        ):
            out = ex.run_plan(plan, feats)
        assert (out == ref).all()
        assert ex.stats.lane_retries == 1, ex.stats.lane_retries
        # sibling isolation: exactly one launch per scheduled batch — the
        # retried lane recovered in place, nothing was re-run
        assert ex.stats.lane_launches == mp.total_batches
        assert ex.stats.batches == mp.total_batches
        print("fault isolation ok")
    """, devices=4)
