"""Pallas flash-attention kernel vs plain-softmax oracle (interpret mode)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention, flash_ref


def _mk(bh, s, t, hd, seed=0, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(k1, (bh, s, hd), dtype)
    k = jax.random.normal(k2, (bh, t, hd), dtype)
    v = jax.random.normal(k3, (bh, t, hd), dtype)
    return q, k, v


@pytest.mark.parametrize("s,t,qb,kb", [
    (256, 256, 128, 128),
    (300, 300, 128, 128),   # padding path
    (128, 512, 64, 128),    # cross-length (q short)
])
@pytest.mark.parametrize("window", [0, 100])
def test_flash_causal_matches_ref(s, t, qb, kb, window):
    q, k, v = _mk(4, s, t, 64)
    got = flash_attention(q, k, v, causal=True, window=window,
                          q_block=qb, kv_block=kb)
    want = flash_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_bidirectional():
    q, k, v = _mk(2, 256, 256, 64)
    got = flash_attention(q, k, v, causal=False, q_block=128, kv_block=128)
    want = flash_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_softcap():
    q, k, v = _mk(2, 128, 128, 32, seed=3)
    got = flash_attention(q, k, v, softcap=20.0, q_block=64, kv_block=64)
    want = flash_ref(q, k, v, softcap=20.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_bf16():
    q, k, v = _mk(2, 256, 256, 64, seed=5, dtype=jnp.bfloat16)
    got = flash_attention(q, k, v, q_block=128, kv_block=128)
    want = flash_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                     v.astype(jnp.float32))
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want),
                               rtol=2e-2, atol=2e-2)


def test_flash_matches_model_sdpa():
    """The kernel and the model's lax-flash schedule agree (same math the
    dry-run lowers; the kernel is the TPU deployment form)."""
    import repro.zoo.models.attention as A
    from repro.zoo.configs import get_config
    from repro.zoo.configs.base import materialize, param_tree

    cfg = get_config("qwen3-8b", smoke=True)
    p = materialize(param_tree(cfg)["layers"][0]["attn"], jax.random.key(7),
                    jnp.float32)
    x = jax.random.normal(jax.random.key(8), (2, 256, cfg.d_model), jnp.float32)
    out_model, _ = A.attention(x, p, cfg)
    # run the kernel on the same projected q/k/v
    q, k, v = A._project_qkv(x, p, cfg)
    pos = jnp.arange(256, dtype=jnp.int32)
    q = A.rope(q, pos, cfg.rope_theta)
    k = A.rope(k, pos, cfg.rope_theta)
    b, s, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    qf = jnp.moveaxis(q.reshape(b, s, kv, g, hd), 1, 3).reshape(b * kv * g, s, hd)
    kf = jnp.repeat(jnp.moveaxis(k, 1, 2), g, axis=1).reshape(b * kv * g, s, hd)
    vf = jnp.repeat(jnp.moveaxis(v, 1, 2), g, axis=1).reshape(b * kv * g, s, hd)
    of = flash_attention(qf, kf, vf, causal=True, q_block=128, kv_block=128)
    out_k = jnp.moveaxis(of.reshape(b, kv, g, s, hd), 3, 1).reshape(b, s, h, hd)
    out_kernel = jnp.einsum("bshk,hkd->bsd", out_k, p["wo"])
    np.testing.assert_allclose(np.asarray(out_kernel), np.asarray(out_model),
                               rtol=2e-4, atol=2e-4)
