"""End-to-end behaviour tests for the GROOT verification system.

Validates the paper's pipeline claims at test scale: functional-correct AIG
generators, oracle-consistent labels, partition/re-growth accuracy recovery,
memory-bound partitioned inference, and the full verify() flow.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core import aig as A

pytestmark = pytest.mark.slow  # trains models; full-lane only
from repro.core import gnn, pipeline as P
from repro.core.features import groot_features, gamora_features
from repro.core.labels import structural_detect
from repro.core.partition import PARTITIONERS, edge_cut
from repro.core.regrowth import boundary_edge_fraction, extract_partitions
from repro.core.verify import simulation_check


@pytest.fixture(scope="module")
def trained_params():
    params, _ = P.train_model("csa", 8, epochs=200)
    return params


# ---------------------------------------------------------------------------
# Generators are functionally correct multipliers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [2, 4, 6])
def test_csa_multiplier_functional(bits):
    assert simulation_check(A.csa_multiplier(bits), bits, signed=False)


@pytest.mark.parametrize("bits", [2, 4, 6])
def test_booth_multiplier_functional(bits):
    assert simulation_check(A.booth_multiplier(bits), bits, signed=True)


def test_mapped_multiplier_functional():
    assert simulation_check(A.csa_multiplier(4, mixed_decomp=True), 4, signed=False)


# ---------------------------------------------------------------------------
# Features reproduce the paper's worked example (§III-B, Fig. 3c)
# ---------------------------------------------------------------------------

def test_features_match_paper_vector_table():
    aig = A.csa_multiplier(2)
    f = groot_features(aig)
    # PIs: 0000
    assert (f[: aig.n_pi] == 0).all()
    # ANDs with non-inverted inputs -> 1100
    is_and = aig.kind == A.AND
    noninv = is_and & ((aig.fanin0 & 1) == 0) & ((aig.fanin1 & 1) == 0)
    assert (f[noninv] == np.array([1, 1, 0, 0], np.float32)).all()
    # ANDs with both inputs inverted -> 1111
    bothinv = is_and & ((aig.fanin0 & 1) == 1) & ((aig.fanin1 & 1) == 1)
    assert bothinv.any()
    assert (f[bothinv] == np.array([1, 1, 1, 1], np.float32)).all()
    # PO with non-inverted driver -> 0011
    is_po = aig.kind == A.PO
    po_pos = is_po & ((aig.fanin0 & 1) == 0)
    assert (f[po_pos] == np.array([0, 0, 1, 1], np.float32)).all()
    # GROOT has 4 features vs GAMORA's 3 (the paper's feature-count claim)
    assert f.shape[1] == 4 and gamora_features(aig).shape[1] == 3


def test_structural_detector_agrees_with_construction_labels():
    for ds, min_agree in (("csa", 0.98), ("booth", 0.99)):
        d = A.make_design(ds, 8)
        agree = float((structural_detect(d) == d.label).mean())
        assert agree >= min_agree, (ds, agree)


# ---------------------------------------------------------------------------
# Partitioning + re-growth (§III-C)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("partitioner", ["multilevel", "bfs"])
def test_partition_balance_and_cut(partitioner):
    g = A.csa_multiplier(16).to_edge_graph()
    k = 8
    part = PARTITIONERS[partitioner](g, k)
    sizes = np.bincount(part, minlength=k)
    assert sizes.min() > 0
    assert sizes.max() <= 1.6 * g.num_nodes / k
    assert edge_cut(g, part) < g.num_edges * 0.5


def test_regrowth_algorithm1_invariants():
    """Alg. 1: S_p+ ⊇ S_p; E_p+ = E[S_p] ∪ C_p; halo = 1-hop boundary."""
    g = A.csa_multiplier(8).to_edge_graph()
    part = PARTITIONERS["multilevel"](g, 4)
    subs = extract_partitions(g, part, regrow=True)
    covered = np.zeros(g.num_nodes, bool)
    for p, sg in enumerate(subs):
        covered[sg.global_ids[: sg.num_core]] = True
        core = set(sg.global_ids[: sg.num_core].tolist())
        halo = set(sg.global_ids[sg.num_core :].tolist())
        assert not core & halo
        # every halo node is 1 hop from a core node
        s, d = g.edge_src, g.edge_dst
        nbrs = set()
        mask_c = np.isin(s, list(core))
        nbrs.update(d[mask_c].tolist())
        mask_c2 = np.isin(d, list(core))
        nbrs.update(s[mask_c2].tolist())
        assert halo <= (nbrs - core)
        # every edge has >= 1 core endpoint (E[S_p] ∪ C_p, nothing more)
        gi = sg.global_ids
        src_is_core = sg.edge_src < sg.num_core
        dst_is_core = sg.edge_dst < sg.num_core
        assert (src_is_core | dst_is_core).all()
        # edges exist in the original graph
        orig = set(zip(g.edge_src.tolist(), g.edge_dst.tolist()))
        for es, ed in zip(gi[sg.edge_src].tolist(), gi[sg.edge_dst].tolist()):
            assert (es, ed) in orig
    assert covered.all()  # partitions tile the node set


def test_boundary_edge_fraction_matches_paper_order():
    """Paper §III-C: ~10% boundary edges."""
    g = A.csa_multiplier(32).to_edge_graph()
    part = PARTITIONERS["multilevel"](g, 8)
    assert boundary_edge_fraction(g, part) < 0.25


# ---------------------------------------------------------------------------
# Accuracy + memory claims (Figs. 6/8) at test scale
# ---------------------------------------------------------------------------

def test_unpartitioned_accuracy_high(trained_params):
    cfg = P.PipelineConfig(dataset="csa", bits=16, num_partitions=1)
    r = P.run_pipeline(cfg, trained_params)
    assert r.accuracy >= 0.99


def test_regrowth_recovers_accuracy(trained_params):
    base = P.run_pipeline(
        P.PipelineConfig(dataset="csa", bits=16, num_partitions=4, regrow=False),
        trained_params,
    )
    regrown = P.run_pipeline(
        P.PipelineConfig(dataset="csa", bits=16, num_partitions=4, regrow=True),
        trained_params,
    )
    assert regrown.accuracy > base.accuracy + 0.02  # recovery is real
    assert regrown.accuracy >= 0.95


def test_partitioning_reduces_memory(trained_params):
    full = P.run_pipeline(
        P.PipelineConfig(dataset="csa", bits=32, num_partitions=1), trained_params
    )
    parts = P.run_pipeline(
        P.PipelineConfig(dataset="csa", bits=32, num_partitions=8), trained_params
    )
    assert parts.peak_memory_bytes < 0.5 * full.unpartitioned_memory_bytes


def test_kernel_backend_equivalence(trained_params):
    """groot Pallas backend and ref backend agree on predictions."""
    r_ref = P.run_pipeline(
        P.PipelineConfig(dataset="csa", bits=8, backend="ref"), trained_params
    )
    for backend in ("groot", "groot_fused"):
        cfg = P.PipelineConfig(dataset="csa", bits=8, backend=backend)
        r = P.run_pipeline(cfg, trained_params)
        assert r.accuracy == r_ref.accuracy


def test_full_verification_flow(trained_params):
    cfg = P.PipelineConfig(dataset="csa", bits=8, num_partitions=1)
    r = P.run_pipeline(cfg, trained_params, verify_result=True)
    assert r.verdict is not None and r.verdict.status == "verified"
    assert r.verdict.nonlinear_terms_eliminated > 0


def test_batched_graphs(trained_params):
    cfg = P.PipelineConfig(dataset="csa", bits=8, batch=4, num_partitions=2)
    r = P.run_pipeline(cfg, trained_params)
    assert r.accuracy >= 0.95
    assert r.num_nodes == 4 * A.csa_multiplier(8).num_nodes
