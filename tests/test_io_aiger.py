"""AIGER round-trip: parse(write(aig)) preserves structure and semantics.

Acceptance criterion: csa/booth at 8/16/32 bits, binary and ASCII
formats, reproduce simulation semantics; node counts and construction
labels survive the trip.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core import aig as A
from repro.io import aiger


def _sim_vectors(aig: A.AIG, n: int = 64, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.integers(0, 2, (aig.n_pi, n)).astype(bool)


@pytest.mark.parametrize("binary", [True, False], ids=["binary", "ascii"])
@pytest.mark.parametrize("family", ["csa", "booth"])
@pytest.mark.parametrize("bits", [8, 16, 32])
def test_roundtrip_preserves_semantics(family, bits, binary):
    aig = A.make_design(family, bits)
    back = aiger.loads(aiger.dumps(aig, binary=binary))
    assert back.num_nodes == aig.num_nodes
    assert back.n_pi == aig.n_pi
    assert len(back.pos) == len(aig.pos)
    # generated designs keep PIs-then-ANDs-then-POs layout, so labels
    # line up element-wise
    assert np.array_equal(back.label, aig.label)
    v = _sim_vectors(aig)
    assert np.array_equal(back.simulate(v), aig.simulate(v))


def test_ascii_and_binary_parse_identically():
    aig = A.csa_multiplier(8)
    a = aiger.loads(aiger.dumps(aig, binary=False))
    b = aiger.loads(aiger.dumps(aig, binary=True))
    for field in ("kind", "fanin0", "fanin1", "label", "pos"):
        assert np.array_equal(getattr(a, field), getattr(b, field)), field


def test_mapped_and_mixed_decomp_roundtrip():
    aig = A.csa_multiplier(6, mixed_decomp=True, seed=3)
    back = aiger.loads(aiger.dumps(aig))
    v = _sim_vectors(aig)
    assert np.array_equal(back.simulate(v), aig.simulate(v))
    assert np.array_equal(back.label, aig.label)


def test_label_fallback_via_structural_detector():
    """Files without groot comments recover labels structurally."""
    aig = A.csa_multiplier(6)
    back = aiger.loads(aiger.dumps(aig, comments=False))
    assert (back.label == aig.label).mean() > 0.95
    # type-level labels (PI/PO) are always exact
    assert np.array_equal(back.label == A.LABEL_PI, aig.label == A.LABEL_PI)
    assert np.array_equal(back.label == A.LABEL_PO, aig.label == A.LABEL_PO)


def test_structural_hash_is_format_invariant():
    aig = A.booth_multiplier(8)
    h_obj = aiger.structural_hash(aig)
    h_ascii = aiger.structural_hash(aiger.dumps(aig, binary=False))
    h_bin = aiger.structural_hash(aiger.dumps(aig, binary=True, comments=False))
    assert h_obj == h_ascii == h_bin
    assert aiger.structural_hash(A.booth_multiplier(10)) != h_obj
    assert aiger.structural_hash(A.csa_multiplier(8)) != h_obj


def test_dump_load_file(tmp_path):
    aig = A.csa_multiplier(8)
    path = tmp_path / "csa8.aig"
    aiger.dump(aig, path)
    back = aiger.load(path)
    assert back.num_nodes == aig.num_nodes
    v = _sim_vectors(aig)
    assert np.array_equal(back.simulate(v), aig.simulate(v))


def test_rejects_malformed():
    with pytest.raises(aiger.AigerError):
        aiger.loads(b"not an aiger file\n")
    with pytest.raises(aiger.AigerError):
        aiger.loads(b"aag 1 1 1 0 0\n2\n")  # latches unsupported
    with pytest.raises(aiger.AigerError):
        aiger.loads(b"aag 2 1 0 1 1\n2\n4\n4 2 6\n")  # undefined var in AND
