"""Property-based invariants of ``build_plan`` (paper Fig. 5 step B).

The degree count-sort / row-assembly is the load-bearing host-side step:
every kernel result is only correct if the plan (a) covers every edge
exactly once across LD buckets + HD chunks, (b) keeps each ELL slab
degree-homogeneous, (c) marks exactly one ``is_first`` chunk per HD row
(the VMEM accumulation init), and (d) pays at most the pow-2 padding
bound.  Hypothesis (when installed) drives random *degree distributions*
— including polarized ones with rows far beyond ``e_t`` — and a fixed
seed grid covers the same corners in bare environments.
"""
from __future__ import annotations

import numpy as np
import pytest

try:
    import hypothesis
    import hypothesis.strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.kernels.groot_spmm import build_plan


def graph_from_degrees(rng, n: int, e_t: int, hd_frac: float, scale: int):
    """Random graph built from an explicit degree sequence so every LD
    bucket and the HD path can be forced deterministically."""
    deg = rng.geometric(p=0.35, size=n) - 1          # mostly 0..12
    deg = np.minimum(deg * scale, 4 * e_t)
    hd_rows = rng.random(n) < hd_frac
    deg[hd_rows] += rng.integers(e_t + 1, 3 * e_t + 1, size=int(hd_rows.sum()))
    dst = np.repeat(np.arange(n, dtype=np.int64), deg)
    src = rng.integers(0, n, dst.shape[0], dtype=np.int64)
    perm = rng.permutation(dst.shape[0])             # edge order must not matter
    return src[perm], dst[perm]


def check_plan_invariants(src, dst, n: int, e_t: int) -> None:
    e = int(dst.shape[0])
    deg = np.bincount(dst, minlength=n)
    plan = build_plan(src, dst, n, e_t=e_t)

    # --- (a) every edge id appears exactly once across LD buckets + HD ---
    all_eids = [b.eids for b in plan.buckets]
    if plan.hd is not None:
        all_eids.append(plan.hd.eids)
    seen = np.concatenate(all_eids) if all_eids else np.zeros(0, np.int64)
    real = np.sort(seen[seen < e])
    np.testing.assert_array_equal(real, np.arange(e))
    # and each edge's slot points at its true source
    for b in plan.buckets:
        live = b.eids < e
        np.testing.assert_array_equal(b.cols[live], src[b.eids[live]])
        assert (b.cols[~live] == n).all()
    if plan.hd is not None:
        live = plan.hd.eids < e
        np.testing.assert_array_equal(plan.hd.cols[live], src[plan.hd.eids[live]])

    # --- (b) buckets are degree-homogeneous ELL slabs ---
    for b in plan.buckets:
        lo = 1 if b.deg == 1 else b.deg // 2 + 1
        rows = b.rows[b.rows >= 0]
        assert ((deg[rows] >= lo) & (deg[rows] <= b.deg)).all(), (
            f"bucket d={b.deg} holds rows outside ({lo}, {b.deg}]"
        )
        # each row owns exactly deg[row] real slots of its d-slot stride
        slab = (b.eids < e).reshape(-1, b.deg)
        np.testing.assert_array_equal(slab.sum(axis=1)[: rows.size], deg[rows])
        assert not slab[rows.size:].any()            # padding rows: no real slots
        assert b.rows.size % b.rows_per_tile == 0    # tile-aligned

    # --- (c) HD metadata: exactly one is_first per row, chunks contiguous ---
    if plan.hd is not None:
        assert (deg[plan.hd.rows] > e_t).all()
        meta = plan.hd.chunk_meta
        for slot, r in enumerate(plan.hd.rows):
            idx = np.where(meta[:, 0] == slot)[0]
            assert idx.size == -(-deg[r] // e_t)     # ceil(deg / e_t) chunks
            assert idx.size and meta[idx, 1].sum() == 1
            assert meta[idx[0], 1] == 1              # first chunk initialises
            np.testing.assert_array_equal(idx, np.arange(idx[0], idx[0] + idx.size))
    if plan.buckets:
        ld_rows = np.concatenate([b.rows[b.rows >= 0] for b in plan.buckets])
        assert (deg[ld_rows] <= e_t).all()

    # --- (d) padded slots <= 2x + tile rounding ---
    slots = sum(b.eids.size for b in plan.buckets)
    slots += plan.hd.eids.size if plan.hd is not None else 0
    slack = sum(b.rows_per_tile * b.deg for b in plan.buckets)
    if plan.hd is not None:
        slack += len(plan.hd.rows) * e_t
    assert slots <= 2 * e + slack, (
        f"padding blew the pow-2 bound: {slots} slots for {e} edges "
        f"(+{slack} tile slack)"
    )
    assert plan.padding_overhead() == pytest.approx(slots / max(e, 1))


_CASES = [
    # (n, e_t, hd_frac, scale, seed)
    (2, 512, 0.0, 1, 0),
    (40, 512, 0.0, 1, 1),
    (100, 64, 0.05, 1, 2),        # HD rows just past a small threshold
    (150, 8, 0.2, 1, 3),          # tiny e_t: nearly everything is HD
    (64, 512, 0.0, 40, 4),        # deep LD buckets (deg up to ~500)
    (33, 128, 0.1, 7, 5),
    (120, 512, 0.02, 1, 6),
    (5, 16, 0.5, 1, 7),
]


if HAVE_HYPOTHESIS:

    @hypothesis.given(
        n=st.integers(2, 150),
        e_t=st.sampled_from([8, 64, 512]),
        hd_frac=st.sampled_from([0.0, 0.05, 0.3]),
        scale=st.sampled_from([1, 7, 40]),
        seed=st.integers(0, 2**31 - 1),
    )
    @hypothesis.settings(max_examples=30, deadline=None)
    def test_plan_invariants(n, e_t, hd_frac, scale, seed):
        rng = np.random.default_rng(seed)
        src, dst = graph_from_degrees(rng, n, e_t, hd_frac, scale)
        check_plan_invariants(src, dst, n, e_t)

else:

    @pytest.mark.parametrize("n,e_t,hd_frac,scale,seed", _CASES)
    def test_plan_invariants(n, e_t, hd_frac, scale, seed):
        rng = np.random.default_rng(seed)
        src, dst = graph_from_degrees(rng, n, e_t, hd_frac, scale)
        check_plan_invariants(src, dst, n, e_t)


def test_empty_graph_plan():
    plan = build_plan(np.zeros(0, np.int64), np.zeros(0, np.int64), 8)
    assert plan.buckets == () and plan.hd is None
    assert plan.padding_overhead() == 0.0
