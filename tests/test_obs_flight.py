"""Flight recorder (`repro.obs.flight`) + its service/session wiring.

Fast lane, untrained params.  What is pinned here:

  * the ring is bounded and thread-safe: N threads hammering ``record``
    lose no updates and never exceed capacity (same for
    ``Histogram.observe`` — the concurrent-metrics satellite);
  * the stage-timeline contract: marks are monotonic and the derived
    segment durations tile the timeline exactly
    (``sum(stages) == total_s``);
  * every completed or failed service ticket leaves a record — normal
    completions carry bucket/capacity and a queue-wait segment, cache
    hits are flagged ``cached``, coalesced followers ``coalesced``,
    failures carry the attributable name + cause and dump a JSON
    forensic file at failure time;
  * sync ``Session.verify`` records flights too (negative ids), so
    ``Session.flights()`` is one view over both paths.
"""
from __future__ import annotations

import json
import threading

import jax
import pytest

from repro.core import gnn
from repro.obs import FlightRecorder, record_from_marks
from repro.obs.flight import stages_from_marks
from repro.obs.metrics import MetricsRegistry
from repro.service import VerificationService


@pytest.fixture(scope="module")
def rand_params():
    return gnn.init_params(gnn.GNNConfig(), jax.random.key(0))


def make_service(params, **overrides):
    overrides.setdefault("num_partitions", 1)
    overrides.setdefault("prepare_workers", 2)
    return VerificationService(params, _warn=False, **overrides)


def check_timeline(rec):
    """The assertable contract: monotonic marks, stages tile the total."""
    times = [t for _, t in rec.marks]
    assert times == sorted(times), f"non-monotonic marks: {rec.marks}"
    assert sum(rec.stages.values()) == pytest.approx(rec.total_s, abs=1e-9)
    assert rec.total_s >= 0.0


# ---------------------------------------------------------------------------
# unit: marks -> stages
# ---------------------------------------------------------------------------

def test_stages_tile_timeline_exactly():
    marks = [("submit", 1.0), ("prepared", 1.25), ("admitted", 1.75),
             ("inferred", 2.0), ("done", 2.125)]
    stages, total = stages_from_marks(marks)
    assert stages == {"prepare": 0.25, "queue_wait": 0.5, "infer": 0.25,
                      "finalize": 0.125}
    assert total == pytest.approx(1.125)
    assert sum(stages.values()) == pytest.approx(total)


def test_cache_hit_timeline_is_one_segment():
    stages, total = stages_from_marks([("submit", 3.0), ("done", 3.5)])
    assert stages == {"finalize": 0.5} and total == pytest.approx(0.5)


def test_record_from_marks_derives_failed_stage():
    # died after "prepared": the failing segment is the queue-wait
    rec = record_from_marks(7, "x", "error",
                            [("submit", 0.0), ("prepared", 1.0)],
                            error="RuntimeError: boom")
    assert rec.failed_stage == "queue_wait"
    assert not rec.ok and rec.error == "RuntimeError: boom"
    check_timeline(rec)
    # an explicit failed_stage wins over derivation
    rec2 = record_from_marks(8, "x", "error", [("submit", 0.0)],
                             failed_stage="prepare")
    assert rec2.failed_stage == "prepare"


def test_record_to_dict_is_json_safe():
    rec = record_from_marks(1, "csa:8", "verified",
                            [("submit", 0.0), ("done", 0.25)],
                            bucket=(64, 128), capacity=2, tenant="acme")
    d = json.loads(json.dumps(rec.to_dict()))
    assert d["bucket"] == [64, 128] and d["tenant"] == "acme"
    assert d["marks"] == [["submit", 0.0], ["done", 0.25]]


# ---------------------------------------------------------------------------
# ring semantics + concurrency (the lost-update satellite)
# ---------------------------------------------------------------------------

def test_ring_bound_and_stats():
    fr = FlightRecorder(capacity=4)
    for i in range(10):
        fr.record(record_from_marks(i, "d", "error" if i % 3 == 0 else "ok",
                                    [("submit", 0.0), ("done", 1.0)]))
    st = fr.stats()
    assert len(fr) == 4 and st["retained"] == 4
    assert st["recorded"] == 10 and st["dropped"] == 6
    assert st["failures"] == 4                       # ids 0, 3, 6, 9
    assert st["last"]["req_id"] == 9
    # the ring keeps the newest records
    assert [r.req_id for r in fr.records()] == [6, 7, 8, 9]
    assert [r.req_id for r in fr.records(failures_only=True)] == [6, 9]


def test_concurrent_flight_records_lose_nothing():
    fr = FlightRecorder(capacity=64)
    threads, per = 8, 250

    def hammer(tid):
        for i in range(per):
            fr.record(record_from_marks(tid * per + i, "d", "ok",
                                        [("submit", 0.0), ("done", 1.0)]))

    ts = [threading.Thread(target=hammer, args=(t,)) for t in range(threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    st = fr.stats()
    assert st["recorded"] == threads * per           # no lost updates
    assert st["retained"] == 64 == len(fr)           # bound respected
    assert st["dropped"] == threads * per - 64


def test_concurrent_histogram_observes_lose_nothing():
    reg = MetricsRegistry()
    h = reg.histogram("svc.latency_s")
    threads, per = 8, 500

    def hammer():
        for i in range(per):
            h.observe(i * 1e-4)

    ts = [threading.Thread(target=hammer) for _ in range(threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    s = h.summary()
    assert s["count"] == threads * per               # count == observes
    assert s["min"] >= 0.0 and s["max"] <= per * 1e-4
    assert s["min"] <= s["p50"] <= s["p95"] <= s["max"]


def test_dump_roundtrip(tmp_path):
    fr = FlightRecorder(capacity=8)
    fr.record(record_from_marks(0, "a", "verified",
                                [("submit", 0.0), ("done", 1.0)]))
    fr.record(record_from_marks(1, "b", "error", [("submit", 0.0)],
                                error="ValueError: nope"))
    path = tmp_path / "flights.json"
    assert fr.dump(path) == 2
    data = json.loads(path.read_text())
    assert [d["req_id"] for d in data] == [0, 1]
    assert fr.dump(path, failures_only=True) == 1


# ---------------------------------------------------------------------------
# service wiring: every ticket leaves a consistent record
# ---------------------------------------------------------------------------

def test_completed_tickets_yield_consistent_flights(rand_params):
    svc = make_service(rand_params)
    tickets = [svc.submit(dataset="csa", bits=4, seed=s, verify=False)
               for s in range(3)]
    for t in tickets:
        assert svc.result(t, timeout=60.0).status == "classified"
    recs = {r.req_id: r for r in svc.flights.records()}
    assert set(tickets) <= set(recs)
    for t in tickets:
        rec = recs[t]
        assert rec.ok and rec.status == "classified"
        check_timeline(rec)
        assert [s for s, _ in rec.marks] == [
            "submit", "prepared", "admitted", "inferred", "done"
        ]
        # a full run has a queue-wait and all stage segments
        assert set(rec.stages) == {"prepare", "queue_wait", "infer",
                                   "finalize"}
        assert rec.bucket is not None and rec.capacity == svc.config.capacity
        assert not rec.cached and not rec.coalesced and not rec.streamed
    st = svc.stats()
    assert st["flights"]["recorded"] >= 3
    assert st["flights"]["failures"] == 0
    # the peaks satellite: gauge high-water marks surface in stats()
    assert st["peaks"]["service.slot_occupancy"] > 0
    svc.close()


def test_cache_hit_and_coalesced_flights_are_flagged(rand_params):
    svc = make_service(rand_params)
    t1 = svc.submit(dataset="csa", bits=4, seed=0, verify=False)
    svc.result(t1, timeout=60.0)
    t2 = svc.submit(dataset="csa", bits=4, seed=0, verify=False)  # cache hit
    assert svc.result(t2, timeout=60.0).cached
    recs = {r.req_id: r for r in svc.flights.records()}
    assert not recs[t1].cached
    hit = recs[t2]
    assert hit.cached and not hit.coalesced
    check_timeline(hit)
    assert [s for s, _ in hit.marks] == ["submit", "done"]
    svc.close()


def test_failed_ticket_flight_carries_name_cause_and_dumps(
        rand_params, tmp_path):
    svc = make_service(rand_params, flight_dump_dir=str(tmp_path))
    t = svc.submit(dataset="no-such-family", bits=8)
    r = svc.result(t, timeout=60.0)
    assert r.status == "error"
    rec = {x.req_id: x for x in svc.flights.records(failures_only=True)}[t]
    assert rec.name == "no-such-family:8"            # attributable name
    assert rec.error and "no-such-family" in rec.error
    assert rec.failed_stage == "prepare"             # died before "prepared"
    check_timeline(rec)
    # dump-on-failure: the forensic trail survives the process
    dump = tmp_path / f"flight_fail_{t}.json"
    assert dump.exists()
    payload = json.loads(dump.read_text())
    assert payload["failure"]["req_id"] == t
    assert payload["failure"]["error"] == rec.error
    assert any(c["req_id"] == t for c in payload["context"])
    assert svc.stats()["flights"]["failures"] >= 1
    svc.close()


def test_session_flights_cover_sync_and_async(rand_params):
    from repro.api import Session, SessionConfig

    with Session(rand_params, SessionConfig(flight_records=32)) as sess:
        r = sess.verify(dataset="csa", bits=4, verify=False, use_cache=False)
        assert r.status == "classified"
        ticket = sess.submit(dataset="csa", bits=4, seed=1, verify=False)
        sess.result(ticket, timeout=60.0)
        flights = sess.flights()
    ids = [f.req_id for f in flights]
    assert -1 in ids                                  # the sync verify
    assert ticket in ids                              # the service ticket
    sync = next(f for f in flights if f.req_id == -1)
    assert [s for s, _ in sync.marks] == ["submit", "prepared", "inferred",
                                          "done"]
    check_timeline(sync)
    for f in flights:
        check_timeline(f)
