"""Streaming executor (repro.exec): plans, packing, bit-exactness, probes.

Fast lane (every push): a small ref-backend grid pins down

  * choose_k budget monotonicity and plan-cache identity,
  * schedule completeness (every partition packed exactly once),
  * bit-exact parity with the sequential ``predict_partitioned_loop``,
  * the compile-count probe: <= num_buckets jit compiles for ANY k,
  * scheduler auto-routing of oversized items.

Slow lane: the Pallas ``groot`` backend parity and a 256-bit CSA
(~530k nodes) streamed end to end under the memory model.
"""
from __future__ import annotations

import numpy as np
import pytest

import jax

from repro.core import aig as A
from repro.core import gnn
from repro.core import pipeline as P
from repro.core.features import groot_features
from repro.core.partition import PARTITIONERS
from repro.core.regrowth import extract_partitions
from repro.exec import (
    StreamingExecutor,
    build_partition_plan,
    choose_k,
    choose_k_for_caps,
    plan_from_subgraphs,
)
from repro.exec.plan import _estimated_batch_bytes


@pytest.fixture(scope="module")
def rand_params():
    return gnn.init_params(gnn.GNNConfig(), jax.random.key(0))


@pytest.fixture(scope="module")
def csa12():
    d = A.csa_multiplier(12)
    return d.to_edge_graph(), groot_features(d)


def _subgraphs(g, k, partitioner="multilevel", regrow=True, seed=0):
    part = PARTITIONERS[partitioner](g, k, seed=seed)
    return extract_partitions(g, part, regrow=regrow)


# ---------------------------------------------------------------------------
# PartitionPlan / choose_k
# ---------------------------------------------------------------------------

def test_choose_k_monotone_and_fits_budget():
    cfg = gnn.GNNConfig()
    n, e = 100_000, 200_000
    full = P.memory_model_bytes(n, e, cfg)
    ks = [choose_k(n, e, cfg, budget) for budget in (full, full // 2, full // 8)]
    assert ks == sorted(ks)                       # tighter budget -> more parts
    for budget, k in zip((full, full // 2, full // 8), ks):
        if k < n:                                 # not capped
            assert _estimated_batch_bytes(
                n, e, k, cfg, 2, halo_frac=0.15, min_nodes=64, min_edges=128
            ) <= budget
    assert choose_k(0, 0, cfg, 1) == 1            # empty design


def test_choose_k_for_caps_respects_bucket_ceiling():
    k = choose_k_for_caps(100_000, 200_000, max_bucket_nodes=16384)
    assert k > 1
    n_part = int(np.ceil(100_000 / k * 1.15))
    from repro.kernels import ops

    n_pad, _ = ops.padded_shape(n_part, 1, min_nodes=64, min_edges=128)
    assert n_pad <= 16384


def test_build_partition_plan_is_content_cached(csa12):
    from repro.exec.plan import EXEC_PLAN_CACHE

    g, _ = csa12
    before = EXEC_PLAN_CACHE.snapshot()
    p1 = build_partition_plan(g, 4, seed=0)
    p2 = build_partition_plan(g, 4, seed=0)
    after = EXEC_PLAN_CACHE.snapshot()
    assert p1 is p2                               # same object, jit-friendly
    assert after.builds - before.builds <= 1      # built at most once
    p3 = build_partition_plan(g, 4, seed=1)       # different knobs -> new plan
    assert p3 is not p1


def test_plan_cache_distinguishes_edge_annotations(csa12):
    """Same connectivity, different inverter placement -> different plan.
    (graph_key hashes endpoints only; the exec-plan key must also cover
    edge_inv/edge_slot because Subgraphs embed their slices.)"""
    from repro.core.graph import EdgeGraph

    g, _ = csa12
    inv_a = np.zeros(g.num_edges, bool)
    inv_b = np.ones(g.num_edges, bool)
    ga = EdgeGraph(g.num_nodes, g.edge_src, g.edge_dst, inv_a, g.edge_slot)
    gb = EdgeGraph(g.num_nodes, g.edge_src, g.edge_dst, inv_b, g.edge_slot)
    pa = build_partition_plan(ga, 4, seed=0)
    pb = build_partition_plan(gb, 4, seed=0)
    assert pa is not pb
    assert pa.subgraphs[0].edge_inv is not None
    assert not pa.subgraphs[0].edge_inv.any()
    assert pb.subgraphs[0].edge_inv.all()


def test_empty_graph_pipeline_partitioned_request(rand_params):
    """A 0-node design with num_partitions > 1 must not crash the
    partitioned/streaming path (falls back to unpartitioned)."""
    from repro.core import aig as A

    design = A.AIG(
        name="empty",
        kind=np.zeros(0, np.int8),
        fanin0=np.zeros(0, np.int64),
        fanin1=np.zeros(0, np.int64),
        label=np.zeros(0, np.int8),
        n_pi=0,
        pos=np.zeros(0, np.int64),
    )
    cfg = P.PipelineConfig(dataset="csa", bits=4, num_partitions=4)
    prep = P.prepare(cfg, design)
    assert prep.subgraphs is None
    pred = P.infer(rand_params, prep)
    assert pred.shape == (0,)


def test_plan_schedule_covers_every_partition_once(csa12):
    g, _ = csa12
    plan = build_partition_plan(g, 8, seed=0)
    for capacity in (1, 2, 4):
        sched = plan.schedule(capacity)
        seen = [i for _, idxs in sched for i in idxs]
        assert sorted(seen) == list(range(plan.num_parts))
        for shape, idxs in sched:
            assert 0 < len(idxs) <= capacity
            for i in idxs:                        # same-bucket packing only
                assert plan.buckets[plan.bucket_of[i]] == shape


def test_plan_peak_batch_memory_scales_with_capacity(csa12):
    g, _ = csa12
    plan = build_partition_plan(g, 4, seed=0)
    cfg = gnn.GNNConfig()
    m1 = plan.peak_batch_memory_bytes(cfg, 1)
    m4 = plan.peak_batch_memory_bytes(cfg, 4)
    assert 0 < m1 < m4


# ---------------------------------------------------------------------------
# StreamingExecutor: parity + probes (ref backend, fast)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", [2, 4, 8])
def test_stream_matches_sequential_loop_bit_exact(rand_params, csa12, k):
    g, feats = csa12
    subs = _subgraphs(g, k)
    loop = gnn.predict_partitioned_loop(rand_params, subs, feats, g.num_nodes, "ref")
    ex = StreamingExecutor(rand_params, "ref", capacity=2, prefetch=1)
    out = ex.run_subgraphs(subs, feats, g.num_nodes)
    np.testing.assert_array_equal(out, loop)


@pytest.mark.parametrize("k", [2, 4, 8, 16])
def test_compile_probe_at_most_num_buckets_for_any_k(rand_params, csa12, k):
    """The acceptance criterion: one fresh executor, any partition count,
    shape-stable backend -> compiles <= number of distinct buckets."""
    g, feats = csa12
    plan = build_partition_plan(g, k, seed=0)
    ex = StreamingExecutor(rand_params, "ref", capacity=2)
    ex.run_plan(plan, feats)
    assert 0 < ex.stats.compiles <= plan.num_buckets
    assert ex.stats.partitions == plan.num_parts
    # re-running the same plan compiles nothing new
    before = ex.stats.compiles
    ex.run_plan(plan, feats)
    assert ex.stats.compiles == before


def test_shared_executor_across_k_grid_compiles_by_bucket(rand_params, csa12):
    g, feats = csa12
    ex = StreamingExecutor(rand_params, "ref", capacity=2)
    for k in (2, 4, 8):
        ex.run_plan(build_partition_plan(g, k, seed=0), feats)
    assert ex.stats.compiles <= len(ex.buckets_seen)


def test_prefetch_depths_agree(rand_params, csa12):
    g, feats = csa12
    subs = _subgraphs(g, 8)
    outs = []
    for prefetch in (0, 1, 3):
        ex = StreamingExecutor(rand_params, "ref", capacity=2, prefetch=prefetch)
        outs.append(ex.run_subgraphs(subs, feats, g.num_nodes))
    np.testing.assert_array_equal(outs[0], outs[1])
    np.testing.assert_array_equal(outs[0], outs[2])


def test_stream_stats_probe_counters(rand_params, csa12):
    g, feats = csa12
    plan = build_partition_plan(g, 4, seed=0)
    ex = StreamingExecutor(rand_params, "ref", capacity=2, prefetch=2)
    ex.run_plan(plan, feats)
    s = ex.stats
    assert s.runs == 1
    assert s.batches == s.launches == len(plan.schedule(2))
    assert s.partitions == plan.num_parts
    assert s.core_rows == g.num_nodes             # scatter is complete
    assert s.bytes_h2d > 0 and s.pack_s >= 0.0 and s.device_s > 0.0


def test_prefetch_thread_error_propagates(rand_params, csa12):
    g, _ = csa12
    plan = build_partition_plan(g, 4, seed=0)
    bad_feats = np.zeros((3, 4), np.float32)      # too few rows: pack must fail
    ex = StreamingExecutor(rand_params, "ref", capacity=2, prefetch=1)
    with pytest.raises(Exception):
        ex.run_plan(plan, bad_feats)


def test_pipeline_budget_mode_partitions_to_fit(rand_params):
    full = P.memory_model_bytes(2110, 4124, gnn.GNNConfig())
    cfg = P.PipelineConfig(dataset="csa", bits=16, memory_budget_bytes=full // 3)
    r = P.run_pipeline(cfg, rand_params)
    assert r.exec_stats["chosen_k"] > 1            # the budget forced a cut
    # packed launches are strictly smaller than the full-graph figure (at
    # this tiny scale halo + pow-2 padding eat most of the 1/k win; the
    # 256-bit slow test asserts the real <50% criterion)
    assert r.exec_stats["peak_packed_memory_bytes"] < full
    assert r.exec_stats["compiles"] <= r.exec_stats["num_buckets"]


# ---------------------------------------------------------------------------
# Scheduler auto-routing (oversized items stream instead of rejecting)
# ---------------------------------------------------------------------------

def test_scheduler_streams_oversized_item_bit_exact(rand_params):
    from repro.service.bucketing import items_from_prepared
    from repro.service.scheduler import ShapeBucketScheduler

    prep = P.prepare(P.PipelineConfig(dataset="csa", bits=16))  # 2110 nodes
    items = items_from_prepared(7, prep)
    sched = ShapeBucketScheduler(rand_params, max_bucket_nodes=1024)
    out = sched.run_items(items)
    stats = sched.stats()
    assert stats.streamed_items == 1
    assert stats.compile_count <= len(stats.buckets)

    # replicate the scheduler's internal plan -> bit-exact oracle
    k = choose_k_for_caps(prep.num_nodes, prep.num_edges, 1024)
    assert k > 1
    subs = _subgraphs(prep.graph, k)
    ref = gnn.predict_partitioned_loop(
        rand_params, subs, prep.feats, prep.num_nodes, "ref"
    )
    np.testing.assert_array_equal(out[(7, 0)], ref)

    # small items keep taking the packed-bucket path
    small = items_from_prepared(8, P.prepare(P.PipelineConfig(dataset="csa", bits=6)))
    out2 = sched.run_items(small)
    assert sched.stats().streamed_items == 1      # unchanged
    assert out2[(8, 0)].shape[0] == small[0].num_nodes


# ---------------------------------------------------------------------------
# Slow lane: groot parity + large-design streaming
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_stream_matches_loop_bit_exact_groot(rand_params):
    d = A.csa_multiplier(8)
    g, feats = d.to_edge_graph(), groot_features(d)
    subs = _subgraphs(g, 2)
    loop = gnn.predict_partitioned_loop(rand_params, subs, feats, g.num_nodes, "groot")
    ex = StreamingExecutor(rand_params, "groot", capacity=2)
    out = ex.run_subgraphs(subs, feats, g.num_nodes)
    np.testing.assert_array_equal(out, loop)


@pytest.mark.slow
def test_large_design_streams_under_memory_model(rand_params):
    """256-bit CSA (~530k nodes) through the executor: scatter complete,
    compile probe bounded, peak packed launch < 50% of the full-graph
    memory model."""
    d = A.csa_multiplier(256)
    g, feats = d.to_edge_graph(), groot_features(d)
    plan = build_partition_plan(g, 16, partitioner="multilevel", seed=0)
    ex = StreamingExecutor(rand_params, "ref", capacity=2, prefetch=1)
    out = ex.run_plan(plan, feats)
    assert ex.stats.core_rows == g.num_nodes
    assert ex.stats.compiles <= plan.num_buckets
    cfg = gnn.GNNConfig()
    full = P.memory_model_bytes(g.num_nodes, g.num_edges, cfg)
    peak = plan.peak_batch_memory_bytes(cfg, ex.capacity)
    assert peak < 0.5 * full
    assert out.shape == (g.num_nodes,)
