"""Multi-device distribution tests.

These need >1 device, so each test runs a small script in a subprocess
with ``--xla_force_host_platform_device_count=8`` (the main test process
must keep seeing 1 device — per the brief, the 512-device override belongs
to the dry-run only).
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

pytestmark = pytest.mark.slow  # subprocess jax inits + compiles; full lane

REPO = Path(__file__).resolve().parent.parent


def run_subprocess(code: str, devices: int = 8):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(REPO / "src")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True,
        text=True,
        env=env,
        timeout=420,
        cwd=REPO,
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    return proc.stdout


def test_sharded_train_step_matches_single_device():
    """The distributed train step (2x4 mesh, FSDP+TP, microbatching) and
    the unsharded step produce the same loss and parameter update."""
    run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.zoo.configs import get_config
        from repro.zoo.configs.base import materialize, model_spec_tree
        from repro.sharding.rules import make_rules, tree_shardings, use_sharding
        from repro.training import optimizer as opt_mod
        from repro.training.train_step import make_train_step

        cfg = get_config("qwen3-8b", smoke=True)
        spec = model_spec_tree(cfg)
        params = materialize(spec, jax.random.key(0), jnp.float32)
        opt = opt_mod.AdamW(lr=1e-3)
        tokens = jnp.asarray(
            np.random.default_rng(0).integers(0, cfg.vocab_size, (8, 33)), jnp.int32)
        batch = {"tokens": tokens}

        # single device reference
        step = make_train_step(cfg, opt, microbatches=2)
        p1, _, m1 = jax.jit(step)(params, opt.init(params), batch)

        # sharded
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rules = make_rules(mesh, fsdp=True)
        shard_tree = tree_shardings(spec, mesh, rules)
        with use_sharding(mesh, fsdp=True):
            ps = jax.device_put(params, shard_tree)
            p2, _, m2 = jax.jit(step)(ps, opt.init(ps), batch)

        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=2e-3)
        d = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), p1, p2)
        assert max(jax.tree.leaves(d)) < 5e-3, max(jax.tree.leaves(d))
        print("sharded == single-device: OK")
    """)


def test_shard_map_moe_matches_dense_path():
    """moe_ffn_dist (shard_map EP) == moe_ffn (single-device reference)."""
    run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from repro.zoo.configs import get_config
        from repro.zoo.configs.base import materialize, param_tree
        from repro.zoo.models.moe import moe_ffn, moe_ffn_dist
        from repro.sharding.rules import use_sharding

        cfg = dataclasses.replace(
            get_config("qwen3-moe-235b-a22b", smoke=True),
            num_experts=8, top_k=2, capacity_factor=8.0)
        p = materialize(param_tree(cfg)["layers"][0]["moe"], jax.random.key(1),
                        jnp.float32)
        x = jax.random.normal(jax.random.key(2), (4, 8, cfg.d_model), jnp.float32)
        want = moe_ffn(x, p, cfg)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        with use_sharding(mesh):
            got = jax.jit(lambda x: moe_ffn_dist(x, p, cfg))(x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)
        print("shard_map MoE == dense reference: OK")
    """)


def test_pipeline_parallel_matches_sequential():
    """GPipe ppermute schedule == applying the stages sequentially."""
    run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np, functools
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.distributed.pipeline_parallel import pipeline_apply

        n_stages, n_micro, b, d = 4, 8, 2, 16
        mesh = jax.make_mesh((n_stages,), ("stage",))
        ws = jax.random.normal(jax.random.key(0), (n_stages, d, d)) * 0.3

        def stage_fn(w, x):
            return jnp.tanh(x @ w)

        x_mb = jax.random.normal(jax.random.key(1), (n_micro, b, d))

        # sequential reference
        want = x_mb
        for s in range(n_stages):
            want = jax.vmap(lambda xx: stage_fn(ws[s], xx))(want)

        fn = shard_map(
            functools.partial(pipeline_apply, stage_fn, axis="stage"),
            mesh=mesh,
            in_specs=(P("stage"), P()),
            out_specs=P(),
            check_rep=False,
        )
        got = jax.jit(fn)(ws, x_mb)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
        print("pipeline == sequential: OK")
    """)


def test_grad_compression_error_feedback():
    """int8 psum with error feedback: biased per step, unbiased over steps;
    compression ratio ~0.26."""
    run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np, functools
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.distributed.grad_compression import (
            compressed_psum, compression_ratio, init_error_state)

        mesh = jax.make_mesh((4,), ("pods",))
        g_all = jax.random.normal(jax.random.key(0), (4, 64, 128))
        grads = {"w": g_all}
        err = init_error_state({"w": g_all[0]})

        def body(g, e):
            out, e2 = compressed_psum({"w": g[0]}, "pods", {"w": e})
            return out["w"], e2["w"][None]

        fn = shard_map(body, mesh=mesh, in_specs=(P("pods"), P()),
                       out_specs=(P(), P("pods")), check_rep=False)
        out, err2 = jax.jit(fn)(g_all, err["w"])
        want = g_all.mean(0)
        # single-shot int8 psum: close but quantised
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=0.05)
        # error feedback captured the residual
        assert float(jnp.abs(err2).max()) > 0
        r = compression_ratio({"w": g_all[0]})
        assert r < 0.3, r
        print("compressed psum: OK, ratio", r)
    """)


def test_elastic_mesh_choice():
    run_subprocess("""
        from repro.distributed.elastic import choose_mesh, replan_batch
        m = choose_mesh(8, prefer_model=4)
        assert dict(m.shape) == {"data": 2, "model": 4}, dict(m.shape)
        m2 = choose_mesh(6, prefer_model=4)   # degraded topology
        assert dict(m2.shape) == {"data": 3, "model": 2}
        plan = replan_batch(96, old_data=4, new_data=3)
        assert plan["per_device_batch_new"] == 32
        print("elastic mesh: OK")
    """)


def test_dryrun_cell_compiles_on_tiny_mesh():
    """The dry-run cell builder lowers+compiles on a small (2,4) mesh —
    the same path the 512-chip run takes, runnable in CI."""
    run_subprocess("""
        import jax
        from repro.zoo.configs import get_config
        from repro.launch.steps import build_cell
        from repro.launch.dryrun import run_cell
        from jax.sharding import Mesh
        import numpy as np
        # note: importing repro.launch.dryrun sets the 512-device flag
        # (its brief-mandated first lines); use the first 8 devices.
        mesh = Mesh(np.asarray(jax.devices()[:8]).reshape(2, 4), ("data", "model"))
        cfg = get_config("qwen3-8b", smoke=True)
        import dataclasses
        # shrink the shape grid to smoke scale by monkeypatching SHAPES
        from repro.zoo.configs import shapes as S
        small = {"train_4k": S.ShapeSpec("train_4k", 64, 8, "train"),
                 "decode_32k": S.ShapeSpec("decode_32k", 64, 8, "decode")}
        S.SHAPES.clear(); S.SHAPES.update(small)
        for shape in ("train_4k", "decode_32k"):
            cell = build_cell(cfg, shape, mesh)
            rec = run_cell(cell, mesh, "test", save=False)
            assert rec["hlo"]["dot_flops_per_device"] > 0
        print("tiny-mesh dryrun cells: OK")
    """)
