"""Forward-invariant hoisting: ForwardPlan staging, assembly, and probes.

Four contracts of the hoisted hot path:

  * **staging is a permutation** — the weight streams the ForwardPlan
    gathers once into kernel (ELL / HD-chunk) layout carry exactly the
    per-layer gathered values: every real edge id appears exactly once
    across the concatenated streams, pad slots read the zero weight row,
    and each bucket's staged slab equals ``wg[b.eids]``;
  * **scatter-free assembly** — ``asm_index`` is an inverse count-sort
    permutation: gathering the concatenated bucket/HD reductions
    reproduces the scatter-based assembly bit for bit (and no row is
    both LD and HD);
  * **model parity** — hoisted == pre-hoist bit-exact in f32 through full
    forwards (grouped, fused, across ``num_layers`` in {1, 2, 4}), ref
    parity within fp32 tolerance, bf16 streams within a pinned bound;
  * **probe gate** (CI fast lane) — per forward: ``weight_gathers == 2``
    (was ``2 * num_layers``) and ``output_scatters <= 2`` (was
    ``num_segments`` per aggregation) on every groot backend.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gnn
from repro.kernels import ops
from repro.kernels.forward_plan import build_forward_plan
from repro.kernels.groot_spmm import (
    PROBE,
    apply_plan,
    apply_plan_grouped,
    build_plan,
    plan_cat_eids,
    reset_probe,
    stage_group_weights,
)
from tests.test_plan_properties import graph_from_degrees

GROOT_BACKENDS = ("groot", "groot_mxu", "groot_fused")

# Fig.-4-style mixture degree distributions (n, e_t, hd_frac, scale, seed)
MIXTURES = [
    (60, 512, 0.0, 1, 0),        # LD only
    (150, 64, 0.05, 1, 1),       # HD rows past a small threshold
    (90, 512, 0.03, 20, 2),      # deep LD buckets + HD rows
    (40, 16, 0.4, 1, 3),         # HD-heavy
]


def _mixture(case):
    n, e_t, hd_frac, scale, seed = case
    rng = np.random.default_rng(seed)
    src, dst = graph_from_degrees(rng, n, e_t, hd_frac, scale)
    return src, dst, n, e_t


# ---------------------------------------------------------------------------
# Staged weights are a permutation of the per-layer gathered weights
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("case", MIXTURES)
def test_staged_weights_are_permutation_of_per_layer_gather(case):
    src, dst, n, e_t = _mixture(case)
    e = len(src)
    plan = build_plan(src, dst, n, e_t=e_t)
    cat = plan_cat_eids(plan)
    # every real edge id exactly once; pad slots point at the zero row E
    real = np.sort(cat[cat < e])
    np.testing.assert_array_equal(real, np.arange(e))
    assert (cat[cat >= e] == e).all()

    rng = np.random.default_rng(7)
    wg = jnp.asarray(rng.standard_normal((e, 4)), jnp.float32)
    staged = stage_group_weights(plan, wg)
    wg_pad = np.concatenate([np.asarray(wg), np.zeros((1, 4), np.float32)])
    for b, slab in zip(plan.buckets, staged.buckets):
        np.testing.assert_array_equal(
            np.asarray(slab), wg_pad[np.minimum(b.eids, e)]
        )
    if plan.hd is not None:
        np.testing.assert_array_equal(
            np.asarray(staged.hd), wg_pad[np.minimum(plan.hd.eids, e)]
        )


@pytest.mark.parametrize("case", MIXTURES)
def test_assembly_index_is_inverse_count_sort(case):
    src, dst, n, e_t = _mixture(case)
    plan = build_plan(src, dst, n, e_t=e_t)
    assert plan.asm_index is not None and plan.asm_index.dtype == np.int32
    asm = plan.asm_index
    deg = np.bincount(dst, minlength=n)
    # simulate assembly of a concat whose row i holds value i; every
    # degree>0 row must land on its own unique concat slot, degree-0 rows
    # on the trailing zero row
    off = 0
    owner = np.full(plan.asm_rows, -1, dtype=np.int64)
    for b in plan.buckets:
        live = b.rows >= 0
        owner[off : off + int(live.sum())] = b.rows[live]
        off += b.rows.shape[0]
    if plan.hd is not None:
        owner[off : off + len(plan.hd.rows)] = plan.hd.rows
    for r in range(n):
        if deg[r] > 0:
            assert owner[asm[r]] == r
        else:
            assert asm[r] == plan.asm_rows - 1
    # LD and HD row sets are disjoint (the "no add needed" guarantee)
    if plan.hd is not None and plan.buckets:
        ld = np.concatenate([b.rows[b.rows >= 0] for b in plan.buckets])
        assert np.intersect1d(ld, plan.hd.rows).size == 0


@pytest.mark.parametrize("case", MIXTURES[:2])
def test_scatter_free_assembly_matches_scatter(case):
    """Gather-based assembly == the pre-hoist ``at[rows].add`` bit for bit."""
    src, dst, n, e_t = _mixture(case)
    e = len(src)
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal((n, 8)), jnp.float32)
    w = jnp.asarray(rng.standard_normal(e), jnp.float32)
    plan = build_plan(src, dst, n, e_t=e_t)
    got = np.asarray(apply_plan(plan, x, w))
    wg = jnp.stack([w, 2.0 * w], axis=1)
    grouped = np.asarray(apply_plan_grouped(plan, x, wg))
    if plan.hd is None:
        # identical LD kernel reductions -> assembly is pure data
        # movement: bit-exact
        np.testing.assert_array_equal(grouped[0], got)
    else:
        # the grouped HD kernel reduces via matmul (different reduction
        # order than the ungrouped sum) — tolerance, not bits
        np.testing.assert_allclose(grouped[0], got, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Model-level: hoisted vs pre-hoist vs ref, f32 and bf16 streams
# ---------------------------------------------------------------------------

def _forward(params, x, s, d, inv, slot, n, agg, stream_dtype=None):
    return np.asarray(
        gnn.forward(
            params, x, s, d, inv, slot, num_nodes=n, agg=agg,
            stream_dtype=stream_dtype,
        )
    )


@pytest.mark.parametrize("num_layers", [1, 2, 4])
@pytest.mark.parametrize("backend", GROOT_BACKENDS)
def test_hoisted_parity_across_depths(backend, num_layers):
    src, dst, n, e_t = _mixture(MIXTURES[2])
    assert e_t == 512  # full-size threshold: the real kernel config
    e = len(src)
    rng = np.random.default_rng(9)
    cfg = gnn.GNNConfig(in_features=4, hidden=16, num_layers=num_layers)
    params = gnn.init_params(cfg, jax.random.key(1))
    x = jnp.asarray(rng.standard_normal((n, 4)), jnp.float32)
    inv = jnp.asarray(rng.integers(0, 2, e).astype(bool))
    slot = jnp.asarray(rng.integers(0, 2, e).astype(np.uint8))
    s, d = jnp.asarray(src), jnp.asarray(dst)

    pair = ops.make_agg_pair(src, dst, n, backend)
    assert pair.fwd_plan is not None
    want = _forward(params, x, s, d, inv, slot, n, None)
    hoisted = _forward(params, x, s, d, inv, slot, n, pair)
    prehoist = _forward(params, x, s, d, inv, slot, n, ops.unhoisted(pair))
    pergroup = _forward(params, x, s, d, inv, slot, n, ops.ungrouped(pair))

    # f32 hoisting is pure data movement: bit-exact with the pre-hoist walk
    np.testing.assert_array_equal(hoisted, prehoist)
    np.testing.assert_allclose(hoisted, want, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(pergroup, want, rtol=1e-4, atol=1e-4)

    # bf16 streams: pinned tolerance (weights+messages at 8-bit mantissa,
    # f32 accumulation in-kernel)
    bf16 = _forward(params, x, s, d, inv, slot, n, pair, stream_dtype="bfloat16")
    scale = np.maximum(np.abs(want), 1.0)
    assert np.max(np.abs(bf16 - want) / scale) < 0.05 * num_layers


# ---------------------------------------------------------------------------
# Probe gate (CI fast lane): the hoisting acceptance criterion
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", GROOT_BACKENDS)
def test_probe_gate_weight_gathers_and_scatters(backend):
    src, dst, n, e_t = _mixture(MIXTURES[2])
    e = len(src)
    num_layers = 3
    rng = np.random.default_rng(11)
    cfg = gnn.GNNConfig(in_features=4, hidden=8, num_layers=num_layers)
    params = gnn.init_params(cfg, jax.random.key(2))
    x = jnp.asarray(rng.standard_normal((n, 4)), jnp.float32)
    inv = jnp.asarray(rng.integers(0, 2, e).astype(bool))
    slot = jnp.asarray(rng.integers(0, 2, e).astype(np.uint8))
    s, d = jnp.asarray(src), jnp.asarray(dst)
    pair = ops.make_agg_pair(src, dst, n, backend)

    reset_probe()
    jaxpr = jax.make_jaxpr(
        lambda xx, ii, ss: gnn.forward(
            params, xx, s, d, ii, ss, num_nodes=n, agg=pair
        )
    )(x, inv, slot)
    probe = dict(PROBE)
    # hoisted: the weight streams are staged once per direction per FORWARD
    assert probe["weight_gathers"] == 2
    assert probe["output_scatters"] <= 2
    # the measured form of the scatter gate: count scatter-add primitives
    # in the traced forward.  The only ones allowed are the two degree
    # segment-sums of the norm fold (one per direction) — output assembly
    # must contribute ZERO (pre-hoist it emitted num_segments per
    # aggregation per layer).
    assert str(jaxpr).count("scatter-add") <= 2
    assert probe["edge_stream_gathers"] == 2 * num_layers
    assert probe["stream_bytes"] > 0

    reset_probe()
    gnn.forward(params, x, s, d, inv, slot, num_nodes=n, agg=ops.unhoisted(pair))
    # pre-hoist walk re-stages per layer: the reduction being asserted
    assert PROBE["weight_gathers"] == 2 * num_layers
    reset_probe()


def test_grouped_walks_handle_zero_edge_graph():
    """An inputs-only partition (nodes, no edges) must keep the group
    dimension: assembly cannot infer G from an empty part list."""
    n, g = 5, 4
    plan = build_plan(np.zeros(0, np.int64), np.zeros(0, np.int64), n)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((n, 3)), jnp.float32)
    wg = jnp.zeros((0, g), jnp.float32)
    out = apply_plan_grouped(plan, x, wg)
    assert out.shape == (g, n, 3)
    np.testing.assert_array_equal(np.asarray(out), 0.0)


# ---------------------------------------------------------------------------
# Satellites: int32 narrowing
# ---------------------------------------------------------------------------

def test_plan_indices_are_int32():
    src, dst, n, e_t = _mixture(MIXTURES[1])
    plan = build_plan(src, dst, n, e_t=e_t)
    for b in plan.buckets:
        assert b.cols.dtype == np.int32 and b.eids.dtype == np.int32
    if plan.hd is not None:
        assert plan.hd.cols.dtype == np.int32 and plan.hd.eids.dtype == np.int32
    fp = build_forward_plan(plan, build_plan(dst, src, n, e_t=e_t))
    assert fp.in_cat_eids.dtype == np.int32
    assert fp.out_cat_eids.dtype == np.int32


def test_partitioned_predictions_int32_end_to_end():
    from repro.core import aig as A
    from repro.core.features import groot_features
    from repro.core.partition import PARTITIONERS
    from repro.core.regrowth import extract_partitions
    from repro.exec.stream import stream_predict_partitioned

    d = A.csa_multiplier(8)
    g = d.to_edge_graph()
    feats = groot_features(d)
    cfg = gnn.GNNConfig(in_features=feats.shape[1], hidden=8, num_layers=2)
    params = gnn.init_params(cfg, jax.random.key(0))
    part = PARTITIONERS["multilevel"](g, 2, seed=0)
    subs = extract_partitions(g, part, regrow=True, hops=2)
    loop = gnn.predict_partitioned_loop(params, subs, feats, g.num_nodes, "ref")
    stream = stream_predict_partitioned(params, subs, feats, g.num_nodes, "ref")
    assert loop.dtype == np.int32
    assert stream.dtype == np.int32
    np.testing.assert_array_equal(loop, stream)
