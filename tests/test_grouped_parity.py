"""Grouped multi-polarity SpMM: kernel- and model-level parity.

Two gaps this file closes:

  * the grouped kernels (one gather, one plan walk, all G weight columns
    reduced per pass) must match the per-group kernels bit-for-bit in
    intent — within fp32 tolerance — on both the LD and the HD path;
  * backend parity through a FULL forward pass on graphs whose fanout
    rows exceed ``E_T = 512`` — the HD accumulation path — plus the
    paper's Fig. 4 polarized LD+HD mixture.  The pre-existing tests only
    drove HD through bare SpMM calls, never through the SAGE layer.

Also asserts the hot-path contract the refactor exists for: <= 2
edge-stream gathers and <= 2 bucket-kernel walks per layer grouped,
vs 6 on the per-group path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gnn
from repro.kernels import ops, ref
from repro.kernels.fused_sage import fused_grouped_ref, fused_ld_matmul_grouped
from repro.kernels.groot_spmm import (
    PROBE,
    apply_plan,
    apply_plan_grouped,
    build_plan,
    reset_probe,
)

GROOT_BACKENDS = ("groot", "groot_mxu", "groot_fused")


def polarized_graph(rng, n, e_ld, hd_rows, hd_deg):
    """Fig. 4 shape: a sea of low-degree rows + a few extreme-fanout rows."""
    src = rng.integers(0, n, e_ld, dtype=np.int64)
    dst = rng.integers(0, n, e_ld, dtype=np.int64)
    if hd_rows:
        hsrc = rng.integers(0, n, hd_rows * hd_deg, dtype=np.int64)
        hdst = np.repeat(rng.choice(n, hd_rows, replace=False), hd_deg)
        src = np.concatenate([src, hsrc])
        dst = np.concatenate([dst, hdst])
    return src.astype(np.int32), dst.astype(np.int32)


# ---------------------------------------------------------------------------
# Kernel level: grouped == stacked per-group
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mxu", [False, True])
@pytest.mark.parametrize(
    "n,e_ld,hd_rows,f,g",
    [
        (64, 256, 0, 8, 4),          # LD only
        (120, 500, 0, 33, 2),        # non-pow2 F, G=2 (fanout polarity)
        (300, 900, 2, 17, 4),        # HD rows (deg 600 > E_T)
        (32, 0, 1, 16, 4),           # HD only, no LD edges
    ],
)
def test_apply_plan_grouped_matches_per_group(n, e_ld, hd_rows, f, g, mxu):
    rng = np.random.default_rng(7 + n)
    src, dst = polarized_graph(rng, n, e_ld, hd_rows, hd_deg=600)
    e = len(src)
    x = jnp.asarray(rng.standard_normal((n, f)), jnp.float32)
    wg = jnp.asarray(rng.standard_normal((e, g)), jnp.float32)
    plan = build_plan(src, dst, n)
    got = apply_plan_grouped(plan, x, wg, mxu=mxu)
    assert got.shape == (g, n, f) and got.dtype == x.dtype
    for k in range(g):
        want = apply_plan(plan, x, wg[:, k], mxu=mxu)
        np.testing.assert_allclose(
            np.asarray(got[k]), np.asarray(want), rtol=1e-4, atol=1e-4
        )


def test_apply_plan_grouped_bf16_accumulates_f32():
    rng = np.random.default_rng(11)
    src, dst = polarized_graph(rng, 200, 800, 1, 600)
    x = jnp.asarray(rng.standard_normal((200, 32)), jnp.bfloat16)
    wg = jnp.asarray(rng.standard_normal((len(src), 4)), jnp.float32)
    plan = build_plan(src, dst, 200)
    got = apply_plan_grouped(plan, x, wg)
    assert got.dtype == jnp.bfloat16
    xf = x.astype(jnp.float32)
    deg_max = int(np.bincount(dst, minlength=200).max())
    tol = 8e-2 * np.sqrt(deg_max)
    for k in range(4):
        want = ref.spmm_ref(xf, jnp.asarray(src), jnp.asarray(dst), 200, wg[:, k])
        np.testing.assert_allclose(
            np.asarray(got[k], np.float32), np.asarray(want), rtol=tol, atol=tol
        )


def test_fused_grouped_kernel_matches_ref():
    rng = np.random.default_rng(2)
    deg, r, f, h, g = 4, 64, 128, 128, 4
    msgs = jnp.asarray(rng.standard_normal((r * deg, f)), jnp.float32)
    wg = jnp.asarray(rng.standard_normal((r * deg, g)), jnp.float32)
    w_stack = jnp.asarray(rng.standard_normal((g, f, h)), jnp.float32)
    got = fused_ld_matmul_grouped(msgs, wg, w_stack, deg, rows_per_tile=16)
    want = fused_grouped_ref(msgs, wg, w_stack, deg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# Model level: every backend, grouped and per-group, through graphs that
# force the HD accumulation path inside a full forward pass
# ---------------------------------------------------------------------------

def _forward_all_backends(n, src, dst, seed=0, num_layers=2, hidden=16,
                          per_group=False):
    rng = np.random.default_rng(seed)
    e = len(src)
    cfg = gnn.GNNConfig(in_features=4, hidden=hidden, num_layers=num_layers)
    params = gnn.init_params(cfg, jax.random.key(seed))
    x = jnp.asarray(rng.standard_normal((n, 4)), jnp.float32)
    inv = jnp.asarray(rng.integers(0, 2, e).astype(bool))
    slot = jnp.asarray(rng.integers(0, 2, e).astype(np.uint8))
    s, d = jnp.asarray(src), jnp.asarray(dst)

    outs = {"ref": gnn.forward(params, x, s, d, inv, slot, num_nodes=n, agg=None)}
    outs["onehot"] = gnn.forward(
        params, x, s, d, inv, slot, num_nodes=n,
        agg=ops.make_agg_pair(src, dst, n, "onehot"),
    )
    for backend in GROOT_BACKENDS:
        pair = ops.make_agg_pair(src, dst, n, backend)
        assert pair.in_agg_grouped is not None
        outs[backend] = gnn.forward(
            params, x, s, d, inv, slot, num_nodes=n, agg=pair
        )
        if per_group:
            outs[backend + "/per-group"] = gnn.forward(
                params, x, s, d, inv, slot, num_nodes=n, agg=ops.ungrouped(pair)
            )
    return outs


def _assert_parity(outs, tol=1e-4):
    want = np.asarray(outs["ref"])
    for name, got in outs.items():
        np.testing.assert_allclose(
            np.asarray(got), want, rtol=tol, atol=tol,
            err_msg=f"backend {name} diverges from ref",
        )


def test_forward_parity_hd_fanout():
    """Rows with fanout degree > E_T — the HD path — inside the layer."""
    rng = np.random.default_rng(3)
    src, dst = polarized_graph(rng, 300, 800, hd_rows=2, hd_deg=600)
    # the fanout direction aggregates over edge_src: HD rows live there too
    _assert_parity(_forward_all_backends(300, src, dst))


def test_forward_parity_polarized_mixture():
    """Fig. 4 mixture: deep LD buckets AND multiple HD rows at once."""
    rng = np.random.default_rng(4)
    src, dst = polarized_graph(rng, 400, 1500, hd_rows=2, hd_deg=530)
    # sprinkle mid-degree rows so several LD buckets are populated
    mid_dst = np.repeat(rng.choice(400, 6, replace=False), 40).astype(np.int32)
    mid_src = rng.integers(0, 400, mid_dst.size).astype(np.int32)
    src = np.concatenate([src, mid_src])
    dst = np.concatenate([dst, mid_dst])
    # per-group variants included here: grouped == per-group == ref through
    # the full layer stack on the richest degree mixture
    _assert_parity(_forward_all_backends(400, src, dst, seed=5, per_group=True))


def test_forward_parity_no_polarity_annotations():
    """edge_inv/edge_slot = None collapses groups; grouped must agree."""
    rng = np.random.default_rng(6)
    src, dst = polarized_graph(rng, 128, 512, 1, 600)
    n, e = 128, len(src)
    cfg = gnn.GNNConfig(in_features=4, hidden=8, num_layers=2)
    params = gnn.init_params(cfg, jax.random.key(1))
    x = jnp.asarray(rng.standard_normal((n, 4)), jnp.float32)
    s, d = jnp.asarray(src), jnp.asarray(dst)
    want = gnn.forward(params, x, s, d, None, None, num_nodes=n, agg=None)
    for backend in GROOT_BACKENDS:
        pair = ops.make_agg_pair(src, dst, n, backend)
        got = gnn.forward(params, x, s, d, None, None, num_nodes=n, agg=pair)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4
        )


# ---------------------------------------------------------------------------
# Hot-path probe: the 6 -> 2 contract
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", GROOT_BACKENDS)
def test_grouped_hot_path_probe(backend):
    rng = np.random.default_rng(8)
    n, num_layers = 200, 2
    src, dst = polarized_graph(rng, n, 400, 1, 600)
    e = len(src)
    cfg = gnn.GNNConfig(in_features=4, hidden=8, num_layers=num_layers)
    params = gnn.init_params(cfg, jax.random.key(0))
    x = jnp.asarray(rng.standard_normal((n, 4)), jnp.float32)
    inv = jnp.asarray(rng.integers(0, 2, e).astype(bool))
    slot = jnp.asarray(rng.integers(0, 2, e).astype(np.uint8))
    s, d = jnp.asarray(src), jnp.asarray(dst)
    pair = ops.make_agg_pair(src, dst, n, backend)

    reset_probe()
    gnn.forward(params, x, s, d, inv, slot, num_nodes=n, agg=pair)
    assert PROBE["edge_stream_gathers"] == 2 * num_layers
    assert PROBE["kernel_walks"] == 2 * num_layers

    reset_probe()
    gnn.forward(params, x, s, d, inv, slot, num_nodes=n, agg=ops.ungrouped(pair))
    assert PROBE["edge_stream_gathers"] == 6 * num_layers
    assert PROBE["kernel_walks"] == 6 * num_layers
    reset_probe()
