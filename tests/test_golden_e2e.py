"""Golden end-to-end regression: one trained model, every backend.

Trains the tiny 8-bit model once per session, then pins down that the
whole pipeline — features, partitioning, GNN inference, verification —
produces the SAME verdict and core accuracy under every aggregation
backend, and that the structural plan cache actually removes work on
repeated structures (pipeline re-runs and repeated service submissions
build 0 new plans).
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core import pipeline as P
from repro.kernels import ops
from repro.kernels.plan_cache import PLAN_CACHE
from repro.service import VerificationService

pytestmark = pytest.mark.slow


@pytest.fixture(scope="session")
def trained_params_8b():
    params, _ = P.train_model("csa", 8, epochs=200)
    return params


def _run(params, backend, bits=8, partitions=1):
    cfg = P.PipelineConfig(
        dataset="csa", bits=bits, num_partitions=partitions, backend=backend
    )
    return P.run_pipeline(cfg, params, verify_result=True)


def test_all_backends_identical_verdict_and_accuracy(trained_params_8b):
    results = {b: _run(trained_params_8b, b) for b in ops.BACKENDS}
    golden = results["ref"]
    assert golden.verdict is not None
    for backend, r in results.items():
        assert r.verdict is not None, backend
        assert r.verdict.status == golden.verdict.status, backend
        assert r.core_accuracy == pytest.approx(golden.core_accuracy, abs=1e-12), (
            backend
        )
        assert r.accuracy == pytest.approx(golden.accuracy, abs=1e-12), backend
        assert (r.num_nodes, r.num_edges) == (golden.num_nodes, golden.num_edges)


def test_partitioned_backends_identical_verdict(trained_params_8b):
    golden = _run(trained_params_8b, "ref", bits=10, partitions=4)
    for backend in ("groot", "groot_fused"):
        r = _run(trained_params_8b, backend, bits=10, partitions=4)
        assert r.verdict.status == golden.verdict.status
        assert r.core_accuracy == pytest.approx(golden.core_accuracy, abs=1e-12)


def test_full_loop_and_stream_identical_verdicts_across_backends(trained_params_8b):
    """Full graph, sequential ``predict_partitioned_loop``, and the
    streaming executor (regrow=True) agree on the verdict for every
    backend family — and loop vs stream are bit-exact per backend.

    ``regrow_hops=4`` (= num_layers) makes the partitioned receptive
    field complete, so partitioned predictions must equal the full-graph
    run EXACTLY — the strongest form of the verdict-identity guarantee.
    """
    from repro.core import gnn
    from repro.exec import StreamingExecutor

    full = _run(trained_params_8b, "ref", bits=10, partitions=1)
    assert full.verdict is not None
    prep = P.prepare(
        P.PipelineConfig(
            dataset="csa", bits=10, num_partitions=4, regrow_hops=4
        )
    )
    pred_full = gnn.predict(trained_params_8b, prep.graph, prep.feats, "ref")
    for backend in ("ref", "groot", "groot_fused"):
        loop = gnn.predict_partitioned_loop(
            trained_params_8b, prep.subgraphs, prep.feats, prep.num_nodes, backend
        )
        ex = StreamingExecutor(trained_params_8b, backend, capacity=2)
        stream = ex.run_subgraphs(prep.subgraphs, prep.feats, prep.num_nodes)
        np.testing.assert_array_equal(stream, loop, err_msg=backend)
        if backend == "ref":
            np.testing.assert_array_equal(stream, pred_full)
        v_loop = P.verify_prepared(prep, loop)
        v_stream = P.verify_prepared(prep, stream)
        assert v_loop.status == v_stream.status == full.verdict.status, backend
        # compile probe: shape-stable backends compile per bucket,
        # structure-keyed (groot*) at most per packed batch structure
        if backend == "ref":
            assert ex.stats.compiles <= len(ex.buckets_seen)
        else:
            assert ex.stats.compiles <= ex.stats.batches


def test_pipeline_rerun_builds_zero_new_plans(trained_params_8b):
    first = _run(trained_params_8b, "groot", bits=8, partitions=2)
    second = _run(trained_params_8b, "groot", bits=8, partitions=2)
    # same structural content -> every plan/pair comes from the cache
    assert second.plan_cache["builds"] == 0
    assert second.plan_cache["hits"] >= 1
    assert second.verdict.status == first.verdict.status
    assert first.plan_cache["hits"] + first.plan_cache["builds"] > 0


def test_service_repeated_submission_hits_plan_cache(trained_params_8b):
    with VerificationService(trained_params_8b, backend="groot") as svc:
        r1 = svc.result(svc.submit_design("csa", 8, seed=0), timeout=600)
        assert r1.status != "error"
        before = PLAN_CACHE.snapshot()
        compiles = svc.scheduler.stats().compile_count
        # different seed -> result-cache key differs, but the generated
        # design (and so the packed device batch) is structurally identical
        r2 = svc.result(svc.submit_design("csa", 8, seed=1), timeout=600)
        after = PLAN_CACHE.snapshot()
        assert r2.status == r1.status
        assert not r2.cached                       # result cache did NOT hit
        assert after.builds == before.builds       # 0 new plans built
        assert after.hits >= before.hits + 1       # the pair came from cache
        assert svc.scheduler.stats().compile_count == compiles  # no retrace
        assert r2.accuracy == pytest.approx(r1.accuracy, abs=1e-12)


def test_service_groot_backend_matches_ref_backend(trained_params_8b):
    with VerificationService(trained_params_8b, backend="ref") as svc:
        r_ref = svc.result(svc.submit_design("csa", 8), timeout=600)
    with VerificationService(trained_params_8b, backend="groot") as svc:
        r_groot = svc.result(svc.submit_design("csa", 8), timeout=600)
    assert r_groot.status == r_ref.status
    assert r_groot.accuracy == pytest.approx(r_ref.accuracy, abs=1e-12)
    assert r_groot.num_nodes == r_ref.num_nodes
