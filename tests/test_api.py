"""`repro.api.Session` façade: the execution router, the flattened
config (and its legacy projections), the deprecated shims, and the
committed API-surface snapshot.

Fast lane: router decisions + parity on small random-param designs, the
config alias/override semantics, shim DeprecationWarnings, the
plan/compile probe gates, and the ``__all__`` manifest check.  Slow
lane: trained-model golden parity across routes and the csa-256 routing
acceptance criterion.
"""
from __future__ import annotations

import dataclasses
import warnings
from pathlib import Path

import jax
import numpy as np
import pytest

import repro.api as api
from repro.api import Session, SessionConfig
from repro.core import gnn
from repro.core import pipeline as P
from repro.kernels.plan_cache import PLAN_CACHE

DATA = Path(__file__).parent / "data"


@pytest.fixture(scope="module")
def rand_params():
    return gnn.init_params(gnn.GNNConfig(), jax.random.key(0))


# ---------------------------------------------------------------------------
# API-surface snapshot (accidental public-surface changes fail the build)
# ---------------------------------------------------------------------------

def test_api_surface_matches_committed_manifest():
    manifest = sorted(
        line.strip()
        for line in (DATA / "api_surface.txt").read_text().splitlines()
        if line.strip()
    )
    assert sorted(api.__all__) == manifest, (
        "repro.api public surface changed — if intentional, update "
        "tests/data/api_surface.txt in the same PR"
    )
    for name in manifest:
        assert getattr(api, name) is not None


# ---------------------------------------------------------------------------
# Config unification: backend= everywhere, aggregate= as deprecated alias
# ---------------------------------------------------------------------------

def test_pipeline_config_backend_alias():
    assert P.PipelineConfig().backend == "ref"
    with pytest.warns(DeprecationWarning, match="aggregate"):
        cfg = P.PipelineConfig(aggregate="groot")
    assert cfg.backend == "groot"
    assert cfg.aggregate is None          # write-only alias, consumed
    # the alias being consumed is what keeps replace(backend=...) safe
    assert dataclasses.replace(cfg, backend="groot_fused").backend == "groot_fused"
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError, match="disagree"):
            P.PipelineConfig(backend="ref", aggregate="groot")


def test_session_config_alias_and_projections():
    with pytest.warns(DeprecationWarning, match="aggregate"):
        cfg = SessionConfig(aggregate="groot_mxu")
    assert cfg.backend == "groot_mxu" and cfg.aggregate is None
    assert cfg.replace(backend="ref").backend == "ref"
    svc = SessionConfig(backend="groot", stream_dtype="bfloat16").service_config()
    assert svc.backend == "groot" and svc.stream_dtype == "bfloat16"
    # stream_dtype changes numerics, so it must key the service cache
    assert "bfloat16" in svc.cache_key_part()


def test_pipeline_config_roundtrip_is_exact():
    pcfg = P.PipelineConfig(
        dataset="booth", bits=12, batch=2, num_partitions=4, regrow=False,
        regrow_hops=3, partitioner="multilevel", backend="groot_fused",
        seed=7, memory_budget_bytes=12345, stream_capacity=3,
        stream_prefetch=2, stream_dtype="bfloat16",
    )
    lifted = SessionConfig.from_pipeline(pcfg)
    assert lifted.pipeline_config() == pcfg


def test_service_overrides_apply_on_top_of_config(rand_params):
    """Both ``config`` and ``**overrides`` given: overrides win (via
    dataclasses.replace), untouched fields come from the config."""
    from repro.service.server import ServiceConfig, VerificationService

    base = ServiceConfig(backend="ref", capacity=2, num_partitions=1)
    with pytest.warns(DeprecationWarning, match="Session"):
        svc = VerificationService(
            rand_params, base, num_partitions=3, capacity=4
        )
    try:
        assert svc.config.num_partitions == 3
        assert svc.config.capacity == 4
        assert svc.config.backend == "ref"       # inherited from base
        assert base.num_partitions == 1          # base config untouched
    finally:
        svc.close(timeout=30.0)


def test_session_overrides_apply_on_top_of_config(rand_params):
    base = SessionConfig(backend="ref", num_partitions=1)
    sess = Session(rand_params, base, num_partitions=4, bits=8)
    assert sess.config.num_partitions == 4
    assert sess.config.bits == 8
    assert sess.config.backend == "ref"


# ---------------------------------------------------------------------------
# The execution router
# ---------------------------------------------------------------------------

def test_router_full_route_and_full_parity(rand_params):
    sess = Session(rand_params, SessionConfig(dataset="csa", bits=8))
    d = sess.explain()
    assert d.mode == "full" and d.k == 1
    assert d.modeled_peak_bytes == d.modeled_full_bytes
    r = sess.verify(verify=False, return_predictions=True, use_cache=False)
    assert r.routing == d                  # explain() matches the route taken
    assert r.exec_stats == {}
    prep = sess.prepare()
    np.testing.assert_array_equal(
        r.predictions, gnn.predict(rand_params, prep.graph, prep.feats, "ref")
    )


def test_router_streamed_and_partitioned_routes_agree(rand_params):
    sess = Session(rand_params, SessionConfig(dataset="csa", bits=8,
                                              num_partitions=4))
    d = sess.explain()
    assert d.mode == "streamed" and d.k == 4 and d.num_buckets >= 1
    assert d.buckets and d.modeled_peak_bytes > 0
    r = sess.verify(verify=False, return_predictions=True, use_cache=False)
    assert r.routing == d
    assert r.exec_stats["num_buckets"] == d.num_buckets
    assert r.exec_stats["launches"] >= 1

    loop = sess.options(streaming=False)
    dl = loop.explain()
    assert dl.mode == "partitioned" and dl.k == 4 and dl.num_buckets == 0
    rl = loop.verify(verify=False, return_predictions=True, use_cache=False)
    assert rl.routing == dl and rl.exec_stats == {}
    # streamed and sequential routes are bit-exact on every row
    np.testing.assert_array_equal(r.predictions, rl.predictions)


def test_router_memory_budget_streams_to_fit(rand_params):
    sess = Session(rand_params, SessionConfig(dataset="csa", bits=16))
    full = sess.explain().modeled_full_bytes
    tight = sess.options(memory_budget_bytes=full // 3)
    d = tight.explain()
    assert d.mode == "streamed" and d.k > 1
    assert "choose_k" in d.reason
    r = tight.verify(verify=False, use_cache=False)
    assert r.routing == d
    assert r.exec_stats["chosen_k"] == d.k
    assert r.exec_stats["peak_packed_memory_bytes"] == d.modeled_peak_bytes


def test_repeated_verify_builds_zero_plans_zero_compiles(rand_params):
    """Same-structure designs through a session: the second run touches
    neither the structural plan cache (0 builds) nor jit (0 compiles)."""
    sess = Session(rand_params, SessionConfig(
        dataset="csa", bits=8, num_partitions=2, backend="groot"
    ))
    sess.verify(verify=False, use_cache=False)
    ex = sess._stream_executor()
    compiles_before = ex.runner.compile_count
    pc_before = PLAN_CACHE.snapshot()
    r2 = sess.verify(verify=False, use_cache=False)
    assert r2.plan_cache["builds"] == 0
    assert r2.plan_cache["hits"] >= 1
    assert PLAN_CACHE.snapshot().builds == pc_before.builds
    assert ex.runner.compile_count == compiles_before
    # and with the result LRU on, the third call skips execution entirely
    r3 = sess.verify(verify=False)
    assert r3.cached
    assert r3.accuracy == r2.accuracy
    # mutating a returned result must not corrupt the cached copy
    r3.exec_stats["launches"] = -1
    r3.plan_cache["builds"] = 999
    r4 = sess.verify(verify=False)
    assert r4.cached and r4.exec_stats.get("launches") != -1
    assert r4.plan_cache["builds"] == 0
    # asking for predictions cannot be served from the predictions-free
    # cache: it falls through to a real run
    r5 = sess.verify(verify=False, return_predictions=True)
    assert not r5.cached and r5.predictions is not None


def test_explain_needs_no_params_but_verify_does():
    sess = Session(config=SessionConfig(dataset="csa", bits=6))
    assert sess.explain().mode == "full"          # host-side only
    with pytest.raises(RuntimeError, match="params"):
        sess.verify()


def test_train_invalidates_params_derived_state(rand_params):
    """New params must never serve results cached under the old ones —
    the LRU key carries no params fingerprint, so train()/set_params()
    invalidate it (and drop the stale service engine) wholesale."""
    sess = Session(rand_params, SessionConfig(dataset="csa", bits=6))
    r1 = sess.verify(verify=False)
    assert not r1.cached and sess.verify(verify=False).cached
    sess.train("csa", 6, epochs=40)
    r2 = sess.verify(verify=False)
    assert not r2.cached                 # the old cache entry is gone, so
    assert sess._service is None         # the run used the NEW params
    assert sess.verify(verify=False).cached   # and re-caches under them


def test_closed_session_rejects_async_but_not_sync(rand_params):
    sess = Session(rand_params, SessionConfig(dataset="csa", bits=6))
    sess.close()
    # a resurrected engine would leak threads and not know old tickets
    with pytest.raises(RuntimeError, match="closed"):
        sess.poll(0)
    with pytest.raises(RuntimeError, match="closed"):
        sess.submit()
    assert sess.verify(verify=False, use_cache=False).routing.mode == "full"


# ---------------------------------------------------------------------------
# Deprecated entry points: still correct, now warning
# ---------------------------------------------------------------------------

def test_run_pipeline_shim_warns_and_matches_session(rand_params):
    sess = Session(rand_params, SessionConfig(dataset="csa", bits=8,
                                              num_partitions=2))
    r_new = sess.verify(verify=False, use_cache=False)
    with pytest.warns(DeprecationWarning, match="run_pipeline"):
        r_old = P.run_pipeline(
            P.PipelineConfig(dataset="csa", bits=8, num_partitions=2),
            rand_params,
        )
    assert r_old.accuracy == r_new.accuracy
    assert r_old.num_nodes == r_new.num_nodes
    assert r_old.peak_memory_bytes == r_new.peak_memory_bytes
    assert r_old.exec_stats["num_buckets"] == r_new.exec_stats["num_buckets"]


def test_predict_partitioned_shim_warns_and_is_bit_exact(rand_params):
    from repro.exec.stream import stream_predict_partitioned

    prep = P.prepare(P.PipelineConfig(dataset="csa", bits=8, num_partitions=3))
    with pytest.warns(DeprecationWarning, match="predict_partitioned"):
        old = gnn.predict_partitioned(
            rand_params, prep.subgraphs, prep.feats, prep.num_nodes, "ref"
        )
    new = stream_predict_partitioned(
        rand_params, prep.subgraphs, prep.feats, prep.num_nodes, "ref"
    )
    np.testing.assert_array_equal(old, new)


# ---------------------------------------------------------------------------
# Slow lane: trained-model golden parity + csa-256 routing acceptance
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def trained_params_8b():
    params, _ = P.train_model("csa", 8, epochs=200)
    return params


@pytest.mark.slow
def test_session_golden_parity_across_routes(trained_params_8b):
    """``regrow_hops >= num_layers`` completes the receptive field, so all
    three sync routes must be BIT-EXACT — and every groot backend must
    agree with ref on the verdict."""
    base = Session(trained_params_8b, SessionConfig(
        dataset="csa", bits=10, regrow_hops=4
    ))
    full = base.verify(return_predictions=True, use_cache=False)
    assert full.verdict is not None
    routes = {
        "streamed": base.options(num_partitions=4),
        "partitioned": base.options(num_partitions=4, streaming=False),
    }
    for name, sess in routes.items():
        r = sess.verify(return_predictions=True, use_cache=False)
        assert r.routing.mode == name
        np.testing.assert_array_equal(r.predictions, full.predictions,
                                      err_msg=name)
        assert r.verdict.status == full.verdict.status
    for backend in ("groot", "groot_fused"):
        r = base.options(backend=backend, num_partitions=4).verify(
            use_cache=False
        )
        assert r.verdict.status == full.verdict.status, backend
        assert r.accuracy == pytest.approx(full.accuracy, abs=1e-12), backend


@pytest.mark.slow
def test_csa256_routes_streamed_under_budget_full_without(rand_params):
    """Acceptance: the same csa-256 design goes to the streaming executor
    under a tight memory budget and to full-graph execution without one,
    with matching accuracy."""
    sess = Session(rand_params, SessionConfig(dataset="csa", bits=256))
    d_full = sess.explain()
    assert d_full.mode == "full"
    r_full = sess.verify(verify=False, use_cache=False)
    assert r_full.routing.mode == "full"

    budget = d_full.modeled_full_bytes // 2
    tight = sess.options(memory_budget_bytes=budget)
    d = tight.explain()
    assert d.mode == "streamed" and d.k > 1
    assert d.modeled_peak_bytes <= budget       # prepare() validated the fit
    r = tight.verify(verify=False, use_cache=False)
    assert r.routing == d
    assert r.exec_stats["launches"] >= 1
    assert r.exec_stats["peak_packed_memory_bytes"] <= budget
    assert abs(r.accuracy - r_full.accuracy) < 0.005


@pytest.mark.slow
def test_session_async_path_matches_sync(trained_params_8b):
    """submit()/poll()/result() (the service-batched route) agrees with
    the sync router on the same design."""
    with Session(trained_params_8b, SessionConfig(
        dataset="csa", bits=12, num_partitions=2
    )) as sess:
        r_sync = sess.verify(use_cache=False)
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            ticket = sess.submit()       # façade path must NOT warn
        r_async = sess.result(ticket, timeout=300)
    assert r_async.status == r_sync.status
    assert r_async.accuracy == pytest.approx(r_sync.accuracy, abs=1e-12)
    assert r_async.num_nodes == r_sync.num_nodes
