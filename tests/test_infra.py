"""Infrastructure tests: checkpointing, fault tolerance, data pipeline,
optimizers, sharding rules, HLO roofline parser."""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager, latest_step, restore, save
from repro.distributed.fault_tolerance import Heartbeat, ResilientLoop
from repro.roofline.hlo import analyze
from repro.training import optimizer as opt_mod
from repro.training.data import TokenStream, TokenStreamConfig


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------

def _tree():
    return {
        "w": jnp.arange(12.0).reshape(3, 4),
        "layers": [{"a": jnp.ones((2, 2))}, {"a": jnp.zeros((2, 2))}],
        "step": jnp.asarray(7, jnp.int32),
    }


def test_checkpoint_roundtrip(tmp_path):
    tree = _tree()
    save(tree, tmp_path, 5)
    got, step = restore(jax.tree.map(jnp.zeros_like, tree), tmp_path)
    assert step == 5
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity_ignores_partial(tmp_path):
    tree = _tree()
    save(tree, tmp_path, 1)
    # a crashed write leaves only a .tmp dir -> must be ignored
    (tmp_path / "step_000000009.tmp").mkdir()
    assert latest_step(tmp_path) == 1


def test_checkpoint_manager_async_and_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    tree = _tree()
    for s in (1, 2, 3, 4):
        mgr.save_async(tree, s)
    mgr.wait()
    steps = sorted(
        int(p.name.split("_")[1]) for p in tmp_path.iterdir()
        if p.name.startswith("step_") and not p.name.endswith(".tmp")
    )
    assert steps == [3, 4]  # keep=2
    assert mgr.save_count == 4


def test_restore_with_resharding(tmp_path):
    """Elastic restart: restore onto explicit (here trivial) shardings."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = jax.make_mesh((1,), ("data",))
    tree = _tree()
    save(tree, tmp_path, 0)
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
    got, _ = restore(jax.tree.map(jnp.zeros_like, tree), tmp_path, shardings=sh)
    assert got["w"].sharding == NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# Resilient loop
# ---------------------------------------------------------------------------

def test_resilient_loop_runs_and_checkpoints(tmp_path):
    def step(state, batch):
        return state + batch, {"loss": float(state)}

    loop = ResilientLoop(
        step, jnp.zeros(()), ckpt_dir=str(tmp_path), ckpt_every=2
    )
    list(loop.run(iter([1.0, 1.0, 1.0, 1.0]), steps=4))
    assert latest_step(tmp_path) is not None
    # relaunch resumes
    loop2 = ResilientLoop(
        step, jnp.zeros(()), ckpt_dir=str(tmp_path), ckpt_every=2
    )
    assert loop2.resumed and loop2.step >= 1
    assert float(loop2.state) > 0


def test_resilient_loop_retries_transient_failure(tmp_path):
    calls = {"n": 0}

    def flaky(state, batch):
        calls["n"] += 1
        if calls["n"] == 3:
            raise RuntimeError("simulated preemption")
        return state + 1, {}

    loop = ResilientLoop(
        flaky, jnp.zeros(()), ckpt_dir=str(tmp_path), ckpt_every=1, max_retries=2
    )
    list(loop.run(iter([0, 0, 0, 0]), steps=4))
    assert calls["n"] >= 5  # one retry happened


def test_heartbeat_staleness(tmp_path):
    hb = Heartbeat(str(tmp_path), host_id=3)
    hb.beat(10)
    assert Heartbeat.stale_hosts(str(tmp_path), timeout_s=60) == []
    data = json.loads(hb.path.read_text())
    data["t"] -= 3600
    hb.path.write_text(json.dumps(data))
    assert Heartbeat.stale_hosts(str(tmp_path), timeout_s=60) == ["heartbeat_3"]


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

def test_token_stream_deterministic_and_seekable():
    cfg = TokenStreamConfig(vocab_size=100, seq_len=16, global_batch=4)
    s1, s2 = TokenStream(cfg), TokenStream(cfg)
    np.testing.assert_array_equal(s1.batch_at(7), s2.batch_at(7))
    assert not np.array_equal(s1.batch_at(7), s1.batch_at(8))
    assert s1.batch_at(0).shape == (4, 17)


def test_token_stream_host_sharding():
    cfg0 = TokenStreamConfig(100, 16, 8, n_hosts=2, host_id=0)
    cfg1 = TokenStreamConfig(100, 16, 8, n_hosts=2, host_id=1)
    b0, b1 = TokenStream(cfg0).batch_at(0), TokenStream(cfg1).batch_at(0)
    assert b0.shape == (4, 17) and b1.shape == (4, 17)
    assert not np.array_equal(b0, b1)


# ---------------------------------------------------------------------------
# Optimizers
# ---------------------------------------------------------------------------

def _quad_params():
    return {"w": jnp.asarray([3.0, -2.0, 1.0])}


@pytest.mark.parametrize("name", ["adamw", "adamw8bit"])
def test_optimizer_descends_quadratic(name):
    opt = opt_mod.make_optimizer(name, lr=0.1)
    params = _quad_params()
    state = opt.init(params)

    def loss(p):
        return jnp.sum(jnp.square(p["w"]))

    for i in range(120):
        g = jax.grad(loss)(params)
        # cosine-decayed lr via the schedule helper (also exercises it)
        scale = opt_mod.cosine_schedule(i, base=1.0, warmup=5, total=120)
        upd, state = opt.update(g, state, params, lr_scale=scale)
        params = opt_mod.apply_updates(params, upd)
    assert float(loss(params)) < 5e-2


def test_q8_quantization_error_bounded():
    x = jax.random.normal(jax.random.key(0), (64, 256)) * 3.0
    z = opt_mod._q8_encode(x)
    back = opt_mod._q8_decode(z)
    err = jnp.abs(back - x).max() / jnp.abs(x).max()
    assert float(err) < 1.5 / 127  # per-row absmax quantisation bound
    assert z.q.shape == x.shape and z.scale.shape == (64, 1)


def test_cosine_schedule_shape():
    mult0 = opt_mod.cosine_schedule(0, base=1.0, warmup=10, total=100)
    mult10 = opt_mod.cosine_schedule(10, base=1.0, warmup=10, total=100)
    mult100 = opt_mod.cosine_schedule(100, base=1.0, warmup=10, total=100)
    assert float(mult0) == 0.0 and abs(float(mult10) - 1.0) < 1e-6
    assert float(mult100) == pytest.approx(0.1, abs=1e-6)


# ---------------------------------------------------------------------------
# Sharding rules
# ---------------------------------------------------------------------------

def test_partition_spec_divisibility_and_dedup():
    from jax.sharding import PartitionSpec as P

    from repro.sharding.rules import make_rules, partition_spec

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # fake a 16-wide model axis via rules on a tiny mesh: use divisibility
    rules = make_rules(mesh, fsdp=True)
    # kv_heads=8 on model=1 -> divisible -> sharded entry named "model"
    spec = partition_spec((8, 128), ("kv_heads", None), mesh, rules)
    assert spec == P("model", None)
    # duplicate mesh axis must be dropped on the second dim
    spec2 = partition_spec((8, 8), ("heads", "kv_heads"), mesh, rules)
    assert spec2 == P("model", None)


def test_shard_noop_without_ctx():
    from repro.sharding import shard

    x = jnp.ones((4, 4))
    assert shard(x, ("batch", None)) is x


# ---------------------------------------------------------------------------
# HLO roofline parser
# ---------------------------------------------------------------------------

def test_hlo_parser_loop_correction():
    def body(x, w):
        return jnp.tanh(x @ w), None

    def f(x, ws):
        x, _ = jax.lax.scan(body, x, ws)
        return x

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, 128, 128), jnp.float32)
    st = analyze(jax.jit(f).lower(x, ws).compile().as_text())
    want = 5 * 2 * 128**3
    assert st.dot_flops == pytest.approx(want, rel=1e-6)
    assert 5 in st.while_trips.values()


def test_hlo_parser_counts_collectives():
    # single-device program: no collectives
    f = lambda a, b: a @ b
    a = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    st = analyze(jax.jit(f).lower(a, a).compile().as_text())
    assert st.collective_bytes == 0.0
    assert st.dot_flops == pytest.approx(2 * 64**3, rel=1e-6)
    assert st.entry_param_bytes == 2 * 64 * 64 * 4
