"""Model-stack unit tests: attention paths, RWKV6 forms, RG-LRU, MoE."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.zoo.models.attention as A
from repro.zoo.configs import get_config
from repro.zoo.configs.base import materialize, param_tree
from repro.zoo.models import rglru, rwkv6
from repro.zoo.models.attention import attention
from repro.zoo.models.moe import capacity, moe_ffn, route


def _mat(spec, seed=0):
    return materialize(spec, jax.random.key(seed), jnp.float32)


@pytest.fixture()
def qwen_cfg():
    return get_config("qwen3-8b", smoke=True)


# ---------------------------------------------------------------------------
# Flash (chunked online-softmax) vs plain attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("window", [0, 50])
@pytest.mark.parametrize("seqlen", [64, 300])
def test_flash_matches_plain(qwen_cfg, window, seqlen):
    ap = _mat(param_tree(qwen_cfg)["layers"][0]["attn"], 5)
    x = jax.random.normal(jax.random.key(6), (2, seqlen, qwen_cfg.d_model), jnp.float32)
    out_plain, _ = attention(x, ap, qwen_cfg, window=window)
    old = (A.FLASH_THRESHOLD, A.Q_CHUNK, A.KV_CHUNK)
    try:
        A.FLASH_THRESHOLD, A.Q_CHUNK, A.KV_CHUNK = 1, 64, 128
        out_flash, _ = attention(x, ap, qwen_cfg, window=window)
    finally:
        A.FLASH_THRESHOLD, A.Q_CHUNK, A.KV_CHUNK = old
    np.testing.assert_allclose(
        np.asarray(out_plain), np.asarray(out_flash), rtol=1e-4, atol=1e-4
    )


def test_flash_bidirectional(qwen_cfg):
    ap = _mat(param_tree(qwen_cfg)["layers"][0]["attn"], 5)
    x = jax.random.normal(jax.random.key(1), (2, 100, qwen_cfg.d_model), jnp.float32)
    out_plain, _ = attention(x, ap, qwen_cfg, bidirectional=True)
    old = (A.FLASH_THRESHOLD, A.Q_CHUNK, A.KV_CHUNK)
    try:
        A.FLASH_THRESHOLD, A.Q_CHUNK, A.KV_CHUNK = 1, 32, 64
        out_flash, _ = attention(x, ap, qwen_cfg, bidirectional=True)
    finally:
        A.FLASH_THRESHOLD, A.Q_CHUNK, A.KV_CHUNK = old
    np.testing.assert_allclose(
        np.asarray(out_plain), np.asarray(out_flash), rtol=1e-4, atol=1e-4
    )


def test_softcap_applied():
    cfg = get_config("gemma2-9b", smoke=True)
    ap = _mat(param_tree(cfg)["layers"][1]["attn"], 2)
    x = 100.0 * jax.random.normal(jax.random.key(0), (1, 8, cfg.d_model), jnp.float32)
    out, _ = attention(x, ap, cfg)
    assert bool(jnp.isfinite(out).all())


# ---------------------------------------------------------------------------
# RWKV6: chunked == scan; decode == train
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seqlen,chunk", [(37, 16), (64, 16), (16, 16), (5, 16)])
def test_rwkv_chunked_matches_scan(seqlen, chunk):
    cfg = get_config("rwkv6-3b", smoke=True)
    p = _mat(param_tree(cfg)["layers"][0]["rwkv"], 1)
    x = jax.random.normal(jax.random.key(2), (2, seqlen, cfg.d_model), jnp.float32)
    st = {
        "s": jax.random.normal(jax.random.key(3), (2, cfg.mixer_heads_, 16, 16)),
        "x_prev": jax.random.normal(jax.random.key(4), (2, cfg.d_model)),
    }
    o1, s1 = rwkv6.time_mix_scan(x, p, cfg, st)
    o2, s2 = rwkv6.time_mix_chunked(x, p, cfg, st, chunk=chunk)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(
        np.asarray(s1["s"]), np.asarray(s2["s"]), rtol=2e-4, atol=2e-4
    )


def test_rwkv_stepwise_decode_matches_full():
    cfg = get_config("rwkv6-3b", smoke=True)
    p = _mat(param_tree(cfg)["layers"][0]["rwkv"], 1)
    x = jax.random.normal(jax.random.key(2), (1, 12, cfg.d_model), jnp.float32)
    o_full, _ = rwkv6.time_mix_scan(x, p, cfg)
    st = None
    outs = []
    for t in range(12):
        o, st = rwkv6.time_mix_scan(x[:, t : t + 1], p, cfg, st)
        outs.append(o)
    o_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(o_full), np.asarray(o_step), rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# RG-LRU: associative scan == sequential; decode step == scan
# ---------------------------------------------------------------------------

def test_rglru_assoc_scan_matches_sequential():
    cfg = get_config("recurrentgemma-9b", smoke=True)
    p = _mat(param_tree(cfg)["layers"][0]["rglru"], 7)
    xr = jax.random.normal(jax.random.key(8), (2, 23, cfg.d_rnn_), jnp.float32)
    a, gx = rglru._gates(xr, p)
    h_assoc, h_fin = rglru.rg_lru(xr, p)
    h = jnp.zeros_like(a[:, 0])
    hs = []
    for t in range(23):
        h = a[:, t] * h + gx[:, t]
        hs.append(h)
    h_seq = jnp.stack(hs, 1)
    np.testing.assert_allclose(
        np.asarray(h_assoc, np.float32), np.asarray(h_seq), rtol=1e-5, atol=1e-5
    )
    np.testing.assert_allclose(np.asarray(h_fin), np.asarray(h_seq[:, -1]), rtol=1e-5, atol=1e-5)


def test_rglru_block_decode_matches_scan():
    cfg = get_config("recurrentgemma-9b", smoke=True)
    p = _mat(param_tree(cfg)["layers"][0]["rglru"], 7)
    x = jax.random.normal(jax.random.key(9), (2, 9, cfg.d_model), jnp.float32)
    o_full, _ = rglru.rglru_block(x, p, cfg, None)
    st = None
    outs = []
    for t in range(9):
        o, st = rglru.rglru_block(x[:, t : t + 1], p, cfg, st, decode=True)
        outs.append(o)
    o_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(o_full), np.asarray(o_step), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def test_moe_routing_topk_and_capacity():
    cfg = get_config("qwen3-moe-235b-a22b", smoke=True)
    x = jax.random.normal(jax.random.key(0), (64, cfg.d_model), jnp.float32)
    w = jax.random.normal(jax.random.key(1), (cfg.d_model, cfg.num_experts)) * 0.1
    idx, wts = route(x, w, cfg)
    assert idx.shape == (64, cfg.top_k)
    assert bool((wts >= 0).all())
    np.testing.assert_allclose(np.asarray(wts.sum(-1)), 1.0, rtol=1e-5)
    assert capacity(64, cfg) >= cfg.top_k


def test_moe_matches_dense_ffn_per_expert():
    """With capacity ample + top-1 forced routing, MoE == the picked
    expert's dense FFN."""
    import dataclasses

    cfg = dataclasses.replace(
        get_config("qwen3-moe-235b-a22b", smoke=True),
        top_k=1, capacity_factor=8.0,
    )
    p = _mat(param_tree(cfg)["layers"][0]["moe"], 3)
    # force deterministic routing: positive inputs + all-ones column 2
    # -> expert 2 wins for every token
    router = jnp.zeros_like(p["router"]).at[:, 2].set(1.0)
    p = dict(p, router=router)
    x = jnp.abs(jax.random.normal(jax.random.key(4), (2, 8, cfg.d_model), jnp.float32)) * 0.1
    y = moe_ffn(x, p, cfg)
    # dense reference with expert 2's weights
    h = jnp.einsum("bsd,df->bsf", x, p["w_in"][2])
    g = jnp.einsum("bsd,df->bsf", x, p["w_gate"][2])
    want = jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * h, p["w_out"][2])
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_moe_drops_overflow_tokens():
    import dataclasses

    cfg = dataclasses.replace(
        get_config("qwen3-moe-235b-a22b", smoke=True),
        top_k=1, capacity_factor=0.25,  # tiny capacity -> forced drops
    )
    p = _mat(param_tree(cfg)["layers"][0]["moe"], 3)
    router = jnp.zeros_like(p["router"]).at[0, 1].set(100.0)
    p = dict(p, router=router)
    x = jnp.ones((1, 16, cfg.d_model), jnp.float32)
    y = moe_ffn(x, p, cfg)
    # all tokens routed to expert 1, capacity < 16 -> some outputs are zero
    norms = jnp.linalg.norm(y[0], axis=-1)
    assert bool((norms[capacity(16, cfg) :] == 0).any())
