"""Telemetry export (`repro.obs.export`) + regression sentry (`.regress`).

Fast lane.  Pins the three export surfaces and the sentry's contract:

  * Prometheus text round-trips: render -> parse -> same counter/gauge
    values, dotted names sanitized, gauge high-water ``_max`` twins;
  * the Sampler leaves at least one JSONL line even for a run shorter
    than its interval, and every line is valid JSON with the snapshot
    sections;
  * ``MetricsServer`` answers a live scrape on ``/metrics`` and
    ``/stats`` (what ``repro serve --metrics-port`` / ``repro top`` use);
  * the regress sentry passes an unperturbed self-comparison, fails a
    perturbed one *naming the metric and tolerance*, hard-fails on
    schema mismatch, skips timing rules on host mismatch, and ``--bless``
    installs a new baseline;
  * model-vs-actual memory accounting: a streamed verify reports
    ``modeled_peak_bytes`` / ``actual_peak_bytes`` / ``model_drift`` and
    the session Report carries the ``memory_model`` block;
  * ``repro.obs.check`` forwards ``--design``/``--repeats`` into the
    overhead micro-benchmark.
"""
from __future__ import annotations

import copy
import json
import time
import urllib.request

import jax
import pytest

from repro.core import gnn
from repro.obs import (
    MetricsRegistry,
    Sampler,
    parse_prometheus,
    render_prometheus,
    start_metrics_server,
)
from repro.obs import regress
from repro.obs.export import sanitize_metric_name


@pytest.fixture(scope="module")
def rand_params():
    return gnn.init_params(gnn.GNNConfig(), jax.random.key(0))


def seeded_registry() -> MetricsRegistry:
    reg = MetricsRegistry()
    reg.counter("service.device_calls").inc(7)
    g = reg.gauge("service.queue_depth")
    g.set(3)
    g.set(1)                                  # live value 1, high-water 3
    h = reg.histogram("service.infer_s")
    for v in (0.010, 0.020, 0.030, 0.040):
        h.observe(v)
    return reg


# ---------------------------------------------------------------------------
# Prometheus rendering
# ---------------------------------------------------------------------------

def test_sanitize_metric_name():
    assert sanitize_metric_name("service.queue-depth") == "service_queue_depth"
    assert sanitize_metric_name("exec.h2d bytes") == "exec_h2d_bytes"
    assert sanitize_metric_name("0weird").startswith("_")


def test_prometheus_round_trip():
    text = render_prometheus(seeded_registry())
    parsed = parse_prometheus(text)
    assert parsed["repro_service_device_calls_total"] == 7.0
    # gauges export both the live value and the high-water twin
    assert parsed["repro_service_queue_depth"] == 1.0
    assert parsed["repro_service_queue_depth_max"] == 3.0
    # histogram summary: count/sum plus quantile-labelled lines
    assert parsed["repro_service_infer_s_count"] == 4.0
    assert parsed["repro_service_infer_s_sum"] == pytest.approx(0.1)
    assert parsed['repro_service_infer_s{quantile="0.50"}'] > 0.0
    assert parsed['repro_service_infer_s{quantile="0.95"}'] >= (
        parsed['repro_service_infer_s{quantile="0.50"}']
    )
    # every sample line must be within the exposition grammar
    for line in text.splitlines():
        assert line.startswith("#") or parse_prometheus(line), line


def test_sampler_always_leaves_a_line(tmp_path):
    reg = seeded_registry()
    path = tmp_path / "samples.jsonl"
    s = Sampler(path, reg, interval_s=30.0).start()   # run << interval
    n = s.stop()
    assert n >= 1                                     # the closing bookend
    lines = [json.loads(x) for x in path.read_text().splitlines()]
    assert len(lines) == n
    last = lines[-1]
    assert last["counters"]["service.device_calls"] == 7
    assert last["gauges"]["service.queue_depth"]["max"] == 3
    assert last["histograms"]["service.infer_s"]["count"] == 4
    assert last["elapsed_s"] >= 0.0


def test_sampler_samples_periodically(tmp_path):
    reg = seeded_registry()
    with Sampler(tmp_path / "s.jsonl", reg, interval_s=0.02,
                 extra=lambda: {"pending": 5}) as s:
        time.sleep(0.2)
    assert s.samples >= 3
    line = json.loads(
        (tmp_path / "s.jsonl").read_text().splitlines()[0])
    assert line["pending"] == 5                       # extra() merged in


def test_metrics_server_scrape():
    reg = seeded_registry()
    srv = start_metrics_server(reg, stats_fn=lambda: {"tickets": 12})
    try:
        with urllib.request.urlopen(f"{srv.url}/metrics", timeout=10) as r:
            assert r.status == 200
            parsed = parse_prometheus(r.read().decode())
        assert parsed["repro_service_device_calls_total"] == 7.0
        # the scrape is live, not a snapshot-at-start
        reg.counter("service.device_calls").inc(3)
        with urllib.request.urlopen(f"{srv.url}/metrics", timeout=10) as r:
            assert parse_prometheus(r.read().decode())[
                "repro_service_device_calls_total"] == 10.0
        with urllib.request.urlopen(f"{srv.url}/stats", timeout=10) as r:
            assert json.loads(r.read()) == {"tickets": 12}
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(f"{srv.url}/nope", timeout=10)
    finally:
        srv.close()


# ---------------------------------------------------------------------------
# regression sentry
# ---------------------------------------------------------------------------

def bench_payload(**over) -> dict:
    base = {
        "schema": regress.SCHEMA_VERSION,
        "host": regress.host_info(),
        "suite": "service",
        "ok": True,
        "runtime_s": 10.0,
        "report": {"plan_cache_hit_rate": 0.80},
        "tables": [
            {"mode": "service", "req_per_s": 40.0, "p95_ms": 120.0,
             "cold_compiles": 0, "compiles": 3},
            {"mode": "one-shot", "req_per_s": 10.0, "p95_ms": 300.0,
             "cold_compiles": 0, "compiles": 3},
        ],
    }
    base.update(over)
    return base


def test_flatten_keys_table_rows_by_tag():
    flat = regress.flatten(bench_payload())
    assert flat["tables.service.req_per_s"] == 40.0
    assert flat["tables.one-shot.p95_ms"] == 300.0
    assert flat["runtime_s"] == 10.0
    assert flat["report.plan_cache_hit_rate"] == 0.80
    assert "host.machine" not in " ".join(flat)       # fenced, not compared


def test_compare_unperturbed_passes():
    cmp = regress.compare(bench_payload(), bench_payload(), suite="svc")
    assert cmp.ok and not cmp.skipped_timing
    assert len(cmp.findings) > 0
    table = regress.render_table(cmp)
    assert "0 regression(s)" in table


def test_compare_names_metric_and_tolerance_on_regression():
    fresh = bench_payload()
    fresh["tables"][0]["req_per_s"] = 20.0            # -50% > the 30% floor
    cmp = regress.compare(fresh, bench_payload(), suite="svc")
    assert not cmp.ok
    bad = cmp.regressions[0]
    assert bad.key == "tables.service.req_per_s"
    assert bad.rule.kind == "min_ratio" and bad.rule.tol == 0.30
    table = regress.render_table(cmp)
    assert "tables.service.req_per_s" in table and "REGRESSION" in table
    assert "-30%" in table                            # the tolerance, spelled out


def test_compare_rules():
    # runtimes may grow 50%, no further
    slow = bench_payload(runtime_s=14.9)
    assert regress.compare(slow, bench_payload(), suite="s").ok
    slower = bench_payload(runtime_s=15.1)
    assert not regress.compare(slower, bench_payload(), suite="s").ok
    # cold_compiles must match exactly
    cold = bench_payload()
    cold["tables"][0]["cold_compiles"] = 1
    cmp = regress.compare(cold, bench_payload(), suite="s")
    assert [f.key for f in cmp.regressions] == ["tables.service.cold_compiles"]
    # total compiles may shrink but never grow
    grew = bench_payload()
    grew["tables"][0]["compiles"] = 4
    assert not regress.compare(grew, bench_payload(), suite="s").ok
    shrank = bench_payload()
    shrank["tables"][0]["compiles"] = 2
    assert regress.compare(shrank, bench_payload(), suite="s").ok
    # hit rates may sag 5 points
    sagged = bench_payload(report={"plan_cache_hit_rate": 0.76})
    assert regress.compare(sagged, bench_payload(), suite="s").ok
    cratered = bench_payload(report={"plan_cache_hit_rate": 0.70})
    assert not regress.compare(cratered, bench_payload(), suite="s").ok


def test_schema_mismatch_is_a_hard_failure():
    stale = bench_payload(schema=regress.SCHEMA_VERSION - 1)
    with pytest.raises(ValueError, match="schema mismatch"):
        regress.compare(bench_payload(), stale, suite="svc")


def test_host_mismatch_skips_timing_rules_only():
    other = bench_payload()
    other["host"] = dict(other["host"], machine="arm64", device="tpu")
    fresh = bench_payload(runtime_s=99.0)             # 10x slower...
    fresh["tables"][0]["cold_compiles"] = 1           # ...and a counter break
    cmp = regress.compare(fresh, other, suite="svc")
    assert cmp.skipped_timing and "timing rules skipped" in cmp.note
    # the runtime blowup is forgiven (different machine), the counter is not
    assert [f.key for f in cmp.regressions] == ["tables.service.cold_compiles"]
    with pytest.raises(ValueError, match="host mismatch"):
        regress.compare(fresh, other, suite="svc", strict_host=True)


def test_regress_cli_end_to_end(tmp_path, capsys):
    fresh_p = tmp_path / "BENCH_service.json"
    base_dir = tmp_path / "baselines"
    fresh_p.write_text(json.dumps(bench_payload()))
    # no baseline yet: skip with a notice, exit 0
    assert regress.main([str(fresh_p), "--baseline", str(base_dir)]) == 0
    assert "no baseline" in capsys.readouterr().out
    # bless, then an unperturbed re-run passes
    assert regress.main([str(fresh_p), "--baseline", str(base_dir),
                         "--bless"]) == 0
    assert (base_dir / "BENCH_service.json").exists()
    assert regress.main([str(fresh_p), "--baseline", str(base_dir)]) == 0
    # a perturbed run fails, naming the metric in the output
    bad = bench_payload()
    bad["tables"][0]["req_per_s"] = 1.0
    fresh_p.write_text(json.dumps(bad))
    assert regress.main([str(fresh_p), "--baseline", str(base_dir)]) == 1
    assert "tables.service.req_per_s" in capsys.readouterr().out
    # a suite that itself failed is a regression even if metrics pass
    sick = bench_payload(ok=False, error="boom")
    fresh_p.write_text(json.dumps(sick))
    assert regress.main([str(fresh_p), "--baseline", str(base_dir)]) == 1
    # schema mismatch is exit 2
    stale = copy.deepcopy(bench_payload())
    stale["schema"] = regress.SCHEMA_VERSION - 1
    fresh_p.write_text(json.dumps(stale))
    assert regress.main([str(fresh_p), "--baseline", str(base_dir)]) == 2


def test_committed_baselines_match_sentry_schema():
    """The blessed baselines in-repo must be diffable by this sentry."""
    from pathlib import Path

    base_dir = Path(__file__).resolve().parent.parent / "benchmarks" / "baselines"
    paths = sorted(base_dir.glob("BENCH_*.json"))
    assert paths, f"no blessed baselines under {base_dir}"
    for p in paths:
        payload = json.loads(p.read_text())
        assert payload["schema"] == regress.SCHEMA_VERSION, p.name
        assert payload["ok"] is True, p.name
        assert payload["host"]["machine"], p.name
        # self-comparison of a blessed payload is clean by construction
        assert regress.compare(payload, payload, suite=p.name).ok


# ---------------------------------------------------------------------------
# model-vs-actual memory accounting
# ---------------------------------------------------------------------------

def test_streamed_verify_reports_memory_model(rand_params):
    from repro.api import Session, SessionConfig

    cfg = SessionConfig(num_partitions=4, stream_capacity=2)
    with Session(rand_params, cfg) as sess:
        r = sess.verify(dataset="csa", bits=16, verify=False, use_cache=False)
        assert r.routing.mode == "streamed"
        stats = r.exec_stats
        assert stats["modeled_peak_bytes"] > 0
        assert stats["actual_peak_bytes"] > 0
        assert stats["model_drift"] == pytest.approx(
            stats["actual_peak_bytes"] / stats["modeled_peak_bytes"])
        # the model is an upper bound on a single-bucket plan, and actual
        # should be the same order of magnitude (the whole point of the
        # accounting is to catch this ratio drifting)
        assert 0.01 < stats["model_drift"] <= 1.5
        rep = sess.report()
    mm = rep.memory_model
    assert mm is not None
    assert mm["modeled_peak_bytes"] >= stats["modeled_peak_bytes"]
    assert mm["drift"] == pytest.approx(
        mm["actual_peak_bytes"] / mm["modeled_peak_bytes"])
    # peaks are gauges (high-water), never summed into process counters
    assert "exec.modeled_peak_bytes" not in rep.process
    d = rep.to_dict()
    assert d["memory_model"] == mm
    assert d["process_gauges"]["exec.modeled_peak_bytes"]["max"] > 0


def test_full_mode_has_no_memory_model(rand_params):
    from repro.api import Session, SessionConfig

    with Session(rand_params, SessionConfig(num_partitions=1)) as sess:
        r = sess.verify(dataset="csa", bits=4, verify=False, use_cache=False)
        assert r.routing.mode == "full"
        assert "modeled_peak_bytes" not in r.exec_stats
        assert sess.report().memory_model is None


# ---------------------------------------------------------------------------
# obs.check CLI passthrough
# ---------------------------------------------------------------------------

def test_check_forwards_design_and_repeats(tmp_path, monkeypatch):
    from repro.obs import check

    trace = tmp_path / "t.json"
    trace.write_text(json.dumps({"traceEvents": []}))
    seen = {}

    def fake_overhead(design, repeats=3):
        seen.update(design=design, repeats=repeats)
        return {"design": design, "repeats": repeats,
                "untraced_s": 1.0, "traced_s": 1.01, "overhead": 0.01}

    monkeypatch.setattr(check, "measure_overhead", fake_overhead)
    monkeypatch.setattr(check, "check_trace", lambda *a: [])
    rc = check.main([str(trace), "--overhead-gate", "0.05",
                     "--design", "csa-8", "--repeats", "5"])
    assert rc == 0
    assert seen == {"design": "csa-8", "repeats": 5}
    # --overhead-design remains valid spelling for the same destination
    rc = check.main([str(trace), "--overhead-gate", "0.05",
                     "--overhead-design", "csa-4"])
    assert seen["design"] == "csa-4" and seen["repeats"] == 3
    assert rc == 0
