"""Partitioner quality + degenerate-input contract (core/partition.py).

The contract the streaming executor relies on: every part id emitted by a
partitioner names a non-empty partition in range, for ANY (graph, k) —
including k > num_nodes, k == 1 and empty graphs — and
``extract_partitions`` never yields an empty or out-of-range subgraph.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core import aig as A
from repro.core.graph import EdgeGraph
from repro.core.partition import (
    PARTITIONERS,
    bfs_stripe_partition,
    edge_cut,
    multilevel_partition,
)
from repro.core.regrowth import extract_partitions


def _graph(fam="csa", bits=16):
    return A.make_design(fam, bits).to_edge_graph()


def _empty_graph():
    return EdgeGraph(0, np.zeros(0, np.int32), np.zeros(0, np.int32))


# ---------------------------------------------------------------------------
# Quality
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fam,bits,k", [("csa", 16, 4), ("mapped", 16, 4),
                                        ("booth", 16, 8)])
def test_multilevel_balance_within_tol(fam, bits, k):
    g = _graph(fam, bits)
    part = multilevel_partition(g, k, tol=0.1, seed=0)
    sizes = np.bincount(part, minlength=k)
    assert sizes.min() > 0
    # tol + slack for greedy-grow overshoot on heavy coarse nodes
    assert sizes.max() <= 1.2 * g.num_nodes / k


@pytest.mark.parametrize("k", [4, 8])
def test_bfs_stripes_are_balanced_and_contiguous(k):
    g = _graph()
    part = bfs_stripe_partition(g, k)
    sizes = np.bincount(part, minlength=k)
    assert sizes.max() - sizes.min() <= 1          # equal stripes
    assert (np.diff(part) >= 0).all()              # contiguous in node order


@pytest.mark.parametrize("fam,bits", [("csa", 16), ("booth", 16), ("mapped", 16)])
@pytest.mark.parametrize("k", [4, 8])
def test_multilevel_cut_beats_bfs_stripes(fam, bits, k):
    """Edge-cut sanity on the paper's Fig.-4-style AIG families: the
    METIS-style partitioner must not lose to the O(N) stripe baseline."""
    g = _graph(fam, bits)
    cut_ml = edge_cut(g, multilevel_partition(g, k, seed=0))
    cut_bfs = edge_cut(g, bfs_stripe_partition(g, k))
    assert cut_ml <= cut_bfs


@pytest.mark.parametrize("partitioner", ["multilevel", "bfs"])
def test_partitioner_deterministic_under_fixed_seed(partitioner):
    g = _graph()
    a = PARTITIONERS[partitioner](g, 4, seed=3)
    b = PARTITIONERS[partitioner](g, 4, seed=3)
    np.testing.assert_array_equal(a, b)
    assert a.dtype == np.int32


# ---------------------------------------------------------------------------
# Degenerate inputs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("partitioner", ["multilevel", "bfs"])
def test_k_larger_than_num_nodes(partitioner):
    g = A.csa_multiplier(2).to_edge_graph()     # tiny graph
    part = PARTITIONERS[partitioner](g, g.num_nodes + 100)
    assert part.shape == (g.num_nodes,)
    # every part id in range and every used partition non-empty
    assert part.min() >= 0 and part.max() < g.num_nodes
    sizes = np.bincount(part)
    assert (sizes[np.unique(part)] > 0).all()
    subs = extract_partitions(g, part, regrow=True)
    assert 0 < len(subs) <= g.num_nodes
    assert all(sg.num_core > 0 for sg in subs)


@pytest.mark.parametrize("partitioner", ["multilevel", "bfs"])
def test_k_equals_one_is_trivial(partitioner):
    g = _graph(bits=8)
    part = PARTITIONERS[partitioner](g, 1)
    assert (part == 0).all()
    subs = extract_partitions(g, part, regrow=True)
    assert len(subs) == 1
    assert subs[0].num_core == g.num_nodes and subs[0].num_halo == 0
    assert subs[0].num_edges == g.num_edges


@pytest.mark.parametrize("partitioner", ["multilevel", "bfs"])
@pytest.mark.parametrize("k", [1, 4])
def test_empty_graph(partitioner, k):
    g = _empty_graph()
    part = PARTITIONERS[partitioner](g, k)
    assert part.shape == (0,) and part.dtype == np.int32
    assert extract_partitions(g, part, regrow=True) == []


def test_extract_partitions_compacts_gappy_part_ids():
    """A sparse labeling (empty partition in the middle) yields one
    subgraph per NON-empty partition — the executor can never be handed an
    empty or out-of-range part."""
    g = A.csa_multiplier(2).to_edge_graph()
    n = g.num_nodes
    part = np.full(n, 7, np.int32)
    part[: n // 2] = 2                           # ids {2, 7}: gaps + offset
    subs = extract_partitions(g, part, regrow=False)
    assert len(subs) == 2
    assert sorted(len(sg.global_ids) for sg in subs) == sorted(
        [n // 2, n - n // 2]
    )


@pytest.mark.parametrize("regrow", [True, False])
def test_extract_partitions_core_cover_is_exact(regrow):
    """Core node sets tile the graph: every node is core of exactly one
    subgraph (what makes the executor's scatter complete and unambiguous)."""
    g = _graph(bits=8)
    part = multilevel_partition(g, 4, seed=0)
    subs = extract_partitions(g, part, regrow=regrow)
    seen = np.zeros(g.num_nodes, dtype=np.int64)
    for sg in subs:
        np.add.at(seen, sg.global_ids[: sg.num_core], 1)
        # local edge ids are always in range
        if sg.num_edges:
            assert sg.edge_src.min() >= 0 and sg.edge_src.max() < sg.num_nodes
            assert sg.edge_dst.min() >= 0 and sg.edge_dst.max() < sg.num_nodes
    assert (seen == 1).all()
