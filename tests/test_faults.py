"""Failure-domain hardening: fault injection, retry policy, parser
hardening, crash-safe journaling, degradation, and service blast-radius
isolation.

Fast lane, untrained params throughout — these tests pin *failure
semantics* (who fails, who survives, what never hangs), not accuracy:

  * ``repro.faults``: deterministic seeded triggering (p / nth / every /
    match / max_fires), the spec grammar round-trip, latency-only kinds;
  * ``repro.distributed.fault_tolerance``: the ONE retry/backoff policy
    (deterministic delays, transient classification, bounded replays);
  * ``repro.io.aiger``: malformed input raises typed, byte-offset
    ``AigerParseError`` — fuzz-style over mutations of a valid file;
  * ``PartitionJournal``: atomic commit/restore, fingerprint-mismatch
    wipe, corrupt-entry tolerance;
  * ``StreamingExecutor``: resource-error capacity degradation (bit-exact
    results at reduced capacity), prefetch-death watchdog (loud failure,
    never a silent hang), journaled resume after a mid-run crash;
  * ``VerificationService``: deadlines (expired tickets fail, poll/result
    never block forever), transient-launch retries, pack bisection (a
    poisoned design fails alone), worker-death containment, and resource
    release on every failure path (tenant slots, pool occupancy).
"""
from __future__ import annotations

import dataclasses
import threading
import time

import jax
import numpy as np
import pytest

from repro import faults
from repro.checkpoint import PartitionJournal
from repro.core import aig as A
from repro.core import gnn
from repro.core.features import groot_features
from repro.core.partition import PARTITIONERS
from repro.core.regrowth import extract_partitions
from repro.distributed.fault_tolerance import (
    backoff_delays,
    is_transient,
    retry_call,
)
from repro.exec import StreamingExecutor, plan_from_subgraphs
from repro.io import aiger
from repro.service import VerificationService
from repro.service.server import DeadlineExceeded


@pytest.fixture(scope="module")
def rand_params():
    return gnn.init_params(gnn.GNNConfig(), jax.random.key(0))


@pytest.fixture(autouse=True)
def _no_leaked_plan():
    """Every test starts and ends with no installed fault plan."""
    faults.uninstall()
    yield
    faults.uninstall()


def _partitioned(bits=12, k=4, seed=0):
    d = A.csa_multiplier(bits)
    g = d.to_edge_graph()
    feats = groot_features(d)
    part = PARTITIONERS["multilevel"](g, k, seed=seed)
    subs = extract_partitions(g, part, regrow=True)
    plan = plan_from_subgraphs(list(subs), g.num_nodes, min_nodes=64,
                               min_edges=128)
    return plan, feats


def make_service(params, **overrides):
    overrides.setdefault("num_partitions", 1)
    overrides.setdefault("prepare_workers", 2)
    return VerificationService(params, _warn=False, **overrides)


class GatedRunner:
    """Wraps a BucketRunner: every call blocks until ``release()`` — the
    deterministic-interleaving trick from test_service_loop."""

    def __init__(self, inner):
        self._inner = inner
        self._gate = threading.Event()
        self.entered = threading.Event()

    def __getattr__(self, name):
        return getattr(self._inner, name)

    def release(self):
        self._gate.set()

    def __call__(self, batch):
        self.entered.set()
        assert self._gate.wait(timeout=60.0), "gate never released"
        return self._inner(batch)


def wait_for(cond, timeout=30.0, msg="condition"):
    t0 = time.perf_counter()
    while not cond():
        if time.perf_counter() - t0 > timeout:
            raise AssertionError(f"timed out waiting for {msg}")
        time.sleep(0.005)


# ---------------------------------------------------------------------------
# repro.faults: the injection mechanism itself
# ---------------------------------------------------------------------------

def test_plan_spec_grammar_roundtrip():
    spec = ("service.device:p=0.2,kind=transient,seed=7;"
            "io.parse:nth=3,match=booth,kind=fatal")
    plan = faults.FaultPlan.parse(spec)
    assert plan.seed == 7 and len(plan.specs) == 2
    assert plan.specs[0].p == 0.2 and plan.specs[1].nth == 3
    assert plan.specs[1].match == "booth"
    # the round-trip parses back to the same plan
    assert faults.FaultPlan.parse(plan.to_spec()) == plan
    assert faults.FaultPlan.coerce(plan) is plan
    assert not faults.FaultPlan()
    assert bool(plan)


def test_plan_rejects_unknown_site_and_kind():
    with pytest.raises(ValueError):
        faults.FaultPlan.parse("nope.site:p=1")
    with pytest.raises(ValueError):
        faults.FaultPlan.parse("io.parse:kind=meteor")
    with pytest.raises(ValueError):
        faults.FaultPlan.parse("io.parse:frequency=2")


def test_probability_trigger_is_deterministic_per_seed():
    def fires(seed):
        out = []
        with faults.injected(f"io.parse:p=0.3,kind=transient,seed={seed}"):
            for i in range(50):
                try:
                    faults.fire("io.parse")
                    out.append(False)
                except faults.TransientFault:
                    out.append(True)
        return out

    a, b = fires(11), fires(11)
    assert a == b                      # same seed -> same failures
    assert any(a) and not all(a)       # ~30%: some fire, some don't
    assert fires(12) != a              # a different seed differs


def test_nth_every_match_and_max_fires():
    with faults.injected("io.parse:nth=2,kind=fatal") as inj:
        faults.fire("io.parse")
        with pytest.raises(faults.FatalFault):
            faults.fire("io.parse")
        faults.fire("io.parse")        # nth fires exactly once
        assert inj.stats()["fired"]["io.parse"] == 1

    with faults.injected("io.parse:every=2,max_fires=2,kind=transient"):
        hits = 0
        for _ in range(10):
            try:
                faults.fire("io.parse")
            except faults.TransientFault:
                hits += 1
        assert hits == 2               # every 2nd call, capped at 2 fires

    with faults.injected("io.parse:every=1,match=bad,kind=fatal"):
        faults.fire("io.parse", tag="good_design")
        with pytest.raises(faults.FatalFault) as ei:
            faults.fire("io.parse", tag="bad_design")
        assert "bad_design" in str(ei.value)


def test_latency_only_kind_delays_without_raising():
    with faults.injected("cache.load:every=1,latency=0.05,kind=latency"):
        t0 = time.perf_counter()
        faults.fire("cache.load")
        assert time.perf_counter() - t0 >= 0.045


def test_lazy_tag_not_evaluated_when_inactive():
    evaluated = []
    faults.fire("io.parse", tag=lambda: evaluated.append(1))
    assert not evaluated
    with faults.injected("io.parse:every=1,kind=transient"):
        with pytest.raises(faults.TransientFault):
            faults.fire("io.parse", tag=lambda: (evaluated.append(1), "t")[1])
    assert evaluated


def test_injected_restores_previous_plan():
    outer = faults.install("io.parse:every=1,kind=fatal")
    try:
        with faults.injected("cache.load:every=1,kind=transient"):
            assert faults.active() is not outer
            faults.fire("io.parse")          # outer plan inactive inside
        assert faults.active() is outer
        with pytest.raises(faults.FatalFault):
            faults.fire("io.parse")
    finally:
        faults.uninstall()


def test_is_resource_error_classification():
    assert faults.is_resource_error(faults.ResourceFault("x"))
    assert faults.is_resource_error(MemoryError())
    assert faults.is_resource_error(RuntimeError("RESOURCE_EXHAUSTED: hbm"))
    assert not faults.is_resource_error(faults.TransientFault("x"))
    assert not faults.is_resource_error(ValueError("nope"))


# ---------------------------------------------------------------------------
# distributed.fault_tolerance: the shared retry/backoff policy
# ---------------------------------------------------------------------------

def test_backoff_delays_deterministic_and_bounded():
    a = list(backoff_delays(5, seed=3))
    assert a == list(backoff_delays(5, seed=3))
    assert a != list(backoff_delays(5, seed=4))
    assert len(a) == 5 and all(0 < d <= 5.0 * 1.5 for d in a)
    # exponential spine: later delays dominate earlier ones on average
    assert sum(a[3:]) > sum(a[:2])
    assert list(backoff_delays(0)) == []


def test_is_transient_classifier():
    assert is_transient(faults.TransientFault("x"))
    assert is_transient(ConnectionError())
    assert is_transient(TimeoutError())
    assert is_transient(RuntimeError("UNAVAILABLE: device busy"))
    assert not is_transient(faults.FatalFault("poisoned"))
    assert not is_transient(ValueError("bad input"))


def test_retry_call_replays_transients_and_respects_fatal():
    calls, retries = [], []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise faults.TransientFault("blip")
        return "ok"

    out = retry_call(flaky, retries=3, on_retry=lambda i, e: retries.append(i),
                     sleep=lambda s: None)
    assert out == "ok" and len(calls) == 3 and retries == [0, 1]

    with pytest.raises(faults.FatalFault):
        retry_call(lambda: (_ for _ in ()).throw(faults.FatalFault("dead")),
                   retries=5, sleep=lambda s: None)

    # budget exhaustion re-raises the last transient
    with pytest.raises(faults.TransientFault):
        retry_call(lambda: (_ for _ in ()).throw(faults.TransientFault("x")),
                   retries=2, sleep=lambda s: None)


# ---------------------------------------------------------------------------
# io.aiger hardening: typed, byte-attributed parse errors
# ---------------------------------------------------------------------------

def test_truncated_binary_section_raises_offset_error():
    good = aiger.dumps(A.csa_multiplier(6))
    with pytest.raises(aiger.AigerParseError) as ei:
        aiger.loads(good[: len(good) // 2])
    assert "at byte" in str(ei.value)
    assert ei.value.offset is not None


def test_header_count_sanity():
    with pytest.raises(aiger.AigerParseError):
        aiger.loads(b"aig 5 2 0 1 -3\n")
    # counts absurdly larger than the file must be rejected before sizing
    # any allocation
    with pytest.raises(aiger.AigerParseError):
        aiger.loads(b"aig 999999999 2 0 1 999999997\n")
    with pytest.raises(aiger.AigerParseError):
        aiger.loads(b"aig x y z\n")


def test_bad_ascii_and_line_raises():
    bad = b"aag 3 2 0 1 1\n2\n4\n6\n6 4 banana\n"
    with pytest.raises(aiger.AigerParseError) as ei:
        aiger.loads(bad)
    assert "AND line" in str(ei.value)


def test_fuzz_mutations_never_escape_typed_errors():
    """Truncations and byte flips of a valid file either parse or raise
    AigerError — never IndexError/struct.error/MemoryError."""
    good = aiger.dumps(A.csa_multiplier(4))
    rng = np.random.default_rng(0)
    cases = [good[:n] for n in range(0, len(good), 7)]
    for _ in range(60):
        buf = bytearray(good)
        for _ in range(rng.integers(1, 4)):
            buf[rng.integers(0, len(buf))] = rng.integers(0, 256)
        cases.append(bytes(buf))
    for blob in cases:
        try:
            aiger.loads(blob)
        except aiger.AigerError:
            pass          # typed rejection is the contract


def test_io_parse_fault_site_fires_with_design_tag():
    good = aiger.dumps(A.csa_multiplier(4))
    with faults.injected("io.parse:every=1,kind=fatal"):
        with pytest.raises(faults.FatalFault):
            aiger.loads(good)
    assert aiger.loads(good).num_ands > 0       # no plan: parses fine


# ---------------------------------------------------------------------------
# PartitionJournal: atomic commit / restore / invalidation
# ---------------------------------------------------------------------------

def test_journal_commit_restore_roundtrip(tmp_path):
    plan, _ = _partitioned()
    j = PartitionJournal(tmp_path, "designA")
    assert j.open(plan) == set()
    ref = np.arange(plan.num_nodes, dtype=np.int32) % 5
    for i in (0, 2):
        sg = plan.subgraphs[i]
        ids = sg.global_ids[: sg.num_core]
        j.commit(i, ids, ref[ids])
    out = np.zeros(plan.num_nodes, dtype=np.int32)
    j2 = PartitionJournal(tmp_path, "designA")    # fresh process view
    restored = j2.restore(plan, out)
    assert restored == {0, 2}
    for i in restored:
        sg = plan.subgraphs[i]
        ids = sg.global_ids[: sg.num_core]
        np.testing.assert_array_equal(out[ids], ref[ids])
    j2.complete()
    assert not j2.dir.exists()


def test_journal_wiped_on_plan_fingerprint_mismatch(tmp_path):
    plan, _ = _partitioned(k=4)
    other, _ = _partitioned(k=6)
    j = PartitionJournal(tmp_path, "d")
    j.open(plan)
    sg = plan.subgraphs[0]
    ids = sg.global_ids[: sg.num_core]
    j.commit(0, ids, np.zeros(len(ids), np.int32))
    # same design key, different partitioning -> stale indices, wiped
    assert PartitionJournal(tmp_path, "d").restore(
        other, np.zeros(other.num_nodes, np.int32)
    ) == set()


def test_journal_tolerates_corrupt_entries(tmp_path):
    plan, _ = _partitioned()
    j = PartitionJournal(tmp_path, "d")
    j.open(plan)
    sg = plan.subgraphs[1]
    ids = sg.global_ids[: sg.num_core]
    j.commit(1, ids, np.ones(len(ids), np.int32))
    (j.dir / "part_00003.npz").write_bytes(b"not an npz")   # torn write
    out = np.zeros(plan.num_nodes, np.int32)
    assert PartitionJournal(tmp_path, "d").restore(plan, out) == {1}
    assert not (j.dir / "part_00003.npz").exists()          # dropped


# ---------------------------------------------------------------------------
# StreamingExecutor: degradation, watchdog, resume
# ---------------------------------------------------------------------------

def test_resource_error_halves_capacity_bit_exact(rand_params):
    plan, feats = _partitioned(k=4)
    baseline = StreamingExecutor(rand_params, "ref", capacity=2, prefetch=0)
    want = baseline.run_plan(plan, feats)

    # the degradation premise needs a multi-slot batch to split
    assert any(len(ix) > 1 for _, ix in plan.schedule(2))
    ex = StreamingExecutor(rand_params, "ref", capacity=2, prefetch=0)
    with faults.injected("exec.launch:nth=1,kind=resource"):
        got = ex.run_plan(plan, feats)
    np.testing.assert_array_equal(got, want)
    assert ex.stats.capacity_halvings >= 1


def test_resource_error_on_singleton_propagates(rand_params):
    plan, feats = _partitioned(k=4)
    ex = StreamingExecutor(rand_params, "ref", capacity=2, prefetch=0)
    with faults.injected("exec.launch:every=1,kind=resource"):
        with pytest.raises(faults.ResourceFault):
            ex.run_plan(plan, feats)


def test_prefetch_death_is_detected_not_a_hang(rand_params):
    plan, feats = _partitioned(k=6)
    assert len(plan.schedule(1)) > 1
    ex = StreamingExecutor(rand_params, "ref", capacity=1, prefetch=1)
    with faults.injected("exec.prefetch:nth=2,kind=kill"):
        t0 = time.perf_counter()
        with pytest.raises(RuntimeError, match="prefetch thread died"):
            ex.run_plan(plan, feats)
        assert time.perf_counter() - t0 < 30.0


def test_forwarded_prefetch_exception_still_raises(rand_params):
    plan, feats = _partitioned(k=6)
    ex = StreamingExecutor(rand_params, "ref", capacity=1, prefetch=1)
    with faults.injected("exec.prefetch:nth=2,kind=fatal"):
        with pytest.raises(faults.FatalFault):
            ex.run_plan(plan, feats)


def test_killed_run_resumes_only_unfinished_partitions(
        rand_params, tmp_path):
    plan, feats = _partitioned(k=6)
    total = plan.num_parts
    want = StreamingExecutor(rand_params, "ref", capacity=1,
                             prefetch=0).run_plan(plan, feats)

    journal = PartitionJournal(tmp_path, "csa12")
    ex = StreamingExecutor(rand_params, "ref", capacity=1, prefetch=0)
    # the "crash": a fatal fault partway through the launch sequence
    with faults.injected("exec.launch:nth=3,kind=fatal"):
        with pytest.raises(faults.FatalFault):
            ex.run_plan(plan, feats, journal=journal)
    committed = len(list(journal.dir.glob("part_*.npz")))
    assert 0 < committed < total

    ex2 = StreamingExecutor(rand_params, "ref", capacity=1, prefetch=0)
    got = ex2.run_plan(plan, feats,
                       journal=PartitionJournal(tmp_path, "csa12"))
    np.testing.assert_array_equal(got, want)
    assert ex2.stats.resumed_partitions == committed
    assert ex2.stats.partitions == total - committed    # only the rest ran
    assert not journal.dir.exists()                     # cleared when done


def test_session_config_threads_checkpoint_dir(tmp_path, rand_params):
    """checkpoint_dir flows SessionConfig -> PipelineConfig -> journal."""
    from repro.api import Session, SessionConfig

    cfg = SessionConfig(num_partitions=4, checkpoint_dir=str(tmp_path),
                        bits=10)
    pcfg = cfg.pipeline_config()
    assert pcfg.checkpoint_dir == str(tmp_path) and pcfg.resume
    sess = Session(rand_params, cfg)
    r = sess.verify(verify=False, use_cache=False)
    assert r.status == "classified"
    # a completed run leaves no journal behind
    assert not any(tmp_path.iterdir())


# ---------------------------------------------------------------------------
# VerificationService: deadlines, retries, bisection, containment, leaks
# ---------------------------------------------------------------------------

def test_deadline_expires_slow_ticket(rand_params):
    svc = make_service(rand_params, deadline_s=0.05)
    try:
        with faults.injected("service.prepare:every=1,latency=0.4,kind=latency"):
            t = svc.submit(dataset="csa", bits=4, verify=False)
            r = svc.result(t, timeout=30.0)
        assert r.status == "error"
        assert "DeadlineExceeded" in r.error
        snap = svc.metrics.snapshot()
        assert snap["counters"]["service.deadline_exceeded"] >= 1
        rec = svc.flights.records(failures_only=True)[-1]
        assert rec.deadline_s == 0.05
    finally:
        svc.close()


def test_poll_fails_expired_ticket_instead_of_none_forever(rand_params):
    svc = make_service(rand_params)
    try:
        with faults.injected("service.prepare:every=1,latency=0.5,kind=latency"):
            t = svc.submit(dataset="csa", bits=4, verify=False,
                           deadline_s=0.02)
            time.sleep(0.05)
            r = svc.poll(t)             # poll itself expires the ticket
        assert r is not None and r.status == "error"
    finally:
        svc.close()


def test_transient_launch_failures_retry_to_success(rand_params):
    svc = make_service(rand_params, launch_retries=3, retry_backoff_s=0.01)
    try:
        with faults.injected("service.device:every=1,max_fires=2,kind=transient"):
            t = svc.submit(dataset="csa", bits=4, verify=False)
            r = svc.result(t, timeout=60.0)
        assert r.status == "classified"           # survived the blips
        snap = svc.metrics.snapshot()
        assert snap["counters"]["service.retries"] == 2
        rec = [f for f in svc.flights.records() if f.req_id == t][-1]
        assert rec.retries == 2
    finally:
        svc.close()


def test_fatal_launch_failure_not_retried(rand_params):
    svc = make_service(rand_params, launch_retries=3, retry_backoff_s=0.01)
    try:
        with faults.injected("service.device:nth=1,kind=fatal"):
            t = svc.submit(dataset="csa", bits=4, verify=False)
            r = svc.result(t, timeout=60.0)
        assert r.status == "error" and "FatalFault" in r.error
        assert "service.retries" not in svc.metrics.snapshot()["counters"]
    finally:
        svc.close()


def test_bisection_isolates_poisoned_design(rand_params):
    """Four same-bucket designs packed together, one poisoned: the three
    well-formed tickets complete, the poisoned one fails alone with an
    attributed name."""
    svc = make_service(rand_params, capacity=4, prepare_workers=4,
                       launch_retries=0, coalesce=False)
    gate = GatedRunner(svc.scheduler.runner)
    svc.scheduler.runner = gate
    designs = [A.csa_multiplier(6) for _ in range(4)]
    designs[2] = dataclasses.replace(designs[2], name="poison_csa6")
    try:
        with faults.injected("service.device:every=1,match=poison,kind=fatal"):
            t_first = svc.submit(dataset="csa", bits=4, verify=False)
            assert gate.entered.wait(timeout=30.0)
            tickets = [svc.submit(design=d, verify=False, seed=i)
                       for i, d in enumerate(designs)]
            wait_for(lambda: svc._device_q.qsize() >= 4,
                     msg="all four prepared")
            gate.release()
            results = {t: svc.result(t, timeout=60.0) for t in tickets}
            svc.result(t_first, timeout=60.0)
        good = [r for r in results.values() if r.name != "poison_csa6"]
        bad = [r for r in results.values() if r.name == "poison_csa6"]
        assert len(bad) == 1 and bad[0].status == "error"
        assert "FatalFault" in bad[0].error
        assert all(r.status == "classified" for r in good)
        snap = svc.metrics.snapshot()
        assert snap["counters"].get("service.bisections", 0) >= 1
        rec = [f for f in svc.flights.records(failures_only=True)
               if f.name == "poison_csa6"][-1]
        assert rec.failed_stage == "infer"
    finally:
        gate.release()
        svc.close()


def test_worker_death_fails_pending_tickets_not_hangs(rand_params):
    svc = make_service(rand_params)
    try:
        with faults.injected("service.device:nth=1,kind=kill"):
            t = svc.submit(dataset="csa", bits=4, verify=False)
            t0 = time.perf_counter()
            r = svc.result(t, timeout=60.0)
            assert time.perf_counter() - t0 < 30.0
        assert r.status == "error"
        assert "worker" in r.error
        assert svc.metrics.snapshot()["counters"]["service.worker_deaths"] == 1
        # later tickets fail fast too instead of queueing forever
        t2 = svc.submit(dataset="csa", bits=4, seed=1, verify=False)
        r2 = svc.result(t2, timeout=30.0)
        assert r2.status == "error"
    finally:
        svc.close()


def test_result_timeout_raises(rand_params):
    svc = make_service(rand_params)
    try:
        with faults.injected("service.prepare:every=1,latency=1.0,kind=latency"):
            t = svc.submit(dataset="csa", bits=4, verify=False)
            with pytest.raises(TimeoutError):
                svc.result(t, timeout=0.05)
            r = svc.result(t, timeout=30.0)     # still completes afterwards
        assert r.status == "classified"
    finally:
        svc.close()


def test_failure_paths_release_tenant_and_pool_resources(rand_params):
    """A storm of failing tickets must leave zero residue: tenant slots
    free (no AdmissionError once failures finish), the in-flight map
    empty, and no ghost occupancy in the device pool."""
    svc = make_service(rand_params, max_inflight_per_tenant=5,
                       coalesce=False)
    try:
        with faults.injected("service.prepare:every=1,kind=fatal"):
            for i in range(40):
                t = svc.submit(dataset="csa", bits=4, seed=i, verify=False,
                               tenant="storm")
                r = svc.result(t, timeout=30.0)
                assert r.status == "error"
        assert svc._tenant_inflight == {}
        gauges = svc.metrics.snapshot()["gauges"]
        assert gauges.get("service.pending_items", {}).get("value", 0) == 0
        # the lane is genuinely clean: a healthy submit still works
        t = svc.submit(dataset="csa", bits=4, seed=999, verify=False,
                       tenant="storm")
        assert svc.result(t, timeout=60.0).status == "classified"
    finally:
        svc.close()


def test_slot_pool_prune_releases_dead_occupancy():
    from repro.service import SlotPool
    from repro.service.bucketing import BucketShape

    pool = SlotPool()
    a = BucketShape(64, 128)
    pool.admit(a, 1, 0, "live")
    pool.admit(a, 1, 1, "dead")
    assert pool.prune(lambda s: s == "dead") == 1
    assert len(pool) == 1
    assert [s for (_, _, s) in pool.take(a, 4)] == ["live"]
    assert pool.prune(lambda s: True) == 0      # empty heaps vanish cleanly
