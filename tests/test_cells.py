"""Cell-matrix completeness: the assigned (arch x shape) grid is exactly
the brief's 40 LM cells (skips per DESIGN.md), plus the groot cells."""
from __future__ import annotations

from repro.zoo.configs import ARCHS, LM_ARCHS, get_config
from repro.zoo.configs.shapes import SHAPES, supported_shapes


def test_lm_cell_matrix():
    cells = {
        (a, s) for a in LM_ARCHS for s in supported_shapes(get_config(a))
    }
    # 10 archs x 4 shapes = 40 assigned cells; long_500k runs only for the
    # sub-quadratic families and is a *documented skip* elsewhere.
    long_ok = {a for a, s in cells if s == "long_500k"}
    assert long_ok == {"rwkv6-3b", "recurrentgemma-9b"}
    assert len(cells) == 10 * 3 + 2
    # every skipped cell is a long_500k on a full-attention family
    skipped = {
        (a, s)
        for a in LM_ARCHS
        for s in SHAPES
        if s not in supported_shapes(get_config(a))
    }
    assert all(s == "long_500k" for _, s in skipped)
    assert len(cells) + len(skipped) == 40


def test_every_arch_has_smoke_variant():
    for arch in ARCHS:
        full = get_config(arch)
        smoke = get_config(arch, smoke=True)
        assert type(full) is type(smoke)
        if arch != "groot-gnn":
            assert smoke.num_layers < full.num_layers
            assert smoke.d_model < full.d_model
            assert smoke.family == full.family


def test_padded_heads_exactness_contract():
    """Archs with head padding keep their logical head count."""
    for arch, pad in (("qwen2-7b", 32), ("llama4-maverick-400b-a17b", 48),
                      ("whisper-base", 16)):
        cfg = get_config(arch)
        assert cfg.padded_heads == pad
        assert cfg.num_heads < pad  # logical count untouched
