"""Per-architecture smoke tests (deliverable f).

Each assigned arch instantiates a REDUCED config of the same family and
runs one forward + one train step on CPU, asserting output shapes and
no NaNs; decode-vs-full-forward exactness is asserted for every arch with
a decode path.  Full configs are exercised only by the dry-run.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # ~40 s of per-arch compiles; full-lane only

from repro.zoo.configs import ARCHS, LM_ARCHS, get_config
from repro.zoo.configs.base import abstract, materialize, model_spec_tree, param_tree
from repro.zoo.configs.shapes import SHAPES, input_specs, supported_shapes
from repro.zoo.models.transformer import init_cache_tree, model_forward
from repro.training import optimizer as opt_mod
from repro.training.train_step import make_train_step

ARCH_IDS = sorted(LM_ARCHS)


def _setup(arch):
    cfg = get_config(arch, smoke=True)
    params = materialize(model_spec_tree(cfg), jax.random.key(0), jnp.float32)
    B, S = 2, 16
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + 1)), jnp.int32)
    enc = None
    if cfg.encoder_seq or cfg.cross_seq:
        enc = 0.1 * jnp.asarray(
            rng.standard_normal((B, cfg.encoder_seq or cfg.cross_seq, cfg.d_model)),
            jnp.bfloat16,
        )
    return cfg, params, toks, enc


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg, params, toks, enc = _setup(arch)
    b, s1 = toks.shape
    logits, _ = model_forward(params, cfg, toks[:, :-1], enc_input=enc)
    assert logits.shape == (b, s1 - 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch):
    cfg, params, toks, enc = _setup(arch)
    opt = opt_mod.AdamW(lr=1e-3)
    state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt, microbatches=2))
    batch = {"tokens": toks}
    if enc is not None:
        batch["enc_input"] = enc
    params2, state2, metrics = step(params, state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually moved
    moved = jax.tree.map(
        lambda a, b_: float(jnp.abs(a - b_).max()), params, params2
    )
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_full_forward(arch):
    cfg, params, toks, enc = _setup(arch)
    b = toks.shape[0]
    s = toks.shape[1] - 1
    full, _ = model_forward(params, cfg, toks[:, :s], enc_input=enc)
    cache = init_cache_tree(cfg, b, s + 4, dtype=jnp.float32)
    _, cache = model_forward(
        params, cfg, toks[:, : s - 1], enc_input=enc, cache=cache
    )
    dec, cache = model_forward(params, cfg, toks[:, s - 1 : s], cache=cache, decode=True)
    np.testing.assert_allclose(
        np.asarray(dec[:, -1], np.float32),
        np.asarray(full[:, -1], np.float32),
        rtol=5e-3,
        atol=5e-3,
    )


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_input_specs_all_shapes(arch):
    """input_specs build for every non-skipped shape without allocation."""
    cfg = get_config(arch)  # FULL config: specs are shape-only
    for shape in supported_shapes(cfg):
        specs = input_specs(cfg, shape)
        sh = SHAPES[shape]
        if sh.kind == "train":
            assert specs["tokens"].shape == (sh.global_batch, sh.seq_len + 1)
        elif sh.kind == "prefill":
            assert specs["tokens"].shape == (sh.global_batch, sh.seq_len)
        else:
            assert specs["token"].shape == (sh.global_batch, 1)
            assert "cache" in specs
    # skip notes honoured
    if cfg.skip_shapes:
        assert "long_500k" in cfg.skip_shapes


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_abstract_params_match_materialized(arch):
    cfg = get_config(arch, smoke=True)
    spec = model_spec_tree(cfg)
    abs_tree = abstract(spec, jnp.float32)
    real = materialize(spec, jax.random.key(0), jnp.float32)
    ja, jr = jax.tree.leaves(abs_tree), jax.tree.leaves(real)
    assert len(ja) == len(jr)
    for a, r in zip(ja, jr):
        assert a.shape == r.shape and a.dtype == r.dtype


def test_param_counts_match_billing():
    """Full-config param counts are in the advertised ballpark."""
    expected = {
        "qwen3-8b": (7e9, 10e9),
        "qwen2-7b": (6e9, 9e9),
        "gemma2-9b": (8e9, 11e9),
        "deepseek-67b": (60e9, 72e9),
        "llama4-maverick-400b-a17b": (350e9, 450e9),
        "qwen3-moe-235b-a22b": (200e9, 260e9),
        "rwkv6-3b": (2.5e9, 4e9),
        "whisper-base": (0.04e9, 0.12e9),
        "llama-3.2-vision-11b": (9e9, 13e9),
        "recurrentgemma-9b": (7e9, 11e9),
    }
    for arch, (lo, hi) in expected.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"


def test_moe_active_params():
    cfg = get_config("qwen3-moe-235b-a22b")
    active = cfg.active_param_count()
    assert 15e9 <= active <= 30e9  # ~a22b
    cfg4 = get_config("llama4-maverick-400b-a17b")
    active4 = cfg4.active_param_count()
    assert 10e9 <= active4 <= 25e9  # ~a17b


def test_groot_arch_registered():
    assert "groot-gnn" in ARCHS
    gc = get_config("groot-gnn", smoke=True)
    assert gc.family == "gnn"
