"""`repro.obs`: the tracing + metrics spine.

Fast lane: span nesting/parenting, the disabled no-op path, Chrome
export round-trip, cross-thread adoption (both synthetic and through the
real exec prefetch thread), metrics registry semantics, the PROBE
bridge, and counter isolation between two live sessions.  Slow lane:
the csa-64 acceptance criterion — one traced verify per route (full /
partitioned-loop / streamed) whose trace passes the CI gate (required
children + >=95% coverage) and whose report carries non-zero plan-cache,
compile, and byte counters.
"""
from __future__ import annotations

import json
import threading
import time

import jax
import pytest

from repro.api import Session, SessionConfig
from repro.core import gnn
from repro.obs import (
    REGISTRY,
    CounterGroup,
    MetricsRegistry,
    NULL_TRACER,
    Tracer,
    current_tracer,
    fold_into,
    span,
    span_coverage,
    spans_from_chrome,
)
from repro.obs.check import check_trace


@pytest.fixture(scope="module")
def rand_params():
    return gnn.init_params(gnn.GNNConfig(), jax.random.key(0))


# ---------------------------------------------------------------------------
# Tracer: nesting, disabled path, export round-trip
# ---------------------------------------------------------------------------

def test_span_nesting_records_parent_ids():
    tr = Tracer()
    with tr.activate():
        with span("outer") as outer:
            with span("inner_a") as a:
                pass
            with span("inner_b", k=3) as b:
                b.set(extra="late")
    spans = {s.name: s for s in tr.spans()}
    assert spans["outer"].parent_id is None
    assert spans["inner_a"].parent_id == outer.span_id
    assert spans["inner_b"].parent_id == outer.span_id
    assert spans["inner_b"].attrs == {"k": 3, "extra": "late"}
    # children recorded before the parent closes, all well-formed
    for s in spans.values():
        assert s.t1 >= s.t0


def test_disabled_path_is_the_shared_noop():
    # no tracer active: module-level span() must not record anywhere
    assert current_tracer() is NULL_TRACER
    ctx = span("anything", k=1)
    with ctx as s:
        assert s.span_id is None
        s.set(ignored=True)  # no-op, no error
    # the no-op context is one shared singleton — zero allocation per span
    assert span("other") is ctx
    assert NULL_TRACER.adopt(42) is NULL_TRACER.activate() is ctx


def test_activate_restores_previous_tracer():
    t1, t2 = Tracer(), Tracer()
    with t1.activate():
        with t2.activate():
            with span("inner"):
                pass
        with span("outer"):
            pass
    assert current_tracer() is NULL_TRACER
    assert [s.name for s in t1.spans()] == ["outer"]
    assert [s.name for s in t2.spans()] == ["inner"]


def test_chrome_export_round_trip(tmp_path):
    tr = Tracer()
    with tr.activate():
        with span("root", design="csa-8"):
            with span("child"):
                pass
    path = tmp_path / "trace.json"
    tr.save(path)
    data = json.loads(path.read_text())
    # metadata event names the thread; X events carry the spans
    assert any(ev["ph"] == "M" for ev in data["traceEvents"])
    back = spans_from_chrome(data)
    orig = tr.spans()
    assert {s["name"] for s in back} == {s.name for s in orig}
    by_name = {s["name"]: s for s in back}
    root, child = by_name["root"], by_name["child"]
    assert child["parent_id"] == root["span_id"]
    assert root["attrs"]["design"] == "csa-8"
    # timestamps survive the µs round-trip to within a microsecond
    o = {s.name: s for s in orig}
    for name, s in by_name.items():
        assert abs((s["t1"] - s["t0"]) - o[name].duration) < 2e-6
    # coverage computes identically on dicts and Span objects
    assert span_coverage(back, root["span_id"]) == pytest.approx(
        span_coverage(orig, o["root"].span_id), abs=1e-6
    )


def test_cross_thread_adoption_parents_under_owner_span():
    tr = Tracer()
    with tr.activate():
        with span("owner") as owner:
            parent = tr.current_id()

            def worker():
                with tr.adopt(parent):
                    with span("worker_span"):
                        pass

            t = threading.Thread(target=worker, name="obs-worker")
            t.start()
            t.join()
    spans = {s.name: s for s in tr.spans()}
    w = spans["worker_span"]
    assert w.parent_id == owner.span_id
    assert w.thread == "obs-worker"
    assert w.tid != spans["owner"].tid


# ---------------------------------------------------------------------------
# Metrics: registry semantics, the PROBE bridge, fold_into
# ---------------------------------------------------------------------------

def test_registry_instruments_and_delta():
    reg = MetricsRegistry()
    reg.counter("a.hits").inc()
    reg.counter("a.hits").inc(2)
    assert reg.counter("a.hits") is reg.counter("a.hits")
    before = reg.snapshot()
    reg.counter("a.hits").inc(5)
    reg.counter("b.new").inc()
    assert reg.delta(before) == {"a.hits": 5, "b.new": 1}
    assert reg.delta(before, prefix="a.") == {"a.hits": 5}

    g = reg.gauge("q.depth")
    g.set(3)
    g.set(1)
    assert (g.value, g.max) == (1, 3)

    h = reg.histogram("lat_s")
    for v in (0.1, 0.2, 0.3):
        h.observe(v)
    s = h.summary()
    assert s["count"] == 3
    assert s["sum"] == pytest.approx(0.6)
    assert s["min"] == pytest.approx(0.1)
    assert s["p50"] == pytest.approx(0.2)


def test_counter_group_is_the_probe_bridge():
    reg = MetricsRegistry()
    probe = CounterGroup(reg, "k.spmm", ("walks", "bytes"))
    probe["walks"] += 1
    probe["walks"] += 1
    probe["bytes"] += 128
    assert dict(probe) == {"walks": 2, "bytes": 128}
    assert reg.counters("k.spmm.") == {"k.spmm.walks": 2, "k.spmm.bytes": 128}
    for k in probe:          # reset_probe's historic idiom
        probe[k] = 0
    assert reg.counters("k.spmm.") == {"k.spmm.walks": 0, "k.spmm.bytes": 0}


def test_kernel_probe_feeds_global_registry():
    from repro.kernels import groot_spmm

    groot_spmm.reset_probe()
    before = REGISTRY.counters("kernels.spmm.")
    groot_spmm.PROBE["kernel_walks"] += 1
    after = REGISTRY.counters("kernels.spmm.")
    assert after["kernels.spmm.kernel_walks"] == \
        before["kernels.spmm.kernel_walks"] + 1
    assert groot_spmm.probe_snapshot()["kernel_walks"] == 1


def test_fold_into_routes_ints_and_timings():
    reg = MetricsRegistry()
    fold_into(reg, "exec", {"launches": 3, "wall_s": 0.5, "mode": "streamed",
                            "ok": True})
    assert reg.counters() == {"exec.launches": 3}
    assert reg.histogram("exec.wall_s").summary()["count"] == 1


# ---------------------------------------------------------------------------
# Sessions: prefetch-thread parenting, isolation, cached-root tagging
# ---------------------------------------------------------------------------

def test_streamed_verify_parents_pack_spans_across_prefetch_thread(rand_params):
    sess = Session(rand_params, SessionConfig(num_partitions=4, trace=True))
    r = sess.verify(dataset="csa", bits=16, verify=False, use_cache=False)
    assert r.routing.mode == "streamed"
    spans = r.trace.spans()
    stream = [s for s in spans if s.name == "exec.stream"]
    packs = [s for s in spans if s.name == "exec.pack"]
    assert len(stream) == 1 and packs
    for p in packs:
        assert p.parent_id == stream[0].span_id
        assert p.tid != stream[0].tid          # recorded on the prefetch thread
        assert p.thread == "exec-prefetch"
    assert r.trace.coverage() >= 0.95


def test_session_counter_isolation(rand_params):
    s1 = Session(rand_params, SessionConfig(trace=False))
    s2 = Session(rand_params,
                 SessionConfig(num_partitions=2, streaming=False))
    s1.verify(dataset="csa", bits=8, verify=False, use_cache=False)
    c1 = s1.report().session["counters"]
    c2 = s2.report().session["counters"]
    assert c1["session.verifies"] == 1
    assert c1["session.route.full"] == 1
    assert c2 == {}                            # s2 never ran: sees nothing
    s2.verify(dataset="csa", bits=8, verify=False, use_cache=False)
    c1b = s1.report().session["counters"]
    c2b = s2.report().session["counters"]
    assert c1b == c1                           # s2's run invisible to s1
    assert c2b["session.route.partitioned"] == 1


def test_service_queue_depth_gauge_tracks_both_sides(rand_params):
    """``service.queue_depth`` is set on enqueue AND after drain: while
    the device is held mid-pack the gauge's max records the backlog, and
    once the loop drains it the live value returns to zero."""
    from repro.service import VerificationService

    svc = VerificationService(rand_params, num_partitions=1,
                              prepare_workers=2, _warn=False)
    inner = svc.scheduler.runner
    gate = threading.Event()
    entered = threading.Event()

    class _Gated:
        def __getattr__(self, name):
            return getattr(inner, name)

        def __call__(self, batch):
            entered.set()
            assert gate.wait(timeout=60.0)
            return inner(batch)

    svc.scheduler.runner = _Gated()
    try:
        tickets = [svc.submit(dataset="csa", bits=4, seed=0, verify=False)]
        assert entered.wait(timeout=30.0)      # device held mid-pack
        tickets += [svc.submit(dataset="csa", bits=4, seed=s, verify=False)
                    for s in (1, 2)]
        depth = svc.metrics.gauge("service.queue_depth")
        deadline = time.perf_counter() + 30.0
        while depth.max < 1:                   # both enqueues land behind R1
            assert time.perf_counter() < deadline, "enqueue never moved gauge"
            time.sleep(0.005)
    finally:
        gate.set()
    for t in tickets:
        assert svc.result(t, timeout=60.0).status == "classified"
    # the drain side wrote too: backlog consumed, gauge back to zero
    assert depth.max >= 1
    assert depth.value == 0
    svc.close()


def test_cache_hit_root_is_tagged_and_gate_exempt(rand_params):
    sess = Session(rand_params, SessionConfig(trace=True))
    sess.verify(dataset="csa", bits=8, verify=False)
    r2 = sess.verify(dataset="csa", bits=8, verify=False)
    assert r2.cached
    data = sess.obs.tracer.to_chrome()
    roots = [s for s in spans_from_chrome(data)
             if s["name"] == "session.verify"]
    assert len(roots) == 2
    assert [bool(r["attrs"].get("cached")) for r in sorted(
        roots, key=lambda s: s["t0"])] == [False, True]
    # the gate validates the full root and skips the cached one
    assert check_trace(data, ["parse", "plan", "execute", "verdict"],
                       0.95) == []


def test_trace_disabled_produces_no_handle_and_no_spans(rand_params):
    sess = Session(rand_params, SessionConfig(trace=False))
    r = sess.verify(dataset="csa", bits=8, verify=False, use_cache=False)
    assert r.trace is None
    assert sess.obs.tracer is None
    assert sess.report().spans is None
    with pytest.raises(RuntimeError):
        sess.save_trace("/tmp/never-written.json")


# ---------------------------------------------------------------------------
# Acceptance (slow): csa-64 traced once per route — gate + report counters
# ---------------------------------------------------------------------------

#: per-route (config overrides, expected mode, compile counter, byte counter)
ROUTES = [
    ({"num_partitions": 1}, "full",
     "gnn.forward_traces", "gnn.bytes_staged"),
    ({"num_partitions": 4, "streaming": False}, "partitioned",
     "gnn.forward_traces", "gnn.bytes_staged"),
    ({"num_partitions": 4, "streaming": True}, "streamed",
     "exec.compiles", "exec.bytes_h2d"),
]


@pytest.mark.slow
@pytest.mark.parametrize("overrides,mode,compile_ctr,bytes_ctr", ROUTES,
                         ids=[m for _, m, _, _ in ROUTES])
def test_csa64_traced_verify_acceptance(rand_params, tmp_path, overrides,
                                        mode, compile_ctr, bytes_ctr):
    sess = Session(rand_params,
                   SessionConfig(backend="groot", trace=True, **overrides))
    r = sess.verify(dataset="csa", bits=64, verify=False, use_cache=False)
    assert r.routing.mode == mode

    # trace: write/reload the Chrome JSON and run the exact CI gate
    path = tmp_path / f"csa64_{mode}.json"
    r.trace.save(path)
    data = json.loads(path.read_text())
    assert check_trace(data, ["parse", "plan", "execute", "verdict"],
                       0.95) == []
    assert r.trace.coverage() >= 0.95

    # report: non-zero plan-cache, compile, and byte counters for the route
    rep = sess.report()
    pc = rep.plan_cache
    assert pc["builds"] + pc["hits"] > 0
    assert rep.process.get(compile_ctr, 0) > 0
    assert rep.process.get(bytes_ctr, 0) > 0
    assert rep.session["counters"][f"session.route.{mode}"] == 1
    d = rep.to_dict()
    json.dumps(d)                              # report is json-serialisable
    assert d["session"]["counters"]["session.verifies"] == 1
