"""Per-kernel allclose validation: Pallas (interpret=True) vs pure-jnp ref.

Sweeps shapes/dtypes per the brief; hypothesis (when installed) drives
the structural invariants of the degree-bucketing plan (every edge
covered exactly once, pow-2 padding bound).  Without hypothesis the
same properties run over a fixed seed grid instead, so the tier-1 suite
collects and passes in a bare environment.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis
    import hypothesis.strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.core import aig as A
from repro.kernels import ops, ref
from repro.kernels.fused_sage import fused_ld_matmul, fused_ref
from repro.kernels.groot_spmm import apply_plan, build_plan


def random_graph(rng, n, e, hd_rows=0, hd_deg=1500):
    """Random COO graph; optionally a few extreme-degree rows (paper's
    polarized distribution)."""
    src = rng.integers(0, n, e, dtype=np.int64)
    dst = rng.integers(0, n, e, dtype=np.int64)
    if hd_rows:
        hsrc = rng.integers(0, n, hd_rows * hd_deg, dtype=np.int64)
        hdst = np.repeat(rng.choice(n, hd_rows, replace=False), hd_deg)
        src = np.concatenate([src, hsrc])
        dst = np.concatenate([dst, hdst])
    return src.astype(np.int32), dst.astype(np.int32)


TOL = {jnp.float32: 1e-5, jnp.bfloat16: 8e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "n,e,f,hd_rows",
    [
        (64, 256, 8, 0),
        (128, 512, 32, 0),
        (257, 2000, 100, 0),     # non-pow2 everything
        (300, 1024, 128, 2),     # HD rows (degree 1500 > E_T=512)
        (1000, 4000, 64, 1),
        (32, 0, 16, 0),          # empty edge set
    ],
)
@pytest.mark.parametrize("backend", ["groot", "groot_mxu"])
def test_spmm_matches_ref(n, e, f, hd_rows, dtype, backend):
    rng = np.random.default_rng(42 + n + e)
    src, dst = random_graph(rng, n, e, hd_rows)
    x = jnp.asarray(rng.standard_normal((n, f)), dtype)
    w = jnp.asarray(rng.standard_normal(len(src)), dtype)
    pair = ops.make_agg_pair(src, dst, n, backend)
    # Oracle in f32 over the bf16-rounded inputs: the kernels accumulate in
    # f32 regardless of input dtype, so the only tolerated error is the
    # per-product input quantisation (sqrt(deg)-scaled for bf16).
    xf, wf = x.astype(jnp.float32), w.astype(jnp.float32)
    want = ref.spmm_ref(xf, jnp.asarray(src), jnp.asarray(dst), n, wf)
    deg_max = max(int(np.bincount(dst, minlength=n).max()), 1)
    tol = TOL[dtype] * np.sqrt(deg_max)
    got = pair.in_agg(x, w)
    assert got.dtype == x.dtype
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want), rtol=tol, atol=tol
    )
    # unweighted path + fanout direction
    got_out = pair.out_agg(x, None)
    want_out = ref.spmm_ref(xf, jnp.asarray(dst), jnp.asarray(src), n, None)
    deg_max_o = max(int(np.bincount(src, minlength=n).max()), 1)
    tol_o = TOL[dtype] * np.sqrt(deg_max_o)
    np.testing.assert_allclose(
        np.asarray(got_out, np.float32), np.asarray(want_out), rtol=tol_o, atol=tol_o
    )


@pytest.mark.parametrize("f,h", [(4, 32), (32, 32), (100, 60), (128, 256)])
def test_fused_agg_matmul_matches_ref(f, h):
    rng = np.random.default_rng(0)
    n, e = 200, 900
    src, dst = random_graph(rng, n, e)
    x = jnp.asarray(rng.standard_normal((n, f)), jnp.float32)
    w = jnp.asarray(rng.standard_normal(e), jnp.float32)
    w_mat = jnp.asarray(rng.standard_normal((f, h)), jnp.float32)
    pair = ops.make_agg_pair(src, dst, n, "groot_fused")
    want = ref.spmm_ref(x, jnp.asarray(src), jnp.asarray(dst), n, w) @ w_mat
    got = pair.in_agg_mm(x, w, w_mat)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_fused_kernel_body():
    rng = np.random.default_rng(1)
    deg, r, f, h = 4, 64, 128, 128
    msgs = jnp.asarray(rng.standard_normal((r * deg, f)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((f, h)), jnp.float32)
    got = fused_ld_matmul(msgs, w, deg, rows_per_tile=16)
    want = fused_ref(msgs, w, deg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_onehot_backend_matches_ref():
    rng = np.random.default_rng(3)
    n, e, f = 60, 200, 16
    src, dst = random_graph(rng, n, e)
    x = jnp.asarray(rng.standard_normal((n, f)), jnp.float32)
    w = jnp.asarray(rng.standard_normal(e), jnp.float32)
    pair = ops.make_agg_pair(src, dst, n, "onehot")
    want = ref.spmm_ref(x, jnp.asarray(src), jnp.asarray(dst), n, w)
    np.testing.assert_allclose(
        np.asarray(pair.in_agg(x, w)), np.asarray(want), rtol=1e-4, atol=1e-4
    )


def test_ref_matches_dense_oracle():
    rng = np.random.default_rng(4)
    n, e, f = 40, 150, 8
    src, dst = random_graph(rng, n, e)
    x = jnp.asarray(rng.standard_normal((n, f)), jnp.float32)
    w = jnp.asarray(rng.standard_normal(e), jnp.float32)
    a = ref.spmm_ref(x, jnp.asarray(src), jnp.asarray(dst), n, w)
    b = ref.spmm_dense_ref(x, jnp.asarray(src), jnp.asarray(dst), n, w)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


def test_spmm_on_real_aig():
    """The actual workload: a multiplier AIG's fanout direction has the
    polarized degree distribution (PIs feed O(bits) partial products)."""
    aig = A.csa_multiplier(16)
    g = aig.to_edge_graph()
    deg_out = np.bincount(g.edge_src, minlength=g.num_nodes)
    assert deg_out.max() >= 16  # high-fanout PIs exist
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((g.num_nodes, 32)), jnp.float32)
    for direction in ("in", "out"):
        s, d = (g.edge_src, g.edge_dst) if direction == "in" else (g.edge_dst, g.edge_src)
        pair = ops.make_agg_pair(s, d, g.num_nodes, "groot")
        want = ref.spmm_ref(x, jnp.asarray(s), jnp.asarray(d), g.num_nodes, None)
        np.testing.assert_allclose(
            np.asarray(pair.in_agg(x, None)), np.asarray(want), rtol=1e-5, atol=1e-5
        )


# ---------------------------------------------------------------------------
# Plan invariants (property-based)
# ---------------------------------------------------------------------------

def _check_plan_covers_every_edge_exactly_once(n, e, seed):
    rng = np.random.default_rng(seed)
    src, dst = random_graph(rng, n, e)
    plan = build_plan(src, dst, n)
    seen = np.concatenate(
        [b.eids for b in plan.buckets]
        + ([plan.hd.eids] if plan.hd is not None else [np.zeros(0, np.int32)])
    )
    real = seen[seen < e]
    assert sorted(real.tolist()) == list(range(e))
    # row sets are disjoint and complete over rows with degree >= 1
    rows = np.concatenate(
        [b.rows[b.rows >= 0] for b in plan.buckets]
        + ([plan.hd.rows] if plan.hd is not None else [np.zeros(0, np.int32)])
    )
    deg = np.bincount(dst, minlength=n)
    assert len(set(rows.tolist())) == len(rows)
    assert set(rows.tolist()) == set(np.where(deg > 0)[0].tolist())


def _check_spmm_property_random(n, e, f, seed):
    rng = np.random.default_rng(seed)
    src, dst = random_graph(rng, n, e)
    x = jnp.asarray(rng.standard_normal((n, f)), jnp.float32)
    w = jnp.asarray(rng.standard_normal(e), jnp.float32)
    plan = build_plan(src, dst, n)
    got = apply_plan(plan, x, w)
    want = ref.spmm_ref(x, jnp.asarray(src), jnp.asarray(dst), n, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


if HAVE_HYPOTHESIS:

    @hypothesis.given(
        n=st.integers(2, 120),
        e=st.integers(0, 600),
        seed=st.integers(0, 2**31 - 1),
    )
    @hypothesis.settings(max_examples=40, deadline=None)
    def test_plan_covers_every_edge_exactly_once(n, e, seed):
        _check_plan_covers_every_edge_exactly_once(n, e, seed)

    @hypothesis.given(
        n=st.integers(4, 80),
        e=st.integers(1, 400),
        f=st.sampled_from([1, 3, 8, 33]),
        seed=st.integers(0, 2**31 - 1),
    )
    @hypothesis.settings(max_examples=25, deadline=None)
    def test_spmm_property_random(n, e, f, seed):
        _check_spmm_property_random(n, e, f, seed)

else:
    # fallback strategy: a fixed grid covering the same corners (empty edge
    # sets, e < n, e >> n, non-pow2 sizes) with varied seeds
    _PLAN_CASES = [
        (2, 0, 0), (5, 3, 1), (16, 64, 2), (33, 200, 3),
        (64, 600, 4), (97, 96, 5), (120, 377, 6), (50, 1, 7),
    ]
    _SPMM_CASES = [
        (4, 1, 1, 0), (17, 33, 3, 1), (40, 150, 8, 2), (80, 400, 33, 3),
        (64, 64, 8, 4), (33, 100, 1, 5), (79, 399, 3, 6),
    ]

    @pytest.mark.parametrize("n,e,seed", _PLAN_CASES)
    def test_plan_covers_every_edge_exactly_once(n, e, seed):
        _check_plan_covers_every_edge_exactly_once(n, e, seed)

    @pytest.mark.parametrize("n,e,f,seed", _SPMM_CASES)
    def test_spmm_property_random(n, e, f, seed):
        _check_spmm_property_random(n, e, f, seed)


def test_padding_overhead_bounded():
    """pow-2 bucketing pads <= 2x + tile rounding on the real workload."""
    aig = A.csa_multiplier(32)
    g = aig.to_edge_graph()
    plan = build_plan(g.edge_src, g.edge_dst, g.num_nodes)
    # AIG in-degrees are 1 or 2 -> buckets are nearly exact
    assert plan.padding_overhead() < 2.5
    plan_out = build_plan(g.edge_dst, g.edge_src, g.num_nodes)
    assert plan_out.padding_overhead() < 4.0  # fanout is more ragged
