"""Verification service: correctness vs the one-shot pipeline, shape
bucketing (bounded jit compiles), and cache semantics.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core import aig as A
from repro.core import pipeline as P
from repro.io import aiger
from repro.service import VerificationService

pytestmark = pytest.mark.slow  # trains a model + spins up services; full lane
from repro.service.bucketing import BucketShape, WorkItem, pack_batch, unpack_predictions
from repro.kernels import ops


@pytest.fixture(scope="module")
def trained_params():
    params, _ = P.train_model("csa", 8, epochs=200)
    return params


# ---------------------------------------------------------------------------
# Bucketing / padding units (no model needed)
# ---------------------------------------------------------------------------

def test_padded_shape_pow2_with_spare_row():
    n_pad, e_pad = ops.padded_shape(100, 300, min_nodes=16, min_edges=16)
    assert n_pad == 128 and e_pad == 512
    # exact pow-2 node count still gets a spare dummy row
    n_pad, _ = ops.padded_shape(128, 1)
    assert n_pad == 256
    assert ops.padded_shape(3, 0) == (16, 16)


def test_pad_graph_arrays_contract():
    src = np.array([0, 1], np.int32)
    dst = np.array([2, 2], np.int32)
    s, d, inv, slot = ops.pad_graph_arrays(src, dst, None, None, 3, 8, 4)
    assert s.tolist() == [0, 1, 7, 7] and d.tolist() == [2, 2, 7, 7]
    assert not inv.any() and not slot.any()
    with pytest.raises(ValueError):
        ops.pad_graph_arrays(src, dst, None, None, 3, 2, 4)  # n_pad too small


def _item(rid, n, e, seed=0):
    rng = np.random.default_rng(seed)
    return WorkItem(
        req_id=rid,
        part_index=0,
        feats=rng.standard_normal((n, 4)).astype(np.float32),
        edge_src=rng.integers(0, n, e).astype(np.int32),
        edge_dst=rng.integers(0, n, e).astype(np.int32),
        edge_inv=None,
        edge_slot=None,
        num_core=n,
        global_ids=np.arange(n, dtype=np.int64),
    )


def test_pack_batch_slots_are_disjoint():
    items = [_item(0, 10, 20), _item(1, 14, 30, seed=1)]
    shape = BucketShape(16, 32)
    batch = pack_batch(items, shape, capacity=4)
    assert batch["x"].shape == (64, 4)
    assert batch["edge_src"].shape == (128,)
    # slot i's edges stay inside slot i's node range
    for i in range(4):
        sl = slice(i * 32, (i + 1) * 32)
        assert (batch["edge_src"][sl] >= i * 16).all()
        assert (batch["edge_dst"][sl] < (i + 1) * 16).all()
    outs = unpack_predictions(np.arange(64), items, shape)
    assert outs[0].tolist() == list(range(10))
    assert outs[1].tolist() == list(range(16, 30))


# ---------------------------------------------------------------------------
# Service vs one-shot pipeline (acceptance criterion)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("num_partitions", [1, 4])
def test_service_matches_pipeline(trained_params, num_partitions):
    cfg = P.PipelineConfig(dataset="csa", bits=12, num_partitions=num_partitions)
    base = P.run_pipeline(cfg, trained_params, verify_result=True)
    with VerificationService(trained_params, num_partitions=num_partitions) as svc:
        r = svc.result(svc.submit_design("csa", 12), timeout=300)
    assert base.verdict is not None
    assert r.status == base.verdict.status
    assert r.core_accuracy == pytest.approx(base.core_accuracy, abs=1e-12)
    assert r.accuracy == pytest.approx(base.accuracy, abs=1e-12)
    assert r.num_nodes == base.num_nodes


def test_service_aiger_submission_matches_generated(trained_params, tmp_path):
    aig = A.csa_multiplier(10)
    path = tmp_path / "csa10.aig"
    aiger.dump(aig, path)
    with VerificationService(trained_params, num_partitions=2) as svc:
        r_gen = svc.result(svc.submit_design("csa", 10), timeout=300)
        r_aig = svc.result(svc.submit_aiger(path), timeout=300)
    assert r_aig.status == r_gen.status
    assert r_aig.accuracy == pytest.approx(r_gen.accuracy, abs=1e-12)


# ---------------------------------------------------------------------------
# Bucketing efficacy + cache semantics (acceptance criterion)
# ---------------------------------------------------------------------------

def test_same_family_workload_compiles_at_most_num_buckets(trained_params):
    widths = [6, 8, 10, 12]
    with VerificationService(trained_params) as svc:
        tickets = [svc.submit_design("csa", b) for b in widths]
        for t in tickets:
            assert svc.result(t, timeout=300).status != "error"
        stats = svc.scheduler.stats()
        assert stats.compile_count <= len(stats.buckets)
        assert stats.compile_count < len(widths) or len(stats.buckets) == len(widths)
        # resubmitting the whole workload adds zero compilations
        before = svc.scheduler.stats().compile_count
        tickets = [svc.submit_design("csa", b, seed=1) for b in widths]
        for t in tickets:
            svc.result(t, timeout=300)
        assert svc.scheduler.stats().compile_count == before


def test_cache_hit_skips_inference(trained_params):
    with VerificationService(trained_params) as svc:
        r1 = svc.result(svc.submit_design("csa", 8), timeout=300)
        assert not r1.cached
        runs = svc.scheduler.stats().run_count
        r2 = svc.result(svc.submit_design("csa", 8), timeout=300)
        assert r2.cached
        assert r2.status == r1.status and r2.accuracy == r1.accuracy
        assert svc.scheduler.stats().run_count == runs
        assert svc.cache.stats.hits == 1


def test_identical_aiger_files_dedup_via_structural_hash(trained_params):
    data = aiger.dumps(A.csa_multiplier(8))
    with VerificationService(trained_params) as svc:
        r1 = svc.result(svc.submit_aiger(data), timeout=300)
        r2 = svc.result(svc.submit_aiger(data), timeout=300)
    assert not r1.cached and r2.cached


def test_error_requests_are_isolated(trained_params):
    with VerificationService(trained_params) as svc:
        bad = svc.submit_aiger(b"garbage\n")
        good = svc.submit_design("csa", 6)
        r_bad = svc.result(bad, timeout=300)
        r_good = svc.result(good, timeout=300)
    assert r_bad.status == "error" and r_bad.error
    assert r_good.status != "error"


def test_structure_keyed_runner_bounds_jit_cache():
    """groot-backed runner drops its jit cache past max_structures, so a
    stream of distinct structures cannot grow memory monotonically."""
    import jax
    from repro.core import gnn
    from repro.service.scheduler import BucketRunner

    params = gnn.init_params(gnn.GNNConfig(hidden=8, num_layers=1), jax.random.key(0))
    runner = BucketRunner(params, backend="groot", max_structures=2)
    rng = np.random.default_rng(0)
    for i in range(4):  # 4 distinct structures through a cap of 2
        n, e = 32, 64
        batch = {
            "x": rng.standard_normal((n, 4)).astype(np.float32),
            "edge_src": rng.integers(0, n, e).astype(np.int32),
            "edge_dst": rng.integers(0, n, e).astype(np.int32),
            "edge_inv": np.zeros(e, bool),
            "edge_slot": np.zeros(e, np.uint8),
            "num_nodes": n,
        }
        pred = runner(batch)
        assert pred.shape == (n,)
    assert runner.jit_cache_clears >= 1
    assert len(runner._structures_seen) <= 2


def test_poll_is_nonblocking_and_unknown_ticket_raises(trained_params):
    with VerificationService(trained_params) as svc:
        t = svc.submit_design("csa", 6)
        svc.poll(t)  # may be None or a result; must not raise
        r = svc.result(t, timeout=300)
        assert svc.poll(t) is r
        with pytest.raises(KeyError):
            svc.poll(10_000)
