"""The continuous-batching device loop (`repro.service.server`).

Fast lane, untrained params: these tests pin the loop's *scheduling*
semantics, not verification accuracy —

  * mid-flight admission: a request prepared while a pack is on the
    device joins the very next same-bucket pack instead of waiting out
    a wave barrier;
  * priority lanes: a later priority-0 submission runs before an earlier
    priority-5 one under a saturated queue;
  * compile-ahead warmup: zero cold compiles after warmup, probe-gated;
  * per-tenant admission caps: AdmissionError at the cap, slot freed on
    completion;
  * in-flight coalescing: concurrent same-key submissions share one
    execution, followers finish cached;
  * failed tickets carry an attributable name (never "?").

Device-side timing is made deterministic by gating the BucketRunner: the
device thread blocks inside its first call until the test releases it,
so "arrives mid-flight" is a guaranteed interleaving, not a race.
"""
from __future__ import annotations

import threading
import time

import jax
import pytest

from repro.core import gnn
from repro.service import AdmissionError, SlotPool, VerificationService
from repro.service.bucketing import BucketShape, dummy_item


@pytest.fixture(scope="module")
def rand_params():
    return gnn.init_params(gnn.GNNConfig(), jax.random.key(0))


def make_service(params, **overrides):
    overrides.setdefault("num_partitions", 1)
    overrides.setdefault("prepare_workers", 2)
    return VerificationService(params, _warn=False, **overrides)


class GatedRunner:
    """Wraps a BucketRunner: every call blocks until ``release()``.

    Lets a test hold the device mid-pack, queue more requests, and then
    observe exactly how the loop admits them.
    """

    def __init__(self, inner):
        self._inner = inner
        self._gate = threading.Event()
        self.entered = threading.Event()     # set when a call is blocking

    def __getattr__(self, name):
        return getattr(self._inner, name)

    def release(self):
        self._gate.set()

    def __call__(self, batch):
        self.entered.set()
        assert self._gate.wait(timeout=60.0), "gate never released"
        return self._inner(batch)


def wait_for(cond, timeout=30.0, msg="condition"):
    t0 = time.perf_counter()
    while not cond():
        if time.perf_counter() - t0 > timeout:
            raise AssertionError(f"timed out waiting for {msg}")
        time.sleep(0.005)


# ---------------------------------------------------------------------------
# SlotPool unit semantics (no service needed)
# ---------------------------------------------------------------------------

def test_slot_pool_orders_by_priority_then_arrival():
    pool = SlotPool()
    a, b = BucketShape(64, 128), BucketShape(128, 256)
    pool.admit(a, 1, 0, "a0")
    pool.admit(b, 0, 1, "b0")      # later arrival, higher priority
    pool.admit(a, 1, 2, "a1")
    assert len(pool) == 3
    assert pool.best_bucket() == b
    assert pool.take(b, 4) == [(0, 1, "b0")]
    assert pool.best_bucket() == a
    assert [p for (_, _, p) in pool.take(a, 1)] == ["a0"]
    assert [p for (_, _, p) in pool.take(a, 4)] == ["a1"]
    assert len(pool) == 0 and pool.best_bucket() is None


# ---------------------------------------------------------------------------
# Continuous batching: mid-flight admission, priority lanes
# ---------------------------------------------------------------------------

def test_mid_flight_request_joins_next_pack(rand_params):
    """R2/R3 are prepared while R1's pack is on the device; when it
    returns, they share ONE pack (capacity 2) instead of arriving as
    separate waves."""
    svc = make_service(rand_params, capacity=2)
    gate = GatedRunner(svc.scheduler.runner)
    svc.scheduler.runner = gate
    try:
        t1 = svc.submit(dataset="csa", bits=4, seed=0, verify=False)
        assert gate.entered.wait(timeout=30.0)   # R1's pack is in flight
        t2 = svc.submit(dataset="csa", bits=4, seed=1, verify=False)
        t3 = svc.submit(dataset="csa", bits=4, seed=2, verify=False)
        wait_for(lambda: svc._device_q.qsize() >= 2, msg="R2+R3 prepared")
    finally:
        gate.release()
    rs = [svc.result(t, timeout=60.0) for t in (t1, t2, t3)]
    assert [r.status for r in rs] == ["classified"] * 3
    log = list(svc.scheduler.pack_log)
    assert [sorted(ids) for (_, ids, _) in log] == [[t1], sorted([t2, t3])]
    assert [fill for (_, _, fill) in log] == [0.5, 1.0]
    svc.close()


def test_priority_lane_overtakes_under_saturation(rand_params):
    """With the device saturated, a priority-0 submission made AFTER a
    priority-5 one still runs first."""
    svc = make_service(rand_params, capacity=1)
    gate = GatedRunner(svc.scheduler.runner)
    svc.scheduler.runner = gate
    try:
        t0 = svc.submit(dataset="csa", bits=4, seed=0, verify=False)
        assert gate.entered.wait(timeout=30.0)
        t_slow = svc.submit(dataset="csa", bits=4, seed=1, verify=False,
                            priority=5)
        wait_for(lambda: svc._device_q.qsize() >= 1, msg="bulk queued")
        t_fast = svc.submit(dataset="csa", bits=4, seed=2, verify=False,
                            priority=0)
        wait_for(lambda: svc._device_q.qsize() >= 2, msg="express queued")
    finally:
        gate.release()
    for t in (t0, t_slow, t_fast):
        svc.result(t, timeout=60.0)
    order = [ids[0] for (_, ids, _) in svc.scheduler.pack_log]
    assert order == [t0, t_fast, t_slow]
    svc.close()


# ---------------------------------------------------------------------------
# Compile-ahead warmup: probe-gated zero cold compiles
# ---------------------------------------------------------------------------

def test_warmup_then_zero_cold_compiles(rand_params):
    from repro.core import aig as A
    from repro.kernels import ops

    g = A.make_design("csa", 4).to_edge_graph()
    shape = ops.padded_shape(g.num_nodes, g.num_edges,
                             min_nodes=64, min_edges=128)
    svc = make_service(rand_params, warmup=True, warmup_shapes=(shape,),
                       capacity=2)
    st = svc.stats()
    assert svc.scheduler.runner.warmed
    assert st["warm_compiles"] >= 1
    assert st["warmup_s"] > 0.0
    tickets = [svc.submit(dataset="csa", bits=4, seed=s, verify=False)
               for s in range(4)]
    for t in tickets:
        assert svc.result(t, timeout=60.0).status == "classified"
    st = svc.stats()
    assert st["cold_compiles"] == 0, "a warmed bucket re-traced"
    assert st["compile_count"] == st["warm_compiles"]
    # the loop recorded slot occupancy and admission latency
    assert st["obs"]["gauges"]["service.slot_occupancy"]["max"] > 0
    assert st["obs"]["histograms"]["service.admission_s"]["count"] == 4
    svc.close()


def test_unwarmed_bucket_counts_cold(rand_params):
    """The probe is live: warming shape A then submitting a shape-B
    design must register a cold compile."""
    svc = make_service(rand_params, warmup=True,
                       warmup_shapes=((64, 128),))
    t = svc.submit(dataset="csa", bits=6, seed=0, verify=False)
    svc.result(t, timeout=60.0)
    assert svc.stats()["cold_compiles"] >= 1
    svc.close()


def test_scheduler_warm_covers_stream_capacity():
    """With bucket ceilings set, warm(stream=True) compiles BOTH slot
    layouts, so the streamed route pays no cold jit either."""
    params = gnn.init_params(gnn.GNNConfig(), jax.random.key(1))
    from repro.service import ShapeBucketScheduler

    sched = ShapeBucketScheduler(params, capacity=4, stream_capacity=2,
                                 max_bucket_nodes=256, max_bucket_edges=512)
    n = sched.warm([(64, 128)], stream=True)
    assert n == 2                    # one trace per (bucket, capacity) layout
    out = sched.run_pack([dummy_item(sched.runner.in_features)],
                         BucketShape(64, 128))
    assert sched.runner.cold_compile_count == 0
    assert set(out) == {(-1, 0)}


# ---------------------------------------------------------------------------
# Admission control: tenant caps, coalescing
# ---------------------------------------------------------------------------

def test_tenant_cap_rejects_then_frees(rand_params):
    svc = make_service(rand_params, max_inflight_per_tenant=2)
    gate = GatedRunner(svc.scheduler.runner)
    svc.scheduler.runner = gate
    try:
        t1 = svc.submit(dataset="csa", bits=4, seed=0, verify=False,
                        tenant="acme")
        t2 = svc.submit(dataset="csa", bits=4, seed=1, verify=False,
                        tenant="acme")
        with pytest.raises(AdmissionError):
            svc.submit(dataset="csa", bits=4, seed=2, verify=False,
                       tenant="acme")
        # another tenant is unaffected by acme's saturation
        t3 = svc.submit(dataset="csa", bits=4, seed=3, verify=False,
                        tenant="bob")
    finally:
        gate.release()
    for t in (t1, t2, t3):
        svc.result(t, timeout=60.0)
    # finishing freed the slots
    t4 = svc.submit(dataset="csa", bits=4, seed=4, verify=False,
                    tenant="acme")
    svc.result(t4, timeout=60.0)
    assert svc.metrics.counter("service.rejected").value == 1
    svc.close()


def test_concurrent_duplicates_coalesce_to_one_execution(rand_params):
    svc = make_service(rand_params)
    gate = GatedRunner(svc.scheduler.runner)
    svc.scheduler.runner = gate
    try:
        lead = svc.submit(dataset="csa", bits=4, seed=0, verify=False)
        assert gate.entered.wait(timeout=30.0)
        followers = [svc.submit(dataset="csa", bits=4, seed=0, verify=False)
                     for _ in range(3)]
    finally:
        gate.release()
    r_lead = svc.result(lead, timeout=60.0)
    r_follow = [svc.result(t, timeout=60.0) for t in followers]
    assert not r_lead.cached
    assert all(r.cached for r in r_follow)
    assert {r.status for r in r_follow} == {r_lead.status}
    assert {r.name for r in r_follow} == {r_lead.name}
    # ids are per-ticket even though the execution was shared
    assert sorted(r.req_id for r in r_follow) == sorted(followers)
    assert svc.metrics.counter("service.coalesced").value == 3
    assert svc.scheduler.runner.run_count == 1
    svc.close()


def test_coalesce_off_runs_every_request(rand_params):
    svc = make_service(rand_params, coalesce=False)
    gate = GatedRunner(svc.scheduler.runner)
    svc.scheduler.runner = gate
    try:
        tickets = [svc.submit(dataset="csa", bits=4, seed=0, verify=False)
                   for _ in range(2)]
        assert gate.entered.wait(timeout=30.0)
    finally:
        gate.release()
    rs = [svc.result(t, timeout=60.0) for t in tickets]
    # second request hits the result cache only if the first finished
    # before it was admitted; it must NOT be coalesced
    assert svc.metrics.counter("service.coalesced").value == 0
    assert rs[0].status == "classified"
    svc.close()


# ---------------------------------------------------------------------------
# Failure attribution (no more name="?")
# ---------------------------------------------------------------------------

def test_failed_generated_request_is_attributable(rand_params):
    svc = make_service(rand_params)
    t = svc.submit(dataset="no-such-family", bits=8)
    r = svc.result(t, timeout=60.0)
    assert r.status == "error" and r.error
    assert r.name == "no-such-family:8"
    svc.close()


def test_failed_aiger_request_uses_comment_name(rand_params):
    svc = make_service(rand_params)
    bad = b"not an aiger header\nc\ngroot-name revision_42\n"
    t = svc.submit(aiger_bytes=bad)
    r = svc.result(t, timeout=60.0)
    assert r.status == "error"
    assert r.name == "revision_42"
    # nameless garbage still gets the format tag, never "?"
    t2 = svc.submit(aiger_bytes=b"also not aiger\n")
    r2 = svc.result(t2, timeout=60.0)
    assert r2.status == "error" and r2.name == "aiger"
    svc.close()
