"""Serving example: batched prefill + greedy decode with KV caches, on a
reduced qwen3 config — the same serve_step the decode_32k/long_500k
dry-run shapes lower.

    PYTHONPATH=src python examples/serve_lm.py
"""
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")

from repro.zoo.configs import get_config
from repro.zoo.configs.base import materialize, model_spec_tree
from repro.zoo.serving.decode import greedy_generate, make_prefill_step, make_serve_step

cfg = get_config("qwen3-8b", smoke=True)
params = materialize(model_spec_tree(cfg), jax.random.key(0), jnp.float32)

B, S_PROMPT, STEPS = 4, 24, 16
rng = np.random.default_rng(0)
prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S_PROMPT)), jnp.int32)

print(f"prefill: batch={B} prompt_len={S_PROMPT}")
prefill = jax.jit(make_prefill_step(cfg, S_PROMPT + STEPS))
serve = jax.jit(make_serve_step(cfg))

t0 = time.perf_counter()
last_logits, cache = prefill(params, prompt)
tok = jnp.argmax(last_logits, axis=-1)[:, None].astype(jnp.int32)
print(f"  prefill done in {time.perf_counter()-t0:.2f}s (incl. compile)")

outs = [tok]
t0 = time.perf_counter()
for i in range(STEPS - 1):
    tok, _, cache = serve(params, cache, tok)
    outs.append(tok)
dt = time.perf_counter() - t0
gen = jnp.concatenate(outs, axis=1)
print(f"decoded {STEPS-1} steps x {B} seqs in {dt:.2f}s "
      f"({(STEPS-1)*B/dt:.1f} tok/s incl. compile)")
print("generated ids:\n", np.asarray(gen))

# consistency: the scan-based reference generator matches the step loop
ref = greedy_generate(params, cfg, prompt, steps=STEPS, max_seq=S_PROMPT + STEPS)
assert np.array_equal(np.asarray(ref)[:, :gen.shape[1]], np.asarray(gen)), (
    "scan generator disagrees with step loop"
)
print("scan-generator consistency: OK")
