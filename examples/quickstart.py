"""Quickstart: GROOT end-to-end — train the GNN on an 8-bit multiplier,
verify a 32-bit multiplier with partitioning + boundary edge re-growth.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import pipeline as P

print("1) training GraphSAGE on the 8-bit CSA multiplier (paper's setup)...")
params, hist = P.train_model("csa", 8, epochs=300)
print(f"   final loss: {hist[-1][1]:.2e}")

print("2) verifying a 32-bit CSA multiplier, unpartitioned...")
r = P.run_pipeline(
    P.PipelineConfig(dataset="csa", bits=32, num_partitions=1),
    params,
    verify_result=True,
)
print(f"   accuracy {r.accuracy:.2%}  memory {r.peak_memory_bytes/1e6:.1f} MB  "
      f"verdict: {r.verdict.status}")

print("3) same design, 8 partitions WITHOUT re-growth...")
r_no = P.run_pipeline(
    P.PipelineConfig(dataset="csa", bits=32, num_partitions=8, regrow=False),
    params,
)
print(f"   accuracy {r_no.accuracy:.2%}  memory {r_no.peak_memory_bytes/1e6:.1f} MB")

print("4) 8 partitions WITH boundary edge re-growth (paper Alg. 1)...")
r_re = P.run_pipeline(
    P.PipelineConfig(dataset="csa", bits=32, num_partitions=8, regrow=True),
    params,
)
print(f"   accuracy {r_re.accuracy:.2%}  memory {r_re.peak_memory_bytes/1e6:.1f} MB")
print(f"\n   re-growth recovered +{(r_re.accuracy - r_no.accuracy)*100:.2f}% accuracy")
print(f"   memory reduced {(1 - r_re.peak_memory_bytes / r.unpartitioned_memory_bytes)*100:.1f}% vs unpartitioned")

print("5) inference through the Pallas GROOT kernels (interpret mode)...")
r_k = P.run_pipeline(
    P.PipelineConfig(dataset="csa", bits=16, aggregate="groot_fused"),
    params,
)
print(f"   accuracy {r_k.accuracy:.2%} (HD/LD degree-bucketed kernel path)")
