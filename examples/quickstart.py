"""Quickstart: GROOT end-to-end through the `repro.api.Session` façade —
train the GNN on an 8-bit multiplier, then verify a larger one through
every execution route the session can take: full graph, partitioned with
and without re-growth, streamed under a device memory budget, and the
Pallas kernel backends.

    PYTHONPATH=src python examples/quickstart.py            # full demo
    PYTHONPATH=src python examples/quickstart.py --quick    # CI smoke run
"""
import argparse

from repro.api import Session, SessionConfig

ap = argparse.ArgumentParser()
ap.add_argument("--quick", action="store_true",
                help="small bits / few epochs (the CI fast-lane smoke test)")
ap.add_argument("--trace", metavar="OUT.json", default=None,
                help="record every verify below and write a Chrome-trace "
                     "JSON (derived sessions share the base tracer)")
ap.add_argument("--chaos", action="store_true",
                help="seeded fault-injection smoke: transient device "
                     "faults through the service path must be retried "
                     "away without changing the result")
args = ap.parse_args()
BITS = 16 if args.quick else 32
EPOCHS = 120 if args.quick else 300

sess = Session(config=SessionConfig(dataset="csa", bits=BITS,
                                    trace=bool(args.trace)))

print("1) training GraphSAGE on the 8-bit CSA multiplier (paper's setup)...")
hist = sess.train("csa", 8, epochs=EPOCHS)
print(f"   final loss: {hist[-1][1]:.2e}")

print(f"2) verifying a {BITS}-bit CSA multiplier, unpartitioned...")
r = sess.verify()
print(f"   route: {r.routing.mode} — {r.routing.reason}")
print(f"   accuracy {r.accuracy:.2%}  memory {r.peak_memory_bytes/1e6:.1f} MB  "
      f"verdict: {r.verdict.status}")

print("3) same design, 8 partitions WITHOUT re-growth...")
r_no = sess.options(num_partitions=8, regrow=False).verify(verify=False)
print(f"   route: {r_no.routing.mode} (k={r_no.routing.k}, "
      f"{r_no.routing.num_buckets} buckets)")
print(f"   accuracy {r_no.accuracy:.2%}  memory {r_no.peak_memory_bytes/1e6:.1f} MB")

print("4) 8 partitions WITH boundary edge re-growth (paper Alg. 1)...")
r_re = sess.options(num_partitions=8, regrow=True).verify(verify=False)
print(f"   accuracy {r_re.accuracy:.2%}  memory {r_re.peak_memory_bytes/1e6:.1f} MB")
print(f"\n   re-growth recovered +{(r_re.accuracy - r_no.accuracy)*100:.2f}% accuracy")
print(f"   memory reduced {(1 - r_re.peak_memory_bytes / r.unpartitioned_memory_bytes)*100:.1f}% vs unpartitioned")

print("5) a device memory budget: the router partitions and streams to fit...")
import jax  # noqa: E402 — only consulted for the device count

n_devices = jax.local_device_count()
stream_mode = "sharded" if n_devices > 1 else "streamed"
budget = sess.options(memory_budget_bytes=r.unpartitioned_memory_bytes // 3)
decision = budget.explain()
print(f"   explain(): {decision.reason}")
r_st = budget.verify(verify=False)
assert r_st.routing.mode == decision.mode == stream_mode
print(f"   accuracy {r_st.accuracy:.2%}  "
      f"packed peak {r_st.routing.modeled_peak_bytes/1e6:.1f} MB  "
      f"compiles {r_st.exec_stats['compiles']}  "
      f"launches {r_st.exec_stats['launches']}")

if n_devices > 1:
    print(f"6) sharding the stream across {n_devices} devices (repro.mesh, "
          f"CI fakes them via XLA_FLAGS)...")
    shard = sess.options(num_partitions=8)
    d_sh = shard.explain()
    assert d_sh.mode == "sharded" and d_sh.mesh_devices == n_devices
    print(f"   explain(): {d_sh.reason}")
    r_sh = shard.verify(verify=False, return_predictions=True)
    r_1d = shard.options(mesh_devices=1).verify(
        verify=False, return_predictions=True)
    # the two gates CI holds the mesh to: a compile unit per BUCKET
    # shared by all lanes (never per device), and a bit-identical verdict
    assert r_sh.exec_stats["compiles"] <= d_sh.num_buckets, (
        r_sh.exec_stats["compiles"], d_sh.num_buckets)
    assert (r_sh.predictions == r_1d.predictions).all()
    print(f"   verdict bit-identical to the single-device route; "
          f"compiles {r_sh.exec_stats['compiles']} <= "
          f"{d_sh.num_buckets} buckets across {n_devices} devices")
else:
    print("6) sharding across devices: skipped (1 visible device; set "
          "XLA_FLAGS=--xla_force_host_platform_device_count=4 to fake a "
          "mesh on CPU)")

print("7) inference through the Pallas GROOT kernels (interpret mode)...")
r_k = sess.options(backend="groot_fused").verify(
    bits=8 if args.quick else 16, verify=False
)
print(f"   accuracy {r_k.accuracy:.2%} (HD/LD degree-bucketed kernel path)")

if args.trace:
    sess.save_trace(args.trace)
    rep = sess.report()
    print(f"\n8) observability: {rep!r}")
    print(f"   trace written to {args.trace}")

if args.chaos:
    from repro import faults

    print("\n9) chaos smoke: two injected transient device faults, retried "
          "away (repro.faults)...")
    chaos = sess.options(launch_retries=3, retry_backoff_s=0.01)
    with faults.injected("service.device:every=1,kind=transient,max_fires=2,seed=5"):
        ticket = chaos.submit(bits=8, verify=False)
        rr = chaos.result(ticket, timeout=300)
    chaos.close()
    assert rr.status == "classified", f"chaos smoke failed: {rr.error}"
    retried = chaos.obs.metrics.snapshot()["counters"].get("service.retries", 0)
    assert retried == 2, f"expected exactly 2 replayed transients, saw {retried}"
    print(f"   survived {retried} injected faults; status {rr.status!r}, "
          f"accuracy {rr.accuracy:.2%}")
