"""End-to-end driver: train a ~100M-param qwen3-family model for a few
hundred steps on the synthetic structured token stream, with the full
production machinery — sharded params (data x model host mesh), remat,
microbatching, async checkpointing, straggler monitor, resume-on-restart.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

(On 1 CPU device the mesh is 1x1; the same script drives the production
mesh via --mesh pod on a real cluster — see repro/launch/train.py.)
"""
import argparse
import dataclasses
import sys
import tempfile

import jax
import jax.numpy as jnp

sys.path.insert(0, "src")

from repro.zoo.configs.base import ModelConfig, materialize, model_spec_tree
from repro.distributed.fault_tolerance import ResilientLoop
from repro.launch.mesh import make_host_mesh
from repro.sharding.rules import make_rules, tree_shardings, use_sharding
from repro.training import optimizer as opt_mod
from repro.training.data import TokenStream, TokenStreamConfig
from repro.training.train_step import make_train_step


def lm100m(layers: int = 10, dim: int = 768) -> ModelConfig:
    """~100M params at the defaults, qwen3 family (qk-norm + GQA).
    (--layers/--dim shrink it for 1-core CI validation.)"""
    heads = max(dim // 64, 2)
    return ModelConfig(
        name="qwen3-100m", family="dense",
        num_layers=layers, d_model=dim, num_heads=heads,
        num_kv_heads=max(heads // 3, 1),
        d_ff=4 * dim, vocab_size=8192, qk_norm=True, rope_theta=1e6,
        tie_embeddings=False, layer_pattern=("global",),
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--layers", type=int, default=10)
    ap.add_argument("--dim", type=int, default=768)
    args = ap.parse_args()

    cfg = lm100m(args.layers, args.dim)
    mesh = make_host_mesh()
    rules = make_rules(mesh)
    spec = model_spec_tree(cfg)
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="lm100m_ckpt_")

    with use_sharding(mesh):
        params = jax.device_put(
            materialize(spec, jax.random.key(0), jnp.float32),
            tree_shardings(spec, mesh, rules),
        )
        n = sum(x.size for x in jax.tree.leaves(params))
        print(f"model: {n/1e6:.1f}M params on mesh {dict(mesh.shape)}")

        opt = opt_mod.AdamW(lr=3e-4, weight_decay=0.01)
        opt_state = opt.init(params)
        step_fn = jax.jit(
            make_train_step(cfg, opt, microbatches=2), donate_argnums=(0, 1)
        )

        def loop_step(state, batch):
            p, o = state
            p, o, m = step_fn(p, o, {"tokens": jnp.asarray(batch)})
            return (p, o), m

        stream = TokenStream(
            TokenStreamConfig(cfg.vocab_size, args.seq, args.batch, structure=8)
        )
        loop = ResilientLoop(
            loop_step, (params, opt_state), ckpt_dir=ckpt_dir, ckpt_every=100
        )
        if loop.resumed:
            print(f"resumed at step {loop.step}")
        first = last = None
        batches = (stream.batch_at(s) for s in range(loop.step, args.steps))
        for step, metrics in loop.run(batches, steps=args.steps):
            loss = float(metrics["loss"])
            first = loss if first is None else first
            last = loss
            if step % 20 == 0:
                print(f"step {step:4d}  loss {loss:.4f}", flush=True)
        print(f"\nloss {first:.3f} -> {last:.3f} "
              f"(structured stream entropy floor ~ corruption rate)")
        print(f"checkpoints in {ckpt_dir}; stragglers: {len(loop.stragglers)}")
        assert last < first * 0.7, "training did not learn"


if __name__ == "__main__":
    main()
